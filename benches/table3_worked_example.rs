//! Regenerates Table 3 / the §3.1.2 worked example and times the exact
//! and MILP assignment solvers on it.

use agentic_hetero::opt::assignment::worked_example;
use agentic_hetero::repro;
use agentic_hetero::util::bench::Bench;

fn main() {
    let art = repro::table3();
    println!("=== {} ===\n{}", art.title, art.text);

    let p = worked_example();
    let mut b = Bench::new();
    b.run("table3/solve_exact", || p.solve_exact().unwrap());
    b.run("table3/solve_relaxed_milp", || {
        let mut q = p.clone();
        q.edges.clear();
        q.solve_relaxed().unwrap()
    });
    b.run("table3/evaluate_assignment", || p.evaluate(&[0, 1]));
}
