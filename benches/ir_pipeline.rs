//! IR pipeline throughput: parse, print, verify, and the standard pass
//! pipeline over agent graphs (the slow-path planning front half).

use agentic_hetero::agents::{self, patterns};
use agentic_hetero::ir::parser::parse;
use agentic_hetero::ir::passes::PassManager;
use agentic_hetero::ir::printer::print;
use agentic_hetero::ir::verifier::verify;
use agentic_hetero::util::bench::Bench;

fn main() {
    let mut b = Bench::new();
    let voice = agents::voice_agent("8b-fp16", 512, 256);
    let text = print(&voice);
    println!("voice agent: {} nodes, {} bytes of IR text", voice.size(), text.len());

    b.run("ir/print_voice", || print(&voice));
    b.run("ir/parse_voice", || parse(&text).unwrap());
    b.run("ir/verify_voice", || verify(&voice).unwrap());
    b.run("ir/std_pipeline_voice", || {
        let mut g = voice.clone();
        PassManager::standard().run(&mut g).unwrap();
        g.size()
    });

    let big = patterns::hierarchical("8b-fp16", 3, 3); // 27 leaves
    println!("hierarchical(3,3): {} nodes", big.size());
    b.run("ir/std_pipeline_hierarchical27", || {
        let mut g = big.clone();
        PassManager::standard().run(&mut g).unwrap();
        g.size()
    });
}
