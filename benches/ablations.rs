//! Ablations over the design choices DESIGN.md calls out:
//!
//! 1. disaggregated vs monolithic serving (the paper's core mechanism);
//! 2. cache-locality routing vs pure least-loaded (fast path);
//! 3. paged vs contiguous KV allocation (memory efficiency);
//! 4. bucketed batching vs batch=1 (runtime throughput, when artifacts
//!    are present).

use agentic_hetero::cost::hardware::by_name;
use agentic_hetero::cost::model_profile::{llama3_70b, llama3_8b};
use agentic_hetero::cost::Precision;
use agentic_hetero::kvcache::manager::{CacheManager, NodeBudget};
use agentic_hetero::kvcache::paged::PagedAllocator;
use agentic_hetero::opt::parallelism::{
    best_config, best_monolithic_config, ExploreOpts, SeqShape, SlaMode,
};
use agentic_hetero::util::rng::Rng;

fn main() {
    ablation_disaggregation();
    ablation_locality_routing();
    ablation_paged_vs_contiguous();
    ablation_batching();
}

fn ablation_disaggregation() {
    println!("=== ablation 1: disaggregated vs monolithic (tokens/s/$) ===");
    let opts = ExploreOpts::default();
    for m in [llama3_8b(Precision::Fp16), llama3_70b(Precision::Fp8)] {
        for sla in [SlaMode::paper_latency(), SlaMode::Throughput] {
            let h = by_name("H100").unwrap();
            let g = by_name("Gaudi3").unwrap();
            let mono = best_monolithic_config(&m, &h, SeqShape::fig8(), sla, &opts);
            let disagg_homo = best_config(&m, &h, &h, SeqShape::fig8(), sla, &opts);
            let disagg_het = best_config(&m, &h, &g, SeqShape::fig8(), sla, &opts);
            let fmt = |c: &Option<agentic_hetero::opt::parallelism::EvaluatedConfig>| {
                c.as_ref()
                    .map(|c| format!("{:>10.0}", c.tokens_per_usd))
                    .unwrap_or_else(|| "  infeasible".into())
            };
            println!(
                "  {:<22} {:<15} mono(H100) {}  disagg(H100::H100) {}  disagg(H100::Gaudi3) {}",
                m.name,
                sla.name(),
                fmt(&mono),
                fmt(&disagg_homo),
                fmt(&disagg_het)
            );
        }
    }
}

fn ablation_locality_routing() {
    println!("\n=== ablation 2: cache-locality routing vs least-loaded ===");
    // 8 workers; 80% of requests belong to sessions with cached KV.
    // Metric: fraction of requests that avoid a KV restore/transfer.
    use agentic_hetero::router::router::{Router, RouterConfig, WorkerState};
    let mut rng = Rng::new(9);
    for use_locality in [true, false] {
        let mut router = Router::new(RouterConfig::default());
        for id in 0..8 {
            router.upsert_worker(WorkerState {
                id,
                models: vec!["tiny".into()],
                outstanding: 0,
                draining: false,
            });
        }
        let mut cache = CacheManager::new(
            (0..8)
                .map(|_| NodeBudget { hbm: 1e12, dram: 1e12, disk: 1e15 })
                .collect(),
        );
        for s in 0..256u64 {
            cache.insert(s, (s % 8) as u32, 1e6, s).unwrap();
        }
        let mut hits = 0;
        let n = 10_000;
        for _ in 0..n {
            let session = (rng.f64() < 0.8).then(|| rng.range(0, 256));
            let sid = if use_locality { session } else { None };
            let (worker, _) = router.route("tiny", sid, None, &cache).unwrap();
            if let Some(s) = session {
                if cache.locate(s).map(|(node, _)| node) == Some(worker) {
                    hits += 1;
                }
            }
            router.note_dispatch(worker);
            router.note_complete(worker); // steady state
        }
        println!(
            "  locality={:<5} KV-local rate {:>5.1}%",
            use_locality,
            hits as f64 / n as f64 * 100.0
        );
    }
}

fn ablation_paged_vs_contiguous() {
    println!("\n=== ablation 3: paged vs contiguous KV allocation ===");
    // Contiguous baseline must reserve max_seq upfront; paged grows on
    // demand. Metric: concurrent sequences supported by the same pool
    // for a mixed-length workload (mean 256 of max 2048 tokens).
    let pool_tokens: u64 = 64 * 2048;
    let mut rng = Rng::new(4);
    let lens: Vec<u64> = (0..4096).map(|_| rng.range(32, 512)).collect();

    // Contiguous: each sequence reserves 2048 tokens.
    let contiguous_capacity = pool_tokens / 2048;

    // Paged (16-token pages): admit until alloc fails.
    let mut paged = PagedAllocator::new((pool_tokens / 16) as u32, 16);
    let mut admitted = 0u64;
    for (i, len) in lens.iter().enumerate() {
        if paged.alloc_seq(i as u64, *len).is_err() {
            break;
        }
        admitted += 1;
    }
    println!(
        "  pool of {} tokens: contiguous {} seqs, paged {} seqs ({}x), frag {:.1}%",
        pool_tokens,
        contiguous_capacity,
        admitted,
        admitted / contiguous_capacity,
        paged.fragmentation() * 100.0
    );
}

fn ablation_batching() {
    println!("\n=== ablation 4: bucketed batching vs batch=1 (real runtime) ===");
    let Ok(engine) = agentic_hetero::runtime::Engine::load("artifacts") else {
        println!("  skipped (run `make artifacts`)");
        return;
    };
    use std::time::Instant;
    let n_reqs = 8;
    let max_new = 16;
    let prompts: Vec<Vec<u8>> = (0..n_reqs)
        .map(|i| format!("ablation request {i} ").into_bytes())
        .collect();

    // batch=1: serial generation.
    let t0 = Instant::now();
    for p in &prompts {
        engine.generate_greedy(std::slice::from_ref(p), max_new).unwrap();
    }
    let serial = t0.elapsed().as_secs_f64();

    // bucket=4: two batched runs.
    let t0 = Instant::now();
    for chunk in prompts.chunks(4) {
        engine.generate_greedy(chunk, max_new).unwrap();
    }
    let batched = t0.elapsed().as_secs_f64();
    let tokens = (n_reqs * max_new) as f64;
    println!(
        "  batch=1: {:.0} tok/s   bucket=4: {:.0} tok/s   speedup {:.2}x",
        tokens / serial,
        tokens / batched,
        serial / batched
    );
}
