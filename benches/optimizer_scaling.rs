//! Optimizer scaling: LP simplex, branch&bound MILP, exact assignment
//! solver, and the end-to-end planner on agent graphs. §Perf target:
//! |V|=64, |H|=6 well under 50 ms.

use agentic_hetero::agents;
use agentic_hetero::opt::assignment::{
    AssignmentProblem, EdgeSpec, HardwareClass, Sla, TaskSpec,
};
use agentic_hetero::opt::lp::{solve, Lp};
use agentic_hetero::planner::plan::{Planner, PlannerConfig};
use agentic_hetero::util::bench::Bench;
use agentic_hetero::util::rng::Rng;

fn chain_problem(n_tasks: usize, n_classes: usize, seed: u64) -> AssignmentProblem {
    let mut rng = Rng::new(seed);
    let classes = (0..n_classes)
        .map(|j| HardwareClass { name: format!("C{j}"), capacity: 0.0 })
        .collect();
    let tasks = (0..n_tasks)
        .map(|i| TaskSpec {
            name: format!("t{i}"),
            latency_s: (0..n_classes).map(|_| 0.01 + rng.f64() * 0.1).collect(),
            cost_usd: (0..n_classes).map(|_| rng.f64()).collect(),
            capacity_use: 0.0,
            forbidden: vec![],
        })
        .collect();
    let edges = (1..n_tasks)
        .map(|i| EdgeSpec::free(i - 1, i, n_classes))
        .collect();
    AssignmentProblem { classes, tasks, edges, sla: Sla::None }
}

fn main() {
    let mut b = Bench::new();

    // LP: transport-style problems.
    let mut lp = Lp::new(24);
    let mut rng = Rng::new(3);
    lp.minimize((0..24).map(|_| rng.f64()).collect());
    for i in 0..12 {
        let mut row = vec![0.0; 24];
        row[i] = 1.0;
        row[i + 12] = 1.0;
        lp.add_eq(row, 1.0);
    }
    for _ in 0..8 {
        let row: Vec<f64> = (0..24).map(|_| rng.f64()).collect();
        lp.add_ub(row, 6.0);
    }
    b.run("opt/lp_24var_20con", || solve(&lp));

    for (n, h) in [(8, 6), (16, 6), (64, 6)] {
        let p = chain_problem(n, h, 42);
        b.run(&format!("opt/exact_chain_{n}x{h}"), || p.solve_exact().unwrap());
    }
    let p = chain_problem(16, 6, 43);
    b.run("opt/milp_chain_16x6", || p.solve_relaxed().unwrap());

    // End-to-end planning of the voice agent (lower + annotate + solve).
    let g = agents::voice_agent("8b-fp16", 512, 256);
    let planner = Planner::new(PlannerConfig { sla: Sla::None, ..Default::default() });
    b.run("opt/plan_voice_agent_e2e", || planner.plan(&g).unwrap());
}
