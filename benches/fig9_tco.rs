//! Regenerates Figure 9 (TCO benefit, input=4096 / output=512 —
//! prefill-heavy summarization) and times the explorer.

use agentic_hetero::cost::model_profile::llama3_70b;
use agentic_hetero::cost::Precision;
use agentic_hetero::opt::parallelism::{paper_pairs, tco_series, ExploreOpts, SeqShape};
use agentic_hetero::repro;
use agentic_hetero::util::bench::Bench;

fn main() {
    let art = repro::fig_tco(SeqShape::fig9(), "fig9");
    println!("=== {} ===\n{}", art.title, art.text);

    let opts = ExploreOpts::default();
    let m = llama3_70b(Precision::Fp16);
    let mut b = Bench::new();
    b.run("fig9/tco_series_70b_fp16", || {
        tco_series(std::slice::from_ref(&m), &paper_pairs(), SeqShape::fig9(), &opts)
    });
}
