//! Real-runtime hot path: PJRT prefill latency and decode tokens/s on
//! the AOT tiny-LLaMA artifacts (skips when `make artifacts` hasn't run).
//! This is the L3-side measurement of the L1/L2 stack.

use agentic_hetero::runtime::Engine;
use agentic_hetero::util::bench::Bench;

fn main() {
    let Ok(engine) = Engine::load("artifacts") else {
        println!("skipping runtime bench: artifacts not built (run `make artifacts`)");
        return;
    };
    println!(
        "engine: {} params, buckets {:?}, prompt bucket {}",
        engine.manifest.num_params, engine.manifest.buckets, engine.manifest.prefill_seq
    );

    let mut b = Bench::new();
    b.budget_s = 2.0;

    for bucket in engine.manifest.buckets.clone() {
        let prompts: Vec<Vec<u8>> = (0..bucket)
            .map(|i| format!("benchmark prompt number {i} ").into_bytes())
            .collect();
        b.run(&format!("runtime/prefill_b{bucket}"), || {
            engine.prefill(&prompts).unwrap()
        });

        let pre = engine.prefill(&prompts).unwrap();
        let mut kv = pre.kv;
        let tokens = vec![b'a'; bucket];
        let max_steps = engine.manifest.max_seq - engine.manifest.prefill_seq - 1;
        let mut step = 0usize;
        let sample = b.throughput(&format!("runtime/decode_step_b{bucket}"), bucket as u64, || {
            if step >= max_steps {
                // KV full: restart from a fresh prefill.
                kv = engine.prefill(&prompts).unwrap().kv;
                step = 0;
            }
            step += 1;
            engine.decode_step(&mut kv, &tokens).unwrap()
        });
        println!(
            "  -> decode throughput at batch {bucket}: {:.0} tok/s",
            sample.throughput.unwrap()
        );
    }
}
