//! Regenerates Table 5 (device specs + operating cost, listed vs
//! derived) and times the finance model.

use agentic_hetero::cost::tco::{capex_usd_per_hour, table5, FinanceTerms};
use agentic_hetero::repro;
use agentic_hetero::util::bench::Bench;

fn main() {
    let art = repro::table5_art();
    println!("=== {} ===\n{}", art.title, art.text);

    let terms = FinanceTerms::default();
    let mut b = Bench::new();
    b.run("table5/annuity", || capex_usd_per_hour(25_000.0, &terms));
    b.run("table5/full_table", || table5(&terms));
}
