//! Regenerates Figure 4 (marginal cost-efficiency scatter) and times the
//! catalog analysis.

use agentic_hetero::cost::hardware::{catalog, cost_efficiency};
use agentic_hetero::repro;
use agentic_hetero::util::bench::Bench;

fn main() {
    let art = repro::fig4();
    println!("=== {} ===\n{}", art.title, art.text);

    let mut b = Bench::new();
    b.run("fig4/cost_efficiency_rows", cost_efficiency);
    b.run("fig4/catalog_build", catalog);
    b.run("fig4/full_artifact_with_json", || repro::fig4().json.to_string());
}
