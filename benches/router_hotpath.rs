//! Fast-path hot loops: routing decisions, batcher admission, paged KV
//! allocation, cache-manager touches. §Perf targets: router decision
//! < 5µs, batcher push+poll O(1) amortized.

use std::time::Instant;

use agentic_hetero::kvcache::manager::{CacheManager, NodeBudget};
use agentic_hetero::kvcache::paged::PagedAllocator;
use agentic_hetero::router::batcher::{Batcher, BatcherConfig};
use agentic_hetero::router::router::{Router, RouterConfig, WorkerState};
use agentic_hetero::util::bench::Bench;

fn main() {
    let mut b = Bench::new();

    // Router: 64 workers, mixed load.
    let mut router = Router::new(RouterConfig::default());
    for id in 0..64 {
        router.upsert_worker(WorkerState {
            id,
            models: vec!["tiny-llama".into()],
            outstanding: id % 7,
            draining: false,
        });
    }
    let mut cache = CacheManager::new(
        (0..64)
            .map(|_| NodeBudget { hbm: 1e9, dram: 4e9, disk: 1e12 })
            .collect(),
    );
    for s in 0..512u64 {
        cache.insert(s, (s % 64) as u32, 1e6, s % 32).unwrap();
    }
    b.run("router/route_least_loaded", || {
        router.route("tiny-llama", None, None, &cache).unwrap()
    });
    b.run("router/route_session_affinity", || {
        router.route("tiny-llama", Some(37), None, &cache).unwrap()
    });
    b.run("router/route_prefix_hit", || {
        router.route("tiny-llama", None, Some(7), &cache).unwrap()
    });

    // Batcher: push + poll cycle at bucket 4.
    let mut batcher: Batcher<u64> = Batcher::new(BatcherConfig::default());
    let mut i = 0u64;
    b.run("batcher/push4_poll", || {
        for _ in 0..4 {
            batcher.push(i);
            i += 1;
        }
        batcher.poll(Instant::now()).unwrap().members.len()
    });

    // Paged allocator: alloc 512-token seq, 64 appends, free.
    let mut alloc = PagedAllocator::new(4096, 16);
    let mut seq = 0u64;
    b.run("kvcache/alloc_append64_free", || {
        alloc.alloc_seq(seq, 512).unwrap();
        for _ in 0..64 {
            alloc.append_token(seq).unwrap();
        }
        alloc.free_seq(seq).unwrap();
        seq += 1;
    });

    // Cache manager: touch (LRU maintenance + possible promotion).
    let mut t = 0u64;
    b.run("kvcache/manager_touch", || {
        let s = t % 512;
        t += 1;
        cache.touch(s)
    });
}
