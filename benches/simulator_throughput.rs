//! Discrete-event simulator throughput (events/s) and fabric transfer
//! scheduling. §Perf target: >= 1M events/s.

use agentic_hetero::cluster::arrivals::Poisson;
use agentic_hetero::cluster::sim::{pair_placement, ClusterSim};
use agentic_hetero::cluster::trace::TraceConfig;
use agentic_hetero::cost::hardware::by_name;
use agentic_hetero::cost::model_profile::llama3_8b;
use agentic_hetero::cost::roofline::Parallelism;
use agentic_hetero::cost::Precision;
use agentic_hetero::transport::fabric::{Fabric, NodeAddr};
use agentic_hetero::util::bench::Bench;

fn main() {
    let mut b = Bench::new();

    let h100 = by_name("H100").unwrap();
    let gaudi = by_name("Gaudi3").unwrap();
    // Streamed Poisson arrivals, bit-identical to the legacy
    // `trace::generate` (pinned by the arrivals golden tests).
    let trace: Vec<_> = Poisson::new(&TraceConfig {
        n_requests: 512,
        rate: 32.0,
        isl_mean: 512,
        osl_mean: 128,
        sigma: 0.3,
        seed: 5,
    })
    .expect("poisson process must build")
    .collect();
    let total_events: u64 = {
        let placement = pair_placement(
            &h100, Parallelism { tp: 1, pp: 1 }, 2, 8,
            &gaudi, Parallelism { tp: 1, pp: 1 }, 2, 32,
        );
        let fabric = Fabric::new(8, 8, h100.scaleup_bw_gbps, 400.0);
        let mut sim = ClusterSim::new(llama3_8b(Precision::Fp16), placement, fabric);
        sim.run(&trace).unwrap().events_processed
    };
    println!("trace of {} requests -> {} events", trace.len(), total_events);

    b.throughput("sim/512req_trace_events", total_events, || {
        let placement = pair_placement(
            &h100, Parallelism { tp: 1, pp: 1 }, 2, 8,
            &gaudi, Parallelism { tp: 1, pp: 1 }, 2, 32,
        );
        let fabric = Fabric::new(8, 8, h100.scaleup_bw_gbps, 400.0);
        let mut sim = ClusterSim::new(llama3_8b(Precision::Fp16), placement, fabric);
        sim.run(&trace).unwrap().tokens_per_s
    });

    let mut fabric = Fabric::new(16, 8, 900.0, 400.0);
    let mut i = 0u32;
    b.run("fabric/transfer_schedule", || {
        let from = NodeAddr { chassis: i % 16, slot: 0 };
        let to = NodeAddr { chassis: (i + 7) % 16, slot: 1 };
        i += 1;
        fabric.transfer(from, to, 1e8, i as f64).unwrap()
    });

    // Agent-DAG execution through the unified ExecutionPlan: the voice
    // agent's full stage graph (CPU pre/post + disaggregated LLM) per
    // request, against the planner's own fleet.
    use agentic_hetero::cluster::sim::simulate_plan;
    use agentic_hetero::opt::assignment::Sla;
    use agentic_hetero::planner::plan::{Planner, PlannerConfig};

    let agent = agentic_hetero::agents::voice_agent("8b-fp16", 512, 128);
    let mut cfg = PlannerConfig::default();
    cfg.sla = Sla::EndToEnd(5.0);
    let plan = Planner::new(cfg).plan(&agent).unwrap();
    let dag_trace: Vec<_> = Poisson::new(&TraceConfig {
        n_requests: 256,
        rate: 16.0,
        isl_mean: 512,
        osl_mean: 64,
        sigma: 0.3,
        seed: 13,
    })
    .expect("poisson process must build")
    .collect();
    let dag_events = simulate_plan(&plan, &dag_trace).unwrap().events_processed;
    println!(
        "agent-DAG trace of {} requests -> {} events",
        dag_trace.len(),
        dag_events
    );
    b.throughput("sim/dag_256req_trace_events", dag_events, || {
        simulate_plan(&plan, &dag_trace).unwrap().tokens_per_s
    });
}
