//! Regenerates Figure 8 (TCO benefit, input=512 / output=4096) and times
//! the TP/PP/batch configuration explorer behind it.

use agentic_hetero::cost::hardware::by_name;
use agentic_hetero::cost::model_profile::llama3_8b;
use agentic_hetero::cost::Precision;
use agentic_hetero::opt::parallelism::{
    best_config, paper_pairs, tco_series, ExploreOpts, SeqShape, SlaMode,
};
use agentic_hetero::repro;
use agentic_hetero::util::bench::Bench;

fn main() {
    let art = repro::fig_tco(SeqShape::fig8(), "fig8");
    println!("=== {} ===\n{}", art.title, art.text);

    let opts = ExploreOpts::default();
    let m = llama3_8b(Precision::Fp8);
    let h100 = by_name("H100").unwrap();
    let gaudi = by_name("Gaudi3").unwrap();
    let mut b = Bench::new();
    b.run("fig8/best_config_one_pair", || {
        best_config(&m, &h100, &gaudi, SeqShape::fig8(), SlaMode::paper_latency(), &opts)
    });
    b.run("fig8/tco_series_one_model", || {
        tco_series(std::slice::from_ref(&m), &paper_pairs(), SeqShape::fig8(), &opts)
    });
}
