//! Regenerates Figure 3 / Table 2 (workload radar profiles) and times
//! the profile computation feeding the cost-annotation pass.

use agentic_hetero::cost::workload::WorkloadClass;
use agentic_hetero::repro;
use agentic_hetero::util::bench::Bench;

fn main() {
    let art = repro::fig3();
    println!("=== {} ===\n{}", art.title, art.text);

    let mut b = Bench::new();
    b.run("fig3/radar_all_workloads", || {
        WorkloadClass::ALL
            .iter()
            .map(|w| w.radar().hp_compute + w.dominant() as u8 as f64)
            .sum::<f64>()
    });
    b.run("fig3/full_artifact", repro::fig3);
}
