//! Orchestration-loop hot paths: decision-cycle throughput (diff +
//! retarget + migration lowering), migration-step planning over large
//! fleets, and one end-to-end orchestrated simulation of a bursty
//! trace. Emits `BENCH_orchestrator.json` (decisions/s, migration
//! steps, SLA attainment) for the perf ledger.

use agentic_hetero::cluster::arrivals::SquareWave;
use agentic_hetero::cluster::sim::simulate_plan;
use agentic_hetero::cluster::trace::TraceConfig;
use agentic_hetero::jobj;
use agentic_hetero::orchestrator::{
    lower_diff, retarget, Executor, Orchestrator, OrchestratorConfig, SimExecutor,
};
use agentic_hetero::plan::presets::mixed_generation;
use agentic_hetero::plan::{
    AdmissionPolicy, BatchPolicy, ExecutionPlan, FabricSpec, NodeBinding, PipelineBinding,
    PlanDiff, Role, SlaSpec, Stage,
};
use agentic_hetero::planner::autoscale::AutoscalerConfig;
use agentic_hetero::planner::migration::{plan_migration, RoleMap};
use agentic_hetero::runtime::Engine;
use agentic_hetero::server::{ChatRequest, Server};
use agentic_hetero::transport::fabric::Fabric;
use agentic_hetero::util::bench::Bench;
use agentic_hetero::util::json::Json;

fn bench_plan() -> ExecutionPlan {
    ExecutionPlan {
        agent: "bench".into(),
        model: "8b-fp16".into(),
        sla: SlaSpec::EndToEnd(5.0),
        bindings: vec![
            NodeBinding {
                op: "io.input".into(),
                class: "CPU".into(),
                stage: Stage::Cpu,
                latency_s: 0.0005,
                cost_usd: 0.0,
                deps: vec![],
                xfer_bytes: 0.0,
                token_fraction: 1.0,
                prefix_overlap: 0.0,
            },
            NodeBinding {
                op: "llm.prefill".into(),
                class: "H100".into(),
                stage: Stage::LlmPrefill,
                latency_s: 0.05,
                cost_usd: 1e-5,
                deps: vec![0],
                xfer_bytes: 1e6,
                token_fraction: 1.0,
                prefix_overlap: 0.0,
            },
            NodeBinding {
                op: "llm.decode".into(),
                class: "Gaudi3".into(),
                stage: Stage::LlmDecode,
                latency_s: 0.5,
                cost_usd: 2e-5,
                deps: vec![1],
                xfer_bytes: 1e8,
                token_fraction: 1.0,
                prefix_overlap: 0.0,
            },
        ],
        pipelines: vec![
            PipelineBinding {
                role: Role::Prefill,
                device: "H100".into(),
                tp: 1,
                pp: 1,
                max_batch: 8,
                replicas: 1,
                chassis: 0,
            },
            PipelineBinding {
                role: Role::Decode,
                device: "Gaudi3".into(),
                tp: 1,
                pp: 1,
                max_batch: 8,
                replicas: 1,
                chassis: 1,
            },
        ],
        batching: BatchPolicy::default(),
        admission: AdmissionPolicy::default(),
        fabric: FabricSpec::default(),
        cpu_workers: 64,
        cost_usd: 3e-5,
        latency_s: 0.55,
        pass_log: vec![],
    }
}

fn main() {
    let mut b = Bench::new();
    let plan = bench_plan();

    // 1. The decision cycle the control loop runs when a scaler fires:
    //    retarget → typed diff → capacity-safe migration lowering.
    let mut flip = 0u32;
    let decision_mean_s = b
        .run("orchestrator/decision_cycle", || {
            flip += 1;
            let target = retarget(&plan, 1, 2 + (flip % 7));
            let diff = PlanDiff::between(&plan, &target);
            let m = lower_diff(&plan, &target, 4e9).unwrap();
            (diff.is_empty(), m.steps.len())
        })
        .mean_s;
    let decisions_per_s = 1.0 / decision_mean_s;

    // 2. Migration planning across a wide heterogeneous fleet.
    let fabric = Fabric::new(16, 8, 900.0, 400.0);
    let mut cur = RoleMap::new();
    let mut tgt = RoleMap::new();
    for (i, dev) in ["H100", "Gaudi3", "A100", "MI300x", "B200", "A40"]
        .iter()
        .enumerate()
    {
        cur.insert((dev.to_string(), "decode".to_string()), 8 + i as u32);
        tgt.insert((dev.to_string(), "decode".to_string()), 4 + 2 * i as u32);
        cur.insert((dev.to_string(), "prefill".to_string()), 4);
        tgt.insert((dev.to_string(), "prefill".to_string()), 2 + i as u32);
    }
    let migration_steps = plan_migration(&cur, &tgt, 2e9, &fabric).steps.len() as u64;
    b.throughput("orchestrator/plan_migration_6dev", migration_steps, || {
        plan_migration(&cur, &tgt, 2e9, &fabric).steps.len()
    });

    // 3. End-to-end: orchestrate a bursty trace through the DAG
    //    simulator (smoke scale — the integration test asserts the
    //    behaviour; here we time it and export the attainment).
    // The streaming square-wave process in `compat` mode reproduces the
    // legacy `trace::bursty` request stream bit-for-bit (pinned by the
    // arrivals golden tests), materialized once for the repeated runs.
    let trace: Vec<_> = SquareWave::compat(
        &TraceConfig {
            n_requests: 192,
            rate: 4.0,
            isl_mean: 256,
            osl_mean: 48,
            sigma: 0.0,
            seed: 3,
        },
        8.0,
        30.0,
        8.0,
    )
    .expect("compat square wave must build")
    .collect();
    let orch = || {
        Orchestrator::new(
            OrchestratorConfig {
                window_s: 2.0,
                autoscale: AutoscalerConfig {
                    high_watermark: 0.80,
                    low_watermark: 0.25,
                    patience: 2,
                    min_pipelines: 1,
                    max_pipelines: 16,
                },
                backlog_factor: 1.0,
                cpu_autoscale: None,
            },
            bench_plan(),
            "bursty",
            "sim",
        )
        .unwrap()
    };
    let timeline = {
        let mut exec = SimExecutor::new(&trace);
        exec.orchestrate(orch()).unwrap()
    };
    println!("{}", timeline.summary());
    b.run("orchestrator/e2e_bursty_192req", || {
        let mut exec = SimExecutor::new(&trace);
        exec.orchestrate(orch()).unwrap().n_migrations()
    });

    // 4. Raw simulator event throughput: one `simulate_plan` pass over
    //    the bursty trace, normalised to discrete events processed.
    let sim_report =
        simulate_plan(&plan, &trace).expect("bench plan must simulate");
    let sim_mean_s = b
        .run("orchestrator/simulate_plan_192req", || {
            simulate_plan(&plan, &trace).unwrap().events_processed
        })
        .mean_s;
    let sim_events_per_s = sim_report.events_processed as f64 / sim_mean_s.max(1e-12);

    // 5. Live serving throughput: a synthetic burst through the
    //    threaded dispatcher on the two-generation plan (one engine
    //    worker thread per pipeline group; `time_scale = 0` so the
    //    measurement is dispatch + compute, not modeled sleeps). The
    //    heavier gated run lives in `tools/stress_serve.rs`; this is
    //    the ledger's trend line.
    let live_n: usize = 256;
    let live_plan = mixed_generation("8b-fp16", "H100", "A100", 1, 2);
    let live_wall_s = {
        let mut server = Server::from_plan_with_engines(
            Engine::synthetic_pool(live_plan.pipelines.len()),
            &live_plan,
        )
        .expect("live plan must install");
        let mut cfg = server.config().clone();
        cfg.time_scale = 0.0;
        cfg.max_new_tokens = 16;
        cfg.admission.rate = 1e9;
        cfg.admission.burst = 1e9;
        cfg.admission.max_queue_depth = live_n * 2;
        server.reconfigure(cfg);
        server.install_plan(&live_plan).expect("live plan must install");
        let reqs: Vec<ChatRequest> = (0..live_n as u64)
            .map(|i| {
                let byte = b'a' + (i % 23) as u8;
                ChatRequest::new(i, vec![byte; 48], 16)
                    .with_agent(live_plan.agent.as_str())
            })
            .collect();
        let t0 = std::time::Instant::now();
        let responses = server.run_workload(reqs).expect("live burst must serve");
        let wall = t0.elapsed().as_secs_f64();
        assert_eq!(responses.len(), live_n, "live burst dropped requests");
        assert!(responses.iter().all(|r| r.is_ok()), "live burst failed");
        wall
    };
    let live_requests_per_s = live_n as f64 / live_wall_s.max(1e-12);
    println!(
        "orchestrator/live_serve_{live_n}req      mean {:>9.3} ms   {:>12.1} req/s",
        live_wall_s * 1e3,
        live_requests_per_s
    );

    // Perf ledger artifact.
    let out = jobj! {
        "decisions_per_s" => decisions_per_s,
        "migration_steps" => migration_steps,
        "plans_emitted" => timeline.n_plans() as u64,
        "migrations" => timeline.n_migrations() as u64,
        "sla_attainment" => timeline.sla_attainment(),
        "sim_events_per_s" => sim_events_per_s,
        "live_requests_per_s" => live_requests_per_s,
    };
    let path = "BENCH_orchestrator.json";
    match std::fs::write(path, out.pretty()) {
        Ok(()) => println!("wrote {path}"),
        Err(e) => eprintln!("write {path}: {e}"),
    }
    let _ = Json::parse(&out.pretty()).expect("ledger must be valid JSON");
}
