//! Regenerates the Eqs. 1–3 bandwidth analysis (§5.2) and times the KV
//! sizing + bandwidth-requirement computations.

use agentic_hetero::cost::kv::kv_cache_bytes;
use agentic_hetero::cost::model_profile::llama3_70b;
use agentic_hetero::cost::network::bandwidth_requirement;
use agentic_hetero::cost::Precision;
use agentic_hetero::repro;
use agentic_hetero::util::bench::Bench;

fn main() {
    let art = repro::bandwidth();
    println!("=== {} ===\n{}", art.title, art.text);

    let m = llama3_70b(Precision::Fp16);
    let mut b = Bench::new();
    b.run("bandwidth/eq3_kv_size", || kv_cache_bytes(&m, 32_768, 8));
    b.run("bandwidth/eq12_requirement", || {
        bandwidth_requirement(&m, 32_768, 8, 1.0, 0.02, 8, 8)
    });
    b.run("bandwidth/full_artifact", repro::bandwidth);
}
