//! Release-mode streaming-simulator stress + constant-memory gate (CI:
//! `stress` job).
//!
//! Simulates a multi-day diurnal arrival stream (default 1M requests)
//! through the agent-DAG simulator's pull-based ingestion path — the
//! trace is never materialized, so the working set is bounded by
//! concurrency, not by the request count — and fails if:
//!
//! * any ingested request fails to complete,
//! * the event-queue or in-flight high-watermark scales with the
//!   request count (the constant-memory evidence), or
//! * event throughput falls below `STRESS_SIM_MIN_EVENTS_PER_S`
//!   (default 0 = record without gating; the perf ledger trend-gates
//!   `stream_sim_events_per_s` across commits).
//!
//! Writes `BENCH_stream_sim.json` (events/s, peak RSS, queue peaks)
//! next to the other CI perf artifacts.
//!
//! Env knobs: `STRESS_SIM_REQUESTS` (default 1_000_000),
//! `STRESS_SIM_RATE` (default 16.0 req/s mean rate),
//! `STRESS_SIM_MIN_EVENTS_PER_S` (default 0).

use agentic_hetero::cluster::arrivals::Diurnal;
use agentic_hetero::cluster::dag::DagSim;
use agentic_hetero::cluster::trace::TraceConfig;
use agentic_hetero::jobj;
use agentic_hetero::plan::presets::mixed_generation;

fn env_or(name: &str, default: f64) -> f64 {
    std::env::var(name)
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(default)
}

/// Peak resident set (VmHWM) in MiB from /proc/self/status; 0.0 when
/// unavailable (non-Linux or restricted /proc).
fn peak_rss_mb() -> f64 {
    let Ok(status) = std::fs::read_to_string("/proc/self/status") else {
        return 0.0;
    };
    for line in status.lines() {
        if let Some(rest) = line.strip_prefix("VmHWM:") {
            let kb: f64 = rest
                .trim()
                .trim_end_matches("kB")
                .trim()
                .parse()
                .unwrap_or(0.0);
            return kb / 1024.0;
        }
    }
    0.0
}

fn main() {
    let n = env_or("STRESS_SIM_REQUESTS", 1_000_000.0) as usize;
    let rate = env_or("STRESS_SIM_RATE", 16.0);
    let min_events_per_s = env_or("STRESS_SIM_MIN_EVENTS_PER_S", 0.0);

    let plan = mixed_generation("8b-fp16", "H100", "A100", 2, 2);
    let tc = TraceConfig {
        n_requests: n,
        rate,
        isl_mean: 512,
        osl_mean: 64,
        sigma: 0.4,
        seed: 7,
    };
    let mut arrivals =
        Diurnal::daily(&tc, 0.5).expect("diurnal process must build");

    let mut sim = DagSim::new(&plan).expect("preset plan must simulate");
    let t0 = std::time::Instant::now();
    let report = sim
        .run_stream(&mut arrivals)
        .expect("streaming run must complete");
    let wall_s = t0.elapsed().as_secs_f64();

    let detail = sim.last_detail().expect("run populates detail").clone();
    let events = report.events_processed;
    let events_per_s = events as f64 / wall_s.max(1e-9);
    let rss_mb = peak_rss_mb();

    println!(
        "stress_sim: {n} requests over {:.1} simulated hours ({} events)",
        report.makespan_s / 3600.0,
        events
    );
    println!("{}", report.summary());
    println!("  wall                : {wall_s:10.2} s");
    println!("  events/s            : {events_per_s:10.0}");
    println!("  inflight peak       : {:10}", detail.inflight_peak);
    println!("  event-queue peak    : {:10}", detail.event_queue_peak);
    println!("  peak RSS            : {rss_mb:10.1} MiB");

    assert_eq!(report.n_requests, n, "streaming run dropped requests");

    // Constant memory: the high-watermarks track concurrency. A linear
    // ingestion bug (arrivals pushed eagerly, slots never recycled)
    // puts both at ~n; a generous n/10 ceiling catches that while
    // tolerating genuine backlog under the diurnal peak.
    if n >= 10_000 {
        let cap = n / 10;
        assert!(
            detail.inflight_peak < cap,
            "inflight peak {} scales with request count {} — ingestion \
             is not streaming",
            detail.inflight_peak,
            n
        );
        assert!(
            detail.event_queue_peak < cap,
            "event-queue peak {} scales with request count {} — arrivals \
             are materialized into the heap",
            detail.event_queue_peak,
            n
        );
    }

    let out = jobj! {
        "requests" => n,
        "events_processed" => events,
        "wall_s" => wall_s,
        "stream_sim_events_per_s" => events_per_s,
        "inflight_peak" => detail.inflight_peak,
        "event_queue_peak" => detail.event_queue_peak,
        "peak_rss_mb" => rss_mb,
    };
    std::fs::write("BENCH_stream_sim.json", out.pretty())
        .expect("write BENCH_stream_sim.json");
    println!("wrote BENCH_stream_sim.json");

    if min_events_per_s > 0.0 && events_per_s < min_events_per_s {
        eprintln!(
            "FAIL: {events_per_s:.0} events/s < required {min_events_per_s:.0}"
        );
        std::process::exit(1);
    }
    println!("PASS");
}
