//! CI perf-ledger regression gate.
//!
//! Compares the freshly-produced `BENCH_orchestrator.json` (written by
//! `cargo bench --bench orchestrator_loop`) against the committed
//! `BENCH_baseline.json` and **fails the workflow** on regression,
//! printing a per-metric delta table either way.
//!
//! Tolerance rules (see also the comments in `.github/workflows/ci.yml`):
//!
//! * **Deterministic metrics** (`sla_attainment`) come from seeded,
//!   modeled-time runs — any drift is a behavior change. They gate at
//!   ±20% relative (`BENCH_GATE_TOL`, default 0.20).
//! * **Count metrics** (`migration_steps`, `plans_emitted`,
//!   `migrations`) are also deterministic, but they are *small
//!   integers that step discretely* — a planner emitting one more plan
//!   is a ±25% relative move on a baseline of 4 while still being the
//!   measurement floor, not a regression. They pass when
//!   `|current − baseline| ≤ max(tol·|baseline|, BENCH_GATE_COUNT_SLACK)`
//!   (absolute slack, default 3).
//! * **Timing metrics** (`decisions_per_s`, `live_requests_per_s`,
//!   `sim_events_per_s`) depend on the runner's silicon, so they only
//!   gate on a *collapse*: current must stay above
//!   `baseline / BENCH_GATE_TIMING_COLLAPSE` (default 5×) — catching
//!   an order-of-magnitude hot-path regression without flaking on CI
//!   hardware variance.
//! * A baseline value of `null` means "not yet pinned" — the metric is
//!   reported but does not gate (used to bootstrap a metric before its
//!   first green CI run produces a number to commit).
//!
//! Baseline refresh (after an *intentional* perf/behavior change):
//!
//! ```text
//! cargo bench --bench orchestrator_loop   # writes BENCH_orchestrator.json
//! cargo run --release --bin bench_gate -- --refresh
//! git add BENCH_baseline.json             # commit with the change
//! ```

use agentic_hetero::util::json::Json;

const LEDGER: &str = "BENCH_orchestrator.json";
const BASELINE: &str = "BENCH_baseline.json";

/// Secondary ledgers merged into the comparison when present (written
/// by other CI legs — `tools/stress_sim.rs` today). Absent files are
/// skipped, so the gate still runs standalone; a baseline value of
/// `null` keeps their metrics unpinned until a refresh after the leg
/// has run.
const EXTRA_LEDGERS: &[&str] = &["BENCH_stream_sim.json"];

/// Metrics whose absolute values are machine-dependent (gated only on
/// collapse, never on improvement or modest drift).
const TIMING_METRICS: &[&str] = &[
    "decisions_per_s",
    "live_requests_per_s",
    "sim_events_per_s",
    "stream_sim_events_per_s",
];

/// Deterministic small-integer counters: discrete steps, so they get
/// an absolute slack on top of the relative tolerance (see module
/// docs).
const COUNT_METRICS: &[&str] = &["migration_steps", "plans_emitted", "migrations"];

#[derive(Debug, PartialEq)]
enum Verdict {
    Ok,
    Regressed,
    Unpinned,
    Missing,
}

struct RowResult {
    metric: String,
    baseline: Option<f64>,
    current: Option<f64>,
    delta_pct: Option<f64>,
    verdict: Verdict,
}

/// Compare one metric under the gate's tolerance rules.
fn judge(
    metric: &str,
    baseline: Option<f64>,
    current: Option<f64>,
    tol: f64,
    collapse: f64,
    count_slack: f64,
) -> RowResult {
    let delta_pct = match (baseline, current) {
        (Some(b), Some(c)) if b != 0.0 => Some((c - b) / b * 100.0),
        _ => None,
    };
    let verdict = match (baseline, current) {
        (None, _) => Verdict::Unpinned,
        (Some(_), None) => Verdict::Missing,
        (Some(b), Some(c)) => {
            let regressed = if TIMING_METRICS.contains(&metric) {
                c < b / collapse
            } else if COUNT_METRICS.contains(&metric) {
                (c - b).abs() > (tol * b.abs()).max(count_slack)
            } else if b == 0.0 {
                c != 0.0
            } else {
                ((c - b) / b).abs() > tol
            };
            if regressed {
                Verdict::Regressed
            } else {
                Verdict::Ok
            }
        }
    };
    RowResult {
        metric: metric.to_string(),
        baseline,
        current,
        delta_pct,
        verdict,
    }
}

fn env_f64(key: &str, default: f64) -> f64 {
    std::env::var(key)
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(default)
}

fn num(v: &Json) -> Option<f64> {
    v.as_f64()
}

fn fmt_opt(v: Option<f64>) -> String {
    match v {
        Some(x) => format!("{x:.6}"),
        None => "—".to_string(),
    }
}

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let refresh = args.iter().any(|a| a == "--refresh");

    let ledger_src = match std::fs::read_to_string(LEDGER) {
        Ok(s) => s,
        Err(e) => {
            eprintln!(
                "bench_gate: cannot read {LEDGER}: {e} \
                 (run `cargo bench --bench orchestrator_loop` first)"
            );
            std::process::exit(2);
        }
    };
    let mut ledger = match Json::parse(&ledger_src) {
        Ok(j) => j,
        Err(e) => {
            eprintln!("bench_gate: {LEDGER} is not valid JSON: {e}");
            std::process::exit(2);
        }
    };
    // Fold in secondary ledgers (merged before --refresh so a pin
    // captures them too). A present-but-broken file is an error; an
    // absent one just leaves its metrics unpinned.
    for path in EXTRA_LEDGERS {
        let Ok(src) = std::fs::read_to_string(path) else {
            continue;
        };
        match Json::parse(&src) {
            Ok(Json::Obj(m)) => {
                for (k, v) in m {
                    let _ = ledger.try_set(&k, v);
                }
            }
            Ok(_) => {
                eprintln!("bench_gate: {path} is not a JSON object");
                std::process::exit(2);
            }
            Err(e) => {
                eprintln!("bench_gate: {path} is not valid JSON: {e}");
                std::process::exit(2);
            }
        }
    }

    if refresh {
        // Pin the current ledger as the new baseline verbatim.
        if let Err(e) = std::fs::write(BASELINE, ledger.pretty()) {
            eprintln!("bench_gate: write {BASELINE}: {e}");
            std::process::exit(2);
        }
        println!("bench_gate: pinned {BASELINE} from {LEDGER}");
        return;
    }

    let baseline_src = match std::fs::read_to_string(BASELINE) {
        Ok(s) => s,
        Err(e) => {
            eprintln!("bench_gate: cannot read {BASELINE}: {e}");
            std::process::exit(2);
        }
    };
    let baseline = match Json::parse(&baseline_src) {
        Ok(j) => j,
        Err(e) => {
            eprintln!("bench_gate: {BASELINE} is not valid JSON: {e}");
            std::process::exit(2);
        }
    };

    let tol = env_f64("BENCH_GATE_TOL", 0.20);
    let collapse = env_f64("BENCH_GATE_TIMING_COLLAPSE", 5.0);
    let count_slack = env_f64("BENCH_GATE_COUNT_SLACK", 3.0);

    // Every metric named by the baseline gates; ledger-only metrics are
    // reported as unpinned (candidates for the next refresh).
    let mut metrics: Vec<String> = Vec::new();
    for j in [&baseline, &ledger] {
        if let Json::Obj(m) = j {
            for k in m.keys() {
                if !metrics.iter().any(|x| x == k) {
                    metrics.push(k.clone());
                }
            }
        }
    }

    let mut rows = Vec::new();
    for m in &metrics {
        let b = baseline.get(m).and_then(num);
        let c = ledger.get(m).and_then(num);
        rows.push(judge(m, b, c, tol, collapse, count_slack));
    }

    println!(
        "bench_gate: {LEDGER} vs {BASELINE} (tol ±{:.0}%, timing collapse \
         {collapse}x, count slack ±{count_slack})",
        tol * 100.0
    );
    println!(
        "{:<22} {:>14} {:>14} {:>9}  verdict",
        "metric", "baseline", "current", "delta"
    );
    let mut failed = false;
    for r in &rows {
        let verdict = match r.verdict {
            Verdict::Ok => "ok",
            Verdict::Regressed => {
                failed = true;
                "REGRESSED"
            }
            Verdict::Unpinned => "unpinned (not gated)",
            Verdict::Missing => {
                failed = true;
                "MISSING from ledger"
            }
        };
        let delta = match r.delta_pct {
            Some(d) => format!("{d:+.1}%"),
            None => "—".to_string(),
        };
        println!(
            "{:<22} {:>14} {:>14} {:>9}  {verdict}",
            r.metric,
            fmt_opt(r.baseline),
            fmt_opt(r.current),
            delta
        );
    }
    if failed {
        eprintln!(
            "bench_gate: REGRESSION — if intentional, refresh the baseline: \
             `cargo run --release --bin bench_gate -- --refresh` and commit {BASELINE}"
        );
        std::process::exit(1);
    }
    println!("bench_gate: ok");
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_metrics_gate_at_tolerance() {
        let r = judge("sla_attainment", Some(10.0), Some(11.9), 0.20, 5.0, 3.0);
        assert_eq!(r.verdict, Verdict::Ok);
        assert!((r.delta_pct.unwrap() - 19.0).abs() < 1e-9);
        let r = judge("sla_attainment", Some(10.0), Some(12.1), 0.20, 5.0, 3.0);
        assert_eq!(r.verdict, Verdict::Regressed);
        // Both directions gate: a deterministic metric changing at all
        // beyond tolerance is a behavior change.
        let r = judge("sla_attainment", Some(10.0), Some(7.9), 0.20, 5.0, 3.0);
        assert_eq!(r.verdict, Verdict::Regressed);
        // Zero baselines require exact zero.
        let r = judge("sla_attainment", Some(0.0), Some(0.0), 0.20, 5.0, 3.0);
        assert_eq!(r.verdict, Verdict::Ok);
        let r = judge("sla_attainment", Some(0.0), Some(1.0), 0.20, 5.0, 3.0);
        assert_eq!(r.verdict, Verdict::Regressed);
    }

    #[test]
    fn count_metrics_get_absolute_slack() {
        // +2 on a baseline of 4 is +50% relative but within the ±3
        // discrete-step slack: not a regression.
        let r = judge("plans_emitted", Some(4.0), Some(6.0), 0.20, 5.0, 3.0);
        assert_eq!(r.verdict, Verdict::Ok);
        // Past the slack, the count gates in both directions.
        let r = judge("plans_emitted", Some(4.0), Some(8.0), 0.20, 5.0, 3.0);
        assert_eq!(r.verdict, Verdict::Regressed);
        let r = judge("migrations", Some(10.0), Some(6.0), 0.20, 5.0, 3.0);
        assert_eq!(r.verdict, Verdict::Regressed);
        // Large counts fall back to the relative tolerance once it
        // exceeds the slack: 100 → 115 is within ±20%.
        let r = judge("migration_steps", Some(100.0), Some(115.0), 0.20, 5.0, 3.0);
        assert_eq!(r.verdict, Verdict::Ok);
        let r = judge("migration_steps", Some(100.0), Some(121.0), 0.20, 5.0, 3.0);
        assert_eq!(r.verdict, Verdict::Regressed);
        // Zero slack restores the pure relative rule.
        let r = judge("plans_emitted", Some(4.0), Some(6.0), 0.20, 5.0, 0.0);
        assert_eq!(r.verdict, Verdict::Regressed);
    }

    #[test]
    fn timing_metrics_gate_only_on_collapse() {
        for m in ["decisions_per_s", "live_requests_per_s", "sim_events_per_s"] {
            // 3x slower: noisy CI silicon, still ok.
            let r = judge(m, Some(1000.0), Some(350.0), 0.20, 5.0, 3.0);
            assert_eq!(r.verdict, Verdict::Ok);
            // 10x slower: a hot-path regression.
            let r = judge(m, Some(1000.0), Some(99.0), 0.20, 5.0, 3.0);
            assert_eq!(r.verdict, Verdict::Regressed);
            // Faster never fails.
            let r = judge(m, Some(1000.0), Some(9000.0), 0.20, 5.0, 3.0);
            assert_eq!(r.verdict, Verdict::Ok);
        }
    }

    #[test]
    fn unpinned_and_missing_metrics() {
        let r = judge("new_metric", None, Some(5.0), 0.20, 5.0, 3.0);
        assert_eq!(r.verdict, Verdict::Unpinned);
        let r = judge("gone_metric", Some(5.0), None, 0.20, 5.0, 3.0);
        assert_eq!(r.verdict, Verdict::Missing);
    }
}
