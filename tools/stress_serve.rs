//! Release-mode serving stress + live-throughput gate (CI: `rust` job).
//!
//! Pushes a 10k-request synthetic burst through the server twice on the
//! two-generation `mixed_generation` plan — once with engines on worker
//! threads (the default), once with `serialize_engines` (every batch
//! executed inline on the dispatcher thread, the pre-threading
//! behaviour) — and fails if:
//!
//! * any request is dropped, rejected, duplicated, or failed, or
//! * threaded throughput < `STRESS_MIN_SPEEDUP` × serialized
//!   throughput (default 1.5; the plan's prefill group and two decode
//!   sibling groups live on three engine threads, so ~2x is expected).
//!
//! With `--traced`, a third threaded leg runs with a span
//! [`TraceSink`] attached (the `--trace-out` path): it fails if traced
//! throughput drops more than `STRESS_TRACE_MAX_DROP` (default 5%)
//! below the untraced threaded run — the "tracing is cheap enough to
//! leave on" gate — and writes the captured spans as
//! `STRESS_trace.json` (Chrome trace-event JSON, uploaded by CI).
//!
//! Writes `BENCH_live_serve.json` next to `BENCH_orchestrator.json` so
//! CI archives live throughput alongside the perf ledger.
//!
//! Env knobs: `STRESS_REQUESTS` (default 10000), `STRESS_MIN_SPEEDUP`
//! (default 1.5, `0` records without gating), `STRESS_TRACE_MAX_DROP`
//! (default 0.05, `0` records without gating).
//!
//! The synthetic engine only exists in dependency-free builds; under
//! `--features pjrt` the bin degrades to a clear error (mirroring how
//! the sim/live conformance suite is feature-gated).

#[cfg(not(feature = "pjrt"))]
use std::collections::HashSet;
#[cfg(not(feature = "pjrt"))]
use std::sync::Arc;
#[cfg(not(feature = "pjrt"))]
use std::time::Instant;

#[cfg(not(feature = "pjrt"))]
use agentic_hetero::cluster::arrivals::Poisson;
#[cfg(not(feature = "pjrt"))]
use agentic_hetero::cluster::trace::TraceConfig;
#[cfg(not(feature = "pjrt"))]
use agentic_hetero::jobj;
#[cfg(not(feature = "pjrt"))]
use agentic_hetero::obs::trace::{to_chrome_json_string, TraceSink};
#[cfg(not(feature = "pjrt"))]
use agentic_hetero::orchestrator::chat_request_of;
#[cfg(not(feature = "pjrt"))]
use agentic_hetero::plan::presets::mixed_generation;
#[cfg(not(feature = "pjrt"))]
use agentic_hetero::plan::ExecutionPlan;
#[cfg(not(feature = "pjrt"))]
use agentic_hetero::runtime::Engine;
#[cfg(not(feature = "pjrt"))]
use agentic_hetero::server::{ChatRequest, Server};

#[cfg(not(feature = "pjrt"))]
const ISL: usize = 48;
#[cfg(not(feature = "pjrt"))]
const OSL: usize = 16;

#[cfg(not(feature = "pjrt"))]
fn env_or(name: &str, default: f64) -> f64 {
    std::env::var(name)
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(default)
}

/// One full burst through a fresh server; returns wall seconds.
#[cfg(not(feature = "pjrt"))]
fn run_mode(
    plan: &ExecutionPlan,
    n: usize,
    serialize: bool,
    trace: Option<&Arc<TraceSink>>,
) -> f64 {
    let mut server =
        Server::from_plan_with_engines(Engine::synthetic_pool(plan.pipelines.len()), plan)
            .expect("plan must install");
    let mut cfg = server.config().clone();
    cfg.time_scale = 0.0; // no modeled sleeps: measure dispatch + compute
    cfg.max_new_tokens = OSL;
    cfg.serialize_engines = serialize;
    cfg.admission.rate = 1e9;
    cfg.admission.burst = 1e9;
    cfg.admission.max_queue_depth = n * 2;
    server.reconfigure(cfg);
    server.install_plan(plan).expect("plan must install");
    if let Some(sink) = trace {
        server.set_trace_sink(Arc::clone(sink));
    }

    // Workload from the streaming Poisson process through the shared
    // sim→live request mapping. `sigma: 0.0` pins the lengths to
    // exactly ISL/OSL, so the requests are byte-identical to the old
    // hand-rolled loop (id, `b'a' + id % 23` payload byte, max-new).
    let reqs: Vec<ChatRequest> = Poisson::new(&TraceConfig {
        n_requests: n,
        rate: 1e6,
        isl_mean: ISL as u64,
        osl_mean: OSL as u64,
        sigma: 0.0,
        seed: 0,
    })
    .expect("poisson process must build")
    .map(|r| chat_request_of(&r).with_agent(plan.agent.as_str()))
    .collect();

    let t0 = Instant::now();
    let responses = server.run_workload(reqs).expect("serve must not error");
    let wall = t0.elapsed().as_secs_f64();

    // Zero drops, no duplicates, everything succeeded.
    assert_eq!(responses.len(), n, "dropped responses");
    let mut ids = HashSet::with_capacity(n);
    for r in &responses {
        assert!(
            r.is_ok(),
            "request {} not ok: rejected={} error={:?}",
            r.id,
            r.rejected,
            r.error
        );
        assert!(ids.insert(r.id), "duplicate response {}", r.id);
    }
    wall
}

#[cfg(feature = "pjrt")]
fn main() {
    eprintln!("stress_serve drives the synthetic engine: build without --features pjrt");
    std::process::exit(2);
}

#[cfg(not(feature = "pjrt"))]
fn main() {
    let n = env_or("STRESS_REQUESTS", 10_000.0) as usize;
    let min_speedup = env_or("STRESS_MIN_SPEEDUP", 1.5);
    let plan = mixed_generation("8b-fp16", "H100", "A100", 1, 2);

    // Warm-up: fault in lazily-initialised state on both paths.
    run_mode(&plan, (n / 20).max(64), false, None);
    run_mode(&plan, (n / 20).max(64), true, None);

    let serial_s = run_mode(&plan, n, true, None);
    let threaded_s = run_mode(&plan, n, false, None);

    let serial_rps = n as f64 / serial_s.max(1e-9);
    let live_rps = n as f64 / threaded_s.max(1e-9);
    let speedup = live_rps / serial_rps.max(1e-9);

    println!("stress_serve: {n} requests on `{}`", plan.agent);
    println!("  serialized dispatch : {serial_rps:10.1} req/s ({serial_s:.2}s)");
    println!("  threaded dispatch   : {live_rps:10.1} req/s ({threaded_s:.2}s)");
    println!("  speedup             : {speedup:.2}x (gate: {min_speedup}x)");

    // `--traced`: the tracing-overhead leg. Same threaded burst with a
    // span sink attached; the captured trace becomes the CI artifact.
    let traced = std::env::args().any(|a| a == "--traced");
    let max_drop = env_or("STRESS_TRACE_MAX_DROP", 0.05);
    let mut traced_rps = 0.0;
    let mut trace_drop = 0.0;
    if traced {
        let sink = TraceSink::new();
        let traced_s = run_mode(&plan, n, false, Some(&sink));
        traced_rps = n as f64 / traced_s.max(1e-9);
        trace_drop = 1.0 - traced_rps / live_rps.max(1e-9);
        let spans = sink.spans();
        assert!(
            !spans.is_empty(),
            "traced leg recorded no spans: tracing is not wired"
        );
        std::fs::write("STRESS_trace.json", to_chrome_json_string(&spans))
            .expect("write STRESS_trace.json");
        println!(
            "  traced dispatch     : {traced_rps:10.1} req/s ({traced_s:.2}s, \
             {} spans -> STRESS_trace.json)",
            spans.len()
        );
        println!(
            "  tracing overhead    : {:.1}% throughput drop (gate: {:.0}%)",
            trace_drop * 100.0,
            max_drop * 100.0
        );
    }

    let mut report = jobj! {
        "requests" => n,
        "serialized_requests_per_s" => serial_rps,
        "live_requests_per_s" => live_rps,
        "threaded_speedup" => speedup,
        "min_speedup" => min_speedup,
    };
    if traced {
        report
            .try_set("traced_requests_per_s", traced_rps)
            .expect("report is an object");
        report
            .try_set("tracing_throughput_drop", trace_drop)
            .expect("report is an object");
    }
    std::fs::write("BENCH_live_serve.json", report.pretty())
        .expect("write BENCH_live_serve.json");

    if min_speedup > 0.0 && speedup < min_speedup {
        eprintln!(
            "FAIL: threaded dispatch {speedup:.2}x < required {min_speedup}x \
             over the serialized baseline"
        );
        std::process::exit(1);
    }
    if traced && max_drop > 0.0 && trace_drop > max_drop {
        eprintln!(
            "FAIL: tracing costs {:.1}% of live throughput (> {:.0}% budget)",
            trace_drop * 100.0,
            max_drop * 100.0
        );
        std::process::exit(1);
    }
    println!("PASS");
}
