"""AOT pipeline: train the tiny model briefly, lower prefill/decode to HLO
text, and write the artifact bundle consumed by the Rust runtime.

Interchange format is HLO *text*, NOT `lowered.compile().serialize()`:
jax >= 0.5 emits HloModuleProtos with 64-bit instruction ids which the
xla crate's xla_extension 0.5.1 rejects (`proto.id() <= INT_MAX`). The
text parser reassigns ids, so text round-trips cleanly (see
/opt/xla-example/gen_hlo.py and its README).

Outputs (artifacts/):
  prefill_b{B}.hlo.txt   per batch bucket: (tokens[B,S], lens[B]) ->
                         (last_logits[B,V], k_cache, v_cache)
  decode_b{B}.hlo.txt    (token[B], pos[B], k_cache, v_cache) ->
                         (logits[B,V], k_cache, v_cache)
  manifest.txt           key=value description of shapes & buckets
  train_log.txt          build-time loss curve (real tiny-corpus train)

Weights are baked into the HLO as constants, so the Rust binary needs no
separate weight loading path and Python never runs at request time.
"""

from __future__ import annotations

import argparse
import os
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from compile import model as M

# Batch buckets the Rust batcher routes requests into.
DEFAULT_BUCKETS = (1, 2, 4)
PREFILL_SEQ = 64  # fixed prompt bucket length (padded)


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (see module docstring)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    # print_large_constants: the default HLO printer elides weight
    # tensors as `constant({...})`, which parses back as garbage — the
    # baked weights MUST round-trip through the text format.
    return comp.as_hlo_text(print_large_constants=True)


def _corpus_tokens(cfg: M.ModelConfig) -> np.ndarray:
    """Byte-level training corpus: this repo's own prose documentation."""
    root = os.path.dirname(os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
    texts = []
    for name in ("README.md", "DESIGN.md"):
        path = os.path.join(root, name)
        if os.path.exists(path):
            with open(path, "rb") as f:
                texts.append(f.read())
    data = b"\n".join(texts) if texts else b""
    if len(data) < 4096:
        data = (data + b" the quick brown fox jumps over the lazy dog. ") * 64
    toks = np.frombuffer(data, dtype=np.uint8).astype(np.int32)
    # Sub-byte vocabs (tests): fold into range rather than NaN-fill OOB.
    return toks % cfg.vocab


def train(
    cfg: M.ModelConfig,
    steps: int,
    batch: int = 16,
    seq: int = 48,
    lr: float = 3e-3,
    seed: int = 0,
    log=print,
):
    """Adam on next-byte cross-entropy over the repo corpus.

    Tiny (~0.4M param) model on CPU: a few hundred steps take seconds and
    produce a *real* byte-level LM (loss drops from ~5.5 to ~2.x), which
    the e2e serving example then actually serves.
    """
    params = M.init_params(jax.random.PRNGKey(seed), cfg)
    data = _corpus_tokens(cfg)
    rng = np.random.default_rng(seed)

    grad_fn = jax.jit(
        jax.value_and_grad(M.loss_fn), static_argnames=("cfg",)
    )

    # Hand-rolled Adam (optax is not in the image).
    mu = jax.tree.map(jnp.zeros_like, params)
    nu = jax.tree.map(jnp.zeros_like, params)
    b1, b2, eps = 0.9, 0.999, 1e-8

    @jax.jit
    def adam_step(params, mu, nu, grads, t):
        mu = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g, mu, grads)
        nu = jax.tree.map(lambda n, g: b2 * n + (1 - b2) * g * g, nu, grads)
        scale = lr * jnp.sqrt(1 - b2**t) / (1 - b1**t)
        params = jax.tree.map(
            lambda p, m, n: p - scale * m / (jnp.sqrt(n) + eps), params, mu, nu
        )
        return params, mu, nu

    losses = []
    for step in range(1, steps + 1):
        starts = rng.integers(0, len(data) - seq - 1, size=batch)
        toks = np.stack([data[s : s + seq + 1] for s in starts])
        loss, grads = grad_fn(params, jnp.asarray(toks), cfg)
        params, mu, nu = adam_step(params, mu, nu, grads, step)
        losses.append(float(loss))
        if step == 1 or step % 50 == 0:
            log(f"step {step:4d}  loss {float(loss):.4f}")
    return params, losses


def lower_prefill(params, cfg: M.ModelConfig, batch: int, seq: int) -> str:
    fn = lambda tokens, lens: M.prefill(params, tokens, lens, cfg)
    lowered = jax.jit(fn).lower(
        jax.ShapeDtypeStruct((batch, seq), jnp.int32),
        jax.ShapeDtypeStruct((batch,), jnp.int32),
    )
    return to_hlo_text(lowered)


def lower_decode(params, cfg: M.ModelConfig, batch: int) -> str:
    cache = jax.ShapeDtypeStruct(
        (cfg.n_layers, batch, cfg.n_kv_heads, cfg.max_seq, cfg.head_dim),
        jnp.float32,
    )
    fn = lambda token, pos, kc, vc: M.decode(params, token, pos, kc, vc, cfg)
    lowered = jax.jit(fn).lower(
        jax.ShapeDtypeStruct((batch,), jnp.int32),
        jax.ShapeDtypeStruct((batch,), jnp.int32),
        cache,
        cache,
    )
    return to_hlo_text(lowered)


def write_manifest(path: str, cfg: M.ModelConfig, buckets, seq: int) -> None:
    lines = [
        "format=1",
        f"vocab={cfg.vocab}",
        f"d_model={cfg.d_model}",
        f"n_layers={cfg.n_layers}",
        f"n_heads={cfg.n_heads}",
        f"n_kv_heads={cfg.n_kv_heads}",
        f"head_dim={cfg.head_dim}",
        f"d_ff={cfg.d_ff}",
        f"max_seq={cfg.max_seq}",
        f"prefill_seq={seq}",
        f"buckets={','.join(str(b) for b in buckets)}",
        f"num_params={cfg.num_params()}",
        f"kv_cache_bytes_b1={cfg.kv_cache_bytes(1)}",
    ]
    with open(path, "w") as f:
        f.write("\n".join(lines) + "\n")


def main(argv=None) -> None:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--out-dir", default=None, help="artifacts directory")
    p.add_argument("--steps", type=int, default=200, help="training steps")
    p.add_argument("--buckets", default=",".join(map(str, DEFAULT_BUCKETS)))
    p.add_argument("--seq", type=int, default=PREFILL_SEQ)
    p.add_argument("--seed", type=int, default=0)
    args = p.parse_args(argv)

    out_dir = args.out_dir
    if out_dir is None:
        root = os.path.dirname(
            os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
        )
        out_dir = os.path.join(root, "artifacts")
    os.makedirs(out_dir, exist_ok=True)
    buckets = tuple(int(b) for b in args.buckets.split(","))

    cfg = M.ModelConfig()
    print(f"model: {cfg.num_params()/1e6:.2f}M params, cfg={cfg}")

    t0 = time.time()
    log_lines: list[str] = []

    def log(msg):
        print(msg)
        log_lines.append(str(msg))

    params, losses = train(cfg, steps=args.steps, seed=args.seed, log=log)
    log(f"train: {args.steps} steps in {time.time()-t0:.1f}s "
        f"loss {losses[0]:.3f} -> {losses[-1]:.3f}")
    with open(os.path.join(out_dir, "train_log.txt"), "w") as f:
        f.write("\n".join(log_lines) + "\n")
        f.write("loss_curve=" + ",".join(f"{l:.4f}" for l in losses) + "\n")

    for b in buckets:
        t = time.time()
        text = lower_prefill(params, cfg, b, args.seq)
        path = os.path.join(out_dir, f"prefill_b{b}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        print(f"wrote {path} ({len(text)/1e6:.1f} MB, {time.time()-t:.1f}s)")

        t = time.time()
        text = lower_decode(params, cfg, b)
        path = os.path.join(out_dir, f"decode_b{b}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        print(f"wrote {path} ({len(text)/1e6:.1f} MB, {time.time()-t:.1f}s)")

    write_manifest(os.path.join(out_dir, "manifest.txt"), cfg, buckets, args.seq)
    print(f"artifacts complete in {time.time()-t0:.1f}s -> {out_dir}")


if __name__ == "__main__":
    main()
