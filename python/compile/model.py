"""L2: tiny-LLaMA-family model (JAX) — prefill & decode graphs.

Architecture mirrors LLaMA-3 (Table 4 of the paper) at toy scale:
RMSNorm -> GQA attention with RoPE -> residual -> RMSNorm -> SwiGLU FFN
-> residual, tied embeddings, byte-level vocab. Attention funnels through
the L1 Pallas kernel (kernels/attention.py) so the kernel lowers into the
same AOT HLO artifact the Rust runtime executes.

Two request-path graphs are exported by aot.py:

  prefill(params, tokens[B,S], lens[B])
      -> (last_logits[B,V], k_cache[L,B,Hkv,Smax,D], v_cache[...])
  decode(params, token[B], pos[B], k_cache, v_cache)
      -> (logits[B,V], k_cache, v_cache)

plus a full-logits forward used only for build-time training (aot.py)
and consistency tests.
"""

from __future__ import annotations

import dataclasses
import functools
import math
from typing import Any

import jax
import jax.numpy as jnp

from compile.kernels.attention import decode_attention, flash_attention

Params = dict[str, Any]


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """Hyperparameters of the tiny-LLaMA variant.

    Defaults give ~0.43M parameters: large enough for a byte-level LM to
    learn real statistics at build time, small enough that HLO-text
    artifacts with baked weights stay in the low MBs.
    """

    vocab: int = 256  # byte-level tokenizer
    d_model: int = 96
    n_layers: int = 3
    n_heads: int = 4
    n_kv_heads: int = 2
    d_ff: int = 256
    max_seq: int = 96
    rope_theta: float = 10000.0
    norm_eps: float = 1e-5

    @property
    def head_dim(self) -> int:
        return self.d_model // self.n_heads

    def num_params(self) -> int:
        a = self.vocab * self.d_model  # tied embed/unembed
        attn = self.d_model * self.head_dim * (2 * self.n_heads + 2 * self.n_kv_heads)
        ffn = 3 * self.d_model * self.d_ff
        norms = 2 * self.d_model * self.n_layers + self.d_model
        return a + self.n_layers * (attn + ffn) + norms

    def kv_cache_bytes(self, batch: int, bytes_per_elt: int = 4) -> int:
        """Eq. 3 of the paper at toy scale."""
        return (
            2
            * self.n_layers
            * self.n_kv_heads
            * self.head_dim
            * self.max_seq
            * batch
            * bytes_per_elt
        )


def init_params(key: jax.Array, cfg: ModelConfig) -> Params:
    """Scaled-normal init (tied embeddings)."""
    keys = jax.random.split(key, 1 + cfg.n_layers)

    def dense(k, fan_in, shape):
        return jax.random.normal(k, shape, jnp.float32) / math.sqrt(fan_in)

    params: Params = {
        "embed": dense(keys[0], cfg.d_model, (cfg.vocab, cfg.d_model)),
        "final_norm": jnp.ones((cfg.d_model,), jnp.float32),
        "layers": [],
    }
    hd = cfg.head_dim
    for i in range(cfg.n_layers):
        ks = jax.random.split(keys[1 + i], 7)
        params["layers"].append(
            {
                "attn_norm": jnp.ones((cfg.d_model,), jnp.float32),
                "wq": dense(ks[0], cfg.d_model, (cfg.d_model, cfg.n_heads * hd)),
                "wk": dense(ks[1], cfg.d_model, (cfg.d_model, cfg.n_kv_heads * hd)),
                "wv": dense(ks[2], cfg.d_model, (cfg.d_model, cfg.n_kv_heads * hd)),
                "wo": dense(ks[3], cfg.n_heads * hd, (cfg.n_heads * hd, cfg.d_model)),
                "ffn_norm": jnp.ones((cfg.d_model,), jnp.float32),
                "w_gate": dense(ks[4], cfg.d_model, (cfg.d_model, cfg.d_ff)),
                "w_up": dense(ks[5], cfg.d_model, (cfg.d_model, cfg.d_ff)),
                "w_down": dense(ks[6], cfg.d_ff, (cfg.d_ff, cfg.d_model)),
            }
        )
    return params


def _rmsnorm(x: jax.Array, g: jax.Array, eps: float) -> jax.Array:
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    return x * jax.lax.rsqrt(var + eps) * g


def _rope(x: jax.Array, pos: jax.Array, theta: float) -> jax.Array:
    """Rotary embedding. x: (B, H, S, D); pos: (B, S) absolute positions."""
    d = x.shape[-1]
    half = d // 2
    freqs = jnp.exp(
        -math.log(theta) * jnp.arange(0, half, dtype=jnp.float32) / half
    )  # (half,)
    angles = pos[:, None, :, None].astype(jnp.float32) * freqs  # (B,1,S,half)
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)


def _attn_qkv(layer: Params, x: jax.Array, cfg: ModelConfig):
    """Project to (q, k, v) with head split. x: (B, S, d_model)."""
    b, s, _ = x.shape
    hd = cfg.head_dim
    q = (x @ layer["wq"]).reshape(b, s, cfg.n_heads, hd).transpose(0, 2, 1, 3)
    k = (x @ layer["wk"]).reshape(b, s, cfg.n_kv_heads, hd).transpose(0, 2, 1, 3)
    v = (x @ layer["wv"]).reshape(b, s, cfg.n_kv_heads, hd).transpose(0, 2, 1, 3)
    return q, k, v


def _ffn(layer: Params, x: jax.Array) -> jax.Array:
    return (jax.nn.silu(x @ layer["w_gate"]) * (x @ layer["w_up"])) @ layer["w_down"]


def prefill(
    params: Params,
    tokens: jax.Array,
    lens: jax.Array,
    cfg: ModelConfig,
    *,
    interpret: bool = True,
):
    """Process the full prompt; return last-token logits + padded KV cache.

    tokens: (B, S) int32, right-padded; lens: (B,) valid lengths (>=1).
    """
    b, s = tokens.shape
    x = params["embed"][tokens]  # (B, S, d)
    pos = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None, :], (b, s))

    k_caches, v_caches = [], []
    for layer in params["layers"]:
        h = _rmsnorm(x, layer["attn_norm"], cfg.norm_eps)
        q, k, v = _attn_qkv(layer, h, cfg)
        q = _rope(q, pos, cfg.rope_theta)
        k = _rope(k, pos, cfg.rope_theta)
        o = flash_attention(q, k, v, causal=True, interpret=interpret)
        o = o.transpose(0, 2, 1, 3).reshape(b, s, cfg.n_heads * cfg.head_dim)
        x = x + o @ layer["wo"]
        h = _rmsnorm(x, layer["ffn_norm"], cfg.norm_eps)
        x = x + _ffn(layer, h)
        # Pad the per-layer KV to max_seq for the decode-side cache.
        pad = ((0, 0), (0, 0), (0, cfg.max_seq - s), (0, 0))
        k_caches.append(jnp.pad(k, pad))
        v_caches.append(jnp.pad(v, pad))

    x = _rmsnorm(x, params["final_norm"], cfg.norm_eps)
    logits = x @ params["embed"].T  # (B, S, V)
    last = jnp.take_along_axis(
        logits, (lens - 1)[:, None, None].astype(jnp.int32), axis=1
    )[:, 0, :]
    return last, jnp.stack(k_caches), jnp.stack(v_caches)


def decode(
    params: Params,
    token: jax.Array,
    pos: jax.Array,
    k_cache: jax.Array,
    v_cache: jax.Array,
    cfg: ModelConfig,
    *,
    interpret: bool = True,
):
    """One decode step.

    token: (B,) int32 newest token; pos: (B,) its absolute position.
    k_cache/v_cache: (L, B, Hkv, Smax, D). Returns (logits, new caches).
    """
    b = token.shape[0]
    x = params["embed"][token][:, None, :]  # (B, 1, d)
    pos2 = pos[:, None].astype(jnp.int32)  # (B, 1)

    new_k, new_v = [], []
    for li, layer in enumerate(params["layers"]):
        h = _rmsnorm(x, layer["attn_norm"], cfg.norm_eps)
        q, k, v = _attn_qkv(layer, h, cfg)  # q:(B,H,1,D) k/v:(B,Hkv,1,D)
        q = _rope(q, pos2, cfg.rope_theta)
        k = _rope(k, pos2, cfg.rope_theta)

        # Scatter the new K/V row into the padded cache at pos (per batch).
        def _upd(cache, new):
            return jax.vmap(
                lambda c, n, p: jax.lax.dynamic_update_slice(c, n, (0, p, 0))
            )(cache, new, pos)

        kc = _upd(k_cache[li], k)
        vc = _upd(v_cache[li], v)
        new_k.append(kc)
        new_v.append(vc)

        o = decode_attention(q, kc, vc, pos + 1, interpret=interpret)
        o = o.transpose(0, 2, 1, 3).reshape(b, 1, cfg.n_heads * cfg.head_dim)
        x = x + o @ layer["wo"]
        h = _rmsnorm(x, layer["ffn_norm"], cfg.norm_eps)
        x = x + _ffn(layer, h)

    x = _rmsnorm(x, params["final_norm"], cfg.norm_eps)
    logits = (x @ params["embed"].T)[:, 0, :]
    return logits, jnp.stack(new_k), jnp.stack(new_v)


def forward_full(
    params: Params,
    tokens: jax.Array,
    cfg: ModelConfig,
    *,
    interpret: bool = True,
    use_kernel: bool = True,
) -> jax.Array:
    """All-position logits (B, S, V); build-time training / tests only.

    use_kernel=False routes attention through the pure-jnp oracle; the
    Pallas kernel has no autodiff rule, so the (build-time-only) training
    loop differentiates the oracle path. Both paths are asserted equal in
    python/tests/test_model.py, so trained weights transfer exactly.
    """
    from compile.kernels.ref import attention_ref

    b, s = tokens.shape
    x = params["embed"][tokens]
    pos = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None, :], (b, s))
    for layer in params["layers"]:
        h = _rmsnorm(x, layer["attn_norm"], cfg.norm_eps)
        q, k, v = _attn_qkv(layer, h, cfg)
        q = _rope(q, pos, cfg.rope_theta)
        k = _rope(k, pos, cfg.rope_theta)
        if use_kernel:
            o = flash_attention(q, k, v, causal=True, interpret=interpret)
        else:
            o = attention_ref(q, k, v, causal=True)
        o = o.transpose(0, 2, 1, 3).reshape(b, s, cfg.n_heads * cfg.head_dim)
        x = x + o @ layer["wo"]
        h = _rmsnorm(x, layer["ffn_norm"], cfg.norm_eps)
        x = x + _ffn(layer, h)
    x = _rmsnorm(x, params["final_norm"], cfg.norm_eps)
    return x @ params["embed"].T


@functools.partial(jax.jit, static_argnames=("cfg",))
def loss_fn(params: Params, tokens: jax.Array, cfg: ModelConfig) -> jax.Array:
    """Next-byte cross-entropy (build-time training objective)."""
    logits = forward_full(params, tokens[:, :-1], cfg, use_kernel=False)
    targets = tokens[:, 1:]
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]
    return jnp.mean(nll)
