"""L1: flash-attention-style Pallas kernel (tiled online softmax).

This is the compute hot-spot of the serving stack: every prefill and
decode step of the L2 model (model.py) funnels through this kernel, so it
lowers into the AOT HLO artifacts the Rust coordinator executes.

Hardware adaptation (GPU paper -> TPU/Pallas; see DESIGN.md
S.Hardware-Adaptation): the CUDA flash-attention threadblock tiling
becomes a `pallas_call` grid over (batch*q_heads, q_blocks, kv_blocks);
shared-memory staging becomes BlockSpec-driven HBM->VMEM tiles; the
online-softmax running statistics (m, l) and the output accumulator live
in VMEM scratch instead of registers.

The kernel supports:
  * grouped-query attention (n_q_heads a multiple of n_kv_heads), mapped
    in the BlockSpec index function rather than by materializing repeated
    K/V (saves HBM bandwidth, exactly the GQA motivation);
  * causal masking (prefill) and per-batch valid-length masking (decode
    over a padded KV cache);
  * arbitrary seq lengths via padded tiles + masking.

interpret=True everywhere: the CPU PJRT plugin cannot run Mosaic
custom-calls; interpret-mode lowers to plain HLO so the same artifact
runs under the Rust runtime. Real-TPU efficiency is estimated from the
block geometry in EXPERIMENTS.md S.Perf.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BLOCK_Q = 64
DEFAULT_BLOCK_K = 64

_NEG_INF = -1e30


def _flash_kernel(
    lens_ref,
    q_ref,
    k_ref,
    v_ref,
    o_ref,
    m_scr,
    l_scr,
    acc_scr,
    *,
    sm_scale: float,
    causal: bool,
    block_q: int,
    block_k: int,
    seq_q: int,
    seq_kv: int,
):
    """One (q_block, kv_block) step of online-softmax attention.

    Grid: (batch * n_q_heads, num_q_blocks, num_kv_blocks). Scratch holds
    the running max `m`, normalizer `l`, and unnormalized accumulator per
    q block; the final kv step writes the normalized output.
    """
    kv_idx = pl.program_id(2)
    num_kv = pl.num_programs(2)

    @pl.when(kv_idx == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, _NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0].astype(jnp.float32)  # (block_q, d)
    k = k_ref[0].astype(jnp.float32)  # (block_k, d)
    v = v_ref[0].astype(jnp.float32)  # (block_k, d)

    # MXU-shaped contraction: scores over the tile.
    s = jax.lax.dot_general(
        q,
        k,
        (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    s = s * sm_scale

    # Absolute positions of this tile's rows/cols.
    q_pos = pl.program_id(1) * block_q + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 0
    )
    k_pos = kv_idx * block_k + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 1
    )

    kv_len = lens_ref[0]
    mask = k_pos < kv_len
    if causal:
        mask = jnp.logical_and(mask, q_pos >= k_pos)
    # Padded q rows (q_pos >= seq_q) produce garbage that callers discard.
    s = jnp.where(mask, s, _NEG_INF)

    m_prev = m_scr[...]  # (block_q, 1)
    l_prev = l_scr[...]
    m_cur = jnp.max(s, axis=1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)

    # Guard fully-masked rows: exp(-inf - -inf) -> use large negative m.
    p = jnp.exp(s - m_new)
    p = jnp.where(mask, p, 0.0)
    alpha = jnp.exp(m_prev - m_new)

    l_new = alpha * l_prev + jnp.sum(p, axis=1, keepdims=True)
    acc = acc_scr[...] * alpha + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )

    m_scr[...] = m_new
    l_scr[...] = l_new
    acc_scr[...] = acc

    @pl.when(kv_idx == num_kv - 1)
    def _finalize():
        l_fin = l_scr[...]
        # Rows with no valid keys (padded queries) get 0 output.
        denom = jnp.where(l_fin == 0.0, 1.0, l_fin)
        o_ref[0] = (acc_scr[...] / denom).astype(o_ref.dtype)


def _ceil_to(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


@functools.partial(
    jax.jit,
    static_argnames=("causal", "sm_scale", "block_q", "block_k", "interpret"),
)
def flash_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    lens: jax.Array | None = None,
    *,
    causal: bool = True,
    sm_scale: float | None = None,
    block_q: int = DEFAULT_BLOCK_Q,
    block_k: int = DEFAULT_BLOCK_K,
    interpret: bool = True,
) -> jax.Array:
    """Tiled online-softmax attention.

    Args:
      q: (batch, n_q_heads, seq_q, head_dim).
      k/v: (batch, n_kv_heads, seq_kv, head_dim); n_q_heads must be a
        multiple of n_kv_heads (grouped-query attention).
      lens: (batch,) int32 number of valid KV positions per batch element
        (defaults to seq_kv). Keys at positions >= lens[b] are masked.
      causal: apply q_pos >= k_pos masking (prefill). Requires
        seq_q == seq_kv alignment (query i attends keys <= i).

    Returns:
      (batch, n_q_heads, seq_q, head_dim) with q's dtype.
    """
    batch, n_q_heads, seq_q, head_dim = q.shape
    _, n_kv_heads, seq_kv, _ = k.shape
    if n_q_heads % n_kv_heads != 0:
        raise ValueError(
            f"n_q_heads={n_q_heads} not a multiple of n_kv_heads={n_kv_heads}"
        )
    group = n_q_heads // n_kv_heads
    if sm_scale is None:
        sm_scale = 1.0 / math.sqrt(head_dim)

    block_q = min(block_q, _ceil_to(seq_q, 8))
    block_k = min(block_k, _ceil_to(seq_kv, 8))
    pad_q = _ceil_to(seq_q, block_q)
    pad_kv = _ceil_to(seq_kv, block_k)
    if pad_q != seq_q:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, pad_q - seq_q), (0, 0)))
    if pad_kv != seq_kv:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad_kv - seq_kv), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad_kv - seq_kv), (0, 0)))

    if lens is None:
        lens = jnp.full((batch,), seq_kv, dtype=jnp.int32)
    lens = lens.astype(jnp.int32)

    bh = batch * n_q_heads
    num_q = pad_q // block_q
    num_kv = pad_kv // block_k

    qf = q.reshape(bh, pad_q, head_dim)
    kf = k.reshape(batch * n_kv_heads, pad_kv, head_dim)
    vf = v.reshape(batch * n_kv_heads, pad_kv, head_dim)

    def q_index(b, qi, ki):
        return (b, qi, 0)

    def kv_index(b, qi, ki):
        # GQA: query head h uses kv head h // group.
        bi = b // n_q_heads
        hi = (b % n_q_heads) // group
        return (bi * n_kv_heads + hi, ki, 0)

    def lens_index(b, qi, ki):
        return (b // n_q_heads,)

    out = pl.pallas_call(
        functools.partial(
            _flash_kernel,
            sm_scale=float(sm_scale),
            causal=causal,
            block_q=block_q,
            block_k=block_k,
            seq_q=seq_q,
            seq_kv=seq_kv,
        ),
        grid=(bh, num_q, num_kv),
        in_specs=[
            pl.BlockSpec((1,), lens_index),
            pl.BlockSpec((1, block_q, head_dim), q_index),
            pl.BlockSpec((1, block_k, head_dim), kv_index),
            pl.BlockSpec((1, block_k, head_dim), kv_index),
        ],
        out_specs=pl.BlockSpec((1, block_q, head_dim), q_index),
        out_shape=jax.ShapeDtypeStruct((bh, pad_q, head_dim), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, head_dim), jnp.float32),
        ],
        interpret=interpret,
    )(lens, qf, kf, vf)

    out = out.reshape(batch, n_q_heads, pad_q, head_dim)
    return out[:, :, :seq_q, :]


def decode_attention(
    q: jax.Array,
    k_cache: jax.Array,
    v_cache: jax.Array,
    cur_lens: jax.Array,
    *,
    sm_scale: float | None = None,
    block_k: int = DEFAULT_BLOCK_K,
    interpret: bool = True,
) -> jax.Array:
    """Single-step decode attention over a padded KV cache.

    Args:
      q: (batch, n_q_heads, 1, head_dim) query for the newest token.
      k_cache/v_cache: (batch, n_kv_heads, max_seq, head_dim) padded cache
        that already contains the newest token's K/V.
      cur_lens: (batch,) int32 valid lengths *including* the new token.

    Returns: (batch, n_q_heads, 1, head_dim).
    """
    return flash_attention(
        q,
        k_cache,
        v_cache,
        cur_lens,
        causal=False,
        sm_scale=sm_scale,
        block_q=8,
        block_k=block_k,
        interpret=interpret,
    )
