"""Pure-jnp reference oracle for the Pallas attention kernels.

Deliberately naive: materialize full (seq_q, seq_kv) score matrices and
use stock softmax. Every kernel output is asserted allclose against this
in python/tests/test_kernel.py (including hypothesis shape/dtype sweeps).
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def attention_ref(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    lens: jax.Array | None = None,
    *,
    causal: bool = True,
    sm_scale: float | None = None,
) -> jax.Array:
    """Naive attention with GQA, causal and valid-length masking.

    Shapes match kernels.attention.flash_attention.
    """
    batch, n_q_heads, seq_q, head_dim = q.shape
    _, n_kv_heads, seq_kv, _ = k.shape
    group = n_q_heads // n_kv_heads
    if sm_scale is None:
        sm_scale = 1.0 / math.sqrt(head_dim)

    # Expand KV heads to query heads.
    k = jnp.repeat(k, group, axis=1).astype(jnp.float32)
    v = jnp.repeat(v, group, axis=1).astype(jnp.float32)
    qf = q.astype(jnp.float32)

    s = jnp.einsum("bhqd,bhkd->bhqk", qf, k) * sm_scale

    k_pos = jnp.arange(seq_kv)[None, None, None, :]
    q_pos = jnp.arange(seq_q)[None, None, :, None]
    if lens is None:
        lens = jnp.full((batch,), seq_kv, dtype=jnp.int32)
    mask = k_pos < lens[:, None, None, None]
    if causal:
        mask = jnp.logical_and(mask, q_pos >= k_pos)

    s = jnp.where(mask, s, -jnp.inf)
    # Fully-masked rows (padded queries): softmax would NaN; zero them.
    row_valid = jnp.any(mask, axis=-1, keepdims=True)
    p = jax.nn.softmax(jnp.where(row_valid, s, 0.0), axis=-1)
    p = jnp.where(row_valid, p, 0.0)
    out = jnp.einsum("bhqk,bhkd->bhqd", p, v)
    return out.astype(q.dtype)


def decode_attention_ref(
    q: jax.Array,
    k_cache: jax.Array,
    v_cache: jax.Array,
    cur_lens: jax.Array,
    *,
    sm_scale: float | None = None,
) -> jax.Array:
    """Reference for kernels.attention.decode_attention."""
    return attention_ref(
        q, k_cache, v_cache, cur_lens, causal=False, sm_scale=sm_scale
    )
