"""L1: fused RMSNorm(+optional residual-add) Pallas kernel.

The serving stack's second-hottest op after attention: every layer runs
RMSNorm twice. Fusing the residual add into the normalization removes
one HBM round-trip of the activation tensor — the classic
bandwidth-bound fusion the paper's "Fusion and Decomposition" MLIR pass
family targets (§4.2), expressed here at the kernel level.

TPU mapping: rows are tiled over the grid; each block holds a
(block_rows, d) tile in VMEM; mean-of-squares reduces along lanes.
interpret=True for CPU-PJRT execution, like kernels/attention.py.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _rmsnorm_kernel(x_ref, g_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    o_ref[...] = (x * jax.lax.rsqrt(var + eps) * g_ref[...]).astype(o_ref.dtype)


def _rmsnorm_residual_kernel(x_ref, r_ref, g_ref, o_ref, res_ref, *, eps: float):
    # Fused: res = x + r; out = rmsnorm(res) * g. One pass over HBM.
    s = x_ref[...].astype(jnp.float32) + r_ref[...].astype(jnp.float32)
    res_ref[...] = s.astype(res_ref.dtype)
    var = jnp.mean(jnp.square(s), axis=-1, keepdims=True)
    o_ref[...] = (s * jax.lax.rsqrt(var + eps) * g_ref[...]).astype(o_ref.dtype)


def _grid(rows: int, block_rows: int):
    return ((rows + block_rows - 1) // block_rows,)


@functools.partial(jax.jit, static_argnames=("eps", "block_rows", "interpret"))
def rmsnorm(
    x: jax.Array,
    gain: jax.Array,
    *,
    eps: float = 1e-5,
    block_rows: int = 128,
    interpret: bool = True,
) -> jax.Array:
    """RMSNorm over the last axis. x: (..., d); gain: (d,)."""
    orig_shape = x.shape
    d = x.shape[-1]
    rows = x.size // d
    xf = x.reshape(rows, d)
    block_rows = min(block_rows, rows)

    out = pl.pallas_call(
        functools.partial(_rmsnorm_kernel, eps=eps),
        grid=_grid(rows, block_rows),
        in_specs=[
            pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, d), x.dtype),
        interpret=interpret,
    )(xf, gain)
    return out.reshape(orig_shape)


@functools.partial(jax.jit, static_argnames=("eps", "block_rows", "interpret"))
def rmsnorm_residual(
    x: jax.Array,
    residual: jax.Array,
    gain: jax.Array,
    *,
    eps: float = 1e-5,
    block_rows: int = 128,
    interpret: bool = True,
):
    """Fused (x + residual) -> (rmsnorm(x + residual) * gain, x + residual).

    Returns (normalized, new_residual) — the transformer block pattern.
    """
    orig_shape = x.shape
    d = x.shape[-1]
    rows = x.size // d
    xf = x.reshape(rows, d)
    rf = residual.reshape(rows, d)
    block_rows = min(block_rows, rows)

    out, res = pl.pallas_call(
        functools.partial(_rmsnorm_residual_kernel, eps=eps),
        grid=_grid(rows, block_rows),
        in_specs=[
            pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
            pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
            pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((rows, d), x.dtype),
            jax.ShapeDtypeStruct((rows, d), x.dtype),
        ],
        interpret=interpret,
    )(xf, rf, gain)
    return out.reshape(orig_shape), res.reshape(orig_shape)


def rmsnorm_ref(x: jax.Array, gain: jax.Array, eps: float = 1e-5) -> jax.Array:
    """Pure-jnp oracle (matches model._rmsnorm)."""
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps) * gain).astype(x.dtype)
