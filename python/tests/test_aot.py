"""AOT pipeline contracts: HLO text emission, manifest format, and the
constant-baking property the Rust loader depends on."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot
from compile import model as M

jax.config.update("jax_platform_name", "cpu")

CFG = M.ModelConfig(
    vocab=31, d_model=16, n_layers=1, n_heads=2, n_kv_heads=1, d_ff=24, max_seq=16
)


@pytest.fixture(scope="module")
def params():
    return M.init_params(jax.random.PRNGKey(3), CFG)


class TestLowering:
    def test_prefill_hlo_text_structure(self, params):
        text = aot.lower_prefill(params, CFG, batch=1, seq=8)
        assert text.startswith("HloModule")
        assert "ENTRY" in text
        # Entry layout: two s32 params in, 3-tuple out.
        assert "s32[1,8]" in text
        assert f"f32[1,{CFG.vocab}]" in text

    def test_decode_hlo_text_structure(self, params):
        text = aot.lower_decode(params, CFG, batch=2)
        assert text.startswith("HloModule")
        cache = f"f32[{CFG.n_layers},2,{CFG.n_kv_heads},{CFG.max_seq},{CFG.head_dim}]"
        assert cache in text

    def test_no_elided_constants(self, params):
        """`constant({...})` means the weights did NOT round-trip; the
        Rust runtime would compute garbage. Must never appear."""
        for text in (
            aot.lower_prefill(params, CFG, batch=1, seq=8),
            aot.lower_decode(params, CFG, batch=1),
        ):
            assert "{...}" not in text

    def test_weights_baked_as_constants(self, params):
        """The embed table's actual values must appear in the text."""
        text = aot.lower_decode(params, CFG, batch=1)
        # A distinctive weight value, printed to HLO precision.
        w = float(np.asarray(params["embed"])[0, 0])
        assert f"{CFG.vocab},{CFG.d_model}" in text.replace(" ", "")
        assert "constant" in text
        # Text must be weight-sized, not topology-sized.
        assert len(text) > CFG.num_params() * 4

    def test_hlo_text_roundtrip_via_jax(self, params):
        """Compile the emitted text back and compare numerics vs jax."""
        from jax._src.lib import xla_client as xc

        text = aot.lower_decode(params, CFG, batch=1)
        # Parse back through the XLA client and execute on CPU.
        client = jax.devices("cpu")[0].client
        mod = xc._xla.hlo_module_from_text(text)
        # Round-trip parse is the contract; execution is covered by the
        # Rust integration tests.
        assert mod is not None


class TestManifest:
    def test_manifest_contents(self, tmp_path):
        path = os.path.join(tmp_path, "manifest.txt")
        aot.write_manifest(path, CFG, buckets=(1, 2), seq=8)
        kv = {}
        with open(path) as f:
            for line in f:
                k, _, v = line.strip().partition("=")
                kv[k] = v
        assert kv["vocab"] == str(CFG.vocab)
        assert kv["buckets"] == "1,2"
        assert kv["prefill_seq"] == "8"
        assert int(kv["num_params"]) == CFG.num_params()
        assert int(kv["kv_cache_bytes_b1"]) == CFG.kv_cache_bytes(1)


class TestCorpus:
    def test_corpus_tokens_nonempty_bytes(self):
        data = aot._corpus_tokens(M.ModelConfig())
        assert data.dtype == np.int32
        assert len(data) >= 4096
        assert data.min() >= 0 and data.max() <= 255

    def test_train_few_steps_reduces_loss(self):
        cfg = CFG
        params, losses = aot.train(
            cfg, steps=8, batch=8, seq=24, log=lambda *_: None
        )
        assert losses[-1] < losses[0]
        assert all(np.isfinite(l) for l in losses)


@pytest.mark.skipif(
    not os.path.exists(
        os.path.join(os.path.dirname(__file__), "..", "..", "artifacts", "manifest.txt")
    ),
    reason="artifacts not built (run `make artifacts`)",
)
class TestBuiltArtifacts:
    """Validate the shipped artifact bundle when present."""

    @pytest.fixture(scope="class")
    def art_dir(self):
        return os.path.join(
            os.path.dirname(__file__), "..", "..", "artifacts"
        )

    def test_manifest_and_files_consistent(self, art_dir):
        kv = {}
        with open(os.path.join(art_dir, "manifest.txt")) as f:
            for line in f:
                k, _, v = line.strip().partition("=")
                kv[k] = v
        for b in kv["buckets"].split(","):
            for stem in ("prefill", "decode"):
                p = os.path.join(art_dir, f"{stem}_b{b}.hlo.txt")
                assert os.path.exists(p), p
                with open(p) as f:
                    head = f.read(64)
                assert head.startswith("HloModule")

    def test_artifacts_have_no_elided_constants(self, art_dir):
        import glob

        for p in glob.glob(os.path.join(art_dir, "*.hlo.txt")):
            with open(p) as f:
                assert "{...}" not in f.read(), p
