"""L1 correctness: Pallas flash/decode attention vs the pure-jnp oracle.

This is the CORE kernel correctness signal: fixed-shape unit cases plus
hypothesis sweeps over shapes, dtypes, GQA ratios, block sizes, and
valid-length masks.
"""

import hypothesis
import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.kernels.attention import decode_attention, flash_attention
from compile.kernels.ref import attention_ref, decode_attention_ref

jax.config.update("jax_platform_name", "cpu")

TOL = dict(rtol=2e-5, atol=2e-5)
BF16_TOL = dict(rtol=2e-2, atol=2e-2)


def _rand(key, shape, dtype=jnp.float32):
    return jax.random.normal(jax.random.PRNGKey(key), shape, jnp.float32).astype(
        dtype
    )


class TestFlashBasic:
    def test_matches_ref_causal(self):
        q = _rand(0, (2, 4, 32, 16))
        k = _rand(1, (2, 4, 32, 16))
        v = _rand(2, (2, 4, 32, 16))
        out = flash_attention(q, k, v, block_q=16, block_k=16)
        np.testing.assert_allclose(out, attention_ref(q, k, v), **TOL)

    def test_matches_ref_non_causal(self):
        q = _rand(3, (1, 2, 24, 8))
        k = _rand(4, (1, 2, 40, 8))
        v = _rand(5, (1, 2, 40, 8))
        out = flash_attention(q, k, v, causal=False, block_q=8, block_k=16)
        np.testing.assert_allclose(
            out, attention_ref(q, k, v, causal=False), **TOL
        )

    def test_gqa(self):
        q = _rand(6, (2, 8, 16, 8))
        k = _rand(7, (2, 2, 16, 8))
        v = _rand(8, (2, 2, 16, 8))
        out = flash_attention(q, k, v, block_q=8, block_k=8)
        np.testing.assert_allclose(out, attention_ref(q, k, v), **TOL)

    def test_mqa(self):
        """Multi-query attention: a single shared KV head."""
        q = _rand(9, (1, 4, 16, 8))
        k = _rand(10, (1, 1, 16, 8))
        v = _rand(11, (1, 1, 16, 8))
        out = flash_attention(q, k, v, block_q=8, block_k=8)
        np.testing.assert_allclose(out, attention_ref(q, k, v), **TOL)

    def test_ragged_seq_not_multiple_of_block(self):
        q = _rand(12, (1, 2, 37, 16))
        k = _rand(13, (1, 2, 37, 16))
        v = _rand(14, (1, 2, 37, 16))
        out = flash_attention(q, k, v, block_q=16, block_k=16)
        np.testing.assert_allclose(out, attention_ref(q, k, v), **TOL)

    def test_lens_masking(self):
        q = _rand(15, (3, 2, 16, 8))
        k = _rand(16, (3, 2, 16, 8))
        v = _rand(17, (3, 2, 16, 8))
        lens = jnp.array([4, 16, 9], jnp.int32)
        out = flash_attention(q, k, v, lens, causal=False, block_q=8, block_k=8)
        np.testing.assert_allclose(
            out, attention_ref(q, k, v, lens, causal=False), **TOL
        )

    def test_zero_len_rows_are_zero(self):
        """A batch element with 0 valid keys must produce all-zero output."""
        q = _rand(18, (2, 2, 8, 8))
        k = _rand(19, (2, 2, 8, 8))
        v = _rand(20, (2, 2, 8, 8))
        lens = jnp.array([0, 8], jnp.int32)
        out = flash_attention(q, k, v, lens, causal=False, block_q=8, block_k=8)
        np.testing.assert_allclose(np.asarray(out[0]), 0.0, atol=1e-7)

    def test_custom_scale(self):
        q = _rand(21, (1, 2, 16, 8))
        k = _rand(22, (1, 2, 16, 8))
        v = _rand(23, (1, 2, 16, 8))
        out = flash_attention(q, k, v, sm_scale=0.5, block_q=8, block_k=8)
        np.testing.assert_allclose(
            out, attention_ref(q, k, v, sm_scale=0.5), **TOL
        )

    def test_bf16_inputs(self):
        q = _rand(24, (1, 2, 16, 8), jnp.bfloat16)
        k = _rand(25, (1, 2, 16, 8), jnp.bfloat16)
        v = _rand(26, (1, 2, 16, 8), jnp.bfloat16)
        out = flash_attention(q, k, v, block_q=8, block_k=8)
        assert out.dtype == jnp.bfloat16
        np.testing.assert_allclose(
            np.asarray(out, np.float32),
            np.asarray(attention_ref(q, k, v), np.float32),
            **BF16_TOL,
        )

    def test_rejects_bad_gqa_ratio(self):
        q = _rand(27, (1, 3, 8, 8))
        k = _rand(28, (1, 2, 8, 8))
        with pytest.raises(ValueError, match="multiple"):
            flash_attention(q, k, k)

    def test_single_token(self):
        q = _rand(29, (1, 2, 1, 8))
        k = _rand(30, (1, 2, 1, 8))
        v = _rand(31, (1, 2, 1, 8))
        out = flash_attention(q, k, v)
        np.testing.assert_allclose(out, attention_ref(q, k, v), **TOL)

    def test_numerical_stability_large_scores(self):
        """Large logits must not overflow the online softmax."""
        q = 30.0 * _rand(32, (1, 1, 16, 8))
        k = 30.0 * _rand(33, (1, 1, 16, 8))
        v = _rand(34, (1, 1, 16, 8))
        out = flash_attention(q, k, v, block_q=8, block_k=8)
        assert np.isfinite(np.asarray(out)).all()
        np.testing.assert_allclose(out, attention_ref(q, k, v), rtol=1e-4, atol=1e-4)


class TestDecodeAttention:
    def test_matches_ref(self):
        q = _rand(40, (2, 4, 1, 16))
        kc = _rand(41, (2, 2, 48, 16))
        vc = _rand(42, (2, 2, 48, 16))
        lens = jnp.array([5, 33], jnp.int32)
        out = decode_attention(q, kc, vc, lens, block_k=16)
        np.testing.assert_allclose(
            out, decode_attention_ref(q, kc, vc, lens), **TOL
        )

    def test_full_cache(self):
        q = _rand(43, (1, 2, 1, 8))
        kc = _rand(44, (1, 1, 32, 8))
        vc = _rand(45, (1, 1, 32, 8))
        lens = jnp.array([32], jnp.int32)
        out = decode_attention(q, kc, vc, lens, block_k=8)
        np.testing.assert_allclose(
            out, decode_attention_ref(q, kc, vc, lens), **TOL
        )

    def test_len_one(self):
        q = _rand(46, (1, 2, 1, 8))
        kc = _rand(47, (1, 1, 32, 8))
        vc = _rand(48, (1, 1, 32, 8))
        lens = jnp.array([1], jnp.int32)
        out = decode_attention(q, kc, vc, lens, block_k=8)
        np.testing.assert_allclose(
            out, decode_attention_ref(q, kc, vc, lens), **TOL
        )

    def test_garbage_beyond_len_is_ignored(self):
        """Poisoning cache rows beyond lens must not change the output."""
        q = _rand(49, (1, 2, 1, 8))
        kc = _rand(50, (1, 1, 16, 8))
        vc = _rand(51, (1, 1, 16, 8))
        lens = jnp.array([7], jnp.int32)
        base = decode_attention(q, kc, vc, lens, block_k=8)
        kc2 = kc.at[:, :, 7:, :].set(1e6)
        vc2 = vc.at[:, :, 7:, :].set(-1e6)
        poisoned = decode_attention(q, kc2, vc2, lens, block_k=8)
        np.testing.assert_allclose(base, poisoned, **TOL)


@st.composite
def attn_shapes(draw):
    batch = draw(st.integers(1, 3))
    n_kv = draw(st.sampled_from([1, 2]))
    group = draw(st.sampled_from([1, 2, 4]))
    seq = draw(st.integers(1, 40))
    head_dim = draw(st.sampled_from([4, 8, 16]))
    causal = draw(st.booleans())
    block = draw(st.sampled_from([8, 16]))
    return batch, n_kv * group, n_kv, seq, head_dim, causal, block


@hypothesis.settings(
    max_examples=25,
    deadline=None,
    suppress_health_check=[hypothesis.HealthCheck.too_slow],
)
@hypothesis.given(shapes=attn_shapes(), seed=st.integers(0, 2**16))
def test_flash_matches_ref_property(shapes, seed):
    """Property sweep: kernel == oracle across shape/GQA/mask space."""
    batch, n_q, n_kv, seq, head_dim, causal, block = shapes
    key = jax.random.PRNGKey(seed)
    kq, kk, kv, kl = jax.random.split(key, 4)
    q = jax.random.normal(kq, (batch, n_q, seq, head_dim), jnp.float32)
    k = jax.random.normal(kk, (batch, n_kv, seq, head_dim), jnp.float32)
    v = jax.random.normal(kv, (batch, n_kv, seq, head_dim), jnp.float32)
    lens = jax.random.randint(kl, (batch,), 0 if not causal else 1, seq + 1)
    out = flash_attention(
        q, k, v, lens, causal=causal, block_q=block, block_k=block
    )
    ref = attention_ref(q, k, v, lens, causal=causal)
    if causal:
        # Padded-query rows (beyond lens) are garbage-by-contract in the
        # kernel; compare only valid rows.
        for b in range(batch):
            n = int(lens[b])
            np.testing.assert_allclose(out[b, :, :n], ref[b, :, :n], **TOL)
    else:
        np.testing.assert_allclose(out, ref, **TOL)


@hypothesis.settings(max_examples=15, deadline=None)
@hypothesis.given(
    batch=st.integers(1, 3),
    n_kv=st.sampled_from([1, 2]),
    group=st.sampled_from([1, 2]),
    max_seq=st.sampled_from([16, 32, 48]),
    head_dim=st.sampled_from([4, 8]),
    seed=st.integers(0, 2**16),
)
def test_decode_matches_ref_property(batch, n_kv, group, max_seq, head_dim, seed):
    key = jax.random.PRNGKey(seed)
    kq, kk, kv, kl = jax.random.split(key, 4)
    q = jax.random.normal(kq, (batch, n_kv * group, 1, head_dim), jnp.float32)
    kc = jax.random.normal(kk, (batch, n_kv, max_seq, head_dim), jnp.float32)
    vc = jax.random.normal(kv, (batch, n_kv, max_seq, head_dim), jnp.float32)
    lens = jax.random.randint(kl, (batch,), 1, max_seq + 1)
    out = decode_attention(q, kc, vc, lens, block_k=16)
    np.testing.assert_allclose(out, decode_attention_ref(q, kc, vc, lens), **TOL)
