"""L2 correctness: prefill/decode graph consistency and shape contracts.

The serving stack's core invariant: prefilling a prompt then greedily
decoding must produce exactly the same logits as running the full
sequence through the reference forward pass.
"""

import hypothesis
import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M

jax.config.update("jax_platform_name", "cpu")

CFG = M.ModelConfig(
    vocab=61, d_model=32, n_layers=2, n_heads=4, n_kv_heads=2, d_ff=48, max_seq=32
)


@pytest.fixture(scope="module")
def params():
    return M.init_params(jax.random.PRNGKey(7), CFG)


def _tokens(seed, b, s, vocab=CFG.vocab):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.integers(0, vocab, (b, s)), jnp.int32)


class TestShapes:
    def test_prefill_shapes(self, params):
        toks = _tokens(0, 2, 10)
        lens = jnp.array([10, 10], jnp.int32)
        last, kc, vc = M.prefill(params, toks, lens, CFG)
        assert last.shape == (2, CFG.vocab)
        assert kc.shape == (
            CFG.n_layers, 2, CFG.n_kv_heads, CFG.max_seq, CFG.head_dim,
        )
        assert vc.shape == kc.shape

    def test_decode_shapes(self, params):
        toks = _tokens(1, 1, 4)
        _, kc, vc = M.prefill(params, toks, jnp.array([4], jnp.int32), CFG)
        logits, kc2, vc2 = M.decode(
            params, toks[:, 0], jnp.array([4], jnp.int32), kc, vc, CFG
        )
        assert logits.shape == (1, CFG.vocab)
        assert kc2.shape == kc.shape

    def test_num_params_matches_tree(self, params):
        leaves = jax.tree.leaves(params)
        total = sum(int(np.prod(l.shape)) for l in leaves)
        assert total == CFG.num_params()

    def test_kv_cache_bytes_eq3(self):
        # Eq 3: 2 * L * d_model * (Nkv/Nheads) * ISL * BS * BPE
        got = CFG.kv_cache_bytes(batch=3, bytes_per_elt=2)
        expect = (
            2 * CFG.n_layers * CFG.d_model * (CFG.n_kv_heads / CFG.n_heads)
            * CFG.max_seq * 3 * 2
        )
        assert got == int(expect)


class TestConsistency:
    def test_prefill_last_logits_match_forward_full(self, params):
        toks = _tokens(2, 2, 12)
        lens = jnp.array([12, 12], jnp.int32)
        last, _, _ = M.prefill(params, toks, lens, CFG)
        full = M.forward_full(params, toks, CFG)
        np.testing.assert_allclose(last, full[:, -1, :], rtol=1e-4, atol=1e-4)

    def test_kernel_and_oracle_forward_agree(self, params):
        """The training path (oracle) equals the serving path (kernel)."""
        toks = _tokens(3, 2, 16)
        a = M.forward_full(params, toks, CFG, use_kernel=True)
        b = M.forward_full(params, toks, CFG, use_kernel=False)
        np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-4)

    def test_prefill_then_decode_matches_full(self, params):
        """Prefill n tokens, decode m more: every step's logits must match
        the full-sequence forward pass."""
        b, n, m = 2, 6, 4
        toks = _tokens(4, b, n + m)
        full = M.forward_full(params, toks, CFG)

        _, kc, vc = M.prefill(
            params, toks[:, :n], jnp.full((b,), n, jnp.int32), CFG
        )
        for i in range(m):
            pos = jnp.full((b,), n + i, jnp.int32)
            logits, kc, vc = M.decode(params, toks[:, n + i], pos, kc, vc, CFG)
            np.testing.assert_allclose(
                logits, full[:, n + i, :], rtol=1e-3, atol=1e-3,
                err_msg=f"decode step {i}",
            )

    def test_padded_prefill_matches_unpadded(self, params):
        """Right-padding a prompt must not change its last-token logits."""
        toks = _tokens(5, 1, 8)
        last_a, _, _ = M.prefill(params, toks, jnp.array([8], jnp.int32), CFG)
        padded = jnp.pad(toks, ((0, 0), (0, 6)))
        last_b, _, _ = M.prefill(params, padded, jnp.array([8], jnp.int32), CFG)
        np.testing.assert_allclose(last_a, last_b, rtol=1e-4, atol=1e-4)

    def test_batch_order_invariance(self, params):
        """Each batch lane is independent: swapping lanes swaps outputs."""
        toks = _tokens(6, 2, 10)
        lens = jnp.array([10, 7], jnp.int32)
        last, _, _ = M.prefill(params, toks, lens, CFG)
        last_sw, _, _ = M.prefill(params, toks[::-1], lens[::-1], CFG)
        np.testing.assert_allclose(last, last_sw[::-1], rtol=1e-4, atol=1e-4)

    def test_decode_cache_write_position(self, params):
        """Decode must write the new KV row exactly at pos."""
        toks = _tokens(7, 1, 4)
        _, kc, vc = M.prefill(params, toks, jnp.array([4], jnp.int32), CFG)
        _, kc2, _ = M.decode(
            params, toks[:, 0], jnp.array([4], jnp.int32), kc, vc, CFG
        )
        # Rows 0..3 unchanged, row 4 new & nonzero, rows 5+ still zero.
        np.testing.assert_allclose(kc2[:, :, :, :4], kc[:, :, :, :4], atol=1e-7)
        assert float(jnp.abs(kc2[:, :, :, 4]).sum()) > 0.0
        np.testing.assert_allclose(np.asarray(kc2[:, :, :, 5:]), 0.0, atol=1e-7)

    def test_loss_fn_finite_and_decreasing_direction(self, params):
        toks = _tokens(8, 4, 20)
        loss = M.loss_fn(params, toks, CFG)
        assert np.isfinite(float(loss))
        # Random init: loss should be near -log(1/vocab).
        assert abs(float(loss) - np.log(CFG.vocab)) < 1.5


@hypothesis.settings(max_examples=8, deadline=None)
@hypothesis.given(
    n=st.integers(1, 12),
    m=st.integers(1, 4),
    seed=st.integers(0, 1000),
)
def test_prefill_decode_consistency_property(n, m, seed):
    """Property: for any split point, prefill+decode == full forward."""
    cfg = M.ModelConfig(
        vocab=31, d_model=16, n_layers=1, n_heads=2, n_kv_heads=1,
        d_ff=24, max_seq=24,
    )
    params = M.init_params(jax.random.PRNGKey(123), cfg)
    rng = np.random.default_rng(seed)
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (1, n + m)), jnp.int32)
    full = M.forward_full(params, toks, cfg)
    _, kc, vc = M.prefill(params, toks[:, :n], jnp.array([n], jnp.int32), cfg)
    for i in range(m):
        pos = jnp.array([n + i], jnp.int32)
        logits, kc, vc = M.decode(params, toks[:, n + i], pos, kc, vc, cfg)
    np.testing.assert_allclose(
        logits, full[:, -1, :], rtol=2e-3, atol=2e-3
    )
