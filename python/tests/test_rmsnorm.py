"""Fused RMSNorm kernel vs oracle: fixed cases + hypothesis sweeps."""

import hypothesis
import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np

from compile.kernels.rmsnorm import rmsnorm, rmsnorm_ref, rmsnorm_residual

jax.config.update("jax_platform_name", "cpu")

TOL = dict(rtol=1e-5, atol=1e-5)


def _rand(seed, shape, dtype=jnp.float32):
    return jax.random.normal(jax.random.PRNGKey(seed), shape, jnp.float32).astype(dtype)


class TestRmsNorm:
    def test_matches_ref_2d(self):
        x = _rand(0, (32, 96))
        g = _rand(1, (96,))
        np.testing.assert_allclose(rmsnorm(x, g), rmsnorm_ref(x, g), **TOL)

    def test_matches_ref_3d(self):
        x = _rand(2, (4, 17, 64))
        g = _rand(3, (64,))
        np.testing.assert_allclose(rmsnorm(x, g), rmsnorm_ref(x, g), **TOL)

    def test_matches_model_rmsnorm(self):
        from compile.model import _rmsnorm

        x = _rand(4, (8, 32))
        g = jnp.ones((32,))
        np.testing.assert_allclose(rmsnorm(x, g), _rmsnorm(x, g, 1e-5), **TOL)

    def test_rows_not_multiple_of_block(self):
        x = _rand(5, (37, 48))
        g = _rand(6, (48,))
        out = rmsnorm(x, g, block_rows=16)
        np.testing.assert_allclose(out, rmsnorm_ref(x, g), **TOL)

    def test_unit_gain_unit_norm(self):
        x = _rand(7, (16, 128))
        out = rmsnorm(x, jnp.ones((128,)))
        rms = jnp.sqrt(jnp.mean(jnp.square(out), axis=-1))
        np.testing.assert_allclose(np.asarray(rms), 1.0, rtol=1e-3)

    def test_bf16(self):
        x = _rand(8, (8, 64), jnp.bfloat16)
        g = _rand(9, (64,))
        out = rmsnorm(x, g)
        assert out.dtype == jnp.bfloat16
        np.testing.assert_allclose(
            np.asarray(out, np.float32),
            np.asarray(rmsnorm_ref(x, g), np.float32),
            rtol=2e-2,
            atol=2e-2,
        )


class TestFusedResidual:
    def test_matches_unfused(self):
        x = _rand(10, (16, 64))
        r = _rand(11, (16, 64))
        g = _rand(12, (64,))
        out, res = rmsnorm_residual(x, r, g)
        np.testing.assert_allclose(res, x + r, **TOL)
        np.testing.assert_allclose(out, rmsnorm_ref(x + r, g), **TOL)

    def test_zero_residual_is_plain_rmsnorm(self):
        x = _rand(13, (8, 32))
        g = _rand(14, (32,))
        out, res = rmsnorm_residual(x, jnp.zeros_like(x), g)
        np.testing.assert_allclose(out, rmsnorm(x, g), **TOL)
        np.testing.assert_allclose(res, x, **TOL)


@hypothesis.settings(max_examples=25, deadline=None)
@hypothesis.given(
    rows=st.integers(1, 64),
    d=st.sampled_from([8, 24, 96, 128]),
    block=st.sampled_from([8, 32, 128]),
    seed=st.integers(0, 2**16),
    scale=st.floats(0.01, 100.0),
)
def test_rmsnorm_property(rows, d, block, seed, scale):
    key = jax.random.PRNGKey(seed)
    kx, kg = jax.random.split(key)
    x = scale * jax.random.normal(kx, (rows, d), jnp.float32)
    g = jax.random.normal(kg, (d,), jnp.float32)
    out = rmsnorm(x, g, block_rows=block)
    np.testing.assert_allclose(out, rmsnorm_ref(x, g), rtol=1e-4, atol=1e-4)


@hypothesis.settings(max_examples=15, deadline=None)
@hypothesis.given(
    rows=st.integers(1, 32),
    d=st.sampled_from([16, 64]),
    seed=st.integers(0, 2**16),
)
def test_fused_residual_property(rows, d, seed):
    key = jax.random.PRNGKey(seed)
    kx, kr, kg = jax.random.split(key, 3)
    x = jax.random.normal(kx, (rows, d), jnp.float32)
    r = jax.random.normal(kr, (rows, d), jnp.float32)
    g = jax.random.normal(kg, (d,), jnp.float32)
    out, res = rmsnorm_residual(x, r, g)
    np.testing.assert_allclose(res, x + r, rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(out, rmsnorm_ref(x + r, g), rtol=1e-4, atol=1e-4)
