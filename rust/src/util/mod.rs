//! Utility substrate the offline environment forces us to own:
//! deterministic PRNG, JSON emission, CLI parsing, a micro-benchmark
//! harness (criterion is unavailable), and a property-testing harness
//! (proptest is unavailable).

pub mod bench;
pub mod cli;
pub mod json;
pub mod prop;
pub mod rng;
pub mod stats;

/// Format a duration in human units (ns/µs/ms/s).
pub fn fmt_duration(secs: f64) -> String {
    if secs < 1e-6 {
        format!("{:.1}ns", secs * 1e9)
    } else if secs < 1e-3 {
        format!("{:.2}µs", secs * 1e6)
    } else if secs < 1.0 {
        format!("{:.2}ms", secs * 1e3)
    } else {
        format!("{:.2}s", secs)
    }
}

/// Format a byte count in human units.
pub fn fmt_bytes(bytes: f64) -> String {
    const UNITS: [&str; 6] = ["B", "KiB", "MiB", "GiB", "TiB", "PiB"];
    let mut v = bytes;
    let mut u = 0;
    while v >= 1024.0 && u < UNITS.len() - 1 {
        v /= 1024.0;
        u += 1;
    }
    format!("{:.2}{}", v, UNITS[u])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn duration_units() {
        assert_eq!(fmt_duration(0.5e-9 * 2.0), "1.0ns");
        assert!(fmt_duration(2.5e-6).ends_with("µs"));
        assert!(fmt_duration(3e-3).ends_with("ms"));
        assert!(fmt_duration(1.5).ends_with('s'));
    }

    #[test]
    fn byte_units() {
        assert_eq!(fmt_bytes(512.0), "512.00B");
        assert_eq!(fmt_bytes(2048.0), "2.00KiB");
        assert_eq!(fmt_bytes(3.0 * 1024.0 * 1024.0 * 1024.0), "3.00GiB");
    }
}
