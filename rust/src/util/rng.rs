//! Deterministic xoshiro256** PRNG.
//!
//! The simulator, workload generators, and property-test harness all need
//! reproducible randomness; the `rand` crate is not in the offline
//! registry, so we implement xoshiro256** (Blackman & Vigna) directly.

/// xoshiro256** generator; splittable and seedable for reproducibility.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Seed via SplitMix64 (recommended initializer for xoshiro).
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let mut next = || {
            sm = sm.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        Rng {
            s: [next(), next(), next(), next()],
        }
    }

    pub fn next_u64(&mut self) -> u64 {
        let r = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        r
    }

    /// Uniform in `[0, 1)`.
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in `[lo, hi)`; `lo < hi` required.
    pub fn range(&mut self, lo: u64, hi: u64) -> u64 {
        assert!(lo < hi, "empty range [{lo}, {hi})");
        lo + self.next_u64() % (hi - lo)
    }

    /// Uniform usize in `[0, n)`.
    pub fn index(&mut self, n: usize) -> usize {
        self.range(0, n as u64) as usize
    }

    pub fn bool(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Exponential variate with the given rate (Poisson inter-arrivals).
    pub fn exp(&mut self, rate: f64) -> f64 {
        let u = 1.0 - self.f64(); // avoid ln(0)
        -u.ln() / rate
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = 1.0 - self.f64();
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Log-normal variate (agent tool-call latencies are heavy-tailed).
    pub fn lognormal(&mut self, mu: f64, sigma: f64) -> f64 {
        (mu + sigma * self.normal()).exp()
    }

    /// Fork an independent stream (for per-component determinism).
    pub fn split(&mut self) -> Rng {
        Rng::new(self.next_u64())
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.index(i + 1);
            xs.swap(i, j);
        }
    }

    /// Pick a reference to a uniformly random element.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.index(xs.len())]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        assert_ne!(Rng::new(1).next_u64(), Rng::new(2).next_u64());
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn range_bounds() {
        let mut r = Rng::new(9);
        for _ in 0..10_000 {
            let x = r.range(10, 20);
            assert!((10..20).contains(&x));
        }
    }

    #[test]
    fn f64_mean_near_half() {
        let mut r = Rng::new(11);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| r.f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn exp_mean_matches_rate() {
        let mut r = Rng::new(13);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| r.exp(4.0)).sum::<f64>() / n as f64;
        assert!((mean - 0.25).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(17);
        let n = 100_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(19);
        let mut xs: Vec<u32> = (0..50).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort();
        assert_eq!(sorted, (0..50).collect::<Vec<u32>>());
    }

    #[test]
    fn split_streams_independent() {
        let mut r = Rng::new(23);
        let mut a = r.split();
        let mut b = r.split();
        assert_ne!(a.next_u64(), b.next_u64());
    }
}
