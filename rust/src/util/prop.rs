//! Property-testing harness (proptest is not in the offline registry).
//!
//! Minimal but honest: generators over a seeded [`Rng`](super::rng::Rng),
//! a configurable case count, and failure reporting that prints the seed
//! so any counterexample replays deterministically. Shrinking is traded
//! for reproducibility — with a printed seed, `cargo test -- --nocapture`
//! plus a temporary `case_seed` pin recovers the exact failing input.

use super::rng::Rng;

/// Number of cases per property (override with `AH_PROP_CASES`).
pub fn default_cases() -> u64 {
    std::env::var("AH_PROP_CASES")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(128)
}

/// Run `prop` against `cases` seeded inputs; panic with the failing seed.
pub fn check<F: FnMut(&mut Rng)>(name: &str, mut prop: F) {
    check_cases(name, default_cases(), &mut prop)
}

/// Run with an explicit case count.
pub fn check_cases<F: FnMut(&mut Rng)>(name: &str, cases: u64, prop: &mut F) {
    let base = 0xA6E5_7E50u64;
    for case in 0..cases {
        let seed = base.wrapping_add(case.wrapping_mul(0x9E3779B97F4A7C15));
        let mut rng = Rng::new(seed);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            prop(&mut rng)
        }));
        if let Err(payload) = result {
            let msg = payload
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| payload.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_else(|| "<non-string panic>".to_string());
            panic!(
                "property '{name}' failed at case {case} (seed {seed:#x}): {msg}"
            );
        }
    }
}

/// Generate a vector with a random length in `[0, max_len]`.
pub fn vec_of<T>(rng: &mut Rng, max_len: usize, mut gen: impl FnMut(&mut Rng) -> T) -> Vec<T> {
    let n = rng.index(max_len + 1);
    (0..n).map(|_| gen(rng)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        check_cases("add-commutes", 64, &mut |rng| {
            let a = rng.range(0, 1000);
            let b = rng.range(0, 1000);
            assert_eq!(a + b, b + a);
        });
    }

    #[test]
    #[should_panic(expected = "property 'always-fails' failed")]
    fn failing_property_reports_seed() {
        // Quiet the expected panic's backtrace noise.
        let prev = std::panic::take_hook();
        std::panic::set_hook(Box::new(|_| {}));
        let r = std::panic::catch_unwind(|| {
            check_cases("always-fails", 8, &mut |_rng| panic!("boom"));
        });
        std::panic::set_hook(prev);
        std::panic::resume_unwind(r.unwrap_err());
    }

    #[test]
    fn vec_of_respects_max_len() {
        let mut rng = Rng::new(1);
        for _ in 0..100 {
            let v = vec_of(&mut rng, 7, |r| r.next_u64());
            assert!(v.len() <= 7);
        }
    }
}
