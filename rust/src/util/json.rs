//! Minimal JSON document tree (serde is not in the offline registry).
//!
//! Benches and the CLI emit machine-readable results (EXPERIMENTS.md
//! tables, plot series) through the writer, and [`Json::parse`] reads
//! them back — [`crate::plan::ExecutionPlan`] round-trips through this
//! module so plans can be saved, diffed, and replayed. Human-authored
//! configs still use the TOML-subset parser in [`crate::config`].
//!
//! Object literals are best written with the [`jobj!`](crate::jobj)
//! macro; dynamic mutation uses the fallible [`Json::try_set`] /
//! [`Json::try_push`] (no library-path panics).

use std::collections::BTreeMap;
use std::fmt::Write as _;

use crate::{Error, Result};

/// Build a [`Json::Obj`] from `key => value` pairs (values go through
/// `Into<Json>`). Infallible by construction — the receiver is always
/// an object — unlike mutating an arbitrary `Json` with `try_set`.
#[macro_export]
macro_rules! jobj {
    ( $( $k:expr => $v:expr ),* $(,)? ) => {{
        #[allow(unused_mut)]
        let mut m = std::collections::BTreeMap::new();
        $( m.insert(($k).to_string(), $crate::util::json::Json::from($v)); )*
        $crate::util::json::Json::Obj(m)
    }};
}

/// A JSON value tree.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    // BTreeMap for deterministic key order (diffable outputs).
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn obj() -> Json {
        Json::Obj(BTreeMap::new())
    }

    /// Insert `key` into an object; `Err` on non-objects (the former
    /// `set` builder panicked here — library paths must not).
    pub fn try_set(&mut self, key: &str, val: impl Into<Json>) -> Result<()> {
        match self {
            Json::Obj(m) => {
                m.insert(key.to_string(), val.into());
                Ok(())
            }
            other => Err(Error::Runtime(format!(
                "Json::try_set on non-object ({})",
                other.kind()
            ))),
        }
    }

    /// Append to an array; `Err` on non-arrays.
    pub fn try_push(&mut self, val: impl Into<Json>) -> Result<()> {
        match self {
            Json::Arr(v) => {
                v.push(val.into());
                Ok(())
            }
            other => Err(Error::Runtime(format!(
                "Json::try_push on non-array ({})",
                other.kind()
            ))),
        }
    }

    /// The variant name, for error messages.
    pub fn kind(&self) -> &'static str {
        match self {
            Json::Null => "null",
            Json::Bool(_) => "bool",
            Json::Num(_) => "number",
            Json::Str(_) => "string",
            Json::Arr(_) => "array",
            Json::Obj(_) => "object",
        }
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_u64(&self) -> Option<u64> {
        match self {
            Json::Num(n) if *n >= 0.0 && n.fract() == 0.0 => Some(*n as u64),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    /// Serialize compactly.
    #[allow(clippy::inherent_to_string)]
    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, None, 0);
        s
    }

    /// Append the compact serialization to `out` without allocating an
    /// intermediate `String` — the per-event path of streaming writers
    /// like [`crate::obs::trace::to_chrome_json_string`].
    pub fn write_compact(&self, out: &mut String) {
        self.write(out, None, 0);
    }

    /// Serialize with 2-space indentation.
    pub fn pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, Some(2), 0);
        s
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else if n.is_finite() {
                    let _ = write!(out, "{}", n);
                } else {
                    out.push_str("null"); // JSON has no Inf/NaN
                }
            }
            Json::Str(s) => Self::write_escaped(out, s),
            Json::Arr(xs) => {
                out.push('[');
                for (i, x) in xs.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    Self::newline(out, indent, depth + 1);
                    x.write(out, indent, depth + 1);
                }
                if !xs.is_empty() {
                    Self::newline(out, indent, depth);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    Self::newline(out, indent, depth + 1);
                    Self::write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                if !m.is_empty() {
                    Self::newline(out, indent, depth);
                }
                out.push('}');
            }
        }
    }

    fn newline(out: &mut String, indent: Option<usize>, depth: usize) {
        if let Some(w) = indent {
            out.push('\n');
            for _ in 0..w * depth {
                out.push(' ');
            }
        }
    }

    /// Parse a JSON document (the full grammar this writer emits, plus
    /// standard escapes and `\uXXXX`). Numbers parse as `f64`, matching
    /// the tree's representation.
    pub fn parse(src: &str) -> Result<Json> {
        let mut p = Parser {
            bytes: src.as_bytes(),
            pos: 0,
            depth: 0,
        };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing characters after document"));
        }
        Ok(v)
    }

    fn write_escaped(out: &mut String, s: &str) {
        out.push('"');
        for c in s.chars() {
            match c {
                '"' => out.push_str("\\\""),
                '\\' => out.push_str("\\\\"),
                '\n' => out.push_str("\\n"),
                '\r' => out.push_str("\\r"),
                '\t' => out.push_str("\\t"),
                c if (c as u32) < 0x20 => {
                    let _ = write!(out, "\\u{:04x}", c as u32);
                }
                c => out.push(c),
            }
        }
        out.push('"');
    }
}

/// Deepest container nesting the parser accepts. Recursive descent
/// burns native stack per level; a bound turns adversarial inputs
/// (100k `[`s) into `Err` instead of a stack overflow.
const MAX_DEPTH: usize = 128;

/// Recursive-descent JSON parser over the input bytes.
struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
    depth: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> Error {
        Error::Parse {
            // 1-based "line" is really a byte offset here; JSON payloads
            // are machine-written single documents.
            line: self.pos + 1,
            msg: msg.to_string(),
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn eat(&mut self, c: u8) -> Result<()> {
        if self.peek() == Some(c) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected {:?}", c as char)))
        }
    }

    fn literal(&mut self, word: &str, v: Json) -> Result<Json> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected `{word}`")))
        }
    }

    fn value(&mut self) -> Result<Json> {
        match self.peek() {
            Some(b'n') => self.literal("null", Json::Null),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'"') => self.string().map(Json::Str),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            Some(c) => Err(self.err(&format!("unexpected {:?}", c as char))),
            None => Err(self.err("unexpected end of input")),
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.pos;
        while matches!(
            self.peek(),
            Some(b'-' | b'+' | b'.' | b'e' | b'E' | b'0'..=b'9')
        ) {
            self.pos += 1;
        }
        let s = std::str::from_utf8(&self.bytes[start..self.pos])
            .map_err(|_| self.err("invalid number bytes"))?;
        s.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err(&format!("invalid number `{s}`")))
    }

    fn string(&mut self) -> Result<String> {
        self.eat(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    let esc = self.peek().ok_or_else(|| self.err("bad escape"))?;
                    self.pos += 1;
                    match esc {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'n' => out.push('\n'),
                        b'r' => out.push('\r'),
                        b't' => out.push('\t'),
                        b'b' => out.push('\u{0008}'),
                        b'f' => out.push('\u{000C}'),
                        b'u' => {
                            if self.pos + 4 > self.bytes.len() {
                                return Err(self.err("truncated \\u escape"));
                            }
                            let hex =
                                std::str::from_utf8(&self.bytes[self.pos..self.pos + 4])
                                    .map_err(|_| self.err("bad \\u escape"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            self.pos += 4;
                            // Surrogates only arise for astral chars the
                            // writer never emits; map them to U+FFFD.
                            out.push(char::from_u32(cp).unwrap_or('\u{FFFD}'));
                        }
                        _ => return Err(self.err("unknown escape")),
                    }
                }
                Some(_) => {
                    // Consume one UTF-8 scalar (multi-byte safe).
                    let rest = std::str::from_utf8(&self.bytes[self.pos..])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    let c = rest.chars().next().unwrap();
                    out.push(c);
                    self.pos += c.len_utf8();
                }
            }
        }
    }

    fn enter(&mut self) -> Result<()> {
        self.depth += 1;
        if self.depth > MAX_DEPTH {
            return Err(self.err("nesting too deep"));
        }
        Ok(())
    }

    fn array(&mut self) -> Result<Json> {
        self.eat(b'[')?;
        self.enter()?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            self.depth -= 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    self.depth -= 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(self.err("expected `,` or `]`")),
            }
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.eat(b'{')?;
        self.enter()?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            self.depth -= 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.eat(b':')?;
            self.skip_ws();
            m.insert(key, self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    self.depth -= 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(self.err("expected `,` or `}`")),
            }
        }
    }
}

impl From<bool> for Json {
    fn from(b: bool) -> Json {
        Json::Bool(b)
    }
}
impl From<f64> for Json {
    fn from(n: f64) -> Json {
        Json::Num(n)
    }
}
impl From<i64> for Json {
    fn from(n: i64) -> Json {
        Json::Num(n as f64)
    }
}
impl From<usize> for Json {
    fn from(n: usize) -> Json {
        Json::Num(n as f64)
    }
}
impl From<u64> for Json {
    fn from(n: u64) -> Json {
        Json::Num(n as f64)
    }
}
impl From<u32> for Json {
    fn from(n: u32) -> Json {
        Json::Num(n as f64)
    }
}
impl From<&str> for Json {
    fn from(s: &str) -> Json {
        Json::Str(s.to_string())
    }
}
impl From<String> for Json {
    fn from(s: String) -> Json {
        Json::Str(s)
    }
}
impl<T: Into<Json>> From<Vec<T>> for Json {
    fn from(xs: Vec<T>) -> Json {
        Json::Arr(xs.into_iter().map(Into::into).collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_basic() {
        let j = crate::jobj! {
            "name" => "h100",
            "tflops" => 1979.0,
            "ok" => true,
            "tags" => vec!["gpu", "nvidia"],
        };
        assert_eq!(
            j.to_string(),
            r#"{"name":"h100","ok":true,"tags":["gpu","nvidia"],"tflops":1979}"#
        );
    }

    #[test]
    fn try_set_and_try_push() {
        let mut o = Json::obj();
        o.try_set("a", 1i64).unwrap();
        assert_eq!(o.to_string(), r#"{"a":1}"#);
        assert!(Json::Num(1.0).try_set("a", 1i64).is_err());

        let mut a = Json::Arr(vec![]);
        a.try_push("x").unwrap();
        assert_eq!(a.to_string(), r#"["x"]"#);
        assert!(Json::obj().try_push(1i64).is_err());
    }

    #[test]
    fn parse_round_trips_writer_output() {
        let j = crate::jobj! {
            "pipelines" => Json::Arr(vec![
                crate::jobj! { "device" => "H100", "tp" => 2i64 },
                crate::jobj! { "device" => "Gaudi3", "tp" => 1i64 },
            ]),
            "sla_s" => 0.25,
            "name" => "voice\nagent \"v2\"",
            "none" => Json::Null,
            "on" => true,
        };
        for text in [j.to_string(), j.pretty()] {
            let back = Json::parse(&text).unwrap();
            assert_eq!(back, j);
            assert_eq!(back.to_string(), j.to_string());
        }
    }

    #[test]
    fn parse_rejects_malformed() {
        for bad in [
            "",
            "{",
            "[1,)",
            "{\"a\" 1}",
            "tru",
            "1 2",
            "{\"a\":}",
            "\"unterminated",
        ] {
            assert!(Json::parse(bad).is_err(), "accepted {bad:?}");
        }
    }

    #[test]
    fn parse_bounds_nesting_depth() {
        let deep = "[".repeat(100_000) + &"]".repeat(100_000);
        let err = Json::parse(&deep).unwrap_err();
        assert!(err.to_string().contains("nesting too deep"), "{err}");
        // Depth within the bound still parses.
        let ok = "[".repeat(64) + &"]".repeat(64);
        assert!(Json::parse(&ok).is_ok());
    }

    #[test]
    fn parse_escapes_and_unicode() {
        let j = Json::parse(r#""a\"b\\c\ndA π""#).unwrap();
        assert_eq!(j, Json::Str("a\"b\\c\ndA π".to_string()));
        assert_eq!(
            Json::parse(r#""\u0041\u00e9""#).unwrap(),
            Json::Str("A\u{00e9}".to_string())
        );
    }

    #[test]
    fn escapes() {
        let j = Json::Str("a\"b\\c\nd".to_string());
        assert_eq!(j.to_string(), r#""a\"b\\c\nd""#);
    }

    #[test]
    fn non_finite_becomes_null() {
        assert_eq!(Json::Num(f64::NAN).to_string(), "null");
        assert_eq!(Json::Num(f64::INFINITY).to_string(), "null");
    }

    #[test]
    fn pretty_indents() {
        let j = crate::jobj! { "a" => 1i64 };
        assert_eq!(j.pretty(), "{\n  \"a\": 1\n}");
    }

    #[test]
    fn empty_containers() {
        assert_eq!(Json::obj().to_string(), "{}");
        assert_eq!(Json::Arr(vec![]).to_string(), "[]");
    }

    #[test]
    fn float_precision_kept() {
        assert_eq!(Json::Num(0.095).to_string(), "0.095");
    }
}
