//! Minimal JSON document builder (serde is not in the offline registry).
//!
//! Benches and the CLI emit machine-readable results (EXPERIMENTS.md
//! tables, plot series) through this writer. Parsing is intentionally not
//! implemented — configs use the TOML-subset parser in [`crate::config`].

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A JSON value tree.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    // BTreeMap for deterministic key order (diffable outputs).
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn obj() -> Json {
        Json::Obj(BTreeMap::new())
    }

    pub fn set(mut self, key: &str, val: impl Into<Json>) -> Json {
        if let Json::Obj(ref mut m) = self {
            m.insert(key.to_string(), val.into());
        } else {
            panic!("Json::set on non-object");
        }
        self
    }

    pub fn push(&mut self, val: impl Into<Json>) {
        if let Json::Arr(ref mut v) = self {
            v.push(val.into());
        } else {
            panic!("Json::push on non-array");
        }
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// Serialize compactly.
    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, None, 0);
        s
    }

    /// Serialize with 2-space indentation.
    pub fn pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, Some(2), 0);
        s
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else if n.is_finite() {
                    let _ = write!(out, "{}", n);
                } else {
                    out.push_str("null"); // JSON has no Inf/NaN
                }
            }
            Json::Str(s) => Self::write_escaped(out, s),
            Json::Arr(xs) => {
                out.push('[');
                for (i, x) in xs.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    Self::newline(out, indent, depth + 1);
                    x.write(out, indent, depth + 1);
                }
                if !xs.is_empty() {
                    Self::newline(out, indent, depth);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    Self::newline(out, indent, depth + 1);
                    Self::write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                if !m.is_empty() {
                    Self::newline(out, indent, depth);
                }
                out.push('}');
            }
        }
    }

    fn newline(out: &mut String, indent: Option<usize>, depth: usize) {
        if let Some(w) = indent {
            out.push('\n');
            for _ in 0..w * depth {
                out.push(' ');
            }
        }
    }

    fn write_escaped(out: &mut String, s: &str) {
        out.push('"');
        for c in s.chars() {
            match c {
                '"' => out.push_str("\\\""),
                '\\' => out.push_str("\\\\"),
                '\n' => out.push_str("\\n"),
                '\r' => out.push_str("\\r"),
                '\t' => out.push_str("\\t"),
                c if (c as u32) < 0x20 => {
                    let _ = write!(out, "\\u{:04x}", c as u32);
                }
                c => out.push(c),
            }
        }
        out.push('"');
    }
}

impl From<bool> for Json {
    fn from(b: bool) -> Json {
        Json::Bool(b)
    }
}
impl From<f64> for Json {
    fn from(n: f64) -> Json {
        Json::Num(n)
    }
}
impl From<i64> for Json {
    fn from(n: i64) -> Json {
        Json::Num(n as f64)
    }
}
impl From<usize> for Json {
    fn from(n: usize) -> Json {
        Json::Num(n as f64)
    }
}
impl From<u64> for Json {
    fn from(n: u64) -> Json {
        Json::Num(n as f64)
    }
}
impl From<u32> for Json {
    fn from(n: u32) -> Json {
        Json::Num(n as f64)
    }
}
impl From<&str> for Json {
    fn from(s: &str) -> Json {
        Json::Str(s.to_string())
    }
}
impl From<String> for Json {
    fn from(s: String) -> Json {
        Json::Str(s)
    }
}
impl<T: Into<Json>> From<Vec<T>> for Json {
    fn from(xs: Vec<T>) -> Json {
        Json::Arr(xs.into_iter().map(Into::into).collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_basic() {
        let j = Json::obj()
            .set("name", "h100")
            .set("tflops", 1979.0)
            .set("ok", true)
            .set("tags", vec!["gpu", "nvidia"]);
        assert_eq!(
            j.to_string(),
            r#"{"name":"h100","ok":true,"tags":["gpu","nvidia"],"tflops":1979}"#
        );
    }

    #[test]
    fn escapes() {
        let j = Json::Str("a\"b\\c\nd".to_string());
        assert_eq!(j.to_string(), r#""a\"b\\c\nd""#);
    }

    #[test]
    fn non_finite_becomes_null() {
        assert_eq!(Json::Num(f64::NAN).to_string(), "null");
        assert_eq!(Json::Num(f64::INFINITY).to_string(), "null");
    }

    #[test]
    fn pretty_indents() {
        let j = Json::obj().set("a", 1i64);
        assert_eq!(j.pretty(), "{\n  \"a\": 1\n}");
    }

    #[test]
    fn empty_containers() {
        assert_eq!(Json::obj().to_string(), "{}");
        assert_eq!(Json::Arr(vec![]).to_string(), "[]");
    }

    #[test]
    fn float_precision_kept() {
        assert_eq!(Json::Num(0.095).to_string(), "0.095");
    }
}
