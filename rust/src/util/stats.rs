//! Streaming order statistics for million-request runs.
//!
//! [`QuantileSketch`] replaces the simulator's per-request latency
//! vectors: it stores samples exactly up to a fixed cap (so small runs
//! report **bit-identical** percentiles to the historical
//! sort-the-whole-vector path in [`crate::util::bench::percentile`]),
//! then folds everything into a log-bucketed histogram with ~1%
//! relative resolution. Memory is bounded by the cap and the fixed
//! bucket count, never by the number of samples — the piece that lets
//! `DagSim` ingest an unbounded arrival stream in constant memory.

/// Samples stored exactly before spilling into the histogram. 256 Ki
/// f64s = 2 MiB per sketch; every pre-streaming workload in the repo
/// (tests, benches, conformance suites) stays under this, so their
/// reported percentiles are unchanged to the last bit.
pub const EXACT_CAP: usize = 1 << 18;

/// Smallest resolvable positive sample, seconds. Anything below (or
/// non-positive) lands in the underflow bucket and reports as the
/// observed minimum.
const HIST_MIN: f64 = 1e-9;
/// Geometric bucket growth: each bucket spans ~2% of its lower edge.
const HIST_GROWTH: f64 = 1.02;
/// Buckets covering [1e-9, ~3e7) seconds: ceil(ln(3e16)/ln(1.02)).
const HIST_BUCKETS: usize = 1920;

/// Fixed-memory log-bucketed histogram (the spill target).
struct LogHist {
    counts: Vec<u64>,
    under: u64,
    over: u64,
    count: u64,
    min: f64,
    max: f64,
    inv_ln_growth: f64,
}

impl LogHist {
    fn new() -> LogHist {
        LogHist {
            counts: vec![0; HIST_BUCKETS],
            under: 0,
            over: 0,
            count: 0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
            inv_ln_growth: 1.0 / HIST_GROWTH.ln(),
        }
    }

    fn push(&mut self, x: f64) {
        // Durations are finite by construction (admission rejects
        // non-finite event times); clamp defensively anyway.
        let x = if x.is_finite() { x } else { 0.0 };
        self.count += 1;
        self.min = self.min.min(x);
        self.max = self.max.max(x);
        if x < HIST_MIN {
            self.under += 1;
            return;
        }
        let idx = ((x / HIST_MIN).ln() * self.inv_ln_growth) as usize;
        if idx >= HIST_BUCKETS {
            self.over += 1;
        } else {
            self.counts[idx] += 1;
        }
    }

    /// Nearest-rank quantile walk; bucket values are geometric
    /// midpoints clamped into the observed [min, max] envelope.
    fn quantile(&self, p: f64) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        let rank = ((p / 100.0).clamp(0.0, 1.0) * (self.count - 1) as f64).round() as u64;
        let mut seen = self.under;
        if rank < seen {
            return self.min;
        }
        for (i, &c) in self.counts.iter().enumerate() {
            seen += c;
            if rank < seen {
                let lo = HIST_MIN * HIST_GROWTH.powi(i as i32);
                let mid = lo * HIST_GROWTH.sqrt();
                return mid.clamp(self.min, self.max);
            }
        }
        self.max
    }
}

/// Exact-then-histogram streaming quantile estimator. See the module
/// docs for the exactness contract.
pub struct QuantileSketch {
    cap: usize,
    exact: Vec<f64>,
    hist: Option<LogHist>,
}

impl Default for QuantileSketch {
    fn default() -> Self {
        QuantileSketch::new()
    }
}

impl QuantileSketch {
    pub fn new() -> QuantileSketch {
        QuantileSketch::with_cap(EXACT_CAP)
    }

    /// A sketch that spills after `cap` samples (tests shrink it to
    /// exercise the histogram path cheaply).
    pub fn with_cap(cap: usize) -> QuantileSketch {
        QuantileSketch {
            cap: cap.max(1),
            exact: Vec::new(),
            hist: None,
        }
    }

    pub fn push(&mut self, x: f64) {
        if let Some(h) = &mut self.hist {
            h.push(x);
            return;
        }
        if self.exact.len() < self.cap {
            self.exact.push(x);
            return;
        }
        // Cap crossed: fold the exact prefix into the histogram and
        // release the sample buffer — memory is flat from here on.
        let mut h = LogHist::new();
        for &v in &self.exact {
            h.push(v);
        }
        h.push(x);
        self.exact = Vec::new();
        self.hist = Some(h);
    }

    pub fn count(&self) -> u64 {
        match &self.hist {
            Some(h) => h.count,
            None => self.exact.len() as u64,
        }
    }

    pub fn is_empty(&self) -> bool {
        self.count() == 0
    }

    /// Nearest-rank quantile, `p` in [0, 100]. Below the cap this is
    /// bit-identical to [`crate::util::bench::percentile`]; above it,
    /// log-bucketed (~1–2% relative error). Returns 0.0 when empty.
    pub fn quantile(&self, p: f64) -> f64 {
        match &self.hist {
            Some(h) => h.quantile(p),
            None => {
                if self.exact.is_empty() {
                    return 0.0;
                }
                let mut v = self.exact.clone();
                v.sort_by(|a, b| a.total_cmp(b));
                let idx = ((p / 100.0) * (v.len() - 1) as f64).round() as usize;
                v[idx.min(v.len() - 1)]
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::bench::percentile;
    use crate::util::rng::Rng;

    #[test]
    fn exact_path_matches_percentile_bit_for_bit() {
        let mut rng = Rng::new(7);
        let xs: Vec<f64> = (0..5000).map(|_| rng.lognormal(-1.0, 0.8)).collect();
        let mut q = QuantileSketch::new();
        for &x in &xs {
            q.push(x);
        }
        for p in [0.0, 10.0, 50.0, 90.0, 95.0, 99.0, 100.0] {
            assert_eq!(q.quantile(p), percentile(&xs, p), "p={p}");
        }
    }

    #[test]
    fn spilled_path_stays_close_to_exact() {
        let mut rng = Rng::new(11);
        let xs: Vec<f64> = (0..20_000).map(|_| rng.lognormal(0.0, 1.0)).collect();
        let mut q = QuantileSketch::with_cap(256); // force the histogram
        for &x in &xs {
            q.push(x);
        }
        assert_eq!(q.count(), xs.len() as u64);
        for p in [50.0, 90.0, 95.0, 99.0] {
            let approx = q.quantile(p);
            let exact = percentile(&xs, p);
            let rel = (approx - exact).abs() / exact;
            assert!(rel < 0.03, "p={p}: {approx} vs {exact} (rel {rel})");
        }
    }

    #[test]
    fn spilled_extremes_clamp_to_observed_range() {
        let mut q = QuantileSketch::with_cap(4);
        for x in [0.5, 1.0, 2.0, 4.0, 8.0, 16.0] {
            q.push(x);
        }
        assert!(q.quantile(0.0) >= 0.5);
        assert!(q.quantile(100.0) <= 16.0 + 1e-12);
    }

    #[test]
    fn zero_and_tiny_samples_hit_the_underflow_bucket() {
        let mut q = QuantileSketch::with_cap(2);
        for x in [0.0, 0.0, 0.0, 1e-12, 0.0] {
            q.push(x);
        }
        assert_eq!(q.quantile(50.0), 0.0);
    }

    #[test]
    fn empty_sketch_reports_zero() {
        let q = QuantileSketch::new();
        assert!(q.is_empty());
        assert_eq!(q.quantile(50.0), 0.0);
    }
}
