//! Micro-benchmark harness (criterion is not in the offline registry).
//!
//! Benches are built with `harness = false` in `Cargo.toml` and call
//! [`Bench::run`] / [`Bench::throughput`]. The harness does warmup,
//! adaptive iteration counts, and reports mean / p50 / p95 plus optional
//! throughput — enough statistical hygiene for the §Perf iteration loop.

use std::hint::black_box;
use std::time::Instant;

/// Result of one benchmark case.
#[derive(Debug, Clone)]
pub struct Sample {
    pub name: String,
    pub iters: u64,
    pub mean_s: f64,
    pub p50_s: f64,
    pub p95_s: f64,
    pub min_s: f64,
    /// Optional items/sec derived from a per-iteration item count.
    pub throughput: Option<f64>,
}

impl Sample {
    pub fn report(&self) -> String {
        let tp = match self.throughput {
            Some(t) if t >= 1e6 => format!("  {:>9.2} Mitem/s", t / 1e6),
            Some(t) if t >= 1e3 => format!("  {:>9.2} Kitem/s", t / 1e3),
            Some(t) => format!("  {:>9.2} item/s", t),
            None => String::new(),
        };
        format!(
            "{:<44} {:>10}/iter  p50 {:>10}  p95 {:>10}  ({} iters){}",
            self.name,
            super::fmt_duration(self.mean_s),
            super::fmt_duration(self.p50_s),
            super::fmt_duration(self.p95_s),
            self.iters,
            tp
        )
    }
}

/// Benchmark runner with a fixed time budget per case.
pub struct Bench {
    /// Target measurement time per case, seconds.
    pub budget_s: f64,
    /// Warmup time per case, seconds.
    pub warmup_s: f64,
    pub samples: Vec<Sample>,
}

impl Default for Bench {
    fn default() -> Self {
        Bench {
            budget_s: 1.0,
            warmup_s: 0.2,
            samples: Vec::new(),
        }
    }
}

impl Bench {
    pub fn new() -> Self {
        Self::default()
    }

    /// Quick-mode harness for CI: tiny budgets.
    pub fn quick() -> Self {
        Bench {
            budget_s: 0.2,
            warmup_s: 0.05,
            samples: Vec::new(),
        }
    }

    /// Time `f`, which is called repeatedly; returns the recorded sample.
    pub fn run<T>(&mut self, name: &str, mut f: impl FnMut() -> T) -> &Sample {
        self.run_with_items(name, None, &mut || {
            black_box(f());
        })
    }

    /// Time `f` and report items/sec given `items` produced per call.
    pub fn throughput<T>(
        &mut self,
        name: &str,
        items: u64,
        mut f: impl FnMut() -> T,
    ) -> &Sample {
        self.run_with_items(name, Some(items), &mut || {
            black_box(f());
        })
    }

    fn run_with_items(
        &mut self,
        name: &str,
        items: Option<u64>,
        f: &mut dyn FnMut(),
    ) -> &Sample {
        // Warmup + calibration: find an iteration count that takes ~10ms.
        let t0 = Instant::now();
        let mut calib_iters = 0u64;
        while t0.elapsed().as_secs_f64() < self.warmup_s {
            f();
            calib_iters += 1;
            if calib_iters > 1_000_000 {
                break;
            }
        }
        let per_iter = (t0.elapsed().as_secs_f64() / calib_iters as f64).max(1e-9);
        let batch = ((0.01 / per_iter).ceil() as u64).clamp(1, 1_000_000);

        // Measurement: batches until the budget is used, >= 5 batches.
        let mut times = Vec::new();
        let meas0 = Instant::now();
        while meas0.elapsed().as_secs_f64() < self.budget_s || times.len() < 5 {
            let t = Instant::now();
            for _ in 0..batch {
                f();
            }
            times.push(t.elapsed().as_secs_f64() / batch as f64);
            if times.len() >= 10_000 {
                break;
            }
        }
        times.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let mean = times.iter().sum::<f64>() / times.len() as f64;
        let p50 = times[times.len() / 2];
        let p95 = times[((times.len() as f64 * 0.95) as usize).min(times.len() - 1)];
        let sample = Sample {
            name: name.to_string(),
            iters: batch * times.len() as u64,
            mean_s: mean,
            p50_s: p50,
            p95_s: p95,
            min_s: times[0],
            throughput: items.map(|n| n as f64 / mean),
        };
        println!("{}", sample.report());
        self.samples.push(sample);
        self.samples.last().unwrap()
    }
}

/// Percentile over a slice (nearest-rank); input need not be sorted.
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    assert!(!xs.is_empty());
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let idx = ((p / 100.0) * (v.len() - 1) as f64).round() as usize;
    v[idx]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_runs_and_records() {
        let mut b = Bench {
            budget_s: 0.02,
            warmup_s: 0.005,
            samples: Vec::new(),
        };
        b.run("noop", || 1 + 1);
        assert_eq!(b.samples.len(), 1);
        assert!(b.samples[0].mean_s > 0.0);
    }

    #[test]
    fn throughput_reported() {
        let mut b = Bench {
            budget_s: 0.02,
            warmup_s: 0.005,
            samples: Vec::new(),
        };
        let s = b.throughput("vecsum", 1000, || (0..1000u64).sum::<u64>());
        assert!(s.throughput.unwrap() > 0.0);
    }

    #[test]
    fn percentile_nearest_rank() {
        let xs = [5.0, 1.0, 3.0, 2.0, 4.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 50.0), 3.0);
        assert_eq!(percentile(&xs, 100.0), 5.0);
    }
}
