//! Tiny CLI argument parser (clap is not in the offline registry).
//!
//! Supports `--flag`, `--key value`, `--key=value`, and positional args.
//! Subcommand dispatch lives in `main.rs`; this module only tokenizes.

use std::collections::BTreeMap;

use crate::{Error, Result};

/// Parsed command line: positionals plus `--key [value]` options.
#[derive(Debug, Default, Clone)]
pub struct Args {
    pub positional: Vec<String>,
    pub options: BTreeMap<String, String>,
    pub flags: Vec<String>,
}

impl Args {
    /// Parse from an iterator of raw arguments (excluding argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(args: I) -> Args {
        let mut out = Args::default();
        let mut iter = args.into_iter().peekable();
        while let Some(arg) = iter.next() {
            if let Some(stripped) = arg.strip_prefix("--") {
                if let Some((k, v)) = stripped.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else if iter
                    .peek()
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = iter.next().unwrap();
                    out.options.insert(stripped.to_string(), v);
                } else {
                    out.flags.push(stripped.to_string());
                }
            } else {
                out.positional.push(arg);
            }
        }
        out
    }

    pub fn from_env() -> Args {
        Self::parse(std::env::args().skip(1))
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    /// Typed option lookup with default. Malformed input is an error the
    /// caller reports (the former variant panicked from library code;
    /// `main.rs` now turns the `Err` into exit code 2 + usage).
    pub fn get_parsed<T: std::str::FromStr>(&self, name: &str, default: T) -> Result<T> {
        match self.get(name) {
            None => Ok(default),
            Some(s) => s.parse().map_err(|_| {
                Error::Config(format!("invalid value for --{name}: {s:?}"))
            }),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from))
    }

    #[test]
    fn positionals_and_options() {
        let a = parse("repro fig8 --models 8b,70b --verbose --seed 7");
        assert_eq!(a.positional, vec!["repro", "fig8"]);
        assert_eq!(a.get("models"), Some("8b,70b"));
        assert_eq!(a.get_parsed::<u64>("seed", 0).unwrap(), 7);
        assert!(a.flag("verbose"));
    }

    #[test]
    fn equals_form() {
        let a = parse("--out=/tmp/x.json --n=3");
        assert_eq!(a.get("out"), Some("/tmp/x.json"));
        assert_eq!(a.get_parsed::<usize>("n", 0).unwrap(), 3);
    }

    #[test]
    fn trailing_flag() {
        let a = parse("cmd --quick");
        assert!(a.flag("quick"));
        assert_eq!(a.get("quick"), None);
    }

    #[test]
    fn defaults() {
        let a = parse("cmd");
        assert_eq!(a.get_or("missing", "d"), "d");
        assert_eq!(a.get_parsed::<u32>("missing", 42).unwrap(), 42);
    }

    #[test]
    fn malformed_typed_option_errors() {
        let err = parse("--n notanumber")
            .get_parsed::<u32>("n", 0)
            .unwrap_err();
        assert!(err.to_string().contains("invalid value for --n"));
    }
}
