//! The **ExecutionPlan**: one typed, JSON-serializable artifact flowing
//! from planning to execution (paper §3–§4: "a dynamic orchestration
//! system that can place the granular components across a heterogeneous
//! compute infrastructure and stitch them together while meeting an
//! end-to-end SLA").
//!
//! Before this module the repo had three disconnected deployment
//! representations: the optimizer's `Assignment`, the simulator's
//! `Placement`, and a hand-configured server. An [`ExecutionPlan`] is
//! the single contract between them:
//!
//! ```text
//!   planner::Planner::plan(graph) ──► ExecutionPlan ──► util::json (save / diff / replay)
//!                                          │
//!                      ┌───────────────────┼──────────────────────┐
//!                      ▼                   ▼                      ▼
//!         cluster::sim::simulate_plan   ExecutionPlan::placement  ServerConfig::from_plan
//!         (full agent-DAG simulation)   (+ fabric, flat LLM sim)  (batcher + admission)
//! ```
//!
//! The plan carries: the **agent DAG** (every graph node bound to a
//! hardware class, with dependency edges and transfer-byte estimates),
//! the **pipeline fleet** (device, TP×PP, batch limit, chassis,
//! replicas per LLM stage), the **batching/admission policy**, and the
//! **SLA envelope** — everything needed to simulate or serve the plan
//! without consulting the planner again.

pub mod diag;
pub mod diff;
pub mod instance;
pub mod presets;
pub mod verify;

pub use diag::{Diag, DiagReport, Severity};
pub use diff::{BindingRebind, FractionShift, PipelineResize, PlanDiff, PolicyChange};
pub use instance::{edge_payload_bytes, DagTopology, LlmUnit};

use crate::cluster::sim::{Placement, PipelineSpec};
use crate::cost::hardware::by_name;
use crate::cost::roofline::Parallelism;
use crate::opt::assignment::Sla;
use crate::router::admission::AdmissionConfig;
use crate::router::batcher::BatcherConfig;
use crate::transport::fabric::Fabric;
use crate::util::json::Json;
use crate::{jobj, Error, Result};

/// Current serialization format version.
pub const PLAN_VERSION: u64 = 1;

/// Execution stage of a bound agent-graph node.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Stage {
    /// LLM prefill (or MoE expert prefill): runs on a prefill pipeline.
    LlmPrefill,
    /// LLM decode: runs on a decode pipeline with continuous batching.
    LlmDecode,
    /// Everything else — STT/TTS, tool calls, IO, control, memory ops —
    /// executed on the CPU worker pool at the planner-profiled latency.
    Cpu,
}

impl Stage {
    /// Classify an IR op name.
    pub fn of_op(op: &str) -> Stage {
        match op {
            "llm.prefill" | "moe.expert_prefill" => Stage::LlmPrefill,
            "llm.decode" | "moe.expert_decode" => Stage::LlmDecode,
            _ => Stage::Cpu,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Stage::LlmPrefill => "llm_prefill",
            Stage::LlmDecode => "llm_decode",
            Stage::Cpu => "cpu",
        }
    }

    fn from_name(s: &str) -> Result<Stage> {
        match s {
            "llm_prefill" => Ok(Stage::LlmPrefill),
            "llm_decode" => Ok(Stage::LlmDecode),
            "cpu" => Ok(Stage::Cpu),
            other => Err(Error::Config(format!("unknown stage `{other}`"))),
        }
    }
}

/// One agent-graph node bound to a hardware class.
#[derive(Debug, Clone, PartialEq)]
pub struct NodeBinding {
    /// IR op name ("llm.prefill", "stt.transcribe", ...).
    pub op: String,
    /// Chosen hardware class ("H100", "CPU", ...).
    pub class: String,
    pub stage: Stage,
    /// Planner-profiled latency on the chosen class, seconds. For LLM
    /// stages the simulator re-times with the roofline model; for CPU
    /// stages this is the simulated service time.
    pub latency_s: f64,
    /// Planner-estimated cost on the chosen class, $/request.
    pub cost_usd: f64,
    /// Dataflow dependencies: indices into `ExecutionPlan::bindings`.
    pub deps: Vec<usize>,
    /// Estimated bytes received over incoming edges (fabric transfers
    /// when producer and consumer sit on different chassis).
    pub xfer_bytes: f64,
    /// Fraction of the request's tokens this node processes (expert
    /// parallelism routes ~top_k/N of the stream to each expert; 1.0
    /// for whole-stream nodes). The DAG simulator scales the request's
    /// ISL/OSL by this per node.
    pub token_fraction: f64,
    /// Planner-expected fraction of this node's prompt already resident
    /// in a prefix cache when it dispatches (0.0 = no reuse, the
    /// default; fan-out siblings sharing their gating parents' context
    /// approach 1.0). The cost model discounts the prefill term by the
    /// expected hit; absent in pre-reuse plan JSON.
    pub prefix_overlap: f64,
}

/// Role of a serving pipeline.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Role {
    Prefill,
    Decode,
}

impl Role {
    pub fn name(&self) -> &'static str {
        match self {
            Role::Prefill => "prefill",
            Role::Decode => "decode",
        }
    }

    fn from_name(s: &str) -> Result<Role> {
        match s {
            "prefill" => Ok(Role::Prefill),
            "decode" => Ok(Role::Decode),
            other => Err(Error::Config(format!("unknown role `{other}`"))),
        }
    }
}

/// The one true spelling of a pipeline group's shape key. Every
/// group-granular surface — [`PipelineBinding::shape_key`], the DAG
/// simulator's per-group stats/counters, [`diff::PlanDiff`]'s
/// cross-group detection, rebalance lookups, the live server's
/// `server_group_jobs:*` metrics — formats through this function, so
/// the keys can never drift apart byte-wise.
pub fn shape_key_of(role: Role, device: &str, tp: u32, pp: u32, max_batch: u64) -> String {
    format!("{} {device} tp{tp} pp{pp} b{max_batch}", role.name())
}

/// A serving pipeline group: `replicas` copies of a (device, TP×PP,
/// batch limit) unit, occupying consecutive chassis starting at
/// `chassis`.
#[derive(Debug, Clone, PartialEq)]
pub struct PipelineBinding {
    pub role: Role,
    /// Device name, resolvable via [`crate::cost::hardware::by_name`].
    pub device: String,
    pub tp: u32,
    pub pp: u32,
    pub max_batch: u64,
    pub replicas: u32,
    pub chassis: u32,
}

impl PipelineBinding {
    pub fn par(&self) -> Parallelism {
        Parallelism {
            tp: self.tp,
            pp: self.pp,
        }
    }

    /// Canonical shape identity of this group — the string every
    /// group-granular surface keys on (plan diffs, per-group window
    /// stats, per-group job counters, rebalance decisions), so the
    /// orchestrator, both execution backends, and the conformance suite
    /// all name the same group the same way.
    pub fn shape_key(&self) -> String {
        shape_key_of(self.role, &self.device, self.tp, self.pp, self.max_batch)
    }

    /// Serialize one pipeline group (shared by the plan writer and
    /// [`diff::PlanDiff`]).
    pub fn to_json(&self) -> Json {
        jobj! {
            "role" => self.role.name(),
            "device" => self.device.clone(),
            "tp" => self.tp,
            "pp" => self.pp,
            "max_batch" => self.max_batch,
            "replicas" => self.replicas,
            "chassis" => self.chassis,
        }
    }

    /// Inverse of [`PipelineBinding::to_json`].
    pub fn from_json(p: &Json) -> Result<PipelineBinding> {
        Ok(PipelineBinding {
            role: Role::from_name(req_str(p, "role")?)?,
            device: req_str(p, "device")?.to_string(),
            tp: req_u64(p, "tp")? as u32,
            pp: req_u64(p, "pp")? as u32,
            max_batch: req_u64(p, "max_batch")?,
            replicas: req_u64(p, "replicas")? as u32,
            chassis: req_u64(p, "chassis")? as u32,
        })
    }
}

/// Continuous-batching policy for the serving loop.
#[derive(Debug, Clone, PartialEq)]
pub struct BatchPolicy {
    /// Compiled batch buckets, ascending.
    pub buckets: Vec<usize>,
    /// Head-of-line wait before a partial batch is released, ms.
    pub max_wait_ms: f64,
    /// Decode round active-set cap.
    pub max_decode_batch: usize,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        BatchPolicy {
            buckets: vec![1, 2, 4],
            max_wait_ms: 10.0,
            max_decode_batch: 4,
        }
    }
}

/// Admission policy (token bucket + queue-depth shedding).
#[derive(Debug, Clone, PartialEq)]
pub struct AdmissionPolicy {
    pub rate: f64,
    pub burst: f64,
    pub max_queue_depth: usize,
}

impl Default for AdmissionPolicy {
    fn default() -> Self {
        AdmissionPolicy {
            rate: 1000.0,
            burst: 100.0,
            max_queue_depth: 4096,
        }
    }
}

/// Fabric sizing carried with the plan so simulation is reproducible.
#[derive(Debug, Clone, PartialEq)]
pub struct FabricSpec {
    pub slots_per_chassis: u32,
    /// Scale-out NIC bandwidth per chassis, Gbit/s.
    pub scaleout_gbit: f64,
}

impl Default for FabricSpec {
    fn default() -> Self {
        FabricSpec {
            slots_per_chassis: 8,
            scaleout_gbit: 400.0,
        }
    }
}

/// Serializable mirror of [`crate::opt::assignment::Sla`].
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum SlaSpec {
    None,
    EndToEnd(f64),
    Soft { t_sla_s: f64, lambda: f64 },
}

impl From<Sla> for SlaSpec {
    fn from(s: Sla) -> SlaSpec {
        match s {
            Sla::None => SlaSpec::None,
            Sla::EndToEnd(t) => SlaSpec::EndToEnd(t),
            Sla::Soft { t_sla_s, lambda } => SlaSpec::Soft { t_sla_s, lambda },
        }
    }
}

impl From<SlaSpec> for Sla {
    fn from(s: SlaSpec) -> Sla {
        match s {
            SlaSpec::None => Sla::None,
            SlaSpec::EndToEnd(t) => Sla::EndToEnd(t),
            SlaSpec::Soft { t_sla_s, lambda } => Sla::Soft { t_sla_s, lambda },
        }
    }
}

/// The unified planning → execution artifact. See module docs.
#[derive(Debug, Clone, PartialEq)]
pub struct ExecutionPlan {
    /// Agent graph symbol name.
    pub agent: String,
    /// Model short name ("8b-fp16"); empty when the graph has no LLM.
    pub model: String,
    pub sla: SlaSpec,
    /// The bound agent DAG, in graph node order (a topological order —
    /// IR regions are SSA).
    pub bindings: Vec<NodeBinding>,
    /// The LLM serving fleet.
    pub pipelines: Vec<PipelineBinding>,
    pub batching: BatchPolicy,
    pub admission: AdmissionPolicy,
    pub fabric: FabricSpec,
    /// CPU worker slots for non-LLM stages (tool calls, STT/TTS, ...).
    pub cpu_workers: u32,
    /// Planner objective value, $/request.
    pub cost_usd: f64,
    /// Planner critical-path latency estimate, seconds.
    pub latency_s: f64,
    /// Lowering pass log: (pass name, changed).
    pub pass_log: Vec<(String, bool)>,
}

impl ExecutionPlan {
    /// Which class a given op landed on (first occurrence).
    pub fn class_of(&self, op: &str) -> Option<&str> {
        self.bindings
            .iter()
            .find(|b| b.op == op)
            .map(|b| b.class.as_str())
    }

    /// (op, class) pairs in DAG order — the shape the old `GraphPlan`
    /// exposed, kept for display code.
    pub fn placements(&self) -> impl Iterator<Item = (&str, &str)> {
        self.bindings
            .iter()
            .map(|b| (b.op.as_str(), b.class.as_str()))
    }

    /// Number of chassis the pipeline fleet occupies (≥ 1).
    pub fn n_chassis(&self) -> u32 {
        self.pipelines
            .iter()
            .map(|p| p.chassis + p.replicas)
            .max()
            .unwrap_or(0)
            .max(1)
    }

    /// Structural validation: DAG sanity, resolvable devices, pipelines
    /// for every LLM stage, sane policies. Run by every consumer.
    pub fn validate(&self) -> Result<()> {
        let n = self.bindings.len();
        for (i, b) in self.bindings.iter().enumerate() {
            for &d in &b.deps {
                if d >= n {
                    return Err(Error::Config(format!(
                        "binding {i} ({}) dep {d} out of range",
                        b.op
                    )));
                }
                if d >= i {
                    return Err(Error::Config(format!(
                        "binding {i} ({}) dep {d} not topologically earlier",
                        b.op
                    )));
                }
            }
            if !b.latency_s.is_finite() || b.latency_s < 0.0 {
                return Err(Error::Config(format!(
                    "binding {i} ({}) has bad latency {}",
                    b.op, b.latency_s
                )));
            }
            if !b.token_fraction.is_finite()
                || b.token_fraction <= 0.0
                || b.token_fraction > 1.0
            {
                return Err(Error::Config(format!(
                    "binding {i} ({}) has bad token_fraction {}",
                    b.op, b.token_fraction
                )));
            }
            if !b.prefix_overlap.is_finite()
                || b.prefix_overlap < 0.0
                || b.prefix_overlap > 1.0
            {
                return Err(Error::Config(format!(
                    "binding {i} ({}) has bad prefix_overlap {}",
                    b.op, b.prefix_overlap
                )));
            }
            if matches!(b.stage, Stage::LlmPrefill | Stage::LlmDecode) {
                let role = if b.stage == Stage::LlmPrefill {
                    Role::Prefill
                } else {
                    Role::Decode
                };
                if !self
                    .pipelines
                    .iter()
                    .any(|p| p.role == role && p.device == b.class)
                {
                    return Err(Error::Config(format!(
                        "binding {i} ({}) on {} has no {} pipeline",
                        b.op,
                        b.class,
                        role.name()
                    )));
                }
            }
        }
        for p in &self.pipelines {
            if by_name(&p.device).is_none() {
                return Err(Error::Config(format!(
                    "pipeline device `{}` not in the hardware catalog",
                    p.device
                )));
            }
            if p.replicas == 0 || p.tp == 0 || p.pp == 0 || p.max_batch == 0 {
                return Err(Error::Config(format!(
                    "pipeline on `{}` has a zero-sized dimension",
                    p.device
                )));
            }
        }
        if self.batching.buckets.is_empty() {
            return Err(Error::Config("batching needs ≥ 1 bucket".into()));
        }
        if self.cpu_workers == 0 {
            return Err(Error::Config("cpu_workers must be ≥ 1".into()));
        }
        Ok(())
    }

    /// Lower the pipeline fleet to the simulator's [`Placement`]
    /// (replicas expanded, chassis resolved, devices looked up).
    pub fn placement(&self) -> Result<Placement> {
        let mut prefill = Vec::new();
        let mut decode = Vec::new();
        for p in &self.pipelines {
            let dev = by_name(&p.device).ok_or_else(|| {
                Error::Config(format!("unknown device `{}`", p.device))
            })?;
            for r in 0..p.replicas {
                let spec = PipelineSpec {
                    device: dev.clone(),
                    par: p.par(),
                    max_batch: p.max_batch,
                    chassis: p.chassis + r,
                };
                match p.role {
                    Role::Prefill => prefill.push(spec),
                    Role::Decode => decode.push(spec),
                }
            }
        }
        Ok(Placement { prefill, decode })
    }

    /// Build the fabric this plan assumes: one chassis per pipeline
    /// replica, scale-up bandwidth of the fastest device in the fleet.
    pub fn build_fabric(&self) -> Result<Fabric> {
        let mut scaleup = 0.0f64;
        for p in &self.pipelines {
            let dev = by_name(&p.device).ok_or_else(|| {
                Error::Config(format!("unknown device `{}`", p.device))
            })?;
            scaleup = scaleup.max(dev.scaleup_bw_gbps);
        }
        if scaleup == 0.0 {
            scaleup = 900.0; // CPU-only plan: nominal NVLink-class default
        }
        Ok(Fabric::new(
            self.n_chassis(),
            self.fabric.slots_per_chassis,
            scaleup,
            self.fabric.scaleout_gbit,
        ))
    }

    /// Router-facing batcher configuration.
    pub fn batcher_config(&self) -> BatcherConfig {
        BatcherConfig {
            buckets: self.batching.buckets.clone(),
            max_wait: std::time::Duration::from_secs_f64(
                self.batching.max_wait_ms / 1e3,
            ),
            max_decode_batch: self.batching.max_decode_batch,
        }
    }

    /// Router-facing admission configuration.
    pub fn admission_config(&self) -> AdmissionConfig {
        AdmissionConfig {
            rate: self.admission.rate,
            burst: self.admission.burst,
            max_queue_depth: self.admission.max_queue_depth,
        }
    }

    /// One-line human summary.
    pub fn summary(&self) -> String {
        let n_llm = self
            .bindings
            .iter()
            .filter(|b| b.stage != Stage::Cpu)
            .count();
        format!(
            "plan @{}: {} nodes ({} llm), {} pipeline groups on {} chassis, \
             est ${:.6}/req @ {:.0} ms",
            self.agent,
            self.bindings.len(),
            n_llm,
            self.pipelines.len(),
            self.n_chassis(),
            self.cost_usd,
            self.latency_s * 1e3
        )
    }

    // ---- JSON round-trip -------------------------------------------------

    /// Serialize to the JSON tree (deterministic key order; safe to
    /// diff). Inverse of [`ExecutionPlan::from_json`].
    pub fn to_json(&self) -> Json {
        let sla = match self.sla {
            SlaSpec::None => jobj! { "kind" => "none" },
            SlaSpec::EndToEnd(t) => jobj! { "kind" => "end_to_end", "t_sla_s" => t },
            SlaSpec::Soft { t_sla_s, lambda } => jobj! {
                "kind" => "soft", "t_sla_s" => t_sla_s, "lambda" => lambda,
            },
        };
        let bindings: Vec<Json> = self
            .bindings
            .iter()
            .map(|b| {
                jobj! {
                    "op" => b.op.clone(),
                    "class" => b.class.clone(),
                    "stage" => b.stage.name(),
                    "latency_s" => b.latency_s,
                    "cost_usd" => b.cost_usd,
                    "deps" => b.deps.clone(),
                    "xfer_bytes" => b.xfer_bytes,
                    "token_fraction" => b.token_fraction,
                    "prefix_overlap" => b.prefix_overlap,
                }
            })
            .collect();
        let pipelines: Vec<Json> =
            self.pipelines.iter().map(|p| p.to_json()).collect();
        let pass_log: Vec<Json> = self
            .pass_log
            .iter()
            .map(|(name, changed)| jobj! { "pass" => name.clone(), "changed" => *changed })
            .collect();
        jobj! {
            "version" => PLAN_VERSION,
            "agent" => self.agent.clone(),
            "model" => self.model.clone(),
            "sla" => sla,
            "bindings" => Json::Arr(bindings),
            "pipelines" => Json::Arr(pipelines),
            "batching" => jobj! {
                "buckets" => self.batching.buckets.clone(),
                "max_wait_ms" => self.batching.max_wait_ms,
                "max_decode_batch" => self.batching.max_decode_batch,
            },
            "admission" => jobj! {
                "rate" => self.admission.rate,
                "burst" => self.admission.burst,
                "max_queue_depth" => self.admission.max_queue_depth,
            },
            "fabric" => jobj! {
                "slots_per_chassis" => self.fabric.slots_per_chassis,
                "scaleout_gbit" => self.fabric.scaleout_gbit,
            },
            "cpu_workers" => self.cpu_workers,
            "cost_usd" => self.cost_usd,
            "latency_s" => self.latency_s,
            "pass_log" => Json::Arr(pass_log),
        }
    }

    /// Serialize to a pretty-printed JSON string.
    pub fn to_json_string(&self) -> String {
        self.to_json().pretty()
    }

    /// Parse a plan from a JSON string (see [`ExecutionPlan::to_json`]).
    pub fn parse_json(src: &str) -> Result<ExecutionPlan> {
        Self::from_json(&Json::parse(src)?)
    }

    /// Parse a plan *without* structural validation — the entry point
    /// for `plan lint`, which must be able to load a broken plan so the
    /// analyzer ([`verify::verify`]) can diagnose it instead of the
    /// parser rejecting it with the first error only.
    pub fn parse_json_lenient(src: &str) -> Result<ExecutionPlan> {
        Self::from_json_unchecked(&Json::parse(src)?)
    }

    /// Rebuild a plan from its JSON tree; validates structure.
    pub fn from_json(j: &Json) -> Result<ExecutionPlan> {
        let plan = Self::from_json_unchecked(j)?;
        plan.validate()?;
        Ok(plan)
    }

    /// [`ExecutionPlan::from_json`] minus the [`ExecutionPlan::validate`]
    /// gate (shape errors in the JSON itself still fail).
    pub fn from_json_unchecked(j: &Json) -> Result<ExecutionPlan> {
        let version = req_u64(j, "version")?;
        if version != PLAN_VERSION {
            return Err(Error::Config(format!(
                "plan version {version} unsupported (expected {PLAN_VERSION})"
            )));
        }
        let sla_j = req(j, "sla")?;
        let sla = match req_str(sla_j, "kind")? {
            "none" => SlaSpec::None,
            "end_to_end" => SlaSpec::EndToEnd(req_f64(sla_j, "t_sla_s")?),
            "soft" => SlaSpec::Soft {
                t_sla_s: req_f64(sla_j, "t_sla_s")?,
                lambda: req_f64(sla_j, "lambda")?,
            },
            other => {
                return Err(Error::Config(format!("unknown sla kind `{other}`")))
            }
        };
        let mut bindings = Vec::new();
        for b in req_arr(j, "bindings")? {
            let deps = req_arr(b, "deps")?
                .iter()
                .map(|d| {
                    d.as_u64().map(|v| v as usize).ok_or_else(|| {
                        Error::Config("binding dep must be an integer".into())
                    })
                })
                .collect::<Result<Vec<usize>>>()?;
            bindings.push(NodeBinding {
                op: req_str(b, "op")?.to_string(),
                class: req_str(b, "class")?.to_string(),
                stage: Stage::from_name(req_str(b, "stage")?)?,
                latency_s: req_f64(b, "latency_s")?,
                cost_usd: req_f64(b, "cost_usd")?,
                deps,
                xfer_bytes: req_f64(b, "xfer_bytes")?,
                // Optional for plans written before expert-aware
                // simulation: absent means the whole stream.
                token_fraction: b
                    .get("token_fraction")
                    .and_then(|v| v.as_f64())
                    .unwrap_or(1.0),
                // Optional for plans written before prefix-KV reuse:
                // absent means no expected reuse.
                prefix_overlap: b
                    .get("prefix_overlap")
                    .and_then(|v| v.as_f64())
                    .unwrap_or(0.0),
            });
        }
        let mut pipelines = Vec::new();
        for p in req_arr(j, "pipelines")? {
            pipelines.push(PipelineBinding::from_json(p)?);
        }
        let batching_j = req(j, "batching")?;
        let batching = BatchPolicy {
            buckets: req_arr(batching_j, "buckets")?
                .iter()
                .map(|b| {
                    b.as_u64().map(|v| v as usize).ok_or_else(|| {
                        Error::Config("bucket must be an integer".into())
                    })
                })
                .collect::<Result<Vec<usize>>>()?,
            max_wait_ms: req_f64(batching_j, "max_wait_ms")?,
            max_decode_batch: req_u64(batching_j, "max_decode_batch")? as usize,
        };
        let admission_j = req(j, "admission")?;
        let admission = AdmissionPolicy {
            rate: req_f64(admission_j, "rate")?,
            burst: req_f64(admission_j, "burst")?,
            max_queue_depth: req_u64(admission_j, "max_queue_depth")? as usize,
        };
        let fabric_j = req(j, "fabric")?;
        let fabric = FabricSpec {
            slots_per_chassis: req_u64(fabric_j, "slots_per_chassis")? as u32,
            scaleout_gbit: req_f64(fabric_j, "scaleout_gbit")?,
        };
        let mut pass_log = Vec::new();
        for e in req_arr(j, "pass_log")? {
            pass_log.push((
                req_str(e, "pass")?.to_string(),
                req(e, "changed")?.as_bool().ok_or_else(|| {
                    Error::Config("pass_log.changed must be a bool".into())
                })?,
            ));
        }
        let plan = ExecutionPlan {
            agent: req_str(j, "agent")?.to_string(),
            model: req_str(j, "model")?.to_string(),
            sla,
            bindings,
            pipelines,
            batching,
            admission,
            fabric,
            cpu_workers: req_u64(j, "cpu_workers")? as u32,
            cost_usd: req_f64(j, "cost_usd")?,
            latency_s: req_f64(j, "latency_s")?,
            pass_log,
        };
        Ok(plan)
    }
}

// ---- JSON field helpers --------------------------------------------------

fn req<'a>(j: &'a Json, key: &str) -> Result<&'a Json> {
    j.get(key)
        .ok_or_else(|| Error::Config(format!("plan json missing `{key}`")))
}

fn req_str<'a>(j: &'a Json, key: &str) -> Result<&'a str> {
    req(j, key)?
        .as_str()
        .ok_or_else(|| Error::Config(format!("plan json `{key}` must be a string")))
}

fn req_f64(j: &Json, key: &str) -> Result<f64> {
    req(j, key)?
        .as_f64()
        .ok_or_else(|| Error::Config(format!("plan json `{key}` must be a number")))
}

fn req_u64(j: &Json, key: &str) -> Result<u64> {
    req(j, key)?.as_u64().ok_or_else(|| {
        Error::Config(format!("plan json `{key}` must be a non-negative integer"))
    })
}

fn req_arr<'a>(j: &'a Json, key: &str) -> Result<&'a [Json]> {
    req(j, key)?
        .as_arr()
        .ok_or_else(|| Error::Config(format!("plan json `{key}` must be an array")))
}

#[cfg(test)]
pub(crate) mod tests {
    use super::*;

    /// A small hand-built plan: cpu → prefill → decode → cpu. Shared
    /// with the DAG-simulator unit tests.
    pub(crate) fn tiny_plan() -> ExecutionPlan {
        ExecutionPlan {
            agent: "tiny".into(),
            model: "8b-fp16".into(),
            sla: SlaSpec::EndToEnd(3.0),
            bindings: vec![
                NodeBinding {
                    op: "io.input".into(),
                    class: "CPU".into(),
                    stage: Stage::Cpu,
                    latency_s: 0.0005,
                    cost_usd: 0.0,
                    deps: vec![],
                    xfer_bytes: 0.0,
                    token_fraction: 1.0,
                    prefix_overlap: 0.0,
                },
                NodeBinding {
                    op: "llm.prefill".into(),
                    class: "H100".into(),
                    stage: Stage::LlmPrefill,
                    latency_s: 0.05,
                    cost_usd: 1e-5,
                    deps: vec![0],
                    xfer_bytes: 1e6,
                    token_fraction: 1.0,
                    prefix_overlap: 0.0,
                },
                NodeBinding {
                    op: "llm.decode".into(),
                    class: "Gaudi3".into(),
                    stage: Stage::LlmDecode,
                    latency_s: 0.5,
                    cost_usd: 2e-5,
                    deps: vec![1],
                    xfer_bytes: 1e8,
                    token_fraction: 1.0,
                    prefix_overlap: 0.0,
                },
                NodeBinding {
                    op: "io.output".into(),
                    class: "CPU".into(),
                    stage: Stage::Cpu,
                    latency_s: 0.0005,
                    cost_usd: 0.0,
                    deps: vec![2],
                    xfer_bytes: 0.0,
                    token_fraction: 1.0,
                    prefix_overlap: 0.0,
                },
            ],
            pipelines: vec![
                PipelineBinding {
                    role: Role::Prefill,
                    device: "H100".into(),
                    tp: 1,
                    pp: 1,
                    max_batch: 8,
                    replicas: 1,
                    chassis: 0,
                },
                PipelineBinding {
                    role: Role::Decode,
                    device: "Gaudi3".into(),
                    tp: 1,
                    pp: 1,
                    max_batch: 32,
                    replicas: 2,
                    chassis: 1,
                },
            ],
            batching: BatchPolicy::default(),
            admission: AdmissionPolicy::default(),
            fabric: FabricSpec::default(),
            cpu_workers: 64,
            cost_usd: 3.1e-5,
            latency_s: 0.551,
            pass_log: vec![("decompose-llm".into(), true)],
        }
    }

    #[test]
    fn json_round_trip_is_identity() {
        let plan = tiny_plan();
        let text = plan.to_json_string();
        let back = ExecutionPlan::parse_json(&text).unwrap();
        assert_eq!(back, plan);
        // And byte-stable: serializing again yields the same document.
        assert_eq!(back.to_json_string(), text);
    }

    #[test]
    fn validate_catches_bad_deps_and_missing_pipelines() {
        let mut p = tiny_plan();
        p.bindings[1].deps = vec![9];
        assert!(p.validate().is_err());

        let mut p = tiny_plan();
        p.bindings[1].deps = vec![1]; // self/forward dep
        assert!(p.validate().is_err());

        let mut p = tiny_plan();
        p.pipelines.retain(|pl| pl.role != Role::Decode);
        assert!(p.validate().is_err(), "decode binding without pipeline");

        let mut p = tiny_plan();
        p.pipelines[0].device = "TPUv9".into();
        assert!(p.validate().is_err(), "unknown device");

        let mut p = tiny_plan();
        p.bindings[2].token_fraction = 0.0;
        assert!(p.validate().is_err(), "zero token fraction");
        p.bindings[2].token_fraction = 1.5;
        assert!(p.validate().is_err(), "token fraction above 1");

        let mut p = tiny_plan();
        p.bindings[2].prefix_overlap = -0.1;
        assert!(p.validate().is_err(), "negative prefix overlap");
        p.bindings[2].prefix_overlap = 1.5;
        assert!(p.validate().is_err(), "prefix overlap above 1");
        p.bindings[2].prefix_overlap = f64::NAN;
        assert!(p.validate().is_err(), "non-finite prefix overlap");
        p.bindings[2].prefix_overlap = 1.0; // full overlap is legal
        assert!(p.validate().is_ok(), "prefix_overlap = 1.0 must pass");
    }

    #[test]
    fn placement_expands_replicas_and_chassis() {
        let plan = tiny_plan();
        let placement = plan.placement().unwrap();
        assert_eq!(placement.prefill.len(), 1);
        assert_eq!(placement.decode.len(), 2);
        assert_eq!(placement.decode[0].chassis, 1);
        assert_eq!(placement.decode[1].chassis, 2);
        assert_eq!(plan.n_chassis(), 3);
        let fabric = plan.build_fabric().unwrap();
        assert_eq!(fabric.n_chassis, 3);
    }

    #[test]
    fn router_configs_derive_from_policies() {
        let plan = tiny_plan();
        let b = plan.batcher_config();
        assert_eq!(b.buckets, vec![1, 2, 4]);
        assert!((b.max_wait.as_secs_f64() - 0.010).abs() < 1e-9);
        let a = plan.admission_config();
        assert_eq!(a.max_queue_depth, 4096);
        assert_eq!(a.rate, 1000.0);
    }

    #[test]
    fn parse_rejects_wrong_version_and_missing_fields() {
        let plan = tiny_plan();
        let mut j = plan.to_json();
        j.try_set("version", 99u64).unwrap();
        assert!(ExecutionPlan::from_json(&j).is_err());
        assert!(ExecutionPlan::parse_json("{}").is_err());
        assert!(ExecutionPlan::parse_json("not json").is_err());
    }

    #[test]
    fn stage_classification() {
        assert_eq!(Stage::of_op("llm.prefill"), Stage::LlmPrefill);
        assert_eq!(Stage::of_op("moe.expert_decode"), Stage::LlmDecode);
        assert_eq!(Stage::of_op("tool.lookup"), Stage::Cpu);
        assert_eq!(Stage::of_op("stt.transcribe"), Stage::Cpu);
    }
}
