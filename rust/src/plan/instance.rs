//! Per-request DAG instantiation helpers shared by the simulator
//! ([`crate::cluster::dag::DagSim`]) and the live server's DAG executor
//! ([`crate::server::dag_exec`]): both walk the same bound agent graph,
//! so the successor/indegree structure and the "which LLM bindings form
//! one engine inference" rule live here, next to the plan itself.

use super::{ExecutionPlan, NodeBinding, Stage};
use crate::cost::kv::kv_cache_bytes;
use crate::cost::model_profile::ModelProfile;

/// Successor lists and indegrees of a plan's binding DAG. Bindings are
/// already validated topological (deps point strictly earlier).
#[derive(Debug, Clone)]
pub struct DagTopology {
    /// Successor node indices per node.
    pub succ: Vec<Vec<usize>>,
    /// Static indegree per node.
    pub indeg: Vec<u32>,
}

impl DagTopology {
    pub fn of(plan: &ExecutionPlan) -> DagTopology {
        let n = plan.bindings.len();
        let mut succ = vec![Vec::new(); n];
        let mut indeg = vec![0u32; n];
        for (i, b) in plan.bindings.iter().enumerate() {
            for &d in &b.deps {
                succ[d].push(i);
                indeg[i] += 1;
            }
        }
        DagTopology { succ, indeg }
    }

    pub fn len(&self) -> usize {
        self.indeg.len()
    }

    pub fn is_empty(&self) -> bool {
        self.indeg.is_empty()
    }

    /// Nodes with no dependencies — dispatched on request arrival.
    pub fn roots(&self) -> Vec<usize> {
        (0..self.indeg.len())
            .filter(|&i| self.indeg[i] == 0)
            .collect()
    }
}

/// One live-engine inference unit: a prefill binding fused with the
/// decode binding that consumes it (when that decode depends *only* on
/// the prefill), or a lone LLM binding. The engine executes prefill and
/// decode back-to-back per batch, so the live executor schedules at
/// unit granularity while per-role accounting stays per binding.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct LlmUnit {
    /// Prefill binding index, if the unit has one.
    pub prefill: Option<usize>,
    /// Decode binding index, if the unit has one.
    pub decode: Option<usize>,
    /// Incoming dependency **edges** from outside the unit (binding
    /// indices, sorted, with multiplicity): each edge delivers one
    /// readiness signal, so `ext_deps.len()` is the unit's readiness
    /// counter — the count both execution backends decrement.
    pub ext_deps: Vec<usize>,
}

impl LlmUnit {
    /// Binding indices belonging to this unit, in execution order.
    pub fn members(&self) -> impl Iterator<Item = usize> + '_ {
        self.prefill.into_iter().chain(self.decode.into_iter())
    }
}

/// Payload bytes an edge into `to` carries when producer and consumer
/// sit on different chassis — the **shared** sizing rule of both
/// execution backends: a prefill → decode edge hands over the KV cache
/// (Eq. 3, sized at the consumer's token-fraction-scaled prompt
/// `isl_tokens`); every other edge carries the plan's per-binding
/// estimate. Kept here, next to the unit grouping, so the simulator and
/// the live dispatcher cannot drift apart on what a hop costs.
pub fn edge_payload_bytes(
    model: Option<&ModelProfile>,
    from_stage: Stage,
    to: &NodeBinding,
    isl_tokens: u64,
) -> f64 {
    if from_stage == Stage::LlmPrefill && to.stage == Stage::LlmDecode {
        if let Some(m) = model {
            return kv_cache_bytes(m, isl_tokens, 1);
        }
    }
    to.xfer_bytes
}

/// Group a plan's LLM bindings into engine inference units. Returns the
/// units plus a node-index → unit-index map (None for CPU bindings).
pub fn llm_units(plan: &ExecutionPlan) -> (Vec<LlmUnit>, Vec<Option<usize>>) {
    let n = plan.bindings.len();
    let mut units: Vec<LlmUnit> = Vec::new();
    let mut unit_of: Vec<Option<usize>> = vec![None; n];

    // Pass 1: every prefill binding opens a unit.
    for (i, b) in plan.bindings.iter().enumerate() {
        if b.stage == Stage::LlmPrefill {
            unit_of[i] = Some(units.len());
            units.push(LlmUnit {
                prefill: Some(i),
                decode: None,
                ext_deps: Vec::new(),
            });
        }
    }
    // Pass 2: fuse each decode whose sole dependency is an unclaimed
    // prefill; everything else becomes its own unit.
    for (i, b) in plan.bindings.iter().enumerate() {
        if b.stage != Stage::LlmDecode {
            continue;
        }
        let fused = match b.deps.as_slice() {
            [p] if plan.bindings[*p].stage == Stage::LlmPrefill => {
                let u = unit_of[*p].expect("prefill bindings were assigned units");
                if units[u].decode.is_none() {
                    units[u].decode = Some(i);
                    unit_of[i] = Some(u);
                    true
                } else {
                    false
                }
            }
            _ => false,
        };
        if !fused {
            unit_of[i] = Some(units.len());
            units.push(LlmUnit {
                prefill: None,
                decode: Some(i),
                ext_deps: Vec::new(),
            });
        }
    }
    // Pass 3: external dependency edges — member deps outside the
    // unit, kept with multiplicity (see `LlmUnit::ext_deps`).
    for (u, unit) in units.iter_mut().enumerate() {
        let mut ext: Vec<usize> = Vec::new();
        for m in unit.prefill.into_iter().chain(unit.decode.into_iter()) {
            for &d in &plan.bindings[m].deps {
                if unit_of[d] != Some(u) {
                    ext.push(d);
                }
            }
        }
        ext.sort_unstable();
        unit.ext_deps = ext;
    }
    (units, unit_of)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::plan::tests::tiny_plan;
    use crate::plan::NodeBinding;

    #[test]
    fn topology_matches_tiny_plan() {
        let plan = tiny_plan(); // cpu → prefill → decode → cpu
        let t = DagTopology::of(&plan);
        assert_eq!(t.len(), 4);
        assert_eq!(t.roots(), vec![0]);
        assert_eq!(t.succ[0], vec![1]);
        assert_eq!(t.succ[2], vec![3]);
        assert_eq!(t.indeg, vec![0, 1, 1, 1]);
    }

    #[test]
    fn prefill_decode_pair_fuses_into_one_unit() {
        let plan = tiny_plan();
        let (units, unit_of) = llm_units(&plan);
        assert_eq!(units.len(), 1);
        assert_eq!(units[0].prefill, Some(1));
        assert_eq!(units[0].decode, Some(2));
        // The unit's only external dependency is the cpu input node.
        assert_eq!(units[0].ext_deps, vec![0]);
        assert_eq!(unit_of, vec![None, Some(0), Some(0), None]);
        assert_eq!(units[0].members().collect::<Vec<_>>(), vec![1, 2]);
    }

    #[test]
    fn second_decode_on_same_prefill_becomes_own_unit() {
        let mut plan = tiny_plan();
        // A second decode consuming the same prefill (node 1).
        plan.bindings.push(NodeBinding {
            op: "llm.decode".into(),
            class: "Gaudi3".into(),
            stage: crate::plan::Stage::LlmDecode,
            latency_s: 0.4,
            cost_usd: 1e-5,
            deps: vec![1],
            xfer_bytes: 1e6,
            token_fraction: 1.0,
            prefix_overlap: 0.0,
        });
        plan.validate().unwrap();
        let (units, unit_of) = llm_units(&plan);
        assert_eq!(units.len(), 2);
        assert_eq!(units[1].prefill, None);
        assert_eq!(units[1].decode, Some(4));
        assert_eq!(units[1].ext_deps, vec![1]);
        assert_eq!(unit_of[4], Some(1));
    }

    #[test]
    fn ext_deps_keep_edge_multiplicity() {
        let mut plan = tiny_plan();
        // A decode-only unit consuming the same upstream node twice:
        // two edges → two readiness signals → count must be 2.
        plan.bindings.push(NodeBinding {
            op: "llm.decode".into(),
            class: "Gaudi3".into(),
            stage: Stage::LlmDecode,
            latency_s: 0.1,
            cost_usd: 0.0,
            deps: vec![0, 0],
            xfer_bytes: 0.0,
            token_fraction: 1.0,
            prefix_overlap: 0.0,
        });
        plan.validate().unwrap();
        let (units, _) = llm_units(&plan);
        assert_eq!(units.len(), 2);
        assert_eq!(units[1].ext_deps, vec![0, 0], "edges, not distinct deps");
    }

    #[test]
    fn edge_payload_sizing_rule() {
        use crate::cost::model_profile::llama3_8b;
        use crate::cost::Precision;

        let plan = tiny_plan();
        let m = llama3_8b(Precision::Fp16);
        // prefill → decode carries KV, sized at the consumer's tokens.
        let kv = edge_payload_bytes(Some(&m), Stage::LlmPrefill, &plan.bindings[2], 64);
        assert!((kv - kv_cache_bytes(&m, 64, 1)).abs() < 1e-6);
        // Without a model profile the plan's estimate stands in.
        let est = edge_payload_bytes(None, Stage::LlmPrefill, &plan.bindings[2], 64);
        assert_eq!(est, plan.bindings[2].xfer_bytes);
        // Any other edge kind carries the plan's estimate.
        let other = edge_payload_bytes(Some(&m), Stage::Cpu, &plan.bindings[1], 64);
        assert_eq!(other, plan.bindings[1].xfer_bytes);
    }

    #[test]
    fn cpu_only_plan_has_no_units() {
        let mut plan = tiny_plan();
        plan.bindings.truncate(1); // keep only the cpu input
        plan.pipelines.clear();
        let (units, unit_of) = llm_units(&plan);
        assert!(units.is_empty());
        assert_eq!(unit_of, vec![None]);
    }
}
