//! Ready-made [`ExecutionPlan`]s for the heterogeneity demos and the
//! cross-backend conformance suite.
//!
//! The paper's headline TCO result is that a **mixed-generation** fleet
//! — older accelerators kept in service next to the newest parts — can
//! match the cost-efficiency of the latest homogeneous design.
//! [`mixed_generation`] builds the plan shape that exercises it: one
//! prefill group on the new hardware and the decode stream **split
//! across two decode groups of different generations** (expert-style
//! sibling bindings whose `token_fraction`s sum to 1, each routed to
//! its own hardware class), so the orchestrator's group-granular
//! retarget has real cross-generation capacity to shift.
//! [`homogeneous`] is the newest-only counterpart the demo compares
//! TCO against (`agentic-hetero orchestrate --fleet mixed`).

use super::{
    AdmissionPolicy, BatchPolicy, ExecutionPlan, FabricSpec, NodeBinding, PipelineBinding,
    Role, SlaSpec, Stage,
};

fn cpu(op: &str, latency_s: f64, deps: Vec<usize>) -> NodeBinding {
    NodeBinding {
        op: op.into(),
        class: "CPU".into(),
        stage: Stage::Cpu,
        latency_s,
        cost_usd: 0.0,
        deps,
        xfer_bytes: 0.0,
        token_fraction: 1.0,
        prefix_overlap: 0.0,
    }
}

fn llm(
    op: &str,
    class: &str,
    stage: Stage,
    latency_s: f64,
    deps: Vec<usize>,
    tf: f64,
) -> NodeBinding {
    NodeBinding {
        op: op.into(),
        class: class.into(),
        stage,
        latency_s,
        cost_usd: 1e-5,
        deps,
        xfer_bytes: 1e6,
        token_fraction: tf,
        prefix_overlap: 0.0,
    }
}

/// A two-generation serving plan: prefill on `new_dev`, decode split
/// across a `new_dev` group (`new_decode` replicas) and an `old_dev`
/// group (`old_decode` replicas). The decode siblings' token fractions
/// start proportional to each class's deployed batch capacity — the
/// same rule `orchestrator::retune_token_fractions` re-applies after
/// every cross-group replica shift.
pub fn mixed_generation(
    model: &str,
    new_dev: &str,
    old_dev: &str,
    new_decode: u32,
    old_decode: u32,
) -> ExecutionPlan {
    let new_decode = new_decode.max(1);
    let old_decode = old_decode.max(1);
    let max_batch: u64 = 16;
    let cap_new = (new_decode as u64 * max_batch) as f64;
    let cap_old = (old_decode as u64 * max_batch) as f64;
    let share_new = cap_new / (cap_new + cap_old);
    ExecutionPlan {
        agent: "mixed_generation".into(),
        model: model.into(),
        sla: SlaSpec::EndToEnd(10.0),
        bindings: vec![
            cpu("io.input", 0.0005, vec![]),
            llm("llm.prefill", new_dev, Stage::LlmPrefill, 0.04, vec![0], 1.0),
            llm(
                "llm.decode",
                new_dev,
                Stage::LlmDecode,
                0.4,
                vec![1],
                share_new.clamp(0.01, 1.0),
            ),
            llm(
                "llm.decode",
                old_dev,
                Stage::LlmDecode,
                0.6,
                vec![1],
                (1.0 - share_new).clamp(0.01, 1.0),
            ),
            cpu("io.output", 0.0005, vec![2, 3]),
        ],
        pipelines: vec![
            PipelineBinding {
                role: Role::Prefill,
                device: new_dev.into(),
                tp: 1,
                pp: 1,
                max_batch: 8,
                replicas: 1,
                chassis: 0,
            },
            PipelineBinding {
                role: Role::Decode,
                device: new_dev.into(),
                tp: 1,
                pp: 1,
                max_batch,
                replicas: new_decode,
                chassis: 1,
            },
            PipelineBinding {
                role: Role::Decode,
                device: old_dev.into(),
                tp: 1,
                pp: 1,
                max_batch,
                replicas: old_decode,
                chassis: 1 + new_decode,
            },
        ],
        batching: BatchPolicy::default(),
        admission: AdmissionPolicy::default(),
        fabric: FabricSpec::default(),
        cpu_workers: 32,
        cost_usd: 4e-5,
        latency_s: 0.65,
        pass_log: vec![],
    }
}

/// An agentic fan-out plan built to exercise cross-step prefix-KV
/// reuse: a planner prefill/decode pair whose output gates `workers`
/// sibling worker steps. Every worker prefill shares the planner's
/// context verbatim (identical gating deps), so with reuse enabled one
/// worker pays the full prefill per request and the remaining
/// `workers - 1` hit the prefix cache. Worker bindings carry
/// `prefix_overlap = 1.0` so the planner's cost model prices the same
/// reuse the runtime realizes; with reuse off they prefill from
/// scratch — the TCO delta the `orchestrate` demo reports.
pub fn shared_prefix_fanout(model: &str, device: &str, workers: u32) -> ExecutionPlan {
    let workers = workers.max(2) as usize;
    let mut bindings = vec![
        cpu("io.input", 0.0005, vec![]),
        llm("llm.prefill", device, Stage::LlmPrefill, 0.04, vec![0], 1.0),
        llm("llm.decode", device, Stage::LlmDecode, 0.2, vec![1], 1.0),
    ];
    let mut outs = Vec::with_capacity(workers);
    for _ in 0..workers {
        let mut pre = llm("llm.prefill", device, Stage::LlmPrefill, 0.04, vec![2], 1.0);
        pre.prefix_overlap = 1.0;
        bindings.push(pre);
        let pre_idx = bindings.len() - 1;
        bindings.push(llm(
            "llm.decode",
            device,
            Stage::LlmDecode,
            0.2,
            vec![pre_idx],
            1.0,
        ));
        outs.push(bindings.len() - 1);
    }
    bindings.push(cpu("io.output", 0.0005, outs));
    ExecutionPlan {
        agent: "shared_prefix_fanout".into(),
        model: model.into(),
        sla: SlaSpec::EndToEnd(30.0),
        bindings,
        pipelines: vec![
            PipelineBinding {
                role: Role::Prefill,
                device: device.into(),
                tp: 1,
                pp: 1,
                max_batch: 8,
                replicas: 2,
                chassis: 0,
            },
            PipelineBinding {
                role: Role::Decode,
                device: device.into(),
                tp: 1,
                pp: 1,
                max_batch: 16,
                replicas: workers as u32,
                chassis: 2,
            },
        ],
        batching: BatchPolicy::default(),
        admission: AdmissionPolicy::default(),
        fabric: FabricSpec::default(),
        cpu_workers: 32,
        cost_usd: 4e-5,
        latency_s: 0.65,
        pass_log: vec![],
    }
}

/// The newest-homogeneous counterpart: the same DAG shape served by a
/// single decode group on `dev` — the baseline the mixed fleet's TCO is
/// compared against.
pub fn homogeneous(model: &str, dev: &str, decode_replicas: u32) -> ExecutionPlan {
    ExecutionPlan {
        agent: "homogeneous".into(),
        model: model.into(),
        sla: SlaSpec::EndToEnd(10.0),
        bindings: vec![
            cpu("io.input", 0.0005, vec![]),
            llm("llm.prefill", dev, Stage::LlmPrefill, 0.04, vec![0], 1.0),
            llm("llm.decode", dev, Stage::LlmDecode, 0.4, vec![1], 1.0),
            cpu("io.output", 0.0005, vec![2]),
        ],
        pipelines: vec![
            PipelineBinding {
                role: Role::Prefill,
                device: dev.into(),
                tp: 1,
                pp: 1,
                max_batch: 8,
                replicas: 1,
                chassis: 0,
            },
            PipelineBinding {
                role: Role::Decode,
                device: dev.into(),
                tp: 1,
                pp: 1,
                max_batch: 16,
                replicas: decode_replicas.max(1),
                chassis: 1,
            },
        ],
        batching: BatchPolicy::default(),
        admission: AdmissionPolicy::default(),
        fabric: FabricSpec::default(),
        cpu_workers: 32,
        cost_usd: 4e-5,
        latency_s: 0.45,
        pass_log: vec![],
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mixed_generation_plan_is_valid_and_split() {
        let p = mixed_generation("8b-fp16", "H100", "A100", 2, 2);
        p.validate().unwrap();
        // Two decode groups on different generations.
        let decode_devs: Vec<&str> = p
            .pipelines
            .iter()
            .filter(|g| g.role == Role::Decode)
            .map(|g| g.device.as_str())
            .collect();
        assert_eq!(decode_devs, vec!["H100", "A100"]);
        // Sibling decode bindings split the stream and sum to ~1.
        let tf: f64 = p.bindings[2].token_fraction + p.bindings[3].token_fraction;
        assert!((tf - 1.0).abs() < 1e-9, "fractions sum to 1: {tf}");
        assert_eq!(p.bindings[2].deps, p.bindings[3].deps);
        // Equal capacity ⇒ equal split.
        assert!((p.bindings[2].token_fraction - 0.5).abs() < 1e-9);
        // JSON round-trip (the demo saves these).
        let back = ExecutionPlan::parse_json(&p.to_json_string()).unwrap();
        assert_eq!(back, p);
    }

    #[test]
    fn uneven_capacity_skews_the_split() {
        let p = mixed_generation("8b-fp16", "H100", "A100", 3, 1);
        p.validate().unwrap();
        assert!((p.bindings[2].token_fraction - 0.75).abs() < 1e-9);
        assert!((p.bindings[3].token_fraction - 0.25).abs() < 1e-9);
    }

    #[test]
    fn shared_prefix_fanout_siblings_share_gating_deps() {
        let p = shared_prefix_fanout("8b-fp16", "H100", 4);
        p.validate().unwrap();
        // All worker prefills gate on the planner decode with identical
        // dep lists — the condition under which sim and live derive the
        // same prefix hash — and advertise full expected overlap.
        let worker_pre: Vec<usize> = (0..p.bindings.len())
            .filter(|&i| p.bindings[i].stage == Stage::LlmPrefill && i != 1)
            .collect();
        assert_eq!(worker_pre.len(), 4);
        for &i in &worker_pre {
            assert_eq!(p.bindings[i].deps, vec![2]);
            assert!((p.bindings[i].prefix_overlap - 1.0).abs() < 1e-12);
        }
        // The planner prefill itself expects no reuse.
        assert_eq!(p.bindings[1].prefix_overlap, 0.0);
        // JSON round-trip keeps the overlap estimates.
        let back = ExecutionPlan::parse_json(&p.to_json_string()).unwrap();
        assert_eq!(back, p);
    }

    #[test]
    fn homogeneous_counterpart_is_valid() {
        let p = homogeneous("8b-fp16", "H100", 4);
        p.validate().unwrap();
        assert_eq!(p.pipelines.len(), 2);
        assert!(p.bindings.iter().all(|b| b.token_fraction == 1.0));
    }
}
