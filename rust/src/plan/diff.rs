//! Typed diffs between two [`ExecutionPlan`]s.
//!
//! The orchestration loop never mutates a running fleet directly: it
//! emits a *new* plan, computes a [`PlanDiff`] against the live one,
//! and lowers the diff through `planner::migration` into an ordered
//! drain/transfer/activate sequence. The diff is also a review artifact
//! (`agentic-hetero plan diff a.json b.json`) and a timeline record —
//! it serializes through [`crate::util::json`] like the plan itself.
//!
//! Pipeline fleets are compared by *shape* — (role, device, TP×PP,
//! batch limit) — so a replica-count change is a [`PipelineResize`],
//! while a TP or batch-limit change shows up as a remove + add pair
//! (the honest migration: those pipelines must be rebuilt, not grown).

use std::collections::{BTreeMap, BTreeSet};

use super::{req_arr, req_f64, req_str, req_u64, ExecutionPlan, PipelineBinding, Role, SlaSpec};
use crate::util::json::Json;
use crate::{jobj, Result};

/// Replica-count change of one pipeline shape.
#[derive(Debug, Clone, PartialEq)]
pub struct PipelineResize {
    pub role: Role,
    pub device: String,
    pub tp: u32,
    pub pp: u32,
    pub max_batch: u64,
    pub from_replicas: u32,
    pub to_replicas: u32,
}

/// An agent-graph node moved to a different hardware class.
#[derive(Debug, Clone, PartialEq)]
pub struct BindingRebind {
    /// Index into `ExecutionPlan::bindings`.
    pub index: usize,
    pub op: String,
    pub from_class: String,
    pub to_class: String,
}

/// A scalar policy field change (admission, batching, SLA, ...).
#[derive(Debug, Clone, PartialEq)]
pub struct PolicyChange {
    pub field: String,
    pub from: String,
    pub to: String,
}

/// A binding's token fraction moved — load shifted *between* the
/// hardware classes an expert-style sibling split routes to, without
/// the binding changing class. This is how a heterogeneous rebalance
/// moves work onto the generation whose capacity grew.
#[derive(Debug, Clone, PartialEq)]
pub struct FractionShift {
    /// Index into `ExecutionPlan::bindings`.
    pub index: usize,
    pub op: String,
    pub class: String,
    pub from_fraction: f64,
    pub to_fraction: f64,
}

/// Structured difference between two plans.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct PlanDiff {
    /// Pipeline shapes present only in the target (activate).
    pub added: Vec<PipelineBinding>,
    /// Pipeline shapes present only in the source (drain).
    pub removed: Vec<PipelineBinding>,
    /// Shapes in both with different replica counts.
    pub resized: Vec<PipelineResize>,
    /// Node bindings whose hardware class moved.
    pub rebound: Vec<BindingRebind>,
    /// Node bindings whose token fraction moved (class unchanged):
    /// group-granular load shifts between hardware generations.
    pub retuned: Vec<FractionShift>,
    /// Policy-level changes (admission, batching, SLA, workers, ...).
    pub policy: Vec<PolicyChange>,
}

/// Shape identity of a pipeline group.
type ShapeKey = (Role, String, u32, u32, u64);

fn shapes(p: &ExecutionPlan) -> BTreeMap<ShapeKey, (u32, u32)> {
    // value = (total replicas, chassis of the first group)
    let mut m: BTreeMap<ShapeKey, (u32, u32)> = BTreeMap::new();
    for pl in &p.pipelines {
        let key = (pl.role, pl.device.clone(), pl.tp, pl.pp, pl.max_batch);
        let e = m.entry(key).or_insert((0, pl.chassis));
        e.0 += pl.replicas;
    }
    m
}

fn fmt_sla(s: &SlaSpec) -> String {
    match s {
        SlaSpec::None => "none".into(),
        SlaSpec::EndToEnd(t) => format!("e2e {t}s"),
        SlaSpec::Soft { t_sla_s, lambda } => format!("soft {t_sla_s}s λ{lambda}"),
    }
}

impl PlanDiff {
    /// Structural diff `from → to`.
    pub fn between(from: &ExecutionPlan, to: &ExecutionPlan) -> PlanDiff {
        let mut d = PlanDiff::default();

        // ---- pipeline fleet, by shape -------------------------------
        let a = shapes(from);
        let b = shapes(to);
        let keys: BTreeSet<&ShapeKey> = a.keys().chain(b.keys()).collect();
        for key in keys {
            let (role, device, tp, pp, max_batch) = key.clone();
            match (a.get(key), b.get(key)) {
                (Some(&(n, chassis)), None) => d.removed.push(PipelineBinding {
                    role,
                    device,
                    tp,
                    pp,
                    max_batch,
                    replicas: n,
                    chassis,
                }),
                (None, Some(&(n, chassis))) => d.added.push(PipelineBinding {
                    role,
                    device,
                    tp,
                    pp,
                    max_batch,
                    replicas: n,
                    chassis,
                }),
                (Some(&(na, _)), Some(&(nb, _))) if na != nb => {
                    d.resized.push(PipelineResize {
                        role,
                        device,
                        tp,
                        pp,
                        max_batch,
                        from_replicas: na,
                        to_replicas: nb,
                    })
                }
                _ => {}
            }
        }

        // ---- bindings ----------------------------------------------
        if from.bindings.len() != to.bindings.len() {
            d.pol(
                "bindings.len",
                from.bindings.len().to_string(),
                to.bindings.len().to_string(),
            );
        } else {
            for (i, (x, y)) in from.bindings.iter().zip(&to.bindings).enumerate() {
                if x.op != y.op {
                    d.pol(format!("bindings[{i}].op"), x.op.clone(), y.op.clone());
                } else if x.class != y.class {
                    d.rebound.push(BindingRebind {
                        index: i,
                        op: x.op.clone(),
                        from_class: x.class.clone(),
                        to_class: y.class.clone(),
                    });
                } else if x.token_fraction != y.token_fraction {
                    d.retuned.push(FractionShift {
                        index: i,
                        op: x.op.clone(),
                        class: x.class.clone(),
                        from_fraction: x.token_fraction,
                        to_fraction: y.token_fraction,
                    });
                }
            }
        }

        // ---- policies ----------------------------------------------
        if from.agent != to.agent {
            d.pol("agent", from.agent.clone(), to.agent.clone());
        }
        if from.model != to.model {
            d.pol("model", from.model.clone(), to.model.clone());
        }
        if from.sla != to.sla {
            d.pol("sla", fmt_sla(&from.sla), fmt_sla(&to.sla));
        }
        if from.admission.rate != to.admission.rate {
            d.pol(
                "admission.rate",
                from.admission.rate.to_string(),
                to.admission.rate.to_string(),
            );
        }
        if from.admission.burst != to.admission.burst {
            d.pol(
                "admission.burst",
                from.admission.burst.to_string(),
                to.admission.burst.to_string(),
            );
        }
        if from.admission.max_queue_depth != to.admission.max_queue_depth {
            d.pol(
                "admission.max_queue_depth",
                from.admission.max_queue_depth.to_string(),
                to.admission.max_queue_depth.to_string(),
            );
        }
        if from.batching.buckets != to.batching.buckets {
            d.pol(
                "batching.buckets",
                format!("{:?}", from.batching.buckets),
                format!("{:?}", to.batching.buckets),
            );
        }
        if from.batching.max_wait_ms != to.batching.max_wait_ms {
            d.pol(
                "batching.max_wait_ms",
                from.batching.max_wait_ms.to_string(),
                to.batching.max_wait_ms.to_string(),
            );
        }
        if from.batching.max_decode_batch != to.batching.max_decode_batch {
            d.pol(
                "batching.max_decode_batch",
                from.batching.max_decode_batch.to_string(),
                to.batching.max_decode_batch.to_string(),
            );
        }
        if from.cpu_workers != to.cpu_workers {
            d.pol(
                "cpu_workers",
                from.cpu_workers.to_string(),
                to.cpu_workers.to_string(),
            );
        }
        if from.fabric.slots_per_chassis != to.fabric.slots_per_chassis {
            d.pol(
                "fabric.slots_per_chassis",
                from.fabric.slots_per_chassis.to_string(),
                to.fabric.slots_per_chassis.to_string(),
            );
        }
        if from.fabric.scaleout_gbit != to.fabric.scaleout_gbit {
            d.pol(
                "fabric.scaleout_gbit",
                from.fabric.scaleout_gbit.to_string(),
                to.fabric.scaleout_gbit.to_string(),
            );
        }
        d
    }

    fn pol(&mut self, field: impl Into<String>, from: String, to: String) {
        self.policy.push(PolicyChange {
            field: field.into(),
            from,
            to,
        });
    }

    pub fn is_empty(&self) -> bool {
        self.added.is_empty()
            && self.removed.is_empty()
            && self.resized.is_empty()
            && self.rebound.is_empty()
            && self.retuned.is_empty()
            && self.policy.is_empty()
    }

    /// Does this diff move capacity or load *between* groups? True when
    /// ≥ 2 distinct pipeline shapes of one role changed together (one
    /// side grows while another shrinks or rebuilds), or when any token
    /// fraction shifted between sibling classes. A plain primary-group
    /// grow/shrink is *not* cross-group.
    pub fn is_cross_group(&self) -> bool {
        if !self.retuned.is_empty() {
            return true;
        }
        let mut shapes_of: BTreeMap<Role, BTreeSet<String>> = BTreeMap::new();
        for p in self.added.iter().chain(self.removed.iter()) {
            shapes_of.entry(p.role).or_default().insert(p.shape_key());
        }
        for r in &self.resized {
            shapes_of
                .entry(r.role)
                .or_default()
                .insert(super::shape_key_of(r.role, &r.device, r.tp, r.pp, r.max_batch));
        }
        shapes_of.values().any(|s| s.len() >= 2)
    }

    /// Pipeline units that must be brought up / torn down.
    pub fn replica_delta(&self) -> (u32, u32) {
        let mut up: u32 = self.added.iter().map(|p| p.replicas).sum();
        let mut down: u32 = self.removed.iter().map(|p| p.replicas).sum();
        for r in &self.resized {
            if r.to_replicas > r.from_replicas {
                up += r.to_replicas - r.from_replicas;
            } else {
                down += r.from_replicas - r.to_replicas;
            }
        }
        (up, down)
    }

    /// Human-readable rendering, one change per line.
    pub fn summary(&self) -> String {
        if self.is_empty() {
            return "(no changes)\n".to_string();
        }
        let mut out = String::new();
        let shape = |p: &PipelineBinding| {
            format!(
                "{} {} tp{} pp{} b{}",
                p.role.name(),
                p.device,
                p.tp,
                p.pp,
                p.max_batch
            )
        };
        for p in &self.added {
            out.push_str(&format!(
                "+ {} ×{} @ chassis {}\n",
                shape(p),
                p.replicas,
                p.chassis
            ));
        }
        for p in &self.removed {
            out.push_str(&format!("- {} ×{}\n", shape(p), p.replicas));
        }
        for r in &self.resized {
            out.push_str(&format!(
                "~ {} {} tp{} pp{} b{}: replicas {} -> {}\n",
                r.role.name(),
                r.device,
                r.tp,
                r.pp,
                r.max_batch,
                r.from_replicas,
                r.to_replicas
            ));
        }
        for b in &self.rebound {
            out.push_str(&format!(
                "~ binding {} ({}): {} -> {}\n",
                b.index, b.op, b.from_class, b.to_class
            ));
        }
        for s in &self.retuned {
            out.push_str(&format!(
                "~ binding {} ({} @ {}): token_fraction {:.4} -> {:.4}\n",
                s.index, s.op, s.class, s.from_fraction, s.to_fraction
            ));
        }
        for p in &self.policy {
            out.push_str(&format!("~ {}: {} -> {}\n", p.field, p.from, p.to));
        }
        out
    }

    // ---- JSON round-trip -------------------------------------------

    pub fn to_json(&self) -> Json {
        let resized: Vec<Json> = self
            .resized
            .iter()
            .map(|r| {
                jobj! {
                    "role" => r.role.name(),
                    "device" => r.device.clone(),
                    "tp" => r.tp,
                    "pp" => r.pp,
                    "max_batch" => r.max_batch,
                    "from_replicas" => r.from_replicas,
                    "to_replicas" => r.to_replicas,
                }
            })
            .collect();
        let rebound: Vec<Json> = self
            .rebound
            .iter()
            .map(|b| {
                jobj! {
                    "index" => b.index,
                    "op" => b.op.clone(),
                    "from_class" => b.from_class.clone(),
                    "to_class" => b.to_class.clone(),
                }
            })
            .collect();
        let retuned: Vec<Json> = self
            .retuned
            .iter()
            .map(|s| {
                jobj! {
                    "index" => s.index,
                    "op" => s.op.clone(),
                    "class" => s.class.clone(),
                    "from_fraction" => s.from_fraction,
                    "to_fraction" => s.to_fraction,
                }
            })
            .collect();
        let policy: Vec<Json> = self
            .policy
            .iter()
            .map(|p| {
                jobj! {
                    "field" => p.field.clone(),
                    "from" => p.from.clone(),
                    "to" => p.to.clone(),
                }
            })
            .collect();
        jobj! {
            "added" => Json::Arr(self.added.iter().map(|p| p.to_json()).collect()),
            "removed" => Json::Arr(self.removed.iter().map(|p| p.to_json()).collect()),
            "resized" => Json::Arr(resized),
            "rebound" => Json::Arr(rebound),
            "retuned" => Json::Arr(retuned),
            "policy" => Json::Arr(policy),
        }
    }

    pub fn from_json(j: &Json) -> Result<PlanDiff> {
        let mut d = PlanDiff::default();
        for p in req_arr(j, "added")? {
            d.added.push(PipelineBinding::from_json(p)?);
        }
        for p in req_arr(j, "removed")? {
            d.removed.push(PipelineBinding::from_json(p)?);
        }
        for r in req_arr(j, "resized")? {
            d.resized.push(PipelineResize {
                role: Role::from_name(req_str(r, "role")?)?,
                device: req_str(r, "device")?.to_string(),
                tp: req_u64(r, "tp")? as u32,
                pp: req_u64(r, "pp")? as u32,
                max_batch: req_u64(r, "max_batch")?,
                from_replicas: req_u64(r, "from_replicas")? as u32,
                to_replicas: req_u64(r, "to_replicas")? as u32,
            });
        }
        for b in req_arr(j, "rebound")? {
            d.rebound.push(BindingRebind {
                index: req_u64(b, "index")? as usize,
                op: req_str(b, "op")?.to_string(),
                from_class: req_str(b, "from_class")?.to_string(),
                to_class: req_str(b, "to_class")?.to_string(),
            });
        }
        // Back-compat: diffs written before group-granular retargeting
        // have no `retuned` array.
        if let Some(arr) = j.get("retuned").and_then(|v| v.as_arr()) {
            for s in arr {
                d.retuned.push(FractionShift {
                    index: req_u64(s, "index")? as usize,
                    op: req_str(s, "op")?.to_string(),
                    class: req_str(s, "class")?.to_string(),
                    from_fraction: req_f64(s, "from_fraction")?,
                    to_fraction: req_f64(s, "to_fraction")?,
                });
            }
        }
        for p in req_arr(j, "policy")? {
            d.policy.push(PolicyChange {
                field: req_str(p, "field")?.to_string(),
                from: req_str(p, "from")?.to_string(),
                to: req_str(p, "to")?.to_string(),
            });
        }
        Ok(d)
    }
}

#[cfg(test)]
mod tests {
    use super::super::tests::tiny_plan;
    use super::*;

    #[test]
    fn identical_plans_diff_empty() {
        let p = tiny_plan();
        let d = PlanDiff::between(&p, &p);
        assert!(d.is_empty());
        assert_eq!(d.summary(), "(no changes)\n");
    }

    #[test]
    fn replica_change_is_a_resize() {
        let a = tiny_plan();
        let mut b = tiny_plan();
        b.pipelines[1].replicas = 4; // decode Gaudi3: 2 -> 4
        let d = PlanDiff::between(&a, &b);
        assert!(d.added.is_empty() && d.removed.is_empty());
        assert_eq!(d.resized.len(), 1);
        assert_eq!(d.resized[0].from_replicas, 2);
        assert_eq!(d.resized[0].to_replicas, 4);
        assert_eq!(d.replica_delta(), (2, 0));
        assert!(d.summary().contains("replicas 2 -> 4"));
    }

    #[test]
    fn shape_change_is_remove_plus_add() {
        let a = tiny_plan();
        let mut b = tiny_plan();
        b.pipelines[1].tp = 2; // decode pipelines rebuilt at TP2
        let d = PlanDiff::between(&a, &b);
        assert_eq!(d.removed.len(), 1);
        assert_eq!(d.added.len(), 1);
        assert!(d.resized.is_empty());
        assert_eq!(d.replica_delta(), (2, 2));
    }

    #[test]
    fn rebind_and_policy_changes_tracked() {
        let a = tiny_plan();
        let mut b = tiny_plan();
        b.bindings[2].class = "H100".into();
        b.admission.rate = 2000.0;
        b.cpu_workers = 32;
        let d = PlanDiff::between(&a, &b);
        assert_eq!(d.rebound.len(), 1);
        assert_eq!(d.rebound[0].op, "llm.decode");
        assert_eq!(d.rebound[0].to_class, "H100");
        assert!(d.policy.iter().any(|p| p.field == "admission.rate"));
        assert!(d.policy.iter().any(|p| p.field == "cpu_workers"));
    }

    #[test]
    fn json_round_trip_is_identity() {
        let a = tiny_plan();
        let mut b = tiny_plan();
        b.pipelines[0].replicas = 3;
        b.pipelines[1].device = "MI300x".into();
        b.bindings[1].class = "MI300x".into();
        b.bindings[2].token_fraction = 0.625;
        b.sla = SlaSpec::None;
        let d = PlanDiff::between(&a, &b);
        assert!(!d.is_empty());
        assert_eq!(d.retuned.len(), 1, "fraction shift must be typed");
        let back = PlanDiff::from_json(&Json::parse(&d.to_json().pretty()).unwrap()).unwrap();
        assert_eq!(back, d);
    }

    #[test]
    fn fraction_shift_is_typed_and_cross_group() {
        let a = tiny_plan();
        let mut b = tiny_plan();
        b.bindings[2].token_fraction = 0.5; // llm.decode keeps its class
        let d = PlanDiff::between(&a, &b);
        assert!(d.rebound.is_empty());
        assert_eq!(d.retuned.len(), 1);
        assert_eq!(d.retuned[0].index, 2);
        assert_eq!(d.retuned[0].class, "Gaudi3");
        assert_eq!(d.retuned[0].from_fraction, 1.0);
        assert_eq!(d.retuned[0].to_fraction, 0.5);
        assert!(d.is_cross_group(), "a load shift between classes is cross-group");
        assert!(d.summary().contains("token_fraction"));
    }

    #[test]
    fn cross_group_requires_two_shapes_of_one_role() {
        let a = tiny_plan();
        // Primary-group grow only: not cross-group.
        let mut grow = tiny_plan();
        grow.pipelines[1].replicas = 4;
        assert!(!PlanDiff::between(&a, &grow).is_cross_group());
        // One decode group shrinks while another appears: cross-group.
        let mut shift = tiny_plan();
        shift.pipelines[1].replicas = 1;
        shift.pipelines.push(PipelineBinding {
            role: Role::Decode,
            device: "A100".into(),
            tp: 1,
            pp: 1,
            max_batch: 32,
            replicas: 1,
            chassis: 3,
        });
        let d = PlanDiff::between(&a, &shift);
        assert!(d.is_cross_group(), "{}", d.summary());
        // Changes on different *roles* don't count as one rebalance.
        let mut both = tiny_plan();
        both.pipelines[0].replicas = 2;
        both.pipelines[1].replicas = 4;
        assert!(!PlanDiff::between(&a, &both).is_cross_group());
    }

    #[test]
    fn pre_retune_diff_json_still_parses() {
        // Diffs serialized before the `retuned` field existed.
        let a = tiny_plan();
        let mut b = tiny_plan();
        b.pipelines[1].replicas = 4;
        let d = PlanDiff::between(&a, &b);
        let mut j = d.to_json();
        // Simulate an old artifact: drop the retuned array entirely.
        if let Json::Obj(m) = &mut j {
            m.remove("retuned");
        }
        let back = PlanDiff::from_json(&j).unwrap();
        assert_eq!(back, d, "absent retuned parses as empty");
    }
}
