//! Typed diagnostics for the static plan analyzer ([`super::verify`]).
//!
//! A [`Diag`] is one finding of one analysis pass over an
//! [`super::ExecutionPlan`]: a stable `AH0xx` code, a severity, the
//! plan location it anchors to (binding / pipeline group / plan-level
//! path), a human message, and a suggested fix. [`DiagReport`] is the
//! pass manager's output — it renders the diagnostics table `plan lint`
//! prints and round-trips through [`crate::util::json`] so CI can pin
//! the output byte-for-byte.

use crate::util::json::Json;
use crate::{jobj, Error, Result};

/// How bad a finding is. `Error` diagnostics make a plan unloadable
/// (`DagSim`, `Server`, and the orchestrator pre-flight all reject);
/// `Warn` diagnostics are advisory unless `plan lint --deny-warn`.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Severity {
    Error,
    Warn,
}

impl Severity {
    pub fn name(&self) -> &'static str {
        match self {
            Severity::Error => "error",
            Severity::Warn => "warn",
        }
    }

    fn from_name(s: &str) -> Result<Severity> {
        match s {
            "error" => Ok(Severity::Error),
            "warn" => Ok(Severity::Warn),
            other => Err(Error::Config(format!("unknown severity `{other}`"))),
        }
    }
}

/// One typed finding of the static plan analyzer.
#[derive(Debug, Clone, PartialEq)]
pub struct Diag {
    /// Stable code, `AH0xx` (see the table in ARCHITECTURE.md).
    pub code: String,
    pub severity: Severity,
    /// Plan path the finding anchors to: `binding[i] <op>`,
    /// `pipeline[g] <shape key>`, `plan`, ...
    pub loc: String,
    pub message: String,
    /// Suggested fix; empty when there is no mechanical suggestion.
    pub suggestion: String,
}

impl Diag {
    pub fn new(
        code: &str,
        severity: Severity,
        loc: impl Into<String>,
        message: impl Into<String>,
        suggestion: impl Into<String>,
    ) -> Diag {
        Diag {
            code: code.to_string(),
            severity,
            loc: loc.into(),
            message: message.into(),
            suggestion: suggestion.into(),
        }
    }

    /// One-line rendering (`AH001 error binding[1] llm.prefill: ...`).
    pub fn render(&self) -> String {
        format!(
            "{} {:<5} {}: {}",
            self.code,
            self.severity.name(),
            self.loc,
            self.message
        )
    }

    pub fn to_json(&self) -> Json {
        jobj! {
            "code" => self.code.clone(),
            "severity" => self.severity.name(),
            "loc" => self.loc.clone(),
            "message" => self.message.clone(),
            "suggestion" => self.suggestion.clone(),
        }
    }

    pub fn from_json(j: &Json) -> Result<Diag> {
        let field = |k: &str| -> Result<String> {
            j.get(k)
                .and_then(|v| v.as_str())
                .map(str::to_string)
                .ok_or_else(|| Error::Config(format!("diag json missing `{k}`")))
        };
        Ok(Diag {
            code: field("code")?,
            severity: Severity::from_name(&field("severity")?)?,
            loc: field("loc")?,
            message: field("message")?,
            suggestion: field("suggestion")?,
        })
    }
}

/// The analyzer's output: every diagnostic in pass order, plus the
/// per-pass finding counts (the pass manager's run log).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct DiagReport {
    pub diags: Vec<Diag>,
    /// (pass name, findings emitted) in execution order.
    pub passes: Vec<(String, usize)>,
}

impl DiagReport {
    pub fn errors(&self) -> impl Iterator<Item = &Diag> {
        self.diags.iter().filter(|d| d.severity == Severity::Error)
    }

    pub fn warnings(&self) -> impl Iterator<Item = &Diag> {
        self.diags.iter().filter(|d| d.severity == Severity::Warn)
    }

    pub fn has_errors(&self) -> bool {
        self.errors().next().is_some()
    }

    pub fn is_clean(&self) -> bool {
        self.diags.is_empty()
    }

    /// The diagnostics table `plan lint` prints. Byte-stable (pinned by
    /// the golden test): header, one line per diagnostic with an
    /// indented `fix:` line when a suggestion exists, then the verdict.
    pub fn table(&self) -> String {
        let n_err = self.errors().count();
        let n_warn = self.warnings().count();
        let mut out = String::new();
        out.push_str(&format!(
            "plan diagnostics: {n_err} error(s), {n_warn} warning(s)\n"
        ));
        for d in &self.diags {
            out.push_str(&format!("  {}\n", d.render()));
            if !d.suggestion.is_empty() {
                out.push_str(&format!("        fix: {}\n", d.suggestion));
            }
        }
        let verdict = if n_err > 0 {
            "FAIL"
        } else if n_warn > 0 {
            "PASS (with warnings)"
        } else {
            "PASS"
        };
        out.push_str(&format!("verdict: {verdict}\n"));
        out
    }

    pub fn to_json(&self) -> Json {
        let diags: Vec<Json> = self.diags.iter().map(|d| d.to_json()).collect();
        let passes: Vec<Json> = self
            .passes
            .iter()
            .map(|(name, n)| jobj! { "pass" => name.clone(), "findings" => *n as u64 })
            .collect();
        jobj! {
            "errors" => self.errors().count() as u64,
            "warnings" => self.warnings().count() as u64,
            "diags" => Json::Arr(diags),
            "passes" => Json::Arr(passes),
        }
    }

    pub fn from_json(j: &Json) -> Result<DiagReport> {
        let arr = |k: &str| -> Result<&[Json]> {
            j.get(k)
                .and_then(|v| v.as_arr())
                .ok_or_else(|| Error::Config(format!("diag report missing `{k}`")))
        };
        let mut diags = Vec::new();
        for d in arr("diags")? {
            diags.push(Diag::from_json(d)?);
        }
        let mut passes = Vec::new();
        for p in arr("passes")? {
            let name = p
                .get("pass")
                .and_then(|v| v.as_str())
                .ok_or_else(|| Error::Config("pass entry missing `pass`".into()))?;
            let n = p
                .get("findings")
                .and_then(|v| v.as_u64())
                .ok_or_else(|| Error::Config("pass entry missing `findings`".into()))?;
            passes.push((name.to_string(), n as usize));
        }
        Ok(DiagReport { diags, passes })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> DiagReport {
        DiagReport {
            diags: vec![
                Diag::new(
                    "AH001",
                    Severity::Error,
                    "binding[1] llm.prefill",
                    "dep 9 out of range (plan has 4 bindings)",
                    "point the dep at an existing earlier binding",
                ),
                Diag::new(
                    "AH040",
                    Severity::Warn,
                    "plan",
                    "critical-path lower bound 5.2s exceeds SLA 3.0s",
                    "",
                ),
            ],
            passes: vec![("topology".into(), 1), ("sla".into(), 1)],
        }
    }

    #[test]
    fn json_round_trip_is_identity() {
        let r = sample();
        let j = r.to_json();
        let back = DiagReport::from_json(&j).unwrap();
        assert_eq!(back, r);
        assert_eq!(back.to_json().pretty(), j.pretty(), "byte-stable");
    }

    #[test]
    fn table_counts_and_verdict() {
        let r = sample();
        let t = r.table();
        assert!(t.starts_with("plan diagnostics: 1 error(s), 1 warning(s)\n"));
        assert!(t.contains("AH001 error binding[1] llm.prefill:"));
        assert!(t.contains("        fix: point the dep"));
        assert!(t.ends_with("verdict: FAIL\n"));
        assert!(r.has_errors());

        let clean = DiagReport::default();
        assert!(clean.table().ends_with("verdict: PASS\n"));
        assert!(clean.is_clean());
    }

    #[test]
    fn warn_only_report_passes_with_warnings() {
        let mut r = sample();
        r.diags.remove(0);
        assert!(!r.has_errors());
        assert!(r.table().ends_with("verdict: PASS (with warnings)\n"));
    }

    #[test]
    fn bad_severity_rejected() {
        let mut j = sample().to_json();
        // Corrupt the first diag's severity.
        let text = j.pretty().replace("\"error\"", "\"fatal\"");
        j = Json::parse(&text).unwrap();
        assert!(DiagReport::from_json(&j).is_err());
    }
}
