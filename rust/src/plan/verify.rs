//! Static plan analysis: a pass-manager-driven verifier/linter over
//! [`ExecutionPlan`] + fleet/fabric context.
//!
//! The paper's compilation story (§3) validates agent execution graphs
//! *before* they hit heterogeneous hardware. We already verify the
//! MLIR-like IR (`ir/verifier.rs`); this module is the same discipline
//! at the `ExecutionPlan` layer where planner, orchestrator, `DagSim`,
//! and the live server meet — so a structurally invalid or infeasible
//! placement is a typed [`Diag`] at plan-load / re-plan time, not a
//! runtime `PlanRejection`, a mid-run `Error::Capacity`, or a panic.
//!
//! Five analysis passes, each a pure function over the plan:
//!
//! | pass       | codes        | what it proves statically             |
//! |------------|--------------|---------------------------------------|
//! | `topology` | AH001–AH003  | DAG sanity: no dangling/forward deps (cycles), no disconnected nodes |
//! | `bindings` | AH010–AH017  | binding invariants: sibling token splits, overlap bounds, group references |
//! | `capacity` | AH020–AH021  | HBM footprint per group, admission demand vs throughput bound |
//! | `fabric`   | AH030–AH032  | cross-chassis KV hops have a link, links not oversubscribed, no chassis gaps |
//! | `sla`      | AH040        | cost-model critical-path lower bound vs the SLA target |
//!
//! Severity contract: **Error** diagnostics make a plan unloadable —
//! [`ensure_loadable`] gates `DagSim::new`, `Server::install_plan`, and
//! the orchestrator's re-plan pre-flight. **Warn** diagnostics are
//! advisory (`plan lint --deny-warn` promotes them in CI).
//!
//! [`verify_replan`] is the *contextual* pass (AH050): whether a fresh
//! plan may replace the live one mid-run. `orchestrator::
//! reconcile_replan` delegates here so the runtime `PlanRejection` and
//! the analyzer share one source of truth.

use crate::cost::hardware::by_name;
use crate::cost::kv::kv_cache_bytes;
use crate::cost::model_profile::by_short_name;
use crate::obs::trace::{classify_host_op, SpanKind};
use crate::{Error, Result};

use super::diag::{Diag, DiagReport, Severity};
use super::{ExecutionPlan, PipelineBinding, Role, Stage};

/// Nominal per-request context (tokens) for the static KV working-set
/// estimate — deliberately modest so the HBM pass only fires on plans
/// that cannot fit even a small context at the declared batch size.
const NOMINAL_CTX_TOKENS: u64 = 1024;

/// Static throughput/bandwidth bounds are optimistic upper bounds, so
/// demand checks only fire when the declared admission ceiling exceeds
/// the bound by more than this multiplexing slack — an order-of-
/// magnitude gap no burst smoothing can absorb.
const DEMAND_SLACK: f64 = 20.0;

/// The analysis passes, in execution order.
pub const PASSES: [(&str, fn(&ExecutionPlan, &mut Vec<Diag>)); 5] = [
    ("topology", pass_topology),
    ("bindings", pass_bindings),
    ("capacity", pass_capacity),
    ("fabric", pass_fabric),
    ("sla", pass_sla),
];

/// Run every pass over the plan and collect the findings.
pub fn verify(plan: &ExecutionPlan) -> DiagReport {
    let mut report = DiagReport::default();
    for (name, pass) in PASSES {
        let before = report.diags.len();
        pass(plan, &mut report.diags);
        report
            .passes
            .push((name.to_string(), report.diags.len() - before));
    }
    report
}

/// Gate for plan consumers (`DagSim::new`, `Server::install_plan`, the
/// orchestrator pre-flight): Error-severity findings reject the plan
/// with the full diagnostics table attached.
pub fn ensure_loadable(plan: &ExecutionPlan) -> Result<()> {
    let report = verify(plan);
    if report.has_errors() {
        return Err(Error::Verify(format!(
            "plan rejected by static analysis:\n{}",
            report.table()
        )));
    }
    Ok(())
}

/// Planner self-check: a freshly-lowered plan must analyze clean of
/// errors (debug builds assert; release builds skip the cost).
pub fn debug_assert_clean(plan: &ExecutionPlan) {
    if cfg!(debug_assertions) {
        let report = verify(plan);
        debug_assert!(
            !report.has_errors(),
            "planner emitted a plan with static errors:\n{}",
            report.table()
        );
    }
}

fn bloc(i: usize, plan: &ExecutionPlan) -> String {
    format!("binding[{i}] {}", plan.bindings[i].op)
}

fn gloc(g: usize, plan: &ExecutionPlan) -> String {
    format!("pipeline[{g}] {}", plan.pipelines[g].shape_key())
}

fn role_of(stage: Stage) -> Option<Role> {
    match stage {
        Stage::LlmPrefill => Some(Role::Prefill),
        Stage::LlmDecode => Some(Role::Decode),
        Stage::Cpu => None,
    }
}

/// Pipeline groups a binding can route to: same role, same device
/// class.
fn groups_of(plan: &ExecutionPlan, i: usize) -> Vec<usize> {
    let b = &plan.bindings[i];
    match role_of(b.stage) {
        None => Vec::new(),
        Some(role) => (0..plan.pipelines.len())
            .filter(|&g| {
                plan.pipelines[g].role == role && plan.pipelines[g].device == b.class
            })
            .collect(),
    }
}

/// Chassis span `[chassis, chassis + replicas)` of a group.
fn chassis_range(p: &PipelineBinding) -> (u32, u32) {
    (p.chassis, p.chassis.saturating_add(p.replicas))
}

fn ranges_overlap(a: (u32, u32), b: (u32, u32)) -> bool {
    a.0 < b.1 && b.0 < a.1
}

// ---- pass 1: topology ----------------------------------------------------

/// AH001 dangling dep, AH002 self/forward dep (the cycle class —
/// bindings are index-ordered topological, so any dep `>= i` would
/// close a cycle), AH003 disconnected node.
fn pass_topology(plan: &ExecutionPlan, out: &mut Vec<Diag>) {
    let n = plan.bindings.len();
    let mut referenced = vec![false; n];
    for (i, b) in plan.bindings.iter().enumerate() {
        for &d in &b.deps {
            if d >= n {
                out.push(Diag::new(
                    "AH001",
                    Severity::Error,
                    bloc(i, plan),
                    format!("dep {d} out of range (plan has {n} bindings)"),
                    "point the dep at an existing earlier binding",
                ));
            } else if d >= i {
                out.push(Diag::new(
                    "AH002",
                    Severity::Error,
                    bloc(i, plan),
                    format!(
                        "dep {d} is not topologically earlier (self/forward \
                         deps close a cycle)"
                    ),
                    "reorder the bindings so every dep index is smaller than \
                     its consumer",
                ));
            } else {
                referenced[d] = true;
            }
        }
    }
    if n > 1 {
        for (i, b) in plan.bindings.iter().enumerate() {
            if b.deps.is_empty() && !referenced[i] {
                out.push(Diag::new(
                    "AH003",
                    Severity::Warn,
                    bloc(i, plan),
                    "node is disconnected from the DAG (no deps, no dependents)",
                    "wire the node into the request path or drop it",
                ));
            }
        }
    }
}

// ---- pass 2: binding invariants ------------------------------------------

/// AH010 sibling token-fraction partition, AH011 prefix_overlap bounds,
/// AH012 zero-sized pipeline dims, AH013 binding without a matching
/// group, AH014 unknown device, AH015 token_fraction bounds, AH016
/// duplicate group declaration, AH017 orphaned group.
fn pass_bindings(plan: &ExecutionPlan, out: &mut Vec<Diag>) {
    let n = plan.bindings.len();
    // Expert-sibling sets: same op + same stage + same gating deps.
    // A set where any member takes a partial stream is a *split* whose
    // fractions must partition the stream (sum ≈ 1); all-1.0 sets are
    // fan-out replicas, each processing the whole stream.
    let mut seen = vec![false; n];
    for i in 0..n {
        if seen[i] || plan.bindings[i].stage == Stage::Cpu {
            continue;
        }
        let sibs: Vec<usize> = (i..n)
            .filter(|&j| {
                plan.bindings[j].op == plan.bindings[i].op
                    && plan.bindings[j].stage == plan.bindings[i].stage
                    && plan.bindings[j].deps == plan.bindings[i].deps
            })
            .collect();
        for &j in &sibs {
            seen[j] = true;
        }
        if sibs.len() < 2 {
            continue;
        }
        let split = sibs
            .iter()
            .any(|&j| plan.bindings[j].token_fraction < 1.0 - 1e-9);
        if split {
            let sum: f64 = sibs.iter().map(|&j| plan.bindings[j].token_fraction).sum();
            if (sum - 1.0).abs() > 1e-6 {
                out.push(Diag::new(
                    "AH010",
                    Severity::Error,
                    bloc(i, plan),
                    format!(
                        "expert-sibling token fractions sum to {sum:.6} across \
                         bindings {sibs:?} (must partition the stream: sum = 1)"
                    ),
                    "retune the sibling token_fractions to sum to 1",
                ));
            }
        }
    }
    for (i, b) in plan.bindings.iter().enumerate() {
        if !b.token_fraction.is_finite()
            || b.token_fraction <= 0.0
            || b.token_fraction > 1.0
        {
            out.push(Diag::new(
                "AH015",
                Severity::Error,
                bloc(i, plan),
                format!("token_fraction {} outside (0, 1]", b.token_fraction),
                "set token_fraction to the share of the stream this node \
                 processes",
            ));
        }
        if !b.prefix_overlap.is_finite() || !(0.0..=1.0).contains(&b.prefix_overlap) {
            out.push(Diag::new(
                "AH011",
                Severity::Error,
                bloc(i, plan),
                format!("prefix_overlap {} outside [0, 1]", b.prefix_overlap),
                "clamp prefix_overlap to the expected resident-prefix fraction",
            ));
        }
        if role_of(b.stage).is_some() && groups_of(plan, i).is_empty() {
            out.push(Diag::new(
                "AH013",
                Severity::Error,
                bloc(i, plan),
                format!(
                    "no {} pipeline group bound for class {}",
                    b.stage.name(),
                    b.class
                ),
                "add a pipeline group with the binding's (role, device) or \
                 rebind the node",
            ));
        }
    }
    for (g, p) in plan.pipelines.iter().enumerate() {
        if by_name(&p.device).is_none() {
            out.push(Diag::new(
                "AH014",
                Severity::Error,
                gloc(g, plan),
                format!("device `{}` not in the hardware catalog", p.device),
                "use a catalog device (A40, A100, Gaudi3, MI300x, H100, B200)",
            ));
        }
        if p.replicas == 0 || p.tp == 0 || p.pp == 0 || p.max_batch == 0 {
            out.push(Diag::new(
                "AH012",
                Severity::Error,
                gloc(g, plan),
                format!(
                    "zero-sized dimension (tp {} pp {} max_batch {} replicas {})",
                    p.tp, p.pp, p.max_batch, p.replicas
                ),
                "every pipeline dimension must be >= 1",
            ));
        }
        for (h, q) in plan.pipelines.iter().enumerate().take(g) {
            if p == q {
                out.push(Diag::new(
                    "AH016",
                    Severity::Warn,
                    gloc(g, plan),
                    format!("duplicate of pipeline[{h}] (identical group declared twice)"),
                    "merge the duplicates into one group with more replicas",
                ));
                break;
            }
        }
        let used = plan.bindings.iter().any(|b| {
            role_of(b.stage).is_some_and(|r| r == p.role) && b.class == p.device
        });
        if !used {
            out.push(Diag::new(
                "AH017",
                Severity::Warn,
                gloc(g, plan),
                "no binding routes to this group (orphaned capacity)",
                "drop the group or rebind a node onto its device class",
            ));
        }
    }
}

// ---- pass 3: capacity feasibility ----------------------------------------

/// AH020 per-group HBM footprint (weights + KV working set, Eq. 3) vs
/// device HBM; AH021 declared admission demand vs the fleet's static
/// decode-throughput upper bound.
fn pass_capacity(plan: &ExecutionPlan, out: &mut Vec<Diag>) {
    let Some(model) = by_short_name(&plan.model) else {
        return; // CPU-only plan (or unknown model — AH014/installer report it)
    };
    for (g, p) in plan.pipelines.iter().enumerate() {
        let Some(dev) = by_name(&p.device) else {
            continue; // AH014 already reported
        };
        let shards = (p.tp.max(1) as f64) * (p.pp.max(1) as f64);
        let weights = model.param_bytes() / shards;
        let kv = kv_cache_bytes(&model, NOMINAL_CTX_TOKENS, p.max_batch.max(1)) / shards;
        let need = weights + kv;
        let have = dev.mem_gb * 1e9;
        if need > have {
            out.push(Diag::new(
                "AH020",
                Severity::Error,
                gloc(g, plan),
                format!(
                    "HBM footprint {:.1} GB (weights {:.1} + KV {:.1} at ctx \
                     {} x batch {}) exceeds {} HBM {:.0} GB",
                    need / 1e9,
                    weights / 1e9,
                    kv / 1e9,
                    NOMINAL_CTX_TOKENS,
                    p.max_batch,
                    p.device,
                    dev.mem_gb
                ),
                "raise tp/pp, shrink max_batch, or move the group to a \
                 larger-memory device",
            ));
        }
    }
    // Static decode-throughput upper bound: every decode batch slot
    // turning over at the *fastest* profiled decode latency. A declared
    // admission ceiling beyond DEMAND_SLACK x this bound can never be
    // served, no matter how bursts smooth.
    let decode_slots: u64 = plan
        .pipelines
        .iter()
        .filter(|p| p.role == Role::Decode)
        .map(|p| p.replicas as u64 * p.max_batch)
        .sum();
    let min_latency = plan
        .bindings
        .iter()
        .filter(|b| b.stage == Stage::LlmDecode && b.latency_s > 0.0)
        .map(|b| b.latency_s)
        .fold(f64::INFINITY, f64::min);
    if decode_slots > 0 && min_latency.is_finite() {
        let bound = decode_slots as f64 / min_latency;
        if plan.admission.rate > DEMAND_SLACK * bound {
            out.push(Diag::new(
                "AH021",
                Severity::Warn,
                "plan",
                format!(
                    "admission rate {:.0} req/s exceeds {DEMAND_SLACK:.0}x the \
                     fleet's decode-throughput bound {bound:.1} req/s \
                     ({decode_slots} slots / {min_latency:.3}s)",
                    plan.admission.rate
                ),
                "lower the admission rate or grow the decode fleet",
            ));
        }
    }
}

// ---- pass 4: fabric audit ------------------------------------------------

/// AH030 cross-chassis prefill->decode KV hop with no scale-out link,
/// AH031 statically oversubscribed scale-out link, AH032 chassis gap.
fn pass_fabric(plan: &ExecutionPlan, out: &mut Vec<Diag>) {
    let scaleout = plan.fabric.scaleout_gbit;
    let mut cross_bytes_per_req = 0.0f64;
    for (i, b) in plan.bindings.iter().enumerate() {
        if b.stage == Stage::Cpu {
            continue;
        }
        for &d in &b.deps {
            if d >= plan.bindings.len() || plan.bindings[d].stage == Stage::Cpu {
                continue;
            }
            // The edge must cross chassis when every (producer group,
            // consumer group) pairing occupies disjoint chassis ranges.
            let from_groups = groups_of(plan, d);
            let to_groups = groups_of(plan, i);
            if from_groups.is_empty() || to_groups.is_empty() {
                continue; // AH013 already reported
            }
            let may_be_local = from_groups.iter().any(|&fg| {
                to_groups.iter().any(|&tg| {
                    ranges_overlap(
                        chassis_range(&plan.pipelines[fg]),
                        chassis_range(&plan.pipelines[tg]),
                    )
                })
            });
            if !may_be_local {
                cross_bytes_per_req += b.xfer_bytes.max(0.0);
                let is_kv_hop = plan.bindings[d].stage == Stage::LlmPrefill
                    && b.stage == Stage::LlmDecode;
                if is_kv_hop && !(scaleout > 0.0 && scaleout.is_finite()) {
                    out.push(Diag::new(
                        "AH030",
                        Severity::Error,
                        bloc(i, plan),
                        format!(
                            "prefill->decode KV handoff from binding {d} must \
                             cross chassis but the fabric has no scale-out \
                             link (scaleout_gbit = {scaleout})"
                        ),
                        "give the fabric scale-out bandwidth or co-locate the \
                         prefill and decode groups on shared chassis",
                    ));
                }
            }
        }
    }
    if scaleout > 0.0 && scaleout.is_finite() && cross_bytes_per_req > 0.0 {
        let link_bytes_per_s = scaleout * 1e9 / 8.0;
        let demand = cross_bytes_per_req * plan.admission.rate;
        if demand > DEMAND_SLACK * link_bytes_per_s {
            out.push(Diag::new(
                "AH031",
                Severity::Warn,
                "plan",
                format!(
                    "cross-chassis transfer demand {:.2} GB/s (at the \
                     admission rate) exceeds {DEMAND_SLACK:.0}x the {:.0} \
                     Gbit/s scale-out link",
                    demand / 1e9,
                    scaleout
                ),
                "widen the scale-out link, cut the admission rate, or \
                 co-locate the chatty stages",
            ));
        }
    }
    // Chassis gaps: the fleet's occupied chassis should tile [0, max)
    // — a group stranded past a hole points at a mis-set `chassis`
    // (the fabric builds one link per chassis index, holes included).
    let max_ch = plan
        .pipelines
        .iter()
        .map(|p| chassis_range(p).1)
        .max()
        .unwrap_or(0);
    if max_ch > 0 {
        let mut occupied = vec![false; max_ch as usize];
        for p in &plan.pipelines {
            let (a, b) = chassis_range(p);
            for c in a..b {
                occupied[c as usize] = true;
            }
        }
        if let Some(gap) = occupied.iter().position(|&o| !o) {
            out.push(Diag::new(
                "AH032",
                Severity::Warn,
                "plan",
                format!(
                    "chassis {gap} is unoccupied but the fleet extends to \
                     chassis {} (orphaned chassis range)",
                    max_ch - 1
                ),
                "renumber the groups' chassis to tile [0, n) contiguously",
            ));
        }
    }
}

// ---- pass 5: SLA feasibility ---------------------------------------------

/// AH040: the cost-model critical-path lower bound (longest dependency
/// path over the planner-profiled latencies) against the SLA target,
/// attributed to the same bucket taxonomy `obs/critical_path.rs` uses.
fn pass_sla(plan: &ExecutionPlan, out: &mut Vec<Diag>) {
    let target = match plan.sla {
        super::SlaSpec::EndToEnd(t) => t,
        super::SlaSpec::Soft { t_sla_s, .. } => t_sla_s,
        super::SlaSpec::None => return,
    };
    let n = plan.bindings.len();
    if n == 0 || target <= 0.0 {
        return;
    }
    // Longest-path DP over the topological index order; `from[i]`
    // remembers the gating dep so the bound can be attributed.
    let mut total = vec![0.0f64; n];
    let mut from = vec![usize::MAX; n];
    for (i, b) in plan.bindings.iter().enumerate() {
        let lat = if b.latency_s.is_finite() { b.latency_s } else { 0.0 };
        total[i] = lat;
        for &d in &b.deps {
            if d < i && total[d] + lat > total[i] {
                total[i] = total[d] + lat;
                from[i] = d;
            }
        }
    }
    let (mut node, bound) = total
        .iter()
        .enumerate()
        .map(|(i, &t)| (i, t))
        .fold((0, 0.0), |acc, x| if x.1 > acc.1 { x } else { acc });
    if bound <= target {
        return;
    }
    // Attribute the bound's seconds to the critical-path bucket
    // taxonomy (queue and kv_transfer have no static component).
    let mut buckets = std::collections::BTreeMap::new();
    loop {
        let b = &plan.bindings[node];
        let bucket = match b.stage {
            Stage::LlmPrefill => "prefill",
            Stage::LlmDecode => "decode",
            Stage::Cpu => match classify_host_op(&b.op) {
                SpanKind::ToolIo => "tool_io",
                _ => "host",
            },
        };
        *buckets.entry(bucket).or_insert(0.0) +=
            if b.latency_s.is_finite() { b.latency_s } else { 0.0 };
        if from[node] == usize::MAX {
            break;
        }
        node = from[node];
    }
    let breakdown = crate::obs::critical_path::BUCKETS
        .iter()
        .filter_map(|&b| buckets.get(b).map(|s| format!("{b} {s:.3}s")))
        .collect::<Vec<_>>()
        .join(", ");
    out.push(Diag::new(
        "AH040",
        Severity::Warn,
        "plan",
        format!(
            "critical-path lower bound {bound:.3}s ({breakdown}) exceeds the \
             SLA target {target:.3}s"
        ),
        "relax the SLA or rebind the critical path onto faster classes",
    ));
}

// ---- contextual pass: mid-run re-plan compatibility (AH050) --------------

/// One finding of the re-plan compatibility pass, carrying the typed
/// (role, live group) context `orchestrator::PlanRejection` records.
#[derive(Debug, Clone, PartialEq)]
pub struct ReplanDiag {
    pub role: Role,
    /// Shape key of the live group whose class layout the fresh plan
    /// would move (`None` = the role's primary group).
    pub group: Option<String>,
    pub diag: Diag,
}

/// AH050: whether `fresh` may replace `current` mid-run. In-flight
/// jobs keep routing by the current plan's (role, class) layout, so a
/// fresh plan that moves any role's classes is incompatible. This is
/// the analyzer-side source of truth `orchestrator::reconcile_replan`
/// converts into runtime [`PlanRejection`]s.
///
/// [`PlanRejection`]: crate::orchestrator::PlanRejection
pub fn verify_replan(current: &ExecutionPlan, fresh: &ExecutionPlan) -> Vec<ReplanDiag> {
    use std::collections::BTreeSet;
    let classes = |p: &ExecutionPlan, role: Role| -> BTreeSet<String> {
        p.pipelines
            .iter()
            .filter(|pl| pl.role == role)
            .map(|pl| pl.device.clone())
            .collect()
    };
    let mut out = Vec::new();
    for role in [Role::Prefill, Role::Decode] {
        let cur = classes(current, role);
        let new = classes(fresh, role);
        if cur == new {
            continue;
        }
        // Name the live group whose class the re-plan moved (the
        // symmetric difference), not blindly the role's first group —
        // on a mixed fleet only one generation may be affected.
        let moved: BTreeSet<String> = cur.symmetric_difference(&new).cloned().collect();
        let group = current
            .pipelines
            .iter()
            .find(|pl| pl.role == role && moved.contains(&pl.device))
            .or_else(|| current.pipelines.iter().find(|pl| pl.role == role))
            .map(|pl| pl.shape_key());
        out.push(ReplanDiag {
            role,
            group: group.clone(),
            diag: Diag::new(
                "AH050",
                Severity::Error,
                group.map_or_else(|| format!("role {}", role.name()), |g| format!("group {g}")),
                format!(
                    "planner re-plan moves {} classes {:?} -> {:?} mid-run; \
                     in-flight work keeps routing by the live classes, so the \
                     fresh layout is rejected and the current plan is \
                     structurally retargeted instead",
                    role.name(),
                    cur.iter().cloned().collect::<Vec<_>>(),
                    new.iter().cloned().collect::<Vec<_>>()
                ),
                "drain the role's in-flight work before moving its classes, \
                 or keep the class layout and retune replicas instead",
            ),
        });
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::plan::presets;
    use crate::plan::tests::tiny_plan;

    fn codes(plan: &ExecutionPlan) -> Vec<String> {
        verify(plan).diags.iter().map(|d| d.code.clone()).collect()
    }

    #[test]
    fn clean_plans_verify_clean() {
        for plan in [
            tiny_plan(),
            presets::mixed_generation("8b-fp16", "H100", "A100", 2, 2),
            presets::shared_prefix_fanout("8b-fp16", "H100", 4),
            presets::homogeneous("8b-fp16", "H100", 4),
        ] {
            let report = verify(&plan);
            assert!(
                report.is_clean(),
                "{} should be clean:\n{}",
                plan.agent,
                report.table()
            );
            assert_eq!(report.passes.len(), PASSES.len());
            ensure_loadable(&plan).unwrap();
        }
    }

    #[test]
    fn topology_pass_catches_dangling_forward_and_disconnected() {
        let mut p = tiny_plan();
        p.bindings[1].deps = vec![9];
        assert!(codes(&p).contains(&"AH001".to_string()));

        let mut p = tiny_plan();
        p.bindings[1].deps = vec![1];
        assert!(codes(&p).contains(&"AH002".to_string()));

        let mut p = tiny_plan();
        p.bindings[3].deps = vec![2, 5];
        assert!(codes(&p).contains(&"AH001".to_string()));

        // Disconnect io.output: no deps and nothing depends on it.
        let mut p = tiny_plan();
        p.bindings[3].deps = vec![];
        assert!(codes(&p).contains(&"AH003".to_string()));
    }

    #[test]
    fn binding_pass_catches_splits_bounds_and_groups() {
        let mut p = presets::mixed_generation("8b-fp16", "H100", "A100", 2, 2);
        p.bindings[2].token_fraction = 0.9; // siblings now sum to 1.4
        assert!(codes(&p).contains(&"AH010".to_string()));

        let mut p = tiny_plan();
        p.bindings[2].prefix_overlap = 1.5;
        assert!(codes(&p).contains(&"AH011".to_string()));

        let mut p = tiny_plan();
        p.pipelines[1].replicas = 0;
        assert!(codes(&p).contains(&"AH012".to_string()));

        let mut p = tiny_plan();
        p.pipelines.retain(|g| g.role != Role::Decode);
        assert!(codes(&p).contains(&"AH013".to_string()));

        let mut p = tiny_plan();
        p.pipelines[0].device = "TPUv9".into();
        let c = codes(&p);
        assert!(c.contains(&"AH014".to_string()));
        assert!(c.contains(&"AH013".to_string()), "prefill binding stranded");

        let mut p = tiny_plan();
        p.bindings[2].token_fraction = 0.0;
        assert!(codes(&p).contains(&"AH015".to_string()));

        let mut p = tiny_plan();
        let dup = p.pipelines[1].clone();
        p.pipelines.push(dup);
        assert!(codes(&p).contains(&"AH016".to_string()));

        let mut p = tiny_plan();
        p.pipelines.push(super::super::PipelineBinding {
            role: Role::Decode,
            device: "B200".into(),
            tp: 1,
            pp: 1,
            max_batch: 8,
            replicas: 1,
            chassis: 3,
        });
        assert!(codes(&p).contains(&"AH017".to_string()));
    }

    #[test]
    fn fanout_replicas_are_not_a_split() {
        // shared_prefix_fanout's worker prefills share (op, stage,
        // deps) with token_fraction 1.0 each — fan-out, not an expert
        // split; their sum must NOT be flagged.
        let p = presets::shared_prefix_fanout("8b-fp16", "H100", 4);
        assert!(!codes(&p).contains(&"AH010".to_string()));
    }

    #[test]
    fn capacity_pass_catches_hbm_overflow_and_over_admission() {
        // 70B FP16 weights (~141 GB) cannot fit one A40 (48 GB).
        let p = presets::homogeneous("70b-fp16", "A40", 2);
        let report = verify(&p);
        assert!(
            report.diags.iter().any(|d| d.code == "AH020"),
            "{}",
            report.table()
        );
        assert!(report.has_errors());
        assert!(ensure_loadable(&p).is_err());

        // Sharding the weights across tp recovers feasibility.
        let mut p = presets::homogeneous("70b-fp16", "A40", 2);
        for g in &mut p.pipelines {
            g.tp = 8;
        }
        assert!(!codes(&p).contains(&"AH020".to_string()));

        let mut p = tiny_plan();
        p.admission.rate = 1e7;
        assert!(codes(&p).contains(&"AH021".to_string()));
    }

    #[test]
    fn fabric_pass_catches_missing_link_oversubscription_and_gaps() {
        // tiny_plan's prefill (chassis 0) and decode (chassis 1-2) are
        // disjoint: the KV handoff needs the scale-out link.
        let mut p = tiny_plan();
        p.fabric.scaleout_gbit = 0.0;
        let report = verify(&p);
        assert!(
            report.diags.iter().any(|d| d.code == "AH030"),
            "{}",
            report.table()
        );
        assert!(report.has_errors());

        // Co-locating decode with prefill removes the hop.
        let mut p = tiny_plan();
        p.fabric.scaleout_gbit = 0.0;
        p.pipelines[1].chassis = 0;
        p.pipelines[1].replicas = 1;
        assert!(!codes(&p).contains(&"AH030".to_string()));

        let mut p = tiny_plan();
        p.fabric.scaleout_gbit = 0.01; // 10 Mbit against 1e8 B/req x 1000/s
        assert!(codes(&p).contains(&"AH031".to_string()));

        let mut p = tiny_plan();
        p.pipelines[1].chassis = 7; // strands chassis 1..7
        assert!(codes(&p).contains(&"AH032".to_string()));
    }

    #[test]
    fn sla_pass_warns_on_infeasible_target() {
        let mut p = tiny_plan();
        p.sla = super::super::SlaSpec::EndToEnd(0.1); // path is ~0.551s
        let report = verify(&p);
        let d = report
            .diags
            .iter()
            .find(|d| d.code == "AH040")
            .expect("AH040 must fire");
        assert_eq!(d.severity, Severity::Warn);
        assert!(d.message.contains("decode 0.500s"), "{}", d.message);
        assert!(!report.has_errors(), "SLA feasibility is advisory");
        ensure_loadable(&p).unwrap();

        let mut p = tiny_plan();
        p.sla = super::super::SlaSpec::Soft {
            t_sla_s: 0.1,
            lambda: 1.0,
        };
        assert!(codes(&p).contains(&"AH040".to_string()));
    }

    #[test]
    fn replan_pass_is_the_rejection_source_of_truth() {
        let current = tiny_plan();
        let mut fresh = tiny_plan();
        fresh.pipelines[1].device = "H100".into();
        fresh.bindings[2].class = "H100".into();
        let diags = verify_replan(&current, &fresh);
        assert_eq!(diags.len(), 1);
        assert_eq!(diags[0].role, Role::Decode);
        assert_eq!(diags[0].group.as_deref(), Some("decode Gaudi3 tp1 pp1 b32"));
        assert_eq!(diags[0].diag.code, "AH050");
        assert!(diags[0].diag.message.contains("Gaudi3"));
        assert!(verify_replan(&current, &current.clone()).is_empty());
    }
}
