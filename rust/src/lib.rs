//! # agentic-hetero
//!
//! A serving framework for *agentic AI workloads* over *heterogeneous
//! hardware*, reproducing "Efficient and Scalable Agentic AI with
//! Heterogeneous Systems" (Asgar, Nguyen, Katti; 2025).
//!
//! The stack has three layers (see `DESIGN.md`):
//!
//! * **L3 (this crate)** — the paper's coordination contribution: an
//!   agent-graph IR with decomposition passes ([`ir`]), an analytic
//!   cost/roofline/TCO model ([`cost`]), a cost-aware MILP/LP assignment
//!   optimizer ([`opt`]), a slow-path planner ([`planner`]), a fast-path
//!   router + continuous batcher ([`router`]), a paged KV-cache manager
//!   ([`kvcache`]), an RDMA-fabric model ([`transport`]), a heterogeneous
//!   cluster discrete-event simulator ([`cluster`]), and a serving loop
//!   ([`server`]).
//! * **L2 (python/compile/model.py)** — a tiny-LLaMA JAX model AOT-lowered
//!   to HLO text artifacts at build time.
//! * **L1 (python/compile/kernels/)** — the Pallas flash-attention kernel
//!   those graphs call.
//!
//! The [`runtime`] module loads the AOT artifacts through the PJRT C API
//! (`xla` crate) and executes them on the request path — Python is never
//! invoked at serving time.

pub mod agents;
pub mod cluster;
pub mod config;
pub mod cost;
pub mod ir;
pub mod kvcache;
pub mod obs;
pub mod opt;
pub mod planner;
pub mod repro;
pub mod router;
pub mod runtime;
pub mod server;
pub mod transport;
pub mod util;

/// Crate-wide error type.
#[derive(Debug, thiserror::Error)]
pub enum Error {
    #[error("ir error: {0}")]
    Ir(String),
    #[error("parse error at line {line}: {msg}")]
    Parse { line: usize, msg: String },
    #[error("verification failed: {0}")]
    Verify(String),
    #[error("optimizer error: {0}")]
    Opt(String),
    #[error("infeasible: {0}")]
    Infeasible(String),
    #[error("runtime error: {0}")]
    Runtime(String),
    #[error("config error: {0}")]
    Config(String),
    #[error("capacity exceeded: {0}")]
    Capacity(String),
    #[error("io error: {0}")]
    Io(#[from] std::io::Error),
    #[error("xla error: {0}")]
    Xla(String),
}

impl From<xla::Error> for Error {
    fn from(e: xla::Error) -> Self {
        Error::Xla(e.to_string())
    }
}

pub type Result<T> = std::result::Result<T, Error>;
