//! # agentic-hetero
//!
//! A serving framework for *agentic AI workloads* over *heterogeneous
//! hardware*, reproducing "Efficient and Scalable Agentic AI with
//! Heterogeneous Systems" (Asgar, Nguyen, Katti; 2025).
//!
//! The stack has three layers (see `DESIGN.md`):
//!
//! * **L3 (this crate)** — the paper's coordination contribution: an
//!   agent-graph IR with decomposition passes ([`ir`]), an analytic
//!   cost/roofline/TCO model ([`cost`]), a cost-aware MILP/LP assignment
//!   optimizer ([`opt`]), a slow-path planner ([`planner`]), a closed-loop
//!   orchestrator that re-plans, diffs, and live-migrates running fleets
//!   ([`orchestrator`]), a fast-path router + continuous batcher
//!   ([`router`]), a paged KV-cache manager
//!   ([`kvcache`]), an RDMA-fabric model ([`transport`]), a heterogeneous
//!   cluster discrete-event simulator ([`cluster`]), and a serving loop
//!   ([`server`]).
//! * **L2 (python/compile/model.py)** — a tiny-LLaMA JAX model AOT-lowered
//!   to HLO text artifacts at build time.
//! * **L1 (python/compile/kernels/)** — the Pallas flash-attention kernel
//!   those graphs call.
//!
//! The [`runtime`] module loads the AOT artifacts through the PJRT C API
//! (`xla` crate) and executes them on the request path — Python is never
//! invoked at serving time.

pub mod agents;
pub mod cluster;
pub mod config;
pub mod cost;
pub mod ir;
pub mod kvcache;
pub mod obs;
pub mod opt;
pub mod orchestrator;
pub mod plan;
pub mod planner;
pub mod repro;
pub mod router;
pub mod runtime;
pub mod server;
pub mod transport;
pub mod util;

/// Crate-wide error type (hand-rolled Display/Error — the offline
/// registry has no thiserror).
#[derive(Debug)]
pub enum Error {
    Ir(String),
    Parse { line: usize, msg: String },
    Verify(String),
    Opt(String),
    Infeasible(String),
    Runtime(String),
    Config(String),
    Capacity(String),
    Io(std::io::Error),
    Xla(String),
}

impl std::fmt::Display for Error {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Error::Ir(m) => write!(f, "ir error: {m}"),
            Error::Parse { line, msg } => {
                write!(f, "parse error at line {line}: {msg}")
            }
            Error::Verify(m) => write!(f, "verification failed: {m}"),
            Error::Opt(m) => write!(f, "optimizer error: {m}"),
            Error::Infeasible(m) => write!(f, "infeasible: {m}"),
            Error::Runtime(m) => write!(f, "runtime error: {m}"),
            Error::Config(m) => write!(f, "config error: {m}"),
            Error::Capacity(m) => write!(f, "capacity exceeded: {m}"),
            Error::Io(e) => write!(f, "io error: {e}"),
            Error::Xla(m) => write!(f, "xla error: {m}"),
        }
    }
}

impl std::error::Error for Error {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            Error::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Self {
        Error::Io(e)
    }
}

#[cfg(feature = "pjrt")]
impl From<xla::Error> for Error {
    fn from(e: xla::Error) -> Self {
        Error::Xla(e.to_string())
    }
}

pub type Result<T> = std::result::Result<T, Error>;
