//! `agentic-hetero` — leader entrypoint.
//!
//! ```text
//! agentic-hetero repro <id|all> [--json] [--out FILE]   regenerate paper tables/figures
//! agentic-hetero plan  [--agent voice|rag|langchain] [--model 8b-fp16] [--sla-ms N]
//!                      [--out PLAN.json]                emit an ExecutionPlan
//! agentic-hetero ir    [--agent ...] [--raw]            print (lowered) agent IR
//! agentic-hetero serve [--config FILE] [--plan PLAN.json] [--requests N] [--max-new N]
//! agentic-hetero simulate [--plan PLAN.json | --prefill H100 --decode Gaudi3]
//!                        [--model 8b-fp16] [--rate R] [--requests N]
//! agentic-hetero trace-report TRACE.json                print SLA attribution of a trace
//! agentic-hetero help
//! ```
//!
//! `serve`, `simulate --plan`, and `orchestrate` all accept
//! `--trace-out FILE`: span tracing is enabled for the run and the
//! spans are written as Chrome trace-event JSON (Perfetto /
//! `chrome://tracing` loadable). `trace-report` re-reads such a file
//! and prints the critical-path SLA attribution table.

use agentic_hetero::agents;
use agentic_hetero::cluster::arrivals::{
    ArrivalProcess, Diurnal, FlashCrowd, Poisson, Replay, SquareWave, VoiceAgent,
};
use agentic_hetero::cluster::sim::{pair_placement, simulate_plan, ClusterSim};
use agentic_hetero::cluster::trace::TraceConfig;
use agentic_hetero::config::DeployConfig;
use agentic_hetero::cost::hardware::by_name;
use agentic_hetero::cost::model_profile::by_short_name;
use agentic_hetero::cost::roofline::Parallelism;
use agentic_hetero::ir::passes::PassManager;
use agentic_hetero::ir::printer;
use agentic_hetero::obs::critical_path::attribute_all;
use agentic_hetero::obs::trace::{
    spans_from_chrome_json, to_chrome_json_string, TraceSink,
};
use agentic_hetero::opt::assignment::Sla;
use agentic_hetero::orchestrator::{
    chat_request_of, Executor, Orchestrator, OrchestratorConfig, SimExecutor,
};
use agentic_hetero::plan::{presets, verify, ExecutionPlan, PlanDiff};
use agentic_hetero::planner::plan::{Planner, PlannerConfig};
use agentic_hetero::runtime::Engine;
use agentic_hetero::server::{ChatRequest, Server, ServerConfig};
use agentic_hetero::transport::fabric::Fabric;
use agentic_hetero::util::cli::Args;
use agentic_hetero::util::json::Json;

/// `args.get_parsed` with CLI error handling (exit code 2).
macro_rules! parse_opt {
    ($args:expr, $name:expr, $default:expr) => {
        match $args.get_parsed($name, $default) {
            Ok(v) => v,
            Err(e) => {
                eprintln!("{e}");
                return 2;
            }
        }
    };
}

fn main() {
    let args = Args::from_env();
    let cmd = args.positional.first().map(|s| s.as_str()).unwrap_or("help");
    let code = match cmd {
        "repro" => cmd_repro(&args),
        "plan" => cmd_plan(&args),
        "ir" => cmd_ir(&args),
        "serve" => cmd_serve(&args),
        "simulate" => cmd_simulate(&args),
        "orchestrate" => cmd_orchestrate(&args),
        "trace-report" => cmd_trace_report(&args),
        "help" | "--help" | "-h" => {
            print!("{}", HELP);
            0
        }
        other => {
            eprintln!("unknown command `{other}`\n{HELP}");
            2
        }
    };
    std::process::exit(code);
}

const HELP: &str = "\
agentic-hetero — agentic AI serving over heterogeneous systems

USAGE:
  agentic-hetero repro <all|fig3|fig4|fig7|fig8|fig9|table1|table3|table4|table5|bandwidth>
                 [--json] [--out FILE]
  agentic-hetero plan     [--agent voice|rag|langchain] [--model 8b-fp16] [--sla-ms N]
                          [--out PLAN.json]
  agentic-hetero plan diff A.json B.json [--json]
  agentic-hetero plan lint <PLAN.json | --presets> [--json] [--deny-warn]
  agentic-hetero ir       [--agent voice|rag|langchain] [--model 8b-fp16] [--raw]
  agentic-hetero serve    [--config FILE] [--artifacts DIR] [--plan PLAN.json]
                          [--requests N] [--max-new N] [--synthetic]
                          [--arrivals poisson|diurnal|flash|replay] [--rate R] [--seed S]
                          [--trace-out TRACE.json]
  agentic-hetero simulate [--plan PLAN.json | --prefill H100 --decode Gaudi3 --model 8b-fp16]
                          [--rate R] [--requests N] [--voice] [--seed S]
                          [--arrivals poisson|diurnal|flash|replay] [--amp A] [--period S]
                          [--spike-every S] [--spike-dur S] [--spike-mult M]
                          [--trace-out TRACE.json]
  agentic-hetero orchestrate [--plan PLAN.json | --agent voice | --fleet mixed]
                          [--trace bursty|steady|voice] [--old A100] [--new H100]
                          [--arrivals poisson|diurnal|flash|replay] [--seed S]
                          [--rate R] [--requests N] [--window S] [--config FILE]
                          [--out TIMELINE.json] [--trace-out TRACE.json]
  agentic-hetero trace-report TRACE.json

The `plan` command emits a serializable ExecutionPlan; `simulate --plan`
replays it through the agent-DAG cluster simulator, `serve --plan`
executes the *full agent DAG* live (tool/IO stages on a bounded host
pool, LLM stages batched on the engine; `--synthetic` runs the
in-process byte LM so no artifacts are needed), `plan diff` renders the
typed PlanDiff between two saved plans, `plan lint` runs the static
plan analyzer (topology, binding invariants, capacity, fabric, SLA
feasibility — the AH0xx diagnostics the loader and orchestrator
enforce) over a saved plan or the built-in presets, and `orchestrate`
runs the
closed control loop (observe -> decide -> re-plan -> diff -> migrate ->
apply) against a traced load swing, emitting a replayable timeline.
`orchestrate --fleet mixed` serves a two-generation fleet (decode split
across --new and --old hardware), rebalances load between the
generations group-by-group, and closes with the paper's TCO comparison
against the newest-homogeneous fleet of equal decode capacity.

`--arrivals` (on serve, simulate, orchestrate) switches ingestion to a
pull-based streaming arrival process — requests are generated lazily as
simulated time advances, so memory stays constant at any `--requests`
count (a 1M-request diurnal day fits in a laptop's RAM). `poisson` is a
homogeneous process at --rate; `diurnal` modulates the rate
sinusoidally (--amp 0..1, --period seconds, default one 24 h day);
`flash` layers periodic spikes on the baseline (--spike-every,
--spike-dur, --spike-mult); `replay` streams the legacy materialized
trace. All processes are deterministic under --seed. `--plan` also
accepts a built-in preset by name instead of a file:
presets/mixed_generation, presets/shared_prefix_fanout,
presets/homogeneous.

`--trace-out FILE` (on serve, simulate --plan, orchestrate) records
every request's spans — host/tool stages, prefill, decode, KV
transfers, the request envelope — and writes Chrome trace-event JSON
loadable in Perfetto. `trace-report FILE` replays such a trace through
the critical-path analyzer and prints the per-group SLA attribution
table (queue / prefill / decode / kv_transfer / host / tool_io).
";

/// Write a recorded trace as Chrome trace-event JSON (the streaming
/// serializer — one event tree in memory at a time, so large traces
/// don't double their footprint on export). Returns `false` (after
/// printing the error) when the file cannot be written.
fn write_trace_file(sink: &TraceSink, path: &str) -> bool {
    let spans = sink.spans();
    match std::fs::write(path, to_chrome_json_string(&spans)) {
        Ok(()) => {
            eprintln!("wrote {path} ({} spans)", spans.len());
            true
        }
        Err(e) => {
            eprintln!("write {path}: {e}");
            false
        }
    }
}

/// `trace-report TRACE.json` — re-read a `--trace-out` file (from
/// either backend; the span schema is shared) and print the
/// critical-path SLA attribution table.
fn cmd_trace_report(args: &Args) -> i32 {
    let Some(path) = args.positional.get(1) else {
        eprintln!("usage: agentic-hetero trace-report TRACE.json");
        return 2;
    };
    let src = match std::fs::read_to_string(path) {
        Ok(s) => s,
        Err(e) => {
            eprintln!("trace {path}: {e}");
            return 1;
        }
    };
    let doc = match Json::parse(&src) {
        Ok(d) => d,
        Err(e) => {
            eprintln!("trace {path}: {e}");
            return 1;
        }
    };
    let spans = match spans_from_chrome_json(&doc) {
        Ok(s) => s,
        Err(e) => {
            eprintln!("trace {path}: {e}");
            return 1;
        }
    };
    if spans.is_empty() {
        println!("{path}: no spans recorded");
        return 0;
    }
    print!("{}", attribute_all(&spans).table());
    0
}

fn cmd_repro(args: &Args) -> i32 {
    let which = args.positional.get(1).map(|s| s.as_str()).unwrap_or("all");
    let arts = if which == "all" {
        agentic_hetero::repro::all()
    } else {
        match agentic_hetero::repro::by_id(which) {
            Some(a) => vec![a],
            None => {
                eprintln!("unknown artifact `{which}` (try `repro all`)");
                return 2;
            }
        }
    };
    let as_json = args.flag("json");
    let mut out = String::new();
    if as_json {
        let mut m = std::collections::BTreeMap::new();
        for a in &arts {
            m.insert(a.id.to_string(), a.json.clone());
        }
        out = Json::Obj(m).pretty();
    } else {
        for a in &arts {
            out.push_str(&format!("\n=== {} ===\n{}\n", a.title, a.text));
        }
    }
    match args.get("out") {
        Some(path) => {
            if let Err(e) = std::fs::write(path, &out) {
                eprintln!("write {path}: {e}");
                return 1;
            }
            println!("wrote {path}");
        }
        None => print!("{out}"),
    }
    0
}

/// Load a saved ExecutionPlan from disk (shared by `serve`,
/// `simulate`, and `orchestrate`); the error string carries the path
/// context. A `presets/<name>` path resolves one of the built-in
/// preset plans instead of reading a file, so streaming stress runs
/// need no JSON artifact on disk.
fn load_plan(path: &str) -> Result<ExecutionPlan, String> {
    if let Some(name) = path.strip_prefix("presets/") {
        return match name {
            "mixed_generation" => {
                Ok(presets::mixed_generation("8b-fp16", "H100", "A100", 2, 2))
            }
            "shared_prefix_fanout" => {
                Ok(presets::shared_prefix_fanout("8b-fp16", "H100", 4))
            }
            "homogeneous" => Ok(presets::homogeneous("8b-fp16", "H100", 4)),
            other => Err(format!(
                "plan presets/{other}: unknown preset (mixed_generation, \
                 shared_prefix_fanout, homogeneous)"
            )),
        };
    }
    let src =
        std::fs::read_to_string(path).map_err(|e| format!("plan {path}: {e}"))?;
    ExecutionPlan::parse_json(&src).map_err(|e| format!("plan {path}: {e}"))
}

/// Build the streaming arrival process selected by `--arrivals`.
/// `Ok(None)` when the flag is absent — callers keep their legacy
/// materialized-trace path byte-for-byte. Every process is seeded from
/// `tc.seed`, so calling this twice yields two identical streams (the
/// orchestrate TCO comparison runs re-pull the same workload).
fn arrivals_of(
    args: &Args,
    tc: &TraceConfig,
) -> Result<Option<Box<dyn ArrivalProcess>>, String> {
    let Some(kind) = args.get("arrivals") else {
        return Ok(None);
    };
    let ctx = |e: agentic_hetero::Error| format!("--arrivals {kind}: {e}");
    let src: Box<dyn ArrivalProcess> = match kind {
        "poisson" => Box::new(Poisson::new(tc).map_err(ctx)?),
        "diurnal" => {
            let amp: f64 = args.get_parsed("amp", 0.5).map_err(|e| e.to_string())?;
            let period: f64 = args
                .get_parsed("period", Diurnal::DAY_S)
                .map_err(|e| e.to_string())?;
            Box::new(Diurnal::new(tc, amp, period, 0.0).map_err(ctx)?)
        }
        "flash" => {
            let every: f64 = args
                .get_parsed("spike-every", 300.0)
                .map_err(|e| e.to_string())?;
            let dur: f64 = args
                .get_parsed("spike-dur", 30.0)
                .map_err(|e| e.to_string())?;
            let mult: f64 = args
                .get_parsed("spike-mult", 5.0)
                .map_err(|e| e.to_string())?;
            Box::new(FlashCrowd::periodic(tc, every, dur, mult).map_err(ctx)?)
        }
        "replay" => Box::new(Replay::from_vec(Poisson::new(tc).map_err(ctx)?.collect())),
        other => {
            return Err(format!(
                "unknown --arrivals `{other}` (poisson, diurnal, flash, replay)"
            ))
        }
    };
    Ok(Some(src))
}

fn build_agent(args: &Args) -> agentic_hetero::ir::Graph {
    let model = args.get_or("model", "8b-fp16");
    if by_short_name(model).is_none() {
        eprintln!("warning: model `{model}` not in Table 4; cost estimates degrade");
    }
    match args.get_or("agent", "voice") {
        "rag" => agents::rag_agent(model, 2048, 256, 8),
        "langchain" => agents::langchain_style_agent(model),
        _ => agents::voice_agent(model, 512, 256),
    }
}

/// `plan diff A.json B.json [--json]` — render the typed PlanDiff
/// between two saved plans (the artifact review step before
/// orchestration applies a change).
fn cmd_plan_diff(args: &Args) -> i32 {
    let (Some(a), Some(b)) = (args.positional.get(2), args.positional.get(3)) else {
        eprintln!("usage: agentic-hetero plan diff A.json B.json [--json]");
        return 2;
    };
    let (pa, pb) = match (load_plan(a), load_plan(b)) {
        (Ok(pa), Ok(pb)) => (pa, pb),
        (Err(e), _) | (_, Err(e)) => {
            eprintln!("{e}");
            return 1;
        }
    };
    let diff = PlanDiff::between(&pa, &pb);
    if args.flag("json") {
        println!("{}", diff.to_json().pretty());
    } else {
        print!("{}", diff.summary());
    }
    0
}

/// `plan lint PLAN.json [--json] [--deny-warn]` — run the static plan
/// analyzer and print the diagnostics table (or the report JSON).
/// `--presets` lints the built-in preset plans instead of a file (the
/// CI gate: shipped presets must verify clean). Exit code 1 when any
/// Error is found, or any Warn under `--deny-warn`.
fn cmd_plan_lint(args: &Args) -> i32 {
    let deny_warn = args.flag("deny-warn");
    let verdict = |name: &str, report: &agentic_hetero::plan::DiagReport| -> i32 {
        if args.flag("json") {
            println!("{}", report.to_json().pretty());
        } else {
            print!("{name}: {}", report.table());
        }
        if report.has_errors() || (deny_warn && report.warnings().next().is_some()) {
            1
        } else {
            0
        }
    };
    if args.flag("presets") {
        let presets: Vec<(&str, ExecutionPlan)> = vec![
            ("mixed_generation", presets::mixed_generation("8b-fp16", "H100", "A100", 2, 2)),
            ("shared_prefix_fanout", presets::shared_prefix_fanout("8b-fp16", "H100", 4)),
            ("homogeneous", presets::homogeneous("8b-fp16", "H100", 2)),
        ];
        let mut code = 0;
        for (name, plan) in &presets {
            code = code.max(verdict(name, &verify::verify(plan)));
        }
        return code;
    }
    let Some(path) = args.positional.get(2) else {
        eprintln!("usage: agentic-hetero plan lint <PLAN.json | --presets> [--json] [--deny-warn]");
        return 2;
    };
    // Lenient load: structural `validate()` errors must not mask the
    // analyzer — a broken plan is exactly what lint exists to explain.
    let src = match std::fs::read_to_string(path) {
        Ok(s) => s,
        Err(e) => {
            eprintln!("plan {path}: {e}");
            return 1;
        }
    };
    let plan = match ExecutionPlan::parse_json_lenient(&src) {
        Ok(p) => p,
        Err(e) => {
            eprintln!("plan {path}: {e}");
            return 1;
        }
    };
    verdict(path, &verify::verify(&plan))
}

fn cmd_plan(args: &Args) -> i32 {
    if args.positional.get(1).map(|s| s.as_str()) == Some("diff") {
        return cmd_plan_diff(args);
    }
    if args.positional.get(1).map(|s| s.as_str()) == Some("lint") {
        return cmd_plan_lint(args);
    }
    let g = build_agent(args);
    let mut cfg = PlannerConfig::default();
    let sla_ms: f64 = parse_opt!(args, "sla-ms", 5000.0);
    cfg.sla = if sla_ms <= 0.0 {
        Sla::None
    } else {
        Sla::EndToEnd(sla_ms / 1e3)
    };
    let planner = Planner::new(cfg);
    match planner.plan(&g) {
        Ok(plan) => {
            println!("plan for @{} (SLA {:.0} ms):", g.name, sla_ms);
            for (op, class) in plan.placements() {
                println!("  {op:<22} -> {class}");
            }
            println!(
                "cost ${:.6}/request   critical path {:.1} ms",
                plan.cost_usd,
                plan.latency_s * 1e3
            );
            println!("{}", plan.summary());
            // `--out plan.json`: persist the ExecutionPlan for
            // `simulate --plan` / `serve --plan` replay.
            if let Some(path) = args.get("out") {
                if let Err(e) = std::fs::write(path, plan.to_json_string()) {
                    eprintln!("write {path}: {e}");
                    return 1;
                }
                println!("wrote {path}");
            }
            0
        }
        Err(e) => {
            eprintln!("planning failed: {e}");
            1
        }
    }
}

fn cmd_ir(args: &Args) -> i32 {
    let mut g = build_agent(args);
    if !args.flag("raw") {
        let mut pm = PassManager::standard();
        if let Err(e) = pm.run(&mut g) {
            eprintln!("pass pipeline failed: {e}");
            return 1;
        }
        for (name, changed) in &pm.log {
            eprintln!("pass {name}: {}", if *changed { "changed" } else { "no-op" });
        }
    }
    print!("{}", printer::print(&g));
    0
}

/// `--synthetic`: a pool of deterministic in-process byte LMs — one
/// engine per plan pipeline group, so LLM stages schedule onto the
/// engine their role's group is bound to (non-pjrt builds only; the
/// real engine always executes compiled artifacts).
#[cfg(not(feature = "pjrt"))]
fn synthetic_engines(n: usize) -> Option<Vec<std::sync::Arc<Engine>>> {
    Some(Engine::synthetic_pool(n))
}

#[cfg(feature = "pjrt")]
fn synthetic_engines(_n: usize) -> Option<Vec<std::sync::Arc<Engine>>> {
    None
}

fn cmd_serve(args: &Args) -> i32 {
    let cfg = match args.get("config") {
        Some(path) => match DeployConfig::from_file(path) {
            Ok(c) => c,
            Err(e) => {
                eprintln!("config {path}: {e}");
                return 1;
            }
        },
        None => DeployConfig::default(),
    };
    let artifacts = args.get_or("artifacts", &cfg.artifacts_dir).to_string();
    let n: usize = parse_opt!(args, "requests", 16usize);
    let max_new: usize = parse_opt!(args, "max-new", cfg.max_new_tokens as usize);
    let seed: u64 = parse_opt!(args, "seed", 0u64);
    let rate: f64 = parse_opt!(args, "rate", 8.0);
    // `--arrivals`: synthesize the workload from a streaming arrival
    // process (request IDs and lengths deterministic under --seed)
    // instead of the four rotating demo prompts. Validated up front,
    // before the expensive engine load.
    let serve_tc = TraceConfig {
        n_requests: n,
        rate,
        isl_mean: 48,
        osl_mean: (max_new as u64).max(1),
        sigma: 0.4,
        seed,
    };
    let arrivals = match arrivals_of(args, &serve_tc) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("{e}");
            return 2;
        }
    };

    // `--plan FILE` (or `[server] plan = ...` in the config): the saved
    // ExecutionPlan configures batching/admission *and* installs full
    // agent-DAG execution — requests carry the plan's agent class and
    // traverse every node binding (tool/IO stages on the host pool).
    let plan_path = args
        .get("plan")
        .map(|s| s.to_string())
        .or_else(|| cfg.plan_path.clone());
    let plan = match &plan_path {
        Some(path) => match load_plan(path) {
            Ok(plan) => {
                eprintln!("serving with {}", plan.summary());
                Some(plan)
            }
            Err(e) => {
                eprintln!("{e}");
                return 1;
            }
        },
        None => None,
    };

    let engines = if args.flag("synthetic") {
        // One engine per pipeline group of the plan (1 for flat serving).
        let pool_n = plan.as_ref().map(|p| p.pipelines.len()).unwrap_or(1).max(1);
        match synthetic_engines(pool_n) {
            Some(e) => {
                eprintln!("using {} synthetic in-process engine(s)", e.len());
                e
            }
            None => {
                eprintln!("--synthetic is only available in non-pjrt builds");
                return 2;
            }
        }
    } else {
        eprintln!("loading engine from {artifacts}/ ...");
        match Engine::load(&artifacts) {
            Ok(e) => vec![std::sync::Arc::new(e)],
            Err(e) => {
                eprintln!("engine: {e}");
                return 1;
            }
        }
    };
    eprintln!(
        "{} engine(s) up on {} ({} params, buckets {:?})",
        engines.len(),
        engines[0].platform(),
        engines[0].manifest.num_params,
        engines[0].manifest.buckets
    );
    let (mut server, agent) = match &plan {
        Some(p) => {
            let mut s = match Server::with_engines(engines, ServerConfig::from_plan(p)) {
                Ok(s) => s,
                Err(e) => {
                    eprintln!("server: {e}");
                    return 1;
                }
            };
            match s.install_plan(p) {
                Ok(()) => {
                    eprintln!(
                        "agent-DAG execution installed: {} nodes, host pool {} workers",
                        p.bindings.len(),
                        s.host_capacity().unwrap_or(0)
                    );
                    let agent = Some(p.agent.clone());
                    (s, agent)
                }
                // A plan whose DAG cannot execute live (e.g. model not
                // in the profile catalog) still configures serving
                // policy — the pre-DAG behavior: flat requests only.
                Err(e) => {
                    eprintln!(
                        "plan install: {e}; serving flat requests with the plan's policy"
                    );
                    (s, None)
                }
            }
        }
        None => (
            match Server::with_engines(engines, ServerConfig::default()) {
                Ok(s) => s,
                Err(e) => {
                    eprintln!("server: {e}");
                    return 1;
                }
            },
            None,
        ),
    };
    // `--trace-out FILE`: record spans during the run (agent-DAG
    // requests only — flat serving has no DAG dispatcher to trace) and
    // export them as Chrome trace-event JSON afterwards.
    let trace_out = args.get("trace-out");
    let trace_sink = trace_out.map(|_| TraceSink::new());
    if let Some(sink) = &trace_sink {
        server.set_trace_sink(std::sync::Arc::clone(sink));
    }
    let prompts = [
        "the paper describes ",
        "heterogeneous systems ",
        "the cost model ",
        "agentic workloads are ",
    ];
    let reqs: Vec<ChatRequest> = match arrivals {
        Some(src) => src
            .map(|r| {
                let mut c = chat_request_of(&r);
                c.max_new_tokens = c.max_new_tokens.min(max_new.max(1));
                c.agent = agent.clone();
                c
            })
            .collect(),
        None => (0..n as u64)
            .map(|i| {
                let mut r =
                    ChatRequest::new(i, prompts[(i as usize) % prompts.len()], max_new);
                r.agent = agent.clone();
                r
            })
            .collect(),
    };
    let t0 = std::time::Instant::now();
    match server.run_workload(reqs) {
        Ok(responses) => {
            let wall = t0.elapsed().as_secs_f64();
            let tokens: usize = responses.iter().map(|r| r.tokens).sum();
            for r in responses.iter().take(4) {
                println!("#{}: {:?}", r.id, r.text());
            }
            if let Some(r) = responses.iter().find(|r| !r.stages.is_empty()) {
                println!("\nstage trace of request #{}:", r.id);
                for s in &r.stages {
                    println!(
                        "  {:<22} {:<11} {:>8.2}ms -> {:>8.2}ms",
                        s.op,
                        s.role,
                        s.start_s * 1e3,
                        s.end_s * 1e3
                    );
                }
            }
            println!(
                "\n{} requests, {} tokens in {:.2}s -> {:.0} tok/s",
                responses.len(),
                tokens,
                wall,
                tokens as f64 / wall
            );
            let (pre, dec, host) = server.take_utilization(wall);
            println!(
                "measured utilization: prefill {:.1}% decode {:.1}% host {:.1}%",
                pre * 100.0,
                dec * 100.0,
                host * 100.0
            );
            println!("\nmetrics:\n{}", server.metrics.report());
            if let (Some(sink), Some(path)) = (&trace_sink, trace_out) {
                if !write_trace_file(sink, path) {
                    return 1;
                }
            }
            0
        }
        Err(e) => {
            eprintln!("serve: {e}");
            1
        }
    }
}

fn cmd_simulate(args: &Args) -> i32 {
    let rate: f64 = parse_opt!(args, "rate", 8.0);
    let n: usize = parse_opt!(args, "requests", 256usize);
    let seed: u64 = parse_opt!(args, "seed", 0u64);

    // `--plan FILE`: replay a saved ExecutionPlan through the agent-DAG
    // simulator instead of a hand-assembled pair placement.
    if let Some(path) = args.get("plan") {
        let plan = match load_plan(path) {
            Ok(p) => p,
            Err(e) => {
                eprintln!("{e}");
                return 1;
            }
        };
        let tc = TraceConfig {
            n_requests: n,
            rate,
            isl_mean: 512,
            osl_mean: 128,
            sigma: 0.4,
            seed,
        };
        let arrivals = match arrivals_of(args, &tc) {
            Ok(a) => a,
            Err(e) => {
                eprintln!("{e}");
                return 2;
            }
        };
        let streaming = arrivals.is_some();
        // Inline DagSim (rather than `simulate_plan`) so `--trace-out`
        // can attach a span sink before the run.
        let trace_out = args.get("trace-out");
        let trace_sink = trace_out.map(|_| TraceSink::new());
        let t0 = std::time::Instant::now();
        let report = agentic_hetero::cluster::dag::DagSim::new(&plan).and_then(|mut sim| {
            if let Some(sink) = &trace_sink {
                sim.set_trace_sink(std::sync::Arc::clone(sink));
            }
            match arrivals {
                // Streaming ingestion: arrivals are pulled lazily as
                // simulated time advances — the trace is never
                // materialized, so memory stays flat at any --requests.
                Some(mut src) => sim.run_stream(src.as_mut()),
                // Default path: the materialized trace, built from the
                // streaming twins of the legacy generators (bit-exact,
                // golden-pinned in cluster/arrivals.rs).
                None => {
                    let trace: Vec<_> = if args.flag("voice") {
                        VoiceAgent::new(&tc)?.collect()
                    } else {
                        Poisson::new(&tc)?.collect()
                    };
                    sim.run(&trace)
                }
            }
        });
        return match report {
            Ok(report) => {
                println!("{}", plan.summary());
                println!("{}", report.summary());
                if streaming {
                    println!("sim wall: {:.2}s", t0.elapsed().as_secs_f64());
                }
                if let (Some(sink), Some(path)) = (&trace_sink, trace_out) {
                    if !write_trace_file(sink, path) {
                        return 1;
                    }
                }
                0
            }
            Err(e) => {
                eprintln!("simulate: {e}");
                1
            }
        };
    }

    if args.get("trace-out").is_some() {
        eprintln!("--trace-out requires --plan (the flat pair simulator has no span tracing)");
        return 2;
    }

    let prefill = args.get_or("prefill", "H100");
    let decode = args.get_or("decode", "Gaudi3");
    let model = args.get_or("model", "8b-fp16");

    let (Some(pd), Some(dd)) = (by_name(prefill), by_name(decode)) else {
        eprintln!("unknown device (catalog: A40 A100 Gaudi3 MI300x H100 B200)");
        return 2;
    };
    let Some(m) = by_short_name(model) else {
        eprintln!("unknown model (8b-fp16, 8b-fp8, 70b-fp16, 70b-fp8)");
        return 2;
    };

    let placement = pair_placement(
        &pd,
        Parallelism { tp: 1, pp: 1 },
        2,
        8,
        &dd,
        Parallelism { tp: 1, pp: 1 },
        2,
        32,
    );
    let fabric = Fabric::new(8, 8, pd.scaleup_bw_gbps, 400.0);
    let mut sim = ClusterSim::new(m, placement, fabric);
    let tc = TraceConfig {
        n_requests: n,
        rate,
        isl_mean: 512,
        osl_mean: 128,
        sigma: 0.4,
        seed,
    };
    // The flat pair simulator's `run` takes a slice, so a streaming
    // `--arrivals` source is materialized here; constant-memory runs
    // need the agent-DAG engine (`--plan`).
    let trace = match arrivals_of(args, &tc) {
        Ok(Some(src)) => src.collect(),
        Ok(None) => {
            let built = if args.flag("voice") {
                VoiceAgent::new(&tc).map(|p| p.collect::<Vec<_>>())
            } else {
                Poisson::new(&tc).map(|p| p.collect::<Vec<_>>())
            };
            match built {
                Ok(t) => t,
                Err(e) => {
                    eprintln!("simulate: {e}");
                    return 2;
                }
            }
        }
        Err(e) => {
            eprintln!("{e}");
            return 2;
        }
    };
    match sim.run(&trace) {
        Ok(report) => {
            println!("{prefill}::{decode} on {} @ {rate} req/s", sim.model.name);
            println!("{}", report.summary());
            0
        }
        Err(e) => {
            eprintln!("simulate: {e}");
            1
        }
    }
}

/// `orchestrate`: run the closed control loop against a traced load
/// swing in the DAG simulator, emitting a replayable timeline of plans,
/// diffs, migrations, and SLA attainment.
fn cmd_orchestrate(args: &Args) -> i32 {
    let rate: f64 = parse_opt!(args, "rate", 8.0);
    let n: usize = parse_opt!(args, "requests", 384usize);
    let seed: u64 = parse_opt!(args, "seed", 0u64);

    // Initial plan: a saved artifact (`--plan`) or a fresh slow-path
    // plan over `--agent` (which also arms planner-backed re-planning).
    let sla_ms: f64 = parse_opt!(args, "sla-ms", 5000.0);
    let sla = if sla_ms <= 0.0 {
        Sla::None
    } else {
        Sla::EndToEnd(sla_ms / 1e3)
    };
    // `--fleet mixed`: the paper's headline scenario — a two-generation
    // decode fleet (--new / --old devices) the group-granular retarget
    // rebalances, with no slow-path planner attached (structural
    // retargeting is exactly the path under test).
    let mixed_fleet = args.get_or("fleet", "") == "mixed";
    let new_dev = args.get_or("new", "H100").to_string();
    let old_dev = args.get_or("old", "A100").to_string();
    let (plan, graph) = if mixed_fleet {
        if new_dev.eq_ignore_ascii_case(&old_dev) {
            // Two groups of one device share a shape key, which folds
            // every group-granular surface (rebalance lookups, streaks,
            // per-group counters) into one entry — not a mixed fleet.
            eprintln!(
                "mixed fleet needs two distinct generations \
                 (--new {new_dev} --old {old_dev})"
            );
            return 2;
        }
        let model = args.get_or("model", "8b-fp16");
        let p = agentic_hetero::plan::presets::mixed_generation(model, &new_dev, &old_dev, 2, 2);
        if let Err(e) = p.validate() {
            eprintln!("mixed fleet: {e} (try --new H100 --old A100)");
            return 2;
        }
        (p, None)
    } else {
        match args.get("plan") {
            Some(path) => match load_plan(path) {
                Ok(p) => (p, None),
                Err(e) => {
                    eprintln!("{e}");
                    return 1;
                }
            },
            None => {
                let g = build_agent(args);
                let mut cfg = PlannerConfig::default();
                cfg.sla = sla;
                match Planner::new(cfg).plan(&g) {
                    Ok(p) => (p, Some(g)),
                    Err(e) => {
                        eprintln!("planning failed: {e}");
                        return 1;
                    }
                }
            }
        }
    };

    // Captured before the plan moves into the orchestrator: the
    // homogeneous TCO baseline sizes itself to the *final* plan's
    // decode total, falling back to the initial fleet's if the run
    // never re-planned.
    let initial_decode_total: u32 = plan
        .pipelines
        .iter()
        .filter(|g| g.role == agentic_hetero::plan::Role::Decode)
        .map(|g| g.replicas)
        .sum();

    let trace_kind = args.get_or("trace", "bursty").to_string();
    let tc = TraceConfig {
        n_requests: n,
        rate,
        isl_mean: 512,
        osl_mean: 128,
        sigma: 0.4,
        seed,
    };
    // `--arrivals` streams the workload instead of materializing it —
    // the executor pulls requests lazily, and the TCO comparison runs
    // below re-pull an identical stream (processes are deterministic
    // under --seed). Without the flag, the legacy slice path is kept
    // byte-for-byte.
    let streaming = args.get("arrivals").is_some();
    let trace = if streaming {
        Vec::new()
    } else {
        // Streaming twins of the legacy trace generators: bit-exact
        // request sequences (golden-pinned in cluster/arrivals.rs).
        let built = match trace_kind.as_str() {
            "bursty" => {
                SquareWave::compat(&tc, 8.0, 40.0, 12.0).map(|p| p.collect::<Vec<_>>())
            }
            "voice" => VoiceAgent::new(&tc).map(|p| p.collect::<Vec<_>>()),
            _ => Poisson::new(&tc).map(|p| p.collect::<Vec<_>>()),
        };
        match built {
            Ok(t) => t,
            Err(e) => {
                eprintln!("orchestrate: {e}");
                return 2;
            }
        }
    };

    // Loop knobs: `[orchestrator]` in --config, --window overrides.
    let mut ocfg = match args.get("config") {
        Some(path) => match DeployConfig::from_file(path) {
            Ok(c) => OrchestratorConfig::from_deploy(&c),
            Err(e) => {
                eprintln!("config {path}: {e}");
                return 1;
            }
        },
        None => OrchestratorConfig::default(),
    };
    // A 5 s window × patience-3 hysteresis outlasts a 12 s burst; the
    // standalone demo defaults to 2 s windows so bursts are actionable.
    // An explicit --window (or `[orchestrator] window_s`) wins.
    let default_window = if args.get("config").is_some() {
        ocfg.window_s
    } else {
        2.0
    };
    ocfg.window_s = parse_opt!(args, "window", default_window);

    // Timeline metadata records the workload: the arrival-process kind
    // when streaming, the legacy trace kind otherwise.
    let workload_label: &str = args.get("arrivals").unwrap_or(&trace_kind);
    let mut orch = match Orchestrator::new(ocfg, plan, workload_label, "sim") {
        Ok(o) => o,
        Err(e) => {
            eprintln!("orchestrate: {e}");
            return 1;
        }
    };
    if let Some(g) = graph {
        let mut cfg = PlannerConfig::default();
        cfg.sla = sla;
        orch = orch.with_planner(Planner::new(cfg), g);
    }
    let metrics = orch.metrics.clone();

    let mut exec = match arrivals_of(args, &tc) {
        Ok(Some(src)) => SimExecutor::from_stream(src),
        Ok(None) => SimExecutor::new(&trace),
        Err(e) => {
            eprintln!("{e}");
            return 2;
        }
    };
    // `--trace-out FILE`: span-trace the simulated run; window
    // attribution lands in the timeline and `orch_attr_*` gauges.
    let trace_out = args.get("trace-out");
    let trace_sink = trace_out.map(|_| TraceSink::new());
    if let Some(sink) = &trace_sink {
        exec.trace_sink = Some(std::sync::Arc::clone(sink));
    }
    match exec.orchestrate(orch) {
        Ok(timeline) => {
            println!("{}", timeline.summary());
            println!(
                "cross-group rebalances: {}",
                timeline.n_cross_group_rebalances()
            );
            if let Some(r) = &exec.report {
                println!("{}", r.summary());
            }
            for (k, v) in metrics.snapshot() {
                println!("{k} {v}");
            }
            // The paper's headline comparison: the orchestrated mixed
            // fleet's serving cost vs a newest-homogeneous fleet of
            // equal decode capacity on the *same* trace.
            if mixed_fleet {
                if let Some(mixed_report) = &exec.report {
                    let dec_total: u32 = timeline
                        .plans()
                        .last()
                        .map(|p| {
                            p.pipelines
                                .iter()
                                .filter(|g| g.role == agentic_hetero::plan::Role::Decode)
                                .map(|g| g.replicas)
                                .sum()
                        })
                        .unwrap_or(initial_decode_total)
                        .max(1);
                    let homog = agentic_hetero::plan::presets::homogeneous(
                        args.get_or("model", "8b-fp16"),
                        &new_dev,
                        dec_total,
                    );
                    // With --arrivals the comparison re-pulls an
                    // identical stream (same seed, same process) — the
                    // materialized trace is empty in that mode.
                    let run_cmp = |p: &ExecutionPlan| match arrivals_of(args, &tc) {
                        Ok(Some(mut src)) => {
                            agentic_hetero::cluster::sim::simulate_stream(p, src.as_mut())
                        }
                        _ => simulate_plan(p, &trace),
                    };
                    match run_cmp(&homog) {
                        Ok(hr) => {
                            println!("\nTCO, same trace (modeled $/Mtok):");
                            println!(
                                "  mixed {new_dev}+{old_dev}: {:.4}  ({:.0} tok/s)",
                                mixed_report.usd_per_mtok, mixed_report.tokens_per_s
                            );
                            println!(
                                "  homogeneous {new_dev} x{dec_total}: {:.4}  ({:.0} tok/s)",
                                hr.usd_per_mtok, hr.tokens_per_s
                            );
                            println!(
                                "  mixed/homogeneous cost ratio: {:.3}",
                                mixed_report.usd_per_mtok / hr.usd_per_mtok.max(1e-12)
                            );
                        }
                        Err(e) => eprintln!("homogeneous comparison failed: {e}"),
                    }
                    // Cross-step prefix-KV reuse on the same trace: an
                    // agentic fan-out re-sends the planner's context to
                    // every worker step, so with reuse on only uncached
                    // suffixes prefill — the serving-cost lever the
                    // mixed-fleet TCO question rides on.
                    let model = args.get_or("model", "8b-fp16");
                    let fan = agentic_hetero::plan::presets::shared_prefix_fanout(
                        model, &new_dev, 4,
                    );
                    let run_fan = |reuse: bool| {
                        let mut sim = agentic_hetero::cluster::dag::DagSim::new(&fan)?;
                        if reuse {
                            sim.set_kv_reuse(
                                agentic_hetero::cluster::dag::KvReuseConfig::default(),
                            );
                        }
                        match arrivals_of(args, &tc) {
                            Ok(Some(mut src)) => sim.run_stream(src.as_mut()),
                            _ => sim.run(&trace),
                        }
                    };
                    match (run_fan(false), run_fan(true)) {
                        (Ok(off), Ok(on)) => {
                            println!(
                                "\nPrefix-KV reuse, shared-prefix fan-out on {new_dev} \
                                 (modeled $/Mtok):"
                            );
                            println!(
                                "  reuse off: {:.4}  ({:.0} tok/s)",
                                off.usd_per_mtok, off.tokens_per_s
                            );
                            println!(
                                "  reuse on:  {:.4}  ({:.0} tok/s)",
                                on.usd_per_mtok, on.tokens_per_s
                            );
                            println!(
                                "  TCO delta from reuse: {:+.2}%",
                                (on.usd_per_mtok / off.usd_per_mtok.max(1e-12) - 1.0)
                                    * 100.0
                            );
                        }
                        (Err(e), _) | (_, Err(e)) => {
                            eprintln!("reuse comparison failed: {e}")
                        }
                    }
                }
            }
            if let (Some(sink), Some(path)) = (&trace_sink, trace_out) {
                println!("\nSLA attribution (critical path):");
                print!("{}", attribute_all(&sink.spans()).table());
                if !write_trace_file(sink, path) {
                    return 1;
                }
            }
            if let Some(path) = args.get("out") {
                if let Err(e) = std::fs::write(path, timeline.to_json_string()) {
                    eprintln!("write {path}: {e}");
                    return 1;
                }
                println!("wrote {path}");
            }
            0
        }
        Err(e) => {
            eprintln!("orchestrate: {e}");
            1
        }
    }
}
