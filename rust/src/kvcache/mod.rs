//! Distributed KV-cache management (paper §4.1 "Cache Manager").
//!
//! "Manages distributed key-value (KV) caches ... employing strategies
//! for offloading less frequently accessed data to slower storage
//! mediums such as secondary memory tiers, disks, or object storage."
//!
//! * [`paged`] — the per-device paged block allocator (the paper's
//!   framework "automatically incorporates optimizations such as paged
//!   attention [12]");
//! * [`manager`] — the cluster-level cache directory: per-session
//!   placement, LRU offload across memory tiers, and the prefix-locality
//!   lookups the fast-path router uses.

pub mod manager;
pub mod paged;

pub use manager::{CacheManager, Tier};
pub use paged::PagedAllocator;
