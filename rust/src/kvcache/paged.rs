//! Paged KV block allocator (vLLM-style paged attention bookkeeping).
//!
//! KV memory is carved into fixed-size pages of `page_tokens` tokens;
//! sequences own page lists that grow one token at a time. This
//! eliminates the reservation fragmentation of contiguous allocation —
//! the property tested below and benchmarked in `benches/`.

use std::collections::BTreeMap;

use crate::{Error, Result};

/// Sequence identifier (request / session).
pub type SeqId = u64;

/// Fixed-page KV allocator for one device's cache pool.
#[derive(Debug)]
pub struct PagedAllocator {
    pub page_tokens: u32,
    n_pages: u32,
    free: Vec<u32>,
    seqs: BTreeMap<SeqId, SeqAlloc>,
}

#[derive(Debug, Clone, Default)]
struct SeqAlloc {
    pages: Vec<u32>,
    tokens: u64,
}

impl PagedAllocator {
    pub fn new(n_pages: u32, page_tokens: u32) -> PagedAllocator {
        assert!(page_tokens > 0 && n_pages > 0);
        PagedAllocator {
            page_tokens,
            n_pages,
            // LIFO free list: recently-freed pages are cache-warm.
            free: (0..n_pages).rev().collect(),
            seqs: BTreeMap::new(),
        }
    }

    pub fn free_pages(&self) -> u32 {
        self.free.len() as u32
    }

    pub fn used_pages(&self) -> u32 {
        self.n_pages - self.free_pages()
    }

    pub fn utilization(&self) -> f64 {
        self.used_pages() as f64 / self.n_pages as f64
    }

    pub fn has_seq(&self, seq: SeqId) -> bool {
        self.seqs.contains_key(&seq)
    }

    pub fn seq_tokens(&self, seq: SeqId) -> u64 {
        self.seqs.get(&seq).map(|s| s.tokens).unwrap_or(0)
    }

    /// Pages a sequence of `tokens` tokens needs.
    pub fn pages_for(&self, tokens: u64) -> u32 {
        tokens.div_ceil(self.page_tokens as u64) as u32
    }

    /// Register a new sequence with `tokens` prefix tokens (prefill).
    pub fn alloc_seq(&mut self, seq: SeqId, tokens: u64) -> Result<()> {
        if self.seqs.contains_key(&seq) {
            return Err(Error::Runtime(format!("seq {seq} already allocated")));
        }
        let need = self.pages_for(tokens.max(1));
        if (self.free.len() as u32) < need {
            return Err(Error::Capacity(format!(
                "need {need} pages, {} free",
                self.free.len()
            )));
        }
        let pages = self.free.split_off(self.free.len() - need as usize);
        self.seqs.insert(seq, SeqAlloc { pages, tokens });
        Ok(())
    }

    /// Grow a sequence by one generated token (decode step); allocates a
    /// page only at page boundaries.
    pub fn append_token(&mut self, seq: SeqId) -> Result<()> {
        let page_tokens = self.page_tokens as u64;
        let alloc = self
            .seqs
            .get_mut(&seq)
            .ok_or_else(|| Error::Runtime(format!("unknown seq {seq}")))?;
        if alloc.tokens % page_tokens == 0 && alloc.tokens > 0 || alloc.pages.is_empty()
        {
            // Boundary (or empty): need a fresh page.
            let page = self
                .free
                .pop()
                .ok_or_else(|| Error::Capacity("out of KV pages".into()))?;
            alloc.pages.push(page);
        }
        alloc.tokens += 1;
        Ok(())
    }

    /// Release a sequence (request finished or offloaded).
    pub fn free_seq(&mut self, seq: SeqId) -> Result<u64> {
        let alloc = self
            .seqs
            .remove(&seq)
            .ok_or_else(|| Error::Runtime(format!("unknown seq {seq}")))?;
        self.free.extend(alloc.pages);
        Ok(alloc.tokens)
    }

    /// Internal-fragmentation ratio: wasted slots in tail pages over
    /// total allocated slots.
    pub fn fragmentation(&self) -> f64 {
        let mut alloc_slots = 0u64;
        let mut used_slots = 0u64;
        for s in self.seqs.values() {
            alloc_slots += s.pages.len() as u64 * self.page_tokens as u64;
            used_slots += s.tokens;
        }
        if alloc_slots == 0 {
            0.0
        } else {
            1.0 - used_slots as f64 / alloc_slots as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;
    use crate::util::rng::Rng;

    #[test]
    fn alloc_grow_free_roundtrip() {
        let mut a = PagedAllocator::new(16, 16);
        a.alloc_seq(1, 20).unwrap(); // 2 pages
        assert_eq!(a.used_pages(), 2);
        assert_eq!(a.seq_tokens(1), 20);
        for _ in 0..12 {
            a.append_token(1).unwrap(); // to 32 tokens, still 2 pages
        }
        assert_eq!(a.used_pages(), 2);
        a.append_token(1).unwrap(); // 33rd token: 3rd page
        assert_eq!(a.used_pages(), 3);
        assert_eq!(a.free_seq(1).unwrap(), 33);
        assert_eq!(a.used_pages(), 0);
    }

    #[test]
    fn capacity_enforced() {
        let mut a = PagedAllocator::new(4, 16);
        a.alloc_seq(1, 64).unwrap(); // exactly 4 pages
        assert!(a.alloc_seq(2, 1).is_err());
        assert!(a.append_token(1).is_err()); // 65th token needs page 5
    }

    #[test]
    fn double_alloc_rejected() {
        let mut a = PagedAllocator::new(8, 16);
        a.alloc_seq(1, 1).unwrap();
        assert!(a.alloc_seq(1, 1).is_err());
    }

    #[test]
    fn unknown_seq_rejected() {
        let mut a = PagedAllocator::new(8, 16);
        assert!(a.append_token(9).is_err());
        assert!(a.free_seq(9).is_err());
    }

    #[test]
    fn fragmentation_bounded_by_one_page_per_seq() {
        let mut a = PagedAllocator::new(1024, 16);
        for s in 0..32 {
            a.alloc_seq(s, 17).unwrap(); // 2 pages, 15 slots wasted
        }
        let frag = a.fragmentation();
        assert!((frag - 15.0 / 32.0).abs() < 1e-12, "frag={frag}");
    }

    #[test]
    fn no_page_leak_property() {
        // Random alloc/append/free interleavings never leak or double-
        // free pages: free + used == total always, and a drained
        // allocator returns to fully free.
        prop::check("paged-allocator-conservation", |rng: &mut Rng| {
            let mut a = PagedAllocator::new(64, 8);
            let mut live: Vec<SeqId> = Vec::new();
            let mut next: SeqId = 0;
            for _ in 0..rng.index(200) {
                match rng.index(3) {
                    0 => {
                        let toks = rng.range(1, 40);
                        if a.alloc_seq(next, toks).is_ok() {
                            live.push(next);
                        }
                        next += 1;
                    }
                    1 if !live.is_empty() => {
                        let s = *rng.choose(&live);
                        let _ = a.append_token(s);
                    }
                    2 if !live.is_empty() => {
                        let idx = rng.index(live.len());
                        let s = live.swap_remove(idx);
                        a.free_seq(s).unwrap();
                    }
                    _ => {}
                }
                assert_eq!(a.free_pages() + a.used_pages(), 64);
            }
            for s in live {
                a.free_seq(s).unwrap();
            }
            assert_eq!(a.free_pages(), 64);
            assert_eq!(a.fragmentation(), 0.0);
        });
    }
}
