//! Cluster-level cache directory with tiered offload.
//!
//! Tracks where each session's KV lives (node + tier), serves the
//! fast-path router's locality queries, and offloads least-recently-used
//! entries down the tier ladder (HBM → DRAM → Disk → Object) when a
//! node's HBM pool is under pressure — §4.1's Cache Manager.

use std::collections::BTreeMap;

use crate::{Error, Result};

/// Storage tier ladder, fastest first.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Tier {
    Hbm,
    Dram,
    Disk,
    Object,
}

impl Tier {
    pub fn next_colder(self) -> Option<Tier> {
        match self {
            Tier::Hbm => Some(Tier::Dram),
            Tier::Dram => Some(Tier::Disk),
            Tier::Disk => Some(Tier::Object),
            Tier::Object => None,
        }
    }

    /// Nominal read bandwidth for restore-cost estimates, bytes/s.
    pub fn read_bw(self) -> f64 {
        match self {
            Tier::Hbm => 2e12,
            Tier::Dram => 8e10,
            Tier::Disk => 3e9,
            Tier::Object => 5e8,
        }
    }
}

/// One cached session entry.
#[derive(Debug, Clone)]
pub struct CacheEntry {
    pub session: u64,
    pub node: u32,
    pub tier: Tier,
    pub bytes: f64,
    pub last_use: u64,
    /// Hash of the token prefix (prefix-cache hits).
    pub prefix_hash: u64,
}

/// Per-node tier capacities, bytes.
#[derive(Debug, Clone)]
pub struct NodeBudget {
    pub hbm: f64,
    pub dram: f64,
    pub disk: f64,
}

/// The directory.
#[derive(Debug)]
pub struct CacheManager {
    budgets: Vec<NodeBudget>,
    entries: BTreeMap<u64, CacheEntry>,
    /// prefix_hash -> sessions carrying it (fast-path routing index;
    /// §Perf: turns find_prefix from an O(entries) scan into a map hit).
    prefix_index: BTreeMap<u64, Vec<u64>>,
    clock: u64,
}

impl CacheManager {
    pub fn new(budgets: Vec<NodeBudget>) -> CacheManager {
        CacheManager {
            budgets,
            entries: BTreeMap::new(),
            prefix_index: BTreeMap::new(),
            clock: 0,
        }
    }

    fn tick(&mut self) -> u64 {
        self.clock += 1;
        self.clock
    }

    /// Bytes used on `node` at `tier`.
    pub fn used(&self, node: u32, tier: Tier) -> f64 {
        self.entries
            .values()
            .filter(|e| e.node == node && e.tier == tier)
            .map(|e| e.bytes)
            .sum()
    }

    fn capacity(&self, node: u32, tier: Tier) -> f64 {
        let b = &self.budgets[node as usize];
        match tier {
            Tier::Hbm => b.hbm,
            Tier::Dram => b.dram,
            Tier::Disk => b.disk,
            Tier::Object => f64::INFINITY,
        }
    }

    /// Insert a session's KV on `node` in HBM, offloading LRU entries
    /// down-tier as needed to make room.
    pub fn insert(
        &mut self,
        session: u64,
        node: u32,
        bytes: f64,
        prefix_hash: u64,
    ) -> Result<()> {
        if node as usize >= self.budgets.len() {
            return Err(Error::Runtime(format!("unknown node {node}")));
        }
        if bytes > self.capacity(node, Tier::Hbm) {
            return Err(Error::Capacity(format!(
                "entry of {bytes}B exceeds node {node} HBM pool"
            )));
        }
        self.make_room(node, Tier::Hbm, bytes)?;
        let t = self.tick();
        if let Some(old) = self.entries.insert(
            session,
            CacheEntry {
                session,
                node,
                tier: Tier::Hbm,
                bytes,
                last_use: t,
                prefix_hash,
            },
        ) {
            self.unindex_prefix(old.prefix_hash, session);
        }
        self.prefix_index.entry(prefix_hash).or_default().push(session);
        Ok(())
    }

    fn unindex_prefix(&mut self, prefix_hash: u64, session: u64) {
        if let Some(v) = self.prefix_index.get_mut(&prefix_hash) {
            v.retain(|s| *s != session);
            if v.is_empty() {
                self.prefix_index.remove(&prefix_hash);
            }
        }
    }

    /// Ensure `bytes` of headroom at (node, tier) by demoting LRU
    /// entries to the next-colder tier (recursively).
    fn make_room(&mut self, node: u32, tier: Tier, bytes: f64) -> Result<()> {
        while self.used(node, tier) + bytes > self.capacity(node, tier) {
            // LRU victim at this node+tier.
            let victim = self
                .entries
                .values()
                .filter(|e| e.node == node && e.tier == tier)
                .min_by_key(|e| e.last_use)
                .map(|e| e.session);
            let Some(victim) = victim else {
                return Err(Error::Capacity(format!(
                    "node {node} {tier:?} cannot fit {bytes}B"
                )));
            };
            let colder = tier
                .next_colder()
                .ok_or_else(|| Error::Capacity("object tier full?".into()))?;
            let vbytes = self.entries[&victim].bytes;
            self.make_room(node, colder, vbytes)?;
            self.entries.get_mut(&victim).unwrap().tier = colder;
        }
        Ok(())
    }

    /// Touch a session (request hit); promotes it back to HBM when it
    /// had been offloaded. Returns the tier it was found in (the caller
    /// prices the restore latency) or None for a cold miss.
    pub fn touch(&mut self, session: u64) -> Option<Tier> {
        if !self.entries.contains_key(&session) {
            return None;
        }
        let (node, bytes, found) = {
            let e = &self.entries[&session];
            (e.node, e.bytes, e.tier)
        };
        if found != Tier::Hbm {
            // Promote: make room in HBM first.
            if self.make_room(node, Tier::Hbm, bytes).is_err() {
                // HBM hopeless; leave it where it is.
                let t = self.tick();
                self.entries.get_mut(&session).unwrap().last_use = t;
                return Some(found);
            }
            self.entries.get_mut(&session).unwrap().tier = Tier::Hbm;
        }
        let t = self.tick();
        self.entries.get_mut(&session).unwrap().last_use = t;
        Some(found)
    }

    /// Drop a session's cache.
    pub fn evict(&mut self, session: u64) -> bool {
        match self.entries.remove(&session) {
            Some(e) => {
                self.unindex_prefix(e.prefix_hash, session);
                true
            }
            None => false,
        }
    }

    /// Node holding this session's KV (router locality query).
    pub fn locate(&self, session: u64) -> Option<(u32, Tier)> {
        self.entries.get(&session).map(|e| (e.node, e.tier))
    }

    /// Any node holding a cache entry with this prefix hash (prefix
    /// cache-hit routing for shared system prompts). Most-recently-used
    /// wins; served from the prefix index rather than a full scan.
    pub fn find_prefix(&self, prefix_hash: u64) -> Option<u32> {
        self.prefix_index
            .get(&prefix_hash)?
            .iter()
            .filter_map(|s| self.entries.get(s))
            .max_by_key(|e| e.last_use)
            .map(|e| e.node)
    }

    /// Estimated restore latency from the session's current tier.
    pub fn restore_latency_s(&self, session: u64) -> f64 {
        match self.entries.get(&session) {
            None => 0.0,
            Some(e) => {
                if e.tier == Tier::Hbm {
                    0.0
                } else {
                    e.bytes / e.tier.read_bw()
                }
            }
        }
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mgr(hbm: f64) -> CacheManager {
        CacheManager::new(vec![
            NodeBudget {
                hbm,
                dram: 4.0 * hbm,
                disk: 100.0 * hbm,
            },
            NodeBudget {
                hbm,
                dram: 4.0 * hbm,
                disk: 100.0 * hbm,
            },
        ])
    }

    #[test]
    fn insert_and_locate() {
        let mut m = mgr(100.0);
        m.insert(1, 0, 40.0, 0xAB).unwrap();
        assert_eq!(m.locate(1), Some((0, Tier::Hbm)));
        assert_eq!(m.used(0, Tier::Hbm), 40.0);
    }

    #[test]
    fn lru_offload_on_pressure() {
        let mut m = mgr(100.0);
        m.insert(1, 0, 60.0, 1).unwrap();
        m.insert(2, 0, 30.0, 2).unwrap();
        m.touch(2); // 1 is now LRU
        m.insert(3, 0, 50.0, 3).unwrap(); // forces offload of 1
        assert_eq!(m.locate(1), Some((0, Tier::Dram)));
        assert_eq!(m.locate(3), Some((0, Tier::Hbm)));
    }

    #[test]
    fn cascading_offload_to_disk() {
        let mut m = CacheManager::new(vec![NodeBudget {
            hbm: 100.0,
            dram: 100.0,
            disk: 1000.0,
        }]);
        m.insert(1, 0, 90.0, 1).unwrap();
        m.insert(2, 0, 90.0, 2).unwrap(); // 1 -> DRAM
        m.insert(3, 0, 90.0, 3).unwrap(); // 2 -> DRAM would overflow: 1 -> Disk
        assert_eq!(m.locate(1), Some((0, Tier::Disk)));
        assert_eq!(m.locate(2), Some((0, Tier::Dram)));
        assert_eq!(m.locate(3), Some((0, Tier::Hbm)));
    }

    #[test]
    fn touch_promotes_back_to_hbm() {
        let mut m = mgr(100.0);
        m.insert(1, 0, 60.0, 1).unwrap();
        m.insert(2, 0, 60.0, 2).unwrap(); // 1 offloaded
        assert_eq!(m.locate(1).unwrap().1, Tier::Dram);
        assert!(m.restore_latency_s(1) > 0.0);
        let was = m.touch(1).unwrap();
        assert_eq!(was, Tier::Dram);
        assert_eq!(m.locate(1).unwrap().1, Tier::Hbm);
        // Now 2 got pushed out.
        assert_eq!(m.locate(2).unwrap().1, Tier::Dram);
    }

    #[test]
    fn prefix_lookup_prefers_recent() {
        let mut m = mgr(1000.0);
        m.insert(1, 0, 10.0, 0xFEED).unwrap();
        m.insert(2, 1, 10.0, 0xFEED).unwrap();
        assert_eq!(m.find_prefix(0xFEED), Some(1)); // session 2 is fresher
        m.touch(1);
        assert_eq!(m.find_prefix(0xFEED), Some(0));
        assert_eq!(m.find_prefix(0xDEAD), None);
    }

    #[test]
    fn oversized_entry_rejected() {
        let mut m = mgr(100.0);
        assert!(m.insert(1, 0, 150.0, 0).is_err());
        assert!(m.insert(1, 9, 10.0, 0).is_err());
    }

    #[test]
    fn evict_and_miss() {
        let mut m = mgr(100.0);
        m.insert(1, 0, 10.0, 0).unwrap();
        assert!(m.evict(1));
        assert!(!m.evict(1));
        assert_eq!(m.touch(1), None);
        assert!(m.is_empty());
    }
}
