//! Cluster-level cache directory with tiered offload.
//!
//! Tracks where each session's KV lives (node + tier), serves the
//! fast-path router's locality queries, and offloads least-recently-used
//! entries down the tier ladder (HBM → DRAM → Disk → Object) when a
//! node's HBM pool is under pressure — §4.1's Cache Manager.

use std::collections::BTreeMap;

use crate::{Error, Result};

/// Storage tier ladder, fastest first.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Tier {
    Hbm,
    Dram,
    Disk,
    Object,
}

impl Tier {
    pub fn next_colder(self) -> Option<Tier> {
        match self {
            Tier::Hbm => Some(Tier::Dram),
            Tier::Dram => Some(Tier::Disk),
            Tier::Disk => Some(Tier::Object),
            Tier::Object => None,
        }
    }

    /// Nominal read bandwidth for restore-cost estimates, bytes/s.
    pub fn read_bw(self) -> f64 {
        match self {
            Tier::Hbm => 2e12,
            Tier::Dram => 8e10,
            Tier::Disk => 3e9,
            Tier::Object => 5e8,
        }
    }
}

/// One cached session entry.
#[derive(Debug, Clone)]
pub struct CacheEntry {
    pub session: u64,
    pub node: u32,
    pub tier: Tier,
    pub bytes: f64,
    pub last_use: u64,
    /// Hash of the token prefix (prefix-cache hits).
    pub prefix_hash: u64,
}

/// Per-node tier capacities, bytes.
#[derive(Debug, Clone)]
pub struct NodeBudget {
    pub hbm: f64,
    pub dram: f64,
    pub disk: f64,
}

/// The directory.
#[derive(Debug)]
pub struct CacheManager {
    budgets: Vec<NodeBudget>,
    entries: BTreeMap<u64, CacheEntry>,
    /// prefix_hash -> sessions carrying it (fast-path routing index;
    /// §Perf: turns find_prefix from an O(entries) scan into a map hit).
    prefix_index: BTreeMap<u64, Vec<u64>>,
    clock: u64,
}

impl CacheManager {
    pub fn new(budgets: Vec<NodeBudget>) -> CacheManager {
        CacheManager {
            budgets,
            entries: BTreeMap::new(),
            prefix_index: BTreeMap::new(),
            clock: 0,
        }
    }

    fn tick(&mut self) -> u64 {
        self.clock += 1;
        self.clock
    }

    /// Bytes used on `node` at `tier`.
    pub fn used(&self, node: u32, tier: Tier) -> f64 {
        self.entries
            .values()
            .filter(|e| e.node == node && e.tier == tier)
            .map(|e| e.bytes)
            .sum()
    }

    fn capacity(&self, node: u32, tier: Tier) -> f64 {
        let b = &self.budgets[node as usize];
        match tier {
            Tier::Hbm => b.hbm,
            Tier::Dram => b.dram,
            Tier::Disk => b.disk,
            Tier::Object => f64::INFINITY,
        }
    }

    /// Insert a session's KV on `node` in HBM, offloading LRU entries
    /// down-tier as needed to make room.
    pub fn insert(
        &mut self,
        session: u64,
        node: u32,
        bytes: f64,
        prefix_hash: u64,
    ) -> Result<()> {
        if node as usize >= self.budgets.len() {
            return Err(Error::Runtime(format!("unknown node {node}")));
        }
        if bytes > self.capacity(node, Tier::Hbm) {
            return Err(Error::Capacity(format!(
                "entry of {bytes}B exceeds node {node} HBM pool"
            )));
        }
        // Credit a superseded entry before sizing the insert: the old
        // bytes must not count as resident while make_room runs, or a
        // re-insert of a resized session at near-full HBM demotes
        // bystanders (or fails with a false Capacity error) to fit a
        // total that never coexists.
        let old = self.entries.remove(&session);
        if let Some(o) = &old {
            self.unindex_prefix(o.prefix_hash, session);
        }
        if let Err(e) = self.make_room(node, Tier::Hbm, bytes) {
            // Failed insert must not drop the superseded entry.
            if let Some(o) = old {
                self.prefix_index.entry(o.prefix_hash).or_default().push(session);
                self.entries.insert(session, o);
            }
            return Err(e);
        }
        let t = self.tick();
        self.entries.insert(
            session,
            CacheEntry {
                session,
                node,
                tier: Tier::Hbm,
                bytes,
                last_use: t,
                prefix_hash,
            },
        );
        self.prefix_index.entry(prefix_hash).or_default().push(session);
        Ok(())
    }

    fn unindex_prefix(&mut self, prefix_hash: u64, session: u64) {
        if let Some(v) = self.prefix_index.get_mut(&prefix_hash) {
            v.retain(|s| *s != session);
            if v.is_empty() {
                self.prefix_index.remove(&prefix_hash);
            }
        }
    }

    /// Ensure `bytes` of headroom at (node, tier) by demoting LRU
    /// entries to the next-colder tier (recursively).
    fn make_room(&mut self, node: u32, tier: Tier, bytes: f64) -> Result<()> {
        while self.used(node, tier) + bytes > self.capacity(node, tier) {
            // LRU victim at this node+tier.
            let victim = self
                .entries
                .values()
                .filter(|e| e.node == node && e.tier == tier)
                .min_by_key(|e| e.last_use)
                .map(|e| e.session);
            let Some(victim) = victim else {
                return Err(Error::Capacity(format!(
                    "node {node} {tier:?} cannot fit {bytes}B"
                )));
            };
            let colder = tier
                .next_colder()
                .ok_or_else(|| Error::Capacity("object tier full?".into()))?;
            let vbytes = self.entries[&victim].bytes;
            self.make_room(node, colder, vbytes)?;
            self.entries.get_mut(&victim).unwrap().tier = colder;
        }
        Ok(())
    }

    /// Touch a session (request hit); promotes it back to HBM when it
    /// had been offloaded. Returns the tier it was found in (the caller
    /// prices the restore latency) or None for a cold miss.
    pub fn touch(&mut self, session: u64) -> Option<Tier> {
        if !self.entries.contains_key(&session) {
            return None;
        }
        let (node, bytes, found) = {
            let e = &self.entries[&session];
            (e.node, e.bytes, e.tier)
        };
        if found != Tier::Hbm {
            // Lift the entry out while promoting: it must neither be a
            // cascade victim (HBM→DRAM demotions call make_room at the
            // tier it occupies, and self-demotion would be silently
            // overwritten below) nor count against the tier it is
            // vacating. On failure it goes back where it was.
            let mut lifted = self.entries.remove(&session).unwrap();
            if self.make_room(node, Tier::Hbm, bytes).is_ok() {
                lifted.tier = Tier::Hbm;
            }
            lifted.last_use = self.tick();
            self.entries.insert(session, lifted);
            return Some(found);
        }
        let t = self.tick();
        self.entries.get_mut(&session).unwrap().last_use = t;
        Some(found)
    }

    /// Drop a session's cache.
    pub fn evict(&mut self, session: u64) -> bool {
        match self.entries.remove(&session) {
            Some(e) => {
                self.unindex_prefix(e.prefix_hash, session);
                true
            }
            None => false,
        }
    }

    /// Node holding this session's KV (router locality query).
    pub fn locate(&self, session: u64) -> Option<(u32, Tier)> {
        self.entries.get(&session).map(|e| (e.node, e.tier))
    }

    /// Any node holding a cache entry with this prefix hash (prefix
    /// cache-hit routing for shared system prompts). Most-recently-used
    /// wins; served from the prefix index rather than a full scan.
    pub fn find_prefix(&self, prefix_hash: u64) -> Option<u32> {
        self.prefix_index
            .get(&prefix_hash)?
            .iter()
            .filter_map(|s| self.entries.get(s))
            .max_by_key(|e| e.last_use)
            .map(|e| e.node)
    }

    /// Estimated restore latency from the session's current tier.
    pub fn restore_latency_s(&self, session: u64) -> f64 {
        match self.entries.get(&session) {
            None => 0.0,
            Some(e) => {
                if e.tier == Tier::Hbm {
                    0.0
                } else {
                    e.bytes / e.tier.read_bw()
                }
            }
        }
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mgr(hbm: f64) -> CacheManager {
        CacheManager::new(vec![
            NodeBudget {
                hbm,
                dram: 4.0 * hbm,
                disk: 100.0 * hbm,
            },
            NodeBudget {
                hbm,
                dram: 4.0 * hbm,
                disk: 100.0 * hbm,
            },
        ])
    }

    #[test]
    fn insert_and_locate() {
        let mut m = mgr(100.0);
        m.insert(1, 0, 40.0, 0xAB).unwrap();
        assert_eq!(m.locate(1), Some((0, Tier::Hbm)));
        assert_eq!(m.used(0, Tier::Hbm), 40.0);
    }

    #[test]
    fn lru_offload_on_pressure() {
        let mut m = mgr(100.0);
        m.insert(1, 0, 60.0, 1).unwrap();
        m.insert(2, 0, 30.0, 2).unwrap();
        m.touch(2); // 1 is now LRU
        m.insert(3, 0, 50.0, 3).unwrap(); // forces offload of 1
        assert_eq!(m.locate(1), Some((0, Tier::Dram)));
        assert_eq!(m.locate(3), Some((0, Tier::Hbm)));
    }

    #[test]
    fn cascading_offload_to_disk() {
        let mut m = CacheManager::new(vec![NodeBudget {
            hbm: 100.0,
            dram: 100.0,
            disk: 1000.0,
        }]);
        m.insert(1, 0, 90.0, 1).unwrap();
        m.insert(2, 0, 90.0, 2).unwrap(); // 1 -> DRAM
        m.insert(3, 0, 90.0, 3).unwrap(); // 2 -> DRAM would overflow: 1 -> Disk
        assert_eq!(m.locate(1), Some((0, Tier::Disk)));
        assert_eq!(m.locate(2), Some((0, Tier::Dram)));
        assert_eq!(m.locate(3), Some((0, Tier::Hbm)));
    }

    #[test]
    fn touch_promotes_back_to_hbm() {
        let mut m = mgr(100.0);
        m.insert(1, 0, 60.0, 1).unwrap();
        m.insert(2, 0, 60.0, 2).unwrap(); // 1 offloaded
        assert_eq!(m.locate(1).unwrap().1, Tier::Dram);
        assert!(m.restore_latency_s(1) > 0.0);
        let was = m.touch(1).unwrap();
        assert_eq!(was, Tier::Dram);
        assert_eq!(m.locate(1).unwrap().1, Tier::Hbm);
        // Now 2 got pushed out.
        assert_eq!(m.locate(2).unwrap().1, Tier::Dram);
    }

    #[test]
    fn prefix_lookup_prefers_recent() {
        let mut m = mgr(1000.0);
        m.insert(1, 0, 10.0, 0xFEED).unwrap();
        m.insert(2, 1, 10.0, 0xFEED).unwrap();
        assert_eq!(m.find_prefix(0xFEED), Some(1)); // session 2 is fresher
        m.touch(1);
        assert_eq!(m.find_prefix(0xFEED), Some(0));
        assert_eq!(m.find_prefix(0xDEAD), None);
    }

    #[test]
    fn oversized_entry_rejected() {
        let mut m = mgr(100.0);
        assert!(m.insert(1, 0, 150.0, 0).is_err());
        assert!(m.insert(1, 9, 10.0, 0).is_err());
    }

    #[test]
    fn evict_and_miss() {
        let mut m = mgr(100.0);
        m.insert(1, 0, 10.0, 0).unwrap();
        assert!(m.evict(1));
        assert!(!m.evict(1));
        assert_eq!(m.touch(1), None);
        assert!(m.is_empty());
    }

    #[test]
    fn reinsert_resized_session_credits_old_bytes() {
        // A session growing in place at near-full HBM must not count
        // its superseded bytes while room is made. dram too small to
        // absorb a spurious demotion, so the old double-count turned
        // this into a false Capacity error.
        let mut m = CacheManager::new(vec![NodeBudget {
            hbm: 100.0,
            dram: 50.0,
            disk: 1000.0,
        }]);
        m.insert(1, 0, 80.0, 0xA).unwrap();
        m.insert(1, 0, 90.0, 0xA).unwrap();
        assert_eq!(m.locate(1), Some((0, Tier::Hbm)));
        assert_eq!(m.used(0, Tier::Hbm), 90.0);
        assert_eq!(m.used(0, Tier::Dram), 0.0);
        assert_eq!(m.len(), 1);
        assert_eq!(m.find_prefix(0xA), Some(0));
    }

    #[test]
    fn reinsert_does_not_demote_bystanders() {
        let mut m = mgr(100.0);
        m.insert(1, 0, 60.0, 1).unwrap();
        m.insert(2, 0, 30.0, 2).unwrap();
        m.touch(1); // 2 is LRU — the old code's spurious victim
        m.insert(1, 0, 70.0, 1).unwrap(); // 30 + 70 fits exactly
        assert_eq!(m.locate(1), Some((0, Tier::Hbm)));
        assert_eq!(m.locate(2), Some((0, Tier::Hbm)), "bystander must stay");
        assert_eq!(m.used(0, Tier::Hbm), 100.0);
    }

    #[test]
    fn failed_reinsert_keeps_old_entry() {
        // Oversized replacement is rejected up front; a make_room
        // failure must also restore the superseded entry.
        let mut m = CacheManager::new(vec![NodeBudget {
            hbm: 100.0,
            dram: 10.0,
            disk: 10.0,
        }]);
        m.insert(1, 0, 50.0, 0xA).unwrap();
        m.insert(2, 0, 50.0, 0xB).unwrap();
        // Fitting 90 needs a victim demoted, but dram can't take 50.
        assert!(m.insert(1, 0, 90.0, 0xC).is_err());
        assert_eq!(m.locate(1), Some((0, Tier::Hbm)));
        assert_eq!(m.used(0, Tier::Hbm), 100.0);
        assert_eq!(m.find_prefix(0xA), Some(0), "old prefix still indexed");
        assert_eq!(m.find_prefix(0xC), None);
    }

    #[test]
    fn touch_promotion_never_victimizes_the_promoting_session() {
        // Tight DRAM: promoting 1 evicts 2 from HBM, whose demotion
        // makes room at DRAM — where 1 is the only (and LRU) resident.
        // The old code demoted 1 toward Disk mid-promotion (failing
        // outright when disk is too small), then blindly stamped it
        // Hbm. Fixed: 1 is lifted out, so 2 slides into the space 1
        // vacates and the swap succeeds even with no disk at all.
        let mut m = CacheManager::new(vec![NodeBudget {
            hbm: 100.0,
            dram: 100.0,
            disk: 50.0,
        }]);
        m.insert(1, 0, 90.0, 1).unwrap();
        m.insert(2, 0, 90.0, 2).unwrap(); // 1 → DRAM
        assert_eq!(m.locate(1), Some((0, Tier::Dram)));
        assert_eq!(m.touch(1), Some(Tier::Dram));
        assert_eq!(m.locate(1), Some((0, Tier::Hbm)));
        assert_eq!(m.locate(2), Some((0, Tier::Dram)));
        assert_eq!(m.used(0, Tier::Disk), 0.0, "nothing bounced to disk");
    }

    #[test]
    fn touch_leaves_session_in_place_when_promotion_is_impossible() {
        // Promoting 1 (40B) needs 2 (90B) out of HBM, but 90B fits in
        // neither DRAM nor disk: promotion fails closed and the
        // session keeps its tier instead of bouncing down the ladder.
        let mut m = CacheManager::new(vec![NodeBudget {
            hbm: 100.0,
            dram: 50.0,
            disk: 10.0,
        }]);
        m.insert(1, 0, 40.0, 1).unwrap();
        m.insert(2, 0, 90.0, 2).unwrap(); // 1 → DRAM
        assert_eq!(m.locate(1), Some((0, Tier::Dram)));
        let was = m.touch(1).unwrap();
        assert_eq!(was, Tier::Dram);
        assert_eq!(m.locate(1), Some((0, Tier::Dram)), "left in place");
        assert_eq!(m.locate(2), Some((0, Tier::Hbm)));
    }

    /// Conservation property mirroring the paged allocator's
    /// `no_page_leak_property`: across random insert/touch/evict
    /// interleavings, per-tier residency never exceeds capacity and the
    /// prefix index never dangles (every indexed session exists with
    /// that hash, every entry is indexed exactly once). Deepened by the
    /// nightly `AH_PROP_CASES` run.
    #[test]
    fn cache_conservation_property() {
        use crate::util::prop;
        use crate::util::rng::Rng;

        prop::check("cache-manager-conservation", |rng: &mut Rng| {
            let nodes = rng.index(2) + 1;
            let mut m = CacheManager::new(
                (0..nodes)
                    .map(|_| NodeBudget {
                        hbm: 100.0,
                        dram: (rng.index(3) as f64 + 1.0) * 60.0,
                        disk: (rng.index(4) as f64) * 80.0,
                    })
                    .collect(),
            );
            let steps = rng.index(120);
            for _ in 0..steps {
                let session = rng.index(10) as u64;
                let node = rng.index(nodes) as u32;
                match rng.index(4) {
                    0 | 1 => {
                        let bytes = (rng.index(10) as f64 + 1.0) * 12.0;
                        let hash = rng.index(5) as u64;
                        let _ = m.insert(session, node, bytes, hash);
                    }
                    2 => {
                        m.touch(session);
                    }
                    _ => {
                        m.evict(session);
                    }
                }
                for n in 0..nodes as u32 {
                    for tier in [Tier::Hbm, Tier::Dram, Tier::Disk] {
                        assert!(
                            m.used(n, tier) <= m.capacity(n, tier) + 1e-9,
                            "node {n} {tier:?} over capacity: {} > {}",
                            m.used(n, tier),
                            m.capacity(n, tier)
                        );
                    }
                }
                // Index ↔ entries bijection: no dangling sessions, no
                // stale hashes, no duplicates, nothing unindexed.
                let mut indexed = 0usize;
                for (hash, sessions) in &m.prefix_index {
                    assert!(!sessions.is_empty(), "empty index bucket {hash:#x}");
                    for s in sessions {
                        let e = m
                            .entries
                            .get(s)
                            .unwrap_or_else(|| panic!("dangling session {s}"));
                        assert_eq!(e.prefix_hash, *hash, "stale hash for {s}");
                    }
                    let mut uniq = sessions.clone();
                    uniq.sort_unstable();
                    uniq.dedup();
                    assert_eq!(uniq.len(), sessions.len(), "duplicate index rows");
                    indexed += sessions.len();
                }
                assert_eq!(indexed, m.len(), "entry missing from prefix index");
            }
        });
    }
}
