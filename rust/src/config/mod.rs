//! Configuration system: a TOML-subset parser + typed deployment config.
//!
//! The offline registry has no serde/toml, so we parse the subset real
//! deployments need: `[section]` and `[[array-of-tables]]` headers,
//! `key = value` with strings, ints, floats, bools, and flat arrays,
//! plus `#` comments. The typed [`DeployConfig`] maps a config file to
//! the server/planner knobs and is what `agentic-hetero serve
//! --config` loads.

use std::collections::BTreeMap;

use crate::{Error, Result};

/// A parsed TOML-subset value.
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
    Array(Vec<Value>),
}

impl Value {
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_int(&self) -> Option<i64> {
        match self {
            Value::Int(v) => Some(*v),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Float(v) => Some(*v),
            Value::Int(v) => Some(*v as f64),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }
}

/// One table: key → value.
pub type Table = BTreeMap<String, Value>;

/// A parsed document: the root table, named tables, and arrays of tables.
#[derive(Debug, Clone, Default)]
pub struct Document {
    pub root: Table,
    pub tables: BTreeMap<String, Table>,
    pub table_arrays: BTreeMap<String, Vec<Table>>,
}

impl Document {
    pub fn get(&self, section: &str, key: &str) -> Option<&Value> {
        if section.is_empty() {
            self.root.get(key)
        } else {
            self.tables.get(section).and_then(|t| t.get(key))
        }
    }
}

fn parse_value(s: &str, line_no: usize) -> Result<Value> {
    let s = s.trim();
    let err = |msg: String| Error::Parse { line: line_no, msg };
    if s.starts_with('"') {
        if !s.ends_with('"') || s.len() < 2 {
            return Err(err(format!("unterminated string: {s}")));
        }
        return Ok(Value::Str(s[1..s.len() - 1].replace("\\\"", "\"")));
    }
    if s == "true" {
        return Ok(Value::Bool(true));
    }
    if s == "false" {
        return Ok(Value::Bool(false));
    }
    if s.starts_with('[') {
        if !s.ends_with(']') {
            return Err(err("unterminated array (must be single-line)".into()));
        }
        let inner = &s[1..s.len() - 1];
        let mut items = Vec::new();
        if !inner.trim().is_empty() {
            // Split on commas not inside strings.
            let mut depth_str = false;
            let mut cur = String::new();
            for c in inner.chars() {
                match c {
                    '"' => {
                        depth_str = !depth_str;
                        cur.push(c);
                    }
                    ',' if !depth_str => {
                        items.push(parse_value(&cur, line_no)?);
                        cur.clear();
                    }
                    _ => cur.push(c),
                }
            }
            if !cur.trim().is_empty() {
                items.push(parse_value(&cur, line_no)?);
            }
        }
        return Ok(Value::Array(items));
    }
    if s.contains('.') || s.contains('e') || s.contains('E') {
        if let Ok(f) = s.parse::<f64>() {
            return Ok(Value::Float(f));
        }
    }
    s.parse::<i64>()
        .map(Value::Int)
        .map_err(|_| err(format!("cannot parse value: {s}")))
}

/// Parse a TOML-subset document.
pub fn parse(src: &str) -> Result<Document> {
    let mut doc = Document::default();
    #[derive(Clone)]
    enum Cursor {
        Root,
        Table(String),
        ArrayElem(String),
    }
    let mut cursor = Cursor::Root;

    for (i, raw) in src.lines().enumerate() {
        let line_no = i + 1;
        let line = match raw.find('#') {
            // Keep '#' inside strings: only strip if before any quote or
            // after balanced quotes.
            Some(pos) if raw[..pos].matches('"').count() % 2 == 0 => &raw[..pos],
            _ => raw,
        }
        .trim();
        if line.is_empty() {
            continue;
        }
        let valid_name = |s: &str| {
            !s.is_empty()
                && s.chars()
                    .all(|c| c.is_ascii_alphanumeric() || matches!(c, '_' | '.' | '-'))
        };
        if let Some(name) = line.strip_prefix("[[").and_then(|s| s.strip_suffix("]]")) {
            let name = name.trim().to_string();
            if !valid_name(&name) {
                return Err(Error::Parse {
                    line: line_no,
                    msg: format!("bad table-array header: {line:?}"),
                });
            }
            doc.table_arrays.entry(name.clone()).or_default().push(Table::new());
            cursor = Cursor::ArrayElem(name);
            continue;
        }
        if let Some(name) = line.strip_prefix('[').and_then(|s| s.strip_suffix(']')) {
            let name = name.trim().to_string();
            if !valid_name(&name) {
                return Err(Error::Parse {
                    line: line_no,
                    msg: format!("bad table header: {line:?}"),
                });
            }
            doc.tables.entry(name.clone()).or_default();
            cursor = Cursor::Table(name);
            continue;
        }
        if line.starts_with('[') {
            return Err(Error::Parse {
                line: line_no,
                msg: format!("unterminated table header: {line:?}"),
            });
        }
        let Some((k, v)) = line.split_once('=') else {
            return Err(Error::Parse {
                line: line_no,
                msg: format!("expected `key = value`, got {line:?}"),
            });
        };
        let key = k.trim().to_string();
        let val = parse_value(v, line_no)?;
        match &cursor {
            Cursor::Root => {
                doc.root.insert(key, val);
            }
            Cursor::Table(name) => {
                doc.tables.get_mut(name).unwrap().insert(key, val);
            }
            Cursor::ArrayElem(name) => {
                doc.table_arrays
                    .get_mut(name)
                    .unwrap()
                    .last_mut()
                    .unwrap()
                    .insert(key, val);
            }
        }
    }
    Ok(doc)
}

/// Typed deployment configuration for the serving binary.
#[derive(Debug, Clone)]
pub struct DeployConfig {
    pub artifacts_dir: String,
    /// Optional saved [`crate::plan::ExecutionPlan`] JSON; when set, the
    /// server derives its batching/admission policy from the plan
    /// instead of the knobs below (`[server] plan = "voice.plan.json"`).
    pub plan_path: Option<String>,
    pub max_batch: usize,
    pub batch_wait_ms: u64,
    pub max_new_tokens: u64,
    pub admission_rate: f64,
    pub admission_burst: f64,
    pub sla_ttft_ms: f64,
    pub sla_tbt_ms: f64,
    /// Workers: (name, model list).
    pub workers: Vec<(String, Vec<String>)>,
    // `[orchestrator]` — the control loop's observation cadence and
    // per-role autoscaler policy (see `orchestrator::OrchestratorConfig`).
    pub orch_window_s: f64,
    pub orch_high_watermark: f64,
    pub orch_low_watermark: f64,
    pub orch_patience: u32,
    pub orch_min_pipelines: u32,
    pub orch_max_pipelines: u32,
    /// Ceiling of the `cpu_workers` autoscaler (host worker slots, not
    /// pipelines); 0 disables host-pool autoscaling entirely.
    pub orch_max_cpu_workers: u32,
}

impl Default for DeployConfig {
    fn default() -> Self {
        DeployConfig {
            artifacts_dir: "artifacts".into(),
            plan_path: None,
            max_batch: 4,
            batch_wait_ms: 5,
            max_new_tokens: 24,
            admission_rate: 1000.0,
            admission_burst: 100.0,
            sla_ttft_ms: 250.0,
            sla_tbt_ms: 100.0,
            workers: vec![("worker0".into(), vec!["tiny-llama".into()])],
            orch_window_s: 5.0,
            orch_high_watermark: 0.85,
            orch_low_watermark: 0.30,
            orch_patience: 3,
            orch_min_pipelines: 1,
            orch_max_pipelines: 64,
            orch_max_cpu_workers: 512,
        }
    }
}

impl DeployConfig {
    /// Load from a TOML-subset file.
    pub fn from_file(path: &str) -> Result<DeployConfig> {
        let src = std::fs::read_to_string(path)?;
        Self::from_str_src(&src)
    }

    pub fn from_str_src(src: &str) -> Result<DeployConfig> {
        let doc = parse(src)?;
        let mut cfg = DeployConfig::default();
        let get_f = |sec: &str, key: &str, d: f64| -> f64 {
            doc.get(sec, key).and_then(|v| v.as_f64()).unwrap_or(d)
        };
        let get_i = |sec: &str, key: &str, d: i64| -> i64 {
            doc.get(sec, key).and_then(|v| v.as_int()).unwrap_or(d)
        };
        if let Some(v) = doc.get("server", "artifacts_dir").and_then(|v| v.as_str()) {
            cfg.artifacts_dir = v.to_string();
        }
        if let Some(v) = doc.get("server", "plan").and_then(|v| v.as_str()) {
            cfg.plan_path = Some(v.to_string());
        }
        cfg.max_batch = get_i("server", "max_batch", cfg.max_batch as i64) as usize;
        cfg.batch_wait_ms = get_i("server", "batch_wait_ms", cfg.batch_wait_ms as i64) as u64;
        cfg.max_new_tokens =
            get_i("server", "max_new_tokens", cfg.max_new_tokens as i64) as u64;
        cfg.admission_rate = get_f("admission", "rate", cfg.admission_rate);
        cfg.admission_burst = get_f("admission", "burst", cfg.admission_burst);
        cfg.sla_ttft_ms = get_f("sla", "ttft_ms", cfg.sla_ttft_ms);
        cfg.sla_tbt_ms = get_f("sla", "tbt_ms", cfg.sla_tbt_ms);
        cfg.orch_window_s = get_f("orchestrator", "window_s", cfg.orch_window_s);
        cfg.orch_high_watermark =
            get_f("orchestrator", "high_watermark", cfg.orch_high_watermark);
        cfg.orch_low_watermark =
            get_f("orchestrator", "low_watermark", cfg.orch_low_watermark);
        cfg.orch_patience =
            get_i("orchestrator", "patience", cfg.orch_patience as i64) as u32;
        cfg.orch_min_pipelines =
            get_i("orchestrator", "min_pipelines", cfg.orch_min_pipelines as i64) as u32;
        cfg.orch_max_pipelines =
            get_i("orchestrator", "max_pipelines", cfg.orch_max_pipelines as i64) as u32;
        cfg.orch_max_cpu_workers = get_i(
            "orchestrator",
            "max_cpu_workers",
            cfg.orch_max_cpu_workers as i64,
        ) as u32;
        if let Some(workers) = doc.table_arrays.get("worker") {
            cfg.workers = workers
                .iter()
                .enumerate()
                .map(|(i, t)| {
                    let name = t
                        .get("name")
                        .and_then(|v| v.as_str())
                        .map(|s| s.to_string())
                        .unwrap_or_else(|| format!("worker{i}"));
                    let models = match t.get("models") {
                        Some(Value::Array(xs)) => xs
                            .iter()
                            .filter_map(|v| v.as_str().map(|s| s.to_string()))
                            .collect(),
                        _ => vec!["tiny-llama".to_string()],
                    };
                    (name, models)
                })
                .collect();
        }
        Ok(cfg)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"
# deployment config
title = "prod"

[server]
artifacts_dir = "artifacts"   # relative to cwd
max_batch = 8
batch_wait_ms = 3
max_new_tokens = 16

[admission]
rate = 500.0
burst = 50.0

[sla]
ttft_ms = 250.0
tbt_ms = 20.0

[[worker]]
name = "w0"
models = ["tiny-llama", "tiny-llama-2"]

[[worker]]
name = "w1"
models = ["tiny-llama"]
"#;

    #[test]
    fn parses_sections_and_types() {
        let doc = parse(SAMPLE).unwrap();
        assert_eq!(doc.root["title"], Value::Str("prod".into()));
        assert_eq!(doc.get("server", "max_batch"), Some(&Value::Int(8)));
        assert_eq!(doc.get("admission", "rate"), Some(&Value::Float(500.0)));
        assert_eq!(doc.table_arrays["worker"].len(), 2);
    }

    #[test]
    fn typed_config_loads() {
        let cfg = DeployConfig::from_str_src(SAMPLE).unwrap();
        assert_eq!(cfg.max_batch, 8);
        assert_eq!(cfg.batch_wait_ms, 3);
        assert_eq!(cfg.admission_rate, 500.0);
        assert_eq!(cfg.sla_tbt_ms, 20.0);
        assert_eq!(cfg.workers.len(), 2);
        assert_eq!(cfg.workers[0].1.len(), 2);
    }

    #[test]
    fn defaults_on_missing_keys() {
        let cfg = DeployConfig::from_str_src("[server]\nmax_batch = 2\n").unwrap();
        assert_eq!(cfg.max_batch, 2);
        assert_eq!(cfg.sla_ttft_ms, 250.0); // default
        assert_eq!(cfg.workers.len(), 1);
        assert_eq!(cfg.plan_path, None);
    }

    #[test]
    fn orchestrator_section_parses_with_defaults() {
        let cfg = DeployConfig::from_str_src(
            "[orchestrator]\nwindow_s = 2.5\nhigh_watermark = 0.9\npatience = 2\n",
        )
        .unwrap();
        assert_eq!(cfg.orch_window_s, 2.5);
        assert_eq!(cfg.orch_high_watermark, 0.9);
        assert_eq!(cfg.orch_patience, 2);
        // Unset keys keep autoscaler defaults.
        assert_eq!(cfg.orch_low_watermark, 0.30);
        assert_eq!(cfg.orch_min_pipelines, 1);
        assert_eq!(cfg.orch_max_pipelines, 64);
    }

    #[test]
    fn plan_path_parses() {
        let cfg =
            DeployConfig::from_str_src("[server]\nplan = \"voice.plan.json\"\n").unwrap();
        assert_eq!(cfg.plan_path.as_deref(), Some("voice.plan.json"));
    }

    #[test]
    fn arrays_and_comments() {
        let doc = parse("xs = [1, 2, 3] # trailing\nname = \"a#b\"\n").unwrap();
        assert_eq!(
            doc.root["xs"],
            Value::Array(vec![Value::Int(1), Value::Int(2), Value::Int(3)])
        );
        assert_eq!(doc.root["name"], Value::Str("a#b".into()));
    }

    #[test]
    fn bad_line_errors_with_position() {
        match parse("ok = 1\nbroken line\n") {
            Err(Error::Parse { line, .. }) => assert_eq!(line, 2),
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn value_accessors() {
        assert_eq!(Value::Int(3).as_f64(), Some(3.0));
        assert_eq!(Value::Bool(true).as_bool(), Some(true));
        assert_eq!(Value::Str("x".into()).as_int(), None);
    }
}
