//! API-compatible stand-in for the PJRT engine (built when the `pjrt`
//! feature is off), plus a **synthetic** in-process byte-LM.
//!
//! The real engine in `engine.rs` drives compiled HLO through the PJRT
//! C API. Without the xla toolchain there are two modes:
//!
//! * the plain stub (`Engine::load` always fails with a clear message,
//!   so call-sites degrade exactly as they do when the artifact bundle
//!   is missing), and
//! * a **synthetic engine** ([`Engine::synthetic`]) — a deterministic
//!   hash-mix byte LM that honours the full prefill/decode API. Decode
//!   steps do work proportional to the attended context, so relative
//!   stage costs (decode ≫ prefill per token stream) mirror the real
//!   runtime. This is what lets the live serving stack — admission,
//!   batcher, host pool, full agent-DAG execution — run end-to-end in
//!   dependency-free builds and be conformance-tested against the DAG
//!   simulator (`rust/tests/sim_vs_live.rs`).

use std::path::{Path, PathBuf};

use super::manifest::Manifest;
use crate::{Error, Result};

/// Opaque KV cache state for one in-flight batch (synthetic mode keeps
/// a per-lane hash state standing in for the KV tensors).
pub struct KvState {
    pub bucket: usize,
    /// Current absolute position per lane (next write index).
    pub pos: Vec<i32>,
    /// Per-lane rolling context hash (synthetic attention state).
    state: Vec<u64>,
}

impl KvState {
    /// Bytes held by this state (synthetic mode holds only hashes).
    pub fn bytes(&self) -> usize {
        self.state.len() * 8
    }
}

/// Result of a prefill call.
pub struct PrefillResult {
    pub logits: Vec<Vec<f32>>,
    pub kv: KvState,
}

fn unavailable() -> Error {
    Error::Runtime(
        "PJRT engine unavailable: built without the `pjrt` feature \
         (rebuild with `--features pjrt` and a vendored xla crate, or \
         construct Engine::synthetic for the in-process byte LM)"
            .into(),
    )
}

/// splitmix64 — the same mixer `util::rng` builds on.
fn mix(x: u64) -> u64 {
    let mut z = x.wrapping_add(0x9E37_79B9_7F4A_7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// The per-node engine (stub / synthetic).
pub struct Engine {
    pub manifest: Manifest,
    /// When true, prefill/decode run the deterministic hash-mix LM
    /// instead of failing.
    synthetic: bool,
}

// The serving pool shares each engine (`Arc<Engine>`) with its worker
// thread; keep the stub honest about the same bound the PJRT engine
// must satisfy.
const _: () = {
    const fn assert_send_sync<T: Send + Sync>() {}
    assert_send_sync::<Engine>();
};

impl Engine {
    /// Always fails: the stub cannot execute artifacts.
    pub fn load(dir: impl AsRef<Path>) -> Result<Engine> {
        // Validate the manifest anyway so error messages distinguish
        // "no artifacts" from "no PJRT".
        let _ = Manifest::load(dir)?;
        Err(unavailable())
    }

    /// A deterministic in-process byte LM honouring the engine API —
    /// no artifacts, no PJRT. See module docs.
    pub fn synthetic(manifest: Manifest) -> Engine {
        Engine {
            manifest,
            synthetic: true,
        }
    }

    /// [`Engine::synthetic`] over a built-in tiny manifest (byte vocab,
    /// 96-token prompt bucket, 64-token decode budget).
    pub fn synthetic_default() -> Engine {
        Engine::synthetic(Manifest {
            dir: PathBuf::new(),
            vocab: 256,
            d_model: 64,
            n_layers: 2,
            n_heads: 2,
            n_kv_heads: 2,
            head_dim: 32,
            max_seq: 160,
            prefill_seq: 96,
            buckets: vec![1, 2, 4, 8],
            num_params: 1_000,
            kv_cache_bytes_b1: 1_024,
        })
    }

    /// A pool of `n` independent synthetic engines (≥ 1) sharing the
    /// default manifest — the dependency-free stand-in for "one engine
    /// per plan pipeline group" multi-engine serving
    /// ([`crate::server::Server::with_engines`]).
    pub fn synthetic_pool(n: usize) -> Vec<std::sync::Arc<Engine>> {
        (0..n.max(1))
            .map(|_| std::sync::Arc::new(Engine::synthetic_default()))
            .collect()
    }

    pub fn platform(&self) -> String {
        if self.synthetic {
            "synthetic".to_string()
        } else {
            "stub".to_string()
        }
    }

    /// Logits for one lane from its context hash.
    fn logits_of(&self, state: u64) -> Vec<f32> {
        let v = self.manifest.vocab.max(1);
        (0..v)
            .map(|b| {
                // Low 16 bits of a per-byte mix → [0, 1) range logits.
                (mix(state ^ (b as u64)) & 0xFFFF) as f32 / 65536.0
            })
            .collect()
    }

    pub fn prefill(&self, prompts: &[Vec<u8>]) -> Result<PrefillResult> {
        if !self.synthetic {
            return Err(unavailable());
        }
        if prompts.is_empty() {
            return Err(Error::Runtime("prefill on empty batch".into()));
        }
        let mut logits = Vec::with_capacity(prompts.len());
        let mut pos = Vec::with_capacity(prompts.len());
        let mut state = Vec::with_capacity(prompts.len());
        for p in prompts {
            let take = p.len().min(self.manifest.prefill_seq);
            let mut h = 0x5EED_u64;
            for &b in &p[p.len() - take..] {
                h = mix(h ^ (b as u64));
            }
            logits.push(self.logits_of(h));
            pos.push(take as i32);
            state.push(h);
        }
        Ok(PrefillResult {
            logits,
            kv: KvState {
                bucket: prompts.len(),
                pos,
                state,
            },
        })
    }

    pub fn decode_step(&self, kv: &mut KvState, tokens: &[u8]) -> Result<Vec<Vec<f32>>> {
        if !self.synthetic {
            return Err(unavailable());
        }
        if tokens.len() < kv.state.len() {
            return Err(Error::Runtime(format!(
                "decode_step fed {} tokens for {} lanes",
                tokens.len(),
                kv.state.len()
            )));
        }
        let mut out = Vec::with_capacity(kv.state.len());
        for i in 0..kv.state.len() {
            let mut h = mix(kv.state[i] ^ (tokens[i] as u64));
            // Synthetic attention: touch every cached position so a
            // decode step costs O(context), as the real kernel does.
            for p in 0..kv.pos[i].max(0) as u64 {
                h ^= mix(h ^ p);
            }
            kv.state[i] = h;
            kv.pos[i] += 1;
            out.push(self.logits_of(h));
        }
        Ok(out)
    }

    pub fn generate_greedy(
        &self,
        prompts: &[Vec<u8>],
        max_new: usize,
    ) -> Result<Vec<Vec<u8>>> {
        if !self.synthetic {
            return Err(unavailable());
        }
        let pre = self.prefill(prompts)?;
        let mut kv = pre.kv;
        let n = prompts.len();
        let mut outputs: Vec<Vec<u8>> = vec![Vec::new(); n];
        let mut next: Vec<u8> = vec![0; n];
        for i in 0..n {
            let tok = argmax(&pre.logits[i]) as u8;
            next[i] = tok;
            if max_new > 0 {
                outputs[i].push(tok);
            }
        }
        let budget = self
            .manifest
            .max_seq
            .saturating_sub(self.manifest.prefill_seq)
            .saturating_sub(1);
        for _ in 1..max_new.min(budget + 1) {
            let logits = self.decode_step(&mut kv, &next)?;
            for i in 0..n {
                let tok = argmax(&logits[i]) as u8;
                next[i] = tok;
                outputs[i].push(tok);
            }
        }
        Ok(outputs)
    }
}

/// Argmax over logits (0 on empty — callers guard).
pub fn argmax(xs: &[f32]) -> usize {
    xs.iter()
        .enumerate()
        .max_by(|a, b| a.1.partial_cmp(b.1).unwrap_or(std::cmp::Ordering::Equal))
        .map(|(i, _)| i)
        .unwrap_or(0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn argmax_basics() {
        assert_eq!(argmax(&[0.1, 0.9, 0.5]), 1);
        assert_eq!(argmax(&[3.0]), 0);
        assert_eq!(argmax(&[]), 0);
        assert_eq!(argmax(&[f32::NAN, 1.0]), 1);
    }

    #[test]
    fn stub_load_reports_feature_gate() {
        // Nonexistent dir: the manifest error surfaces first.
        assert!(Engine::load("/nonexistent").is_err());
    }

    #[test]
    fn synthetic_generation_is_deterministic() {
        let e = Engine::synthetic_default();
        assert_eq!(e.platform(), "synthetic");
        let prompts = vec![b"the system ".to_vec(), b"another lane".to_vec()];
        let a = e.generate_greedy(&prompts, 12).unwrap();
        let b = e.generate_greedy(&prompts, 12).unwrap();
        assert_eq!(a, b, "same prompts must generate the same bytes");
        assert_eq!(a[0].len(), 12);
        assert_ne!(a[0], a[1], "different prompts should diverge");
    }

    #[test]
    fn synthetic_pool_builds_independent_engines() {
        let pool = Engine::synthetic_pool(3);
        assert_eq!(pool.len(), 3);
        // Same manifest, same deterministic LM: any engine of the pool
        // reconstructs the same state from the same context — the
        // property the split prefill/decode phases rely on.
        let a = pool[0].generate_greedy(&[b"ctx".to_vec()], 6).unwrap();
        let b = pool[2].generate_greedy(&[b"ctx".to_vec()], 6).unwrap();
        assert_eq!(a, b);
        assert_eq!(Engine::synthetic_pool(0).len(), 1, "pool floors at 1");
    }

    #[test]
    fn synthetic_lanes_are_independent() {
        let e = Engine::synthetic_default();
        let solo = e.generate_greedy(&[b"hello".to_vec()], 8).unwrap();
        let pair = e
            .generate_greedy(&[b"hello".to_vec(), b"world".to_vec()], 8)
            .unwrap();
        assert_eq!(solo[0], pair[0], "batch lane 0 must match solo run");
    }

    #[test]
    fn synthetic_respects_decode_budget() {
        let e = Engine::synthetic_default();
        let budget = e.manifest.max_seq - e.manifest.prefill_seq;
        let out = e.generate_greedy(&[vec![b'a'; 200]], budget + 50).unwrap();
        assert!(out[0].len() <= budget, "generated past the KV budget");
    }

    #[test]
    fn plain_stub_still_fails_closed() {
        let mut e = Engine::synthetic_default();
        e.synthetic = false;
        assert!(e.prefill(&[b"x".to_vec()]).is_err());
        assert!(e.generate_greedy(&[b"x".to_vec()], 4).is_err());
    }
}
