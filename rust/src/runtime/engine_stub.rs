//! API-compatible stand-in for the PJRT engine (built when the `pjrt`
//! feature is off). The real engine in `engine.rs` drives compiled HLO
//! through the PJRT C API; this stub keeps every dependent layer —
//! server, router, CLI, tests — compiling and running on machines
//! without the xla toolchain. `Engine::load` always fails with a clear
//! message, so call-sites degrade exactly as they do when the artifact
//! bundle is missing.

use std::path::Path;

use super::manifest::Manifest;
use crate::{Error, Result};

/// Opaque KV cache state for one in-flight batch (stub: no buffers).
pub struct KvState {
    pub bucket: usize,
    /// Current absolute position per lane (next write index).
    pub pos: Vec<i32>,
}

impl KvState {
    /// Bytes held by this state (stub holds none).
    pub fn bytes(&self) -> usize {
        0
    }
}

/// Result of a prefill call.
pub struct PrefillResult {
    pub logits: Vec<Vec<f32>>,
    pub kv: KvState,
}

fn unavailable() -> Error {
    Error::Runtime(
        "PJRT engine unavailable: built without the `pjrt` feature \
         (rebuild with `--features pjrt` and a vendored xla crate)"
            .into(),
    )
}

/// The per-node engine (stub).
pub struct Engine {
    pub manifest: Manifest,
}

impl Engine {
    /// Always fails: the stub cannot execute artifacts.
    pub fn load(dir: impl AsRef<Path>) -> Result<Engine> {
        // Validate the manifest anyway so error messages distinguish
        // "no artifacts" from "no PJRT".
        let _ = Manifest::load(dir)?;
        Err(unavailable())
    }

    pub fn platform(&self) -> String {
        "stub".to_string()
    }

    pub fn prefill(&self, _prompts: &[Vec<u8>]) -> Result<PrefillResult> {
        Err(unavailable())
    }

    pub fn decode_step(&self, _kv: &mut KvState, _tokens: &[u8]) -> Result<Vec<Vec<f32>>> {
        Err(unavailable())
    }

    pub fn generate_greedy(
        &self,
        _prompts: &[Vec<u8>],
        _max_new: usize,
    ) -> Result<Vec<Vec<u8>>> {
        Err(unavailable())
    }
}

/// Argmax over logits (0 on empty — callers guard).
pub fn argmax(xs: &[f32]) -> usize {
    xs.iter()
        .enumerate()
        .max_by(|a, b| a.1.partial_cmp(b.1).unwrap_or(std::cmp::Ordering::Equal))
        .map(|(i, _)| i)
        .unwrap_or(0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn argmax_basics() {
        assert_eq!(argmax(&[0.1, 0.9, 0.5]), 1);
        assert_eq!(argmax(&[3.0]), 0);
        assert_eq!(argmax(&[]), 0);
        assert_eq!(argmax(&[f32::NAN, 1.0]), 1);
    }

    #[test]
    fn stub_load_reports_feature_gate() {
        // Nonexistent dir: the manifest error surfaces first.
        assert!(Engine::load("/nonexistent").is_err());
    }
}
