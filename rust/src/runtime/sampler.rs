//! Token sampling: greedy, temperature, and top-k over logits.

use crate::util::rng::Rng;

/// Sampling configuration.
#[derive(Debug, Clone)]
pub struct Sampler {
    /// 0.0 = greedy.
    pub temperature: f64,
    /// 0 = no top-k truncation.
    pub top_k: usize,
    rng: Rng,
}

impl Sampler {
    pub fn greedy() -> Sampler {
        Sampler {
            temperature: 0.0,
            top_k: 0,
            rng: Rng::new(0),
        }
    }

    pub fn new(temperature: f64, top_k: usize, seed: u64) -> Sampler {
        Sampler {
            temperature,
            top_k,
            rng: Rng::new(seed),
        }
    }

    /// Sample one token id from logits.
    pub fn sample(&mut self, logits: &[f32]) -> usize {
        if logits.is_empty() {
            return 0;
        }
        if self.temperature <= 0.0 {
            return super::engine::argmax(logits);
        }
        // Temperature softmax over (optionally) the top-k logits.
        let mut idx: Vec<usize> = (0..logits.len()).collect();
        if self.top_k > 0 && self.top_k < logits.len() {
            idx.sort_by(|&a, &b| logits[b].partial_cmp(&logits[a]).unwrap());
            idx.truncate(self.top_k);
        }
        let max = idx
            .iter()
            .map(|&i| logits[i] as f64)
            .fold(f64::NEG_INFINITY, f64::max);
        let weights: Vec<f64> = idx
            .iter()
            .map(|&i| ((logits[i] as f64 - max) / self.temperature).exp())
            .collect();
        let total: f64 = weights.iter().sum();
        let mut u = self.rng.f64() * total;
        for (w, &i) in weights.iter().zip(&idx) {
            u -= w;
            if u <= 0.0 {
                return i;
            }
        }
        *idx.last().unwrap()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn greedy_is_argmax() {
        let mut s = Sampler::greedy();
        assert_eq!(s.sample(&[0.0, 5.0, 1.0]), 1);
    }

    #[test]
    fn temperature_zero_edge() {
        let mut s = Sampler::new(0.0, 0, 1);
        assert_eq!(s.sample(&[1.0, 2.0]), 1);
    }

    #[test]
    fn high_temp_spreads_low_temp_concentrates() {
        let logits = [1.0f32, 0.0, -1.0];
        let count_top = |temp: f64| {
            let mut s = Sampler::new(temp, 0, 42);
            (0..2000).filter(|_| s.sample(&logits) == 0).count()
        };
        let hot = count_top(10.0);
        let cold = count_top(0.05);
        assert!(cold > 1950, "cold={cold}");
        assert!(hot < 1200, "hot={hot}");
    }

    #[test]
    fn top_k_truncates_support() {
        let logits = [5.0f32, 4.0, -100.0, -100.0];
        let mut s = Sampler::new(1.0, 2, 7);
        for _ in 0..500 {
            let t = s.sample(&logits);
            assert!(t < 2, "sampled outside top-k: {t}");
        }
    }

    #[test]
    fn deterministic_by_seed() {
        let logits = [0.5f32, 0.4, 0.3];
        let mut a = Sampler::new(1.0, 0, 9);
        let mut b = Sampler::new(1.0, 0, 9);
        for _ in 0..100 {
            assert_eq!(a.sample(&logits), b.sample(&logits));
        }
    }

    #[test]
    fn empty_logits_safe() {
        assert_eq!(Sampler::greedy().sample(&[]), 0);
    }
}
