//! PJRT execution engine: compiled prefill/decode executables per batch
//! bucket, with KV state threaded between calls.
//!
//! Loading: `HloModuleProto::from_text_file` → `XlaComputation` →
//! `client.compile` — the pattern of `/opt/xla-example/load_hlo.rs`.
//! Outputs arrive as a single tuple buffer (this PJRT build does not
//! untuple), so every execute is followed by `to_literal_sync` +
//! `decompose_tuple`; calling `to_vec`/`shape` on a tuple literal is a
//! fatal CHECK in xla_extension — never do that.

use std::collections::BTreeMap;
use std::path::Path;

use xla::{HloModuleProto, Literal, PjRtClient, PjRtLoadedExecutable, XlaComputation};

use super::manifest::Manifest;
use crate::{Error, Result};

/// Opaque KV cache state for one in-flight batch (host literals).
pub struct KvState {
    pub bucket: usize,
    k: Literal,
    v: Literal,
    /// Current absolute position per lane (next write index).
    pub pos: Vec<i32>,
}

impl KvState {
    /// Bytes held by this state (both sides).
    pub fn bytes(&self) -> usize {
        self.k.size_bytes() + self.v.size_bytes()
    }
}

/// Result of a prefill call.
pub struct PrefillResult {
    /// Per-lane logits over the vocab (only the first `n` lanes of the
    /// bucket are meaningful, where `n` = submitted prompts).
    pub logits: Vec<Vec<f32>>,
    pub kv: KvState,
}

/// The per-node engine.
pub struct Engine {
    pub manifest: Manifest,
    client: PjRtClient,
    prefill: BTreeMap<usize, PjRtLoadedExecutable>,
    decode: BTreeMap<usize, PjRtLoadedExecutable>,
}

// The serving pool shares each engine (`Arc<Engine>`) with its worker
// thread; a PJRT client/executable that stops being thread-shareable
// must fail the build here, not deadlock in production.
const _: () = {
    const fn assert_send_sync<T: Send + Sync>() {}
    assert_send_sync::<Engine>();
};

impl Engine {
    /// Load every bucket's executables from an artifact directory.
    pub fn load(dir: impl AsRef<Path>) -> Result<Engine> {
        let manifest = Manifest::load(dir)?;
        let client = PjRtClient::cpu()?;
        let mut prefill = BTreeMap::new();
        let mut decode = BTreeMap::new();
        for &b in &manifest.buckets {
            prefill.insert(b, compile(&client, &manifest.artifact_path("prefill", b))?);
            decode.insert(b, compile(&client, &manifest.artifact_path("decode", b))?);
        }
        Ok(Engine {
            manifest,
            client,
            prefill,
            decode,
        })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Prefill a batch of prompts (byte tokens). Prompts are truncated /
    /// right-padded to the compiled prompt length; the bucket is the
    /// smallest compiled batch ≥ prompts.len().
    pub fn prefill(&self, prompts: &[Vec<u8>]) -> Result<PrefillResult> {
        if prompts.is_empty() {
            return Err(Error::Runtime("empty prefill batch".into()));
        }
        let bucket = self
            .manifest
            .bucket_for(prompts.len())
            .ok_or_else(|| {
                Error::Capacity(format!(
                    "batch {} exceeds largest bucket {}",
                    prompts.len(),
                    self.manifest.buckets.last().unwrap()
                ))
            })?;
        let seq = self.manifest.prefill_seq;

        let mut tokens = vec![0i32; bucket * seq];
        let mut lens = vec![1i32; bucket];
        for (i, p) in prompts.iter().enumerate() {
            let n = p.len().min(seq).max(1);
            // Keep the *tail* of over-long prompts (most recent context).
            let src = &p[p.len().saturating_sub(seq)..];
            for (j, b) in src.iter().enumerate() {
                tokens[i * seq + j] = *b as i32;
            }
            lens[i] = n as i32;
        }

        let toks_lit = Literal::vec1(&tokens).reshape(&[bucket as i64, seq as i64])?;
        let lens_lit = Literal::vec1(&lens);
        let exe = &self.prefill[&bucket];
        let result = exe.execute::<Literal>(&[toks_lit, lens_lit])?;
        let mut parts = result[0][0].to_literal_sync()?.decompose_tuple()?;
        if parts.len() != 3 {
            return Err(Error::Runtime(format!(
                "prefill returned {} outputs, expected 3",
                parts.len()
            )));
        }
        let v = parts.pop().unwrap();
        let k = parts.pop().unwrap();
        let logits_flat = parts.pop().unwrap().to_vec::<f32>()?;
        let vocab = self.manifest.vocab;
        let logits = (0..bucket)
            .map(|i| logits_flat[i * vocab..(i + 1) * vocab].to_vec())
            .collect();
        let pos = lens.clone();
        Ok(PrefillResult {
            logits,
            kv: KvState { bucket, k, v, pos },
        })
    }

    /// One decode step for the whole batch: feeds `tokens[i]` at each
    /// lane's current position, returns next-token logits per lane.
    /// Lanes past their request's end can feed any token; callers ignore
    /// their logits.
    pub fn decode_step(&self, kv: &mut KvState, tokens: &[u8]) -> Result<Vec<Vec<f32>>> {
        if tokens.len() != kv.bucket {
            return Err(Error::Runtime(format!(
                "decode batch {} != bucket {}",
                tokens.len(),
                kv.bucket
            )));
        }
        for p in &kv.pos {
            if *p as usize >= self.manifest.max_seq {
                return Err(Error::Capacity(format!(
                    "KV cache full (max_seq {})",
                    self.manifest.max_seq
                )));
            }
        }
        let toks: Vec<i32> = tokens.iter().map(|t| *t as i32).collect();
        let tok_lit = Literal::vec1(&toks);
        let pos_lit = Literal::vec1(&kv.pos);
        let exe = &self.decode[&kv.bucket];
        // KV literals move in by reference; outputs replace them.
        let result = exe.execute::<&Literal>(&[&tok_lit, &pos_lit, &kv.k, &kv.v])?;
        let mut parts = result[0][0].to_literal_sync()?.decompose_tuple()?;
        if parts.len() != 3 {
            return Err(Error::Runtime(format!(
                "decode returned {} outputs, expected 3",
                parts.len()
            )));
        }
        kv.v = parts.pop().unwrap();
        kv.k = parts.pop().unwrap();
        let logits_flat = parts.pop().unwrap().to_vec::<f32>()?;
        for p in kv.pos.iter_mut() {
            *p += 1;
        }
        let vocab = self.manifest.vocab;
        Ok((0..kv.bucket)
            .map(|i| logits_flat[i * vocab..(i + 1) * vocab].to_vec())
            .collect())
    }

    /// Convenience: greedy-generate `max_new` tokens for a batch of
    /// prompts (used by tests and the quickstart example).
    pub fn generate_greedy(
        &self,
        prompts: &[Vec<u8>],
        max_new: usize,
    ) -> Result<Vec<Vec<u8>>> {
        let pre = self.prefill(prompts)?;
        let mut kv = pre.kv;
        let mut out: Vec<Vec<u8>> = vec![Vec::new(); prompts.len()];
        let mut next: Vec<u8> = (0..kv.bucket)
            .map(|i| {
                argmax(pre.logits.get(i).map(|l| l.as_slice()).unwrap_or(&[])) as u8
            })
            .collect();
        for (i, o) in out.iter_mut().enumerate() {
            o.push(next[i]);
        }
        for _ in 1..max_new {
            if kv.pos.iter().any(|p| *p as usize >= self.manifest.max_seq) {
                break;
            }
            let logits = self.decode_step(&mut kv, &next)?;
            for i in 0..prompts.len() {
                next[i] = argmax(&logits[i]) as u8;
                out[i].push(next[i]);
            }
        }
        Ok(out)
    }
}

/// Argmax over logits (0 on empty — callers guard).
pub fn argmax(xs: &[f32]) -> usize {
    xs.iter()
        .enumerate()
        .max_by(|a, b| a.1.partial_cmp(b.1).unwrap_or(std::cmp::Ordering::Equal))
        .map(|(i, _)| i)
        .unwrap_or(0)
}

fn compile(client: &PjRtClient, path: &Path) -> Result<PjRtLoadedExecutable> {
    let proto = HloModuleProto::from_text_file(
        path.to_str()
            .ok_or_else(|| Error::Runtime("non-utf8 artifact path".into()))?,
    )?;
    let comp = XlaComputation::from_proto(&proto);
    Ok(client.compile(&comp)?)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn argmax_basics() {
        assert_eq!(argmax(&[0.1, 0.9, 0.5]), 1);
        assert_eq!(argmax(&[3.0]), 0);
        assert_eq!(argmax(&[]), 0);
        assert_eq!(argmax(&[f32::NAN, 1.0]), 1);
    }

    // Engine execution tests live in rust/tests/runtime_e2e.rs (they
    // need the artifact bundle from `make artifacts`).
}
