//! Per-node runtime (paper §4.1 "Runtime"): loads AOT artifacts and
//! executes model stages through the PJRT C API — Python never runs on
//! the request path.
//!
//! * [`manifest`] — the artifact bundle description written by
//!   `python/compile/aot.py`;
//! * [`engine`] — PJRT client + compiled executables per batch bucket,
//!   exposing `prefill` / `decode` with host-side KV state handles;
//! * [`sampler`] — greedy / temperature token sampling.
//!
//! Interchange is HLO *text*: jax ≥ 0.5 emits protos with 64-bit
//! instruction ids which xla_extension 0.5.1 rejects; the text parser
//! reassigns ids (see `/opt/xla-example/README.md`).

#[cfg(feature = "pjrt")]
pub mod engine;
/// Without the `pjrt` feature the engine is an API-compatible stub:
/// everything compiles and the serving stack is testable, but
/// `Engine::load` reports that PJRT is unavailable. This keeps
/// `cargo test -q` green on machines without an accelerator toolchain.
#[cfg(not(feature = "pjrt"))]
#[path = "engine_stub.rs"]
pub mod engine;
pub mod manifest;
pub mod sampler;

pub use engine::{Engine, KvState, PrefillResult};
pub use manifest::Manifest;
pub use sampler::Sampler;
