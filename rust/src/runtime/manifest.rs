//! Artifact-bundle manifest (`artifacts/manifest.txt`), written by
//! `python/compile/aot.py` as `key=value` lines.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use crate::{Error, Result};

/// Parsed manifest: model hyperparameters + compiled buckets.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub dir: PathBuf,
    pub vocab: usize,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub n_kv_heads: usize,
    pub head_dim: usize,
    pub max_seq: usize,
    pub prefill_seq: usize,
    /// Batch buckets, ascending.
    pub buckets: Vec<usize>,
    pub num_params: u64,
    pub kv_cache_bytes_b1: u64,
}

impl Manifest {
    /// Load and validate `dir/manifest.txt`.
    pub fn load(dir: impl AsRef<Path>) -> Result<Manifest> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.txt");
        let text = std::fs::read_to_string(&path).map_err(|e| {
            Error::Runtime(format!(
                "cannot read {} (run `make artifacts`): {e}",
                path.display()
            ))
        })?;
        let kv = parse_kv(&text);
        let get = |k: &str| -> Result<&str> {
            kv.get(k)
                .map(|s| s.as_str())
                .ok_or_else(|| Error::Runtime(format!("manifest missing key {k}")))
        };
        let get_usize = |k: &str| -> Result<usize> {
            get(k)?
                .parse()
                .map_err(|e| Error::Runtime(format!("manifest {k}: {e}")))
        };
        let buckets: Vec<usize> = get("buckets")?
            .split(',')
            .map(|s| s.trim().parse::<usize>())
            .collect::<std::result::Result<_, _>>()
            .map_err(|e| Error::Runtime(format!("manifest buckets: {e}")))?;
        if buckets.is_empty() {
            return Err(Error::Runtime("manifest has no buckets".into()));
        }
        let m = Manifest {
            dir,
            vocab: get_usize("vocab")?,
            d_model: get_usize("d_model")?,
            n_layers: get_usize("n_layers")?,
            n_heads: get_usize("n_heads")?,
            n_kv_heads: get_usize("n_kv_heads")?,
            head_dim: get_usize("head_dim")?,
            max_seq: get_usize("max_seq")?,
            prefill_seq: get_usize("prefill_seq")?,
            buckets,
            num_params: get_usize("num_params")? as u64,
            kv_cache_bytes_b1: get_usize("kv_cache_bytes_b1")? as u64,
        };
        m.validate()?;
        Ok(m)
    }

    fn validate(&self) -> Result<()> {
        if self.prefill_seq > self.max_seq {
            return Err(Error::Runtime(format!(
                "prefill_seq {} exceeds max_seq {}",
                self.prefill_seq, self.max_seq
            )));
        }
        let mut sorted = self.buckets.clone();
        sorted.sort_unstable();
        if sorted != self.buckets {
            return Err(Error::Runtime("buckets must be ascending".into()));
        }
        for b in &self.buckets {
            for stem in ["prefill", "decode"] {
                let p = self.artifact_path(stem, *b);
                if !p.exists() {
                    return Err(Error::Runtime(format!(
                        "missing artifact {}",
                        p.display()
                    )));
                }
            }
        }
        Ok(())
    }

    pub fn artifact_path(&self, stem: &str, bucket: usize) -> PathBuf {
        self.dir.join(format!("{stem}_b{bucket}.hlo.txt"))
    }

    /// Smallest bucket that fits `batch` requests.
    pub fn bucket_for(&self, batch: usize) -> Option<usize> {
        self.buckets.iter().find(|b| **b >= batch).copied()
    }

    /// KV-state shape per side (k or v): (L, B, Hkv, Smax, D).
    pub fn kv_dims(&self, bucket: usize) -> [usize; 5] {
        [
            self.n_layers,
            bucket,
            self.n_kv_heads,
            self.max_seq,
            self.head_dim,
        ]
    }
}

fn parse_kv(text: &str) -> BTreeMap<String, String> {
    text.lines()
        .filter_map(|l| {
            let l = l.trim();
            if l.is_empty() || l.starts_with('#') {
                return None;
            }
            l.split_once('=')
                .map(|(k, v)| (k.trim().to_string(), v.trim().to_string()))
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn write_bundle(dir: &Path, buckets: &str) {
        std::fs::create_dir_all(dir).unwrap();
        let manifest = format!(
            "format=1\nvocab=256\nd_model=96\nn_layers=3\nn_heads=4\nn_kv_heads=2\n\
             head_dim=24\nd_ff=256\nmax_seq=96\nprefill_seq=64\nbuckets={buckets}\n\
             num_params=329376\nkv_cache_bytes_b1=55296\n"
        );
        std::fs::write(dir.join("manifest.txt"), manifest).unwrap();
        for b in buckets.split(',') {
            for stem in ["prefill", "decode"] {
                std::fs::write(dir.join(format!("{stem}_b{b}.hlo.txt")), "HloModule x")
                    .unwrap();
            }
        }
    }

    fn tmpdir(name: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!("ah-manifest-{name}-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&d);
        d
    }

    #[test]
    fn loads_valid_bundle() {
        let d = tmpdir("ok");
        write_bundle(&d, "1,2,4");
        let m = Manifest::load(&d).unwrap();
        assert_eq!(m.vocab, 256);
        assert_eq!(m.buckets, vec![1, 2, 4]);
        assert_eq!(m.kv_dims(2), [3, 2, 2, 96, 24]);
        assert_eq!(m.bucket_for(3), Some(4));
        assert_eq!(m.bucket_for(1), Some(1));
        assert_eq!(m.bucket_for(9), None);
    }

    #[test]
    fn missing_artifact_rejected() {
        let d = tmpdir("missing");
        write_bundle(&d, "1,2");
        std::fs::remove_file(d.join("decode_b2.hlo.txt")).unwrap();
        assert!(Manifest::load(&d).is_err());
    }

    #[test]
    fn missing_manifest_hint() {
        let d = tmpdir("nomanifest");
        std::fs::create_dir_all(&d).unwrap();
        let err = Manifest::load(&d).unwrap_err().to_string();
        assert!(err.contains("make artifacts"), "{err}");
    }

    #[test]
    fn real_bundle_if_present() {
        // When `make artifacts` has run, validate the real bundle too.
        let root = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if root.join("manifest.txt").exists() {
            let m = Manifest::load(&root).unwrap();
            assert!(m.num_params > 0);
            assert!(!m.buckets.is_empty());
        }
    }
}
