//! Pareto-frontier utilities (§3.1: "this is often a multi-objective
//! problem, where Pareto-optimal solutions must balance tradeoffs
//! between cost, latency, energy, or other constraints").

/// A candidate point: both axes are minimized (e.g. cost, latency).
#[derive(Debug, Clone, PartialEq)]
pub struct Point<T> {
    pub cost: f64,
    pub latency: f64,
    pub tag: T,
}

/// True iff `a` dominates `b` (no worse on both axes, better on one).
pub fn dominates<T>(a: &Point<T>, b: &Point<T>) -> bool {
    a.cost <= b.cost
        && a.latency <= b.latency
        && (a.cost < b.cost || a.latency < b.latency)
}

/// Extract the Pareto frontier, sorted by ascending cost.
pub fn frontier<T: Clone>(points: &[Point<T>]) -> Vec<Point<T>> {
    let mut sorted: Vec<Point<T>> = points.to_vec();
    // Sort by cost asc, then latency asc; sweep keeping decreasing latency.
    sorted.sort_by(|a, b| {
        a.cost
            .partial_cmp(&b.cost)
            .unwrap()
            .then(a.latency.partial_cmp(&b.latency).unwrap())
    });
    let mut out: Vec<Point<T>> = Vec::new();
    let mut best_latency = f64::INFINITY;
    for p in sorted {
        if p.latency < best_latency {
            best_latency = p.latency;
            out.push(p);
        }
    }
    out
}

/// The cheapest point meeting a latency bound, if any.
pub fn cheapest_within<T: Clone>(points: &[Point<T>], latency_bound: f64) -> Option<Point<T>> {
    points
        .iter()
        .filter(|p| p.latency <= latency_bound)
        .min_by(|a, b| a.cost.partial_cmp(&b.cost).unwrap())
        .cloned()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;
    use crate::util::rng::Rng;

    fn pt(cost: f64, latency: f64) -> Point<u32> {
        Point {
            cost,
            latency,
            tag: 0,
        }
    }

    #[test]
    fn domination_rules() {
        assert!(dominates(&pt(1.0, 1.0), &pt(2.0, 2.0)));
        assert!(dominates(&pt(1.0, 2.0), &pt(1.0, 3.0)));
        assert!(!dominates(&pt(1.0, 1.0), &pt(1.0, 1.0))); // equal: no
        assert!(!dominates(&pt(1.0, 3.0), &pt(2.0, 2.0))); // trade-off
    }

    #[test]
    fn frontier_removes_dominated() {
        let pts = vec![pt(1.0, 5.0), pt(2.0, 3.0), pt(3.0, 4.0), pt(4.0, 1.0)];
        let f = frontier(&pts);
        let coords: Vec<(f64, f64)> = f.iter().map(|p| (p.cost, p.latency)).collect();
        assert_eq!(coords, vec![(1.0, 5.0), (2.0, 3.0), (4.0, 1.0)]);
    }

    #[test]
    fn cheapest_within_bound() {
        let pts = vec![pt(1.0, 5.0), pt(2.0, 3.0), pt(4.0, 1.0)];
        assert_eq!(cheapest_within(&pts, 3.5).unwrap().cost, 2.0);
        assert_eq!(cheapest_within(&pts, 10.0).unwrap().cost, 1.0);
        assert!(cheapest_within(&pts, 0.5).is_none());
    }

    #[test]
    fn frontier_property_no_internal_domination() {
        prop::check("pareto-frontier-antichain", |rng: &mut Rng| {
            let pts: Vec<Point<u32>> = (0..rng.index(40) + 1)
                .map(|i| Point {
                    cost: rng.f64() * 10.0,
                    latency: rng.f64() * 10.0,
                    tag: i as u32,
                })
                .collect();
            let f = frontier(&pts);
            // No frontier point dominates another.
            for a in &f {
                for b in &f {
                    if a.tag != b.tag {
                        assert!(!dominates(a, b), "frontier not an antichain");
                    }
                }
            }
            // Every input point is dominated-or-equal by some frontier pt.
            for p in &pts {
                assert!(
                    f.iter().any(|q| dominates(q, p)
                        || (q.cost == p.cost && q.latency == p.latency)),
                    "point not covered by frontier"
                );
            }
        });
    }
}
