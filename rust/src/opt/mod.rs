//! Cost-aware optimization framework (paper §3.1).
//!
//! * [`lp`] — dense two-phase simplex solver (the offline registry has
//!   no LP crate; problem sizes are |V|·|H| + |V| slack variables, tiny);
//! * [`milp`] — branch & bound over the LP relaxation for integral
//!   assignments `x_ij ∈ {0,1}`;
//! * [`assignment`] — builds the §3.1.2 objective/constraints from an
//!   annotated task graph and solves it (exact for edge-dependent
//!   transfer terms, LP/MILP for the linear part);
//! * [`parallelism`] — the §5 explorer: TP/PP/batch search per device
//!   pair under SLA, producing the Figure 8/9 TCO series;
//! * [`pareto`] — Pareto-frontier utilities for multi-objective reports.

pub mod assignment;
pub mod lp;
pub mod milp;
pub mod parallelism;
pub mod pareto;
