//! Dense two-phase simplex, from scratch.
//!
//! Solves `min c·x  s.t.  A_ub·x <= b_ub,  A_eq·x = b_eq,  x >= 0`.
//!
//! Phase 1 minimizes the sum of artificial variables to find a basic
//! feasible solution; phase 2 optimizes the real objective. Bland's rule
//! guards against cycling. Problem sizes here are tiny (tens of
//! variables), so a dense tableau is the right tool.

/// An LP in standard-ish form (`x >= 0` implicit).
#[derive(Debug, Clone, Default)]
pub struct Lp {
    /// Number of structural variables.
    pub n: usize,
    /// Objective coefficients (minimized).
    pub c: Vec<f64>,
    /// `row · x <= rhs` constraints.
    pub a_ub: Vec<(Vec<f64>, f64)>,
    /// `row · x == rhs` constraints.
    pub a_eq: Vec<(Vec<f64>, f64)>,
}

impl Lp {
    pub fn new(n: usize) -> Lp {
        Lp {
            n,
            c: vec![0.0; n],
            a_ub: Vec::new(),
            a_eq: Vec::new(),
        }
    }

    pub fn minimize(&mut self, c: Vec<f64>) -> &mut Self {
        assert_eq!(c.len(), self.n);
        self.c = c;
        self
    }

    pub fn add_ub(&mut self, row: Vec<f64>, rhs: f64) -> &mut Self {
        assert_eq!(row.len(), self.n);
        self.a_ub.push((row, rhs));
        self
    }

    pub fn add_eq(&mut self, row: Vec<f64>, rhs: f64) -> &mut Self {
        assert_eq!(row.len(), self.n);
        self.a_eq.push((row, rhs));
        self
    }

    /// `row · x >= rhs` convenience (negated <=).
    pub fn add_lb(&mut self, row: Vec<f64>, rhs: f64) -> &mut Self {
        let neg: Vec<f64> = row.iter().map(|v| -v).collect();
        self.add_ub(neg, -rhs)
    }
}

#[derive(Debug, Clone, PartialEq)]
pub enum LpResult {
    Optimal(LpSolution),
    Infeasible,
    Unbounded,
}

#[derive(Debug, Clone, PartialEq)]
pub struct LpSolution {
    pub x: Vec<f64>,
    pub objective: f64,
}

const EPS: f64 = 1e-9;

/// Solve the LP; see module docs.
pub fn solve(lp: &Lp) -> LpResult {
    // Tableau layout: columns = [structural | slack(ub) | artificial], plus rhs.
    let n = lp.n;
    let n_ub = lp.a_ub.len();
    let n_eq = lp.a_eq.len();
    let rows = n_ub + n_eq;

    // Normalize rows to nonnegative rhs.
    // For <= with negative rhs we must flip to >=, which needs an
    // artificial (surplus + artificial). Track per-row: slack col sign.
    #[derive(Clone, Copy)]
    enum RowKind {
        UbPos(usize),  // slack index
        UbNeg(usize),  // surplus index (coef -1) + artificial
        Eq,
    }

    let mut a: Vec<Vec<f64>> = Vec::with_capacity(rows);
    let mut b: Vec<f64> = Vec::with_capacity(rows);
    let mut kinds: Vec<RowKind> = Vec::with_capacity(rows);

    let mut n_slack = 0usize;
    for (row, rhs) in &lp.a_ub {
        if *rhs >= 0.0 {
            a.push(row.clone());
            b.push(*rhs);
            kinds.push(RowKind::UbPos(n_slack));
        } else {
            // -row · x >= -rhs  =>  flip to >= with positive rhs.
            a.push(row.iter().map(|v| -v).collect());
            b.push(-*rhs);
            kinds.push(RowKind::UbNeg(n_slack));
        }
        n_slack += 1;
    }
    for (row, rhs) in &lp.a_eq {
        if *rhs >= 0.0 {
            a.push(row.clone());
            b.push(*rhs);
        } else {
            a.push(row.iter().map(|v| -v).collect());
            b.push(-*rhs);
        }
        kinds.push(RowKind::Eq);
    }

    // Count artificials: UbNeg and Eq rows need one each.
    let mut n_art = 0usize;
    for k in &kinds {
        match k {
            RowKind::UbPos(_) => {}
            _ => n_art += 1,
        }
    }

    let total = n + n_slack + n_art;
    // Build tableau: rows x (total + 1).
    let mut t = vec![vec![0.0; total + 1]; rows];
    let mut basis = vec![0usize; rows];
    let mut art_i = 0usize;
    for (i, kind) in kinds.iter().enumerate() {
        t[i][..n].copy_from_slice(&a[i]);
        t[i][total] = b[i];
        match kind {
            RowKind::UbPos(s) => {
                t[i][n + s] = 1.0;
                basis[i] = n + s;
            }
            RowKind::UbNeg(s) => {
                t[i][n + s] = -1.0; // surplus
                t[i][n + n_slack + art_i] = 1.0;
                basis[i] = n + n_slack + art_i;
                art_i += 1;
            }
            RowKind::Eq => {
                t[i][n + n_slack + art_i] = 1.0;
                basis[i] = n + n_slack + art_i;
                art_i += 1;
            }
        }
    }

    // Phase 1: minimize sum of artificials.
    if n_art > 0 {
        let mut obj = vec![0.0; total + 1];
        for j in n + n_slack..total {
            obj[j] = 1.0;
        }
        // Reduce objective row by basic artificials.
        for (i, &bv) in basis.iter().enumerate() {
            if bv >= n + n_slack {
                for j in 0..=total {
                    obj[j] -= t[i][j];
                }
            }
        }
        if !simplex_iterate(&mut t, &mut obj, &mut basis, total) {
            return LpResult::Unbounded; // cannot happen in phase 1
        }
        if -obj[total] > 1e-7 {
            return LpResult::Infeasible;
        }
        // Drive any artificial still in the basis out (degenerate case).
        for i in 0..rows {
            if basis[i] >= n + n_slack {
                if let Some(j) = (0..n + n_slack).find(|&j| t[i][j].abs() > EPS) {
                    pivot(&mut t, &mut basis, i, j, total);
                }
            }
        }
    }

    // Phase 2: real objective over structural + slack columns.
    let mut obj = vec![0.0; total + 1];
    obj[..n].copy_from_slice(&lp.c);
    // Zero out artificial columns so they never re-enter.
    for row in t.iter_mut() {
        for j in n + n_slack..total {
            row[j] = 0.0;
        }
    }
    // Reduce by current basis.
    for (i, &bv) in basis.iter().enumerate() {
        let coef = obj[bv];
        if coef.abs() > EPS {
            for j in 0..=total {
                obj[j] -= coef * t[i][j];
            }
        }
    }
    if !simplex_iterate(&mut t, &mut obj, &mut basis, total) {
        return LpResult::Unbounded;
    }

    let mut x = vec![0.0; n];
    for (i, &bv) in basis.iter().enumerate() {
        if bv < n {
            x[bv] = t[i][total];
        }
    }
    let objective = lp.c.iter().zip(&x).map(|(c, v)| c * v).sum();
    LpResult::Optimal(LpSolution { x, objective })
}

/// Run simplex pivots until optimal; false if unbounded.
fn simplex_iterate(
    t: &mut [Vec<f64>],
    obj: &mut [f64],
    basis: &mut [usize],
    total: usize,
) -> bool {
    let rows = t.len();
    for _ in 0..20_000 {
        // Entering: Bland's rule — first column with negative reduced cost.
        let Some(enter) = (0..total).find(|&j| obj[j] < -EPS) else {
            return true; // optimal
        };
        // Leaving: min ratio, ties by smallest basis index (Bland).
        let mut leave: Option<usize> = None;
        let mut best = f64::INFINITY;
        for i in 0..rows {
            if t[i][enter] > EPS {
                let ratio = t[i][total] / t[i][enter];
                if ratio < best - EPS
                    || (ratio < best + EPS
                        && leave.map(|l| basis[i] < basis[l]).unwrap_or(false))
                {
                    best = ratio;
                    leave = Some(i);
                }
            }
        }
        let Some(leave) = leave else {
            return false; // unbounded
        };
        pivot_with_obj(t, obj, basis, leave, enter, total);
    }
    true // iteration cap: treat as converged (tiny problems never hit this)
}

fn pivot(t: &mut [Vec<f64>], basis: &mut [usize], row: usize, col: usize, total: usize) {
    let p = t[row][col];
    for j in 0..=total {
        t[row][j] /= p;
    }
    for i in 0..t.len() {
        if i != row && t[i][col].abs() > EPS {
            let f = t[i][col];
            for j in 0..=total {
                t[i][j] -= f * t[row][j];
            }
        }
    }
    basis[row] = col;
}

fn pivot_with_obj(
    t: &mut [Vec<f64>],
    obj: &mut [f64],
    basis: &mut [usize],
    row: usize,
    col: usize,
    total: usize,
) {
    pivot(t, basis, row, col, total);
    let f = obj[col];
    if f.abs() > EPS {
        for j in 0..=total {
            obj[j] -= f * t[row][j];
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn assert_opt(r: &LpResult, x: &[f64], obj: f64) {
        match r {
            LpResult::Optimal(s) => {
                assert!((s.objective - obj).abs() < 1e-6, "obj={} want={}", s.objective, obj);
                for (a, b) in s.x.iter().zip(x) {
                    assert!((a - b).abs() < 1e-6, "x={:?} want={:?}", s.x, x);
                }
            }
            other => panic!("expected optimal, got {other:?}"),
        }
    }

    #[test]
    fn simple_min() {
        // min -x - 2y  s.t. x + y <= 4, x <= 2 => x=2, y=2, obj=-6.
        let mut lp = Lp::new(2);
        lp.minimize(vec![-1.0, -2.0]);
        lp.add_ub(vec![1.0, 1.0], 4.0);
        lp.add_ub(vec![1.0, 0.0], 2.0);
        // optimum: y=4? x+y<=4 so (0,4): obj=-8 < (2,2)=-6. x<=2 doesn't
        // bind for y. So x=0,y=4, obj=-8.
        assert_opt(&solve(&lp), &[0.0, 4.0], -8.0);
    }

    #[test]
    fn with_equality() {
        // min x + y  s.t. x + y = 3, x <= 1 => x=1? any split has obj 3.
        let mut lp = Lp::new(2);
        lp.minimize(vec![1.0, 1.0]);
        lp.add_eq(vec![1.0, 1.0], 3.0);
        match solve(&lp) {
            LpResult::Optimal(s) => {
                assert!((s.objective - 3.0).abs() < 1e-7);
                assert!((s.x[0] + s.x[1] - 3.0).abs() < 1e-7);
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn infeasible_detected() {
        // x <= 1, x >= 2.
        let mut lp = Lp::new(1);
        lp.minimize(vec![1.0]);
        lp.add_ub(vec![1.0], 1.0);
        lp.add_lb(vec![1.0], 2.0);
        assert_eq!(solve(&lp), LpResult::Infeasible);
    }

    #[test]
    fn unbounded_detected() {
        // min -x, no constraints.
        let mut lp = Lp::new(1);
        lp.minimize(vec![-1.0]);
        assert_eq!(solve(&lp), LpResult::Unbounded);
    }

    #[test]
    fn degenerate_ok() {
        // Redundant constraints shouldn't cycle.
        let mut lp = Lp::new(2);
        lp.minimize(vec![-1.0, -1.0]);
        lp.add_ub(vec![1.0, 0.0], 1.0);
        lp.add_ub(vec![1.0, 0.0], 1.0);
        lp.add_ub(vec![0.0, 1.0], 1.0);
        lp.add_ub(vec![1.0, 1.0], 2.0);
        assert_opt(&solve(&lp), &[1.0, 1.0], -2.0);
    }

    #[test]
    fn negative_rhs_ub() {
        // -x <= -2  (x >= 2), min x => x=2.
        let mut lp = Lp::new(1);
        lp.minimize(vec![1.0]);
        lp.add_ub(vec![-1.0], -2.0);
        assert_opt(&solve(&lp), &[2.0], 2.0);
    }

    #[test]
    fn lb_helper() {
        // min x + y s.t. x + 2y >= 4, y <= 1 => y=1, x=2, obj=3.
        let mut lp = Lp::new(2);
        lp.minimize(vec![1.0, 1.0]);
        lp.add_lb(vec![1.0, 2.0], 4.0);
        lp.add_ub(vec![0.0, 1.0], 1.0);
        assert_opt(&solve(&lp), &[2.0, 1.0], 3.0);
    }

    #[test]
    fn transport_like_problem() {
        // Classic 2x2 transport: supplies [3,2], demands [2,3],
        // costs [[1,4],[2,1]]. Optimal: x00=2, x01=1, x11=2 => 2+4+2=8.
        let mut lp = Lp::new(4); // x00 x01 x10 x11
        lp.minimize(vec![1.0, 4.0, 2.0, 1.0]);
        lp.add_eq(vec![1.0, 1.0, 0.0, 0.0], 3.0);
        lp.add_eq(vec![0.0, 0.0, 1.0, 1.0], 2.0);
        lp.add_eq(vec![1.0, 0.0, 1.0, 0.0], 2.0);
        lp.add_eq(vec![0.0, 1.0, 0.0, 1.0], 3.0);
        match solve(&lp) {
            LpResult::Optimal(s) => assert!((s.objective - 8.0).abs() < 1e-6),
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn fractional_lp_relaxation() {
        // min -x s.t. 2x <= 1 => x=0.5 (fractional, MILP will branch).
        let mut lp = Lp::new(1);
        lp.minimize(vec![-1.0]);
        lp.add_ub(vec![2.0], 1.0);
        assert_opt(&solve(&lp), &[0.5], -0.5);
    }
}
