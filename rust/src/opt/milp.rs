//! Branch & bound MILP on top of the simplex relaxation.
//!
//! §3.1.2: "In most systems, x_ij ∈ {0,1}" — assignments are integral in
//! practice. We branch on the most-fractional integer variable, prune by
//! incumbent bound, and solve each node's LP with [`super::lp`].

use super::lp::{solve, Lp, LpResult, LpSolution};

/// MILP = LP + a set of variables constrained to be integral (0/1 here;
/// general integrality is supported by the same branching).
#[derive(Debug, Clone)]
pub struct Milp {
    pub lp: Lp,
    /// Indices of variables required integral.
    pub integers: Vec<usize>,
}

#[derive(Debug, Clone, PartialEq)]
pub enum MilpResult {
    Optimal(LpSolution),
    Infeasible,
    Unbounded,
}

const INT_EPS: f64 = 1e-6;

/// Solve by best-incumbent DFS branch & bound.
pub fn solve_milp(p: &Milp) -> MilpResult {
    let mut best: Option<LpSolution> = None;
    let mut stack: Vec<Lp> = vec![p.lp.clone()];
    let mut nodes = 0usize;

    while let Some(node) = stack.pop() {
        nodes += 1;
        if nodes > 100_000 {
            break; // safety valve; problems here are tiny
        }
        let rel = match solve(&node) {
            LpResult::Optimal(s) => s,
            LpResult::Infeasible => continue,
            LpResult::Unbounded => return MilpResult::Unbounded,
        };
        // Prune by bound.
        if let Some(ref b) = best {
            if rel.objective >= b.objective - 1e-9 {
                continue;
            }
        }
        // Most-fractional branching variable.
        let frac = p
            .integers
            .iter()
            .map(|&i| (i, (rel.x[i] - rel.x[i].round()).abs()))
            .filter(|(_, f)| *f > INT_EPS)
            .max_by(|a, b| a.1.partial_cmp(&b.1).unwrap());

        match frac {
            None => {
                // Integral: new incumbent.
                if best
                    .as_ref()
                    .map(|b| rel.objective < b.objective - 1e-12)
                    .unwrap_or(true)
                {
                    best = Some(rel);
                }
            }
            Some((i, _)) => {
                let floor = rel.x[i].floor();
                // x_i <= floor branch.
                let mut lo = node.clone();
                let mut row = vec![0.0; lo.n];
                row[i] = 1.0;
                lo.add_ub(row.clone(), floor);
                // x_i >= floor + 1 branch.
                let mut hi = node;
                hi.add_lb(row, floor + 1.0);
                stack.push(lo);
                stack.push(hi);
            }
        }
    }

    match best {
        Some(s) => MilpResult::Optimal(s),
        None => MilpResult::Infeasible,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn knapsack_binary() {
        // max 10a + 6b + 4c s.t. a+b+c <= 2, binary.
        // (min negated) => pick a, b => -16.
        let mut lp = Lp::new(3);
        lp.minimize(vec![-10.0, -6.0, -4.0]);
        lp.add_ub(vec![1.0, 1.0, 1.0], 2.0);
        for i in 0..3 {
            let mut row = vec![0.0; 3];
            row[i] = 1.0;
            lp.add_ub(row, 1.0);
        }
        let r = solve_milp(&Milp {
            lp,
            integers: vec![0, 1, 2],
        });
        match r {
            MilpResult::Optimal(s) => {
                assert!((s.objective + 16.0).abs() < 1e-6);
                assert!((s.x[0] - 1.0).abs() < 1e-6);
                assert!((s.x[1] - 1.0).abs() < 1e-6);
                assert!(s.x[2].abs() < 1e-6);
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn lp_fractional_milp_integral() {
        // min -x s.t. 2x <= 3, x <= 1... LP gives x=1 (bounded by x<=1),
        // use 2x <= 1 => LP x=0.5, MILP x=0.
        let mut lp = Lp::new(1);
        lp.minimize(vec![-1.0]);
        lp.add_ub(vec![2.0], 1.0);
        let r = solve_milp(&Milp {
            lp,
            integers: vec![0],
        });
        match r {
            MilpResult::Optimal(s) => {
                assert!(s.x[0].abs() < 1e-6);
                assert!(s.objective.abs() < 1e-6);
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn infeasible_integrality() {
        // 0.4 <= x <= 0.6, x integer => infeasible.
        let mut lp = Lp::new(1);
        lp.minimize(vec![1.0]);
        lp.add_ub(vec![1.0], 0.6);
        lp.add_lb(vec![1.0], 0.4);
        assert_eq!(
            solve_milp(&Milp {
                lp,
                integers: vec![0]
            }),
            MilpResult::Infeasible
        );
    }

    #[test]
    fn assignment_one_hot() {
        // Two tasks, two devices; costs [[1, 3], [4, 1]];
        // each task exactly one device => diag assignment, cost 2.
        // Vars: x00 x01 x10 x11.
        let mut lp = Lp::new(4);
        lp.minimize(vec![1.0, 3.0, 4.0, 1.0]);
        lp.add_eq(vec![1.0, 1.0, 0.0, 0.0], 1.0);
        lp.add_eq(vec![0.0, 0.0, 1.0, 1.0], 1.0);
        let r = solve_milp(&Milp {
            lp,
            integers: vec![0, 1, 2, 3],
        });
        match r {
            MilpResult::Optimal(s) => {
                assert!((s.objective - 2.0).abs() < 1e-6);
                assert!((s.x[0] - 1.0).abs() < 1e-6);
                assert!((s.x[3] - 1.0).abs() < 1e-6);
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn respects_capacity_coupling() {
        // Both tasks prefer device 0 but its capacity fits only one.
        // costs [[1,2],[1,2]], capacity row: x00 + x10 <= 1.
        let mut lp = Lp::new(4);
        lp.minimize(vec![1.0, 2.0, 1.0, 2.0]);
        lp.add_eq(vec![1.0, 1.0, 0.0, 0.0], 1.0);
        lp.add_eq(vec![0.0, 0.0, 1.0, 1.0], 1.0);
        lp.add_ub(vec![1.0, 0.0, 1.0, 0.0], 1.0);
        let r = solve_milp(&Milp {
            lp,
            integers: vec![0, 1, 2, 3],
        });
        match r {
            MilpResult::Optimal(s) => {
                assert!((s.objective - 3.0).abs() < 1e-6);
                // Exactly one of the two tasks lands on device 0.
                assert!((s.x[0] + s.x[2] - 1.0).abs() < 1e-6);
            }
            other => panic!("{other:?}"),
        }
    }
}
