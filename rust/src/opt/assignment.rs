//! Task → hardware-class assignment (paper §3.1.2).
//!
//! Decision variables `x_ij` assign task `i` to hardware class `j`;
//! the objective minimizes Σ cost_ij·x_ij + γ·(transfer costs) + λ·Σ s_i
//! where `s_i` is per-task SLA slack. Two solvers:
//!
//! * [`solve_exact`] — branch-and-bound enumeration that models the
//!   *pair-dependent* edge transfer terms exactly (the `d_ij` of the
//!   worked example: KV transfer only exists when prefill and decode
//!   land on different classes). Exact for the graph sizes agents have.
//! * [`solve_relaxed`] — the paper's LP/MILP formulation via
//!   [`super::milp`], with per-task latency, soft SLA slack, and
//!   capacity coupling; cross-checked against the exact solver in tests.

use super::lp::Lp;
use super::milp::{solve_milp, Milp, MilpResult};
use crate::{Error, Result};

/// A hardware class available to the optimizer ("HP", "CO", "H100", ...).
#[derive(Debug, Clone)]
pub struct HardwareClass {
    pub name: String,
    /// Optional capacity per resource consumed by `TaskSpec::capacity_use`.
    pub capacity: f64,
}

/// One task (node) with profiled per-class latency and cost.
///
/// "In practice, these latency terms can be profiled from system traces,
/// benchmarks, or prior executions" (§3.1.1) — these vectors are that
/// profile.
#[derive(Debug, Clone)]
pub struct TaskSpec {
    pub name: String,
    /// t_ij, seconds, indexed by class.
    pub latency_s: Vec<f64>,
    /// Cost_ij, dollars, indexed by class.
    pub cost_usd: Vec<f64>,
    /// Capacity units consumed on the assigned class (0 = ignore).
    pub capacity_use: f64,
    /// Classes this task may not use (e.g. CPU-only tasks).
    pub forbidden: Vec<usize>,
}

/// A dependency edge with assignment-pair-dependent transfer terms.
#[derive(Debug, Clone)]
pub struct EdgeSpec {
    pub from: usize,
    pub to: usize,
    /// transfer_latency_s[j_from][j_to].
    pub latency_s: Vec<Vec<f64>>,
    /// transfer_cost_usd[j_from][j_to].
    pub cost_usd: Vec<Vec<f64>>,
}

impl EdgeSpec {
    /// An edge with zero transfer everywhere (pure dependency).
    pub fn free(from: usize, to: usize, n_classes: usize) -> EdgeSpec {
        EdgeSpec {
            from,
            to,
            latency_s: vec![vec![0.0; n_classes]; n_classes],
            cost_usd: vec![vec![0.0; n_classes]; n_classes],
        }
    }
}

/// SLA constraint shape.
#[derive(Debug, Clone, Copy)]
pub enum Sla {
    /// Hard end-to-end bound over the critical path, seconds.
    EndToEnd(f64),
    /// Soft end-to-end bound with penalty λ ($/second of violation).
    Soft { t_sla_s: f64, lambda: f64 },
    /// Unconstrained (pure cost minimization).
    None,
}

/// The full problem.
#[derive(Debug, Clone)]
pub struct AssignmentProblem {
    pub classes: Vec<HardwareClass>,
    pub tasks: Vec<TaskSpec>,
    pub edges: Vec<EdgeSpec>,
    pub sla: Sla,
}

/// A solved assignment.
#[derive(Debug, Clone, PartialEq)]
pub struct Assignment {
    /// choice[i] = class index for task i.
    pub choice: Vec<usize>,
    pub cost_usd: f64,
    /// Critical-path latency including transfers, seconds.
    pub latency_s: f64,
    /// SLA violation (soft mode), seconds.
    pub slack_s: f64,
}

impl AssignmentProblem {
    pub fn n_tasks(&self) -> usize {
        self.tasks.len()
    }

    pub fn n_classes(&self) -> usize {
        self.classes.len()
    }

    /// Evaluate a concrete assignment: (cost, critical-path latency).
    pub fn evaluate(&self, choice: &[usize]) -> (f64, f64) {
        let mut cost = 0.0;
        for (i, &j) in choice.iter().enumerate() {
            cost += self.tasks[i].cost_usd[j];
        }
        for e in &self.edges {
            cost += e.cost_usd[choice[e.from]][choice[e.to]];
        }
        (cost, self.critical_path(choice))
    }

    /// Longest path through the DAG with node latency t_ij and edge
    /// transfer latency; graphs with cycles must be unrolled upstream
    /// (§3.1: "bounded unrolling or check-pointing").
    pub fn critical_path(&self, choice: &[usize]) -> f64 {
        let n = self.tasks.len();
        let mut adj: Vec<Vec<(usize, f64)>> = vec![Vec::new(); n];
        let mut indeg = vec![0usize; n];
        for e in &self.edges {
            adj[e.from].push((e.to, e.latency_s[choice[e.from]][choice[e.to]]));
            indeg[e.to] += 1;
        }
        // Kahn topo order.
        let mut finish: Vec<f64> = (0..n)
            .map(|i| self.tasks[i].latency_s[choice[i]])
            .collect();
        let mut queue: Vec<usize> = (0..n).filter(|&i| indeg[i] == 0).collect();
        let mut seen = 0;
        while let Some(u) = queue.pop() {
            seen += 1;
            for &(v, tl) in &adj[u] {
                let cand = finish[u] + tl + self.tasks[v].latency_s[choice[v]];
                if cand > finish[v] {
                    finish[v] = cand;
                }
                indeg[v] -= 1;
                if indeg[v] == 0 {
                    queue.push(v);
                }
            }
        }
        assert_eq!(seen, n, "assignment graph has a cycle; unroll first");
        finish.iter().cloned().fold(0.0, f64::max)
    }

    /// Auto-select solver: exact branch & bound for small graphs,
    /// greedy + local-search heuristic beyond (the exact bound ignores
    /// edge terms, so worst-case blowup grows fast past ~10 tasks with
    /// many near-tied classes).
    pub fn solve_auto(&self) -> Result<Assignment> {
        if self.n_tasks() <= 10 {
            self.solve_exact()
        } else {
            self.solve_heuristic()
        }
    }

    /// Greedy cheapest-feasible assignment followed by single-task
    /// local-search improvement (first-improvement, to fixpoint or the
    /// iteration cap). Not optimal, but edge-aware and fast; quality is
    /// cross-checked against solve_exact on small graphs in tests.
    pub fn solve_heuristic(&self) -> Result<Assignment> {
        let n = self.n_tasks();
        if n == 0 {
            return Err(Error::Opt("empty problem".into()));
        }
        let (t_sla, lambda) = match self.sla {
            Sla::EndToEnd(t) => (t, f64::INFINITY),
            Sla::Soft { t_sla_s, lambda } => (t_sla_s, lambda),
            Sla::None => (f64::INFINITY, 0.0),
        };
        let objective = |choice: &[usize]| -> f64 {
            let (cost, lat) = self.evaluate(choice);
            let over = (lat - t_sla_s_or(t_sla)).max(0.0);
            if over > 0.0 && lambda.is_infinite() {
                f64::INFINITY
            } else {
                cost + lambda.min(1e12) * over
            }
        };
        fn t_sla_s_or(t: f64) -> f64 {
            t
        }

        // Start: per-task latency-weighted cheapest class (break SLA
        // ties toward faster classes).
        let mut choice: Vec<usize> = self
            .tasks
            .iter()
            .map(|t| {
                (0..self.classes.len())
                    .filter(|j| !t.forbidden.contains(j))
                    .min_by(|&a, &b| {
                        t.cost_usd[a].partial_cmp(&t.cost_usd[b]).unwrap()
                    })
                    .expect("task with all classes forbidden")
            })
            .collect();
        // If infeasible, greedily move the task with the best
        // latency-reduction-per-dollar to a faster class.
        for _ in 0..10 * n {
            if objective(&choice).is_finite() {
                break;
            }
            let mut best_move: Option<(usize, usize, f64)> = None;
            let (_, cur_lat) = self.evaluate(&choice);
            for i in 0..n {
                for j in 0..self.classes.len() {
                    if j == choice[i] || self.tasks[i].forbidden.contains(&j) {
                        continue;
                    }
                    let old = choice[i];
                    choice[i] = j;
                    let (cost, lat) = self.evaluate(&choice);
                    choice[i] = old;
                    if lat < cur_lat - 1e-12 {
                        let gain = (cur_lat - lat) / (cost + 1e-9);
                        if best_move.map(|(_, _, g)| gain > g).unwrap_or(true) {
                            best_move = Some((i, j, gain));
                        }
                    }
                }
            }
            match best_move {
                Some((i, j, _)) => choice[i] = j,
                None => break, // cannot reduce latency further
            }
        }
        if !objective(&choice).is_finite() {
            return Err(Error::Infeasible(
                "heuristic found no SLA-feasible assignment".into(),
            ));
        }
        // Local search: single-task reassignments, first-improvement.
        let mut improved = true;
        let mut iters = 0;
        while improved && iters < 100 {
            improved = false;
            iters += 1;
            let cur = objective(&choice);
            'outer: for i in 0..n {
                for j in 0..self.classes.len() {
                    if j == choice[i] || self.tasks[i].forbidden.contains(&j) {
                        continue;
                    }
                    let old = choice[i];
                    choice[i] = j;
                    if objective(&choice) < cur - 1e-15 {
                        improved = true;
                        break 'outer;
                    }
                    choice[i] = old;
                }
            }
        }
        let (cost, lat) = self.evaluate(&choice);
        Ok(Assignment {
            choice,
            cost_usd: cost,
            latency_s: lat,
            slack_s: (lat - t_sla).max(0.0).min(f64::MAX),
        })
    }

    /// Exact branch & bound over all assignments.
    pub fn solve_exact(&self) -> Result<Assignment> {
        let n = self.n_tasks();
        if n == 0 {
            return Err(Error::Opt("empty problem".into()));
        }
        // Lower bound on remaining cost: per-task min cost.
        let min_cost: Vec<f64> = self
            .tasks
            .iter()
            .map(|t| {
                t.cost_usd
                    .iter()
                    .enumerate()
                    .filter(|(j, _)| !t.forbidden.contains(j))
                    .map(|(_, c)| *c)
                    .fold(f64::INFINITY, f64::min)
            })
            .collect();
        let suffix_min: Vec<f64> = {
            let mut s = vec![0.0; n + 1];
            for i in (0..n).rev() {
                s[i] = s[i + 1] + min_cost[i];
            }
            s
        };

        // Branch ordering: visit each task's classes cheapest-first so
        // the first complete leaf is a strong incumbent and the
        // cost-lower-bound prune fires early (§Perf: ~3x on the
        // 64-task chain vs naive index order).
        let order: Vec<Vec<usize>> = self
            .tasks
            .iter()
            .map(|t| {
                let mut idx: Vec<usize> = (0..self.n_classes())
                    .filter(|j| !t.forbidden.contains(j))
                    .collect();
                idx.sort_by(|&a, &b| {
                    t.cost_usd[a].partial_cmp(&t.cost_usd[b]).unwrap()
                });
                idx
            })
            .collect();

        let mut best: Option<(f64, Assignment)> = None;
        let mut choice = vec![0usize; n];
        let mut prefix_cost = vec![0.0f64; n + 1];
        self.dfs(0, &mut choice, &suffix_min, &order, &mut prefix_cost, &mut best);
        best.map(|(_, a)| a).ok_or_else(|| {
            Error::Infeasible("no assignment satisfies the SLA".into())
        })
    }

    #[allow(clippy::too_many_arguments)]
    fn dfs(
        &self,
        i: usize,
        choice: &mut Vec<usize>,
        suffix_min: &[f64],
        order: &[Vec<usize>],
        prefix_cost: &mut Vec<f64>,
        best: &mut Option<(f64, Assignment)>,
    ) {
        let n = self.n_tasks();
        if i == n {
            let (cost, lat) = self.evaluate(choice);
            let (total, slack) = match self.sla {
                Sla::EndToEnd(t) => {
                    if lat > t + 1e-12 {
                        return; // infeasible leaf
                    }
                    (cost, 0.0)
                }
                Sla::Soft { t_sla_s, lambda } => {
                    let s = (lat - t_sla_s).max(0.0);
                    (cost + lambda * s, s)
                }
                Sla::None => (cost, 0.0),
            };
            if best.as_ref().map(|(b, _)| total < b - 1e-15).unwrap_or(true) {
                *best = Some((
                    total,
                    Assignment {
                        choice: choice.clone(),
                        cost_usd: cost,
                        latency_s: lat,
                        slack_s: slack,
                    },
                ));
            }
            return;
        }
        // Prune: partial cost + optimistic suffix >= incumbent objective.
        // (Edge costs and slack penalties are nonnegative, so task cost
        // alone is a valid lower bound; the prefix cost is maintained
        // incrementally instead of re-summed per node — §Perf.)
        if let Some((b, _)) = best {
            if prefix_cost[i] + suffix_min[i] >= *b - 1e-15 {
                return;
            }
        }
        for &j in &order[i] {
            choice[i] = j;
            prefix_cost[i + 1] = prefix_cost[i] + self.tasks[i].cost_usd[j];
            self.dfs(i + 1, choice, suffix_min, order, prefix_cost, best);
        }
    }

    /// The paper's MILP formulation (per-task latency + soft slack).
    ///
    /// Variables: `x_ij` (n·h, binary) then `s_i` (n, continuous).
    /// Edge transfer terms are approximated by their per-pair *minimum*
    /// (a valid lower bound; exact when transfers are
    /// assignment-independent). Use [`solve_exact`] when edges matter.
    pub fn solve_relaxed(&self) -> Result<Assignment> {
        let n = self.n_tasks();
        let h = self.n_classes();
        let nv = n * h + n;
        let xi = |i: usize, j: usize| i * h + j;
        let si = |i: usize| n * h + i;

        let mut lp = Lp::new(nv);
        let (t_sla, lambda) = match self.sla {
            Sla::EndToEnd(t) => (t, 1e9),
            Sla::Soft { t_sla_s, lambda } => (t_sla_s, lambda),
            Sla::None => (f64::INFINITY, 0.0),
        };

        let mut c = vec![0.0; nv];
        for i in 0..n {
            for j in 0..h {
                c[xi(i, j)] = self.tasks[i].cost_usd[j];
            }
            c[si(i)] = lambda;
        }
        lp.minimize(c);

        // Assignment: Σ_j x_ij = 1.
        for i in 0..n {
            let mut row = vec![0.0; nv];
            for j in 0..h {
                row[xi(i, j)] = 1.0;
            }
            lp.add_eq(row, 1.0);
        }
        // Forbidden classes: x_ij = 0.
        for (i, t) in self.tasks.iter().enumerate() {
            for &j in &t.forbidden {
                let mut row = vec![0.0; nv];
                row[xi(i, j)] = 1.0;
                lp.add_eq(row, 0.0);
            }
        }
        // Latency with slack: Σ over the chain of Σ_j x_ij·t_ij - Σ s_i <= T_SLA.
        // (End-to-end over all tasks: valid for chain graphs, which is
        // what the relaxed path handles; DAG fan-out uses solve_exact.)
        if t_sla.is_finite() {
            let mut row = vec![0.0; nv];
            for i in 0..n {
                for j in 0..h {
                    row[xi(i, j)] = self.tasks[i].latency_s[j];
                }
                row[si(i)] = -1.0;
            }
            // add minimal edge transfer latencies as constants -> move to rhs.
            let min_edge: f64 = self
                .edges
                .iter()
                .map(|e| {
                    e.latency_s
                        .iter()
                        .flatten()
                        .cloned()
                        .fold(f64::INFINITY, f64::min)
                })
                .sum();
            lp.add_ub(row, t_sla - min_edge);
        }
        // Capacity: Σ_i x_ij · use_i <= cap_j.
        for j in 0..h {
            if self.classes[j].capacity > 0.0 {
                let mut row = vec![0.0; nv];
                for i in 0..n {
                    row[xi(i, j)] = self.tasks[i].capacity_use;
                }
                lp.add_ub(row, self.classes[j].capacity);
            }
        }
        // x_ij <= 1 for integrality branching.
        for i in 0..n {
            for j in 0..h {
                let mut row = vec![0.0; nv];
                row[xi(i, j)] = 1.0;
                lp.add_ub(row, 1.0);
            }
        }

        let milp = Milp {
            lp,
            integers: (0..n * h).collect(),
        };
        match solve_milp(&milp) {
            MilpResult::Optimal(s) => {
                let choice: Vec<usize> = (0..n)
                    .map(|i| {
                        (0..h)
                            .max_by(|&a, &b| {
                                s.x[xi(i, a)].partial_cmp(&s.x[xi(i, b)]).unwrap()
                            })
                            .unwrap()
                    })
                    .collect();
                let (cost, lat) = self.evaluate(&choice);
                let slack = (0..n).map(|i| s.x[si(i)]).sum();
                Ok(Assignment {
                    choice,
                    cost_usd: cost,
                    latency_s: lat,
                    slack_s: slack,
                })
            }
            MilpResult::Infeasible => {
                Err(Error::Infeasible("MILP infeasible".into()))
            }
            MilpResult::Unbounded => Err(Error::Opt("MILP unbounded".into())),
        }
    }
}

impl Assignment {
    /// Human-readable "task -> class" listing.
    pub fn describe(&self, p: &AssignmentProblem) -> String {
        self.choice
            .iter()
            .enumerate()
            .map(|(i, &j)| {
                format!("{} -> {}", p.tasks[i].name, p.classes[j].name)
            })
            .collect::<Vec<_>>()
            .join(", ")
    }
}

/// The §3.1.2 worked example (Table 3): prefill→decode over HP/CO with
/// KV-transfer on the HP→CO path, T_SLA = 120 ms.
///
/// The per-token rates are those used in the paper's arithmetic
/// (the table column header drops a zero: Option A evaluates
/// 1000·0.00008 + 500·0.00006 = $0.11, fixing the rates below).
pub fn worked_example() -> AssignmentProblem {
    let classes = vec![
        HardwareClass {
            name: "HP".into(),
            capacity: 0.0,
        },
        HardwareClass {
            name: "CO".into(),
            capacity: 0.0,
        },
    ];
    let prefill_tokens = 1000.0;
    let decode_tokens = 500.0;
    let tasks = vec![
        TaskSpec {
            name: "prefill".into(),
            latency_s: vec![0.080, 0.130],
            cost_usd: vec![prefill_tokens * 0.00008, prefill_tokens * 0.00005],
            capacity_use: 0.0,
            forbidden: vec![],
        },
        TaskSpec {
            name: "decode".into(),
            latency_s: vec![0.025, 0.030],
            cost_usd: vec![decode_tokens * 0.00006, decode_tokens * 0.00002],
            capacity_use: 0.0,
            forbidden: vec![],
        },
    ];
    // KV transfer: only when prefill(HP) -> decode(CO) or vice versa.
    let t = 0.010;
    let c = prefill_tokens * 0.000005;
    let edges = vec![EdgeSpec {
        from: 0,
        to: 1,
        latency_s: vec![vec![0.0, t], vec![t, 0.0]],
        cost_usd: vec![vec![0.0, c], vec![c, 0.0]],
    }];
    AssignmentProblem {
        classes,
        tasks,
        edges,
        sla: Sla::EndToEnd(0.120),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn worked_example_selects_option_b() {
        // Paper: "the optimal assignment is x_prefill,HP = 1,
        // x_decode,CO = 1" at cost $0.095 within 120 ms.
        let p = worked_example();
        let a = p.solve_exact().unwrap();
        assert_eq!(a.choice, vec![0, 1], "{}", a.describe(&p));
        assert!((a.cost_usd - 0.095).abs() < 1e-9, "cost={}", a.cost_usd);
        assert!((a.latency_s - 0.120).abs() < 1e-9);
    }

    #[test]
    fn worked_example_option_values_match_paper() {
        let p = worked_example();
        // Option A: both HP.
        let (cost_a, lat_a) = p.evaluate(&[0, 0]);
        assert!((cost_a - 0.11).abs() < 1e-9);
        assert!((lat_a - 0.105).abs() < 1e-9);
        // Option B: prefill HP, decode CO.
        let (cost_b, lat_b) = p.evaluate(&[0, 1]);
        assert!((cost_b - 0.095).abs() < 1e-9);
        assert!((lat_b - 0.120).abs() < 1e-9);
        // Option C: both CO — SLA violated (160 ms).
        let (cost_c, lat_c) = p.evaluate(&[1, 1]);
        assert!((lat_c - 0.160).abs() < 1e-9);
        // Paper prints $0.07; the stated rates give $0.06 (its arithmetic
        // slip) — either way C is cheapest-but-infeasible.
        assert!(cost_c < cost_b);
    }

    #[test]
    fn without_sla_cheapest_wins() {
        let mut p = worked_example();
        p.sla = Sla::None;
        let a = p.solve_exact().unwrap();
        assert_eq!(a.choice, vec![1, 1]); // Option C
    }

    #[test]
    fn tight_sla_forces_all_hp() {
        let mut p = worked_example();
        p.sla = Sla::EndToEnd(0.110);
        let a = p.solve_exact().unwrap();
        assert_eq!(a.choice, vec![0, 0]); // Option A (105 ms)
    }

    #[test]
    fn impossible_sla_is_infeasible() {
        let mut p = worked_example();
        p.sla = Sla::EndToEnd(0.050);
        assert!(p.solve_exact().is_err());
    }

    #[test]
    fn soft_sla_trades_violation_for_cost() {
        let mut p = worked_example();
        // λ tiny: violation is cheap, pick Option C and eat the slack.
        p.sla = Sla::Soft {
            t_sla_s: 0.120,
            lambda: 0.01,
        };
        let a = p.solve_exact().unwrap();
        assert_eq!(a.choice, vec![1, 1]);
        assert!((a.slack_s - 0.040).abs() < 1e-9);
        // λ huge: acts like the hard constraint.
        p.sla = Sla::Soft {
            t_sla_s: 0.120,
            lambda: 1e6,
        };
        let a = p.solve_exact().unwrap();
        assert_eq!(a.choice, vec![0, 1]);
    }

    #[test]
    fn relaxed_milp_agrees_on_chain_without_edges() {
        // Drop the transfer edge; relaxed and exact must agree.
        let mut p = worked_example();
        p.edges.clear();
        let e = p.solve_exact().unwrap();
        let r = p.solve_relaxed().unwrap();
        assert_eq!(e.choice, r.choice);
        assert!((e.cost_usd - r.cost_usd).abs() < 1e-9);
    }

    #[test]
    fn forbidden_classes_respected() {
        let mut p = worked_example();
        p.tasks[1].forbidden = vec![1]; // decode may not use CO
        let a = p.solve_exact().unwrap();
        assert_eq!(a.choice[1], 0);
    }

    #[test]
    fn heuristic_matches_exact_on_worked_example() {
        let p = worked_example();
        let e = p.solve_exact().unwrap();
        let h = p.solve_heuristic().unwrap();
        assert_eq!(h.choice, e.choice, "heuristic {h:?} vs exact {e:?}");
        assert!((h.cost_usd - e.cost_usd).abs() < 1e-12);
    }

    #[test]
    fn heuristic_near_exact_on_random_chains() {
        use crate::util::rng::Rng;
        let mut rng = Rng::new(77);
        for case in 0..30 {
            let n = rng.index(6) + 2;
            let h = rng.index(3) + 2;
            let tasks: Vec<TaskSpec> = (0..n)
                .map(|i| TaskSpec {
                    name: format!("t{i}"),
                    latency_s: (0..h).map(|_| 0.01 + rng.f64() * 0.2).collect(),
                    cost_usd: (0..h).map(|_| rng.f64()).collect(),
                    capacity_use: 0.0,
                    forbidden: vec![],
                })
                .collect();
            let edges = (1..n).map(|i| EdgeSpec::free(i - 1, i, h)).collect();
            let classes = (0..h)
                .map(|j| HardwareClass {
                    name: format!("C{j}"),
                    capacity: 0.0,
                })
                .collect();
            let p = AssignmentProblem {
                classes,
                tasks,
                edges,
                sla: Sla::None,
            };
            let e = p.solve_exact().unwrap();
            let heur = p.solve_heuristic().unwrap();
            assert!(
                heur.cost_usd <= e.cost_usd * 1.2 + 1e-9,
                "case {case}: heuristic {} vs exact {}",
                heur.cost_usd,
                e.cost_usd
            );
        }
    }

    #[test]
    fn solve_auto_dispatches_by_size() {
        // <=10 tasks: exact; the worked example qualifies.
        let p = worked_example();
        let a = p.solve_auto().unwrap();
        assert_eq!(a.choice, vec![0, 1]);
    }

    #[test]
    fn heuristic_respects_hard_sla() {
        let mut p = worked_example();
        p.sla = Sla::EndToEnd(0.110);
        let h = p.solve_heuristic().unwrap();
        assert!(h.latency_s <= 0.110 + 1e-12);
        p.sla = Sla::EndToEnd(0.050);
        assert!(p.solve_heuristic().is_err());
    }

    #[test]
    fn critical_path_takes_longest_branch() {
        // Diamond: a -> {b, c} -> d; b slow, c fast.
        let classes = vec![HardwareClass {
            name: "X".into(),
            capacity: 0.0,
        }];
        let t = |name: &str, lat: f64| TaskSpec {
            name: name.into(),
            latency_s: vec![lat],
            cost_usd: vec![1.0],
            capacity_use: 0.0,
            forbidden: vec![],
        };
        let p = AssignmentProblem {
            classes,
            tasks: vec![t("a", 1.0), t("b", 5.0), t("c", 1.0), t("d", 1.0)],
            edges: vec![
                EdgeSpec::free(0, 1, 1),
                EdgeSpec::free(0, 2, 1),
                EdgeSpec::free(1, 3, 1),
                EdgeSpec::free(2, 3, 1),
            ],
            sla: Sla::None,
        };
        assert_eq!(p.critical_path(&[0, 0, 0, 0]), 7.0);
    }

    #[test]
    #[should_panic(expected = "cycle")]
    fn cyclic_graph_panics_in_critical_path() {
        let classes = vec![HardwareClass {
            name: "X".into(),
            capacity: 0.0,
        }];
        let t = TaskSpec {
            name: "a".into(),
            latency_s: vec![1.0],
            cost_usd: vec![1.0],
            capacity_use: 0.0,
            forbidden: vec![],
        };
        let p = AssignmentProblem {
            classes,
            tasks: vec![t.clone(), t],
            edges: vec![EdgeSpec::free(0, 1, 1), EdgeSpec::free(1, 0, 1)],
            sla: Sla::None,
        };
        p.critical_path(&[0, 0]);
    }
}
