//! Disaggregated-serving configuration explorer (paper §5, Figures 8–9).
//!
//! For a device pair `prefill_dev :: decode_dev` ("the left and right
//! operands correspond to the hardware configurations used during the
//! prefill and decode stages"), explore tensor-parallel × pipeline-
//! parallel × batch-size layouts per stage, validate the SLA and
//! KV-transfer feasibility (Eqs. 1–2), and return the configuration with
//! the best tokens/s/$. Normalizing every pair against H100::H100
//! regenerates the Figure 8/9 bars.

use crate::cost::hardware::DeviceSpec;
use crate::cost::model_profile::ModelProfile;
use crate::cost::network;
use crate::cost::roofline::{
    decode_step_time, max_batch, prefill_time, Efficiency, Parallelism,
};
use crate::cost::tco::{opex_usd_per_hour, FinanceTerms, OpexModel};

/// SLA regime (paper §5): interactive latency vs offline throughput.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum SlaMode {
    /// TTFT ≤ 250 ms, TBT ≤ 20 ms.
    Latency { ttft_s: f64, tbt_s: f64 },
    /// Maximize tokens/s/$ (no latency bound).
    Throughput,
}

impl SlaMode {
    pub fn paper_latency() -> SlaMode {
        SlaMode::Latency {
            ttft_s: 0.250,
            tbt_s: 0.020,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            SlaMode::Latency { .. } => "Latency SLA",
            SlaMode::Throughput => "Throughput SLA",
        }
    }
}

/// The workload shape for one Figure (ISL, OSL).
#[derive(Debug, Clone, Copy)]
pub struct SeqShape {
    pub isl: u64,
    pub osl: u64,
}

impl SeqShape {
    /// Figure 8: reasoning-style, decode-heavy.
    pub fn fig8() -> SeqShape {
        SeqShape {
            isl: 512,
            osl: 4096,
        }
    }

    /// Figure 9: summarization-style, prefill-heavy.
    pub fn fig9() -> SeqShape {
        SeqShape {
            isl: 4096,
            osl: 512,
        }
    }
}

/// One stage of an evaluated configuration.
#[derive(Debug, Clone)]
pub struct StagePlan {
    pub device: String,
    pub par: Parallelism,
    pub batch: u64,
    /// Stage step time: full prefill (TTFT) or one decode step (TBT), s.
    pub step_s: f64,
    /// Device-seconds consumed per request on this stage.
    pub device_s_per_req: f64,
    pub bound: &'static str,
}

/// A fully evaluated prefill::decode configuration.
#[derive(Debug, Clone)]
pub struct EvaluatedConfig {
    pub model: String,
    pub prefill: StagePlan,
    pub decode: StagePlan,
    pub ttft_s: f64,
    pub tbt_s: f64,
    /// KV transfer time per request over the scale-out fabric, s.
    pub kv_transfer_s: f64,
    /// Output tokens per dollar (the §5 objective "tokens/s/$").
    pub tokens_per_usd: f64,
    /// $ per 1M output tokens.
    pub usd_per_mtok: f64,
}

/// Explorer options.
#[derive(Debug, Clone)]
pub struct ExploreOpts {
    pub eff: Efficiency,
    pub opex: OpexModel,
    pub terms: FinanceTerms,
    /// TP degrees to consider (bounded by the scale-up domain).
    pub tp_candidates: Vec<u32>,
    pub pp_candidates: Vec<u32>,
    /// Batch sizes to consider per stage.
    pub batch_candidates: Vec<u64>,
}

impl Default for ExploreOpts {
    /// Defaults use [`OpexModel::Derived`] (the paper's *stated* cost
    /// formula): under it the reproduction recovers the paper's headline
    /// ordering — B200::Gaudi3 best overall (esp. FP8), H100::Gaudi3 ≳
    /// B200::B200. The listed Table-5 rates (`PaperTable`) make B200's
    /// $/hr so low that B200::B200 wins everything; see EXPERIMENTS.md.
    fn default() -> Self {
        ExploreOpts {
            eff: Efficiency::default(),
            opex: OpexModel::Derived,
            terms: FinanceTerms::default(),
            tp_candidates: vec![1, 2, 4, 8],
            pp_candidates: vec![1, 2, 4],
            batch_candidates: vec![1, 2, 4, 8, 16, 32, 64, 128, 256],
        }
    }
}

/// Find the best configuration for `prefill_dev :: decode_dev` on model
/// `m` under `sla`; `None` when no layout fits memory + SLA.
pub fn best_config(
    m: &ModelProfile,
    prefill_dev: &DeviceSpec,
    decode_dev: &DeviceSpec,
    shape: SeqShape,
    sla: SlaMode,
    opts: &ExploreOpts,
) -> Option<EvaluatedConfig> {
    let mut best: Option<EvaluatedConfig> = None;
    let p_opex = opex_usd_per_hour(prefill_dev, opts.opex, &opts.terms);
    let d_opex = opex_usd_per_hour(decode_dev, opts.opex, &opts.terms);

    // Average decode context: ISL plus half the generated tokens.
    let avg_ctx = shape.isl + shape.osl / 2;
    let max_ctx = shape.isl + shape.osl;

    for &tp_p in &opts.tp_candidates {
        if tp_p > prefill_dev.scaleup_size {
            continue;
        }
        for &pp_p in &opts.pp_candidates {
            let par_p = Parallelism { tp: tp_p, pp: pp_p };
            let max_bp = max_batch(m, prefill_dev, par_p, shape.isl, &opts.eff);
            if max_bp == 0 {
                continue;
            }
            for &bp in &opts.batch_candidates {
                if bp > max_bp {
                    break;
                }
                let tp_time = prefill_time(m, prefill_dev, par_p, shape.isl, bp, &opts.eff);
                let ttft = tp_time.total();

                for &tp_d in &opts.tp_candidates {
                    if tp_d > decode_dev.scaleup_size {
                        continue;
                    }
                    for &pp_d in &opts.pp_candidates {
                        let par_d = Parallelism { tp: tp_d, pp: pp_d };
                        // KV budget at the *maximum* context (worst case).
                        let max_bd = max_batch(m, decode_dev, par_d, max_ctx, &opts.eff);
                        if max_bd == 0 {
                            continue;
                        }
                        for &bd in &opts.batch_candidates {
                            if bd > max_bd {
                                break;
                            }
                            let td = decode_step_time(
                                m, decode_dev, par_d, avg_ctx, bd, &opts.eff,
                            );
                            let tbt = td.total();

                            // KV transfer (prefill -> decode) over the
                            // slower of the two scale-out NICs.
                            let kv_bytes =
                                crate::cost::kv::kv_cache_bytes(m, shape.isl, 1);
                            let link_gbit = prefill_dev
                                .scaleout_bw_gbps
                                .min(decode_dev.scaleout_bw_gbps)
                                * 8.0
                                * opts.eff.net_util;
                            let kv_s = if prefill_dev.name == decode_dev.name {
                                // Same class: planner may collocate; still
                                // disaggregated but over scale-up domain.
                                network::transfer_time_s(
                                    kv_bytes,
                                    prefill_dev.scaleup_bw_gbps * 8.0 * opts.eff.net_util,
                                )
                            } else {
                                network::transfer_time_s(kv_bytes, link_gbit)
                            };

                            // Non-blocking pipelining (Eqs 1–2): transfer
                            // must be overlappable within a decode round.
                            let overlapped = kv_s <= tbt * bd as f64;

                            if let SlaMode::Latency { ttft_s, tbt_s } = sla {
                                // KV transfer hits the *second token*
                                // (§5.2), so TBT budget must absorb it
                                // amortized; TTFT gets prefill only.
                                if ttft > ttft_s || tbt > tbt_s || !overlapped {
                                    continue;
                                }
                            } else if !overlapped {
                                continue;
                            }

                            // Device-seconds per request.
                            let p_devs = par_p.devices() as f64;
                            let d_devs = par_d.devices() as f64;
                            let p_dev_s = ttft * p_devs / bp as f64;
                            let d_dev_s = tbt * shape.osl as f64 * d_devs / bd as f64;
                            let usd_per_req = p_dev_s * p_opex / 3600.0
                                + d_dev_s * d_opex / 3600.0;
                            let tokens_per_usd = shape.osl as f64 / usd_per_req;
                            let usd_per_mtok = 1e6 / tokens_per_usd;

                            let cand = EvaluatedConfig {
                                model: m.name.to_string(),
                                prefill: StagePlan {
                                    device: prefill_dev.name.to_string(),
                                    par: par_p,
                                    batch: bp,
                                    step_s: ttft,
                                    device_s_per_req: p_dev_s,
                                    bound: tp_time.bound(),
                                },
                                decode: StagePlan {
                                    device: decode_dev.name.to_string(),
                                    par: par_d,
                                    batch: bd,
                                    step_s: tbt,
                                    device_s_per_req: d_dev_s,
                                    bound: td.bound(),
                                },
                                ttft_s: ttft,
                                tbt_s: tbt,
                                kv_transfer_s: kv_s,
                                tokens_per_usd,
                                usd_per_mtok,
                            };
                            if best
                                .as_ref()
                                .map(|b| cand.tokens_per_usd > b.tokens_per_usd)
                                .unwrap_or(true)
                            {
                                best = Some(cand);
                            }
                        }
                    }
                }
            }
        }
    }
    best
}

/// Baseline ablation: *monolithic* (non-disaggregated) serving — prefill
/// and decode share one device pool and one batch, as in single-node
/// vLLM-style serving. The same roofline prices both phases; there is no
/// KV transfer, but the pool must meet both phases' SLAs and the
/// compute-heavy prefill steals time from decode (modeled as additive
/// round time at matched request rates: each request costs one prefill
/// plus `osl` decode steps on the same devices).
///
/// The paper's framework "integrat[es] both disaggregated and monolithic
/// serving strategies as specific instances within a unified
/// optimization formulation" (§6.2) — this is the monolithic instance.
pub fn best_monolithic_config(
    m: &ModelProfile,
    dev: &DeviceSpec,
    shape: SeqShape,
    sla: SlaMode,
    opts: &ExploreOpts,
) -> Option<EvaluatedConfig> {
    let opex = opex_usd_per_hour(dev, opts.opex, &opts.terms);
    let avg_ctx = shape.isl + shape.osl / 2;
    let max_ctx = shape.isl + shape.osl;
    let mut best: Option<EvaluatedConfig> = None;

    for &tp in &opts.tp_candidates {
        if tp > dev.scaleup_size {
            continue;
        }
        for &pp in &opts.pp_candidates {
            let par = Parallelism { tp, pp };
            let max_b = max_batch(m, dev, par, max_ctx, &opts.eff);
            if max_b == 0 {
                continue;
            }
            for &b in &opts.batch_candidates {
                if b > max_b {
                    break;
                }
                let tp_time = prefill_time(m, dev, par, shape.isl, b, &opts.eff);
                let ttft = tp_time.total();
                let td = decode_step_time(m, dev, par, avg_ctx, b, &opts.eff);
                // Prefill interleaves with decode on the same pool: the
                // effective TBT absorbs the amortized prefill stall.
                let tbt = td.total() + ttft / shape.osl as f64;
                if let SlaMode::Latency { ttft_s, tbt_s } = sla {
                    if ttft > ttft_s || tbt > tbt_s {
                        continue;
                    }
                }
                let devices = par.devices() as f64;
                let dev_s_per_req =
                    (ttft + td.total() * shape.osl as f64) * devices / b as f64;
                let usd_per_req = dev_s_per_req * opex / 3600.0;
                let tokens_per_usd = shape.osl as f64 / usd_per_req;
                let cand = EvaluatedConfig {
                    model: m.name.to_string(),
                    prefill: StagePlan {
                        device: dev.name.to_string(),
                        par,
                        batch: b,
                        step_s: ttft,
                        device_s_per_req: dev_s_per_req,
                        bound: tp_time.bound(),
                    },
                    decode: StagePlan {
                        device: dev.name.to_string(),
                        par,
                        batch: b,
                        step_s: tbt,
                        device_s_per_req: dev_s_per_req,
                        bound: td.bound(),
                    },
                    ttft_s: ttft,
                    tbt_s: tbt,
                    kv_transfer_s: 0.0,
                    tokens_per_usd,
                    usd_per_mtok: 1e6 / tokens_per_usd,
                };
                if best
                    .as_ref()
                    .map(|x| cand.tokens_per_usd > x.tokens_per_usd)
                    .unwrap_or(true)
                {
                    best = Some(cand);
                }
            }
        }
    }
    best
}

/// One Figure 8/9 bar: TCO benefit of `pair` relative to the baseline.
#[derive(Debug, Clone)]
pub struct TcoBar {
    pub pair: String,
    pub model: String,
    pub sla: &'static str,
    /// baseline $/tok ÷ config $/tok (≥ 1.0 means cheaper than H100::H100).
    pub tco_benefit: f64,
    pub config: EvaluatedConfig,
}

/// The device pairs evaluated in Figures 8–9 (prefill :: decode).
pub fn paper_pairs() -> Vec<(&'static str, &'static str)> {
    vec![
        ("B200", "B200"),
        ("B200", "Gaudi3"),
        ("H100", "Gaudi3"),
        ("Gaudi3", "Gaudi3"),
        ("H100", "A100"),
        ("A100", "A40"),
    ]
}

/// Compute the Figure 8/9 series: every pair × model × SLA mode,
/// normalized to H100::H100.
pub fn tco_series(
    models: &[ModelProfile],
    pairs: &[(&str, &str)],
    shape: SeqShape,
    opts: &ExploreOpts,
) -> Vec<TcoBar> {
    use crate::cost::hardware::by_name;
    let mut out = Vec::new();
    for m in models {
        for sla in [SlaMode::paper_latency(), SlaMode::Throughput] {
            let h100 = by_name("H100").unwrap();
            let Some(base) = best_config(m, &h100, &h100, shape, sla, opts) else {
                continue;
            };
            for (p, d) in pairs {
                let (Some(pd), Some(dd)) = (by_name(p), by_name(d)) else {
                    continue;
                };
                let Some(cfg) = best_config(m, &pd, &dd, shape, sla, opts) else {
                    continue;
                };
                out.push(TcoBar {
                    pair: format!("{p}::{d}"),
                    model: m.name.to_string(),
                    sla: sla.name(),
                    tco_benefit: base.usd_per_mtok / cfg.usd_per_mtok,
                    config: cfg,
                });
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cost::hardware::by_name;
    use crate::cost::model_profile::{llama3_70b, llama3_8b, table4};
    use crate::cost::Precision;

    fn opts() -> ExploreOpts {
        ExploreOpts::default()
    }

    #[test]
    fn h100_8b_meets_latency_sla() {
        let m = llama3_8b(Precision::Fp16);
        let h = by_name("H100").unwrap();
        let cfg = best_config(
            &m,
            &h,
            &h,
            SeqShape::fig8(),
            SlaMode::paper_latency(),
            &opts(),
        )
        .expect("feasible");
        assert!(cfg.ttft_s <= 0.250);
        assert!(cfg.tbt_s <= 0.020);
        assert!(cfg.tokens_per_usd > 0.0);
    }

    #[test]
    fn throughput_mode_at_least_as_cheap_as_latency_mode() {
        let m = llama3_8b(Precision::Fp16);
        let h = by_name("H100").unwrap();
        let lat = best_config(&m, &h, &h, SeqShape::fig8(), SlaMode::paper_latency(), &opts())
            .unwrap();
        let thr =
            best_config(&m, &h, &h, SeqShape::fig8(), SlaMode::Throughput, &opts()).unwrap();
        assert!(thr.tokens_per_usd >= lat.tokens_per_usd * 0.999);
    }

    #[test]
    fn a40_cannot_serve_70b_fp16_in_one_chassis() {
        // 140 GB weights over ≤8 × 48 GB with ~700 GB/s HBM: within a
        // single scale-up domain (pp=1) the 20 ms TBT is unattainable —
        // serving 70B on A40s interactively requires pipelining across
        // chassis (the explorer finds pp>=2 configs).
        let m = llama3_70b(Precision::Fp16);
        let a40 = by_name("A40").unwrap();
        let mut o = opts();
        o.pp_candidates = vec![1];
        let cfg = best_config(
            &m,
            &a40,
            &a40,
            SeqShape::fig8(),
            SlaMode::paper_latency(),
            &o,
        );
        assert!(cfg.is_none(), "A40 pp=1 shouldn't meet 20ms TBT on 70B FP16");
        // With pipelining allowed it becomes feasible but needs a big fleet.
        if let Some(cfg) = best_config(
            &m,
            &a40,
            &a40,
            SeqShape::fig8(),
            SlaMode::paper_latency(),
            &opts(),
        ) {
            assert!(cfg.decode.par.devices() >= 16, "{:?}", cfg.decode.par);
        }
    }

    #[test]
    fn prefill_compute_bound_decode_memory_bound() {
        let m = llama3_70b(Precision::Fp8);
        let h = by_name("H100").unwrap();
        let g = by_name("Gaudi3").unwrap();
        let cfg = best_config(&m, &h, &g, SeqShape::fig9(), SlaMode::Throughput, &opts())
            .unwrap();
        assert_eq!(cfg.prefill.bound, "compute");
        assert_eq!(cfg.decode.bound, "memory");
    }

    #[test]
    fn fig8_headline_b200_gaudi3_beats_baseline() {
        // Paper: "B200::Gaudi 3 has the best overall TCO benefit,
        // especially for FP8".
        let models = [llama3_8b(Precision::Fp8)];
        let bars = tco_series(
            &models,
            &[("B200", "Gaudi3")],
            SeqShape::fig8(),
            &opts(),
        );
        for b in &bars {
            assert!(
                b.tco_benefit > 1.0,
                "{} {} benefit {}",
                b.pair,
                b.sla,
                b.tco_benefit
            );
        }
    }

    #[test]
    fn fig8_h100_gaudi3_comparable_to_b200_b200() {
        // Paper: "H100::Gaudi 3 configuration is often comparable or
        // slightly better than a B200::B200 configuration".
        let m = llama3_70b(Precision::Fp16);
        let bars = tco_series(
            std::slice::from_ref(&m),
            &[("H100", "Gaudi3"), ("B200", "B200")],
            SeqShape::fig8(),
            &opts(),
        );
        let get = |pair: &str, sla: &str| {
            bars.iter()
                .find(|b| b.pair == pair && b.sla == sla)
                .map(|b| b.tco_benefit)
        };
        let hg = get("H100::Gaudi3", "Throughput SLA").unwrap();
        let bb = get("B200::B200", "Throughput SLA").unwrap();
        assert!(
            hg > bb * 0.8,
            "H100::Gaudi3 ({hg:.2}) should be comparable to B200::B200 ({bb:.2})"
        );
    }

    #[test]
    fn full_series_has_all_slas_for_8b() {
        let models = [llama3_8b(Precision::Fp16)];
        let bars = tco_series(&models, &paper_pairs(), SeqShape::fig8(), &opts());
        assert!(bars.iter().any(|b| b.sla == "Latency SLA"));
        assert!(bars.iter().any(|b| b.sla == "Throughput SLA"));
    }

    #[test]
    fn table4_models_all_evaluable_on_big_pairs() {
        for m in table4() {
            let b200 = by_name("B200").unwrap();
            let g3 = by_name("Gaudi3").unwrap();
            assert!(
                best_config(&m, &b200, &g3, SeqShape::fig8(), SlaMode::Throughput, &opts())
                    .is_some(),
                "{} must be servable on B200::Gaudi3",
                m.name
            );
        }
    }

    #[test]
    fn disaggregation_beats_monolithic_under_latency_sla() {
        // The paper's core §2.4.2 argument: staged prefill/decode with
        // overlapped execution wins against a single pool that must
        // interleave both phases, once the interactive SLA binds.
        let m = llama3_8b(Precision::Fp16);
        let h = by_name("H100").unwrap();
        let mono = best_monolithic_config(
            &m,
            &h,
            SeqShape::fig8(),
            SlaMode::paper_latency(),
            &opts(),
        );
        let disagg = best_config(
            &m,
            &h,
            &h,
            SeqShape::fig8(),
            SlaMode::paper_latency(),
            &opts(),
        )
        .unwrap();
        match mono {
            None => {} // monolithic can't even meet the SLA: stronger win
            Some(mono) => {
                assert!(
                    disagg.tokens_per_usd >= mono.tokens_per_usd,
                    "disagg {} < mono {}",
                    disagg.tokens_per_usd,
                    mono.tokens_per_usd
                );
            }
        }
    }

    #[test]
    fn monolithic_has_no_kv_transfer() {
        let m = llama3_8b(Precision::Fp8);
        let h = by_name("B200").unwrap();
        let mono =
            best_monolithic_config(&m, &h, SeqShape::fig8(), SlaMode::Throughput, &opts())
                .unwrap();
        assert_eq!(mono.kv_transfer_s, 0.0);
        assert_eq!(mono.prefill.device, mono.decode.device);
    }

    #[test]
    fn kv_transfer_overlappable_claim() {
        // §5.2: provisioned bandwidth suffices for non-blocking pipelining.
        let m = llama3_70b(Precision::Fp16);
        let h = by_name("H100").unwrap();
        let g = by_name("Gaudi3").unwrap();
        let cfg = best_config(&m, &h, &g, SeqShape::fig9(), SlaMode::Throughput, &opts())
            .unwrap();
        assert!(cfg.kv_transfer_s <= cfg.tbt_s * cfg.decode.batch as f64);
    }
}
