//! Heterogeneous cluster discrete-event simulator (paper §5's
//! "simulated a continuous workload scenario").
//!
//! The simulator executes a request trace against a *placement* (which
//! device pipelines serve prefill and decode, at which parallelism and
//! batch limits), moving KV caches over the [`crate::transport`] fabric
//! and timing stages with the [`crate::cost::roofline`] model. It
//! reports the paper's serving metrics — TTFT, TBT, end-to-end latency,
//! throughput, utilization, and $/Mtok — so planner decisions can be
//! validated end-to-end rather than just analytically.
//!
//! * [`sim`] — the flat event loop, pipelines, continuous decode
//!   batching, and [`sim::simulate_plan`] — the
//!   [`ExecutionPlan`](crate::plan::ExecutionPlan)-native entry point;
//! * [`dag`] — full agent-DAG execution per request (CPU stages, tool
//!   calls, multiple LLM inferences, per-edge fabric transfers);
//! * [`trace`] — workload generators (Poisson arrivals, lognormal
//!   sequence lengths, the Figure-2 voice-agent stage structure).

pub mod dag;
pub mod sim;
pub mod trace;

pub use dag::{DagDetail, DagSim, FleetChangeStats, FleetController, GroupWindow, WindowStats};
pub use sim::{simulate_plan, ClusterSim, Placement, PipelineSpec, SimReport};
pub use trace::{bursty, Request, TraceConfig};
