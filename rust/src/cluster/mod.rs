//! Heterogeneous cluster discrete-event simulator (paper §5's
//! "simulated a continuous workload scenario").
//!
//! The simulator executes a request trace against a *placement* (which
//! device pipelines serve prefill and decode, at which parallelism and
//! batch limits), moving KV caches over the [`crate::transport`] fabric
//! and timing stages with the [`crate::cost::roofline`] model. It
//! reports the paper's serving metrics — TTFT, TBT, end-to-end latency,
//! throughput, utilization, and $/Mtok — so planner decisions can be
//! validated end-to-end rather than just analytically.
//!
//! * [`sim`] — the flat event loop, pipelines, continuous decode
//!   batching, and [`sim::simulate_plan`] — the
//!   [`ExecutionPlan`](crate::plan::ExecutionPlan)-native entry point;
//! * [`dag`] — full agent-DAG execution per request (CPU stages, tool
//!   calls, multiple LLM inferences, per-edge fabric transfers);
//! * [`arrivals`] — pull-based streaming arrival processes (the
//!   [`arrivals::ArrivalProcess`] trait: Poisson, diurnal, flash-crowd,
//!   square-wave, voice-agent, and slice replay) — constant-memory
//!   ingestion for million-request days;
//! * [`eventq`] — the calendar-queue event scheduler behind
//!   [`dag::DagSim`]'s hot loop;
//! * [`trace`] — materialized workload generators (Poisson arrivals,
//!   lognormal sequence lengths, the Figure-2 voice-agent stage
//!   structure); kept as the slice-API anchors the streaming processes
//!   reproduce bit-for-bit.

pub mod arrivals;
pub mod dag;
pub mod eventq;
pub mod sim;
pub mod trace;

pub use arrivals::{
    ArrivalProcess, Diurnal, FlashCrowd, Poisson, Replay, Spike, SquareWave, VoiceAgent,
};
pub use dag::{DagDetail, DagSim, FleetChangeStats, FleetController, GroupWindow, WindowStats};
pub use eventq::EventQueue;
pub use sim::{simulate_plan, simulate_stream, ClusterSim, Placement, PipelineSpec, SimReport};
pub use trace::{bursty, Request, TraceConfig};
