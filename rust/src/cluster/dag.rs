//! Agent-DAG discrete-event simulation: execute an
//! [`ExecutionPlan`](crate::plan::ExecutionPlan) — not just a flat LLM
//! request stream — against the planned fleet.
//!
//! Where [`super::sim::ClusterSim`] models the classic disaggregated
//! prefill → decode pipeline for one LLM per request, [`DagSim`] walks
//! the *whole bound agent graph* per request, as MARS-style agent
//! co-scheduling does and as the CPU-centric agentic-execution study
//! argues is necessary (non-LLM stages dominate once they are
//! first-class):
//!
//! * **CPU stages** (STT/TTS, tool calls, memory/IO/control ops) run on
//!   a bounded worker pool at the planner-profiled latency, queueing
//!   FIFO when the pool saturates;
//! * **LLM prefill/decode stages** run on the plan's pipelines with the
//!   same roofline timing, bucketed prefill batching, and
//!   continuous-batching decode rounds as the flat simulator — a
//!   request may contain *several* LLM inferences (supervisor patterns,
//!   MoE experts) and each is scheduled independently;
//! * **edges** between stages on different chassis move their payload
//!   over the contended [`Fabric`](crate::transport::fabric::Fabric)
//!   (KV caches for prefill→decode handoffs, `est_bytes` otherwise).
//!
//! Entry point: [`crate::cluster::sim::simulate_plan`].

use std::cmp::Reverse;
use std::collections::{BTreeMap, BinaryHeap, VecDeque};

use super::sim::{PipelineSpec, SimReport};
use super::trace::Request;
use crate::cost::kv::kv_cache_bytes;
use crate::cost::model_profile::{by_short_name, ModelProfile};
use crate::cost::roofline::{decode_step_time, prefill_time, Efficiency};
use crate::cost::tco::{FinanceTerms, OpexModel};
use crate::plan::{ExecutionPlan, Role, Stage};
use crate::transport::fabric::{Fabric, NodeAddr};
use crate::util::bench::percentile;
use crate::{Error, Result};

/// One unit of work: node `node` of request `req`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
struct Job {
    req: usize,
    node: usize,
}

#[derive(Debug, Clone, PartialEq)]
enum Ev {
    /// Request hits the front door; its root nodes become ready.
    Arrival(usize),
    /// One incoming dependency of `job` is satisfied (post-transfer).
    DepArrived(Job),
    /// CPU-pool stage finished.
    CpuDone(Job),
    /// Prefill batch `batch` on pipeline `pipe` finished.
    PrefillDone { pipe: usize, batch: u64 },
    /// Decode round boundary on a pipeline.
    DecodeRound(usize),
}

#[derive(Debug, Clone, PartialEq)]
struct Event {
    t: f64,
    seq: u64,
    ev: Ev,
}

impl Eq for Event {}
impl PartialOrd for Event {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for Event {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        self.t
            .partial_cmp(&other.t)
            .unwrap()
            .then(self.seq.cmp(&other.seq))
    }
}

struct PrefillPipe {
    spec: PipelineSpec,
    queue: VecDeque<Job>,
    busy: bool,
    busy_time: f64,
    next_batch: u64,
    in_flight: BTreeMap<u64, Vec<Job>>,
}

struct DecodePipe {
    spec: PipelineSpec,
    active: Vec<Job>,
    waiting: VecDeque<Job>,
    round_scheduled: bool,
    busy_time: f64,
}

/// Mutable per-run state (pipes, pools, per-job bookkeeping).
struct RunState {
    prefill: Vec<PrefillPipe>,
    decode: Vec<DecodePipe>,
    cpu_free: u32,
    cpu_queue: VecDeque<(Job, f64)>,
    /// Unsatisfied dependency count per flat job index.
    remaining: Vec<u32>,
    /// Decode progress per flat job index.
    tokens_done: Vec<u64>,
    /// Pipeline chosen for an LLM job (role, pipe index).
    pipe_of: Vec<Option<(Role, usize)>>,
    /// Per-request nodes still outstanding.
    nodes_left: Vec<usize>,
    /// First decode token per *request* (TTFT).
    first_token_s: Vec<f64>,
    /// Last token time per *job* (TBT sampling per decode stream).
    last_token_s: Vec<f64>,
    done_s: Vec<f64>,
    tbt_samples: Vec<f64>,
    completed: usize,
    kv_bytes_moved: f64,
    output_tokens: u64,
}

/// The agent-DAG simulator. Construct with [`DagSim::new`] from a
/// validated plan; [`DagSim::run`] executes a request trace.
pub struct DagSim {
    pub eff: Efficiency,
    pub opex: OpexModel,
    pub terms: FinanceTerms,
    plan: ExecutionPlan,
    /// None only when the plan has no LLM stages.
    model: Option<ModelProfile>,
    fabric: Fabric,
    /// Successor lists per node index.
    succ: Vec<Vec<usize>>,
    /// Static indegree per node index.
    indeg: Vec<u32>,
    /// Pipeline candidates per (role, class), indices into the expanded
    /// pipe vectors.
    prefill_pipes_of: BTreeMap<String, Vec<usize>>,
    decode_pipes_of: BTreeMap<String, Vec<usize>>,
    /// Expanded pipeline specs (replicas resolved), prefill then decode.
    prefill_specs: Vec<PipelineSpec>,
    decode_specs: Vec<PipelineSpec>,
    heap: BinaryHeap<Reverse<Event>>,
    seq: u64,
}

impl DagSim {
    pub fn new(plan: &ExecutionPlan) -> Result<DagSim> {
        plan.validate()?;
        let has_llm = plan.bindings.iter().any(|b| b.stage != Stage::Cpu);
        let model = by_short_name(&plan.model);
        if has_llm && model.is_none() {
            return Err(Error::Config(format!(
                "plan model `{}` not in the profile catalog",
                plan.model
            )));
        }
        let placement = plan.placement()?;
        let fabric = plan.build_fabric()?;

        let n = plan.bindings.len();
        let mut succ = vec![Vec::new(); n];
        let mut indeg = vec![0u32; n];
        for (i, b) in plan.bindings.iter().enumerate() {
            for &d in &b.deps {
                succ[d].push(i);
                indeg[i] += 1;
            }
        }

        let mut prefill_pipes_of: BTreeMap<String, Vec<usize>> = BTreeMap::new();
        for (k, spec) in placement.prefill.iter().enumerate() {
            prefill_pipes_of
                .entry(spec.device.name.to_string())
                .or_default()
                .push(k);
        }
        let mut decode_pipes_of: BTreeMap<String, Vec<usize>> = BTreeMap::new();
        for (k, spec) in placement.decode.iter().enumerate() {
            decode_pipes_of
                .entry(spec.device.name.to_string())
                .or_default()
                .push(k);
        }

        Ok(DagSim {
            eff: Efficiency::default(),
            opex: OpexModel::Derived,
            terms: FinanceTerms::default(),
            plan: plan.clone(),
            model,
            fabric,
            succ,
            indeg,
            prefill_pipes_of,
            decode_pipes_of,
            prefill_specs: placement.prefill,
            decode_specs: placement.decode,
            heap: BinaryHeap::new(),
            seq: 0,
        })
    }

    fn push(&mut self, t: f64, ev: Ev) {
        self.seq += 1;
        self.heap.push(Reverse(Event {
            t,
            seq: self.seq,
            ev,
        }));
    }

    fn flat(&self, job: Job) -> usize {
        job.req * self.plan.bindings.len() + job.node
    }

    /// Start a prefill batch on pipe `pi` if idle with work queued.
    fn try_start_prefill(&mut self, st: &mut RunState, pi: usize, now: f64, trace: &[Request]) {
        let model = self.model.as_ref().expect("LLM job without model");
        let p = &mut st.prefill[pi];
        if p.busy || p.queue.is_empty() {
            return;
        }
        let take = (p.spec.max_batch as usize).min(p.queue.len());
        let batch: Vec<Job> = p.queue.drain(..take).collect();
        // Batch prefill time at the longest prompt in the batch.
        let isl = batch.iter().map(|j| trace[j.req].isl).max().unwrap_or(1);
        let t_pre = prefill_time(
            model,
            &p.spec.device,
            p.spec.par,
            isl,
            batch.len() as u64,
            &self.eff,
        )
        .total();
        let id = p.next_batch;
        p.next_batch += 1;
        p.busy = true;
        p.busy_time += t_pre;
        p.in_flight.insert(id, batch);
        self.push(now + t_pre, Ev::PrefillDone { pipe: pi, batch: id });
    }

    /// Schedule a decode round on pipe `di` if needed.
    fn maybe_schedule_round(&mut self, st: &mut RunState, di: usize, now: f64, trace: &[Request]) {
        let model = self.model.as_ref().expect("LLM job without model");
        let n_nodes = self.plan.bindings.len();
        let d = &mut st.decode[di];
        if d.round_scheduled {
            return;
        }
        while d.active.len() < d.spec.max_batch as usize {
            match d.waiting.pop_front() {
                Some(j) => d.active.push(j),
                None => break,
            }
        }
        if d.active.is_empty() {
            return;
        }
        let ctx: u64 = d
            .active
            .iter()
            .map(|j| trace[j.req].isl + st.tokens_done[j.req * n_nodes + j.node])
            .sum::<u64>()
            / d.active.len() as u64;
        let step = decode_step_time(
            model,
            &d.spec.device,
            d.spec.par,
            ctx.max(1),
            d.active.len() as u64,
            &self.eff,
        )
        .total();
        let d = &mut st.decode[di];
        d.round_scheduled = true;
        d.busy_time += step;
        self.push(now + step, Ev::DecodeRound(di));
    }

    /// Least-loaded pipe among `candidates`.
    fn pick_prefill(&self, st: &RunState, class: &str) -> usize {
        let cands = &self.prefill_pipes_of[class];
        *cands
            .iter()
            .min_by_key(|&&k| st.prefill[k].queue.len() + st.prefill[k].busy as usize)
            .unwrap()
    }

    fn pick_decode(&self, st: &RunState, class: &str) -> usize {
        let cands = &self.decode_pipes_of[class];
        *cands
            .iter()
            .min_by_key(|&&k| st.decode[k].active.len() + st.decode[k].waiting.len())
            .unwrap()
    }

    /// All dependencies of `job` satisfied: dispatch it to its stage.
    fn dispatch(&mut self, st: &mut RunState, job: Job, now: f64, trace: &[Request]) {
        let binding = &self.plan.bindings[job.node];
        match binding.stage {
            Stage::Cpu => {
                let service = binding.latency_s;
                if st.cpu_free > 0 {
                    st.cpu_free -= 1;
                    self.push(now + service, Ev::CpuDone(job));
                } else {
                    st.cpu_queue.push_back((job, service));
                }
            }
            Stage::LlmPrefill => {
                let fi = self.flat(job);
                let pi = match st.pipe_of[fi] {
                    Some((Role::Prefill, k)) => k,
                    _ => self.pick_prefill(st, &binding.class.clone()),
                };
                st.pipe_of[fi] = Some((Role::Prefill, pi));
                st.prefill[pi].queue.push_back(job);
                self.try_start_prefill(st, pi, now, trace);
            }
            Stage::LlmDecode => {
                let fi = self.flat(job);
                let di = match st.pipe_of[fi] {
                    Some((Role::Decode, k)) => k,
                    _ => self.pick_decode(st, &binding.class.clone()),
                };
                st.pipe_of[fi] = Some((Role::Decode, di));
                st.decode[di].waiting.push_back(job);
                self.maybe_schedule_round(st, di, now, trace);
            }
        }
    }

    /// Chassis a completed job ran on, if pipeline-bound.
    fn chassis_of(&self, st: &RunState, job: Job) -> Option<u32> {
        match st.pipe_of[self.flat(job)] {
            Some((Role::Prefill, k)) => Some(st.prefill[k].spec.chassis),
            Some((Role::Decode, k)) => Some(st.decode[k].spec.chassis),
            None => None,
        }
    }

    /// Node complete: propagate to successors (with fabric transfers for
    /// cross-chassis pipeline edges) and account request completion.
    fn complete_node(
        &mut self,
        st: &mut RunState,
        job: Job,
        now: f64,
        trace: &[Request],
    ) -> Result<()> {
        st.nodes_left[job.req] -= 1;
        if st.nodes_left[job.req] == 0 {
            st.done_s[job.req] = now;
            st.completed += 1;
        }
        let from_chassis = self.chassis_of(st, job);
        let from_stage = self.plan.bindings[job.node].stage;
        let successors = self.succ[job.node].clone();
        for s in successors {
            let succ_job = Job {
                req: job.req,
                node: s,
            };
            let succ_binding = &self.plan.bindings[s];
            let mut arrive = now;
            // Fabric transfer only for pipeline → pipeline edges; CPU
            // stages have no chassis (host-side ingest is part of their
            // profiled latency).
            if succ_binding.stage != Stage::Cpu && from_chassis.is_some() {
                // Destination pipe decided now so the hop is addressable.
                let fi = self.flat(succ_job);
                let (to_chassis, choice) = match succ_binding.stage {
                    Stage::LlmPrefill => {
                        let k = match st.pipe_of[fi] {
                            Some((Role::Prefill, k)) => k,
                            _ => self.pick_prefill(st, &succ_binding.class.clone()),
                        };
                        (st.prefill[k].spec.chassis, (Role::Prefill, k))
                    }
                    Stage::LlmDecode => {
                        let k = match st.pipe_of[fi] {
                            Some((Role::Decode, k)) => k,
                            _ => self.pick_decode(st, &succ_binding.class.clone()),
                        };
                        (st.decode[k].spec.chassis, (Role::Decode, k))
                    }
                    Stage::Cpu => unreachable!(),
                };
                st.pipe_of[fi] = Some(choice);
                let from = NodeAddr {
                    chassis: from_chassis.unwrap(),
                    slot: 0,
                };
                let to = NodeAddr {
                    chassis: to_chassis,
                    slot: 0,
                };
                if from != to {
                    // Prefill → decode hands over the KV cache, sized at
                    // this request's actual prompt; other edges carry
                    // the plan's estimate.
                    let bytes = if from_stage == Stage::LlmPrefill
                        && succ_binding.stage == Stage::LlmDecode
                    {
                        match &self.model {
                            Some(m) => kv_cache_bytes(m, trace[job.req].isl, 1),
                            None => succ_binding.xfer_bytes,
                        }
                    } else {
                        succ_binding.xfer_bytes
                    };
                    st.kv_bytes_moved += bytes;
                    arrive = self.fabric.transfer(from, to, bytes, now)?;
                }
            }
            self.push(arrive, Ev::DepArrived(succ_job));
        }
        Ok(())
    }

    /// Execute the trace to completion; aggregate the serving metrics.
    pub fn run(&mut self, trace: &[Request]) -> Result<SimReport> {
        let n_req = trace.len();
        let n_nodes = self.plan.bindings.len();
        if n_nodes == 0 {
            return Err(Error::Runtime("plan has no bindings to execute".into()));
        }
        if n_req == 0 {
            return Err(Error::Runtime("empty request trace".into()));
        }
        self.fabric.reset();
        self.heap.clear();

        let mut st = RunState {
            prefill: self
                .prefill_specs
                .clone()
                .into_iter()
                .map(|spec| PrefillPipe {
                    spec,
                    queue: VecDeque::new(),
                    busy: false,
                    busy_time: 0.0,
                    next_batch: 0,
                    in_flight: BTreeMap::new(),
                })
                .collect(),
            decode: self
                .decode_specs
                .clone()
                .into_iter()
                .map(|spec| DecodePipe {
                    spec,
                    active: Vec::new(),
                    waiting: VecDeque::new(),
                    round_scheduled: false,
                    busy_time: 0.0,
                })
                .collect(),
            cpu_free: self.plan.cpu_workers,
            cpu_queue: VecDeque::new(),
            remaining: (0..n_req)
                .flat_map(|_| self.indeg.iter().copied())
                .collect(),
            tokens_done: vec![0; n_req * n_nodes],
            pipe_of: vec![None; n_req * n_nodes],
            nodes_left: vec![n_nodes; n_req],
            first_token_s: vec![f64::NAN; n_req],
            last_token_s: vec![0.0; n_req * n_nodes],
            done_s: vec![0.0; n_req],
            tbt_samples: Vec::new(),
            completed: 0,
            kv_bytes_moved: 0.0,
            output_tokens: 0,
        };

        for (i, r) in trace.iter().enumerate() {
            self.push(r.arrive_s, Ev::Arrival(i));
        }

        let mut events = 0u64;
        let mut makespan = 0.0f64;
        while let Some(Reverse(Event { t, ev, .. })) = self.heap.pop() {
            events += 1;
            if events > 100_000_000 {
                return Err(Error::Runtime("event budget exceeded".into()));
            }
            makespan = makespan.max(t);
            match ev {
                Ev::Arrival(req) => {
                    for node in 0..n_nodes {
                        if self.indeg[node] == 0 {
                            self.dispatch(&mut st, Job { req, node }, t, trace);
                        }
                    }
                }
                Ev::DepArrived(job) => {
                    let fi = self.flat(job);
                    st.remaining[fi] -= 1;
                    if st.remaining[fi] == 0 {
                        self.dispatch(&mut st, job, t, trace);
                    }
                }
                Ev::CpuDone(job) => {
                    // Hand the slot to the next queued stage, if any.
                    if let Some((next, service)) = st.cpu_queue.pop_front() {
                        self.push(t + service, Ev::CpuDone(next));
                    } else {
                        st.cpu_free += 1;
                    }
                    self.complete_node(&mut st, job, t, trace)?;
                }
                Ev::PrefillDone { pipe, batch } => {
                    st.prefill[pipe].busy = false;
                    let members = st.prefill[pipe].in_flight.remove(&batch).unwrap();
                    for job in members {
                        self.complete_node(&mut st, job, t, trace)?;
                    }
                    self.try_start_prefill(&mut st, pipe, t, trace);
                }
                Ev::DecodeRound(di) => {
                    st.decode[di].round_scheduled = false;
                    let active = st.decode[di].active.clone();
                    let mut still = Vec::with_capacity(active.len());
                    for job in active {
                        let fi = self.flat(job);
                        if st.tokens_done[fi] == 0 {
                            if st.first_token_s[job.req].is_nan() {
                                st.first_token_s[job.req] = t;
                            }
                        } else {
                            st.tbt_samples.push(t - st.last_token_s[fi]);
                        }
                        st.last_token_s[fi] = t;
                        st.tokens_done[fi] += 1;
                        st.output_tokens += 1;
                        if st.tokens_done[fi] >= trace[job.req].osl {
                            self.complete_node(&mut st, job, t, trace)?;
                        } else {
                            still.push(job);
                        }
                    }
                    st.decode[di].active = still;
                    self.maybe_schedule_round(&mut st, di, t, trace);
                }
            }
        }

        if st.completed != n_req {
            return Err(Error::Runtime(format!(
                "DAG simulation stalled: {}/{} requests completed",
                st.completed, n_req
            )));
        }

        let ttfts: Vec<f64> = (0..n_req)
            .map(|i| {
                // Requests without decode stages: time to completion.
                if st.first_token_s[i].is_nan() {
                    st.done_s[i] - trace[i].arrive_s
                } else {
                    st.first_token_s[i] - trace[i].arrive_s
                }
            })
            .collect();
        let e2es: Vec<f64> = (0..n_req)
            .map(|i| st.done_s[i] - trace[i].arrive_s)
            .collect();

        // Fleet cost: the LLM pipelines (CPU workers are priced into the
        // planner's per-request cost, not the serving fleet $/hr —
        // matching the flat simulator's accounting).
        let usd_per_hr = self
            .plan
            .placement()?
            .usd_per_hour(self.opex, &self.terms);
        let tokens_per_s = if makespan > 0.0 {
            st.output_tokens as f64 / makespan
        } else {
            0.0
        };
        let dev_seconds = |pipes_busy: &[(f64, f64)]| -> (f64, f64) {
            let busy: f64 = pipes_busy.iter().map(|(b, d)| b * d).sum();
            let total: f64 = pipes_busy.iter().map(|(_, d)| d).sum::<f64>() * makespan;
            (busy, total)
        };
        let (p_busy, p_total) = dev_seconds(
            &st.prefill
                .iter()
                .map(|p| (p.busy_time, p.spec.par.devices() as f64))
                .collect::<Vec<_>>(),
        );
        let (d_busy, d_total) = dev_seconds(
            &st.decode
                .iter()
                .map(|d| (d.busy_time, d.spec.par.devices() as f64))
                .collect::<Vec<_>>(),
        );

        Ok(SimReport {
            n_requests: n_req,
            makespan_s: makespan,
            ttft_p50_s: percentile(&ttfts, 50.0),
            ttft_p95_s: percentile(&ttfts, 95.0),
            tbt_p50_s: if st.tbt_samples.is_empty() {
                0.0
            } else {
                percentile(&st.tbt_samples, 50.0)
            },
            tbt_p95_s: if st.tbt_samples.is_empty() {
                0.0
            } else {
                percentile(&st.tbt_samples, 95.0)
            },
            e2e_p50_s: percentile(&e2es, 50.0),
            output_tokens: st.output_tokens,
            tokens_per_s,
            usd_per_mtok: if tokens_per_s > 0.0 {
                usd_per_hr / 3600.0 / tokens_per_s * 1e6
            } else {
                0.0
            },
            prefill_utilization: if p_total > 0.0 { p_busy / p_total } else { 0.0 },
            decode_utilization: if d_total > 0.0 { d_busy / d_total } else { 0.0 },
            kv_bytes_moved: st.kv_bytes_moved,
            events_processed: events,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::trace::{generate, TraceConfig};
    use crate::cost::Precision;
    use crate::plan::tests::tiny_plan;
    use crate::plan::{AdmissionPolicy, BatchPolicy, FabricSpec, NodeBinding};

    fn trace(n: usize, rate: f64) -> Vec<Request> {
        generate(&TraceConfig {
            n_requests: n,
            rate,
            isl_mean: 512,
            osl_mean: 32,
            sigma: 0.0,
            seed: 9,
        })
    }

    #[test]
    fn tiny_dag_completes_all_requests() {
        let plan = tiny_plan();
        let mut sim = DagSim::new(&plan).unwrap();
        let t = trace(24, 4.0);
        let r = sim.run(&t).unwrap();
        assert_eq!(r.n_requests, 24);
        // One decode node per request → osl tokens each.
        assert_eq!(r.output_tokens, t.iter().map(|r| r.osl).sum::<u64>());
        assert!(r.makespan_s > 0.0);
        assert!(r.ttft_p50_s > 0.0);
        assert!(r.e2e_p50_s >= r.ttft_p50_s);
    }

    #[test]
    fn cross_chassis_handoff_moves_kv_bytes() {
        let plan = tiny_plan(); // prefill H100 (chassis 0) → decode Gaudi3
        let mut sim = DagSim::new(&plan).unwrap();
        let t = trace(8, 2.0);
        let r = sim.run(&t).unwrap();
        let m = crate::cost::model_profile::llama3_8b(Precision::Fp16);
        let expected: f64 = t.iter().map(|r| kv_cache_bytes(&m, r.isl, 1)).sum();
        assert!(
            (r.kv_bytes_moved - expected).abs() < 1.0,
            "moved {} expected {expected}",
            r.kv_bytes_moved
        );
    }

    #[test]
    fn deterministic_replay() {
        let plan = tiny_plan();
        let t = trace(16, 8.0);
        let r1 = DagSim::new(&plan).unwrap().run(&t).unwrap();
        let r2 = DagSim::new(&plan).unwrap().run(&t).unwrap();
        assert_eq!(r1.events_processed, r2.events_processed);
        assert_eq!(r1.tokens_per_s, r2.tokens_per_s);
        assert_eq!(r1.kv_bytes_moved, r2.kv_bytes_moved);
    }

    #[test]
    fn cpu_only_dag_runs_without_pipelines() {
        let plan = ExecutionPlan {
            agent: "tools_only".into(),
            model: String::new(),
            sla: crate::plan::SlaSpec::None,
            bindings: vec![
                NodeBinding {
                    op: "io.input".into(),
                    class: "CPU".into(),
                    stage: Stage::Cpu,
                    latency_s: 0.001,
                    cost_usd: 0.0,
                    deps: vec![],
                    xfer_bytes: 0.0,
                },
                NodeBinding {
                    op: "tool.lookup".into(),
                    class: "CPU".into(),
                    stage: Stage::Cpu,
                    latency_s: 0.3,
                    cost_usd: 0.0,
                    deps: vec![0],
                    xfer_bytes: 0.0,
                },
                NodeBinding {
                    op: "io.output".into(),
                    class: "CPU".into(),
                    stage: Stage::Cpu,
                    latency_s: 0.001,
                    cost_usd: 0.0,
                    deps: vec![1],
                    xfer_bytes: 0.0,
                },
            ],
            pipelines: vec![],
            batching: BatchPolicy::default(),
            admission: AdmissionPolicy::default(),
            fabric: FabricSpec::default(),
            cpu_workers: 2,
            cost_usd: 0.0,
            latency_s: 0.302,
            pass_log: vec![],
        };
        let mut sim = DagSim::new(&plan).unwrap();
        let t = trace(12, 50.0); // overload the 2-slot pool
        let r = sim.run(&t).unwrap();
        assert_eq!(r.n_requests, 12);
        assert_eq!(r.output_tokens, 0);
        // No decode stages: TTFT falls back to completion time.
        assert!(r.ttft_p50_s >= 0.3);
        // 12 × 0.302 s of work on 2 slots arriving in ~0.24 s: the pool
        // must serialize (makespan well beyond a single request chain).
        assert!(r.makespan_s > 1.0, "cpu pool did not queue: {}", r.makespan_s);
    }

    #[test]
    fn cpu_pool_size_bounds_throughput() {
        let mut narrow = tiny_plan();
        narrow.cpu_workers = 1;
        let mut wide = tiny_plan();
        wide.cpu_workers = 64;
        // Raise CPU stage cost so the pool is the bottleneck.
        for p in [&mut narrow, &mut wide] {
            p.bindings[0].latency_s = 0.2;
            p.bindings[3].latency_s = 0.2;
        }
        let t = trace(24, 100.0);
        let rn = DagSim::new(&narrow).unwrap().run(&t).unwrap();
        let rw = DagSim::new(&wide).unwrap().run(&t).unwrap();
        assert!(
            rn.makespan_s > rw.makespan_s * 1.5,
            "narrow {} vs wide {}",
            rn.makespan_s,
            rw.makespan_s
        );
    }
}
