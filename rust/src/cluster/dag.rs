//! Agent-DAG discrete-event simulation: execute an
//! [`ExecutionPlan`](crate::plan::ExecutionPlan) — not just a flat LLM
//! request stream — against the planned fleet.
//!
//! Where [`super::sim::ClusterSim`] models the classic disaggregated
//! prefill → decode pipeline for one LLM per request, [`DagSim`] walks
//! the *whole bound agent graph* per request, as MARS-style agent
//! co-scheduling does and as the CPU-centric agentic-execution study
//! argues is necessary (non-LLM stages dominate once they are
//! first-class):
//!
//! * **CPU stages** (STT/TTS, tool calls, memory/IO/control ops) run on
//!   a bounded worker pool at the planner-profiled latency, queueing
//!   FIFO when the pool saturates;
//! * **LLM prefill/decode stages** run on the plan's pipelines with the
//!   same roofline timing, bucketed prefill batching, and
//!   continuous-batching decode rounds as the flat simulator — a
//!   request may contain *several* LLM inferences (supervisor patterns,
//!   MoE experts) and each is scheduled independently, with the
//!   request's ISL/OSL scaled by each node's `token_fraction` (expert
//!   parallelism routes ~top_k/N of the stream per expert);
//! * **edges** between stages on different chassis move their payload
//!   over the contended [`Fabric`](crate::transport::fabric::Fabric)
//!   (KV caches for prefill→decode handoffs, `est_bytes` otherwise).
//!
//! The fleet is **time-varying**: [`DagSim::run_controlled`] invokes a
//! [`FleetController`] at fixed observation windows, and the controller
//! may hand back a new `ExecutionPlan`. Pipelines matching the new plan
//! survive untouched; surplus pipelines retire gracefully (in-flight
//! work finishes, queued decode sessions migrate their KV over the
//! fabric — occupying real links); missing pipelines activate on their
//! target chassis. No in-flight request is ever dropped. This is what
//! the `orchestrator` subsystem drives to evaluate re-planning policies
//! end-to-end against traced load swings.
//!
//! Ingestion is **streaming**: [`DagSim::run_stream`] pulls requests
//! lazily from any [`ArrivalProcess`] — the event queue holds at most
//! one future arrival, so a million-request diurnal day simulates in
//! memory bounded by the *in-flight* set, not the trace length.
//! Per-request state lives in a recycled slot slab, latency
//! percentiles stream through [`QuantileSketch`], and the event loop
//! runs on the calendar-queue [`EventQueue`]. The historical slice
//! APIs ([`DagSim::run`]/[`DagSim::run_controlled`]) are thin
//! [`Replay`] wrappers — byte-identical reports, pinned by the
//! replay-equivalence suite (`rust/tests/arrivals.rs`).
//!
//! Entry point: [`crate::cluster::sim::simulate_plan`] (static fleet)
//! or [`crate::orchestrator`] (closed-loop).

use std::collections::{BTreeMap, VecDeque};
use std::sync::Arc;

use super::arrivals::{ArrivalProcess, Replay};
use super::eventq::EventQueue;
use super::sim::{PipelineSpec, SimReport};
use super::trace::Request;
use crate::cost::kv::kv_cache_bytes;
use crate::cost::model_profile::{by_short_name, ModelProfile};
use crate::cost::roofline::{decode_step_time, prefill_time, Efficiency};
use crate::cost::tco::{opex_usd_per_hour, FinanceTerms, OpexModel};
use crate::kvcache::manager::NodeBudget;
use crate::kvcache::{CacheManager, PagedAllocator, Tier};
use crate::obs::trace::{classify_host_op, Span, SpanKind, TraceSink};
use crate::plan::instance::{edge_payload_bytes, DagTopology};
use crate::plan::{ExecutionPlan, Role, SlaSpec, Stage};
use crate::transport::fabric::TransferClock;
use crate::util::stats::QuantileSketch;
use crate::{Error, Result};

/// One unit of work: node `node` of request `req`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
struct Job {
    req: usize,
    node: usize,
}

#[derive(Debug, Clone, PartialEq)]
enum Ev {
    /// Request hits the front door; its root nodes become ready. The
    /// payload is a *slot* index into `RunState::slots` (slots are
    /// recycled as requests complete, keeping state O(in-flight)).
    Arrival(usize),
    /// One incoming dependency of `job` is satisfied (post-transfer).
    /// `from` is the completed upstream node — the last one to arrive
    /// becomes the job's gating edge (`Span::parent`).
    DepArrived { job: Job, from: usize },
    /// CPU-pool stage finished.
    CpuDone(Job),
    /// Prefill batch `batch` on pipeline `pipe` finished.
    PrefillDone { pipe: usize, batch: u64 },
    /// Decode round boundary on a pipeline.
    DecodeRound(usize),
    /// A drained decode session's KV landed on pipeline `to`.
    KvMigrated { job: Job, to: usize },
    /// Observation-window boundary (controlled runs only).
    WindowTick,
}

struct PrefillPipe {
    spec: PipelineSpec,
    /// Canonical group key of this pipe (see [`group_key`]), computed
    /// once at construction — the hot paths (per-job group counters,
    /// prefix-cache consults) were formatting it per call.
    gkey: String,
    queue: VecDeque<Job>,
    busy: bool,
    busy_time: f64,
    /// `busy_time` at the last window boundary (per-group windowed
    /// utilization).
    prev_busy: f64,
    next_batch: u64,
    in_flight: BTreeMap<u64, Vec<Job>>,
    /// Draining: accepts no new work; in-flight batches finish.
    retired: bool,
    created_s: f64,
    retired_s: Option<f64>,
}

struct DecodePipe {
    spec: PipelineSpec,
    /// Canonical group key of this pipe (see [`group_key`]), computed
    /// once at construction.
    gkey: String,
    active: Vec<Job>,
    waiting: VecDeque<Job>,
    round_scheduled: bool,
    busy_time: f64,
    /// `busy_time` at the last window boundary (per-group windowed
    /// utilization).
    prev_busy: f64,
    /// Draining: active sessions finish here; waiting sessions migrate.
    retired: bool,
    created_s: f64,
    retired_s: Option<f64>,
}

impl PrefillPipe {
    fn new(spec: PipelineSpec, created_s: f64) -> PrefillPipe {
        PrefillPipe {
            gkey: group_key(Role::Prefill, &spec),
            spec,
            queue: VecDeque::new(),
            busy: false,
            busy_time: 0.0,
            prev_busy: 0.0,
            next_batch: 0,
            in_flight: BTreeMap::new(),
            retired: false,
            created_s,
            retired_s: None,
        }
    }
}

impl DecodePipe {
    fn new(spec: PipelineSpec, created_s: f64) -> DecodePipe {
        DecodePipe {
            gkey: group_key(Role::Decode, &spec),
            spec,
            active: Vec::new(),
            waiting: VecDeque::new(),
            round_scheduled: false,
            busy_time: 0.0,
            prev_busy: 0.0,
            retired: false,
            created_s,
            retired_s: None,
        }
    }
}

/// One pipeline group's window observation: the per-hardware-generation
/// signal the orchestrator's group-granular rebalancing consumes. Both
/// backends produce these — the simulator from per-pipe busy time, the
/// live server from its engine pool ([`crate::server::Server::group_utilization`]).
#[derive(Debug, Clone)]
pub struct GroupWindow {
    pub role: Role,
    /// Canonical shape key (see [`crate::plan::PipelineBinding::shape_key`]).
    pub key: String,
    pub device: String,
    /// Live (non-retired) replicas at the window boundary.
    pub replicas: u32,
    /// Per-replica batch limit (backlog normalization).
    pub max_batch: u64,
    /// Device-time utilization of the group over the window.
    pub util: f64,
    /// Queued jobs at the boundary (prefill queues / decode waiting).
    pub queue: usize,
    /// Prefix-cache hits / misses over the window — 0 unless KV reuse
    /// is active on the backend. A high hit rate means the group's
    /// effective prefill demand is lower than its job count suggests,
    /// which the orchestrator folds into its scaling pressure.
    pub prefix_hits: u64,
    pub prefix_misses: u64,
}

/// Per-window observations handed to the [`FleetController`] — the raw
/// material for autoscaling and SLA-driven re-planning decisions.
#[derive(Debug, Clone)]
pub struct WindowStats {
    pub t0: f64,
    pub t1: f64,
    /// Requests that arrived / completed inside the window.
    pub arrivals: usize,
    pub completed: usize,
    /// Fraction of window completions inside the plan SLA (1.0 when
    /// nothing completed or the plan has no SLA).
    pub sla_attained: f64,
    /// Device-time utilization of live pipelines over the window.
    pub prefill_util: f64,
    pub decode_util: f64,
    /// CPU worker-pool utilization over the window (tool/IO stages).
    pub host_util: f64,
    /// Instantaneous backlog at the window boundary.
    pub prefill_queue: usize,
    pub decode_queue: usize,
    pub decode_active: usize,
    /// KV bytes resident on decode pipelines right now (sizes the cost
    /// of draining them).
    pub kv_resident_bytes: f64,
    /// Live pipeline counts per role.
    pub prefill_pipes: u32,
    pub decode_pipes: u32,
    /// Per-pipeline-group observations (empty when the backend cannot
    /// attribute load to groups — the loop then falls back to
    /// role-aggregate decisions).
    pub groups: Vec<GroupWindow>,
}

/// What a fleet change actually did (returned to the controller so it
/// can reconcile planned vs observed migration cost).
#[derive(Debug, Clone, Default)]
pub struct FleetChangeStats {
    pub t: f64,
    /// Pipelines brought up / retired.
    pub activated: u32,
    pub retired: u32,
    /// Decode sessions whose KV moved over the fabric.
    pub kv_moves: u32,
    pub kv_bytes: f64,
    /// When the last in-flight KV migration lands (== `t` if none).
    pub done_s: f64,
}

/// splitmix64 finalizer — the same mixer the live dispatcher uses for
/// its payload digests; here it derives context/prefix identities.
fn mix64(mut x: u64) -> u64 {
    x = x.wrapping_add(0x9E3779B97F4A7C15);
    x = (x ^ (x >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    x = (x ^ (x >> 27)).wrapping_mul(0x94D049BB133111EB);
    x ^ (x >> 31)
}

/// Per-group tiered budgets for cross-step prefix-KV reuse. When
/// attached via [`DagSim::set_kv_reuse`], LLM prefill admission hashes
/// each job's gating parents (the same context identity the live
/// dispatcher derives from its concatenated input payloads), consults a
/// per-pipeline-group [`CacheManager`] + [`PagedAllocator`], and
/// charges prefill only for the uncached suffix. Reuse is **off by
/// default**: runs without it are bit-identical to before.
#[derive(Debug, Clone)]
pub struct KvReuseConfig {
    /// Per-group HBM prefix-pool bytes (page-quantized).
    pub hbm_bytes: f64,
    /// DRAM / disk spill tiers per group — a colder-tier hit pays the
    /// tier's restore latency instead of a full re-prefill.
    pub dram_bytes: f64,
    pub disk_bytes: f64,
    /// Paged-allocator page size, tokens.
    pub page_tokens: u32,
}

impl Default for KvReuseConfig {
    fn default() -> KvReuseConfig {
        KvReuseConfig {
            hbm_bytes: 16e9,
            dram_bytes: 64e9,
            disk_bytes: 256e9,
            page_tokens: 256,
        }
    }
}

/// Per-run prefix-cache state: one cache node and one HBM page pool per
/// prefill pipeline group, assigned lazily as groups first dispatch.
/// The [`CacheManager`] is the tier directory (LRU demotion under
/// pressure), the [`PagedAllocator`] shadows HBM residency at page
/// granularity; both are sized from the same budget so they agree on
/// capacity. Shared with the live dispatcher
/// (`server::dag_exec::DagDispatch`) so both backends run *identical*
/// hit/miss accounting — the basis of the conformance suite's exact
/// per-group hit-count parity.
pub(crate) struct KvReuse {
    cache: CacheManager,
    pages: Vec<PagedAllocator>,
    /// Sessions currently shadowed in each group's page pool.
    resident: Vec<Vec<u64>>,
    node_of_group: BTreeMap<String, u32>,
    /// Cached prefix length per session, tokens.
    tokens_of: BTreeMap<u64, u64>,
    /// KV bytes per token of the plan's model (page pricing).
    token_bytes: f64,
    page_tokens: u32,
    /// Cumulative per-group hit/miss ledgers plus window snapshots.
    hits: BTreeMap<String, u64>,
    misses: BTreeMap<String, u64>,
    prev_hits: BTreeMap<String, u64>,
    prev_misses: BTreeMap<String, u64>,
}

impl KvReuse {
    pub(crate) fn new(cfg: &KvReuseConfig, n_groups: usize, token_bytes: f64) -> KvReuse {
        let page_bytes = cfg.page_tokens as f64 * token_bytes;
        let pages_per_group = ((cfg.hbm_bytes / page_bytes).floor() as u32).max(1);
        // Quantize the HBM budget to whole pages so the directory and
        // the page pool can never disagree on what fits.
        let hbm = pages_per_group as f64 * page_bytes;
        let budgets = vec![
            NodeBudget {
                hbm,
                dram: cfg.dram_bytes,
                disk: cfg.disk_bytes,
            };
            n_groups
        ];
        KvReuse {
            cache: CacheManager::new(budgets),
            pages: (0..n_groups)
                .map(|_| PagedAllocator::new(pages_per_group, cfg.page_tokens))
                .collect(),
            resident: vec![Vec::new(); n_groups],
            node_of_group: BTreeMap::new(),
            tokens_of: BTreeMap::new(),
            token_bytes,
            page_tokens: cfg.page_tokens,
            hits: BTreeMap::new(),
            misses: BTreeMap::new(),
            prev_hits: BTreeMap::new(),
            prev_misses: BTreeMap::new(),
        }
    }

    /// The tier directory, read-only — what the prefix-hit
    /// [`crate::router::Router`] probes (`find_prefix` worker ids are
    /// the cache node ids handed out by [`Self::node_for`]).
    pub(crate) fn cache(&self) -> &CacheManager {
        &self.cache
    }

    /// Cache node for a group key, assigned on first sight. None when
    /// more groups appeared (fleet changes) than nodes were pre-sized
    /// for — those groups bypass the cache (every lookup misses).
    pub(crate) fn node_for(&mut self, key: &str) -> Option<u32> {
        if let Some(&n) = self.node_of_group.get(key) {
            return Some(n);
        }
        let n = self.node_of_group.len();
        if n < self.pages.len() {
            self.node_of_group.insert(key.to_string(), n as u32);
            Some(n as u32)
        } else {
            None
        }
    }

    /// Session identity of a context hash pinned to a group: the first
    /// job writes it, every later job with the same context hits it.
    fn session_of(node: u32, hash: u64) -> u64 {
        mix64(hash ^ (((node as u64) << 48) | 0x5EED))
    }

    /// Whether `key`'s group already holds `hash` (read-only; the
    /// prefix-affinity routing probe).
    fn holds(&self, key: &str, hash: u64) -> bool {
        self.node_of_group
            .get(key)
            .is_some_and(|&n| self.cache.locate(Self::session_of(n, hash)).is_some())
    }

    /// Reconcile the page shadow with the directory: free pages of
    /// sessions the manager demoted out of HBM since the last sync.
    fn sync_pages(&mut self, node: u32) {
        let ni = node as usize;
        let cache = &self.cache;
        let pages = &mut self.pages[ni];
        self.resident[ni].retain(|&s| {
            if cache.locate(s) == Some((node, Tier::Hbm)) {
                true
            } else {
                let _ = pages.free_seq(s);
                false
            }
        });
    }

    /// Shadow an HBM-resident session's pages after an insert or a
    /// promoting touch.
    fn shadow(&mut self, node: u32, session: u64, tokens: u64) {
        self.sync_pages(node);
        let ni = node as usize;
        if self.cache.locate(session) != Some((node, Tier::Hbm)) || self.pages[ni].has_seq(session)
        {
            return;
        }
        if self.pages[ni].alloc_seq(session, tokens.max(1)).is_ok() {
            self.resident[ni].push(session);
        }
    }

    /// Consult the group's prefix cache for a prefill of `tokens`
    /// tokens under context `hash`: returns the tokens to actually
    /// prefill (the uncached suffix on a hit, ≥ 1), any tier-restore
    /// stall, and whether it was a hit, recording it in the per-group
    /// ledger.
    pub(crate) fn consult(&mut self, key: &str, hash: u64, tokens: u64) -> (u64, f64, bool) {
        let Some(node) = self.node_for(key) else {
            *self.misses.entry(key.to_string()).or_insert(0) += 1;
            return (tokens, 0.0, false);
        };
        let session = Self::session_of(node, hash);
        if self.cache.locate(session).is_some() {
            *self.hits.entry(key.to_string()).or_insert(0) += 1;
            // Colder-tier hits stall for the restore, not a re-prefill.
            let restore = self.cache.restore_latency_s(session);
            self.cache.touch(session);
            let cached = self.tokens_of.get(&session).copied().unwrap_or(0);
            self.shadow(node, session, cached);
            (tokens.saturating_sub(cached).max(1), restore, true)
        } else {
            *self.misses.entry(key.to_string()).or_insert(0) += 1;
            let page_bytes = self.page_tokens as f64 * self.token_bytes;
            let bytes =
                self.pages[node as usize].pages_for(tokens.max(1)) as f64 * page_bytes;
            // Insert can fail when the spill tiers are exhausted — the
            // prefix simply stays uncacheable and later jobs miss: hit
            // rate is capacity-dependent, not a constant.
            if self.cache.insert(session, node, bytes, hash).is_ok() {
                self.tokens_of.insert(session, tokens);
                self.shadow(node, session, tokens);
            }
            (tokens, 0.0, false)
        }
    }

    /// Per-group hit/miss deltas since the last window, rolling the
    /// snapshot.
    pub(crate) fn window_delta(&mut self, key: &str) -> (u64, u64) {
        let h = self.hits.get(key).copied().unwrap_or(0);
        let m = self.misses.get(key).copied().unwrap_or(0);
        let dh = h - self.prev_hits.get(key).copied().unwrap_or(0);
        let dm = m - self.prev_misses.get(key).copied().unwrap_or(0);
        self.prev_hits.insert(key.to_string(), h);
        self.prev_misses.insert(key.to_string(), m);
        (dh, dm)
    }
}

/// Closed-loop hook: observe window boundaries, optionally re-plan.
pub trait FleetController {
    /// Called at each window boundary. Returning a plan migrates the
    /// running fleet to it — in-flight work is preserved. The plan must
    /// keep the same model and cover every LLM binding's (role, class).
    fn on_window(&mut self, stats: &WindowStats) -> Option<ExecutionPlan>;

    /// Called after a returned plan has been applied.
    fn on_applied(&mut self, _t: f64, _stats: &FleetChangeStats) {}
}

/// Static-fleet runs: never intervenes.
struct NoopFleetController;

impl FleetController for NoopFleetController {
    fn on_window(&mut self, _stats: &WindowStats) -> Option<ExecutionPlan> {
        None
    }
}

/// Per-node execution state of one in-flight request (one entry per
/// plan binding, indexed by node).
#[derive(Debug, Clone, Copy)]
struct NodeSt {
    /// Unsatisfied dependency count.
    remaining: u32,
    /// Dispatch-ready time (sojourn accounting).
    ready_s: f64,
    /// Execution-start time (NaN until started) — `Span::t_start`,
    /// with `start - ready` as the queue wait.
    start_s: f64,
    /// Last-arriving dependency node (-1 for roots) — the gating edge
    /// recorded as `Span::parent`.
    dep_from: i64,
    /// ISL/OSL snapshotted at request arrival (the request's lengths
    /// scaled by the node's `token_fraction` *as bound at arrival*): a
    /// mid-run token-fraction retune only redirects requests that
    /// arrive after it — in-flight work keeps the split it was
    /// admitted under.
    isl: u64,
    osl: u64,
    /// Decode progress.
    tokens_done: u64,
    /// Last token time (TBT sampling per decode stream).
    last_token_s: f64,
    /// Pipeline chosen for an LLM job (role, pipe index).
    pipe: Option<(Role, usize)>,
}

impl NodeSt {
    fn fresh() -> NodeSt {
        NodeSt {
            remaining: 0,
            ready_s: 0.0,
            start_s: f64::NAN,
            dep_from: -1,
            isl: 0,
            osl: 0,
            tokens_done: 0,
            last_token_s: 0.0,
            pipe: None,
        }
    }
}

/// One in-flight request's slab slot. Slots are recycled as requests
/// complete (`RunState::free_slots`), so live memory is bounded by the
/// *in-flight* set — the streaming-ingestion contract that lets a
/// million-request day run in constant memory.
struct ReqSlot {
    /// External request id ([`Request::id`]) — stable across slot
    /// reuse; feeds span attribution and the prefix-cache context hash.
    ext_id: u64,
    arrive_s: f64,
    /// Unscaled request lengths (per-node scaling applied at the
    /// Arrival event).
    isl_raw: u64,
    osl_raw: u64,
    /// Plan nodes still outstanding; 0 frees the slot.
    nodes_left: usize,
    /// First decode token (TTFT; NaN until emitted).
    first_token_s: f64,
    nodes: Vec<NodeSt>,
}

impl ReqSlot {
    fn empty() -> ReqSlot {
        ReqSlot {
            ext_id: 0,
            arrive_s: 0.0,
            isl_raw: 0,
            osl_raw: 0,
            nodes_left: 0,
            first_token_s: f64::NAN,
            nodes: Vec::new(),
        }
    }
}

/// Increment a per-group counter without allocating the key `String`
/// on the hit path.
fn bump_group(map: &mut BTreeMap<String, u64>, key: &str) {
    if let Some(v) = map.get_mut(key) {
        *v += 1;
    } else {
        map.insert(key.to_string(), 1);
    }
}

/// Mutable per-run state (pipes, pools, per-job bookkeeping).
struct RunState {
    prefill: Vec<PrefillPipe>,
    decode: Vec<DecodePipe>,
    /// Live (non-retired) pipeline indices per hardware class.
    prefill_pipes_of: BTreeMap<String, Vec<usize>>,
    decode_pipes_of: BTreeMap<String, Vec<usize>>,
    /// Current CPU pool width — fleet changes resize it mid-run (the
    /// cpu_workers autoscaler's knob), so it lives in run state rather
    /// than on the immutable plan.
    cpu_workers: u32,
    /// CPU stages currently executing (≤ `cpu_workers` except briefly
    /// after a shrink, while over-width stages finish).
    cpu_busy: u32,
    cpu_queue: VecDeque<(Job, f64)>,
    /// CPU pool busy time (service time attributed at start, like the
    /// pipeline `busy_time`s).
    cpu_busy_time: f64,
    /// In-flight request slots (`Job::req` indexes this slab).
    slots: Vec<ReqSlot>,
    /// Recycled slot indices — popped before growing the slab.
    free_slots: Vec<usize>,
    /// Requests pulled from the arrival process so far.
    arrived: usize,
    /// Last admitted arrival time (monotonicity guard on the stream).
    last_arrival_s: f64,
    /// High-watermark of concurrently in-flight requests.
    inflight_peak: usize,
    /// Per-node sojourn (ready → complete) sums and counts.
    node_lat_sum: Vec<f64>,
    node_lat_n: Vec<u64>,
    /// Jobs dispatched per stage kind (cross-backend conformance).
    host_jobs: u64,
    prefill_jobs: u64,
    decode_jobs: u64,
    /// LLM jobs dispatched per pipeline group, keyed by shape key —
    /// the per-group counts the cross-backend conformance suite pins
    /// against the live server's `server_group_jobs:*` counters.
    jobs_by_group: BTreeMap<String, u64>,
    /// Busy-time aggregates at the last window boundary.
    prev_pre_busy: f64,
    prev_dec_busy: f64,
    prev_cpu_busy: f64,
    /// Streaming latency percentiles — exact below the sketch cap,
    /// bounded-memory log-histogram beyond it, so a million-request
    /// run never materializes per-request latency vectors.
    ttft: QuantileSketch,
    e2e: QuantileSketch,
    tbt: QuantileSketch,
    /// Recycled scratch for decode-round survivor rebuilds.
    round_scratch: Vec<Job>,
    /// Recycled prefill-batch buffers.
    batch_pool: Vec<Vec<Job>>,
    completed: usize,
    kv_bytes_moved: f64,
    output_tokens: u64,
    /// Cross-step prefix reuse state (None = reuse disabled).
    reuse: Option<KvReuse>,
    /// Prompt tokens actually prefilled — with reuse on, only the
    /// uncached suffixes are charged, so this shrinks as hit rate
    /// rises. Compared against the live server's prefill-token counter
    /// by the conformance suite.
    prefill_tokens: u64,
    // Window accumulators (reset at every tick).
    win_arrivals: usize,
    win_completed: usize,
    win_sla_ok: usize,
}

impl RunState {
    /// Rebuild the class → pipeline routing maps over live pipes (run
    /// start and after every fleet change).
    fn rebuild_routing_maps(&mut self) {
        self.prefill_pipes_of.clear();
        for (k, p) in self.prefill.iter().enumerate() {
            if !p.retired {
                self.prefill_pipes_of
                    .entry(p.spec.device.name.to_string())
                    .or_default()
                    .push(k);
            }
        }
        self.decode_pipes_of.clear();
        for (k, d) in self.decode.iter().enumerate() {
            if !d.retired {
                self.decode_pipes_of
                    .entry(d.spec.device.name.to_string())
                    .or_default()
                    .push(k);
            }
        }
    }
}

/// Per-stage execution detail of the last finished run — the quantities
/// the cross-backend conformance suite (`rust/tests/sim_vs_live.rs`)
/// compares against the live server's measured metrics.
#[derive(Debug, Clone, Default)]
pub struct DagDetail {
    /// Jobs dispatched to the CPU worker pool.
    pub host_jobs: u64,
    /// Jobs dispatched to prefill / decode pipelines.
    pub prefill_jobs: u64,
    pub decode_jobs: u64,
    /// LLM jobs dispatched per pipeline group (shape key → count) —
    /// compared 1:1 against the live server's per-group counters by
    /// `rust/tests/sim_vs_live.rs`.
    pub jobs_by_group: BTreeMap<String, u64>,
    /// Mean sojourn (dispatch-ready → complete) per plan binding.
    pub node_mean_latency_s: Vec<f64>,
    /// Prompt tokens actually prefilled (reuse-on charges only uncached
    /// suffixes, so this drops as the prefix cache warms).
    pub prefill_tokens: u64,
    /// Cumulative prefix-cache hits / misses per pipeline group (empty
    /// when KV reuse is disabled) — pinned 1:1 against the live
    /// server's `server_prefix_hits:*` counters by the conformance
    /// suite.
    pub prefix_hits_by_group: BTreeMap<String, u64>,
    pub prefix_misses_by_group: BTreeMap<String, u64>,
    /// High-watermark of concurrently in-flight requests — together
    /// with `event_queue_peak`, the constant-memory evidence for
    /// streaming runs (both are bounded by concurrency, not by the
    /// number of requests ingested).
    pub inflight_peak: usize,
    /// High-watermark of pending events in the scheduler.
    pub event_queue_peak: usize,
}

/// The agent-DAG simulator. Construct with [`DagSim::new`] from a
/// validated plan; [`DagSim::run`] executes a request trace against a
/// static fleet, [`DagSim::run_controlled`] against a closed-loop
/// controller that may re-plan the fleet mid-run.
pub struct DagSim {
    pub eff: Efficiency,
    pub opex: OpexModel,
    pub terms: FinanceTerms,
    plan: ExecutionPlan,
    /// None only when the plan has no LLM stages.
    model: Option<ModelProfile>,
    /// Shared contended edge-transfer model (same clock the live
    /// dispatcher drives — see `transport::fabric::TransferClock`).
    clock: TransferClock,
    /// End-to-end SLA threshold, if the plan carries one.
    sla_s: Option<f64>,
    /// Successor lists per node index.
    succ: Vec<Vec<usize>>,
    /// Static indegree per node index.
    indeg: Vec<u32>,
    /// Expanded pipeline specs of the *initial* fleet.
    prefill_specs: Vec<PipelineSpec>,
    decode_specs: Vec<PipelineSpec>,
    /// Calendar-queue event scheduler — pop order is identical to the
    /// old `BinaryHeap<Reverse<Event>>` (time, then push sequence), at
    /// O(1) amortized per event for the clustered times a simulation
    /// produces.
    queue: EventQueue<Ev>,
    /// Populated by the last completed run (see [`DagSim::last_detail`]).
    detail: Option<DagDetail>,
    /// Cross-step prefix-KV reuse budgets; None (the default) disables
    /// reuse entirely — see [`DagSim::set_kv_reuse`].
    reuse_cfg: Option<KvReuseConfig>,
    /// When attached, every executed stage, cross-chassis transfer, and
    /// request envelope is emitted as a [`Span`] (see `obs::trace`) —
    /// the same schema the live server records.
    trace_sink: Option<Arc<TraceSink>>,
}

/// Shape identity of a pipeline (fleet changes match by shape). Must
/// stay in lock-step with the per-role shape key in `plan/diff.rs` and
/// `orchestrator::diff_apply::shape_map_of` — all three encode the same
/// "which pipelines are the same rebuildable unit" rule.
type ShapeKey = (String, u32, u32, u64);

fn shape_of(spec: &PipelineSpec) -> ShapeKey {
    (
        spec.device.name.to_string(),
        spec.par.tp,
        spec.par.pp,
        spec.max_batch,
    )
}

/// The canonical group key of a pipe — formatted by the same
/// [`crate::plan::shape_key_of`] as [`crate::plan::PipelineBinding::shape_key`],
/// so per-group stats and counters line up byte-for-byte across the
/// planner, both backends, and the conformance suite.
fn group_key(role: Role, spec: &PipelineSpec) -> String {
    crate::plan::shape_key_of(
        role,
        spec.device.name,
        spec.par.tp,
        spec.par.pp,
        spec.max_batch,
    )
}

impl DagSim {
    pub fn new(plan: &ExecutionPlan) -> Result<DagSim> {
        plan.validate()?;
        // Static pre-flight: an Error-severity diagnostic (infeasible
        // HBM footprint, impossible KV hop, broken token split, ...)
        // rejects the plan here with the full table attached instead of
        // surfacing mid-run as `Error::Capacity` or a wrong answer.
        crate::plan::verify::ensure_loadable(plan)?;
        let has_llm = plan.bindings.iter().any(|b| b.stage != Stage::Cpu);
        let model = by_short_name(&plan.model);
        if has_llm && model.is_none() {
            return Err(Error::Config(format!(
                "plan model `{}` not in the profile catalog",
                plan.model
            )));
        }
        let placement = plan.placement()?;
        let clock = TransferClock::new(plan.build_fabric()?);
        let sla_s = match plan.sla {
            SlaSpec::None => None,
            SlaSpec::EndToEnd(t) => Some(t),
            SlaSpec::Soft { t_sla_s, .. } => Some(t_sla_s),
        };

        let topo = DagTopology::of(plan);

        Ok(DagSim {
            eff: Efficiency::default(),
            opex: OpexModel::Derived,
            terms: FinanceTerms::default(),
            plan: plan.clone(),
            model,
            clock,
            sla_s,
            succ: topo.succ,
            indeg: topo.indeg,
            prefill_specs: placement.prefill,
            decode_specs: placement.decode,
            queue: EventQueue::new(),
            detail: None,
            reuse_cfg: None,
            trace_sink: None,
        })
    }

    /// Enable cross-step prefix-KV reuse for subsequent runs: prefill
    /// admission hashes each LLM job's gating parents, consults a
    /// per-group prefix cache under `cfg`'s budgets, and charges only
    /// the uncached suffix. Off by default — runs without it are
    /// bit-identical to the pre-reuse simulator.
    pub fn set_kv_reuse(&mut self, cfg: KvReuseConfig) {
        self.reuse_cfg = Some(cfg);
    }

    /// Context identity of an LLM job: its request plus its gating
    /// parents, mixed the way the live dispatcher hashes concatenated
    /// input payloads. Two jobs share a hash exactly when the live
    /// backend would hand their units byte-identical context (same
    /// request, same dependency list) — the sim/live parity contract
    /// the conformance suite pins. Keyed by the *external* request id
    /// ([`Request::id`]), which is stable across slot recycling.
    fn prefix_hash_of(&self, ext_id: u64, node: usize) -> u64 {
        let mut h = mix64(ext_id ^ 0xA5A5_5A5A_DEAD_BEEF);
        for &d in &self.plan.bindings[node].deps {
            h = mix64(h ^ (d as u64).wrapping_add(0x517C_C1B7_2722_0A95));
        }
        h
    }

    /// Per-stage detail of the last completed run (None before any).
    pub fn last_detail(&self) -> Option<&DagDetail> {
        self.detail.as_ref()
    }

    /// Attach a span recorder: subsequent runs emit every executed
    /// stage, KV transfer, and request envelope into it.
    pub fn set_trace_sink(&mut self, sink: Arc<TraceSink>) {
        self.trace_sink = Some(sink);
    }

    #[inline]
    fn emit(&self, span: Span) {
        if let Some(s) = &self.trace_sink {
            s.record(span);
        }
    }

    fn push(&mut self, t: f64, ev: Ev) {
        self.queue.push(t, ev);
    }

    /// A request length scaled by `node`'s *currently bound* token
    /// fraction (≥ 1 token) — evaluated once per request at arrival.
    fn scaled_len(&self, len: u64, node: usize) -> u64 {
        let tf = self.plan.bindings[node].token_fraction;
        ((len as f64 * tf).round() as u64).max(1)
    }

    /// Start a prefill batch on pipe `pi` if idle with work queued.
    fn try_start_prefill(&mut self, st: &mut RunState, pi: usize, now: f64) {
        let model = self.model.as_ref().expect("LLM job without model");
        let mut batch = st.batch_pool.pop().unwrap_or_default();
        batch.clear();
        {
            let p = &mut st.prefill[pi];
            if p.retired || p.busy || p.queue.is_empty() {
                st.batch_pool.push(batch);
                return;
            }
            let take = (p.spec.max_batch as usize).min(p.queue.len());
            batch.extend(p.queue.drain(..take));
        }
        for j in &batch {
            st.slots[j.req].nodes[j.node].start_s = now;
        }
        // Batch prefill time at the longest (token-fraction-scaled)
        // prompt in the batch. With reuse on, each job consults the
        // pipe group's prefix cache and is charged only its uncached
        // suffix, so the batch is timed at the longest *uncached*
        // prompt plus any tier-restore stall.
        let mut isl = 1u64;
        let mut restore = 0.0f64;
        for idx in 0..batch.len() {
            let j = batch[idx];
            let tokens = st.slots[j.req].nodes[j.node].isl;
            let (uncached, stall) = if st.reuse.is_some() {
                let hash = self.prefix_hash_of(st.slots[j.req].ext_id, j.node);
                let rz = st.reuse.as_mut().expect("checked is_some above");
                let (u, s, _hit) = rz.consult(&st.prefill[pi].gkey, hash, tokens);
                (u, s)
            } else {
                (tokens, 0.0)
            };
            st.prefill_tokens += uncached;
            isl = isl.max(uncached);
            restore = restore.max(stall);
        }
        let p = &mut st.prefill[pi];
        let t_pre = prefill_time(
            model,
            &p.spec.device,
            p.spec.par,
            isl,
            batch.len() as u64,
            &self.eff,
        )
        .total()
            + restore;
        let id = p.next_batch;
        p.next_batch += 1;
        p.busy = true;
        p.busy_time += t_pre;
        p.in_flight.insert(id, batch);
        self.push(now + t_pre, Ev::PrefillDone { pipe: pi, batch: id });
    }

    /// Schedule a decode round on pipe `di` if needed.
    fn maybe_schedule_round(&mut self, st: &mut RunState, di: usize, now: f64) {
        let model = self.model.as_ref().expect("LLM job without model");
        let admitted: Vec<Job> = {
            let d = &mut st.decode[di];
            if d.round_scheduled {
                return;
            }
            let mut admitted = Vec::new();
            while d.active.len() < d.spec.max_batch as usize {
                match d.waiting.pop_front() {
                    Some(j) => {
                        d.active.push(j);
                        admitted.push(j);
                    }
                    None => break,
                }
            }
            if d.active.is_empty() {
                return;
            }
            admitted
        };
        // First admission starts the decode span; a KV-migrated session
        // re-admitted elsewhere keeps its original start (its span
        // covers the migration gap).
        for j in admitted {
            let ns = &mut st.slots[j.req].nodes[j.node];
            if ns.start_s.is_nan() {
                ns.start_s = now;
            }
        }
        let ctx: u64 = st.decode[di]
            .active
            .iter()
            .map(|j| {
                let ns = &st.slots[j.req].nodes[j.node];
                ns.isl + ns.tokens_done
            })
            .sum::<u64>()
            / st.decode[di].active.len() as u64;
        let d = &mut st.decode[di];
        let step = decode_step_time(
            model,
            &d.spec.device,
            d.spec.par,
            ctx.max(1),
            d.active.len() as u64,
            &self.eff,
        )
        .total();
        d.round_scheduled = true;
        d.busy_time += step;
        self.push(now + step, Ev::DecodeRound(di));
    }

    /// Least-loaded live pipe serving `class` — prefix-affinity first
    /// when reuse is on (a pipe whose group already holds the job's
    /// context wins), mirroring the live router's PrefixHit →
    /// LeastLoaded order. A drained class (last live pipe retired
    /// mid-run) surfaces as a typed `Capacity` error, never a panic.
    fn pick_prefill(&self, st: &RunState, node: usize, prefix: Option<u64>) -> Result<usize> {
        let class = self.plan.bindings[node].class.as_str();
        let cands = st
            .prefill_pipes_of
            .get(class)
            .filter(|v| !v.is_empty())
            .ok_or_else(|| {
                Error::Capacity(format!("no live prefill pipelines for class {class}"))
            })?;
        if let (Some(h), Some(rz)) = (prefix, st.reuse.as_ref()) {
            let hit = cands
                .iter()
                .filter(|&&k| rz.holds(&st.prefill[k].gkey, h))
                .min_by_key(|&&k| st.prefill[k].queue.len() + st.prefill[k].busy as usize);
            if let Some(&k) = hit {
                return Ok(k);
            }
        }
        Ok(*cands
            .iter()
            .min_by_key(|&&k| st.prefill[k].queue.len() + st.prefill[k].busy as usize)
            .expect("candidate set is non-empty"))
    }

    fn pick_decode(&self, st: &RunState, node: usize) -> Result<usize> {
        let class = self.plan.bindings[node].class.as_str();
        let cands = st
            .decode_pipes_of
            .get(class)
            .filter(|v| !v.is_empty())
            .ok_or_else(|| {
                Error::Capacity(format!("no live decode pipelines for class {class}"))
            })?;
        Ok(*cands
            .iter()
            .min_by_key(|&&k| st.decode[k].active.len() + st.decode[k].waiting.len())
            .expect("candidate set is non-empty"))
    }

    /// All dependencies of `job` satisfied: dispatch it to its stage.
    fn dispatch(&mut self, st: &mut RunState, job: Job, now: f64) -> Result<()> {
        st.slots[job.req].nodes[job.node].ready_s = now;
        match self.plan.bindings[job.node].stage {
            Stage::Cpu => {
                st.host_jobs += 1;
                let service = self.plan.bindings[job.node].latency_s;
                if st.cpu_busy < st.cpu_workers {
                    st.cpu_busy += 1;
                    st.cpu_busy_time += service;
                    st.slots[job.req].nodes[job.node].start_s = now;
                    self.push(now + service, Ev::CpuDone(job));
                } else {
                    st.cpu_queue.push_back((job, service));
                }
            }
            Stage::LlmPrefill => {
                st.prefill_jobs += 1;
                let pi = match st.slots[job.req].nodes[job.node].pipe {
                    Some((Role::Prefill, k)) if !st.prefill[k].retired => k,
                    _ => {
                        let ph = st
                            .reuse
                            .is_some()
                            .then(|| self.prefix_hash_of(st.slots[job.req].ext_id, job.node));
                        self.pick_prefill(st, job.node, ph)?
                    }
                };
                bump_group(&mut st.jobs_by_group, &st.prefill[pi].gkey);
                st.slots[job.req].nodes[job.node].pipe = Some((Role::Prefill, pi));
                st.prefill[pi].queue.push_back(job);
                self.try_start_prefill(st, pi, now);
            }
            Stage::LlmDecode => {
                st.decode_jobs += 1;
                let di = match st.slots[job.req].nodes[job.node].pipe {
                    Some((Role::Decode, k)) if !st.decode[k].retired => k,
                    _ => self.pick_decode(st, job.node)?,
                };
                bump_group(&mut st.jobs_by_group, &st.decode[di].gkey);
                st.slots[job.req].nodes[job.node].pipe = Some((Role::Decode, di));
                st.decode[di].waiting.push_back(job);
                self.maybe_schedule_round(st, di, now);
            }
        }
        Ok(())
    }

    /// Chassis a completed job ran on, if pipeline-bound.
    fn chassis_of(&self, st: &RunState, job: Job) -> Option<u32> {
        match st.slots[job.req].nodes[job.node].pipe {
            Some((Role::Prefill, k)) => Some(st.prefill[k].spec.chassis),
            Some((Role::Decode, k)) => Some(st.decode[k].spec.chassis),
            None => None,
        }
    }

    /// Node complete: propagate to successors (with fabric transfers for
    /// cross-chassis pipeline edges) and account request completion.
    /// Frees the request's slot once its last node completes (after
    /// propagation — successors of the final node are impossible, but
    /// chassis/span attribution still reads the slot).
    fn complete_node(&mut self, st: &mut RunState, job: Job, now: f64) -> Result<()> {
        let ns = st.slots[job.req].nodes[job.node];
        st.node_lat_sum[job.node] += now - ns.ready_s;
        st.node_lat_n[job.node] += 1;
        if self.trace_sink.is_some() {
            let binding = &self.plan.bindings[job.node];
            let start = if ns.start_s.is_nan() {
                ns.ready_s
            } else {
                ns.start_s
            };
            let (kind, group, chassis) = match binding.stage {
                Stage::Cpu => (classify_host_op(&binding.op), "host".to_string(), 0),
                Stage::LlmPrefill => {
                    let k = match ns.pipe {
                        Some((Role::Prefill, k)) => k,
                        _ => unreachable!("prefill job completed without a pipe"),
                    };
                    (
                        SpanKind::Prefill,
                        st.prefill[k].gkey.clone(),
                        st.prefill[k].spec.chassis,
                    )
                }
                Stage::LlmDecode => {
                    let k = match ns.pipe {
                        Some((Role::Decode, k)) => k,
                        _ => unreachable!("decode job completed without a pipe"),
                    };
                    (
                        SpanKind::Decode,
                        st.decode[k].gkey.clone(),
                        st.decode[k].spec.chassis,
                    )
                }
            };
            self.emit(Span {
                request: st.slots[job.req].ext_id,
                node: job.node as i64,
                kind,
                group,
                chassis,
                t_start: start,
                t_end: now,
                parent: ns.dep_from,
                queue_wait: (start - ns.ready_s).max(0.0),
            });
        }
        st.slots[job.req].nodes_left -= 1;
        let finished = st.slots[job.req].nodes_left == 0;
        if finished {
            st.completed += 1;
            st.win_completed += 1;
            let arrive = st.slots[job.req].arrive_s;
            let e2e = now - arrive;
            if self.sla_s.map_or(true, |s| e2e <= s) {
                st.win_sla_ok += 1;
            }
            let first = st.slots[job.req].first_token_s;
            let ttft = if first.is_nan() { e2e } else { first - arrive };
            st.ttft.push(ttft);
            st.e2e.push(e2e);
            // Request envelope: submit → final completion. The sim has
            // no admission gate, so the envelope's queue_wait is 0.
            self.emit(Span {
                request: st.slots[job.req].ext_id,
                node: -1,
                kind: SpanKind::Request,
                group: String::new(),
                chassis: 0,
                t_start: arrive,
                t_end: now,
                parent: -1,
                queue_wait: 0.0,
            });
        }
        let from_chassis = self.chassis_of(st, job);
        let from_stage = self.plan.bindings[job.node].stage;
        // Temporarily take the successor list so propagation can borrow
        // `self` mutably (fabric clock, event pushes) without cloning
        // the list on every completion.
        let successors = std::mem::take(&mut self.succ[job.node]);
        let mut result: Result<()> = Ok(());
        for &s in &successors {
            if let Err(e) = self.propagate_edge(st, job, s, from_chassis, from_stage, now) {
                result = Err(e);
                break;
            }
        }
        self.succ[job.node] = successors;
        if finished {
            st.free_slots.push(job.req);
        }
        result
    }

    /// Propagate one completed-node edge `job.node → s`: route the
    /// successor (deciding its pipe now so the hop is addressable),
    /// charge any cross-chassis fabric transfer, and schedule its
    /// `DepArrived`.
    fn propagate_edge(
        &mut self,
        st: &mut RunState,
        job: Job,
        s: usize,
        from_chassis: Option<u32>,
        from_stage: Stage,
        now: f64,
    ) -> Result<()> {
        let succ_job = Job {
            req: job.req,
            node: s,
        };
        let succ_stage = self.plan.bindings[s].stage;
        let mut arrive = now;
        // Fabric transfer only for pipeline → pipeline edges; CPU
        // stages have no chassis (host-side ingest is part of their
        // profiled latency).
        if succ_stage != Stage::Cpu && from_chassis.is_some() {
            // Destination pipe decided now so the hop is addressable.
            let (to_chassis, choice) = match succ_stage {
                Stage::LlmPrefill => {
                    let k = match st.slots[job.req].nodes[s].pipe {
                        Some((Role::Prefill, k)) if !st.prefill[k].retired => k,
                        _ => {
                            let ph = st
                                .reuse
                                .is_some()
                                .then(|| self.prefix_hash_of(st.slots[job.req].ext_id, s));
                            self.pick_prefill(st, s, ph)?
                        }
                    };
                    (st.prefill[k].spec.chassis, (Role::Prefill, k))
                }
                Stage::LlmDecode => {
                    let k = match st.slots[job.req].nodes[s].pipe {
                        Some((Role::Decode, k)) if !st.decode[k].retired => k,
                        _ => self.pick_decode(st, s)?,
                    };
                    (st.decode[k].spec.chassis, (Role::Decode, k))
                }
                Stage::Cpu => unreachable!(),
            };
            st.slots[job.req].nodes[s].pipe = Some(choice);
            let from_ch = from_chassis.unwrap();
            if from_ch != to_chassis {
                let bytes = edge_payload_bytes(
                    self.model.as_ref(),
                    from_stage,
                    &self.plan.bindings[s],
                    st.slots[job.req].nodes[s].isl,
                );
                st.kv_bytes_moved += bytes;
                arrive = self.clock.transfer(from_ch, to_chassis, bytes, now)?;
                if self.trace_sink.is_some() {
                    let group = match choice {
                        (Role::Prefill, k) => st.prefill[k].gkey.clone(),
                        (Role::Decode, k) => st.decode[k].gkey.clone(),
                    };
                    self.emit(Span {
                        request: st.slots[job.req].ext_id,
                        node: s as i64,
                        kind: SpanKind::KvTransfer,
                        group,
                        chassis: to_chassis,
                        t_start: now,
                        t_end: arrive,
                        parent: job.node as i64,
                        queue_wait: 0.0,
                    });
                }
            }
        }
        self.push(
            arrive,
            Ev::DepArrived {
                job: succ_job,
                from: job.node,
            },
        );
        Ok(())
    }

    /// KV bytes currently resident on decode pipelines (active and
    /// waiting sessions at their decoded-so-far context).
    fn kv_resident(&self, st: &RunState) -> f64 {
        let Some(m) = &self.model else { return 0.0 };
        let mut total = 0.0;
        for d in &st.decode {
            for j in d.active.iter().chain(d.waiting.iter()) {
                let ns = &st.slots[j.req].nodes[j.node];
                total += kv_cache_bytes(m, ns.isl + ns.tokens_done, 1);
            }
        }
        total
    }

    fn window_stats(&self, st: &mut RunState, t0: f64, t1: f64) -> WindowStats {
        let pre_busy: f64 = st
            .prefill
            .iter()
            .map(|p| p.busy_time * p.spec.par.devices() as f64)
            .sum();
        let dec_busy: f64 = st
            .decode
            .iter()
            .map(|d| d.busy_time * d.spec.par.devices() as f64)
            .sum();
        // Denominators count live pipes plus retired pipes still
        // draining (they accrue busy_time in the numerator, so leaving
        // them out would read post-scale-down pressure as ~1.0 and
        // oscillate the autoscaler: drain → spurious scale-up).
        let pre_dev: f64 = st
            .prefill
            .iter()
            .filter(|p| !p.retired || p.busy || !p.queue.is_empty())
            .map(|p| p.spec.par.devices() as f64)
            .sum();
        let dec_dev: f64 = st
            .decode
            .iter()
            .filter(|d| !d.retired || !d.active.is_empty() || !d.waiting.is_empty())
            .map(|d| d.spec.par.devices() as f64)
            .sum();
        let wlen = (t1 - t0).max(1e-9);
        let util = |busy: f64, prev: f64, dev: f64| {
            if dev > 0.0 {
                ((busy - prev) / (dev * wlen)).clamp(0.0, 1.0)
            } else {
                0.0
            }
        };

        // Per-group view: pipes bucketed by (role, shape), windowed on
        // each pipe's own prev_busy snapshot. Draining pipes keep
        // contributing devices (same anti-oscillation rule as above)
        // but only live ones count as replicas.
        #[derive(Default)]
        struct Acc {
            device: String,
            max_batch: u64,
            busy_delta: f64,
            devices: f64,
            replicas: u32,
            queue: usize,
        }
        let mut acc: BTreeMap<(Role, String), Acc> = BTreeMap::new();
        for p in &st.prefill {
            if p.retired && !p.busy && p.queue.is_empty() {
                continue;
            }
            let a = acc
                .entry((Role::Prefill, p.gkey.clone()))
                .or_default();
            a.device = p.spec.device.name.to_string();
            a.max_batch = p.spec.max_batch;
            a.busy_delta += (p.busy_time - p.prev_busy) * p.spec.par.devices() as f64;
            a.devices += p.spec.par.devices() as f64;
            a.replicas += u32::from(!p.retired);
            a.queue += p.queue.len();
        }
        for d in &st.decode {
            if d.retired && d.active.is_empty() && d.waiting.is_empty() {
                continue;
            }
            let a = acc
                .entry((Role::Decode, d.gkey.clone()))
                .or_default();
            a.device = d.spec.device.name.to_string();
            a.max_batch = d.spec.max_batch;
            a.busy_delta += (d.busy_time - d.prev_busy) * d.spec.par.devices() as f64;
            a.devices += d.spec.par.devices() as f64;
            a.replicas += u32::from(!d.retired);
            a.queue += d.waiting.len();
        }
        let mut groups: Vec<GroupWindow> = Vec::with_capacity(acc.len());
        for ((role, key), a) in acc {
            let (prefix_hits, prefix_misses) = match (role, st.reuse.as_mut()) {
                (Role::Prefill, Some(rz)) => rz.window_delta(&key),
                _ => (0, 0),
            };
            groups.push(GroupWindow {
                role,
                key,
                device: a.device,
                replicas: a.replicas,
                max_batch: a.max_batch,
                util: util(a.busy_delta, 0.0, a.devices),
                queue: a.queue,
                prefix_hits,
                prefix_misses,
            });
        }

        let stats = WindowStats {
            t0,
            t1,
            arrivals: st.win_arrivals,
            completed: st.win_completed,
            sla_attained: if st.win_completed == 0 {
                1.0
            } else {
                st.win_sla_ok as f64 / st.win_completed as f64
            },
            prefill_util: util(pre_busy, st.prev_pre_busy, pre_dev),
            decode_util: util(dec_busy, st.prev_dec_busy, dec_dev),
            host_util: util(st.cpu_busy_time, st.prev_cpu_busy, st.cpu_workers as f64),
            prefill_queue: st.prefill.iter().map(|p| p.queue.len()).sum(),
            decode_queue: st.decode.iter().map(|d| d.waiting.len()).sum(),
            decode_active: st.decode.iter().map(|d| d.active.len()).sum(),
            kv_resident_bytes: self.kv_resident(st),
            prefill_pipes: st.prefill.iter().filter(|p| !p.retired).count() as u32,
            decode_pipes: st.decode.iter().filter(|d| !d.retired).count() as u32,
            groups,
        };
        // Roll the window: aggregate and per-pipe snapshots.
        st.prev_pre_busy = pre_busy;
        st.prev_dec_busy = dec_busy;
        st.prev_cpu_busy = st.cpu_busy_time;
        for p in &mut st.prefill {
            p.prev_busy = p.busy_time;
        }
        for d in &mut st.decode {
            d.prev_busy = d.busy_time;
        }
        stats
    }

    /// Migrate the running fleet to `target`'s pipeline layout.
    ///
    /// Pipelines are matched by shape (device, TP×PP, batch limit):
    /// surviving pipelines are untouched, surplus ones retire (queued
    /// prefills re-route, waiting decode sessions move their KV over
    /// the fabric, active sessions drain in place), missing ones
    /// activate on their target chassis. The target must keep the
    /// plan's model and cover every LLM binding's (role, class).
    fn apply_fleet(
        &mut self,
        st: &mut RunState,
        target: &ExecutionPlan,
        now: f64,
    ) -> Result<FleetChangeStats> {
        target.validate()?;
        if target.model != self.plan.model {
            return Err(Error::Config(format!(
                "fleet change cannot swap model `{}` -> `{}` mid-run",
                self.plan.model, target.model
            )));
        }
        // Adopt binding-level retunes (token-fraction shifts between
        // sibling classes, refreshed latency estimates) when the DAG
        // *structure* is unchanged: requests arriving after this point
        // snapshot the new fractions; in-flight work keeps the lengths
        // it was admitted under (see `NodeSt::isl`). A structural
        // change (ops, classes, deps) is not adoptable mid-run — the
        // orchestrator rejects those re-plans with a typed reason.
        let same_structure = target.bindings.len() == self.plan.bindings.len()
            && target
                .bindings
                .iter()
                .zip(&self.plan.bindings)
                .all(|(a, b)| {
                    a.op == b.op && a.class == b.class && a.stage == b.stage && a.deps == b.deps
                });
        if same_structure {
            self.plan.bindings = target.bindings.clone();
        }
        let placement = target.placement()?;
        let max_chassis = placement
            .prefill
            .iter()
            .chain(placement.decode.iter())
            .map(|s| s.chassis + 1)
            .max()
            .unwrap_or(1);
        self.clock.grow(max_chassis);

        let mut fc = FleetChangeStats {
            t: now,
            done_s: now,
            ..Default::default()
        };

        // ---- prefill fleet -----------------------------------------
        let mut prefill_requeue: Vec<Job> = Vec::new();
        {
            let mut want: BTreeMap<ShapeKey, Vec<PipelineSpec>> = BTreeMap::new();
            for s in placement.prefill {
                want.entry(shape_of(&s)).or_default().push(s);
            }
            let mut have: BTreeMap<ShapeKey, Vec<usize>> = BTreeMap::new();
            for (k, p) in st.prefill.iter().enumerate() {
                if !p.retired {
                    have.entry(shape_of(&p.spec)).or_default().push(k);
                }
            }
            for (key, specs) in &want {
                let live = have.get(key).map_or(0, |v| v.len());
                for s in specs.iter().skip(live) {
                    st.prefill.push(PrefillPipe::new(s.clone(), now));
                    fc.activated += 1;
                }
            }
            for (key, idxs) in &have {
                let keep = want.get(key).map_or(0, |v| v.len());
                if idxs.len() > keep {
                    // Retire the idle-most pipelines first.
                    let mut by_load = idxs.clone();
                    by_load.sort_by_key(|&k| {
                        st.prefill[k].queue.len() + st.prefill[k].busy as usize
                    });
                    for &k in by_load.iter().take(idxs.len() - keep) {
                        let p = &mut st.prefill[k];
                        p.retired = true;
                        p.retired_s = Some(now);
                        prefill_requeue.extend(p.queue.drain(..));
                        fc.retired += 1;
                    }
                }
            }
        }

        // ---- decode fleet ------------------------------------------
        let mut kv_moves: Vec<(Job, u32)> = Vec::new();
        {
            let mut want: BTreeMap<ShapeKey, Vec<PipelineSpec>> = BTreeMap::new();
            for s in placement.decode {
                want.entry(shape_of(&s)).or_default().push(s);
            }
            let mut have: BTreeMap<ShapeKey, Vec<usize>> = BTreeMap::new();
            for (k, d) in st.decode.iter().enumerate() {
                if !d.retired {
                    have.entry(shape_of(&d.spec)).or_default().push(k);
                }
            }
            for (key, specs) in &want {
                let live = have.get(key).map_or(0, |v| v.len());
                for s in specs.iter().skip(live) {
                    st.decode.push(DecodePipe::new(s.clone(), now));
                    fc.activated += 1;
                }
            }
            for (key, idxs) in &have {
                let keep = want.get(key).map_or(0, |v| v.len());
                if idxs.len() > keep {
                    let mut by_load = idxs.clone();
                    by_load.sort_by_key(|&k| {
                        st.decode[k].active.len() + st.decode[k].waiting.len()
                    });
                    for &k in by_load.iter().take(idxs.len() - keep) {
                        let d = &mut st.decode[k];
                        d.retired = true;
                        d.retired_s = Some(now);
                        let from_ch = d.spec.chassis;
                        kv_moves.extend(d.waiting.drain(..).map(|j| (j, from_ch)));
                        fc.retired += 1;
                    }
                }
            }
        }

        st.rebuild_routing_maps();

        // ---- every in-flight class must still be servable ----------
        for b in &self.plan.bindings {
            let ok = match b.stage {
                Stage::Cpu => true,
                Stage::LlmPrefill => st
                    .prefill_pipes_of
                    .get(&b.class)
                    .is_some_and(|v| !v.is_empty()),
                Stage::LlmDecode => st
                    .decode_pipes_of
                    .get(&b.class)
                    .is_some_and(|v| !v.is_empty()),
            };
            if !ok {
                return Err(Error::Capacity(format!(
                    "fleet change strands {} (no live {} pipelines for {})",
                    b.op,
                    b.class,
                    b.stage.name()
                )));
            }
        }

        // ---- re-route displaced work -------------------------------
        for job in prefill_requeue {
            let ph = st
                .reuse
                .is_some()
                .then(|| self.prefix_hash_of(st.slots[job.req].ext_id, job.node));
            let pi = self.pick_prefill(st, job.node, ph)?;
            st.slots[job.req].nodes[job.node].pipe = Some((Role::Prefill, pi));
            st.prefill[pi].queue.push_back(job);
            self.try_start_prefill(st, pi, now);
        }
        for (job, from_ch) in kv_moves {
            let di = self.pick_decode(st, job.node)?;
            let to_ch = st.decode[di].spec.chassis;
            let bytes = match &self.model {
                Some(m) => {
                    let ns = &st.slots[job.req].nodes[job.node];
                    kv_cache_bytes(m, ns.isl + ns.tokens_done, 1)
                }
                None => 0.0,
            };
            let arrive = if bytes > 0.0 && from_ch != to_ch {
                let arrive = self.clock.transfer(from_ch, to_ch, bytes, now)?;
                // Mid-decode KV migration: keyed to the job's own node
                // as both span node and parent (it is not a dependency
                // edge — the decode span it interrupts covers the gap).
                if self.trace_sink.is_some() {
                    self.emit(Span {
                        request: st.slots[job.req].ext_id,
                        node: job.node as i64,
                        kind: SpanKind::KvTransfer,
                        group: st.decode[di].gkey.clone(),
                        chassis: to_ch,
                        t_start: now,
                        t_end: arrive,
                        parent: job.node as i64,
                        queue_wait: 0.0,
                    });
                }
                arrive
            } else {
                now
            };
            st.kv_bytes_moved += bytes;
            fc.kv_moves += 1;
            fc.kv_bytes += bytes;
            fc.done_s = fc.done_s.max(arrive);
            self.push(arrive, Ev::KvMigrated { job, to: di });
        }

        // ---- CPU worker pool (the cpu_workers autoscaler's knob) ----
        // Grows take effect immediately (queued tool/IO stages start on
        // the fresh slots); shrinks let over-width stages finish — the
        // same graceful semantics as the live host pool's resize.
        if target.cpu_workers != st.cpu_workers {
            st.cpu_workers = target.cpu_workers;
            self.plan.cpu_workers = target.cpu_workers;
            while st.cpu_busy < st.cpu_workers {
                match st.cpu_queue.pop_front() {
                    Some((job, service)) => {
                        st.cpu_busy += 1;
                        st.cpu_busy_time += service;
                        st.slots[job.req].nodes[job.node].start_s = now;
                        self.push(now + service, Ev::CpuDone(job));
                    }
                    None => break,
                }
            }
        }
        Ok(fc)
    }

    /// Execute the trace to completion against a static fleet.
    ///
    /// Thin wrapper over the streaming engine: the slice is replayed
    /// through [`DagSim::run_stream`] via [`Replay`], producing a
    /// byte-identical [`SimReport`] (pinned by the replay-equivalence
    /// suite in `rust/tests/arrivals.rs`).
    pub fn run(&mut self, trace: &[Request]) -> Result<SimReport> {
        self.run_controlled(trace, f64::INFINITY, &mut NoopFleetController)
    }

    /// Execute the trace with a closed-loop [`FleetController`] invoked
    /// every `window_s` seconds (pass a non-finite window to disable
    /// the ticks). Aggregates the same serving metrics as [`DagSim::run`].
    pub fn run_controlled(
        &mut self,
        trace: &[Request],
        window_s: f64,
        ctl: &mut dyn FleetController,
    ) -> Result<SimReport> {
        if self.plan.bindings.is_empty() {
            return Err(Error::Runtime("plan has no bindings to execute".into()));
        }
        if trace.is_empty() {
            return Err(Error::Runtime("empty request trace".into()));
        }
        // Reject non-finite event times at admission: the queue's
        // ordering is total either way (`f64::total_cmp`), but a NaN
        // arrival would sort *after* every finite event and silently
        // warp the schedule instead of failing loudly.
        for (i, r) in trace.iter().enumerate() {
            if !r.arrive_s.is_finite() {
                return Err(Error::Config(format!(
                    "request {i} has non-finite arrival time {}",
                    r.arrive_s
                )));
            }
        }
        let mut replay = Replay::ordered(trace);
        self.run_stream_controlled(&mut replay, window_s, ctl)
    }

    /// Execute a streaming arrival process to completion against a
    /// static fleet. Arrivals are pulled *lazily* — at most one future
    /// arrival is buffered in the event queue — so memory is bounded by
    /// the in-flight set, not the number of requests: a million-request
    /// diurnal day runs in constant memory (see
    /// `DagDetail::inflight_peak` / `event_queue_peak`).
    pub fn run_stream(&mut self, arrivals: &mut dyn ArrivalProcess) -> Result<SimReport> {
        self.run_stream_controlled(arrivals, f64::INFINITY, &mut NoopFleetController)
    }

    /// Pull the next request from the arrival process into a (possibly
    /// recycled) slot and schedule its Arrival event. Returns false
    /// when the stream is exhausted.
    fn pull_arrival(
        &mut self,
        st: &mut RunState,
        arrivals: &mut dyn ArrivalProcess,
    ) -> Result<bool> {
        let Some(r) = arrivals.next() else {
            return Ok(false);
        };
        if !r.arrive_s.is_finite() {
            return Err(Error::Config(format!(
                "request {} has non-finite arrival time {}",
                st.arrived, r.arrive_s
            )));
        }
        // Streams must be time-ordered: the engine has already drained
        // every event earlier than the previous arrival, so a
        // back-in-time request could not be scheduled faithfully.
        if r.arrive_s < st.last_arrival_s {
            return Err(Error::Config(format!(
                "arrival process is not time-ordered: request {} at {} after {}",
                st.arrived, r.arrive_s, st.last_arrival_s
            )));
        }
        st.last_arrival_s = r.arrive_s;
        st.arrived += 1;
        let n_nodes = self.plan.bindings.len();
        let slot = match st.free_slots.pop() {
            Some(i) => i,
            None => {
                st.slots.push(ReqSlot::empty());
                st.slots.len() - 1
            }
        };
        {
            let s = &mut st.slots[slot];
            s.ext_id = r.id;
            s.arrive_s = r.arrive_s;
            s.isl_raw = r.isl;
            s.osl_raw = r.osl;
            s.nodes_left = n_nodes;
            s.first_token_s = f64::NAN;
            s.nodes.clear();
            for node in 0..n_nodes {
                let mut ns = NodeSt::fresh();
                ns.remaining = self.indeg[node];
                s.nodes.push(ns);
            }
        }
        let inflight = st.slots.len() - st.free_slots.len();
        if inflight > st.inflight_peak {
            st.inflight_peak = inflight;
        }
        self.push(r.arrive_s, Ev::Arrival(slot));
        Ok(true)
    }

    /// Execute a streaming arrival process with a closed-loop
    /// [`FleetController`] — the engine every other entry point wraps.
    pub fn run_stream_controlled(
        &mut self,
        arrivals: &mut dyn ArrivalProcess,
        window_s: f64,
        ctl: &mut dyn FleetController,
    ) -> Result<SimReport> {
        let n_nodes = self.plan.bindings.len();
        if n_nodes == 0 {
            return Err(Error::Runtime("plan has no bindings to execute".into()));
        }
        self.clock.reset();
        self.queue.clear();

        let mut st = RunState {
            prefill: self
                .prefill_specs
                .clone()
                .into_iter()
                .map(|spec| PrefillPipe::new(spec, 0.0))
                .collect(),
            decode: self
                .decode_specs
                .clone()
                .into_iter()
                .map(|spec| DecodePipe::new(spec, 0.0))
                .collect(),
            prefill_pipes_of: BTreeMap::new(),
            decode_pipes_of: BTreeMap::new(),
            cpu_workers: self.plan.cpu_workers,
            cpu_busy: 0,
            cpu_queue: VecDeque::new(),
            cpu_busy_time: 0.0,
            slots: Vec::new(),
            free_slots: Vec::new(),
            arrived: 0,
            last_arrival_s: f64::NEG_INFINITY,
            inflight_peak: 0,
            node_lat_sum: vec![0.0; n_nodes],
            node_lat_n: vec![0; n_nodes],
            host_jobs: 0,
            prefill_jobs: 0,
            decode_jobs: 0,
            jobs_by_group: BTreeMap::new(),
            prev_pre_busy: 0.0,
            prev_dec_busy: 0.0,
            prev_cpu_busy: 0.0,
            ttft: QuantileSketch::new(),
            e2e: QuantileSketch::new(),
            tbt: QuantileSketch::new(),
            round_scratch: Vec::new(),
            batch_pool: Vec::new(),
            completed: 0,
            kv_bytes_moved: 0.0,
            output_tokens: 0,
            reuse: self.reuse_cfg.as_ref().and_then(|cfg| {
                self.model.as_ref().map(|m| {
                    // One cache node per initial prefill group, with
                    // headroom for groups that fleet changes introduce
                    // mid-run (overflow groups bypass the cache).
                    let mut keys: Vec<String> = self
                        .prefill_specs
                        .iter()
                        .map(|s| group_key(Role::Prefill, s))
                        .collect();
                    keys.sort();
                    keys.dedup();
                    KvReuse::new(cfg, keys.len() + 8, kv_cache_bytes(m, 1, 1))
                })
            }),
            prefill_tokens: 0,
            win_arrivals: 0,
            win_completed: 0,
            win_sla_ok: 0,
        };
        st.rebuild_routing_maps();

        if !self.pull_arrival(&mut st, arrivals)? {
            return Err(Error::Runtime("empty request trace".into()));
        }
        let ticking = window_s.is_finite() && window_s > 0.0;
        if ticking {
            self.push(window_s, Ev::WindowTick);
        }

        let mut win_t0 = 0.0f64;
        let mut events = 0u64;
        let mut makespan = 0.0f64;
        while let Some((t, ev)) = self.queue.pop() {
            events += 1;
            // The budget scales with ingested requests so unbounded
            // streams are not capped at a fixed total, while a stalled
            // plan (live-lock, zero progress) still trips it.
            if events > 100_000_000u64.max(st.arrived as u64 * 1024) {
                return Err(Error::Runtime("event budget exceeded".into()));
            }
            // Window ticks are observation points, not work: they must
            // not stretch the makespan past the last real event.
            if !matches!(ev, Ev::WindowTick) {
                makespan = makespan.max(t);
            }
            match ev {
                Ev::Arrival(slot) => {
                    st.win_arrivals += 1;
                    // Snapshot every node's token-fraction-scaled
                    // lengths now: a later retune redirects only
                    // requests that have not arrived yet.
                    let (isl_raw, osl_raw) = (st.slots[slot].isl_raw, st.slots[slot].osl_raw);
                    for node in 0..n_nodes {
                        let isl = self.scaled_len(isl_raw, node);
                        let osl = self.scaled_len(osl_raw, node);
                        let ns = &mut st.slots[slot].nodes[node];
                        ns.isl = isl;
                        ns.osl = osl;
                    }
                    for node in 0..n_nodes {
                        if self.indeg[node] == 0 {
                            self.dispatch(&mut st, Job { req: slot, node }, t)?;
                        }
                    }
                    // Lazy lookahead: refill the queue's single buffered
                    // arrival only once the previous one is admitted.
                    self.pull_arrival(&mut st, arrivals)?;
                }
                Ev::DepArrived { job, from } => {
                    // Deps arrive in time order, so the value standing
                    // when the count hits zero is the gating edge.
                    let ready = {
                        let ns = &mut st.slots[job.req].nodes[job.node];
                        ns.dep_from = from as i64;
                        ns.remaining -= 1;
                        ns.remaining == 0
                    };
                    if ready {
                        self.dispatch(&mut st, job, t)?;
                    }
                }
                Ev::CpuDone(job) => {
                    // Free the worker, then hand it (and any slots a
                    // mid-run grow added) to queued stages — unless a
                    // shrink left the pool over-width, in which case the
                    // worker retires instead.
                    st.cpu_busy = st.cpu_busy.saturating_sub(1);
                    while st.cpu_busy < st.cpu_workers {
                        match st.cpu_queue.pop_front() {
                            Some((next, service)) => {
                                st.cpu_busy += 1;
                                st.cpu_busy_time += service;
                                st.slots[next.req].nodes[next.node].start_s = t;
                                self.push(t + service, Ev::CpuDone(next));
                            }
                            None => break,
                        }
                    }
                    self.complete_node(&mut st, job, t)?;
                }
                Ev::PrefillDone { pipe, batch } => {
                    st.prefill[pipe].busy = false;
                    let mut members = st.prefill[pipe]
                        .in_flight
                        .remove(&batch)
                        .expect("prefill batch vanished");
                    for job in members.drain(..) {
                        self.complete_node(&mut st, job, t)?;
                    }
                    st.batch_pool.push(members);
                    if !st.prefill[pipe].retired {
                        self.try_start_prefill(&mut st, pipe, t);
                    }
                }
                Ev::DecodeRound(di) => {
                    st.decode[di].round_scheduled = false;
                    let mut active = std::mem::take(&mut st.decode[di].active);
                    let mut still = std::mem::take(&mut st.round_scratch);
                    still.clear();
                    for job in active.drain(..) {
                        if st.slots[job.req].nodes[job.node].tokens_done == 0 {
                            let slot = &mut st.slots[job.req];
                            if slot.first_token_s.is_nan() {
                                slot.first_token_s = t;
                            }
                        } else {
                            let gap = t - st.slots[job.req].nodes[job.node].last_token_s;
                            st.tbt.push(gap);
                        }
                        let (done, osl) = {
                            let ns = &mut st.slots[job.req].nodes[job.node];
                            ns.last_token_s = t;
                            ns.tokens_done += 1;
                            (ns.tokens_done, ns.osl)
                        };
                        st.output_tokens += 1;
                        if done >= osl {
                            self.complete_node(&mut st, job, t)?;
                        } else {
                            still.push(job);
                        }
                    }
                    st.round_scratch = active;
                    st.decode[di].active = still;
                    self.maybe_schedule_round(&mut st, di, t);
                }
                Ev::KvMigrated { job, to } => {
                    // Destination may itself have retired since the
                    // transfer was scheduled; land on a live pipe.
                    let di = if st.decode[to].retired {
                        self.pick_decode(&st, job.node)?
                    } else {
                        to
                    };
                    st.slots[job.req].nodes[job.node].pipe = Some((Role::Decode, di));
                    st.decode[di].waiting.push_back(job);
                    self.maybe_schedule_round(&mut st, di, t);
                }
                Ev::WindowTick => {
                    let stats = self.window_stats(&mut st, win_t0, t);
                    st.win_arrivals = 0;
                    st.win_completed = 0;
                    st.win_sla_ok = 0;
                    if let Some(next) = ctl.on_window(&stats) {
                        let fcs = self.apply_fleet(&mut st, &next, t)?;
                        ctl.on_applied(t, &fcs);
                    }
                    win_t0 = t;
                    if !self.queue.is_empty() {
                        self.push(t + window_s, Ev::WindowTick);
                    }
                }
            }
        }

        if st.completed != st.arrived {
            return Err(Error::Runtime(format!(
                "DAG simulation stalled: {}/{} requests completed",
                st.completed, st.arrived
            )));
        }

        self.detail = Some(DagDetail {
            host_jobs: st.host_jobs,
            prefill_jobs: st.prefill_jobs,
            decode_jobs: st.decode_jobs,
            jobs_by_group: st.jobs_by_group.clone(),
            prefill_tokens: st.prefill_tokens,
            prefix_hits_by_group: st
                .reuse
                .as_ref()
                .map(|r| r.hits.clone())
                .unwrap_or_default(),
            prefix_misses_by_group: st
                .reuse
                .as_ref()
                .map(|r| r.misses.clone())
                .unwrap_or_default(),
            node_mean_latency_s: (0..n_nodes)
                .map(|i| {
                    if st.node_lat_n[i] > 0 {
                        st.node_lat_sum[i] / st.node_lat_n[i] as f64
                    } else {
                        0.0
                    }
                })
                .collect(),
            inflight_peak: st.inflight_peak,
            event_queue_peak: self.queue.high_watermark(),
        });

        // Fleet cost and utilization integrate each pipeline over its
        // *lifespan* (activation → retirement), so time-varying fleets
        // are priced for what they actually deployed. CPU workers are
        // priced into the planner's per-request cost, as before.
        let mut total_usd = 0.0f64;
        let mut p_busy = 0.0f64;
        let mut p_devsec = 0.0f64;
        for p in &st.prefill {
            let dev = p.spec.par.devices() as f64;
            let end = p.retired_s.unwrap_or(makespan).min(makespan).max(p.created_s);
            let span = end - p.created_s;
            p_busy += p.busy_time * dev;
            p_devsec += dev * span;
            total_usd +=
                dev * opex_usd_per_hour(&p.spec.device, self.opex, &self.terms) * span / 3600.0;
        }
        let mut d_busy = 0.0f64;
        let mut d_devsec = 0.0f64;
        for d in &st.decode {
            let dev = d.spec.par.devices() as f64;
            let end = d.retired_s.unwrap_or(makespan).min(makespan).max(d.created_s);
            let span = end - d.created_s;
            d_busy += d.busy_time * dev;
            d_devsec += dev * span;
            total_usd +=
                dev * opex_usd_per_hour(&d.spec.device, self.opex, &self.terms) * span / 3600.0;
        }
        let tokens_per_s = if makespan > 0.0 {
            st.output_tokens as f64 / makespan
        } else {
            0.0
        };

        Ok(SimReport {
            n_requests: st.arrived,
            makespan_s: makespan,
            // Streaming percentiles: exact (bit-identical to the old
            // sort-and-rank over materialized vectors) below the sketch
            // cap, bounded-memory log-histogram beyond it. TTFT of a
            // request without decode stages is its time to completion.
            ttft_p50_s: st.ttft.quantile(50.0),
            ttft_p95_s: st.ttft.quantile(95.0),
            tbt_p50_s: if st.tbt.is_empty() {
                0.0
            } else {
                st.tbt.quantile(50.0)
            },
            tbt_p95_s: if st.tbt.is_empty() {
                0.0
            } else {
                st.tbt.quantile(95.0)
            },
            e2e_p50_s: st.e2e.quantile(50.0),
            output_tokens: st.output_tokens,
            tokens_per_s,
            usd_per_mtok: if st.output_tokens > 0 {
                total_usd / (st.output_tokens as f64 / 1e6)
            } else {
                0.0
            },
            prefill_utilization: if p_devsec > 0.0 {
                (p_busy / p_devsec).min(1.0)
            } else {
                0.0
            },
            decode_utilization: if d_devsec > 0.0 {
                (d_busy / d_devsec).min(1.0)
            } else {
                0.0
            },
            kv_bytes_moved: st.kv_bytes_moved,
            events_processed: events,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::trace::{generate, TraceConfig};
    use crate::cost::Precision;
    use crate::plan::tests::tiny_plan;
    use crate::plan::{AdmissionPolicy, BatchPolicy, FabricSpec, NodeBinding};

    fn trace(n: usize, rate: f64) -> Vec<Request> {
        generate(&TraceConfig {
            n_requests: n,
            rate,
            isl_mean: 512,
            osl_mean: 32,
            sigma: 0.0,
            seed: 9,
        })
    }

    #[test]
    fn tiny_dag_completes_all_requests() {
        let plan = tiny_plan();
        let mut sim = DagSim::new(&plan).unwrap();
        let t = trace(24, 4.0);
        let r = sim.run(&t).unwrap();
        assert_eq!(r.n_requests, 24);
        // One decode node per request → osl tokens each.
        assert_eq!(r.output_tokens, t.iter().map(|r| r.osl).sum::<u64>());
        assert!(r.makespan_s > 0.0);
        assert!(r.ttft_p50_s > 0.0);
        assert!(r.e2e_p50_s >= r.ttft_p50_s);
    }

    #[test]
    fn cross_chassis_handoff_moves_kv_bytes() {
        let plan = tiny_plan(); // prefill H100 (chassis 0) → decode Gaudi3
        let mut sim = DagSim::new(&plan).unwrap();
        let t = trace(8, 2.0);
        let r = sim.run(&t).unwrap();
        let m = crate::cost::model_profile::llama3_8b(Precision::Fp16);
        let expected: f64 = t.iter().map(|r| kv_cache_bytes(&m, r.isl, 1)).sum();
        assert!(
            (r.kv_bytes_moved - expected).abs() < 1.0,
            "moved {} expected {expected}",
            r.kv_bytes_moved
        );
    }

    #[test]
    fn deterministic_replay() {
        let plan = tiny_plan();
        let t = trace(16, 8.0);
        let r1 = DagSim::new(&plan).unwrap().run(&t).unwrap();
        let r2 = DagSim::new(&plan).unwrap().run(&t).unwrap();
        assert_eq!(r1.events_processed, r2.events_processed);
        assert_eq!(r1.tokens_per_s, r2.tokens_per_s);
        assert_eq!(r1.kv_bytes_moved, r2.kv_bytes_moved);
    }

    #[test]
    fn token_fraction_scales_expert_work() {
        // Halving a decode node's token fraction halves its generated
        // tokens and shrinks the KV handed across the fabric.
        let full = tiny_plan();
        let mut half = tiny_plan();
        half.bindings[2].token_fraction = 0.5; // llm.decode
        let t = trace(12, 3.0);
        let rf = DagSim::new(&full).unwrap().run(&t).unwrap();
        let rh = DagSim::new(&half).unwrap().run(&t).unwrap();
        let expect_half: u64 = t
            .iter()
            .map(|r| ((r.osl as f64 * 0.5).round() as u64).max(1))
            .sum();
        assert_eq!(rh.output_tokens, expect_half);
        assert!(rh.output_tokens < rf.output_tokens);
        assert!(
            rh.kv_bytes_moved < rf.kv_bytes_moved,
            "scaled ISL must shrink the prefill→decode KV handoff: {} vs {}",
            rh.kv_bytes_moved,
            rf.kv_bytes_moved
        );
    }

    #[test]
    fn cpu_only_dag_runs_without_pipelines() {
        let plan = ExecutionPlan {
            agent: "tools_only".into(),
            model: String::new(),
            sla: crate::plan::SlaSpec::None,
            bindings: vec![
                NodeBinding {
                    op: "io.input".into(),
                    class: "CPU".into(),
                    stage: Stage::Cpu,
                    latency_s: 0.001,
                    cost_usd: 0.0,
                    deps: vec![],
                    xfer_bytes: 0.0,
                    token_fraction: 1.0,
                    prefix_overlap: 0.0,
                },
                NodeBinding {
                    op: "tool.lookup".into(),
                    class: "CPU".into(),
                    stage: Stage::Cpu,
                    latency_s: 0.3,
                    cost_usd: 0.0,
                    deps: vec![0],
                    xfer_bytes: 0.0,
                    token_fraction: 1.0,
                    prefix_overlap: 0.0,
                },
                NodeBinding {
                    op: "io.output".into(),
                    class: "CPU".into(),
                    stage: Stage::Cpu,
                    latency_s: 0.001,
                    cost_usd: 0.0,
                    deps: vec![1],
                    xfer_bytes: 0.0,
                    token_fraction: 1.0,
                    prefix_overlap: 0.0,
                },
            ],
            pipelines: vec![],
            batching: BatchPolicy::default(),
            admission: AdmissionPolicy::default(),
            fabric: FabricSpec::default(),
            cpu_workers: 2,
            cost_usd: 0.0,
            latency_s: 0.302,
            pass_log: vec![],
        };
        let mut sim = DagSim::new(&plan).unwrap();
        let t = trace(12, 50.0); // overload the 2-slot pool
        let r = sim.run(&t).unwrap();
        assert_eq!(r.n_requests, 12);
        assert_eq!(r.output_tokens, 0);
        // No decode stages: TTFT falls back to completion time.
        assert!(r.ttft_p50_s >= 0.3);
        // 12 × 0.302 s of work on 2 slots arriving in ~0.24 s: the pool
        // must serialize (makespan well beyond a single request chain).
        assert!(r.makespan_s > 1.0, "cpu pool did not queue: {}", r.makespan_s);
    }

    #[test]
    fn cpu_pool_size_bounds_throughput() {
        let mut narrow = tiny_plan();
        narrow.cpu_workers = 1;
        let mut wide = tiny_plan();
        wide.cpu_workers = 64;
        // Raise CPU stage cost so the pool is the bottleneck.
        for p in [&mut narrow, &mut wide] {
            p.bindings[0].latency_s = 0.2;
            p.bindings[3].latency_s = 0.2;
        }
        let t = trace(24, 100.0);
        let rn = DagSim::new(&narrow).unwrap().run(&t).unwrap();
        let rw = DagSim::new(&wide).unwrap().run(&t).unwrap();
        assert!(
            rn.makespan_s > rw.makespan_s * 1.5,
            "narrow {} vs wide {}",
            rn.makespan_s,
            rw.makespan_s
        );
    }

    /// Scripted controller: applies fixed plans at given window indices.
    struct Scripted {
        window: usize,
        script: Vec<(usize, ExecutionPlan)>,
        applied: Vec<FleetChangeStats>,
        windows_seen: usize,
    }

    impl FleetController for Scripted {
        fn on_window(&mut self, _stats: &WindowStats) -> Option<ExecutionPlan> {
            let w = self.window;
            self.window += 1;
            self.windows_seen += 1;
            self.script
                .iter()
                .find(|(at, _)| *at == w)
                .map(|(_, p)| p.clone())
        }

        fn on_applied(&mut self, _t: f64, stats: &FleetChangeStats) {
            self.applied.push(stats.clone());
        }
    }

    #[test]
    fn fleet_scales_up_and_down_without_dropping_requests() {
        let base = tiny_plan(); // 1× H100 prefill, 2× Gaudi3 decode
        let mut grown = tiny_plan();
        grown.pipelines[1].replicas = 4;
        let mut shrunk = tiny_plan();
        shrunk.pipelines[1].replicas = 1;

        // A hot trace that keeps decode busy across both migrations.
        let t = trace(96, 24.0);
        let mut sim = DagSim::new(&base).unwrap();
        let mut ctl = Scripted {
            window: 0,
            script: vec![(1, grown), (4, shrunk)],
            applied: Vec::new(),
            windows_seen: 0,
        };
        let r = sim.run_controlled(&t, 0.5, &mut ctl).unwrap();
        assert_eq!(r.n_requests, 96, "no request may be dropped");
        assert_eq!(r.output_tokens, t.iter().map(|r| r.osl).sum::<u64>());
        assert_eq!(ctl.applied.len(), 2, "both migrations must apply");
        assert_eq!(ctl.applied[0].activated, 2, "2 → 4 decode pipelines");
        assert!(ctl.applied[1].retired >= 1, "shrink must retire pipelines");
        assert!(ctl.windows_seen >= 5);
    }

    #[test]
    fn drained_decode_sessions_migrate_kv_over_fabric() {
        let base = tiny_plan();
        let mut shrunk = tiny_plan();
        shrunk.pipelines[1].replicas = 1;
        // Overload decode (2 pipes × batch 32) far past its active-set
        // capacity so both pipes hold waiting sessions when the shrink
        // lands a few windows in.
        let t = trace(150, 200.0);
        let mut sim = DagSim::new(&base).unwrap();
        let mut ctl = Scripted {
            window: 0,
            script: vec![(3, shrunk)],
            applied: Vec::new(),
            windows_seen: 0,
        };
        let r = sim.run_controlled(&t, 0.2, &mut ctl).unwrap();
        assert_eq!(r.n_requests, 150);
        let fc = &ctl.applied[0];
        assert_eq!(fc.retired, 1);
        if fc.kv_moves > 0 {
            assert!(fc.kv_bytes > 0.0);
            assert!(
                fc.done_s >= fc.t,
                "KV landing cannot precede the migration"
            );
        }
    }

    #[test]
    fn fleet_change_resizes_cpu_pool_mid_run() {
        // The cpu_workers autoscaler's knob: a plan change that only
        // grows cpu_workers must widen the live pool (queued tool/IO
        // stages start on the fresh slots) and shorten the run.
        let mut narrow = tiny_plan();
        narrow.cpu_workers = 1;
        narrow.bindings[0].latency_s = 0.2; // make CPU the bottleneck
        narrow.bindings[3].latency_s = 0.2;
        let mut wide = narrow.clone();
        wide.cpu_workers = 32;
        let t = trace(24, 100.0);
        let r_narrow = DagSim::new(&narrow).unwrap().run(&t).unwrap();
        let mut sim = DagSim::new(&narrow).unwrap();
        let mut ctl = Scripted {
            window: 0,
            script: vec![(1, wide)],
            applied: Vec::new(),
            windows_seen: 0,
        };
        let r_grown = sim.run_controlled(&t, 0.5, &mut ctl).unwrap();
        assert_eq!(r_grown.n_requests, 24, "no request may be dropped");
        assert_eq!(ctl.applied.len(), 1);
        assert_eq!(ctl.applied[0].activated, 0, "no pipeline churn");
        assert!(
            r_grown.makespan_s < r_narrow.makespan_s * 0.8,
            "grown pool must beat the narrow run: {} vs {}",
            r_grown.makespan_s,
            r_narrow.makespan_s
        );
    }

    #[test]
    fn per_group_jobs_and_window_signals_are_reported() {
        use crate::plan::presets::mixed_generation;

        let plan = mixed_generation("8b-fp16", "H100", "A100", 1, 1);
        let t = trace(16, 8.0);
        let mut sim = DagSim::new(&plan).unwrap();
        let mut ctl = Scripted {
            window: 0,
            script: vec![],
            applied: Vec::new(),
            windows_seen: 0,
        };
        let r = sim.run_controlled(&t, 0.5, &mut ctl).unwrap();
        assert_eq!(r.n_requests, 16);
        let detail = sim.last_detail().unwrap();
        // Every request runs one prefill (H100) and both decode
        // siblings (one per generation): the per-group ledger is exact.
        assert_eq!(
            detail.jobs_by_group.get("prefill H100 tp1 pp1 b8"),
            Some(&16)
        );
        assert_eq!(
            detail.jobs_by_group.get("decode H100 tp1 pp1 b16"),
            Some(&16)
        );
        assert_eq!(
            detail.jobs_by_group.get("decode A100 tp1 pp1 b16"),
            Some(&16)
        );
        assert_eq!(detail.jobs_by_group.values().sum::<u64>(), 48);
    }

    /// Controller that records every window's group observations.
    struct GroupWatcher {
        seen: Vec<Vec<GroupWindow>>,
    }

    impl FleetController for GroupWatcher {
        fn on_window(&mut self, stats: &WindowStats) -> Option<ExecutionPlan> {
            self.seen.push(stats.groups.clone());
            None
        }
    }

    #[test]
    fn window_stats_carry_per_group_utilization() {
        use crate::plan::presets::mixed_generation;

        let plan = mixed_generation("8b-fp16", "H100", "A100", 1, 1);
        let t = trace(24, 12.0);
        let mut sim = DagSim::new(&plan).unwrap();
        let mut ctl = GroupWatcher { seen: Vec::new() };
        sim.run_controlled(&t, 0.5, &mut ctl).unwrap();
        assert!(!ctl.seen.is_empty());
        // Every window names all three groups with sane readings.
        for groups in &ctl.seen {
            assert_eq!(groups.len(), 3, "{groups:?}");
            for g in groups {
                assert!((0.0..=1.0).contains(&g.util), "{g:?}");
                assert!(g.replicas >= 1);
                assert!(g.max_batch > 0);
            }
        }
        // Some window saw decode work on both generations.
        let busy = |key: &str| {
            ctl.seen
                .iter()
                .flatten()
                .any(|g| g.key == key && g.util > 0.0)
        };
        assert!(busy("decode H100 tp1 pp1 b16"));
        assert!(busy("decode A100 tp1 pp1 b16"));
    }

    #[test]
    fn token_fraction_retune_applies_to_future_arrivals_only() {
        use crate::plan::presets::mixed_generation;

        let plan = mixed_generation("8b-fp16", "H100", "A100", 1, 1); // 0.5/0.5
        // Retuned mid-run: the H100 sibling's fraction doubles (a
        // deliberately lopsided retune so the adoption is observable in
        // the token totals — a share-preserving retune conserves them).
        let mut retuned = plan.clone();
        retuned.bindings[2].token_fraction = 1.0;
        // Arrivals spread over ~8 s; the retune lands at t=2 s.
        let t = trace(32, 4.0);
        let mut sim = DagSim::new(&plan).unwrap();
        let mut ctl = Scripted {
            window: 0,
            script: vec![(0, retuned)],
            applied: Vec::new(),
            windows_seen: 0,
        };
        let r = sim.run_controlled(&t, 2.0, &mut ctl).unwrap();
        assert_eq!(r.n_requests, 32, "no request dropped across the retune");
        assert_eq!(ctl.applied.len(), 1, "the retune-only change applies");
        assert_eq!(ctl.applied[0].activated, 0, "no pipeline churn");
        // Requests arriving before the retune decode 0.5+0.5 of their
        // OSL; later arrivals decode 1.0+0.5 — the mixed total sits
        // strictly between the two extremes, proving the new fractions
        // reached future arrivals and *only* future arrivals.
        let total_at = |f2: f64, f3: f64| -> u64 {
            t.iter()
                .map(|r| {
                    ((r.osl as f64 * f2).round() as u64).max(1)
                        + ((r.osl as f64 * f3).round() as u64).max(1)
                })
                .sum()
        };
        let all_old = total_at(0.5, 0.5);
        let all_new = total_at(1.0, 0.5);
        assert!(
            r.output_tokens > all_old && r.output_tokens < all_new,
            "mixed split must land between the extremes: {} not in ({all_old}, {all_new})",
            r.output_tokens
        );
    }

    #[test]
    fn controlled_run_with_no_interventions_matches_static_run() {
        let plan = tiny_plan();
        let t = trace(24, 6.0);
        let r_static = DagSim::new(&plan).unwrap().run(&t).unwrap();
        let mut ctl = Scripted {
            window: 0,
            script: vec![],
            applied: Vec::new(),
            windows_seen: 0,
        };
        let r_ctl = DagSim::new(&plan)
            .unwrap()
            .run_controlled(&t, 1.0, &mut ctl)
            .unwrap();
        assert_eq!(r_static.output_tokens, r_ctl.output_tokens);
        assert_eq!(r_static.kv_bytes_moved, r_ctl.kv_bytes_moved);
        assert!((r_static.makespan_s - r_ctl.makespan_s).abs() < 1e-9);
    }

    #[test]
    fn incompatible_fleet_change_rejected() {
        let base = tiny_plan();
        // A target that strands in-flight decode work: decode moves to
        // H100 while the bindings still route llm.decode to Gaudi3.
        let mut bad = tiny_plan();
        bad.pipelines[1].device = "H100".into();
        bad.bindings[2].class = "H100".into(); // keeps validate() happy
        let t = trace(32, 50.0);
        let mut sim = DagSim::new(&base).unwrap();
        let mut ctl = Scripted {
            window: 0,
            script: vec![(0, bad)],
            applied: Vec::new(),
            windows_seen: 0,
        };
        assert!(sim.run_controlled(&t, 0.2, &mut ctl).is_err());
    }

    #[test]
    fn non_finite_arrival_is_rejected_not_panicked() {
        let plan = tiny_plan();
        let mut t = trace(4, 2.0);
        t[2].arrive_s = f64::NAN;
        let r = DagSim::new(&plan).unwrap().run(&t);
        assert!(matches!(r, Err(Error::Config(_))));
        let mut t2 = trace(4, 2.0);
        t2[0].arrive_s = f64::INFINITY;
        let r2 = DagSim::new(&plan).unwrap().run(&t2);
        assert!(matches!(r2, Err(Error::Config(_))));
    }

    #[test]
    fn drain_to_zero_surfaces_typed_error_not_panic() {
        // A fleet change that retires a class's last live pipes while
        // work is in flight must surface as a typed Capacity rejection
        // — the routing layer (`pick_prefill`/`pick_decode`) returns
        // Result now instead of panicking on an empty candidate set.
        let base = tiny_plan();
        let mut bad = tiny_plan();
        bad.pipelines[1].device = "H100".into();
        bad.bindings[2].class = "H100".into(); // validate() stays happy
        let t = trace(48, 80.0); // keeps decode saturated at the change
        let mut sim = DagSim::new(&base).unwrap();
        let mut ctl = Scripted {
            window: 0,
            script: vec![(1, bad)],
            applied: Vec::new(),
            windows_seen: 0,
        };
        let r = sim.run_controlled(&t, 0.1, &mut ctl);
        assert!(matches!(r, Err(Error::Capacity(_))));
    }

    #[test]
    fn prefix_reuse_charges_only_uncached_suffixes() {
        use crate::plan::presets::shared_prefix_fanout;

        let plan = shared_prefix_fanout("8b-fp16", "H100", 4);
        let t = trace(8, 1.0);
        let mut off = DagSim::new(&plan).unwrap();
        off.run(&t).unwrap();
        let d_off = off.last_detail().unwrap().clone();
        let mut on = DagSim::new(&plan).unwrap();
        on.set_kv_reuse(KvReuseConfig::default());
        on.run(&t).unwrap();
        let d_on = on.last_detail().unwrap().clone();
        // The same work reaches the same groups either way...
        assert_eq!(d_on.jobs_by_group, d_off.jobs_by_group);
        // ...but reuse-off never consults the cache...
        assert_eq!(d_off.prefix_hits_by_group.values().sum::<u64>(), 0);
        assert_eq!(d_off.prefix_misses_by_group.values().sum::<u64>(), 0);
        // ...while reuse-on hits for every fan-out sibling after the
        // first (4 workers share the planner's context → 3 hits per
        // request) and charges strictly fewer prefill tokens.
        let hits: u64 = d_on.prefix_hits_by_group.values().sum();
        assert_eq!(hits, 8 * 3, "{:?}", d_on.prefix_hits_by_group);
        assert!(
            d_on.prefill_tokens < d_off.prefill_tokens,
            "reuse-on must prefill fewer tokens: {} vs {}",
            d_on.prefill_tokens,
            d_off.prefill_tokens
        );
    }

    #[test]
    fn tight_hbm_budget_evicts_and_reinflates_prefill_cost() {
        use crate::cost::Precision;
        use crate::plan::presets::shared_prefix_fanout;

        // Hit rate is capacity-dependent: with an HBM pool holding one
        // prefix and no spill tiers, the planner's own context occupies
        // the pool and every fan-out worker misses — the same trace
        // that hits 3×/request under ample budgets prefills from
        // scratch here.
        let plan = shared_prefix_fanout("8b-fp16", "H100", 4);
        let t = trace(8, 1.0);
        let m = crate::cost::model_profile::llama3_8b(Precision::Fp16);
        let token_bytes = kv_cache_bytes(&m, 1, 1);
        let tight = KvReuseConfig {
            hbm_bytes: 2.0 * 256.0 * token_bytes, // one 512-token entry
            dram_bytes: 0.0,
            disk_bytes: 0.0,
            page_tokens: 256,
        };
        let mut sim_tight = DagSim::new(&plan).unwrap();
        sim_tight.set_kv_reuse(tight);
        sim_tight.run(&t).unwrap();
        let d_tight = sim_tight.last_detail().unwrap().clone();
        let mut sim_ample = DagSim::new(&plan).unwrap();
        sim_ample.set_kv_reuse(KvReuseConfig::default());
        sim_ample.run(&t).unwrap();
        let d_ample = sim_ample.last_detail().unwrap().clone();
        let hits_tight: u64 = d_tight.prefix_hits_by_group.values().sum();
        let hits_ample: u64 = d_ample.prefix_hits_by_group.values().sum();
        assert!(hits_tight < hits_ample, "{hits_tight} vs {hits_ample}");
        assert!(
            d_tight.prefill_tokens > d_ample.prefill_tokens,
            "capacity pressure must re-inflate prefill cost: {} vs {}",
            d_tight.prefill_tokens,
            d_ample.prefill_tokens
        );
    }

    #[test]
    fn window_stats_surface_prefix_hit_rates() {
        use crate::plan::presets::shared_prefix_fanout;

        let plan = shared_prefix_fanout("8b-fp16", "H100", 4);
        let t = trace(16, 4.0);
        let mut sim = DagSim::new(&plan).unwrap();
        sim.set_kv_reuse(KvReuseConfig::default());
        let mut ctl = GroupWatcher { seen: Vec::new() };
        sim.run_controlled(&t, 0.5, &mut ctl).unwrap();
        let hits: u64 = ctl.seen.iter().flatten().map(|g| g.prefix_hits).sum();
        let misses: u64 = ctl.seen.iter().flatten().map(|g| g.prefix_misses).sum();
        assert!(hits > 0, "windows must surface prefix hits");
        assert!(misses > 0, "first-touch contexts must surface as misses");
        // Only prefill groups carry prefix traffic.
        for g in ctl.seen.iter().flatten() {
            if g.role == Role::Decode {
                assert_eq!(g.prefix_hits + g.prefix_misses, 0, "{g:?}");
            }
        }
    }

    #[test]
    fn run_stream_matches_slice_replay() {
        let plan = tiny_plan();
        let t = trace(32, 6.0);
        let r_slice = DagSim::new(&plan).unwrap().run(&t).unwrap();
        let mut sim = DagSim::new(&plan).unwrap();
        let mut replay = crate::cluster::arrivals::Replay::new(&t);
        let r_stream = sim.run_stream(&mut replay).unwrap();
        assert_eq!(r_slice, r_stream);
        let d = sim.last_detail().unwrap();
        assert!(d.inflight_peak >= 1 && d.inflight_peak <= t.len());
        assert!(d.event_queue_peak >= 1);
    }

    #[test]
    fn out_of_order_stream_is_rejected() {
        let plan = tiny_plan();
        let mut t = trace(4, 4.0);
        t.swap(0, 3);
        let mut sim = DagSim::new(&plan).unwrap();
        let mut replay = crate::cluster::arrivals::Replay::new(&t);
        let err = sim.run_stream(&mut replay).unwrap_err();
        assert!(
            matches!(err, Error::Config(ref m) if m.contains("not time-ordered")),
            "{err:?}"
        );
        // The slice APIs sort instead: same trace runs fine.
        DagSim::new(&plan).unwrap().run(&t).unwrap();
    }
}
