//! The discrete-event cluster simulator.
//!
//! A [`Placement`] describes the serving fleet: prefill pipelines and
//! decode pipelines, each a (device, TP×PP, batch limit) tuple pinned to
//! a chassis of the [`Fabric`]. The event loop executes a request trace:
//!
//! ```text
//! Arrival → [cpu pre-stage] → prefill queue → batched prefill
//!        → KV transfer over fabric (overlap-aware)
//!        → continuous-batching decode rounds → [cpu post-stage] → done
//! ```
//!
//! Timing comes from [`crate::cost::roofline`] — the same calibration
//! the analytic Figure-8/9 explorer uses, so simulated and analytic TCO
//! cross-check (see `rust/tests/sim_vs_analytic.rs`).

use std::cmp::Reverse;
use std::collections::{BinaryHeap, BTreeMap, VecDeque};

use super::trace::Request;
use crate::cost::hardware::DeviceSpec;
use crate::cost::model_profile::ModelProfile;
use crate::cost::roofline::{decode_step_time, prefill_time, Efficiency, Parallelism};
use crate::cost::tco::{opex_usd_per_hour, FinanceTerms, OpexModel};
use crate::transport::fabric::{Fabric, NodeAddr};
use crate::util::bench::percentile;
use crate::{Error, Result};

/// One serving pipeline (a TP×PP device group acting as a unit).
#[derive(Debug, Clone)]
pub struct PipelineSpec {
    pub device: DeviceSpec,
    pub par: Parallelism,
    /// Max requests per prefill batch / decode round.
    pub max_batch: u64,
    /// Chassis this pipeline's lead device occupies.
    pub chassis: u32,
}

/// The fleet layout the planner chose.
#[derive(Debug, Clone)]
pub struct Placement {
    pub prefill: Vec<PipelineSpec>,
    pub decode: Vec<PipelineSpec>,
}

impl Placement {
    /// Total device count (for cost reporting).
    pub fn device_count(&self) -> u32 {
        self.prefill
            .iter()
            .chain(self.decode.iter())
            .map(|p| p.par.devices())
            .sum()
    }

    /// Fleet $/hr under the given opex model.
    pub fn usd_per_hour(&self, opex: OpexModel, terms: &FinanceTerms) -> f64 {
        self.prefill
            .iter()
            .chain(self.decode.iter())
            .map(|p| p.par.devices() as f64 * opex_usd_per_hour(&p.device, opex, terms))
            .sum()
    }
}

#[derive(Debug, Clone, PartialEq)]
enum Ev {
    /// Request hits the front door.
    Arrival(usize),
    /// CPU pre-stage finished; request joins a prefill queue.
    PrefillReady(usize),
    /// Prefill batch `id` on pipeline finished.
    PrefillDone { pipe: usize, batch: u64 },
    /// Request's KV landed on its decode pipeline.
    KvArrived(usize),
    /// Decode round boundary on a pipeline.
    DecodeRound(usize),
    /// CPU post-stage complete.
    Done(usize),
}

#[derive(Debug, Clone, PartialEq)]
struct Event {
    t: f64,
    seq: u64,
    ev: Ev,
}

impl Eq for Event {}
impl PartialOrd for Event {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for Event {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        self.t
            .partial_cmp(&other.t)
            .unwrap()
            .then(self.seq.cmp(&other.seq))
    }
}

#[derive(Debug, Clone, Default)]
struct ReqState {
    decode_pipe: usize,
    first_token_s: f64,
    tokens_done: u64,
    done_s: f64,
}

struct PrefillPipe {
    spec: PipelineSpec,
    queue: VecDeque<usize>,
    busy: bool,
    busy_time: f64,
    next_batch: u64,
    in_flight: BTreeMap<u64, Vec<usize>>,
}

struct DecodePipe {
    spec: PipelineSpec,
    active: Vec<usize>,
    waiting: VecDeque<usize>,
    round_scheduled: bool,
    busy_time: f64,
}

/// Simulation results. `PartialEq` is derived so the replay-equivalence
/// suite can pin slice and streaming runs byte-identical.
#[derive(Debug, Clone, PartialEq)]
pub struct SimReport {
    pub n_requests: usize,
    pub makespan_s: f64,
    pub ttft_p50_s: f64,
    pub ttft_p95_s: f64,
    pub tbt_p50_s: f64,
    pub tbt_p95_s: f64,
    pub e2e_p50_s: f64,
    pub output_tokens: u64,
    pub tokens_per_s: f64,
    pub usd_per_mtok: f64,
    pub prefill_utilization: f64,
    pub decode_utilization: f64,
    pub kv_bytes_moved: f64,
    pub events_processed: u64,
}

impl SimReport {
    pub fn summary(&self) -> String {
        format!(
            "{} reqs in {:.1}s | TTFT p50 {:.0}ms p95 {:.0}ms | TBT p50 {:.1}ms p95 {:.1}ms | \
             {:.0} tok/s | ${:.3}/Mtok | util p{:.0}% d{:.0}%",
            self.n_requests,
            self.makespan_s,
            self.ttft_p50_s * 1e3,
            self.ttft_p95_s * 1e3,
            self.tbt_p50_s * 1e3,
            self.tbt_p95_s * 1e3,
            self.tokens_per_s,
            self.usd_per_mtok,
            self.prefill_utilization * 100.0,
            self.decode_utilization * 100.0
        )
    }
}

/// The simulator.
pub struct ClusterSim {
    pub model: ModelProfile,
    pub eff: Efficiency,
    pub opex: OpexModel,
    pub terms: FinanceTerms,
    pub placement: Placement,
    fabric: Fabric,
    heap: BinaryHeap<Reverse<Event>>,
    seq: u64,
}

impl ClusterSim {
    pub fn new(model: ModelProfile, placement: Placement, fabric: Fabric) -> ClusterSim {
        ClusterSim {
            model,
            eff: Efficiency::default(),
            opex: OpexModel::Derived,
            terms: FinanceTerms::default(),
            placement,
            fabric,
            heap: BinaryHeap::new(),
            seq: 0,
        }
    }

    /// Build a flat (single prefill→decode) simulator directly from an
    /// [`ExecutionPlan`]: the placement, fabric, and model all come from
    /// the plan, so the simulated fleet is exactly the planned fleet.
    pub fn from_plan(plan: &crate::plan::ExecutionPlan) -> Result<ClusterSim> {
        plan.validate()?;
        let model = crate::cost::model_profile::by_short_name(&plan.model)
            .ok_or_else(|| {
                Error::Config(format!(
                    "plan model `{}` not in the profile catalog",
                    plan.model
                ))
            })?;
        Ok(ClusterSim::new(model, plan.placement()?, plan.build_fabric()?))
    }

    fn push(&mut self, t: f64, ev: Ev) {
        self.seq += 1;
        self.heap.push(Reverse(Event {
            t,
            seq: self.seq,
            ev,
        }));
    }

    /// Start a prefill batch on pipeline `pi` if it is idle and has work.
    fn try_start_prefill(
        &mut self,
        pipes: &mut [PrefillPipe],
        pi: usize,
        now: f64,
        trace: &[Request],
    ) {
        let p = &mut pipes[pi];
        if p.busy || p.queue.is_empty() {
            return;
        }
        let take = (p.spec.max_batch as usize).min(p.queue.len());
        let batch: Vec<usize> = p.queue.drain(..take).collect();
        // Batch prefill time at the longest prompt in the batch
        // (padding to the bucket, as real serving does).
        let isl = batch.iter().map(|&i| trace[i].isl).max().unwrap_or(1);
        let t_pre = prefill_time(
            &self.model,
            &p.spec.device,
            p.spec.par,
            isl,
            batch.len() as u64,
            &self.eff,
        )
        .total();
        let id = p.next_batch;
        p.next_batch += 1;
        p.busy = true;
        p.busy_time += t_pre;
        p.in_flight.insert(id, batch);
        self.push(now + t_pre, Ev::PrefillDone { pipe: pi, batch: id });
    }

    /// Schedule a decode round on pipeline `di` if needed.
    fn maybe_schedule_round(&mut self, pipes: &mut [DecodePipe], di: usize, now: f64, trace: &[Request], states: &[ReqState]) {
        let d = &mut pipes[di];
        if d.round_scheduled {
            return;
        }
        // Admit waiting requests (continuous batching).
        while d.active.len() < d.spec.max_batch as usize {
            match d.waiting.pop_front() {
                Some(i) => d.active.push(i),
                None => break,
            }
        }
        if d.active.is_empty() {
            return;
        }
        // Round time at the mean current context of active requests.
        let ctx: u64 = d
            .active
            .iter()
            .map(|&i| trace[i].isl + states[i].tokens_done)
            .sum::<u64>()
            / d.active.len() as u64;
        let step = decode_step_time(
            &self.model,
            &d.spec.device,
            d.spec.par,
            ctx.max(1),
            d.active.len() as u64,
            &self.eff,
        )
        .total();
        d.round_scheduled = true;
        d.busy_time += step;
        self.push(now + step, Ev::DecodeRound(di));
    }

    /// Run the trace to completion; returns aggregate metrics.
    pub fn run(&mut self, trace: &[Request]) -> Result<SimReport> {
        if self.placement.prefill.is_empty() || self.placement.decode.is_empty() {
            return Err(Error::Runtime(
                "placement needs ≥1 pipeline per stage".into(),
            ));
        }
        let n = trace.len();
        let mut states = vec![ReqState::default(); n];
        let mut prefill: Vec<PrefillPipe> = self
            .placement
            .prefill
            .clone()
            .into_iter()
            .map(|spec| PrefillPipe {
                spec,
                queue: VecDeque::new(),
                busy: false,
                busy_time: 0.0,
                next_batch: 0,
                in_flight: BTreeMap::new(),
            })
            .collect();
        let mut decode: Vec<DecodePipe> = self
            .placement
            .decode
            .clone()
            .into_iter()
            .map(|spec| DecodePipe {
                spec,
                active: Vec::new(),
                waiting: VecDeque::new(),
                round_scheduled: false,
                busy_time: 0.0,
            })
            .collect();

        self.heap.clear();
        for (i, r) in trace.iter().enumerate() {
            self.push(r.arrive_s, Ev::Arrival(i));
        }

        let mut tbt_samples: Vec<f64> = Vec::new();
        let mut last_token_t: Vec<f64> = vec![0.0; n];
        let mut kv_bytes_moved = 0.0;
        let mut events = 0u64;
        let mut completed = 0usize;
        let mut makespan = 0.0f64;

        while let Some(Reverse(Event { t, ev, .. })) = self.heap.pop() {
            events += 1;
            if events > 100_000_000 {
                return Err(Error::Runtime("event budget exceeded".into()));
            }
            makespan = makespan.max(t);
            match ev {
                Ev::Arrival(i) => {
                    self.push(t + trace[i].pre_s, Ev::PrefillReady(i));
                }
                Ev::PrefillReady(i) => {
                    // Fast-path router: least-loaded prefill pipeline.
                    let pi = (0..prefill.len())
                        .min_by_key(|&k| prefill[k].queue.len() + prefill[k].busy as usize)
                        .unwrap();
                    prefill[pi].queue.push_back(i);
                    self.try_start_prefill(&mut prefill, pi, t, trace);
                }
                Ev::PrefillDone { pipe, batch } => {
                    prefill[pipe].busy = false;
                    let members = prefill[pipe].in_flight.remove(&batch).unwrap();
                    let from = NodeAddr {
                        chassis: prefill[pipe].spec.chassis,
                        slot: 0,
                    };
                    for i in members {
                        // Route to least-loaded decode pipeline.
                        let di = (0..decode.len())
                            .min_by_key(|&k| decode[k].active.len() + decode[k].waiting.len())
                            .unwrap();
                        states[i].decode_pipe = di;
                        let to = NodeAddr {
                            chassis: decode[di].spec.chassis,
                            slot: 0,
                        };
                        let bytes =
                            crate::cost::kv::kv_cache_bytes(&self.model, trace[i].isl, 1);
                        kv_bytes_moved += bytes;
                        let arrive = self.fabric.transfer(from, to, bytes, t)?;
                        self.push(arrive, Ev::KvArrived(i));
                    }
                    self.try_start_prefill(&mut prefill, pipe, t, trace);
                }
                Ev::KvArrived(i) => {
                    let di = states[i].decode_pipe;
                    decode[di].waiting.push_back(i);
                    self.maybe_schedule_round(&mut decode, di, t, trace, &states);
                }
                Ev::DecodeRound(di) => {
                    decode[di].round_scheduled = false;
                    // Every active request emits one token.
                    let active = decode[di].active.clone();
                    let mut still = Vec::with_capacity(active.len());
                    for i in active {
                        if states[i].tokens_done == 0 {
                            states[i].first_token_s = t;
                        } else {
                            tbt_samples.push(t - last_token_t[i]);
                        }
                        last_token_t[i] = t;
                        states[i].tokens_done += 1;
                        if states[i].tokens_done >= trace[i].osl {
                            self.push(t + trace[i].post_s, Ev::Done(i));
                        } else {
                            still.push(i);
                        }
                    }
                    decode[di].active = still;
                    self.maybe_schedule_round(&mut decode, di, t, trace, &states);
                }
                Ev::Done(i) => {
                    states[i].done_s = t;
                    completed += 1;
                }
            }
        }

        if completed != n {
            return Err(Error::Runtime(format!(
                "simulation stalled: {completed}/{n} requests completed"
            )));
        }

        let ttfts: Vec<f64> = (0..n)
            .map(|i| states[i].first_token_s - trace[i].arrive_s)
            .collect();
        let e2es: Vec<f64> = (0..n)
            .map(|i| states[i].done_s - trace[i].arrive_s)
            .collect();
        let output_tokens: u64 = trace.iter().map(|r| r.osl).sum();
        let usd_per_hr = self.placement.usd_per_hour(self.opex, &self.terms);
        let tokens_per_s = output_tokens as f64 / makespan;
        let prefill_devsec: f64 = prefill
            .iter()
            .map(|p| p.busy_time * p.spec.par.devices() as f64)
            .sum();
        let decode_devsec: f64 = decode
            .iter()
            .map(|d| d.busy_time * d.spec.par.devices() as f64)
            .sum();
        let prefill_dev: f64 = prefill
            .iter()
            .map(|p| p.spec.par.devices() as f64)
            .sum::<f64>()
            * makespan;
        let decode_dev: f64 = decode
            .iter()
            .map(|d| d.spec.par.devices() as f64)
            .sum::<f64>()
            * makespan;

        Ok(SimReport {
            n_requests: n,
            makespan_s: makespan,
            ttft_p50_s: percentile(&ttfts, 50.0),
            ttft_p95_s: percentile(&ttfts, 95.0),
            tbt_p50_s: if tbt_samples.is_empty() {
                0.0
            } else {
                percentile(&tbt_samples, 50.0)
            },
            tbt_p95_s: if tbt_samples.is_empty() {
                0.0
            } else {
                percentile(&tbt_samples, 95.0)
            },
            e2e_p50_s: percentile(&e2es, 50.0),
            output_tokens,
            tokens_per_s,
            usd_per_mtok: usd_per_hr / 3600.0 / tokens_per_s * 1e6,
            prefill_utilization: prefill_devsec / prefill_dev,
            decode_utilization: decode_devsec / decode_dev,
            kv_bytes_moved,
            events_processed: events,
        })
    }
}

/// Execute an [`ExecutionPlan`](crate::plan::ExecutionPlan)'s full
/// agent DAG against its planned fleet — CPU pre/post stages, tool/IO
/// nodes, any number of LLM inferences per request, with per-edge
/// fabric transfers. This is the plan-native entry point; the flat
/// [`ClusterSim`] remains for single-LLM request streams and the
/// analytic cross-checks. For *time-varying* fleets (the orchestration
/// loop re-planning mid-run), drive
/// [`DagSim::run_controlled`](super::dag::DagSim::run_controlled)
/// through [`crate::orchestrator::SimExecutor`] instead.
pub fn simulate_plan(
    plan: &crate::plan::ExecutionPlan,
    trace: &[Request],
) -> Result<SimReport> {
    super::dag::DagSim::new(plan)?.run(trace)
}

/// Streaming twin of [`simulate_plan`]: pulls requests lazily from any
/// [`ArrivalProcess`](super::arrivals::ArrivalProcess), so memory is
/// bounded by the in-flight set rather than the trace length — the
/// entry point for million-request diurnal days.
pub fn simulate_stream(
    plan: &crate::plan::ExecutionPlan,
    arrivals: &mut dyn super::arrivals::ArrivalProcess,
) -> Result<SimReport> {
    super::dag::DagSim::new(plan)?.run_stream(arrivals)
}

/// Convenience: build a homogeneous-pair placement (`n_p` prefill and
/// `n_d` decode pipelines on the given devices), chassis-separated.
pub fn pair_placement(
    prefill_dev: &DeviceSpec,
    prefill_par: Parallelism,
    n_p: usize,
    prefill_batch: u64,
    decode_dev: &DeviceSpec,
    decode_par: Parallelism,
    n_d: usize,
    decode_batch: u64,
) -> Placement {
    let mut chassis = 0u32;
    let prefill = (0..n_p)
        .map(|_| {
            let s = PipelineSpec {
                device: prefill_dev.clone(),
                par: prefill_par,
                max_batch: prefill_batch,
                chassis,
            };
            chassis += 1;
            s
        })
        .collect();
    let decode = (0..n_d)
        .map(|_| {
            let s = PipelineSpec {
                device: decode_dev.clone(),
                par: decode_par,
                max_batch: decode_batch,
                chassis,
            };
            chassis += 1;
            s
        })
        .collect();
    Placement { prefill, decode }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::trace::{generate, TraceConfig};
    use crate::cost::hardware::by_name;
    use crate::cost::model_profile::llama3_8b;
    use crate::cost::Precision;

    fn basic_sim(rate: f64, n: usize) -> (ClusterSim, Vec<Request>) {
        let h100 = by_name("H100").unwrap();
        let placement = pair_placement(
            &h100,
            Parallelism { tp: 1, pp: 1 },
            1,
            8,
            &h100,
            Parallelism { tp: 1, pp: 1 },
            1,
            32,
        );
        let fabric = Fabric::new(4, 8, h100.scaleup_bw_gbps, 400.0);
        let sim = ClusterSim::new(llama3_8b(Precision::Fp16), placement, fabric);
        let trace = generate(&TraceConfig {
            n_requests: n,
            rate,
            isl_mean: 512,
            osl_mean: 64,
            sigma: 0.3,
            seed: 1,
        });
        (sim, trace)
    }

    #[test]
    fn completes_all_requests() {
        let (mut sim, trace) = basic_sim(4.0, 64);
        let r = sim.run(&trace).unwrap();
        assert_eq!(r.n_requests, 64);
        assert_eq!(r.output_tokens, trace.iter().map(|t| t.osl).sum::<u64>());
        assert!(r.makespan_s > 0.0);
        assert!(r.tokens_per_s > 0.0);
    }

    #[test]
    fn ttft_reasonable_under_light_load() {
        let (mut sim, trace) = basic_sim(0.5, 16);
        let r = sim.run(&trace).unwrap();
        // Light load: TTFT ≈ prefill + transfer ≈ tens of ms.
        assert!(r.ttft_p50_s < 0.25, "{}", r.summary());
        assert!(r.tbt_p50_s < 0.02, "{}", r.summary());
    }

    #[test]
    fn overload_inflates_ttft() {
        let (mut s1, t1) = basic_sim(0.5, 48);
        let (mut s2, t2) = basic_sim(50.0, 48);
        let r1 = s1.run(&t1).unwrap();
        let r2 = s2.run(&t2).unwrap();
        assert!(
            r2.ttft_p95_s > 2.0 * r1.ttft_p95_s,
            "overloaded {} vs light {}",
            r2.ttft_p95_s,
            r1.ttft_p95_s
        );
    }

    #[test]
    fn more_decode_pipelines_increase_throughput() {
        let h100 = by_name("H100").unwrap();
        let make = |nd: usize| {
            let placement = pair_placement(
                &h100,
                Parallelism { tp: 1, pp: 1 },
                1,
                8,
                &h100,
                Parallelism { tp: 1, pp: 1 },
                nd,
                16,
            );
            let fabric = Fabric::new(8, 8, h100.scaleup_bw_gbps, 400.0);
            ClusterSim::new(llama3_8b(Precision::Fp16), placement, fabric)
        };
        let trace = generate(&TraceConfig {
            n_requests: 96,
            rate: 30.0,
            isl_mean: 512,
            osl_mean: 128,
            sigma: 0.0,
            seed: 3,
        });
        let r1 = make(1).run(&trace).unwrap();
        let r3 = make(3).run(&trace).unwrap();
        assert!(
            r3.tokens_per_s > r1.tokens_per_s * 1.2,
            "1 pipe {} vs 3 pipes {}",
            r1.tokens_per_s,
            r3.tokens_per_s
        );
    }

    #[test]
    fn utilization_in_unit_range() {
        let (mut sim, trace) = basic_sim(8.0, 64);
        let r = sim.run(&trace).unwrap();
        assert!(r.prefill_utilization > 0.0 && r.prefill_utilization <= 1.0);
        assert!(r.decode_utilization > 0.0 && r.decode_utilization <= 1.0);
    }

    #[test]
    fn kv_bytes_match_eq3() {
        let (mut sim, trace) = basic_sim(4.0, 16);
        let m = llama3_8b(Precision::Fp16);
        let expected: f64 = trace
            .iter()
            .map(|r| crate::cost::kv::kv_cache_bytes(&m, r.isl, 1))
            .sum();
        let r = sim.run(&trace).unwrap();
        assert!((r.kv_bytes_moved - expected).abs() < 1.0);
    }

    #[test]
    fn empty_placement_rejected() {
        let h100 = by_name("H100").unwrap();
        let placement = Placement {
            prefill: vec![],
            decode: vec![PipelineSpec {
                device: h100.clone(),
                par: Parallelism { tp: 1, pp: 1 },
                max_batch: 1,
                chassis: 0,
            }],
        };
        let mut sim = ClusterSim::new(
            llama3_8b(Precision::Fp16),
            placement,
            Fabric::new(1, 8, 900.0, 400.0),
        );
        assert!(sim.run(&[]).is_err());
    }

    #[test]
    fn deterministic_runs() {
        let (mut s1, t1) = basic_sim(8.0, 48);
        let (mut s2, t2) = basic_sim(8.0, 48);
        let r1 = s1.run(&t1).unwrap();
        let r2 = s2.run(&t2).unwrap();
        assert_eq!(r1.events_processed, r2.events_processed);
        assert_eq!(r1.tokens_per_s, r2.tokens_per_s);
        let _ = t2;
    }
}
