//! Streaming arrival processes: pull-based request generators.
//!
//! The historical trace API ([`crate::cluster::trace`]) materializes a
//! whole `Vec<Request>` up front, which caps a "day of traffic" at
//! whatever fits in memory. This module redesigns ingestion around the
//! [`ArrivalProcess`] trait — an infallible iterator of [`Request`]s
//! with a peekable next-arrival time — so `DagSim::run_stream` and the
//! orchestrator executors can pull arrivals lazily: the event queue
//! holds in-flight work plus exactly one future arrival, never the
//! future itself. All processes are seeded, deterministic, and O(1)
//! memory in the number of requests emitted.
//!
//! Back-compat is exact, not approximate: [`Poisson`] reproduces
//! [`trace::generate`](crate::cluster::trace::generate) bit-for-bit
//! (same seed, same RNG draw order), [`SquareWave::compat`] reproduces
//! [`trace::bursty`](crate::cluster::trace::bursty), [`VoiceAgent`]
//! reproduces [`trace::voice_agent`](crate::cluster::trace::voice_agent),
//! and [`Replay`] adapts any existing slice. Golden tests in this
//! module and `rust/tests/arrivals.rs` pin all four equivalences.

use std::borrow::Cow;

use crate::cluster::trace::{lognormal_len, Request, TraceConfig};
use crate::util::rng::Rng;
use crate::{Error, Result};

/// A pull-based, infallible stream of requests in non-decreasing
/// arrival order.
///
/// Contract:
/// - `next()` yields requests with non-decreasing `arrive_s`; all
///   arrival times are finite. (Constructor validation plus process
///   math guarantee this; `DagSim` still checks defensively and
///   reports `Error::Config` on violation.)
/// - `peek_arrival()` returns the `arrive_s` of the request the next
///   `next()` call will yield, without consuming it — the hook that
///   lets an event loop decide whether the stream or the event queue
///   fires first, with O(1) lookahead.
/// - Exhaustion is permanent: once `next()` returns `None`, both
///   methods return `None` forever.
///
/// The trait is object-safe; `DagSim::run_stream` takes
/// `&mut dyn ArrivalProcess`.
pub trait ArrivalProcess: Iterator<Item = Request> {
    /// Arrival time of the next request, without consuming it.
    fn peek_arrival(&mut self) -> Option<f64>;
}

impl<P: ArrivalProcess + ?Sized> ArrivalProcess for &mut P {
    fn peek_arrival(&mut self) -> Option<f64> {
        (**self).peek_arrival()
    }
}

/// Lognormal length marginals shared by every synthetic process —
/// the same clamps ([8, 32768] prompt / [1, 16384] output tokens) and
/// draw order (ISL before OSL) as `trace::generate`, so equal RNG
/// states produce equal requests.
#[derive(Debug, Clone, Copy)]
struct Lengths {
    isl_mean: u64,
    osl_mean: u64,
    sigma: f64,
}

impl Lengths {
    fn of(cfg: &TraceConfig) -> Lengths {
        Lengths {
            isl_mean: cfg.isl_mean,
            osl_mean: cfg.osl_mean,
            sigma: cfg.sigma,
        }
    }

    fn request(&self, rng: &mut Rng, id: u64, arrive_s: f64) -> Request {
        Request {
            id,
            arrive_s,
            isl: lognormal_len(rng, self.isl_mean, self.sigma, 8, 32_768),
            osl: lognormal_len(rng, self.osl_mean, self.sigma, 1, 16_384),
            pre_s: 0.0,
            post_s: 0.0,
        }
    }
}

/// Implements `Iterator` + `ArrivalProcess` on top of a one-slot
/// `pending` buffer and a private `gen_next()` — peeking generates at
/// most one request ahead, keeping lookahead O(1).
macro_rules! impl_arrival_process {
    ($ty:ty) => {
        impl Iterator for $ty {
            type Item = Request;

            fn next(&mut self) -> Option<Request> {
                match self.pending.take() {
                    Some(r) => Some(r),
                    None => self.gen_next(),
                }
            }
        }

        impl ArrivalProcess for $ty {
            fn peek_arrival(&mut self) -> Option<f64> {
                if self.pending.is_none() {
                    self.pending = self.gen_next();
                }
                self.pending.as_ref().map(|r| r.arrive_s)
            }
        }
    };
}

/// Homogeneous Poisson arrivals with lognormal lengths — the streaming
/// twin of [`trace::generate`](crate::cluster::trace::generate),
/// bit-for-bit: `Poisson::new(&cfg)?.collect::<Vec<_>>()` equals
/// `generate(&cfg)` exactly (pinned by a golden test).
pub struct Poisson {
    rng: Rng,
    rate: f64,
    lens: Lengths,
    t: f64,
    next_id: u64,
    remaining: usize,
    pending: Option<Request>,
}

impl Poisson {
    pub fn new(cfg: &TraceConfig) -> Result<Poisson> {
        cfg.validate()?;
        Ok(Poisson {
            rng: Rng::new(cfg.seed),
            rate: cfg.rate,
            lens: Lengths::of(cfg),
            t: 0.0,
            next_id: 0,
            remaining: cfg.n_requests,
            pending: None,
        })
    }

    fn gen_next(&mut self) -> Option<Request> {
        if self.remaining == 0 {
            return None;
        }
        self.remaining -= 1;
        self.t += self.rng.exp(self.rate);
        let id = self.next_id;
        self.next_id += 1;
        Some(Lengths::request(&self.lens, &mut self.rng, id, self.t))
    }
}

impl_arrival_process!(Poisson);

/// Non-homogeneous Poisson arrivals whose rate follows a sinusoidal
/// 24h template: `rate(t) = base * (1 + amplitude * sin(2π (t + phase)
/// / period))`. Sampled exactly by Lewis–Shedler thinning against the
/// peak rate `base * (1 + amplitude)`, so the emitted point process has
/// the true time-varying intensity — not a staircase approximation.
pub struct Diurnal {
    rng: Rng,
    base_rate: f64,
    amplitude: f64,
    period_s: f64,
    phase_s: f64,
    rate_max: f64,
    lens: Lengths,
    t: f64,
    next_id: u64,
    remaining: usize,
    pending: Option<Request>,
}

impl Diurnal {
    /// Seconds in the canonical diurnal period.
    pub const DAY_S: f64 = 86_400.0;

    /// Full-knob constructor. `amplitude` must sit in `[0, 1)` so the
    /// instantaneous rate stays strictly positive; `period_s > 0`;
    /// `phase_s` finite (shifts where in the cycle `t = 0` lands).
    pub fn new(cfg: &TraceConfig, amplitude: f64, period_s: f64, phase_s: f64) -> Result<Diurnal> {
        cfg.validate()?;
        if !amplitude.is_finite() || !(0.0..1.0).contains(&amplitude) {
            return Err(Error::Config(format!(
                "diurnal amplitude must be in [0, 1), got {amplitude}"
            )));
        }
        if !period_s.is_finite() || period_s <= 0.0 {
            return Err(Error::Config(format!(
                "diurnal period must be finite and > 0, got {period_s}"
            )));
        }
        if !phase_s.is_finite() {
            return Err(Error::Config(format!(
                "diurnal phase must be finite, got {phase_s}"
            )));
        }
        Ok(Diurnal {
            rng: Rng::new(cfg.seed),
            base_rate: cfg.rate,
            amplitude,
            period_s,
            phase_s,
            rate_max: cfg.rate * (1.0 + amplitude),
            lens: Lengths::of(cfg),
            t: 0.0,
            next_id: 0,
            remaining: cfg.n_requests,
            pending: None,
        })
    }

    /// The common case: a 24-hour sinusoid starting at the mean rate.
    pub fn daily(cfg: &TraceConfig, amplitude: f64) -> Result<Diurnal> {
        Diurnal::new(cfg, amplitude, Diurnal::DAY_S, 0.0)
    }

    /// Instantaneous arrival rate at absolute time `t`.
    pub fn rate_at(&self, t: f64) -> f64 {
        self.base_rate
            * (1.0
                + self.amplitude
                    * (std::f64::consts::TAU * (t + self.phase_s) / self.period_s).sin())
    }

    fn gen_next(&mut self) -> Option<Request> {
        if self.remaining == 0 {
            return None;
        }
        self.remaining -= 1;
        // Thinning: candidate points at the peak rate, accepted with
        // probability rate(t)/rate_max.
        loop {
            self.t += self.rng.exp(self.rate_max);
            if self.rng.f64() * self.rate_max <= self.rate_at(self.t) {
                break;
            }
        }
        let id = self.next_id;
        self.next_id += 1;
        Some(Lengths::request(&self.lens, &mut self.rng, id, self.t))
    }
}

impl_arrival_process!(Diurnal);

/// One rate spike in a [`FlashCrowd`] schedule: the arrival rate is
/// multiplied by `mult` for `dur_s` seconds starting at `at_s`.
#[derive(Debug, Clone, Copy)]
pub struct Spike {
    pub at_s: f64,
    pub dur_s: f64,
    pub mult: f64,
}

enum Schedule {
    /// Sorted by `at_s`; overlapping spikes apply sequentially (the
    /// earlier spike runs to its end before the later one is
    /// consulted), never multiplicatively.
    Explicit(Vec<Spike>),
    Periodic { every_s: f64, dur_s: f64, mult: f64 },
}

/// Baseline Poisson traffic plus scheduled rate spikes — the
/// flash-crowd template. Piecewise-constant rates are sampled
/// *exactly*: a gap drawn at rate λ that would cross a rate boundary
/// is discarded and redrawn from the boundary (valid by memorylessness
/// of the exponential), so spike edges are sharp — unlike the
/// documented drift in [`trace::bursty`](crate::cluster::trace::bursty).
pub struct FlashCrowd {
    rng: Rng,
    rate: f64,
    lens: Lengths,
    sched: Schedule,
    /// Cursor into `Schedule::Explicit` — spikes before it are in the
    /// past. Makes `segment_at` O(1) amortized over a whole run.
    next_spike: usize,
    t: f64,
    next_id: u64,
    remaining: usize,
    pending: Option<Request>,
}

impl FlashCrowd {
    /// Explicit spike schedule. Spikes are sorted by start time; each
    /// needs `at_s >= 0`, `dur_s > 0`, `mult > 0`, all finite.
    pub fn new(cfg: &TraceConfig, mut spikes: Vec<Spike>) -> Result<FlashCrowd> {
        cfg.validate()?;
        for s in &spikes {
            if !s.at_s.is_finite() || s.at_s < 0.0 {
                return Err(Error::Config(format!(
                    "spike start must be finite and >= 0, got {}",
                    s.at_s
                )));
            }
            if !s.dur_s.is_finite() || s.dur_s <= 0.0 {
                return Err(Error::Config(format!(
                    "spike duration must be finite and > 0, got {}",
                    s.dur_s
                )));
            }
            if !s.mult.is_finite() || s.mult <= 0.0 {
                return Err(Error::Config(format!(
                    "spike multiplier must be finite and > 0, got {}",
                    s.mult
                )));
            }
        }
        spikes.sort_by(|a, b| a.at_s.total_cmp(&b.at_s));
        Ok(FlashCrowd::build(cfg, Schedule::Explicit(spikes)))
    }

    /// A spike of `mult`× lasting `dur_s` at the start of every
    /// `every_s`-second cycle, forever.
    pub fn periodic(cfg: &TraceConfig, every_s: f64, dur_s: f64, mult: f64) -> Result<FlashCrowd> {
        cfg.validate()?;
        if !every_s.is_finite() || every_s <= 0.0 {
            return Err(Error::Config(format!(
                "spike interval must be finite and > 0, got {every_s}"
            )));
        }
        if !dur_s.is_finite() || dur_s <= 0.0 || dur_s > every_s {
            return Err(Error::Config(format!(
                "spike duration must be in (0, every_s], got {dur_s}"
            )));
        }
        if !mult.is_finite() || mult <= 0.0 {
            return Err(Error::Config(format!(
                "spike multiplier must be finite and > 0, got {mult}"
            )));
        }
        Ok(FlashCrowd::build(
            cfg,
            Schedule::Periodic {
                every_s,
                dur_s,
                mult,
            },
        ))
    }

    fn build(cfg: &TraceConfig, sched: Schedule) -> FlashCrowd {
        FlashCrowd {
            rng: Rng::new(cfg.seed),
            rate: cfg.rate,
            lens: Lengths::of(cfg),
            sched,
            next_spike: 0,
            t: 0.0,
            next_id: 0,
            remaining: cfg.n_requests,
            pending: None,
        }
    }

    /// The constant-rate segment containing `t`: (rate, segment end).
    fn segment_at(&mut self, t: f64) -> (f64, f64) {
        match &self.sched {
            Schedule::Periodic {
                every_s,
                dur_s,
                mult,
            } => {
                let phase = t.rem_euclid(*every_s);
                let start = t - phase;
                if phase < *dur_s {
                    (self.rate * mult, start + dur_s)
                } else {
                    (self.rate, start + every_s)
                }
            }
            Schedule::Explicit(spikes) => {
                while let Some(s) = spikes.get(self.next_spike) {
                    if t < s.at_s {
                        return (self.rate, s.at_s);
                    }
                    if t < s.at_s + s.dur_s {
                        return (self.rate * s.mult, s.at_s + s.dur_s);
                    }
                    self.next_spike += 1;
                }
                (self.rate, f64::INFINITY)
            }
        }
    }

    fn gen_next(&mut self) -> Option<Request> {
        if self.remaining == 0 {
            return None;
        }
        self.remaining -= 1;
        loop {
            let (rate, boundary) = self.segment_at(self.t);
            let gap = self.rng.exp(rate);
            if self.t + gap <= boundary {
                self.t += gap;
                break;
            }
            // Gap crosses a rate boundary: restart from the boundary —
            // exact for exponential gaps (memorylessness).
            self.t = boundary;
        }
        let id = self.next_id;
        self.next_id += 1;
        Some(Lengths::request(&self.lens, &mut self.rng, id, self.t))
    }
}

impl_arrival_process!(FlashCrowd);

/// Square-wave rate modulation: the first `burst_s` seconds of every
/// `period_s` cycle run at `rate * mult`, the rest at `rate`.
///
/// Two modes:
/// - [`SquareWave::new`] — exact piecewise-constant semantics with
///   boundary-clipped sampling (sharp burst edges, bursts never
///   skipped).
/// - [`SquareWave::compat`] — bit-for-bit reproduction of
///   [`trace::bursty`](crate::cluster::trace::bursty), including its
///   documented drift (rate picked from the phase at the *previous*
///   arrival, gaps never clipped). Kept so existing benches and pinned
///   replay traces are stable across the API migration.
pub struct SquareWave {
    rng: Rng,
    rate: f64,
    mult: f64,
    period_s: f64,
    burst_s: f64,
    exact: bool,
    lens: Lengths,
    t: f64,
    next_id: u64,
    remaining: usize,
    pending: Option<Request>,
}

impl SquareWave {
    /// Exact piecewise-constant square wave.
    pub fn new(cfg: &TraceConfig, mult: f64, period_s: f64, burst_s: f64) -> Result<SquareWave> {
        SquareWave::build(cfg, mult, period_s, burst_s, true)
    }

    /// `trace::bursty`-compatible mode (bit-identical output, same
    /// seed XOR and RNG draw order).
    pub fn compat(cfg: &TraceConfig, mult: f64, period_s: f64, burst_s: f64) -> Result<SquareWave> {
        SquareWave::build(cfg, mult, period_s, burst_s, false)
    }

    fn build(
        cfg: &TraceConfig,
        mult: f64,
        period_s: f64,
        burst_s: f64,
        exact: bool,
    ) -> Result<SquareWave> {
        cfg.validate()?;
        if !mult.is_finite() || mult <= 0.0 {
            return Err(Error::Config(format!(
                "burst multiplier must be finite and > 0, got {mult}"
            )));
        }
        if !period_s.is_finite() || period_s <= 0.0 {
            return Err(Error::Config(format!(
                "burst period must be finite and > 0, got {period_s}"
            )));
        }
        if !burst_s.is_finite() || !(0.0..=period_s).contains(&burst_s) {
            return Err(Error::Config(format!(
                "burst length must be in [0, period], got {burst_s}"
            )));
        }
        Ok(SquareWave {
            rng: Rng::new(cfg.seed ^ 0xB525_7ABC),
            rate: cfg.rate,
            mult,
            period_s,
            burst_s,
            exact,
            lens: Lengths::of(cfg),
            t: 0.0,
            next_id: 0,
            remaining: cfg.n_requests,
            pending: None,
        })
    }

    fn gen_next(&mut self) -> Option<Request> {
        if self.remaining == 0 {
            return None;
        }
        self.remaining -= 1;
        if self.exact {
            loop {
                let phase = self.t.rem_euclid(self.period_s);
                let start = self.t - phase;
                let (rate, boundary) = if phase < self.burst_s {
                    (self.rate * self.mult, start + self.burst_s)
                } else {
                    (self.rate, start + self.period_s)
                };
                let gap = self.rng.exp(rate);
                if self.t + gap <= boundary {
                    self.t += gap;
                    break;
                }
                self.t = boundary;
            }
        } else {
            // bursty()'s historical sequence: rate from the phase at
            // the previous arrival, gap never clipped.
            let rate = if self.t % self.period_s < self.burst_s {
                self.rate * self.mult
            } else {
                self.rate
            };
            self.t += self.rng.exp(rate);
        }
        let id = self.next_id;
        self.next_id += 1;
        Some(Lengths::request(&self.lens, &mut self.rng, id, self.t))
    }
}

impl_arrival_process!(SquareWave);

/// The Figure-2 voice agent as a stream: Poisson base arrivals plus
/// STT/TTS stage latencies and a probabilistic search branch, drawn
/// from an independent stage RNG. Bit-identical to
/// [`trace::voice_agent`](crate::cluster::trace::voice_agent): the two
/// RNG streams are independent, so interleaving their draws
/// per-request reproduces the historical two-pass sequence exactly.
pub struct VoiceAgent {
    rng: Rng,
    stage_rng: Rng,
    rate: f64,
    lens: Lengths,
    t: f64,
    next_id: u64,
    remaining: usize,
    pending: Option<Request>,
}

impl VoiceAgent {
    pub fn new(cfg: &TraceConfig) -> Result<VoiceAgent> {
        cfg.validate()?;
        Ok(VoiceAgent {
            rng: Rng::new(cfg.seed),
            stage_rng: Rng::new(cfg.seed ^ 0x5052_4F42),
            rate: cfg.rate,
            lens: Lengths::of(cfg),
            t: 0.0,
            next_id: 0,
            remaining: cfg.n_requests,
            pending: None,
        })
    }

    fn gen_next(&mut self) -> Option<Request> {
        if self.remaining == 0 {
            return None;
        }
        self.remaining -= 1;
        self.t += self.rng.exp(self.rate);
        let id = self.next_id;
        self.next_id += 1;
        let mut r = Lengths::request(&self.lens, &mut self.rng, id, self.t);
        r.pre_s = self.stage_rng.lognormal(-0.6, 0.4).clamp(0.1, 5.0);
        r.post_s = self.stage_rng.lognormal(-1.2, 0.4).clamp(0.05, 2.0);
        if self.stage_rng.bool(0.35) {
            r.isl += 256;
            r.pre_s += self.stage_rng.lognormal(-1.0, 0.6).clamp(0.05, 3.0);
        }
        Some(r)
    }
}

impl_arrival_process!(VoiceAgent);

/// Adapter over a materialized trace — the back-compat bridge that
/// lets `DagSim::run(&[Request])` stay a thin wrapper around the
/// streaming path. Borrows when it can, owns when it must.
pub struct Replay<'a> {
    items: Cow<'a, [Request]>,
    idx: usize,
}

impl<'a> Replay<'a> {
    /// Replay a slice as-is (caller vouches for arrival order).
    pub fn new(items: &'a [Request]) -> Replay<'a> {
        Replay {
            items: Cow::Borrowed(items),
            idx: 0,
        }
    }

    /// Replay an owned trace (e.g. one sorted copy).
    pub fn from_vec(items: Vec<Request>) -> Replay<'static> {
        Replay {
            items: Cow::Owned(items),
            idx: 0,
        }
    }

    /// Replay a slice, stably sorting a copy by arrival time if it is
    /// not already non-decreasing — the old `run_controlled`
    /// tolerance for unsorted traces, preserved tie order included.
    pub fn ordered(items: &'a [Request]) -> Replay<'a> {
        if items.windows(2).all(|w| w[0].arrive_s <= w[1].arrive_s) {
            Replay::new(items)
        } else {
            let mut v = items.to_vec();
            v.sort_by(|a, b| a.arrive_s.total_cmp(&b.arrive_s));
            Replay::from_vec(v)
        }
    }

    /// Requests not yet yielded.
    pub fn remaining(&self) -> usize {
        self.items.len() - self.idx
    }
}

impl Iterator for Replay<'_> {
    type Item = Request;

    fn next(&mut self) -> Option<Request> {
        let r = self.items.get(self.idx).cloned();
        if r.is_some() {
            self.idx += 1;
        }
        r
    }
}

impl ArrivalProcess for Replay<'_> {
    fn peek_arrival(&mut self) -> Option<f64> {
        self.items.get(self.idx).map(|r| r.arrive_s)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::trace::{bursty, generate, voice_agent};

    fn cfg(n: usize) -> TraceConfig {
        TraceConfig {
            n_requests: n,
            rate: 6.0,
            isl_mean: 256,
            osl_mean: 64,
            sigma: 0.4,
            seed: 42,
        }
    }

    fn same_request(a: &Request, b: &Request) -> bool {
        a.id == b.id
            && a.arrive_s == b.arrive_s
            && a.isl == b.isl
            && a.osl == b.osl
            && a.pre_s == b.pre_s
            && a.post_s == b.post_s
    }

    #[test]
    fn poisson_matches_generate_bit_for_bit() {
        let c = cfg(3000);
        let streamed: Vec<Request> = Poisson::new(&c).unwrap().collect();
        let materialized = generate(&c);
        assert_eq!(streamed.len(), materialized.len());
        for (a, b) in streamed.iter().zip(&materialized) {
            assert!(same_request(a, b), "{a:?} vs {b:?}");
        }
    }

    #[test]
    fn square_wave_compat_matches_bursty_bit_for_bit() {
        let c = cfg(3000);
        let streamed: Vec<Request> = SquareWave::compat(&c, 8.0, 30.0, 8.0).unwrap().collect();
        let materialized = bursty(&c, 8.0, 30.0, 8.0);
        for (a, b) in streamed.iter().zip(&materialized) {
            assert!(same_request(a, b), "{a:?} vs {b:?}");
        }
        assert_eq!(streamed.len(), materialized.len());
    }

    #[test]
    fn voice_agent_matches_trace_voice_agent_bit_for_bit() {
        let c = cfg(2000);
        let streamed: Vec<Request> = VoiceAgent::new(&c).unwrap().collect();
        let materialized = voice_agent(&c);
        for (a, b) in streamed.iter().zip(&materialized) {
            assert!(same_request(a, b), "{a:?} vs {b:?}");
        }
        assert_eq!(streamed.len(), materialized.len());
    }

    #[test]
    fn golden_pinned_first_arrivals() {
        // Structural golden: the first arrivals of the compat processes
        // must equal the legacy generators *evaluated at the same
        // version*, and the sequences must be reproducible run-to-run.
        // (We pin against the legacy functions rather than hardcoded
        // floats so the test is robust to libm differences across
        // targets while still failing loudly if either side drifts.)
        let c = cfg(16);
        let p: Vec<f64> = Poisson::new(&c).unwrap().map(|r| r.arrive_s).collect();
        let g: Vec<f64> = generate(&c).iter().map(|r| r.arrive_s).collect();
        assert_eq!(p, g);
        let s: Vec<f64> = SquareWave::compat(&c, 5.0, 30.0, 6.0)
            .unwrap()
            .map(|r| r.arrive_s)
            .collect();
        let b: Vec<f64> = bursty(&c, 5.0, 30.0, 6.0)
            .iter()
            .map(|r| r.arrive_s)
            .collect();
        assert_eq!(s, b);
        // Monotone, strictly positive, finite — the trait contract.
        for w in p.windows(2) {
            assert!(w[0] <= w[1]);
        }
        assert!(p.iter().all(|t| t.is_finite() && *t > 0.0));
    }

    #[test]
    fn peek_is_stable_and_nonconsuming() {
        let c = cfg(5);
        let mut p = Poisson::new(&c).unwrap();
        let t0 = p.peek_arrival().unwrap();
        assert_eq!(p.peek_arrival(), Some(t0));
        let r = p.next().unwrap();
        assert_eq!(r.arrive_s, t0);
        let t1 = p.peek_arrival().unwrap();
        assert!(t1 >= t0);
        // Drain and verify permanent exhaustion.
        assert_eq!(p.by_ref().count(), 4);
        assert_eq!(p.peek_arrival(), None);
        assert!(p.next().is_none());
    }

    #[test]
    fn diurnal_is_deterministic_and_modulated() {
        let c = TraceConfig {
            n_requests: 20_000,
            rate: 10.0,
            sigma: 0.0,
            ..cfg(0)
        };
        let a: Vec<f64> = Diurnal::new(&c, 0.8, 1000.0, 0.0)
            .unwrap()
            .map(|r| r.arrive_s)
            .collect();
        let b: Vec<f64> = Diurnal::new(&c, 0.8, 1000.0, 0.0)
            .unwrap()
            .map(|r| r.arrive_s)
            .collect();
        assert_eq!(a, b);
        for w in a.windows(2) {
            assert!(w[0] <= w[1]);
        }
        // Density at the sinusoid's crest (phase ~ period/4) must beat
        // the trough (~ 3*period/4): count arrivals by cycle quarter.
        let mut quarters = [0usize; 4];
        for t in &a {
            quarters[((t.rem_euclid(1000.0) / 250.0) as usize).min(3)] += 1;
        }
        assert!(
            quarters[0] as f64 > 2.0 * quarters[2] as f64,
            "quarters={quarters:?}"
        );
    }

    #[test]
    fn diurnal_rejects_bad_knobs() {
        let c = cfg(10);
        assert!(matches!(
            Diurnal::new(&c, 1.0, 100.0, 0.0),
            Err(Error::Config(_))
        ));
        assert!(matches!(
            Diurnal::new(&c, -0.1, 100.0, 0.0),
            Err(Error::Config(_))
        ));
        assert!(matches!(
            Diurnal::new(&c, 0.5, 0.0, 0.0),
            Err(Error::Config(_))
        ));
        assert!(matches!(
            Diurnal::new(&c, 0.5, 100.0, f64::NAN),
            Err(Error::Config(_))
        ));
        let bad_rate = TraceConfig {
            rate: 0.0,
            ..cfg(10)
        };
        assert!(matches!(
            Diurnal::daily(&bad_rate, 0.5),
            Err(Error::Config(_))
        ));
    }

    #[test]
    fn flash_crowd_spikes_concentrate_arrivals() {
        let c = TraceConfig {
            n_requests: 8000,
            rate: 4.0,
            sigma: 0.0,
            ..cfg(0)
        };
        let spikes = vec![Spike {
            at_s: 100.0,
            dur_s: 50.0,
            mult: 20.0,
        }];
        let arr: Vec<f64> = FlashCrowd::new(&c, spikes)
            .unwrap()
            .map(|r| r.arrive_s)
            .collect();
        for w in arr.windows(2) {
            assert!(w[0] <= w[1]);
        }
        let in_spike = arr
            .iter()
            .filter(|t| (100.0..150.0).contains(*t))
            .count() as f64;
        let before = arr.iter().filter(|t| **t < 100.0).count() as f64;
        // 20x rate over 50 s vs 4/s over the first 100 s.
        assert!(
            in_spike / 50.0 > 5.0 * (before / 100.0),
            "in={in_spike} before={before}"
        );
    }

    #[test]
    fn flash_crowd_periodic_determinism_and_validation() {
        let c = cfg(2000);
        let a: Vec<f64> = FlashCrowd::periodic(&c, 60.0, 10.0, 6.0)
            .unwrap()
            .map(|r| r.arrive_s)
            .collect();
        let b: Vec<f64> = FlashCrowd::periodic(&c, 60.0, 10.0, 6.0)
            .unwrap()
            .map(|r| r.arrive_s)
            .collect();
        assert_eq!(a, b);
        assert!(matches!(
            FlashCrowd::periodic(&c, 0.0, 10.0, 6.0),
            Err(Error::Config(_))
        ));
        assert!(matches!(
            FlashCrowd::periodic(&c, 60.0, 61.0, 6.0),
            Err(Error::Config(_))
        ));
        assert!(matches!(
            FlashCrowd::periodic(&c, 60.0, 10.0, -1.0),
            Err(Error::Config(_))
        ));
        assert!(matches!(
            FlashCrowd::new(
                &c,
                vec![Spike {
                    at_s: f64::NAN,
                    dur_s: 1.0,
                    mult: 2.0
                }]
            ),
            Err(Error::Config(_))
        ));
    }

    #[test]
    fn square_wave_exact_mode_keeps_short_bursts() {
        // At a low base rate the compat mode routinely jumps short
        // bursts (the documented bursty() drift); exact mode resamples
        // at every boundary so burst windows always see the high rate.
        let c = TraceConfig {
            n_requests: 4000,
            rate: 0.5,
            sigma: 0.0,
            seed: 7,
            ..cfg(0)
        };
        let (mult, period, burst) = (40.0, 60.0, 2.0);
        let density = |arr: &[f64]| {
            let span = *arr.last().unwrap();
            let cycles = (span / period).floor().max(1.0);
            let in_burst = arr
                .iter()
                .filter(|t| t.rem_euclid(period) < burst)
                .count() as f64;
            in_burst / (cycles * burst)
        };
        let exact: Vec<f64> = SquareWave::new(&c, mult, period, burst)
            .unwrap()
            .map(|r| r.arrive_s)
            .collect();
        let compat: Vec<f64> = SquareWave::compat(&c, mult, period, burst)
            .unwrap()
            .map(|r| r.arrive_s)
            .collect();
        // Exact mode: in-burst density near rate*mult = 20/s.
        assert!(density(&exact) > 10.0, "exact density={}", density(&exact));
        // And clearly sharper than the drifted legacy sampling.
        assert!(
            density(&exact) > 1.5 * density(&compat),
            "exact={} compat={}",
            density(&exact),
            density(&compat)
        );
    }

    #[test]
    fn replay_adapts_slices_and_sorts_when_needed() {
        let c = cfg(50);
        let trace = generate(&c);
        let mut rp = Replay::new(&trace);
        assert_eq!(rp.peek_arrival(), Some(trace[0].arrive_s));
        assert_eq!(rp.remaining(), 50);
        let back: Vec<Request> = rp.collect();
        for (a, b) in back.iter().zip(&trace) {
            assert!(same_request(a, b));
        }

        let mut shuffled = trace.clone();
        shuffled.reverse();
        let ordered: Vec<f64> = Replay::ordered(&shuffled).map(|r| r.arrive_s).collect();
        for w in ordered.windows(2) {
            assert!(w[0] <= w[1]);
        }
        // Already-sorted input borrows (no copy) and yields as-is.
        let ordered2: Vec<f64> = Replay::ordered(&trace).map(|r| r.arrive_s).collect();
        let orig: Vec<f64> = trace.iter().map(|r| r.arrive_s).collect();
        assert_eq!(ordered2, orig);
    }

    #[test]
    fn processes_reject_invalid_trace_config() {
        let bad = TraceConfig {
            rate: f64::NAN,
            ..cfg(10)
        };
        assert!(matches!(Poisson::new(&bad), Err(Error::Config(_))));
        assert!(matches!(VoiceAgent::new(&bad), Err(Error::Config(_))));
        assert!(matches!(
            SquareWave::new(&bad, 2.0, 10.0, 2.0),
            Err(Error::Config(_))
        ));
        assert!(matches!(
            FlashCrowd::new(&bad, Vec::new()),
            Err(Error::Config(_))
        ));
    }
}
