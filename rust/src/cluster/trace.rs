//! Workload trace generation.
//!
//! The paper has no public trace (its evaluation uses a continuous
//! synthetic workload); we generate Poisson arrivals with lognormal
//! sequence-length marginals — the standard synthetic stand-in used by
//! serving papers — plus the voice-agent stage structure of Figure 2
//! (STT preprocessing and TTS postprocessing around the LLM, with a
//! probabilistic web-search loop).

use crate::util::rng::Rng;
use crate::{Error, Result};

/// One request in a trace.
#[derive(Debug, Clone)]
pub struct Request {
    pub id: u64,
    pub arrive_s: f64,
    /// Prompt tokens.
    pub isl: u64,
    /// Tokens to generate.
    pub osl: u64,
    /// CPU-side preprocessing before prefill (e.g. STT), seconds.
    pub pre_s: f64,
    /// CPU-side postprocessing after last token (e.g. TTS), seconds.
    pub post_s: f64,
}

/// Trace generator parameters.
#[derive(Debug, Clone)]
pub struct TraceConfig {
    pub n_requests: usize,
    /// Mean arrival rate, requests/second (Poisson).
    pub rate: f64,
    pub isl_mean: u64,
    pub osl_mean: u64,
    /// Lognormal sigma for length dispersion (0 = constant lengths).
    pub sigma: f64,
    pub seed: u64,
}

impl Default for TraceConfig {
    fn default() -> Self {
        TraceConfig {
            n_requests: 256,
            rate: 8.0,
            isl_mean: 512,
            osl_mean: 128,
            sigma: 0.4,
            seed: 0,
        }
    }
}

impl TraceConfig {
    /// Validated builder entry point — the preferred constructor for
    /// code that takes rates/sigmas from user input (CLI flags, config
    /// files). Field-struct construction stays available for static
    /// in-repo configs.
    pub fn builder() -> TraceConfigBuilder {
        TraceConfigBuilder {
            cfg: TraceConfig::default(),
        }
    }

    /// Static-first validation (consistent with the plan analyzer's
    /// AH0xx philosophy): reject non-finite/non-positive rates and
    /// garbage dispersion *before* any generator silently emits NaN
    /// arrival times or degenerate lengths.
    pub fn validate(&self) -> Result<()> {
        if !self.rate.is_finite() || self.rate <= 0.0 {
            return Err(Error::Config(format!(
                "arrival rate must be finite and > 0, got {}",
                self.rate
            )));
        }
        if !self.sigma.is_finite() || self.sigma < 0.0 {
            return Err(Error::Config(format!(
                "length sigma must be finite and >= 0, got {}",
                self.sigma
            )));
        }
        if self.isl_mean == 0 || self.osl_mean == 0 {
            return Err(Error::Config(
                "isl_mean/osl_mean must be >= 1 token".into(),
            ));
        }
        Ok(())
    }
}

/// Builder for [`TraceConfig`] whose `build()` runs
/// [`TraceConfig::validate`] — malformed knobs surface as typed
/// [`Error::Config`] instead of generating garbage traces.
#[derive(Debug, Clone)]
pub struct TraceConfigBuilder {
    cfg: TraceConfig,
}

impl TraceConfigBuilder {
    pub fn n_requests(mut self, n: usize) -> Self {
        self.cfg.n_requests = n;
        self
    }

    pub fn rate(mut self, rate: f64) -> Self {
        self.cfg.rate = rate;
        self
    }

    pub fn isl_mean(mut self, isl: u64) -> Self {
        self.cfg.isl_mean = isl;
        self
    }

    pub fn osl_mean(mut self, osl: u64) -> Self {
        self.cfg.osl_mean = osl;
        self
    }

    pub fn sigma(mut self, sigma: f64) -> Self {
        self.cfg.sigma = sigma;
        self
    }

    pub fn seed(mut self, seed: u64) -> Self {
        self.cfg.seed = seed;
        self
    }

    pub fn build(self) -> Result<TraceConfig> {
        self.cfg.validate()?;
        Ok(self.cfg)
    }
}

pub(crate) fn lognormal_len(rng: &mut Rng, mean: u64, sigma: f64, lo: u64, hi: u64) -> u64 {
    if sigma == 0.0 {
        return mean.clamp(lo, hi);
    }
    // Choose mu so the lognormal's mean equals `mean`.
    let mu = (mean as f64).ln() - sigma * sigma / 2.0;
    (rng.lognormal(mu, sigma).round() as u64).clamp(lo, hi)
}

/// Poisson arrivals with lognormal lengths.
///
/// Materializes the whole trace up front. Binaries and new call sites
/// should prefer the streaming equivalent,
/// [`crate::cluster::arrivals::Poisson`], which emits the exact
/// same request sequence (same seed, same RNG draw order) without the
/// O(n) allocation; this function remains the slice-API anchor the
/// replay-equivalence suite pins against.
pub fn generate(cfg: &TraceConfig) -> Vec<Request> {
    let mut rng = Rng::new(cfg.seed);
    let mut t = 0.0;
    (0..cfg.n_requests as u64)
        .map(|id| {
            t += rng.exp(cfg.rate);
            Request {
                id,
                arrive_s: t,
                isl: lognormal_len(&mut rng, cfg.isl_mean, cfg.sigma, 8, 32_768),
                osl: lognormal_len(&mut rng, cfg.osl_mean, cfg.sigma, 1, 16_384),
                pre_s: 0.0,
                post_s: 0.0,
            }
        })
        .collect()
}

/// Piecewise-Poisson arrivals with square-wave rate modulation: the
/// first `burst_s` seconds of every `period_s` run at
/// `cfg.rate * burst_mult`, the rest at `cfg.rate` — the diurnal /
/// flash-crowd load swings the orchestration loop must absorb.
///
/// **Known semantic drift** (kept bit-for-bit for replay stability;
/// see `cluster::arrivals::SquareWave` for both modes): the rate for
/// each gap is chosen from the phase at the *previous* arrival, and the
/// drawn gap is never clipped at the phase boundary. Gaps therefore
/// bleed across phase edges — an off-phase arrival just before a burst
/// samples at the base rate and can jump the entire burst, so at low
/// base rates short bursts are skipped outright, and burst edges are
/// softened by one mean gap on each side. The exact piecewise-constant
/// semantics (memoryless resampling at every boundary) are implemented
/// by `SquareWave::new`; `SquareWave::compat` reproduces *this*
/// function's sequence bit-for-bit, which a golden test pins.
pub fn bursty(cfg: &TraceConfig, burst_mult: f64, period_s: f64, burst_s: f64) -> Vec<Request> {
    assert!(burst_mult > 0.0, "burst_mult must be positive");
    assert!(
        period_s > 0.0 && (0.0..=period_s).contains(&burst_s),
        "need 0 <= burst_s <= period_s"
    );
    let mut rng = Rng::new(cfg.seed ^ 0xB525_7ABC);
    let mut t = 0.0f64;
    (0..cfg.n_requests as u64)
        .map(|id| {
            let rate = if t % period_s < burst_s {
                cfg.rate * burst_mult
            } else {
                cfg.rate
            };
            t += rng.exp(rate);
            Request {
                id,
                arrive_s: t,
                isl: lognormal_len(&mut rng, cfg.isl_mean, cfg.sigma, 8, 32_768),
                osl: lognormal_len(&mut rng, cfg.osl_mean, cfg.sigma, 1, 16_384),
                pre_s: 0.0,
                post_s: 0.0,
            }
        })
        .collect()
}

/// The Figure-2 conversational voice agent: STT in front, TTS behind,
/// and an occasional extra LLM round-trip for web search (the feedback
/// loop is unrolled per §3.1's bounded-unrolling rule).
pub fn voice_agent(cfg: &TraceConfig) -> Vec<Request> {
    let mut rng = Rng::new(cfg.seed ^ 0x5052_4F42);
    generate(cfg)
        .into_iter()
        .map(|mut r| {
            // STT: ~real-time factor 0.1 on a ~6 s utterance, lognormal.
            r.pre_s = rng.lognormal(-0.6, 0.4).clamp(0.1, 5.0);
            // TTS synthesis of the reply.
            r.post_s = rng.lognormal(-1.2, 0.4).clamp(0.05, 2.0);
            if rng.bool(0.35) {
                // Search branch taken: extra context tokens + a tool wait
                // folded into preprocessing (network-bound, Table 2).
                r.isl += 256;
                r.pre_s += rng.lognormal(-1.0, 0.6).clamp(0.05, 3.0);
            }
            r
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn arrivals_monotone_and_rate_right() {
        let cfg = TraceConfig {
            n_requests: 2000,
            rate: 10.0,
            ..Default::default()
        };
        let t = generate(&cfg);
        assert_eq!(t.len(), 2000);
        for w in t.windows(2) {
            assert!(w[1].arrive_s >= w[0].arrive_s);
        }
        let span = t.last().unwrap().arrive_s;
        let rate = t.len() as f64 / span;
        assert!((rate - 10.0).abs() < 1.0, "rate={rate}");
    }

    #[test]
    fn lengths_near_means() {
        let cfg = TraceConfig {
            n_requests: 4000,
            ..Default::default()
        };
        let t = generate(&cfg);
        let isl: f64 = t.iter().map(|r| r.isl as f64).sum::<f64>() / t.len() as f64;
        let osl: f64 = t.iter().map(|r| r.osl as f64).sum::<f64>() / t.len() as f64;
        assert!((isl - 512.0).abs() < 40.0, "isl={isl}");
        assert!((osl - 128.0).abs() < 12.0, "osl={osl}");
    }

    #[test]
    fn sigma_zero_is_constant() {
        let cfg = TraceConfig {
            sigma: 0.0,
            n_requests: 10,
            ..Default::default()
        };
        assert!(generate(&cfg).iter().all(|r| r.isl == 512 && r.osl == 128));
    }

    #[test]
    fn deterministic_by_seed() {
        let cfg = TraceConfig::default();
        let a = generate(&cfg);
        let b = generate(&cfg);
        assert_eq!(a.len(), b.len());
        assert!(a
            .iter()
            .zip(&b)
            .all(|(x, y)| x.arrive_s == y.arrive_s && x.isl == y.isl));
    }

    #[test]
    fn bursty_rate_modulation_shows_up() {
        let cfg = TraceConfig {
            n_requests: 4000,
            rate: 2.0,
            sigma: 0.0,
            ..Default::default()
        };
        let t = bursty(&cfg, 10.0, 20.0, 5.0);
        assert_eq!(t.len(), 4000);
        for w in t.windows(2) {
            assert!(w[1].arrive_s >= w[0].arrive_s);
        }
        // Arrival density inside burst phases must clearly beat the
        // off-phase density (10x rate -> expect >> 2x observed).
        let span = t.last().unwrap().arrive_s;
        let in_burst = t
            .iter()
            .filter(|r| r.arrive_s % 20.0 < 5.0)
            .count() as f64;
        let out_burst = t.len() as f64 - in_burst;
        let burst_time: f64 = (span / 20.0).floor() * 5.0 + (span % 20.0).min(5.0);
        let off_time = span - burst_time;
        let density_ratio = (in_burst / burst_time) / (out_burst / off_time);
        assert!(density_ratio > 2.0, "ratio={density_ratio}");
    }

    #[test]
    fn bursty_deterministic_by_seed() {
        let cfg = TraceConfig::default();
        let a = bursty(&cfg, 5.0, 30.0, 6.0);
        let b = bursty(&cfg, 5.0, 30.0, 6.0);
        assert!(a
            .iter()
            .zip(&b)
            .all(|(x, y)| x.arrive_s == y.arrive_s && x.isl == y.isl));
    }

    #[test]
    fn builder_validates_knobs() {
        let ok = TraceConfig::builder()
            .n_requests(10)
            .rate(4.0)
            .sigma(0.2)
            .seed(3)
            .build()
            .unwrap();
        assert_eq!(ok.n_requests, 10);
        assert_eq!(ok.rate, 4.0);
        for bad in [
            TraceConfig::builder().rate(0.0).build(),
            TraceConfig::builder().rate(-2.0).build(),
            TraceConfig::builder().rate(f64::NAN).build(),
            TraceConfig::builder().rate(f64::INFINITY).build(),
            TraceConfig::builder().sigma(-0.1).build(),
            TraceConfig::builder().sigma(f64::NAN).build(),
            TraceConfig::builder().isl_mean(0).build(),
            TraceConfig::builder().osl_mean(0).build(),
        ] {
            assert!(matches!(bad, Err(Error::Config(_))), "{bad:?}");
        }
    }

    #[test]
    fn voice_agent_has_stages() {
        let t = voice_agent(&TraceConfig::default());
        assert!(t.iter().all(|r| r.pre_s > 0.0 && r.post_s > 0.0));
        // Some requests take the search branch (longer context).
        let searched = t.iter().filter(|r| r.isl > 512 + 128).count();
        assert!(searched > 0);
    }
}
