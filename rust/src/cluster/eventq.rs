//! Calendar-queue event scheduler for the DAG simulator's hot loop.
//!
//! `DagSim` historically ran on `BinaryHeap<Reverse<Event>>`: O(log n)
//! per operation with poor locality once millions of events churn
//! through. [`EventQueue`] keeps the exact same observable interface —
//! `push(t, item)` / `pop() -> (t, item)` in non-decreasing `(t,
//! insertion order)` — but spreads pending events across a ring of
//! time buckets (a calendar queue, Brown 1988): O(1) amortized push
//! and pop when the bucket width tracks the mean event spacing, which
//! the queue retunes itself from an EMA of popped inter-event gaps at
//! every window rebase.
//!
//! Ordering is a drop-in match for the old heap: each entry carries an
//! internal monotone sequence number, entries are bucketed by
//! `floor(t / width)`, buckets are min-heaps over `(t, seq)`, and a
//! bucket never holds an entry from an earlier window than the scan
//! cursor — so ties in `t` still pop FIFO and the stream of popped
//! events is bit-identical to `BinaryHeap<Reverse<(t, seq)>>` (a
//! randomized conformance test drives both side by side).

use std::cmp::Reverse;
use std::collections::BinaryHeap;

/// Ring size; a power of two so the modulo folds to a mask.
const N_BUCKETS: usize = 1024;
/// Starting bucket width (seconds) before any gap statistics exist.
const DEFAULT_WIDTH_S: f64 = 0.002;
/// Retuning clamp: never finer than 100 ns per bucket…
const MIN_WIDTH_S: f64 = 1e-7;
/// …never coarser than a minute.
const MAX_WIDTH_S: f64 = 60.0;

struct Entry<T> {
    t: f64,
    seq: u64,
    item: T,
}

impl<T> PartialEq for Entry<T> {
    fn eq(&self, other: &Self) -> bool {
        self.t.total_cmp(&other.t) == std::cmp::Ordering::Equal && self.seq == other.seq
    }
}

impl<T> Eq for Entry<T> {}

impl<T> PartialOrd for Entry<T> {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}

impl<T> Ord for Entry<T> {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        self.t.total_cmp(&other.t).then(self.seq.cmp(&other.seq))
    }
}

/// Self-tuning calendar queue; see module docs for the contract.
pub struct EventQueue<T> {
    /// Ring of per-bucket min-heaps. Slot `b % N_BUCKETS` holds only
    /// entries whose absolute bucket `b` lies in `[base, base + N)`.
    ring: Vec<BinaryHeap<Reverse<Entry<T>>>>,
    /// Entries beyond the current window, ordered globally.
    overflow: BinaryHeap<Reverse<Entry<T>>>,
    ring_len: usize,
    /// First absolute bucket of the current window.
    base: u64,
    /// Scan cursor (absolute bucket), `base <= cur < base + N`.
    cur: u64,
    width: f64,
    inv_width: f64,
    seq: u64,
    len: usize,
    high_watermark: usize,
    /// EMA of popped inter-event gaps, feeding width retuning.
    ema_gap: f64,
    last_pop_t: f64,
    pops: u64,
}

impl<T> Default for EventQueue<T> {
    fn default() -> Self {
        EventQueue::new()
    }
}

impl<T> EventQueue<T> {
    pub fn new() -> EventQueue<T> {
        EventQueue {
            ring: (0..N_BUCKETS).map(|_| BinaryHeap::new()).collect(),
            overflow: BinaryHeap::new(),
            ring_len: 0,
            base: 0,
            cur: 0,
            width: DEFAULT_WIDTH_S,
            inv_width: 1.0 / DEFAULT_WIDTH_S,
            seq: 0,
            len: 0,
            high_watermark: 0,
            ema_gap: DEFAULT_WIDTH_S / 4.0,
            last_pop_t: 0.0,
            pops: 0,
        }
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Largest number of events ever simultaneously queued — the
    /// constant-memory evidence the streaming tests assert on.
    pub fn high_watermark(&self) -> usize {
        self.high_watermark
    }

    /// Empty the queue and reset tuning state (width, watermark, seq).
    pub fn clear(&mut self) {
        for b in &mut self.ring {
            b.clear();
        }
        self.overflow.clear();
        self.ring_len = 0;
        self.len = 0;
        self.base = 0;
        self.cur = 0;
        self.width = DEFAULT_WIDTH_S;
        self.inv_width = 1.0 / DEFAULT_WIDTH_S;
        self.seq = 0;
        self.high_watermark = 0;
        self.ema_gap = DEFAULT_WIDTH_S / 4.0;
        self.last_pop_t = 0.0;
        self.pops = 0;
    }

    /// Absolute bucket index for time `t` at the current width.
    /// (`as u64` saturates on overflow/∞, handled at rebase.)
    fn abs_bucket(&self, t: f64) -> u64 {
        if t <= 0.0 {
            0
        } else {
            (t * self.inv_width) as u64
        }
    }

    pub fn push(&mut self, t: f64, item: T) {
        let seq = self.seq;
        self.seq += 1;
        let entry = Reverse(Entry { t, seq, item });
        // Clamp past-times to the scan cursor: the cursor never moves
        // past a non-empty bucket, so a late push lands in the bucket
        // popped next and — because buckets heap-order by (t, seq) —
        // still pops in exact global order.
        let ab = self.abs_bucket(t).max(self.cur);
        if ab >= self.base.saturating_add(N_BUCKETS as u64) {
            self.overflow.push(entry);
        } else {
            self.ring[(ab % N_BUCKETS as u64) as usize].push(entry);
            self.ring_len += 1;
        }
        self.len += 1;
        if self.len > self.high_watermark {
            self.high_watermark = self.len;
        }
    }

    pub fn pop(&mut self) -> Option<(f64, T)> {
        if self.len == 0 {
            return None;
        }
        loop {
            if self.ring_len == 0 {
                self.rebase();
            }
            let slot = (self.cur % N_BUCKETS as u64) as usize;
            if let Some(Reverse(e)) = self.ring[slot].pop() {
                self.ring_len -= 1;
                self.len -= 1;
                if e.t.is_finite() {
                    if self.pops > 0 {
                        let gap = (e.t - self.last_pop_t).max(0.0);
                        self.ema_gap = 0.875 * self.ema_gap + 0.125 * gap;
                    }
                    self.last_pop_t = e.t;
                    self.pops += 1;
                }
                return Some((e.t, e.item));
            }
            self.cur = self.cur.saturating_add(1);
            if self.cur >= self.base.saturating_add(N_BUCKETS as u64) {
                self.rebase();
            }
        }
    }

    /// Ring exhausted: retune the bucket width to ~4 events per bucket
    /// (from the observed gap EMA), move the window to the earliest
    /// overflow entry, and drain every overflow entry that now fits.
    fn rebase(&mut self) {
        debug_assert_eq!(self.ring_len, 0);
        if self.pops > 4 {
            let w = (self.ema_gap * 4.0).clamp(MIN_WIDTH_S, MAX_WIDTH_S);
            if w.is_finite() && w > 0.0 {
                self.width = w;
                self.inv_width = 1.0 / w;
            }
        }
        let top_t = match self.overflow.peek() {
            Some(Reverse(e)) => e.t,
            None => {
                self.base = self.cur;
                return;
            }
        };
        let nb = self.abs_bucket(top_t);
        self.base = nb;
        self.cur = nb;
        let end = self.base.saturating_add(N_BUCKETS as u64);
        let mut moved = 0usize;
        while let Some(Reverse(e)) = self.overflow.peek() {
            let ab = self.abs_bucket(e.t).max(self.base);
            if ab >= end {
                break;
            }
            let Some(Reverse(e)) = self.overflow.pop() else {
                unreachable!()
            };
            self.ring[(ab % N_BUCKETS as u64) as usize].push(Reverse(e));
            moved += 1;
        }
        if moved == 0 {
            // Degenerate times (∞ / saturated buckets): force one
            // entry across so every rebase makes progress.
            if let Some(e) = self.overflow.pop() {
                self.ring[(self.base % N_BUCKETS as u64) as usize].push(e);
                moved = 1;
            }
        }
        self.ring_len += moved;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    /// Reference model: the exact structure `DagSim` used before —
    /// `BinaryHeap<Reverse<(t, seq)>>` with `total_cmp` ordering.
    struct RefQueue {
        heap: BinaryHeap<Reverse<Entry<u32>>>,
        seq: u64,
    }

    impl RefQueue {
        fn new() -> RefQueue {
            RefQueue {
                heap: BinaryHeap::new(),
                seq: 0,
            }
        }

        fn push(&mut self, t: f64, item: u32) {
            let seq = self.seq;
            self.seq += 1;
            self.heap.push(Reverse(Entry { t, seq, item }));
        }

        fn pop(&mut self) -> Option<(f64, u32)> {
            self.heap.pop().map(|Reverse(e)| (e.t, e.item))
        }
    }

    /// Drive both queues with an identical operation stream and demand
    /// bit-identical pops (same t AND same payload, so tie order in t
    /// must match too).
    fn conformance(seed: u64, ops: usize, gap_scale: f64, jumpy: bool) {
        let mut rng = Rng::new(seed);
        let mut q: EventQueue<u32> = EventQueue::new();
        let mut r = RefQueue::new();
        let mut now = 0.0f64;
        let mut payload = 0u32;
        for _ in 0..ops {
            let roll = rng.f64();
            if roll < 0.65 || q.is_empty() {
                let t = if jumpy && rng.bool(0.05) {
                    now + rng.f64() * gap_scale * 50_000.0
                } else if rng.bool(0.10) {
                    // Past push: schedule at/before the current time.
                    (now - rng.f64() * gap_scale).max(0.0)
                } else if rng.bool(0.15) {
                    // Exact tie with the current time.
                    now
                } else {
                    now + rng.f64() * gap_scale
                };
                q.push(t, payload);
                r.push(t, payload);
                payload += 1;
            } else {
                let a = q.pop();
                let b = r.pop();
                assert_eq!(a, b, "divergence at payload {payload}");
                if let Some((t, _)) = a {
                    now = now.max(t);
                }
            }
        }
        // Drain completely; order must stay identical.
        loop {
            let a = q.pop();
            let b = r.pop();
            assert_eq!(a, b);
            if a.is_none() {
                break;
            }
        }
        assert!(q.is_empty());
    }

    #[test]
    fn matches_binary_heap_clustered() {
        conformance(1, 20_000, 1e-4, false);
    }

    #[test]
    fn matches_binary_heap_spread() {
        conformance(2, 20_000, 10.0, false);
    }

    #[test]
    fn matches_binary_heap_with_jumps_across_windows() {
        conformance(3, 20_000, 0.01, true);
    }

    #[test]
    fn matches_binary_heap_many_seeds() {
        for seed in 10..26 {
            conformance(seed, 4000, 0.003, seed % 2 == 0);
        }
    }

    #[test]
    fn fifo_on_exact_time_ties() {
        let mut q: EventQueue<u32> = EventQueue::new();
        for i in 0..100 {
            q.push(1.5, i);
        }
        for i in 0..100 {
            assert_eq!(q.pop(), Some((1.5, i)));
        }
    }

    #[test]
    fn watermark_and_clear() {
        let mut q: EventQueue<u32> = EventQueue::new();
        for i in 0..50 {
            q.push(i as f64, i);
        }
        assert_eq!(q.len(), 50);
        assert_eq!(q.high_watermark(), 50);
        for _ in 0..30 {
            q.pop();
        }
        assert_eq!(q.high_watermark(), 50, "watermark is a high-water mark");
        q.clear();
        assert!(q.is_empty());
        assert_eq!(q.high_watermark(), 0);
        assert_eq!(q.pop(), None);
        q.push(0.25, 7);
        assert_eq!(q.pop(), Some((0.25, 7)));
    }

    #[test]
    fn survives_infinite_and_huge_times() {
        let mut q: EventQueue<u32> = EventQueue::new();
        q.push(f64::INFINITY, 1);
        q.push(1e300, 2);
        q.push(0.5, 3);
        assert_eq!(q.pop(), Some((0.5, 3)));
        assert_eq!(q.pop(), Some((1e300, 2)));
        assert_eq!(q.pop(), Some((f64::INFINITY, 1)));
        assert_eq!(q.pop(), None);
    }

    #[test]
    fn million_event_sweep_is_ordered() {
        // A cheap smoke test of the retuning path at scale: diurnal-ish
        // spacing (alternating dense and sparse phases).
        let mut rng = Rng::new(99);
        let mut q: EventQueue<u64> = EventQueue::new();
        let mut t = 0.0;
        let mut id = 0u64;
        for phase in 0..20 {
            let rate = if phase % 2 == 0 { 2000.0 } else { 5.0 };
            for _ in 0..5_000 {
                t += rng.exp(rate);
                q.push(t, id);
                id += 1;
            }
        }
        let mut last = -1.0;
        let mut n = 0;
        while let Some((t, _)) = q.pop() {
            assert!(t >= last);
            last = t;
            n += 1;
        }
        assert_eq!(n, 100_000);
    }
}
