//! Total-cost-of-ownership model (paper §5.1).
//!
//! The paper's operating-cost assumptions: hardware financed over a
//! 4-year amortization period at 8% interest; energy billed at max rated
//! TDP and $0.40/kWh; datacenter/colo fees and NRE excluded.
//!
//! Two operating-cost sources are supported because the paper's stated
//! formula does not exactly regenerate its own Table 5 column (its
//! derived $/hr exceeds the listed values for the high-end parts; see
//! EXPERIMENTS.md): [`OpexModel::PaperTable`] uses the listed numbers,
//! [`OpexModel::Derived`] uses the stated formula. Figures 8–9 default
//! to `Derived` — the stated formula is what recovers the paper's
//! headline ordering — and the benches print both for comparison.

use super::hardware::DeviceSpec;

/// Amortization assumptions from §5.1.
#[derive(Debug, Clone, Copy)]
pub struct FinanceTerms {
    /// Amortization period, years.
    pub years: f64,
    /// Annual interest rate (fraction).
    pub rate: f64,
    /// Energy price, $/kWh.
    pub usd_per_kwh: f64,
}

impl Default for FinanceTerms {
    fn default() -> Self {
        FinanceTerms {
            years: 4.0,
            rate: 0.08,
            usd_per_kwh: 0.40,
        }
    }
}

/// Which operating-cost number to use.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum OpexModel {
    /// Table 5's listed "Operating Cost ($/hr)".
    PaperTable,
    /// Derived from [`FinanceTerms`]: annuity-amortized capex + energy.
    Derived,
}

/// Annuity payment per hour for capex `price` under `terms`.
///
/// Standard annuity with monthly compounding:
/// `P · r_m / (1 - (1+r_m)^-n)` over `n = years·12` months.
pub fn capex_usd_per_hour(price: f64, terms: &FinanceTerms) -> f64 {
    let rm = terms.rate / 12.0;
    let n = terms.years * 12.0;
    let monthly = if rm == 0.0 {
        price / n
    } else {
        price * rm / (1.0 - (1.0 + rm).powf(-n))
    };
    monthly * 12.0 / 8760.0
}

/// Energy cost per hour at max rated TDP.
pub fn energy_usd_per_hour(tdp_w: f64, terms: &FinanceTerms) -> f64 {
    tdp_w / 1000.0 * terms.usd_per_kwh
}

/// Operating cost in $/hr for one device under the chosen model.
pub fn opex_usd_per_hour(d: &DeviceSpec, model: OpexModel, terms: &FinanceTerms) -> f64 {
    match model {
        OpexModel::PaperTable => d.paper_opex_usd_hr,
        OpexModel::Derived => {
            capex_usd_per_hour(d.price_usd, terms) + energy_usd_per_hour(d.tdp_w, terms)
        }
    }
}

/// A costed serving configuration: devices × hours → $.
#[derive(Debug, Clone)]
pub struct FleetCost {
    /// (device name, count, $/hr each).
    pub items: Vec<(String, u32, f64)>,
}

impl FleetCost {
    pub fn usd_per_hour(&self) -> f64 {
        self.items.iter().map(|(_, n, c)| *n as f64 * c).sum()
    }

    /// $ per 1M output tokens at the given aggregate token rate.
    pub fn usd_per_mtok(&self, tokens_per_s: f64) -> f64 {
        self.usd_per_hour() / 3600.0 / tokens_per_s * 1e6
    }
}

/// Table 5 regenerated: per-device derived vs listed operating cost.
#[derive(Debug, Clone)]
pub struct Table5Row {
    pub device: &'static str,
    pub price_usd: f64,
    pub mem_gb: f64,
    pub bw_gbps: f64,
    pub tflops_fp16: f64,
    pub paper_opex: f64,
    pub derived_capex_hr: f64,
    pub derived_energy_hr: f64,
    pub derived_opex: f64,
}

pub fn table5(terms: &FinanceTerms) -> Vec<Table5Row> {
    super::hardware::catalog()
        .iter()
        .map(|d| {
            let cap = capex_usd_per_hour(d.price_usd, terms);
            let en = energy_usd_per_hour(d.tdp_w, terms);
            Table5Row {
                device: d.name,
                price_usd: d.price_usd,
                mem_gb: d.mem_gb,
                bw_gbps: d.mem_bw_gbps,
                tflops_fp16: d.tflops_fp16,
                paper_opex: d.paper_opex_usd_hr,
                derived_capex_hr: cap,
                derived_energy_hr: en,
                derived_opex: cap + en,
            }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cost::hardware::by_name;

    #[test]
    fn annuity_no_interest_is_straight_line() {
        let terms = FinanceTerms {
            years: 4.0,
            rate: 0.0,
            usd_per_kwh: 0.0,
        };
        let hr = capex_usd_per_hour(35_040.0, &terms);
        // 35040 $ / (4y · 8760 h/y) = 1 $/h.
        assert!((hr - 1.0).abs() < 1e-9);
    }

    #[test]
    fn annuity_with_interest_exceeds_straight_line() {
        let terms = FinanceTerms::default();
        let straight = 10_000.0 / (4.0 * 8760.0);
        assert!(capex_usd_per_hour(10_000.0, &terms) > straight);
    }

    #[test]
    fn energy_h100() {
        // 700 W at $0.40/kWh = $0.28/hr.
        let terms = FinanceTerms::default();
        assert!((energy_usd_per_hour(700.0, &terms) - 0.28).abs() < 1e-9);
    }

    #[test]
    fn opex_models_diverge_on_high_end_parts() {
        // Documented discrepancy: the stated formula produces more than
        // the listed $/hr for H100/B200.
        let terms = FinanceTerms::default();
        let h100 = by_name("H100").unwrap();
        let derived = opex_usd_per_hour(&h100, OpexModel::Derived, &terms);
        let listed = opex_usd_per_hour(&h100, OpexModel::PaperTable, &terms);
        assert!(derived > listed);
    }

    #[test]
    fn opex_ordering_consistent_across_models() {
        // Both models must order the catalog identically (cheap -> dear);
        // the TCO *shape* is insensitive to the choice.
        let terms = FinanceTerms::default();
        let cat = crate::cost::hardware::catalog();
        let mut by_paper: Vec<&str> = cat.iter().map(|d| d.name).collect();
        let mut by_derived = by_paper.clone();
        by_paper.sort_by(|a, b| {
            let fa = by_name(a).unwrap().paper_opex_usd_hr;
            let fb = by_name(b).unwrap().paper_opex_usd_hr;
            fa.partial_cmp(&fb).unwrap()
        });
        by_derived.sort_by(|a, b| {
            let fa = opex_usd_per_hour(&by_name(a).unwrap(), OpexModel::Derived, &terms);
            let fb = opex_usd_per_hour(&by_name(b).unwrap(), OpexModel::Derived, &terms);
            fa.partial_cmp(&fb).unwrap()
        });
        // Identical except Gaudi3/MI300x which are within noise of each
        // other in the paper's table.
        fn norm(v: &[&str]) -> Vec<String> {
            v.iter()
                .map(|s| match *s {
                    "Gaudi3" | "MI300x" => "G3/MI3".to_string(),
                    other => other.to_string(),
                })
                .collect()
        }
        assert_eq!(norm(&by_paper), norm(&by_derived));
    }

    #[test]
    fn fleet_cost_math() {
        let fleet = FleetCost {
            items: vec![("H100".into(), 2, 0.60), ("Gaudi3".into(), 4, 0.49)],
        };
        assert!((fleet.usd_per_hour() - (1.2 + 1.96)).abs() < 1e-12);
        // 3.16 $/hr at 1000 tok/s -> $0.8778 per Mtok.
        let per_mtok = fleet.usd_per_mtok(1000.0);
        assert!((per_mtok - 3.16 / 3.6).abs() < 1e-9);
    }

    #[test]
    fn table5_has_all_devices() {
        let t = table5(&FinanceTerms::default());
        assert_eq!(t.len(), 6);
        assert!(t.iter().all(|r| r.derived_opex > 0.0));
    }
}
