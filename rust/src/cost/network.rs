//! Interconnect bandwidth model for disaggregated serving — Eqs. 1–2:
//!
//! ```text
//! BW_peak_egress  = KVCacheSize / (TTFT · N_prefill_gpu)    (1)
//! BW_peak_ingress = KVCacheSize / (TBT  · N_decode_gpu)     (2)
//! ```
//!
//! plus the paper's §5.2 observation that a 200–400 Gb/s link suffices
//! for KV transfer up to 32K-token prompts (validated in
//! `benches/bandwidth_model.rs`).

use super::kv::kv_cache_bytes;
use super::model_profile::ModelProfile;

/// Result of the Eq. 1–2 analysis for one configuration.
#[derive(Debug, Clone)]
pub struct BandwidthRequirement {
    pub kv_bytes: f64,
    /// Eq. 1, bytes/s that must leave each prefill GPU.
    pub peak_egress_bps: f64,
    /// Eq. 2, bytes/s that must arrive at each decode GPU.
    pub peak_ingress_bps: f64,
}

/// Compute Eqs. 1–2 for a single request (`batch` scales linearly).
pub fn bandwidth_requirement(
    m: &ModelProfile,
    isl: u64,
    batch: u64,
    ttft_s: f64,
    tbt_s: f64,
    n_prefill_gpu: u32,
    n_decode_gpu: u32,
) -> BandwidthRequirement {
    let kv = kv_cache_bytes(m, isl, batch);
    BandwidthRequirement {
        kv_bytes: kv,
        peak_egress_bps: kv / (ttft_s * n_prefill_gpu as f64),
        peak_ingress_bps: kv / (tbt_s * n_decode_gpu as f64),
    }
}

/// Convert bytes/s to Gbit/s (network links are quoted in Gb/s).
pub fn bps_to_gbit(bytes_per_s: f64) -> f64 {
    bytes_per_s * 8.0 / 1e9
}

/// Time to push a KV cache of `kv_bytes` over a `link_gbit` Gb/s link.
pub fn transfer_time_s(kv_bytes: f64, link_gbit: f64) -> f64 {
    kv_bytes * 8.0 / (link_gbit * 1e9)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cost::model_profile::{llama3_70b, llama3_8b};
    use crate::cost::Precision;

    #[test]
    fn paper_claim_32k_fits_in_400gbit() {
        // §5.2: "a 200–400 Gbps link is sufficient ... for input sequence
        // lengths up to 32K tokens" at interactive SLAs. TTFT for a 32K
        // prompt is well over a second on any evaluated device; use the
        // conservative 1 s with a single prefill GPU.
        for m in [llama3_8b(Precision::Fp16), llama3_70b(Precision::Fp16)] {
            let r = bandwidth_requirement(&m, 32_768, 1, 1.0, 0.02, 1, 1);
            let egress = bps_to_gbit(r.peak_egress_bps);
            assert!(egress <= 400.0, "{}: egress {egress} Gb/s", m.name);
        }
    }

    #[test]
    fn ingress_decreases_with_more_decode_gpus() {
        // §5.2: "while decode latency depends on the number of decoding
        // GPUs, the corresponding ingress bandwidth requirement decreases
        // inversely."
        let m = llama3_8b(Precision::Fp16);
        let r1 = bandwidth_requirement(&m, 4096, 1, 0.25, 0.02, 1, 1);
        let r4 = bandwidth_requirement(&m, 4096, 1, 0.25, 0.02, 1, 4);
        assert!((r4.peak_ingress_bps - r1.peak_ingress_bps / 4.0).abs() < 1.0);
    }

    #[test]
    fn batch_scales_linearly() {
        let m = llama3_8b(Precision::Fp16);
        let r1 = bandwidth_requirement(&m, 512, 1, 0.25, 0.02, 1, 1);
        let r8 = bandwidth_requirement(&m, 512, 8, 0.25, 0.02, 1, 1);
        assert!((r8.peak_egress_bps - 8.0 * r1.peak_egress_bps).abs() < 1.0);
    }

    #[test]
    fn transfer_time_roundtrip() {
        // 1 GB over 400 Gb/s = 20 ms.
        let t = transfer_time_s(1e9, 400.0);
        assert!((t - 0.02).abs() < 1e-9);
    }

    #[test]
    fn gbit_conversion() {
        assert_eq!(bps_to_gbit(1e9), 8.0);
    }
}
