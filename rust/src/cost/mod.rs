//! Cost modeling substrate (paper §3.1.1, §5.1–5.2).
//!
//! Everything the optimizer consumes is produced here:
//!
//! * [`hardware`] — the accelerator catalog (Table 5) and the marginal
//!   cost-efficiency analysis behind Figure 4;
//! * [`model_profile`] — LLaMA-3 architectural constants (Table 4) and
//!   analytic FLOP/byte counts for prefill and decode;
//! * [`roofline`] — the execution-time model `t_ij = max_r θ/perf + l +
//!   d + δ` with tensor/pipeline-parallel communication terms;
//! * [`tco`] — amortized capex + energy opex (§5.1's operating-cost
//!   assumptions) and TCO-benefit normalization;
//! * [`kv`] — KV-cache sizing (Eq. 3);
//! * [`network`] — peak egress/ingress bandwidth for disaggregated
//!   serving (Eqs. 1–2);
//! * [`workload`] — the qualitative workload radar profiles (Fig. 3 /
//!   Table 2) used to annotate IR nodes with resource vectors.

pub mod hardware;
pub mod kv;
pub mod model_profile;
pub mod network;
pub mod roofline;
pub mod tco;
pub mod workload;

/// The six hardware dimensions of §2.5.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Resource {
    /// Specialized high-FLOP compute (GPU/accelerator).
    HpCompute,
    /// Memory bandwidth (HBM GB/s).
    MemBandwidth,
    /// Network bandwidth across nodes/services.
    NetBandwidth,
    /// Total device/system memory capacity.
    MemCapacity,
    /// Persistent storage capacity.
    DiskCapacity,
    /// Scalar CPU compute (logic, parsing, orchestration).
    GpCompute,
}

impl Resource {
    pub const ALL: [Resource; 6] = [
        Resource::HpCompute,
        Resource::MemBandwidth,
        Resource::NetBandwidth,
        Resource::MemCapacity,
        Resource::DiskCapacity,
        Resource::GpCompute,
    ];

    pub fn name(&self) -> &'static str {
        match self {
            Resource::HpCompute => "hp_compute",
            Resource::MemBandwidth => "mem_bandwidth",
            Resource::NetBandwidth => "net_bandwidth",
            Resource::MemCapacity => "mem_capacity",
            Resource::DiskCapacity => "disk_capacity",
            Resource::GpCompute => "gp_compute",
        }
    }
}

/// A demand/usage vector over the six resources.
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub struct ResourceVec {
    pub hp_compute: f64,
    pub mem_bandwidth: f64,
    pub net_bandwidth: f64,
    pub mem_capacity: f64,
    pub disk_capacity: f64,
    pub gp_compute: f64,
}

impl ResourceVec {
    pub fn get(&self, r: Resource) -> f64 {
        match r {
            Resource::HpCompute => self.hp_compute,
            Resource::MemBandwidth => self.mem_bandwidth,
            Resource::NetBandwidth => self.net_bandwidth,
            Resource::MemCapacity => self.mem_capacity,
            Resource::DiskCapacity => self.disk_capacity,
            Resource::GpCompute => self.gp_compute,
        }
    }

    pub fn set(&mut self, r: Resource, v: f64) {
        match r {
            Resource::HpCompute => self.hp_compute = v,
            Resource::MemBandwidth => self.mem_bandwidth = v,
            Resource::NetBandwidth => self.net_bandwidth = v,
            Resource::MemCapacity => self.mem_capacity = v,
            Resource::DiskCapacity => self.disk_capacity = v,
            Resource::GpCompute => self.gp_compute = v,
        }
    }
}

/// Numeric precision of a model execution task (Table 4).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Precision {
    Fp16,
    Fp8,
}

impl Precision {
    pub fn bytes_per_elt(&self) -> f64 {
        match self {
            Precision::Fp16 => 2.0,
            Precision::Fp8 => 1.0,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Precision::Fp16 => "FP16",
            Precision::Fp8 => "FP8",
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn resource_vec_get_set_roundtrip() {
        let mut v = ResourceVec::default();
        for (i, r) in Resource::ALL.iter().enumerate() {
            v.set(*r, i as f64);
        }
        for (i, r) in Resource::ALL.iter().enumerate() {
            assert_eq!(v.get(*r), i as f64);
        }
    }

    #[test]
    fn precision_bytes() {
        assert_eq!(Precision::Fp16.bytes_per_elt(), 2.0);
        assert_eq!(Precision::Fp8.bytes_per_elt(), 1.0);
    }
}
