//! Model architecture profiles (paper Table 4) and analytic FLOP / byte
//! accounting for prefill and decode.
//!
//! The paper profiles "computational and memory demands based on model
//! size, sequence lengths, and architectural details" and feeds them to
//! the optimizer; this module is exactly that input. All FLOP values are
//! dense (the paper: "without accounting for sparsity").

use super::Precision;

/// Transformer architecture constants (LLaMA-3 herd, Meta AI [39]).
#[derive(Debug, Clone)]
pub struct ModelProfile {
    pub name: &'static str,
    pub params_b: f64, // billions
    pub n_layers: u32,
    pub d_model: u32,
    pub n_heads: u32,
    pub n_kv_heads: u32,
    pub d_ff: u32,
    pub vocab: u32,
    pub precision: Precision,
}

impl ModelProfile {
    pub fn head_dim(&self) -> u32 {
        self.d_model / self.n_heads
    }

    /// Parameter bytes at this precision.
    pub fn param_bytes(&self) -> f64 {
        self.params_b * 1e9 * self.precision.bytes_per_elt()
    }

    /// KV-cache bytes per token (Eq. 3 with ISL = BS = 1):
    /// `2 · N_layers · d_model · (N_kv / N_heads) · BPE`.
    pub fn kv_bytes_per_token(&self) -> f64 {
        2.0 * self.n_layers as f64
            * self.d_model as f64
            * (self.n_kv_heads as f64 / self.n_heads as f64)
            * self.precision.bytes_per_elt()
    }

    /// Dense FLOPs to prefill `seq` prompt tokens (batch 1).
    ///
    /// 2·P FLOPs per token for the weight GEMMs plus the quadratic
    /// attention term 4·L·d_model·S² (QKᵀ and PV, causal halving folded
    /// into the constant).
    pub fn prefill_flops(&self, seq: u64) -> f64 {
        let s = seq as f64;
        let weight = 2.0 * self.params_b * 1e9 * s;
        let attn = 2.0 * self.n_layers as f64 * self.d_model as f64 * s * s;
        weight + attn
    }

    /// Dense FLOPs for one decode step at context length `ctx`.
    pub fn decode_flops(&self, ctx: u64) -> f64 {
        let weight = 2.0 * self.params_b * 1e9;
        let attn = 4.0 * self.n_layers as f64 * self.d_model as f64 * ctx as f64
            * (self.n_kv_heads as f64 / self.n_heads as f64).max(0.25);
        weight + attn
    }

    /// HBM bytes moved for one decode step at context `ctx` and batch
    /// `batch`: every step streams the full weights once (shared across
    /// the batch) plus each sequence's KV cache.
    pub fn decode_bytes(&self, ctx: u64, batch: u64) -> f64 {
        self.param_bytes() + batch as f64 * self.kv_bytes_per_token() * ctx as f64
    }

    /// HBM bytes moved to prefill `seq` tokens (weights streamed once;
    /// activations assumed cache-resident — prefill is compute-bound).
    pub fn prefill_bytes(&self, seq: u64, batch: u64) -> f64 {
        self.param_bytes() + batch as f64 * self.kv_bytes_per_token() * seq as f64
    }

    /// Per-layer activation bytes crossing a tensor-parallel boundary for
    /// `tokens` tokens (two all-reduces of d_model activations per layer).
    pub fn tp_allreduce_bytes_per_layer(&self, tokens: u64) -> f64 {
        2.0 * tokens as f64 * self.d_model as f64 * self.precision.bytes_per_elt()
    }
}

/// Table 4: the four evaluated configurations.
pub fn table4() -> Vec<ModelProfile> {
    vec![
        llama3_8b(Precision::Fp16),
        llama3_8b(Precision::Fp8),
        llama3_70b(Precision::Fp16),
        llama3_70b(Precision::Fp8),
    ]
}

pub fn llama3_8b(precision: Precision) -> ModelProfile {
    ModelProfile {
        name: match precision {
            Precision::Fp16 => "Llama 3 - 8B - FP16",
            Precision::Fp8 => "Llama 3 - 8B - FP8",
        },
        params_b: 8.0,
        n_layers: 32,
        d_model: 4096,
        n_heads: 32,
        n_kv_heads: 8,
        d_ff: 14336,
        vocab: 128_256,
        precision,
    }
}

pub fn llama3_70b(precision: Precision) -> ModelProfile {
    ModelProfile {
        name: match precision {
            Precision::Fp16 => "Llama 3 - 70B - FP16",
            Precision::Fp8 => "Llama 3 - 70B - FP8",
        },
        params_b: 70.0,
        n_layers: 80,
        d_model: 8192,
        n_heads: 64,
        n_kv_heads: 8,
        d_ff: 28672,
        vocab: 128_256,
        precision,
    }
}

/// Look up by short name ("8b-fp16", "70b-fp8", ...).
pub fn by_short_name(s: &str) -> Option<ModelProfile> {
    match s.to_ascii_lowercase().as_str() {
        "8b-fp16" => Some(llama3_8b(Precision::Fp16)),
        "8b-fp8" => Some(llama3_8b(Precision::Fp8)),
        "70b-fp16" => Some(llama3_70b(Precision::Fp16)),
        "70b-fp8" => Some(llama3_70b(Precision::Fp8)),
        _ => None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table4_has_four_configs() {
        let t = table4();
        assert_eq!(t.len(), 4);
        assert_eq!(t.iter().filter(|m| m.precision == Precision::Fp8).count(), 2);
    }

    #[test]
    fn kv_bytes_per_token_8b_fp16() {
        // 2 * 32 * 4096 * (8/32) * 2 = 131072 bytes/token.
        let m = llama3_8b(Precision::Fp16);
        assert_eq!(m.kv_bytes_per_token(), 131_072.0);
    }

    #[test]
    fn kv_bytes_per_token_70b_fp16() {
        // 2 * 80 * 8192 * (8/64) * 2 = 327680 bytes/token.
        let m = llama3_70b(Precision::Fp16);
        assert_eq!(m.kv_bytes_per_token(), 327_680.0);
    }

    #[test]
    fn fp8_halves_kv_and_weights() {
        let a = llama3_8b(Precision::Fp16);
        let b = llama3_8b(Precision::Fp8);
        assert_eq!(a.kv_bytes_per_token(), 2.0 * b.kv_bytes_per_token());
        assert_eq!(a.param_bytes(), 2.0 * b.param_bytes());
    }

    #[test]
    fn prefill_flops_superlinear_in_seq() {
        // TTFT grows superlinearly with ISL (paper §5.2) because of the
        // quadratic attention term.
        let m = llama3_8b(Precision::Fp16);
        let f1 = m.prefill_flops(4096);
        let f2 = m.prefill_flops(8192);
        assert!(f2 > 2.0 * f1);
    }

    #[test]
    fn decode_flops_near_2p() {
        let m = llama3_70b(Precision::Fp16);
        let f = m.decode_flops(1);
        assert!((f - 2.0 * 70e9).abs() / (2.0 * 70e9) < 0.01);
    }

    #[test]
    fn decode_bytes_dominated_by_params_at_small_ctx() {
        let m = llama3_8b(Precision::Fp16);
        let b = m.decode_bytes(128, 1);
        assert!((b - m.param_bytes()).abs() / m.param_bytes() < 0.01);
    }

    #[test]
    fn short_names_resolve() {
        assert!(by_short_name("8b-fp16").is_some());
        assert!(by_short_name("70b-fp8").is_some());
        assert!(by_short_name("13b-fp16").is_none());
    }

    #[test]
    fn head_dim_is_128() {
        assert_eq!(llama3_8b(Precision::Fp16).head_dim(), 128);
        assert_eq!(llama3_70b(Precision::Fp16).head_dim(), 128);
    }
}
