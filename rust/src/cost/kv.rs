//! KV-cache sizing — Eq. 3 of the paper:
//!
//! ```text
//! KVCacheSize_peak = 2 · N_layers · d_model · (N_kv / N_heads) · ISL · BS · BPE
//! ```

use super::model_profile::ModelProfile;

/// Eq. 3: peak KV-cache bytes for a batch of sequences of length `isl`.
pub fn kv_cache_bytes(m: &ModelProfile, isl: u64, batch: u64) -> f64 {
    2.0 * m.n_layers as f64
        * m.d_model as f64
        * (m.n_kv_heads as f64 / m.n_heads as f64)
        * isl as f64
        * batch as f64
        * m.precision.bytes_per_elt()
}

/// KV bytes for a single sequence (`BS = 1`).
pub fn kv_cache_bytes_seq(m: &ModelProfile, isl: u64) -> f64 {
    kv_cache_bytes(m, isl, 1)
}

/// Maximum batch size whose KV fits in `budget_bytes` at context `ctx`.
pub fn max_batch_for_budget(m: &ModelProfile, ctx: u64, budget_bytes: f64) -> u64 {
    if budget_bytes <= 0.0 {
        return 0;
    }
    (budget_bytes / kv_cache_bytes_seq(m, ctx)).floor() as u64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cost::model_profile::{llama3_70b, llama3_8b};
    use crate::cost::Precision;

    #[test]
    fn eq3_8b_fp16_32k() {
        // 8B FP16 @ 32K tokens: 2·32·4096·(8/32)·32768·1·2 = 4.295 GB.
        let m = llama3_8b(Precision::Fp16);
        let gb = kv_cache_bytes_seq(&m, 32_768) / 1e9;
        assert!((gb - 4.295).abs() < 0.01, "gb={gb}");
    }

    #[test]
    fn eq3_70b_fp16_32k() {
        // 70B FP16 @ 32K: 2·80·8192·(8/64)·32768·2 = 10.74 GB.
        let m = llama3_70b(Precision::Fp16);
        let gb = kv_cache_bytes_seq(&m, 32_768) / 1e9;
        assert!((gb - 10.74).abs() < 0.02, "gb={gb}");
    }

    #[test]
    fn linear_in_batch_and_isl() {
        let m = llama3_8b(Precision::Fp16);
        assert_eq!(
            kv_cache_bytes(&m, 1024, 4),
            4.0 * kv_cache_bytes(&m, 1024, 1)
        );
        assert_eq!(
            kv_cache_bytes(&m, 2048, 1),
            2.0 * kv_cache_bytes(&m, 1024, 1)
        );
    }

    #[test]
    fn matches_profile_per_token() {
        let m = llama3_8b(Precision::Fp8);
        assert_eq!(kv_cache_bytes_seq(&m, 1), m.kv_bytes_per_token());
    }

    #[test]
    fn max_batch_budget() {
        let m = llama3_8b(Precision::Fp16);
        let per_seq = kv_cache_bytes_seq(&m, 4096);
        assert_eq!(max_batch_for_budget(&m, 4096, 10.0 * per_seq), 10);
        assert_eq!(max_batch_for_budget(&m, 4096, 0.5 * per_seq), 0);
        assert_eq!(max_batch_for_budget(&m, 4096, -1.0), 0);
    }
}
