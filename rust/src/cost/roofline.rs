//! Roofline execution-time model (paper §3.1.1, citing Williams et al.):
//!
//! ```text
//! t_ij = max_r( θ_ij^(r) / perf_j^(r) ) + l_i + d_ij + δ_ij
//! ```
//!
//! where the max is over compute vs memory-bandwidth rooflines, `l_i` is
//! static overhead (kernel launch, framework), `d_ij` is pipeline /
//! inter-device transfer cost, and `δ_ij` is tensor-parallel
//! synchronization (all-reduce) overhead.
//!
//! "Device-specific performance metrics ... are augmented by theoretical
//! roofline modeling to represent realistic performance boundaries"
//! (§5) — the efficiency factors below derate peak spec to achievable
//! rates; they are the calibration knobs of the reproduction.

use super::hardware::DeviceSpec;
use super::model_profile::ModelProfile;

/// Achievable-fraction-of-peak calibration (akin to the paper's
/// "performance model fit to real measurements").
#[derive(Debug, Clone, Copy)]
pub struct Efficiency {
    /// Model FLOPs utilization during prefill (compute-bound GEMMs).
    pub mfu_prefill: f64,
    /// FLOPs utilization during decode (GEMV-shaped, lower).
    pub mfu_decode: f64,
    /// Achievable fraction of peak HBM bandwidth.
    pub hbm_util: f64,
    /// Achievable fraction of interconnect bandwidth.
    pub net_util: f64,
    /// Static per-invocation overhead `l_i`, seconds (kernel launches,
    /// runtime dispatch) per prefill.
    pub prefill_overhead_s: f64,
    /// Static overhead per decode step, seconds.
    pub decode_overhead_s: f64,
    /// Per-hop link latency for collectives / pipeline stages, seconds.
    pub link_latency_s: f64,
    /// Fraction of device memory usable (allocator + fragmentation).
    pub mem_util: f64,
}

impl Default for Efficiency {
    fn default() -> Self {
        Efficiency {
            mfu_prefill: 0.55,
            mfu_decode: 0.35,
            hbm_util: 0.75,
            net_util: 0.80,
            prefill_overhead_s: 1.5e-3,
            decode_overhead_s: 0.3e-3,
            link_latency_s: 5e-6,
            mem_util: 0.90,
        }
    }
}

/// Additive latency breakdown for one stage execution.
#[derive(Debug, Clone, Copy, Default)]
pub struct TimeBreakdown {
    /// Compute roofline term, seconds.
    pub compute_s: f64,
    /// HBM roofline term, seconds.
    pub memory_s: f64,
    /// δ: tensor-parallel collective time, seconds.
    pub collective_s: f64,
    /// d: pipeline-stage transfer time, seconds.
    pub pipeline_s: f64,
    /// l: static overhead, seconds.
    pub overhead_s: f64,
}

impl TimeBreakdown {
    /// `max(compute, memory) + δ + d + l` — Eq. of §3.1.1.
    pub fn total(&self) -> f64 {
        self.compute_s.max(self.memory_s)
            + self.collective_s
            + self.pipeline_s
            + self.overhead_s
    }

    /// Which roofline binds this stage?
    pub fn bound(&self) -> &'static str {
        if self.compute_s >= self.memory_s {
            "compute"
        } else {
            "memory"
        }
    }
}

/// A parallelism layout for one stage on one device class.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct Parallelism {
    /// Tensor-parallel degree (within the scale-up domain).
    pub tp: u32,
    /// Pipeline-parallel degree (across scale-up domains).
    pub pp: u32,
}

impl Parallelism {
    pub fn devices(&self) -> u32 {
        self.tp * self.pp
    }
}

/// Per-device weight bytes under a layout.
pub fn weight_bytes_per_device(m: &ModelProfile, par: Parallelism) -> f64 {
    m.param_bytes() / par.devices() as f64
}

/// Memory left for KV on each device, after weights. Negative => doesn't fit.
pub fn kv_budget_per_device(
    m: &ModelProfile,
    d: &DeviceSpec,
    par: Parallelism,
    eff: &Efficiency,
) -> f64 {
    d.mem_gb * 1e9 * eff.mem_util - weight_bytes_per_device(m, par)
}

/// Max batch size at context `ctx` fitting the layout's KV budget.
/// KV is sharded over TP (heads) and PP (layers), so the per-device
/// budget is multiplied back by the device count.
pub fn max_batch(m: &ModelProfile, d: &DeviceSpec, par: Parallelism, ctx: u64, eff: &Efficiency) -> u64 {
    let budget = kv_budget_per_device(m, d, par, eff);
    if budget <= 0.0 {
        return 0;
    }
    let total = budget * par.devices() as f64;
    (total / (m.kv_bytes_per_token() * ctx as f64)).floor() as u64
}

/// Tensor-parallel all-reduce time for `bytes` payload per device.
///
/// Ring all-reduce moves `2·(tp-1)/tp · bytes` per device; `2·L/pp`
/// collectives happen per forward pass (two per layer on this stage's
/// layers), each paying one link latency.
fn tp_collective_s(
    m: &ModelProfile,
    d: &DeviceSpec,
    par: Parallelism,
    tokens: u64,
    eff: &Efficiency,
) -> f64 {
    if par.tp <= 1 {
        return 0.0;
    }
    let layers_here = (m.n_layers as f64 / par.pp as f64).ceil();
    let act_bytes = tokens as f64 * m.d_model as f64 * m.precision.bytes_per_elt();
    let per_collective =
        2.0 * (par.tp - 1) as f64 / par.tp as f64 * act_bytes
            / (d.scaleup_bw_gbps * 1e9 * eff.net_util);
    let n_collectives = 2.0 * layers_here;
    n_collectives * (per_collective + eff.link_latency_s)
}

/// Pipeline-stage boundary cost: (pp-1) activation hops.
fn pp_transfer_s(
    m: &ModelProfile,
    d: &DeviceSpec,
    par: Parallelism,
    tokens: u64,
    eff: &Efficiency,
) -> f64 {
    if par.pp <= 1 {
        return 0.0;
    }
    let act_bytes = tokens as f64 * m.d_model as f64 * m.precision.bytes_per_elt();
    let hop = act_bytes / (d.scaleout_bw_gbps * 1e9 * eff.net_util) + eff.link_latency_s;
    (par.pp - 1) as f64 * hop
}

/// Time to prefill a batch of `batch` prompts of `isl` tokens.
///
/// With pipeline parallelism the batch is split into microbatches; the
/// bubble inflates latency by `(pp-1)/mb` (GPipe-style schedule).
pub fn prefill_time(
    m: &ModelProfile,
    d: &DeviceSpec,
    par: Parallelism,
    isl: u64,
    batch: u64,
    eff: &Efficiency,
) -> TimeBreakdown {
    let flops = m.prefill_flops(isl) * batch as f64;
    let bytes = m.prefill_bytes(isl, batch);
    let devices = par.devices() as f64;
    let tokens = isl * batch;

    let mut t = TimeBreakdown {
        compute_s: flops / (d.tflops(m.precision) * 1e12 * eff.mfu_prefill * devices),
        memory_s: bytes / (d.mem_bw_gbps * 1e9 * eff.hbm_util * devices),
        collective_s: tp_collective_s(m, d, par, tokens, eff),
        pipeline_s: pp_transfer_s(m, d, par, tokens, eff),
        overhead_s: eff.prefill_overhead_s,
    };
    if par.pp > 1 {
        // GPipe bubble with mb = 4 microbatches.
        let mb = 4.0_f64.min(batch as f64).max(1.0);
        let bubble = 1.0 + (par.pp as f64 - 1.0) / mb;
        t.compute_s *= bubble;
        t.memory_s *= bubble;
    }
    t
}

/// Time for one decode step over a running batch at context `ctx`.
pub fn decode_step_time(
    m: &ModelProfile,
    d: &DeviceSpec,
    par: Parallelism,
    ctx: u64,
    batch: u64,
    eff: &Efficiency,
) -> TimeBreakdown {
    let flops = m.decode_flops(ctx) * batch as f64;
    let bytes = m.decode_bytes(ctx, batch);
    let devices = par.devices() as f64;

    TimeBreakdown {
        compute_s: flops / (d.tflops(m.precision) * 1e12 * eff.mfu_decode * devices),
        memory_s: bytes / (d.mem_bw_gbps * 1e9 * eff.hbm_util * devices),
        collective_s: tp_collective_s(m, d, par, batch, eff),
        // Each generated token crosses every pipeline boundary.
        pipeline_s: pp_transfer_s(m, d, par, batch, eff),
        overhead_s: eff.decode_overhead_s,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cost::hardware::by_name;
    use crate::cost::model_profile::{llama3_70b, llama3_8b};
    use crate::cost::Precision;

    fn eff() -> Efficiency {
        Efficiency::default()
    }

    #[test]
    fn prefill_is_compute_bound() {
        // §5.3: "prefill is computationally intensive".
        let m = llama3_8b(Precision::Fp16);
        let d = by_name("H100").unwrap();
        let t = prefill_time(&m, &d, Parallelism { tp: 1, pp: 1 }, 2048, 1, &eff());
        assert_eq!(t.bound(), "compute");
    }

    #[test]
    fn decode_is_memory_bound() {
        // §5.3: "decode is more memory capacity intensive" / bandwidth
        // bound at moderate batch.
        let m = llama3_8b(Precision::Fp16);
        let d = by_name("H100").unwrap();
        let t = decode_step_time(&m, &d, Parallelism { tp: 1, pp: 1 }, 1024, 8, &eff());
        assert_eq!(t.bound(), "memory");
    }

    #[test]
    fn h100_8b_latencies_realistic() {
        // Sanity-anchor against public H100 serving numbers: 8B FP16
        // prefill of 512 tokens ~5-30 ms; decode step at batch 1 ~5-15 ms.
        let m = llama3_8b(Precision::Fp16);
        let d = by_name("H100").unwrap();
        let p = prefill_time(&m, &d, Parallelism { tp: 1, pp: 1 }, 512, 1, &eff());
        assert!(p.total() > 0.004 && p.total() < 0.04, "prefill {}", p.total());
        let t = decode_step_time(&m, &d, Parallelism { tp: 1, pp: 1 }, 512, 1, &eff());
        assert!(t.total() > 0.004 && t.total() < 0.02, "decode {}", t.total());
    }

    #[test]
    fn tp_reduces_prefill_time_until_comm_dominates() {
        // §5: "Initial increases in tensor parallelism substantially
        // reduced latency; further increases introduced significant
        // device-to-device communication overhead."
        let m = llama3_70b(Precision::Fp16);
        let d = by_name("A40").unwrap(); // weak interconnect
        let t1 = prefill_time(&m, &d, Parallelism { tp: 1, pp: 1 }, 2048, 1, &eff());
        let t4 = prefill_time(&m, &d, Parallelism { tp: 4, pp: 1 }, 2048, 1, &eff());
        assert!(t4.total() < t1.total(), "tp4 should beat tp1");
        // Marginal speedup degrades: 4->8 gains less than 1->2.
        let t2 = prefill_time(&m, &d, Parallelism { tp: 2, pp: 1 }, 2048, 1, &eff());
        let t8 = prefill_time(&m, &d, Parallelism { tp: 8, pp: 1 }, 2048, 1, &eff());
        let gain_12 = t1.total() / t2.total();
        let gain_48 = t4.total() / t8.total();
        assert!(gain_48 < gain_12, "speedup should saturate");
    }

    #[test]
    fn seventy_b_fp16_does_not_fit_one_h100() {
        let m = llama3_70b(Precision::Fp16);
        let d = by_name("H100").unwrap();
        assert!(kv_budget_per_device(&m, &d, Parallelism { tp: 1, pp: 1 }, &eff()) < 0.0);
        assert!(max_batch(&m, &d, Parallelism { tp: 1, pp: 1 }, 4096, &eff()) == 0);
        // TP2 fits weights (70 GB/dev) but leaves little for KV; TP4 is roomy.
        assert!(kv_budget_per_device(&m, &d, Parallelism { tp: 4, pp: 1 }, &eff()) > 0.0);
    }

    #[test]
    fn max_batch_monotone_in_devices() {
        let m = llama3_8b(Precision::Fp16);
        let d = by_name("A100").unwrap();
        let b1 = max_batch(&m, &d, Parallelism { tp: 1, pp: 1 }, 4096, &eff());
        let b2 = max_batch(&m, &d, Parallelism { tp: 2, pp: 1 }, 4096, &eff());
        assert!(b2 > b1);
    }

    #[test]
    fn pp_adds_latency_per_token() {
        let m = llama3_70b(Precision::Fp16);
        let d = by_name("A100").unwrap();
        let t1 = decode_step_time(&m, &d, Parallelism { tp: 4, pp: 1 }, 1024, 4, &eff());
        let t2 = decode_step_time(&m, &d, Parallelism { tp: 4, pp: 2 }, 1024, 4, &eff());
        // Same device count halving roofline terms, but pipeline hop added.
        assert!(t2.pipeline_s > 0.0 && t1.pipeline_s == 0.0);
    }

    #[test]
    fn fp8_speeds_up_both_phases() {
        let d = by_name("H100").unwrap();
        let m16 = llama3_8b(Precision::Fp16);
        let m8 = llama3_8b(Precision::Fp8);
        let par = Parallelism { tp: 1, pp: 1 };
        assert!(
            prefill_time(&m8, &d, par, 2048, 1, &eff()).total()
                < prefill_time(&m16, &d, par, 2048, 1, &eff()).total()
        );
        assert!(
            decode_step_time(&m8, &d, par, 1024, 1, &eff()).total()
                < decode_step_time(&m16, &d, par, 1024, 1, &eff()).total()
        );
    }

    #[test]
    fn breakdown_total_is_additive() {
        let t = TimeBreakdown {
            compute_s: 2.0,
            memory_s: 3.0,
            collective_s: 0.5,
            pipeline_s: 0.25,
            overhead_s: 0.125,
        };
        assert_eq!(t.total(), 3.0 + 0.5 + 0.25 + 0.125);
        assert_eq!(t.bound(), "memory");
    }
}
