//! Workload characterization (paper §2.5, Table 2, Figure 3).
//!
//! Each representative workload gets a radar profile: relative demand
//! (0–10) across the six hardware dimensions. The paper presents these
//! as "qualitative estimates intended to illustrate workload
//! characteristics"; here they additionally seed the cost annotation
//! pass ([`crate::ir::passes::annotate_cost`]) that converts IR nodes
//! into the optimizer's resource vectors.

use super::{Resource, ResourceVec};

/// The seven representative workloads of Figure 3.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum WorkloadClass {
    LlmInferenceSingleNode,
    LlmPrefillDisagg,
    LlmDecodeDisagg,
    DiffusionModel,
    KvCacheStorage,
    ToolCall,
    GeneralDataProcessing,
}

impl WorkloadClass {
    pub const ALL: [WorkloadClass; 7] = [
        WorkloadClass::LlmInferenceSingleNode,
        WorkloadClass::LlmPrefillDisagg,
        WorkloadClass::LlmDecodeDisagg,
        WorkloadClass::DiffusionModel,
        WorkloadClass::KvCacheStorage,
        WorkloadClass::ToolCall,
        WorkloadClass::GeneralDataProcessing,
    ];

    pub fn name(&self) -> &'static str {
        match self {
            WorkloadClass::LlmInferenceSingleNode => "LLM Inference (Single Node)",
            WorkloadClass::LlmPrefillDisagg => "LLM Prefill (Disaggregated)",
            WorkloadClass::LlmDecodeDisagg => "LLM Decode (Disaggregated)",
            WorkloadClass::DiffusionModel => "Diffusion Models",
            WorkloadClass::KvCacheStorage => "KV Cache Storage",
            WorkloadClass::ToolCall => "Tool Calls",
            WorkloadClass::GeneralDataProcessing => "General Purpose Data Processing",
        }
    }

    /// Figure 3 radar values, on the paper's normalized 0–10 scale, in
    /// order (mem capacity, disk, GP compute, HP compute, mem BW, net BW).
    pub fn radar(&self) -> ResourceVec {
        let v = |mem_cap: f64, disk: f64, gp: f64, hp: f64, mem_bw: f64, net_bw: f64| {
            ResourceVec {
                mem_capacity: mem_cap,
                disk_capacity: disk,
                gp_compute: gp,
                hp_compute: hp,
                mem_bandwidth: mem_bw,
                net_bandwidth: net_bw,
            }
        };
        match self {
            // (a) compute- and memory-intensive, single server => low net.
            WorkloadClass::LlmInferenceSingleNode => v(9.0, 2.0, 2.0, 9.0, 8.0, 1.0),
            // (b) high compute + memory and network BW (distributed tokens).
            WorkloadClass::LlmPrefillDisagg => v(7.0, 1.0, 2.0, 10.0, 8.0, 7.0),
            // (c) lower compute than prefill, high memory + network use.
            WorkloadClass::LlmDecodeDisagg => v(8.0, 1.0, 2.0, 5.0, 9.0, 7.0),
            // (d) broadly intensive, especially compute and memory BW.
            WorkloadClass::DiffusionModel => v(7.0, 3.0, 3.0, 10.0, 9.0, 4.0),
            // (e) memory + disk heavy, elevated network for remote reads.
            WorkloadClass::KvCacheStorage => v(9.0, 8.0, 2.0, 1.0, 6.0, 7.0),
            // (f) low compute, network-dominated.
            WorkloadClass::ToolCall => v(2.0, 2.0, 4.0, 1.0, 2.0, 8.0),
            // (g) strong GP compute, balanced elsewhere.
            WorkloadClass::GeneralDataProcessing => v(6.0, 5.0, 9.0, 1.0, 5.0, 5.0),
        }
    }

    /// The dominant hardware dimension (argmax of the radar).
    pub fn dominant(&self) -> Resource {
        let r = self.radar();
        *Resource::ALL
            .iter()
            .max_by(|a, b| r.get(**a).partial_cmp(&r.get(**b)).unwrap())
            .unwrap()
    }

    /// Does this workload belong on an accelerator (vs CPU)?
    ///
    /// §5: "Our optimization framework places the non-LLM components of
    /// the voice agent on CPUs given the task characteristic."
    pub fn wants_accelerator(&self) -> bool {
        self.radar().hp_compute >= 5.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_profiles_in_scale() {
        for w in WorkloadClass::ALL {
            let r = w.radar();
            for res in Resource::ALL {
                let v = r.get(res);
                assert!((0.0..=10.0).contains(&v), "{w:?} {res:?} = {v}");
            }
        }
    }

    #[test]
    fn prefill_more_compute_than_decode() {
        let p = WorkloadClass::LlmPrefillDisagg.radar();
        let d = WorkloadClass::LlmDecodeDisagg.radar();
        assert!(p.hp_compute > d.hp_compute);
        // decode leans harder on memory bandwidth.
        assert!(d.mem_bandwidth >= p.mem_bandwidth);
    }

    #[test]
    fn single_node_has_negligible_network() {
        assert!(WorkloadClass::LlmInferenceSingleNode.radar().net_bandwidth <= 2.0);
    }

    #[test]
    fn tool_calls_are_network_dominated() {
        assert_eq!(
            WorkloadClass::ToolCall.dominant(),
            Resource::NetBandwidth
        );
        assert!(!WorkloadClass::ToolCall.wants_accelerator());
    }

    #[test]
    fn data_processing_is_gp_dominated() {
        assert_eq!(
            WorkloadClass::GeneralDataProcessing.dominant(),
            Resource::GpCompute
        );
        assert!(!WorkloadClass::GeneralDataProcessing.wants_accelerator());
    }

    #[test]
    fn llm_stages_want_accelerators() {
        assert!(WorkloadClass::LlmPrefillDisagg.wants_accelerator());
        assert!(WorkloadClass::LlmDecodeDisagg.wants_accelerator());
        assert!(WorkloadClass::DiffusionModel.wants_accelerator());
    }

    #[test]
    fn kv_storage_disk_heavy() {
        let r = WorkloadClass::KvCacheStorage.radar();
        assert!(r.disk_capacity >= 7.0 && r.hp_compute <= 2.0);
    }
}
