//! Accelerator catalog (paper Table 5) and marginal cost-efficiency
//! analysis (paper Figure 4).
//!
//! Specs come from the public datasheets the paper cites [24–30]. The
//! paper's "Operating Cost ($/hr)" column is reproduced verbatim in
//! [`DeviceSpec::paper_opex_usd_hr`]; [`crate::cost::tco`] additionally
//! *derives* an operating cost from the stated assumptions (4-year
//! amortization at 8%, max-TDP energy at $0.40/kWh) so the two can be
//! compared (see EXPERIMENTS.md — the paper's own table is not exactly
//! reproducible from its stated formula; we track both).

use super::Precision;

/// Hardware vendor (Fig. 4 color-codes by manufacturer).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Vendor {
    Nvidia,
    Intel,
    Amd,
}

impl Vendor {
    pub fn name(&self) -> &'static str {
        match self {
            Vendor::Nvidia => "NVIDIA",
            Vendor::Intel => "Intel",
            Vendor::Amd => "AMD",
        }
    }
}

/// One accelerator class (a row of Table 5 plus datasheet constants).
#[derive(Debug, Clone)]
pub struct DeviceSpec {
    pub name: &'static str,
    pub vendor: Vendor,
    /// Street price, USD (Table 5; June-2025 reseller average).
    pub price_usd: f64,
    /// HBM capacity, GB.
    pub mem_gb: f64,
    /// HBM bandwidth, GB/s.
    pub mem_bw_gbps: f64,
    /// Dense FP16 throughput, TFLOPs.
    pub tflops_fp16: f64,
    /// Dense FP8 throughput, TFLOPs (falls back to FP16 rate where the
    /// part has no FP8 path — A40/A100 predate FP8).
    pub tflops_fp8: f64,
    /// Board TDP, watts (datasheets).
    pub tdp_w: f64,
    /// Paper Table 5 "Operating Cost ($/hr)".
    pub paper_opex_usd_hr: f64,
    /// Scale-up domain size (accelerators per chassis, §5.2).
    pub scaleup_size: u32,
    /// Scale-up per-device interconnect bandwidth, GB/s (NVLink/ICL/IF).
    pub scaleup_bw_gbps: f64,
    /// Scale-out NIC bandwidth per device, GB/s (RoCE, §5.2).
    pub scaleout_bw_gbps: f64,
}

impl DeviceSpec {
    pub fn tflops(&self, p: Precision) -> f64 {
        match p {
            Precision::Fp16 => self.tflops_fp16,
            Precision::Fp8 => self.tflops_fp8,
        }
    }

    /// Fig. 4(a): $ per GB/s of memory bandwidth.
    pub fn cost_per_gbps(&self) -> f64 {
        self.price_usd / self.mem_bw_gbps
    }

    /// Fig. 4(b)/(c): $ per TFLOP at the given precision.
    pub fn cost_per_tflop(&self, p: Precision) -> f64 {
        self.price_usd / self.tflops(p)
    }

    /// Fig. 4(d): $ per GB of memory capacity.
    pub fn cost_per_gb(&self) -> f64 {
        self.price_usd / self.mem_gb
    }
}

/// The six-device catalog of Table 5.
///
/// FP8 rates and TDPs are from the cited datasheets: H100 SXM 3,958
/// TFLOPs FP8 / 700 W; B200 4,500 TFLOPs FP8 / 1,000 W; Gaudi3 1,835
/// TFLOPs FP8 / 900 W; MI300X 2,615 TFLOPs FP8 / 750 W; A100 SXM 400 W
/// (no FP8 — INT8 624 TOPS path approximated at the FP16 rate); A40
/// 300 W (no FP8).
pub fn catalog() -> Vec<DeviceSpec> {
    vec![
        DeviceSpec {
            name: "A40",
            vendor: Vendor::Nvidia,
            price_usd: 3_000.0,
            mem_gb: 48.0,
            mem_bw_gbps: 696.0,
            tflops_fp16: 75.0,
            tflops_fp8: 75.0,
            tdp_w: 300.0,
            paper_opex_usd_hr: 0.15,
            scaleup_size: 8,
            scaleup_bw_gbps: 56.0, // PCIe gen4 x16 + NVLink bridge pairs
            scaleout_bw_gbps: 25.0,
        },
        DeviceSpec {
            name: "A100",
            vendor: Vendor::Nvidia,
            price_usd: 8_000.0,
            mem_gb: 80.0,
            mem_bw_gbps: 2_039.0,
            tflops_fp16: 322.0,
            tflops_fp8: 322.0,
            tdp_w: 400.0,
            paper_opex_usd_hr: 0.25,
            scaleup_size: 8,
            scaleup_bw_gbps: 600.0, // NVLink3
            scaleout_bw_gbps: 25.0, // 200 Gb/s HDR
        },
        DeviceSpec {
            name: "Gaudi3",
            vendor: Vendor::Intel,
            price_usd: 12_500.0,
            mem_gb: 128.0,
            mem_bw_gbps: 3_700.0,
            tflops_fp16: 1_678.0,
            tflops_fp8: 1_835.0,
            tdp_w: 900.0,
            paper_opex_usd_hr: 0.49,
            scaleup_size: 8,
            scaleup_bw_gbps: 1_050.0, // 21x 200GbE RoCE links on-card
            scaleout_bw_gbps: 100.0,  // 800 Gb/s Ethernet
        },
        DeviceSpec {
            name: "MI300x",
            vendor: Vendor::Amd,
            price_usd: 20_000.0,
            mem_gb: 192.0,
            mem_bw_gbps: 5_300.0,
            tflops_fp16: 1_307.0,
            tflops_fp8: 2_615.0,
            tdp_w: 750.0,
            paper_opex_usd_hr: 0.52,
            scaleup_size: 8,
            scaleup_bw_gbps: 896.0, // Infinity Fabric
            scaleout_bw_gbps: 50.0, // 400 Gb/s
        },
        DeviceSpec {
            name: "H100",
            vendor: Vendor::Nvidia,
            price_usd: 25_000.0,
            mem_gb: 80.0,
            mem_bw_gbps: 3_350.0,
            tflops_fp16: 1_979.0,
            tflops_fp8: 3_958.0,
            tdp_w: 700.0,
            paper_opex_usd_hr: 0.60,
            scaleup_size: 8,
            scaleup_bw_gbps: 900.0, // NVLink4
            scaleout_bw_gbps: 50.0, // 400 Gb/s NDR
        },
        DeviceSpec {
            name: "B200",
            vendor: Vendor::Nvidia,
            price_usd: 40_000.0,
            mem_gb: 192.0,
            mem_bw_gbps: 8_000.0,
            tflops_fp16: 2_250.0,
            // NVIDIA's headline FP8 figure (sparsity-enabled). The dense
            // rate is 4.5 PF, but Fig. 4(c) of the paper reports B200 as
            // the FP8 cost-efficiency leader, which only holds with the
            // 9 PF headline number — so that is what the paper evidently
            // used and what we calibrate to (see EXPERIMENTS.md).
            tflops_fp8: 9_000.0,
            tdp_w: 1_000.0,
            paper_opex_usd_hr: 0.83,
            scaleup_size: 8,
            scaleup_bw_gbps: 1_800.0, // NVLink5
            scaleout_bw_gbps: 50.0,
        },
    ]
}

/// Look up a device by (case-insensitive) name.
pub fn by_name(name: &str) -> Option<DeviceSpec> {
    catalog()
        .into_iter()
        .find(|d| d.name.eq_ignore_ascii_case(name))
}

/// One row of the Figure 4 analysis.
#[derive(Debug, Clone)]
pub struct CostEfficiencyRow {
    pub device: &'static str,
    pub vendor: &'static str,
    pub usd_per_gbps: f64,
    pub usd_per_tflop_fp16: f64,
    pub usd_per_tflop_fp8: f64,
    pub usd_per_gb: f64,
}

/// Figure 4 (a)–(d): marginal cost per unit of each resource.
pub fn cost_efficiency() -> Vec<CostEfficiencyRow> {
    catalog()
        .iter()
        .map(|d| CostEfficiencyRow {
            device: d.name,
            vendor: d.vendor.name(),
            usd_per_gbps: d.cost_per_gbps(),
            usd_per_tflop_fp16: d.cost_per_tflop(Precision::Fp16),
            usd_per_tflop_fp8: d.cost_per_tflop(Precision::Fp8),
            usd_per_gb: d.cost_per_gb(),
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn catalog_matches_table5() {
        let cat = catalog();
        assert_eq!(cat.len(), 6);
        let h100 = by_name("h100").unwrap();
        assert_eq!(h100.price_usd, 25_000.0);
        assert_eq!(h100.mem_gb, 80.0);
        assert_eq!(h100.mem_bw_gbps, 3_350.0);
        assert_eq!(h100.tflops_fp16, 1_979.0);
        assert_eq!(h100.paper_opex_usd_hr, 0.60);
    }

    #[test]
    fn fig4a_bandwidth_efficiency_leaders() {
        // Paper: "Gaudi3 and MI300x exhibit the highest bandwidth
        // efficiency" (lowest $/GBps).
        let mut rows = cost_efficiency();
        rows.sort_by(|a, b| a.usd_per_gbps.partial_cmp(&b.usd_per_gbps).unwrap());
        let top2: Vec<&str> = rows[..2].iter().map(|r| r.device).collect();
        assert!(top2.contains(&"Gaudi3"), "top2={top2:?}");
        assert!(top2.contains(&"MI300x"), "top2={top2:?}");
    }

    #[test]
    fn fig4b_fp16_compute_efficiency_leaders() {
        // Paper: "H100, Gaudi3, and MI300x provide strong cost-efficiency"
        // at FP16.
        let mut rows = cost_efficiency();
        rows.sort_by(|a, b| {
            a.usd_per_tflop_fp16
                .partial_cmp(&b.usd_per_tflop_fp16)
                .unwrap()
        });
        let top3: Vec<&str> = rows[..3].iter().map(|r| r.device).collect();
        for d in ["H100", "Gaudi3", "MI300x"] {
            assert!(top3.contains(&d), "top3={top3:?}");
        }
    }

    #[test]
    fn fig4c_fp8_leader_is_b200_class() {
        // Paper: "B200 offers leading efficiency at low precision".
        let mut rows = cost_efficiency();
        rows.sort_by(|a, b| {
            a.usd_per_tflop_fp8.partial_cmp(&b.usd_per_tflop_fp8).unwrap()
        });
        let top: Vec<&str> = rows[..2].iter().map(|r| r.device).collect();
        assert!(top.contains(&"B200"), "top2={top:?}");
    }

    #[test]
    fn fig4d_memory_capacity_leaders() {
        // Paper: "MI300x and A40 deliver the most cost-effective memory
        // provisioning". From Table 5's own prices, A40 is the strict
        // leader; MI300x leads the large-memory (>=128 GB) class but
        // trails Gaudi3/A100 slightly on raw $/GB — we assert the
        // derivable shape (see EXPERIMENTS.md deviation note).
        let mut rows = cost_efficiency();
        rows.sort_by(|a, b| a.usd_per_gb.partial_cmp(&b.usd_per_gb).unwrap());
        assert_eq!(rows[0].device, "A40");
        let mi300x = rows.iter().find(|r| r.device == "MI300x").unwrap();
        let b200 = rows.iter().find(|r| r.device == "B200").unwrap();
        let h100 = rows.iter().find(|r| r.device == "H100").unwrap();
        assert!(mi300x.usd_per_gb < b200.usd_per_gb);
        assert!(mi300x.usd_per_gb < h100.usd_per_gb);
    }

    #[test]
    fn fp8_never_slower_than_fp16() {
        for d in catalog() {
            assert!(d.tflops_fp8 >= d.tflops_fp16, "{}", d.name);
        }
    }

    #[test]
    fn unknown_device_is_none() {
        assert!(by_name("TPUv9").is_none());
    }
}
