//! Paper-artifact regeneration: every table and figure of the
//! evaluation, as printable text + machine-readable JSON. Used by the
//! `agentic-hetero repro` CLI and by `benches/*` (which time the
//! underlying computations and print the same rows).

use crate::cost::hardware::cost_efficiency;
use crate::cost::model_profile::{by_short_name, table4, ModelProfile};
use crate::cost::network::{bandwidth_requirement, bps_to_gbit};
use crate::cost::tco::{table5, FinanceTerms};
use crate::cost::workload::WorkloadClass;
use crate::cost::{Precision, Resource};
use crate::ir::passes::PassManager;
use crate::ir::printer;
use crate::opt::assignment::worked_example;
use crate::opt::parallelism::{paper_pairs, tco_series, ExploreOpts, SeqShape, TcoBar};
use crate::util::json::Json;

/// A regenerated artifact: human text + JSON series.
pub struct Artifact {
    pub id: &'static str,
    pub title: String,
    pub text: String,
    pub json: Json,
}

/// Figure 4: marginal cost-efficiency of the accelerator catalog.
pub fn fig4() -> Artifact {
    let rows = cost_efficiency();
    let mut text = String::new();
    text.push_str(&format!(
        "{:<8} {:<8} {:>12} {:>16} {:>15} {:>10}\n",
        "Device", "Vendor", "$/(GB/s)", "$/TFLOP(FP16)", "$/TFLOP(FP8)", "$/GB"
    ));
    let mut arr: Vec<Json> = Vec::new();
    for r in &rows {
        text.push_str(&format!(
            "{:<8} {:<8} {:>12.2} {:>16.2} {:>15.2} {:>10.2}\n",
            r.device,
            r.vendor,
            r.usd_per_gbps,
            r.usd_per_tflop_fp16,
            r.usd_per_tflop_fp8,
            r.usd_per_gb
        ));
        arr.push(crate::jobj! {
            "device" => r.device,
            "vendor" => r.vendor,
            "usd_per_gbps" => r.usd_per_gbps,
            "usd_per_tflop_fp16" => r.usd_per_tflop_fp16,
            "usd_per_tflop_fp8" => r.usd_per_tflop_fp8,
            "usd_per_gb" => r.usd_per_gb,
        });
    }
    text.push_str(
        "\nPaper shape: (a) Gaudi3/MI300x best $/GBps; (b) H100/Gaudi3/MI300x \
         strong FP16; (c) B200 leads FP8; (d) A40 leads $/GB, MI300x best of \
         the large-memory parts.\n",
    );
    Artifact {
        id: "fig4",
        title: "Figure 4: marginal cost-efficiency of AI accelerators".into(),
        text,
        json: Json::Arr(arr),
    }
}

/// Figure 3 / Table 2: workload radar profiles.
pub fn fig3() -> Artifact {
    let mut text = format!(
        "{:<34} {:>7} {:>5} {:>5} {:>5} {:>6} {:>6}  {}\n",
        "Workload", "MemCap", "Disk", "GP", "HP", "MemBW", "NetBW", "dominant"
    );
    let mut arr: Vec<Json> = Vec::new();
    for w in WorkloadClass::ALL {
        let r = w.radar();
        text.push_str(&format!(
            "{:<34} {:>7.0} {:>5.0} {:>5.0} {:>5.0} {:>6.0} {:>6.0}  {}\n",
            w.name(),
            r.mem_capacity,
            r.disk_capacity,
            r.gp_compute,
            r.hp_compute,
            r.mem_bandwidth,
            r.net_bandwidth,
            w.dominant().name()
        ));
        // Dynamic keys: build the map directly rather than go through
        // the fallible `try_set` on a value that is statically an object.
        let mut row = std::collections::BTreeMap::new();
        row.insert("workload".to_string(), Json::from(w.name()));
        row.insert(
            "wants_accelerator".to_string(),
            Json::from(w.wants_accelerator()),
        );
        for res in Resource::ALL {
            row.insert(res.name().to_string(), Json::from(r.get(res)));
        }
        arr.push(Json::Obj(row));
    }
    Artifact {
        id: "fig3",
        title: "Figure 3 / Table 2: workload resource-demand radar profiles".into(),
        text,
        json: Json::Arr(arr),
    }
}

/// Table 1: the agent task taxonomy as implemented by the IR dialects.
pub fn table1() -> Artifact {
    let mut text = format!("{:<22} {:<10} {:<8} {}\n", "Op", "Results", "Pure", "Workload class");
    let mut arr: Vec<Json> = Vec::new();
    for op in crate::ir::ops::REGISTRY {
        text.push_str(&format!(
            "{:<22} {:<10} {:<8} {}\n",
            op.name,
            op.results,
            op.pure_op,
            op.workload.map(|w| w.name()).unwrap_or("-")
        ));
        arr.push(crate::jobj! {
            "op" => op.name,
            "results" => op.results,
            "pure" => op.pure_op,
            "workload" => op.workload.map(|w| w.name()).unwrap_or("-"),
        });
    }
    Artifact {
        id: "table1",
        title: "Table 1: agent task types (IR dialect registry)".into(),
        text,
        json: Json::Arr(arr),
    }
}

/// Table 3 + §3.1.2 worked example.
pub fn table3() -> Artifact {
    let p = worked_example();
    let mut text = String::new();
    let options = [("A (all HP)", vec![0, 0]), ("B (HP::CO)", vec![0, 1]), ("C (all CO)", vec![1, 1])];
    let mut arr: Vec<Json> = Vec::new();
    for (name, choice) in &options {
        let (cost, lat) = p.evaluate(choice);
        let feasible = lat <= 0.120 + 1e-12;
        text.push_str(&format!(
            "Option {name:<12} t = {:>3.0} ms   cost = ${cost:.3}   {}\n",
            lat * 1e3,
            if feasible { "SLA satisfied" } else { "SLA violated" }
        ));
        arr.push(crate::jobj! {
            "option" => *name,
            "latency_ms" => lat * 1e3,
            "cost_usd" => cost,
            "feasible" => feasible,
        });
    }
    let best = p.solve_exact().expect("worked example is feasible");
    text.push_str(&format!(
        "\nOptimizer selects: {} (cost ${:.3}, {:.0} ms) — the paper's Option B.\n\
         (Paper prints $0.07 for Option C; its stated rates give $0.06 — \
         arithmetic slip, argmin unchanged.)\n",
        best.describe(&p),
        best.cost_usd,
        best.latency_s * 1e3
    ));
    Artifact {
        id: "table3",
        title: "Table 3 / §3.1.2 worked example: prefill/decode under SLA".into(),
        text,
        json: Json::Arr(arr),
    }
}

/// Table 4: evaluated model configurations.
pub fn table4_art() -> Artifact {
    let mut text = format!(
        "{:<24} {:>8} {:>10} {:>8} {:>8} {:>9} {:>14}\n",
        "Model", "Params", "Precision", "Layers", "d_model", "KV B/tok", "Weights (GB)"
    );
    let mut arr: Vec<Json> = Vec::new();
    for m in table4() {
        text.push_str(&format!(
            "{:<24} {:>7}B {:>10} {:>8} {:>8} {:>9.0} {:>14.1}\n",
            m.name,
            m.params_b,
            m.precision.name(),
            m.n_layers,
            m.d_model,
            m.kv_bytes_per_token(),
            m.param_bytes() / 1e9
        ));
        arr.push(crate::jobj! {
            "model" => m.name,
            "params_b" => m.params_b,
            "precision" => m.precision.name(),
            "kv_bytes_per_token" => m.kv_bytes_per_token(),
        });
    }
    Artifact {
        id: "table4",
        title: "Table 4: model configurations".into(),
        text,
        json: Json::Arr(arr),
    }
}

/// Table 5: device specs + operating cost (listed vs derived).
pub fn table5_art() -> Artifact {
    let terms = FinanceTerms::default();
    let rows = table5(&terms);
    let mut text = format!(
        "{:<8} {:>9} {:>8} {:>9} {:>8} {:>11} {:>12} {:>12} {:>12}\n",
        "Device", "Cost($)", "Mem(GB)", "BW(GB/s)", "TFLOPs", "Paper $/hr", "Capex $/hr", "Energy $/hr", "Derived $/hr"
    );
    let mut arr: Vec<Json> = Vec::new();
    for r in &rows {
        text.push_str(&format!(
            "{:<8} {:>9.0} {:>8.0} {:>9.0} {:>8.0} {:>11.2} {:>12.3} {:>12.3} {:>12.3}\n",
            r.device,
            r.price_usd,
            r.mem_gb,
            r.bw_gbps,
            r.tflops_fp16,
            r.paper_opex,
            r.derived_capex_hr,
            r.derived_energy_hr,
            r.derived_opex
        ));
        arr.push(crate::jobj! {
            "device" => r.device,
            "price_usd" => r.price_usd,
            "paper_opex_hr" => r.paper_opex,
            "derived_opex_hr" => r.derived_opex,
        });
    }
    text.push_str(
        "\nNote: the stated formula (4-yr amortization @ 8% + max-TDP energy @ \
         $0.40/kWh) yields more than the listed column for high-end parts; \
         both are carried (see EXPERIMENTS.md).\n",
    );
    Artifact {
        id: "table5",
        title: "Table 5: accelerator specifications & operating cost".into(),
        text,
        json: Json::Arr(arr),
    }
}

fn tco_text(bars: &[TcoBar], models: &[ModelProfile]) -> (String, Json) {
    let mut text = String::new();
    let mut arr: Vec<Json> = Vec::new();
    for m in models {
        for sla in ["Latency SLA", "Throughput SLA"] {
            text.push_str(&format!("\n{} — {}\n", m.name, sla));
            for b in bars.iter().filter(|b| b.model == m.name && b.sla == sla) {
                let nstars = (b.tco_benefit * 20.0).round() as usize;
                text.push_str(&format!(
                    "  {:<16} {:>5.2}x  {}  [p {} tp{} pp{} b{} | d {} tp{} pp{} b{} | ttft {:.0}ms tbt {:.1}ms]\n",
                    b.pair,
                    b.tco_benefit,
                    "#".repeat(nstars.min(80)),
                    b.config.prefill.device,
                    b.config.prefill.par.tp,
                    b.config.prefill.par.pp,
                    b.config.prefill.batch,
                    b.config.decode.device,
                    b.config.decode.par.tp,
                    b.config.decode.par.pp,
                    b.config.decode.batch,
                    b.config.ttft_s * 1e3,
                    b.config.tbt_s * 1e3,
                ));
                arr.push(crate::jobj! {
                    "model" => b.model.clone(),
                    "sla" => b.sla,
                    "pair" => b.pair.clone(),
                    "tco_benefit" => b.tco_benefit,
                    "usd_per_mtok" => b.config.usd_per_mtok,
                    "ttft_ms" => b.config.ttft_s * 1e3,
                    "tbt_ms" => b.config.tbt_s * 1e3,
                });
            }
        }
    }
    (text, Json::Arr(arr))
}

/// Figures 8/9: TCO benefit bars for heterogeneous configs.
pub fn fig_tco(shape: SeqShape, id: &'static str) -> Artifact {
    let models = table4();
    let opts = ExploreOpts::default();
    let bars = tco_series(&models, &paper_pairs(), shape, &opts);
    let (mut text, json) = tco_text(&bars, &models);
    text.push_str(
        "\nDashed baseline 1.0 = H100::H100. Paper shape: B200::Gaudi3 best \
         overall (esp. FP8); H100::Gaudi3 comparable-or-better than B200::B200.\n",
    );
    Artifact {
        id,
        title: format!(
            "TCO benefit for heterogeneous configs (input={}, output={})",
            shape.isl, shape.osl
        ),
        text,
        json,
    }
}

/// Eqs. 1–3: KV sizing and interconnect feasibility up to 32K ISL.
pub fn bandwidth() -> Artifact {
    let mut text = format!(
        "{:<24} {:>8} {:>12} {:>16} {:>16}\n",
        "Model", "ISL", "KV (GB)", "Egress (Gbit/s)", "Ingress (Gbit/s)"
    );
    let mut arr: Vec<Json> = Vec::new();
    // Interactive SLA targets; TTFT grows with ISL (superlinear prefill),
    // modeled via the roofline on an H100 TP8 pipeline.
    let h100 = crate::cost::hardware::by_name("H100").unwrap();
    let eff = crate::cost::roofline::Efficiency::default();
    for name in ["8b-fp16", "70b-fp16"] {
        let m = by_short_name(name).unwrap();
        for isl in [1024u64, 4096, 8192, 16_384, 32_768] {
            let par = crate::cost::roofline::Parallelism { tp: 8, pp: 1 };
            let ttft = crate::cost::roofline::prefill_time(&m, &h100, par, isl, 1, &eff)
                .total();
            let r = bandwidth_requirement(&m, isl, 1, ttft, 0.020, 8, 8);
            text.push_str(&format!(
                "{:<24} {:>8} {:>12.3} {:>16.1} {:>16.1}\n",
                m.name,
                isl,
                r.kv_bytes / 1e9,
                bps_to_gbit(r.peak_egress_bps),
                bps_to_gbit(r.peak_ingress_bps)
            ));
            arr.push(crate::jobj! {
                "model" => m.name,
                "isl" => isl,
                "kv_gb" => r.kv_bytes / 1e9,
                "egress_gbit" => bps_to_gbit(r.peak_egress_bps),
                "ingress_gbit" => bps_to_gbit(r.peak_ingress_bps),
            });
        }
    }
    text.push_str(
        "\n§5.2 claim: a 200–400 Gb/s link suffices for KV transfer up to 32K \
         ISL at interactive SLAs (per-GPU egress column stays below 400).\n",
    );
    Artifact {
        id: "bandwidth",
        title: "Eqs. 1–3: KV-cache transfer bandwidth model".into(),
        text,
        json: Json::Arr(arr),
    }
}

/// Figure 7: LangChain-style agent lowered through the IR pipeline.
pub fn fig7() -> Artifact {
    let g = crate::agents::langchain_style_agent("8b-fp16");
    let before = printer::print(&g);
    let mut lowered = g.clone();
    let mut pm = PassManager::standard();
    pm.run(&mut lowered).expect("pipeline runs");
    let after = printer::print(&lowered);
    let log: Vec<String> = pm
        .log
        .iter()
        .map(|(n, c)| format!("{n}: {}", if *c { "changed" } else { "no-op" }))
        .collect();
    let text = format!(
        "--- (a)+(b) authored / high-level IR ---\n{before}\n\
         --- passes ---\n{}\n\n--- (c) decomposed IR ---\n{after}",
        log.join("\n")
    );
    Artifact {
        id: "fig7",
        title: "Figure 7: agent program → high-level IR → decomposed IR".into(),
        text,
        json: crate::jobj! {
            "before_ops" => g.op_names().len(),
            "after_ops" => lowered.op_names().len(),
            "passes" => log,
        },
    }
}

/// Everything, in paper order.
pub fn all() -> Vec<Artifact> {
    vec![
        table1(),
        fig3(),
        fig4(),
        table3(),
        table4_art(),
        table5_art(),
        fig_tco(SeqShape::fig8(), "fig8"),
        fig_tco(SeqShape::fig9(), "fig9"),
        bandwidth(),
        fig7(),
    ]
}

/// Look up one artifact by id.
pub fn by_id(id: &str) -> Option<Artifact> {
    match id {
        "table1" => Some(table1()),
        "fig3" | "table2" => Some(fig3()),
        "fig4" => Some(fig4()),
        "table3" => Some(table3()),
        "table4" => Some(table4_art()),
        "table5" => Some(table5_art()),
        "fig8" => Some(fig_tco(SeqShape::fig8(), "fig8")),
        "fig9" => Some(fig_tco(SeqShape::fig9(), "fig9")),
        "bandwidth" | "eq13" => Some(bandwidth()),
        "fig7" => Some(fig7()),
        _ => None,
    }
}

/// Sanity marker kept in sync with tests: FP8 precision exists.
pub fn _precision_check() -> Precision {
    Precision::Fp8
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_artifacts_generate_nonempty() {
        for a in all() {
            assert!(!a.text.is_empty(), "{} empty", a.id);
            assert!(!a.title.is_empty());
            let j = a.json.to_string();
            assert!(j.len() > 2, "{} json empty", a.id);
        }
    }

    #[test]
    fn by_id_resolves_all_paper_ids() {
        for id in [
            "table1", "fig3", "fig4", "table3", "table4", "table5", "fig8", "fig9",
            "bandwidth", "fig7",
        ] {
            assert!(by_id(id).is_some(), "missing {id}");
        }
        assert!(by_id("fig99").is_none());
    }

    #[test]
    fn fig8_headline_claims_hold() {
        let a = fig_tco(SeqShape::fig8(), "fig8");
        // Parse back out of the JSON: B200::Gaudi3 must beat baseline
        // for every FP8 model/SLA combination.
        if let Json::Arr(rows) = &a.json {
            let mut checked = 0;
            for r in rows {
                let pair = r.get("pair").and_then(|j| match j {
                    Json::Str(s) => Some(s.as_str()),
                    _ => None,
                });
                let model = r.get("model").and_then(|j| match j {
                    Json::Str(s) => Some(s.as_str()),
                    _ => None,
                });
                if pair == Some("B200::Gaudi3")
                    && model.map(|m| m.contains("FP8")).unwrap_or(false)
                {
                    let benefit = match r.get("tco_benefit") {
                        Some(Json::Num(v)) => *v,
                        _ => panic!("missing benefit"),
                    };
                    assert!(benefit > 1.0, "{model:?} benefit {benefit}");
                    checked += 1;
                }
            }
            assert!(checked >= 2, "too few B200::Gaudi3 FP8 rows");
        } else {
            panic!("fig8 json not array");
        }
    }

    #[test]
    fn bandwidth_claim_holds_to_32k() {
        let a = bandwidth();
        if let Json::Arr(rows) = &a.json {
            for r in rows {
                if let Some(Json::Num(egress)) = r.get("egress_gbit") {
                    assert!(*egress <= 400.0, "egress {egress} > 400 Gbit");
                }
            }
        }
    }
}
