//! Request/response types for the serving loop.

/// A chat/completion request (byte-level prompt — the tiny model is a
/// byte LM).
#[derive(Debug, Clone)]
pub struct ChatRequest {
    pub id: u64,
    /// Multi-turn session affinity (None = stateless).
    pub session: Option<u64>,
    pub prompt: Vec<u8>,
    pub max_new_tokens: usize,
    /// 0.0 = greedy.
    pub temperature: f64,
}

impl ChatRequest {
    pub fn new(id: u64, prompt: impl Into<Vec<u8>>, max_new_tokens: usize) -> ChatRequest {
        ChatRequest {
            id,
            session: None,
            prompt: prompt.into(),
            max_new_tokens,
            temperature: 0.0,
        }
    }
}

/// The completed response with serving metrics attached.
#[derive(Debug, Clone)]
pub struct ChatResponse {
    pub id: u64,
    pub output: Vec<u8>,
    /// Time to first token (from submission).
    pub ttft_s: f64,
    /// Mean token-to-token gap.
    pub tbt_mean_s: f64,
    /// Total latency.
    pub e2e_s: f64,
    pub tokens: usize,
    /// Whether the request was rejected by admission control.
    pub rejected: bool,
}

impl ChatResponse {
    pub fn rejected(id: u64) -> ChatResponse {
        ChatResponse {
            id,
            output: Vec::new(),
            ttft_s: 0.0,
            tbt_mean_s: 0.0,
            e2e_s: 0.0,
            tokens: 0,
            rejected: true,
        }
    }

    /// Lossy text rendering of the output bytes.
    pub fn text(&self) -> String {
        String::from_utf8_lossy(&self.output).into_owned()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn request_builder() {
        let r = ChatRequest::new(7, "hello", 16);
        assert_eq!(r.prompt, b"hello");
        assert_eq!(r.max_new_tokens, 16);
        assert!(r.session.is_none());
    }

    #[test]
    fn response_text_lossy() {
        let r = ChatResponse {
            id: 1,
            output: vec![104, 105, 0xFF],
            ttft_s: 0.0,
            tbt_mean_s: 0.0,
            e2e_s: 0.0,
            tokens: 3,
            rejected: false,
        };
        assert!(r.text().starts_with("hi"));
    }
}
