//! Request/response types for the serving loop.

/// A chat/completion request (byte-level prompt — the tiny model is a
/// byte LM).
#[derive(Debug, Clone)]
pub struct ChatRequest {
    pub id: u64,
    /// Multi-turn session affinity (None = stateless).
    pub session: Option<u64>,
    pub prompt: Vec<u8>,
    pub max_new_tokens: usize,
    /// 0.0 = greedy.
    pub temperature: f64,
    /// Agent-graph class: `Some(agent)` asks the server to execute the
    /// request through its installed `ExecutionPlan`'s full DAG (tool/
    /// IO stages on the host pool, LLM stages on the engine). `None` is
    /// the classic flat prefill→decode path.
    pub agent: Option<String>,
}

impl ChatRequest {
    pub fn new(id: u64, prompt: impl Into<Vec<u8>>, max_new_tokens: usize) -> ChatRequest {
        ChatRequest {
            id,
            session: None,
            prompt: prompt.into(),
            max_new_tokens,
            temperature: 0.0,
            agent: None,
        }
    }

    /// Same request, routed through the named agent graph.
    pub fn with_agent(mut self, agent: impl Into<String>) -> ChatRequest {
        self.agent = Some(agent.into());
        self
    }
}

/// One executed stage of an agent-DAG request: which plan binding ran,
/// on which role, and when (offsets from request submission, seconds).
/// Execution spans, not queue spans — `start_s` is when a worker or the
/// engine picked the stage up.
#[derive(Debug, Clone, PartialEq)]
pub struct StageSpan {
    /// Binding index in the server's `ExecutionPlan`.
    pub node: usize,
    /// IR op name ("tool.search", "llm.decode", ...).
    pub op: String,
    /// "cpu" | "llm_prefill" | "llm_decode".
    pub role: &'static str,
    pub start_s: f64,
    pub end_s: f64,
}

impl StageSpan {
    pub fn duration_s(&self) -> f64 {
        (self.end_s - self.start_s).max(0.0)
    }
}

/// The completed response with serving metrics attached.
#[derive(Debug, Clone)]
pub struct ChatResponse {
    pub id: u64,
    pub output: Vec<u8>,
    /// Time to first token (from submission).
    pub ttft_s: f64,
    /// Mean token-to-token gap.
    pub tbt_mean_s: f64,
    /// Total latency.
    pub e2e_s: f64,
    pub tokens: usize,
    /// Whether the request was rejected by admission control.
    pub rejected: bool,
    /// Whether a stage failed mid-DAG (the request is terminated; the
    /// dispatcher and every other request keep running).
    pub failed: bool,
    /// Failure detail when `failed`.
    pub error: Option<String>,
    /// Per-stage execution trace (agent-DAG requests only; empty on the
    /// flat path).
    pub stages: Vec<StageSpan>,
    /// Bytes this request moved across chassis on pipeline → pipeline
    /// edges — the fused prefill→decode KV handoff plus any cross-unit
    /// LLM edges (agent-DAG requests; 0.0 on the flat path). Sized by
    /// the same rule the simulator prices
    /// ([`crate::plan::instance::edge_payload_bytes`]) and defined
    /// identically to `DagSim`'s per-edge `kv_bytes_moved`, so
    /// conformance tests can match live hops against the plan's unit
    /// placement exactly.
    pub kv_hop_bytes: f64,
}

impl ChatResponse {
    pub fn rejected(id: u64) -> ChatResponse {
        ChatResponse {
            id,
            output: Vec::new(),
            ttft_s: 0.0,
            tbt_mean_s: 0.0,
            e2e_s: 0.0,
            tokens: 0,
            rejected: true,
            failed: false,
            error: None,
            stages: Vec::new(),
            kv_hop_bytes: 0.0,
        }
    }

    /// A request terminated by a failing stage.
    pub fn failed(id: u64, e2e_s: f64, error: impl Into<String>) -> ChatResponse {
        ChatResponse {
            id,
            output: Vec::new(),
            ttft_s: 0.0,
            tbt_mean_s: 0.0,
            e2e_s,
            tokens: 0,
            rejected: false,
            failed: true,
            error: Some(error.into()),
            stages: Vec::new(),
            kv_hop_bytes: 0.0,
        }
    }

    /// Completed normally (admitted, no stage failure).
    pub fn is_ok(&self) -> bool {
        !self.rejected && !self.failed
    }

    /// Lossy text rendering of the output bytes.
    pub fn text(&self) -> String {
        String::from_utf8_lossy(&self.output).into_owned()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn request_builder() {
        let r = ChatRequest::new(7, "hello", 16);
        assert_eq!(r.prompt, b"hello");
        assert_eq!(r.max_new_tokens, 16);
        assert!(r.session.is_none());
        assert!(r.agent.is_none());
        let r = r.with_agent("voice_agent");
        assert_eq!(r.agent.as_deref(), Some("voice_agent"));
    }

    #[test]
    fn response_text_lossy() {
        let r = ChatResponse {
            id: 1,
            output: vec![104, 105, 0xFF],
            ttft_s: 0.0,
            tbt_mean_s: 0.0,
            e2e_s: 0.0,
            tokens: 3,
            rejected: false,
            failed: false,
            error: None,
            stages: Vec::new(),
            kv_hop_bytes: 0.0,
        };
        assert!(r.text().starts_with("hi"));
        assert!(r.is_ok());
    }

    #[test]
    fn failure_states_are_distinct() {
        assert!(ChatResponse::rejected(1).rejected);
        assert!(!ChatResponse::rejected(1).failed);
        let f = ChatResponse::failed(2, 0.5, "tool exploded");
        assert!(f.failed && !f.rejected && !f.is_ok());
        assert_eq!(f.error.as_deref(), Some("tool exploded"));
        assert_eq!(f.e2e_s, 0.5);
    }

    #[test]
    fn stage_span_duration() {
        let s = StageSpan {
            node: 3,
            op: "tool.search".into(),
            role: "cpu",
            start_s: 0.5,
            end_s: 0.9,
        };
        assert!((s.duration_s() - 0.4).abs() < 1e-12);
    }
}
