//! Serving front-end: the leader's request loop over the real PJRT
//! engine (§4.1 objectives: scalability via batching, flexibility via
//! channel-fed synchronous/asynchronous submission, composability via
//! multi-turn sessions).
//!
//! * [`request`] — request/response types and SLA accounting;
//! * [`session`] — multi-turn session store (history → prompt
//!   assembly within the compiled prompt bucket);
//! * [`serve`] — the serving loop: admission → continuous batcher →
//!   prefill/decode on the engine → streamed responses, on std threads
//!   + mpsc (tokio is not in the offline registry; the event loop is a
//!   single dispatcher thread with worker-side compute, which the tiny
//!   CPU model saturates).

pub mod request;
pub mod serve;
pub mod session;

pub use request::{ChatRequest, ChatResponse};
pub use serve::{Server, ServerConfig};
pub use session::SessionStore;
