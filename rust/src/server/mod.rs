//! Serving front-end: the leader's request loop over the real PJRT
//! engine (§4.1 objectives: scalability via batching, flexibility via
//! channel-fed synchronous/asynchronous submission, composability via
//! multi-turn sessions) — now executing **full agent DAGs** per
//! request, not just the LLM stages.
//!
//! * [`request`] — request/response types, per-stage execution spans,
//!   and SLA accounting;
//! * [`session`] — multi-turn session store (history → prompt
//!   assembly within the compiled prompt bucket);
//! * [`hostpool`] — bounded worker pool for CPU/tool/IO stages (the
//!   live counterpart of the simulator's `cpu_workers` slots);
//! * [`dag_exec`] — per-request DAG traversal over an installed
//!   [`crate::plan::ExecutionPlan`]: dependency tracking, engine
//!   inference units split into prefill/decode phases scheduled onto
//!   their pipeline group's engine, contended cross-chassis transfers
//!   (the fused prefill→decode KV hop included), payload propagation,
//!   failure isolation;
//! * [`engine_exec`] — the per-engine worker threads: batch execution
//!   (prefill/decode phases, flat generate) with measured busy-time
//!   accounting and panic isolation, reporting completions onto the
//!   dispatcher's unified event channel;
//! * [`serve`] — the dispatcher: admission → continuous batchers →
//!   per-engine worker threads (+ host-pool completions and transfer
//!   timers in DAG mode) → streamed responses, on std threads + mpsc
//!   (tokio is not in the offline registry). The dispatcher blocks on
//!   one merged event channel; engines on different threads execute
//!   truly concurrently (see ARCHITECTURE.md "Threading model").

pub mod dag_exec;
pub(crate) mod engine_exec;
pub mod hostpool;
pub mod request;
pub mod serve;
pub mod session;

pub use dag_exec::{host_payload, DagRuntime, HostFault, LlmJob, LlmPhase, UnitOutcome};
pub use hostpool::{HostDone, HostPool, HostTask};
pub use request::{ChatRequest, ChatResponse, StageSpan};
pub use serve::{Server, ServerConfig};
pub use session::SessionStore;
