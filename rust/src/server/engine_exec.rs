//! Engine-side batch execution: the prefill/decode phase runners shared
//! by the worker-thread engine pool and the serialized fallback path,
//! plus the worker threads themselves.
//!
//! Each engine of the server's pool is owned by **one** worker thread
//! ([`spawn_engine_worker`]) with its own `mpsc::Receiver<EngineWork>`
//! queue — no shared work queue, no locking on the hot path. The
//! dispatcher sends batches in; the worker executes them against its
//! engine (engines take `&self` for inference, so the `Arc` is shared,
//! not moved) and reports an [`EngineDone`] through the server's
//! unified event channel. Busy time is accumulated into per-engine
//! atomic counters ([`EngineStats`]) as phases execute, so the
//! dispatcher reports *measured per-thread* utilization without any
//! cross-thread bookkeeping or locks.
//!
//! Failure isolation mirrors the host pool: an engine error or a panic
//! inside a batch fails exactly the requests of that batch (their ids
//! come back in `EngineDone::*::failed`) — the worker, the engine, and
//! every other request keep running.

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{mpsc, Arc};
use std::thread;
use std::time::{Duration, Instant};

use crate::runtime::{Engine, Sampler};
use crate::server::dag_exec::{LlmJob, LlmPhase, UnitOutcome};
use crate::server::request::ChatRequest;
use crate::Error;

/// Per-engine cumulative busy time, split by role half. Shared between
/// the worker thread (writer) and the dispatcher (reader) — the same
/// atomics shape as the host pool's `PoolStats`.
#[derive(Debug, Default)]
pub(crate) struct EngineStats {
    prefill_busy_ns: AtomicU64,
    decode_busy_ns: AtomicU64,
}

impl EngineStats {
    fn add_prefill(&self, d: Duration) {
        self.prefill_busy_ns
            .fetch_add(d.as_nanos() as u64, Ordering::Relaxed);
    }

    fn add_decode(&self, d: Duration) {
        self.decode_busy_ns
            .fetch_add(d.as_nanos() as u64, Ordering::Relaxed);
    }

    /// Cumulative (prefill, decode) busy nanoseconds since construction.
    pub(crate) fn busy_ns(&self) -> (u64, u64) {
        (
            self.prefill_busy_ns.load(Ordering::Relaxed),
            self.decode_busy_ns.load(Ordering::Relaxed),
        )
    }
}

/// One admitted flat (non-agent) request, session prompt already
/// assembled by the dispatcher (the `SessionStore` stays dispatcher-
/// owned; workers never touch it).
pub(crate) struct FlatSlot {
    pub req: ChatRequest,
    pub submitted: Instant,
    pub prompt: Vec<u8>,
}

/// A completed flat request, latencies measured against submission.
pub(crate) struct FlatOutcome {
    pub req: ChatRequest,
    pub output: Vec<u8>,
    pub ttft_s: f64,
    pub tbt_mean_s: f64,
    pub e2e_s: f64,
}

/// One batch handed to an engine worker.
pub(crate) enum EngineWork {
    /// Agent-DAG LLM phases (already partitioned onto this engine).
    Dag(Vec<LlmJob>),
    /// Flat prefill+decode requests (always engine 0).
    Flat(Vec<FlatSlot>),
}

/// What a worker did with one [`EngineWork`]. `failed` carries the
/// request id of every job/slot whose batch died (engine error or
/// panic) — one entry per job, so the dispatcher can return each one's
/// outstanding slot.
pub(crate) enum EngineDone {
    Dag {
        outcomes: Vec<UnitOutcome>,
        failed: Vec<u64>,
        error: Option<String>,
    },
    Flat {
        outcomes: Vec<FlatOutcome>,
        failed: Vec<u64>,
        error: Option<String>,
    },
}

/// Execute one work item against `engine`, panic-isolated. Runs on the
/// engine's worker thread (threaded mode) or inline on the dispatcher
/// (`serialize_engines` — the measured baseline the perf gate compares
/// against).
pub(crate) fn execute_work(engine: &Engine, stats: &EngineStats, work: EngineWork) -> EngineDone {
    match work {
        EngineWork::Dag(jobs) => {
            let ids: Vec<u64> = jobs.iter().map(|j| j.req).collect();
            match catch_unwind(AssertUnwindSafe(|| run_dag_batch(engine, stats, jobs))) {
                Ok((outcomes, failed, error)) => EngineDone::Dag {
                    outcomes,
                    failed,
                    error,
                },
                Err(_) => EngineDone::Dag {
                    outcomes: Vec::new(),
                    failed: ids,
                    error: Some("engine batch panicked".into()),
                },
            }
        }
        EngineWork::Flat(slots) => {
            let ids: Vec<u64> = slots.iter().map(|s| s.req.id).collect();
            match catch_unwind(AssertUnwindSafe(|| run_flat_batch(engine, stats, slots))) {
                Ok(Ok(outcomes)) => EngineDone::Flat {
                    outcomes,
                    failed: Vec::new(),
                    error: None,
                },
                Ok(Err((e, slots))) => EngineDone::Flat {
                    outcomes: Vec::new(),
                    failed: slots.iter().map(|s| s.req.id).collect(),
                    error: Some(e.to_string()),
                },
                Err(_) => EngineDone::Flat {
                    outcomes: Vec::new(),
                    failed: ids,
                    error: Some("engine batch panicked".into()),
                },
            }
        }
    }
}

/// Spawn the worker thread owning engine `index` of the pool: block on
/// the work queue, execute, report through `done` via `wrap` (the
/// server wraps each [`EngineDone`] into its unified event type). The
/// worker exits when every `EngineWork` sender is dropped (server
/// teardown) or the event channel closes.
pub(crate) fn spawn_engine_worker<E, F>(
    index: usize,
    engine: Arc<Engine>,
    stats: Arc<EngineStats>,
    rx: mpsc::Receiver<EngineWork>,
    done: mpsc::Sender<E>,
    wrap: F,
) -> thread::JoinHandle<()>
where
    E: Send + 'static,
    F: Fn(EngineDone) -> E + Send + 'static,
{
    thread::Builder::new()
        .name(format!("engine-worker-{index}"))
        .spawn(move || {
            while let Ok(work) = rx.recv() {
                let d = execute_work(&engine, &stats, work);
                if done.send(wrap(d)).is_err() {
                    break;
                }
            }
        })
        .expect("spawn engine worker")
}

/// Run one mixed batch of DAG phases: the engine's prefill ingests and
/// its decode rounds execute as separate batched passes (each pipeline
/// group is its own serialized resource). Returns (outcomes, failed
/// request ids, first error).
fn run_dag_batch(
    engine: &Engine,
    stats: &EngineStats,
    jobs: Vec<LlmJob>,
) -> (Vec<UnitOutcome>, Vec<u64>, Option<String>) {
    let mut pre = Vec::new();
    let mut dec = Vec::new();
    for j in jobs {
        match j.phase {
            LlmPhase::Prefill { .. } => pre.push(j),
            LlmPhase::Decode { .. } => dec.push(j),
        }
    }
    let mut outcomes = Vec::new();
    let mut failed = Vec::new();
    let mut error = None;
    if !pre.is_empty() {
        match run_prefill_phase(engine, stats, pre) {
            Ok(o) => outcomes.extend(o),
            Err((e, js)) => {
                failed.extend(js.iter().map(|j| j.req));
                error.get_or_insert(e.to_string());
            }
        }
    }
    if !dec.is_empty() {
        match run_decode_phase(engine, stats, dec) {
            Ok(o) => outcomes.extend(o),
            Err((e, js)) => {
                failed.extend(js.iter().map(|j| j.req));
                error.get_or_insert(e.to_string());
            }
        }
    }
    (outcomes, failed, error)
}

/// Context ingestion for a batch of prefill phases.
fn run_prefill_phase(
    engine: &Engine,
    stats: &EngineStats,
    jobs: Vec<LlmJob>,
) -> Result<Vec<UnitOutcome>, (Error, Vec<LlmJob>)> {
    let seq_budget = engine.manifest.prefill_seq;
    let prompts: Vec<Vec<u8>> = jobs
        .iter()
        .map(|j| match &j.phase {
            LlmPhase::Prefill { prompt } => clip_tail(prompt, seq_budget),
            LlmPhase::Decode { .. } => unreachable!("partitioned by phase"),
        })
        .collect();
    let t0 = Instant::now();
    if let Err(e) = engine.prefill(&prompts) {
        return Err((e, jobs));
    }
    let finished = Instant::now();
    stats.add_prefill(finished.duration_since(t0));
    Ok(jobs
        .into_iter()
        .map(|job| UnitOutcome {
            job,
            started: t0,
            finished,
            first_token: None,
            output: Vec::new(),
            tbt_sum_s: 0.0,
            tbt_n: 0,
        })
        .collect())
}

/// Decode rounds for a batch of decode phases: rebuild each lane's
/// context (the stand-in for adopting the transferred KV cache — the
/// synthetic state is a pure function of the context, so this
/// reconstructs exactly what the prefill engine held), sample the first
/// token, then continuous decode rounds until every lane hits its
/// budget.
fn run_decode_phase(
    engine: &Engine,
    stats: &EngineStats,
    jobs: Vec<LlmJob>,
) -> Result<Vec<UnitOutcome>, (Error, Vec<LlmJob>)> {
    let seq_budget = engine.manifest.prefill_seq;
    let mut prompts = Vec::with_capacity(jobs.len());
    let mut osls = Vec::with_capacity(jobs.len());
    for j in &jobs {
        match &j.phase {
            LlmPhase::Decode { prompt, osl } => {
                prompts.push(clip_tail(prompt, seq_budget));
                osls.push(*osl);
            }
            LlmPhase::Prefill { .. } => unreachable!("partitioned by phase"),
        }
    }
    let t0 = Instant::now();
    let pre = match engine.prefill(&prompts) {
        Ok(p) => p,
        Err(e) => return Err((e, jobs)),
    };
    let ctx_end = Instant::now();
    // KV adoption is decode-side work: charge it to the decode engine's
    // decode budget, not prefill.
    stats.add_decode(ctx_end.duration_since(t0));
    let mut kv = pre.kv;
    let n = jobs.len();

    let mut samplers: Vec<Sampler> = jobs
        .iter()
        .map(|j| {
            if j.temperature > 0.0 {
                Sampler::new(j.temperature, 0, j.req)
            } else {
                Sampler::greedy()
            }
        })
        .collect();
    let mut outputs: Vec<Vec<u8>> = vec![Vec::new(); n];
    let mut next: Vec<u8> = vec![0; kv.bucket.max(n)];
    let mut first_token: Vec<Option<Instant>> = vec![None; n];
    let mut last_token: Vec<Instant> = vec![ctx_end; n];
    let mut tbt_sum = vec![0.0f64; n];
    let mut tbt_n = vec![0u64; n];
    for i in 0..n {
        if osls[i] > 0 {
            let tok = samplers[i].sample(&pre.logits[i]) as u8;
            next[i] = tok;
            outputs[i].push(tok);
            first_token[i] = Some(ctx_end);
        }
    }
    let budget_cap = engine
        .manifest
        .max_seq
        .saturating_sub(seq_budget)
        .saturating_sub(1);
    let max_rounds = osls
        .iter()
        .map(|o| o.saturating_sub(1))
        .max()
        .unwrap_or(0)
        .min(budget_cap);
    for _round in 0..max_rounds {
        let t_r0 = Instant::now();
        let logits = match engine.decode_step(&mut kv, &next) {
            Ok(l) => l,
            Err(e) => return Err((e, jobs)),
        };
        let now = Instant::now();
        stats.add_decode(now.duration_since(t_r0));
        for i in 0..n {
            if outputs[i].len() >= osls[i] {
                continue;
            }
            let tok = samplers[i].sample(&logits[i]) as u8;
            next[i] = tok;
            outputs[i].push(tok);
            tbt_sum[i] += now.duration_since(last_token[i]).as_secs_f64();
            tbt_n[i] += 1;
            last_token[i] = now;
        }
    }

    let mut outcomes = Vec::with_capacity(n);
    for (i, job) in jobs.into_iter().enumerate() {
        outcomes.push(UnitOutcome {
            job,
            started: t0,
            finished: last_token[i],
            first_token: first_token[i],
            output: std::mem::take(&mut outputs[i]),
            tbt_sum_s: tbt_sum[i],
            tbt_n: tbt_n[i],
        });
    }
    Ok(outcomes)
}

/// Execute one flat prefill+decode batch to completion.
fn run_flat_batch(
    engine: &Engine,
    stats: &EngineStats,
    members: Vec<FlatSlot>,
) -> Result<Vec<FlatOutcome>, (Error, Vec<FlatSlot>)> {
    let prompts: Vec<Vec<u8>> = members.iter().map(|f| f.prompt.clone()).collect();
    let t_batch0 = Instant::now();
    let pre = match engine.prefill(&prompts) {
        Ok(p) => p,
        Err(e) => return Err((e, members)),
    };
    let t_prefill_end = Instant::now();
    stats.add_prefill(t_prefill_end.duration_since(t_batch0));
    let mut kv = pre.kv;
    let n = members.len();
    let bucket = kv.bucket;

    let mut samplers: Vec<Sampler> = members
        .iter()
        .map(|f| {
            if f.req.temperature > 0.0 {
                Sampler::new(f.req.temperature, 0, f.req.id)
            } else {
                Sampler::greedy()
            }
        })
        .collect();

    let mut outputs: Vec<Vec<u8>> = vec![Vec::new(); n];
    let mut first_token_at: Vec<Instant> = vec![t_batch0; n];
    let mut last_token_at: Vec<Instant> = vec![t_batch0; n];
    let mut gaps: Vec<Vec<f64>> = vec![Vec::new(); n];

    // First token from prefill logits (zero-budget requests emit
    // nothing, matching the DAG path's `osl > 0` guard).
    let now = Instant::now();
    let mut next: Vec<u8> = vec![0; bucket.max(n)];
    for i in 0..n {
        if members[i].req.max_new_tokens == 0 {
            continue;
        }
        let tok = samplers[i].sample(&pre.logits[i]) as u8;
        next[i] = tok;
        outputs[i].push(tok);
        first_token_at[i] = now;
        last_token_at[i] = now;
    }

    // Decode rounds until every member hit its budget (lanes that
    // finish keep feeding their last token; outputs stop growing).
    let seq_budget = engine.manifest.prefill_seq;
    let max_rounds = members
        .iter()
        .map(|f| f.req.max_new_tokens.saturating_sub(1))
        .max()
        .unwrap_or(0)
        .min(engine.manifest.max_seq - seq_budget - 1);
    for _round in 0..max_rounds {
        let t_r0 = Instant::now();
        let logits = match engine.decode_step(&mut kv, &next) {
            Ok(l) => l,
            Err(e) => return Err((e, members)),
        };
        let now = Instant::now();
        stats.add_decode(now.duration_since(t_r0));
        for i in 0..n {
            if outputs[i].len() >= members[i].req.max_new_tokens {
                continue;
            }
            let tok = samplers[i].sample(&logits[i]) as u8;
            next[i] = tok;
            outputs[i].push(tok);
            gaps[i].push(now.duration_since(last_token_at[i]).as_secs_f64());
            last_token_at[i] = now;
        }
    }

    let mut out = Vec::with_capacity(n);
    for (i, f) in members.into_iter().enumerate() {
        let ttft = first_token_at[i].duration_since(f.submitted).as_secs_f64();
        let e2e = last_token_at[i].duration_since(f.submitted).as_secs_f64();
        let tbt = if gaps[i].is_empty() {
            0.0
        } else {
            gaps[i].iter().sum::<f64>() / gaps[i].len() as f64
        };
        out.push(FlatOutcome {
            req: f.req,
            output: std::mem::take(&mut outputs[i]),
            ttft_s: ttft,
            tbt_mean_s: tbt,
            e2e_s: e2e,
        });
    }
    Ok(out)
}

/// Keep the most recent `budget` bytes of a prompt (the compiled prompt
/// bucket ingests the tail — most recent context wins).
pub(crate) fn clip_tail(prompt: &[u8], budget: usize) -> Vec<u8> {
    if prompt.len() > budget {
        prompt[prompt.len() - budget..].to_vec()
    } else {
        prompt.to_vec()
    }
}

#[cfg(test)]
#[cfg(not(feature = "pjrt"))]
mod tests {
    use super::*;

    #[test]
    fn worker_executes_and_reports_through_wrap() {
        let engine = Arc::new(Engine::synthetic_default());
        let stats = Arc::new(EngineStats::default());
        let (work_tx, work_rx) = mpsc::channel();
        let (done_tx, done_rx) = mpsc::channel();
        let h = spawn_engine_worker(0, Arc::clone(&engine), Arc::clone(&stats), work_rx, done_tx, |d| d);
        work_tx
            .send(EngineWork::Flat(vec![FlatSlot {
                req: ChatRequest::new(7, "hello worker", 4),
                submitted: Instant::now(),
                prompt: b"hello worker".to_vec(),
            }]))
            .unwrap();
        let done = done_rx.recv_timeout(Duration::from_secs(10)).unwrap();
        match done {
            EngineDone::Flat { outcomes, failed, error } => {
                assert!(failed.is_empty());
                assert!(error.is_none());
                assert_eq!(outcomes.len(), 1);
                assert_eq!(outcomes[0].req.id, 7);
                assert_eq!(outcomes[0].output.len(), 4);
                assert!(outcomes[0].e2e_s >= outcomes[0].ttft_s);
            }
            EngineDone::Dag { .. } => panic!("flat work must yield a flat outcome"),
        }
        let (p, d) = stats.busy_ns();
        assert!(p > 0, "prefill busy time must be measured");
        assert!(d > 0, "decode busy time must be measured");
        drop(work_tx);
        h.join().unwrap();
    }

    #[test]
    fn flat_outputs_match_generate_semantics_across_batch_shapes() {
        // Lanes are independent in the synthetic engine: batch
        // composition must not change any request's tokens. This is the
        // invariant that makes threaded serving deterministic.
        let engine = Engine::synthetic_default();
        let stats = EngineStats::default();
        let solo = |id: u64, prompt: &str| {
            let r = run_flat_batch(
                &engine,
                &stats,
                vec![FlatSlot {
                    req: ChatRequest::new(id, prompt, 6),
                    submitted: Instant::now(),
                    prompt: prompt.as_bytes().to_vec(),
                }],
            )
            .unwrap();
            r.into_iter().next().unwrap().output
        };
        let a = solo(1, "first prompt");
        let b = solo(2, "second prompt longer");
        let batched = run_flat_batch(
            &engine,
            &stats,
            vec![
                FlatSlot {
                    req: ChatRequest::new(1, "first prompt", 6),
                    submitted: Instant::now(),
                    prompt: b"first prompt".to_vec(),
                },
                FlatSlot {
                    req: ChatRequest::new(2, "second prompt longer", 6),
                    submitted: Instant::now(),
                    prompt: b"second prompt longer".to_vec(),
                },
            ],
        )
        .unwrap();
        assert_eq!(batched[0].output, a);
        assert_eq!(batched[1].output, b);
    }

    #[test]
    fn empty_batch_fails_closed_with_ids() {
        // The synthetic engine rejects empty prefill batches; the ids
        // must come back so the dispatcher can fail those requests.
        let engine = Engine::synthetic_default();
        let stats = EngineStats::default();
        let done = execute_work(
            &engine,
            &stats,
            EngineWork::Flat(vec![FlatSlot {
                req: ChatRequest::new(3, "", 0),
                submitted: Instant::now(),
                prompt: Vec::new(),
            }]),
        );
        // A 1-slot batch with an empty prompt still prefills (prompt
        // bytes are hashed, len 0 is fine) — build a genuinely failing
        // case via a zero-length batch instead.
        match done {
            EngineDone::Flat { outcomes, .. } => assert_eq!(outcomes.len(), 1),
            EngineDone::Dag { .. } => panic!("wrong arm"),
        }
        match execute_work(&engine, &stats, EngineWork::Flat(Vec::new())) {
            EngineDone::Flat { outcomes, failed, error } => {
                assert!(outcomes.is_empty());
                assert!(failed.is_empty());
                assert!(error.is_some(), "empty batch is an engine error");
            }
            EngineDone::Dag { .. } => panic!("wrong arm"),
        }
    }
}
