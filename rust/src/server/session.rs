//! Multi-turn session store: conversation history → prompt assembly.
//!
//! §4.1 "Composability: facilitates multi-turn interactions activated
//! through repeated API calls or system state changes." History is
//! byte-level (matching the tiny model); prompt assembly keeps the most
//! recent `budget` bytes so the compiled prompt bucket always fits.

use std::collections::BTreeMap;

/// One session's transcript.
#[derive(Debug, Clone, Default)]
pub struct Session {
    pub history: Vec<u8>,
    pub turns: u32,
}

/// Thread-compatible session store (callers wrap in a mutex when shared).
#[derive(Debug, Default)]
pub struct SessionStore {
    sessions: BTreeMap<u64, Session>,
    /// Cap on stored history per session, bytes.
    pub max_history: usize,
}

impl SessionStore {
    pub fn new(max_history: usize) -> SessionStore {
        SessionStore {
            sessions: BTreeMap::new(),
            max_history,
        }
    }

    /// Assemble the model prompt for a turn: recent history + new input,
    /// trimmed from the front to `budget` bytes.
    pub fn assemble(&self, session: Option<u64>, input: &[u8], budget: usize) -> Vec<u8> {
        let mut prompt = Vec::with_capacity(budget);
        if let Some(sid) = session {
            if let Some(s) = self.sessions.get(&sid) {
                prompt.extend_from_slice(&s.history);
            }
        }
        prompt.extend_from_slice(input);
        if prompt.len() > budget {
            prompt.drain(..prompt.len() - budget);
        }
        prompt
    }

    /// Record a completed turn (user input + model output).
    pub fn record_turn(&mut self, session: u64, input: &[u8], output: &[u8]) {
        let s = self.sessions.entry(session).or_default();
        s.history.extend_from_slice(input);
        s.history.extend_from_slice(output);
        s.turns += 1;
        if s.history.len() > self.max_history {
            let overflow = s.history.len() - self.max_history;
            s.history.drain(..overflow);
        }
    }

    pub fn turns(&self, session: u64) -> u32 {
        self.sessions.get(&session).map(|s| s.turns).unwrap_or(0)
    }

    pub fn drop_session(&mut self, session: u64) -> bool {
        self.sessions.remove(&session).is_some()
    }

    pub fn len(&self) -> usize {
        self.sessions.len()
    }

    pub fn is_empty(&self) -> bool {
        self.sessions.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stateless_assembly_is_input() {
        let s = SessionStore::new(1024);
        assert_eq!(s.assemble(None, b"hello", 64), b"hello");
    }

    #[test]
    fn history_prepended_and_trimmed() {
        let mut s = SessionStore::new(1024);
        s.record_turn(1, b"hi ", b"there ");
        let p = s.assemble(Some(1), b"again", 64);
        assert_eq!(p, b"hi there again");
        // Tight budget keeps the tail.
        let p = s.assemble(Some(1), b"again", 8);
        assert_eq!(p.len(), 8);
        assert!(p.ends_with(b"again"));
    }

    #[test]
    fn history_capped() {
        let mut s = SessionStore::new(10);
        s.record_turn(1, b"0123456789", b"abcdefghij");
        let p = s.assemble(Some(1), b"", 100);
        assert_eq!(p, b"abcdefghij");
        assert_eq!(s.turns(1), 1);
    }

    #[test]
    fn sessions_isolated() {
        let mut s = SessionStore::new(100);
        s.record_turn(1, b"a", b"b");
        s.record_turn(2, b"x", b"y");
        assert_eq!(s.assemble(Some(1), b"", 10), b"ab");
        assert_eq!(s.assemble(Some(2), b"", 10), b"xy");
        assert!(s.drop_session(1));
        assert!(!s.drop_session(1));
        assert_eq!(s.len(), 1);
    }
}
