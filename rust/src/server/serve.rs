//! The serving loop: channel-fed requests → admission → continuous
//! batcher → PJRT prefill/decode → responses with SLA metrics — and,
//! when an [`ExecutionPlan`] is installed, **full agent-DAG execution**:
//! a [`ChatRequest`] carrying an agent class traverses every plan
//! binding, with CPU/tool/IO stages on the bounded [`HostPool`] and LLM
//! stages batched onto the engine pool, mirroring the DAG simulator
//! (`cluster/dag.rs`) in wall-clock time.
//!
//! Threading model (tokio is unavailable offline; see also
//! ARCHITECTURE.md "Threading model"):
//!
//! * **Engine workers** — one thread per pool engine
//!   ([`crate::server::engine_exec`]), each owning its own work queue.
//!   Engines on different threads execute truly concurrently, so the
//!   pipeline groups of a plan overlap in wall-clock — the property the
//!   paper's heterogeneous fleets need to realize their planned
//!   throughput. `ServerConfig::serialize_engines` forces the old
//!   inline execution (the measured baseline the perf gate compares
//!   against).
//! * **Host workers** — the bounded [`HostPool`] for CPU/tool/IO
//!   stages, unchanged.
//! * **The dispatcher** — the thread calling [`Server::serve`]: pure
//!   admission + batching + completion routing. It blocks on ONE
//!   unified event channel (intake, host completions, engine
//!   completions) with `recv`/`recv_timeout` deadlines from batcher
//!   waits and modeled-transfer timers — an idle server burns ~0 CPU.
//! * **An intake forwarder** — a short-lived thread per `serve` call
//!   that moves the caller's request receiver into the unified event
//!   stream, so the dispatcher has a single blocking point.

// The dispatcher is a hot path serving live traffic: a panic here takes
// the whole server down, so unwrap/expect are banned outside tests —
// failures must flow into typed `Error`s or failed responses.
#![deny(clippy::unwrap_used, clippy::expect_used)]

use std::sync::mpsc;
use std::sync::Arc;
use std::time::Instant;

use crate::obs::trace::TraceSink;
use crate::obs::{Counter, Histogram, MetricsRegistry};
use crate::plan::{ExecutionPlan, Role};
use crate::router::admission::{Admission, AdmissionConfig, AdmissionController};
use crate::router::batcher::{Batcher, BatcherConfig};
use crate::runtime::Engine;
use crate::server::dag_exec::{DagDispatch, DagRuntime, HostFault, Step};
use crate::server::engine_exec::{self, EngineDone, EngineStats, EngineWork, FlatSlot};
use crate::server::hostpool::{HostDone, HostPool};
use crate::server::request::{ChatRequest, ChatResponse};
use crate::server::session::SessionStore;
use crate::{Error, Result};

/// Server knobs (subset of [`crate::config::DeployConfig`]).
#[derive(Debug, Clone)]
pub struct ServerConfig {
    pub batch: BatcherConfig,
    pub admission: AdmissionConfig,
    /// Hard cap on generated tokens per request.
    pub max_new_tokens: usize,
    /// History budget per session, bytes.
    pub max_history: usize,
    /// Host worker pool size for the CPU/tool/IO stages of agent DAGs
    /// (derived from the plan's `cpu_workers`).
    pub host_workers: u32,
    /// Wall-clock seconds per modeled second for host-stage latencies
    /// and cross-chassis edge transfers (tests shrink it to run fast).
    pub time_scale: f64,
    /// Execute engine batches inline on the dispatcher thread instead
    /// of on the per-engine workers. This is the pre-threading behavior
    /// kept as a measured A/B baseline: the live-throughput gate proves
    /// the worker pool beats it on multi-group plans.
    pub serialize_engines: bool,
    /// Cross-step prefix-KV reuse for agent-DAG prefills: keep real
    /// paged prefix state per prefill group (the simulator's exact
    /// accounting engine), route repeated contexts via the prefix-hit
    /// router, and prefill only uncached suffixes. Off by default —
    /// reuse-off serving is byte-identical to before the feature.
    pub kv_reuse: bool,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            batch: BatcherConfig::default(),
            admission: AdmissionConfig::default(),
            max_new_tokens: 24,
            max_history: 256,
            host_workers: 4,
            time_scale: 1.0,
            serialize_engines: false,
            kv_reuse: false,
        }
    }
}

impl ServerConfig {
    /// Derive the serving knobs from an
    /// [`ExecutionPlan`](crate::plan::ExecutionPlan): the batcher
    /// (buckets, wait, decode cap — the planner aligns the cap with the
    /// planned decode pipelines), the admission token bucket, and the
    /// host-pool sizing all come from the same artifact the simulator
    /// executed. Engine-bound limits (max tokens, history) stay server
    /// defaults: they follow the compiled artifact set, not the plan.
    pub fn from_plan(plan: &ExecutionPlan) -> ServerConfig {
        ServerConfig {
            batch: plan.batcher_config(),
            admission: plan.admission_config(),
            host_workers: plan.cpu_workers,
            ..ServerConfig::default()
        }
    }
}

/// Batcher payload: classic flat requests and agent-DAG LLM units share
/// the same continuous batchers (and therefore the same engine batches).
enum Work {
    Flat(FlatSlot),
    Dag(crate::server::dag_exec::LlmJob),
}

/// Everything the dispatcher can be woken by, merged onto one channel
/// so it can block instead of spinning.
enum Event {
    /// A request arrived (relayed by the intake forwarder); the instant
    /// is when it entered the event stream — admission wait for spans.
    Intake(ChatRequest, Instant),
    /// The caller's request channel disconnected.
    IntakeClosed,
    /// A host-pool stage finished.
    Host(HostDone),
    /// An engine worker finished a batch.
    Engine(EngineDone),
}

/// Response-side plumbing shared by every dispatch site in the loop.
struct Sinks<'a> {
    tx: &'a mpsc::Sender<ChatResponse>,
    m_tok: Arc<Counter>,
    h_ttft: Arc<Histogram>,
    h_e2e: Arc<Histogram>,
}

impl Sinks<'_> {
    /// Route a dispatcher step: jobs to their engine's batcher,
    /// responses out.
    fn drain(&self, step: Step, batchers: &mut [Batcher<Work>]) {
        let n = batchers.len();
        for j in step.jobs {
            let e = j.engine.min(n - 1);
            batchers[e].push(Work::Dag(j));
        }
        for r in step.responses {
            self.send(r);
        }
    }

    fn send(&self, r: ChatResponse) {
        // Rejections/failures carry no meaningful latency — recording
        // their zeros would improve p50/p95 the more load is shed.
        if r.is_ok() {
            self.m_tok.add(r.tokens as u64);
            self.h_ttft.record_secs(r.ttft_s);
            self.h_e2e.record_secs(r.e2e_s);
        }
        let _ = self.tx.send(r);
    }
}

/// The serving coordinator.
pub struct Server {
    /// The engine pool: one engine per plan pipeline group (groups wrap
    /// round-robin when the pool is smaller; a single-engine pool hosts
    /// every group). The flat request path always runs on `engines[0]`.
    engines: Vec<Arc<Engine>>,
    /// Per-engine busy-time atomics, written by the worker threads.
    engine_stats: Vec<Arc<EngineStats>>,
    /// busy_ns already handed out by `take_utilization`, per engine
    /// (the windowing cursor over the cumulative counters).
    engine_taken_ns: Vec<(u64, u64)>,
    /// Per-engine work queues into the worker threads.
    engine_tx: Vec<mpsc::Sender<EngineWork>>,
    engine_handles: Vec<std::thread::JoinHandle<()>>,
    /// The unified dispatcher event channel. The sender half is cloned
    /// into the host-pool sink, the engine workers, and each serve
    /// call's intake forwarder.
    event_tx: mpsc::Sender<Event>,
    event_rx: mpsc::Receiver<Event>,
    cfg: ServerConfig,
    pub metrics: Arc<MetricsRegistry>,
    sessions: SessionStore,
    /// Plan-derived DAG execution structure (None = flat-only server).
    dag: Option<DagRuntime>,
    /// Host worker pool for CPU/tool/IO stages; persists across
    /// `serve` calls and resizes on reconfiguration.
    host: Option<HostPool>,
    fault: Option<HostFault>,
    /// Span recorder for the live DAG path (None = tracing off; the
    /// dispatcher then skips every span allocation).
    trace: Option<Arc<TraceSink>>,
}

impl Server {
    pub fn new(engine: impl Into<Arc<Engine>>, cfg: ServerConfig) -> Server {
        match Server::with_engines(vec![engine.into()], cfg) {
            Ok(server) => server,
            // with_engines only fails on an empty pool; one engine was
            // just passed.
            Err(_) => unreachable!("a one-engine pool is always valid"),
        }
    }

    /// Bring up a server over an explicit engine pool — the live
    /// counterpart of the plan's pipeline fleet: LLM stages are
    /// scheduled onto the engine their role's pipeline group is bound
    /// to (see [`DagRuntime::engine_of_group`]). One worker thread is
    /// spawned per engine and lives until the server drops.
    pub fn with_engines(engines: Vec<Arc<Engine>>, cfg: ServerConfig) -> Result<Server> {
        if engines.is_empty() {
            return Err(Error::Config("server needs ≥ 1 engine".into()));
        }
        let max_history = cfg.max_history;
        let n = engines.len();
        let (event_tx, event_rx) = mpsc::channel();
        let mut engine_stats = Vec::with_capacity(n);
        let mut engine_tx = Vec::with_capacity(n);
        let mut engine_handles = Vec::with_capacity(n);
        for (i, e) in engines.iter().enumerate() {
            let stats = Arc::new(EngineStats::default());
            let (wtx, wrx) = mpsc::channel();
            engine_handles.push(engine_exec::spawn_engine_worker(
                i,
                Arc::clone(e),
                Arc::clone(&stats),
                wrx,
                event_tx.clone(),
                Event::Engine,
            ));
            engine_stats.push(stats);
            engine_tx.push(wtx);
        }
        Ok(Server {
            engines,
            engine_stats,
            engine_taken_ns: vec![(0, 0); n],
            engine_tx,
            engine_handles,
            event_tx,
            event_rx,
            cfg,
            metrics: Arc::new(MetricsRegistry::new()),
            sessions: SessionStore::new(max_history),
            dag: None,
            host: None,
            fault: None,
            trace: None,
        })
    }

    /// Install a span recorder: every subsequent [`Server::serve`] call
    /// emits [`crate::obs::trace::Span`]s for each admitted agent
    /// request into it (host, prefill/decode, KV-transfer, and request
    /// envelope spans in modeled seconds — the same schema the DAG
    /// simulator records). No-op for the flat request path.
    pub fn set_trace_sink(&mut self, sink: Arc<TraceSink>) {
        self.trace = Some(sink);
    }

    /// Bring up a server configured by an execution plan (see
    /// [`ServerConfig::from_plan`]) with full agent-DAG execution
    /// installed: requests carrying the plan's agent class traverse
    /// every node binding.
    pub fn from_plan(
        engine: impl Into<Arc<Engine>>,
        plan: &ExecutionPlan,
    ) -> Result<Server> {
        Server::from_plan_with_engines(vec![engine.into()], plan)
    }

    /// [`Server::from_plan`] over an explicit engine pool.
    pub fn from_plan_with_engines(
        engines: Vec<Arc<Engine>>,
        plan: &ExecutionPlan,
    ) -> Result<Server> {
        let mut server = Server::with_engines(engines, ServerConfig::from_plan(plan))?;
        server.install_plan(plan)?;
        Ok(server)
    }

    /// Install (or swap) the agent-DAG execution structure derived from
    /// `plan`, bringing the host pool to `cfg.host_workers`. Fails
    /// before any state changes if the plan cannot execute live.
    pub fn install_plan(&mut self, plan: &ExecutionPlan) -> Result<()> {
        let rt = DagRuntime::new(plan, self.cfg.time_scale, self.engines.len())?;
        self.install_runtime(rt);
        Ok(())
    }

    fn install_runtime(&mut self, rt: DagRuntime) {
        match self.host.as_mut() {
            Some(pool) => pool.resize(self.cfg.host_workers.max(1) as usize),
            None => {
                // Host completions feed the unified event channel
                // directly — no side channel for the dispatcher to
                // poll. The Mutex makes the sender shareable across the
                // pool's workers (mpsc senders are not Sync on older
                // toolchains); completions are low-rate, so the lock is
                // uncontended.
                let tx = std::sync::Mutex::new(self.event_tx.clone());
                self.host = Some(HostPool::with_sink(
                    self.cfg.host_workers.max(1) as usize,
                    move |d| {
                        let guard = tx.lock().unwrap_or_else(|e| e.into_inner());
                        let _ = guard.send(Event::Host(d));
                    },
                ));
            }
        }
        self.dag = Some(rt);
    }

    /// Swap the serving policy between workloads — the orchestrator's
    /// live backend applies each re-planned `ExecutionPlan` this way.
    /// Batcher and admission take effect at the next [`Server::serve`] /
    /// [`Server::run_workload`] call; the host pool resizes immediately
    /// to the new config's `host_workers` (the sizing the new plan
    /// derived from its `cpu_workers`). Sessions and metrics persist
    /// across the swap.
    pub fn reconfigure(&mut self, cfg: ServerConfig) {
        self.sessions.max_history = cfg.max_history;
        if let Some(pool) = self.host.as_mut() {
            pool.resize(cfg.host_workers.max(1) as usize);
        }
        self.cfg = cfg;
    }

    /// Full live re-plan: serving policy *and* the DAG execution
    /// structure (topology, units, virtual fleet, host-pool sizing)
    /// follow the new plan. Engine-bound limits, the time scale, and
    /// the dispatch mode are preserved from the current config.
    /// All-or-nothing: an unexecutable plan fails before any policy or
    /// pool state changes.
    pub fn reconfigure_plan(&mut self, plan: &ExecutionPlan) -> Result<()> {
        let mut cfg = ServerConfig::from_plan(plan);
        cfg.max_new_tokens = self.cfg.max_new_tokens;
        cfg.max_history = self.cfg.max_history;
        cfg.time_scale = self.cfg.time_scale;
        cfg.serialize_engines = self.cfg.serialize_engines;
        cfg.kv_reuse = self.cfg.kv_reuse;
        let rt = DagRuntime::new(plan, cfg.time_scale, self.engines.len())?;
        self.reconfigure(cfg);
        self.install_runtime(rt);
        Ok(())
    }

    /// The active serving configuration.
    pub fn config(&self) -> &ServerConfig {
        &self.cfg
    }

    /// The installed execution plan, if any.
    pub fn plan(&self) -> Option<&ExecutionPlan> {
        self.dag.as_ref().map(|rt| &rt.plan)
    }

    /// Current host-pool capacity (None = no plan installed).
    pub fn host_capacity(&self) -> Option<usize> {
        self.host.as_ref().map(|p| p.capacity())
    }

    /// Max concurrently-running host stages ever observed.
    pub fn host_high_watermark(&self) -> u64 {
        self.host.as_ref().map(|p| p.high_watermark()).unwrap_or(0)
    }

    /// Install a host-stage fault hook (`(op, request id) -> fail?`) —
    /// failure-injection tests prove a failing tool node terminates
    /// only its request.
    pub fn inject_host_fault(
        &mut self,
        f: impl Fn(&str, u64) -> bool + Send + Sync + 'static,
    ) {
        self.fault = Some(Arc::new(f));
    }

    /// Number of engines in the pool.
    pub fn engine_count(&self) -> usize {
        self.engines.len()
    }

    /// Engines hosting ≥ 1 pipeline group of each role — the per-role
    /// utilization denominators. (1, 1) when no plan is installed: the
    /// flat path runs everything on engine 0.
    fn role_engine_counts(&self) -> (usize, usize) {
        match &self.dag {
            Some(rt) => {
                let mut pre = std::collections::BTreeSet::new();
                let mut dec = std::collections::BTreeSet::new();
                for (g, p) in rt.plan.pipelines.iter().enumerate() {
                    let e = rt.engine_of_group.get(g).copied().unwrap_or(0);
                    match p.role {
                        Role::Prefill => {
                            pre.insert(e);
                        }
                        Role::Decode => {
                            dec.insert(e);
                        }
                    }
                }
                (pre.len().max(1), dec.len().max(1))
            }
            None => (1, 1),
        }
    }

    /// Per-engine (prefill, decode) busy seconds accumulated since the
    /// last [`Server::take_utilization`] — the delta between each
    /// worker thread's cumulative atomics and the windowing cursor.
    fn engine_busy_window(&self) -> Vec<(f64, f64)> {
        self.engine_stats
            .iter()
            .zip(self.engine_taken_ns.iter())
            .map(|(s, taken)| {
                let (p, d) = s.busy_ns();
                (
                    p.saturating_sub(taken.0) as f64 / 1e9,
                    d.saturating_sub(taken.1) as f64 / 1e9,
                )
            })
            .collect()
    }

    /// Measured per-**engine** busy fractions over the last `window_s`
    /// seconds: (prefill, decode) per pool engine, from each worker
    /// thread's measured execution time. Read-only — call before
    /// [`Server::take_utilization`], which resets the window.
    pub fn engine_utilization(&self, window_s: f64) -> Vec<(f64, f64)> {
        let w = window_s.max(1e-9);
        self.engine_busy_window()
            .iter()
            .map(|b| ((b.0 / w).clamp(0.0, 1.0), (b.1 / w).clamp(0.0, 1.0)))
            .collect()
    }

    /// Measured busy fraction per **plan pipeline group** over the last
    /// `window_s` seconds, aligned with the installed plan's
    /// `pipelines` order: each group reads the (prefill or decode) half
    /// of the engine it is bound to, so the orchestrator's group
    /// signals name which hardware generation is hot. Groups sharing an
    /// engine read the same signal (the pool wraps round-robin). Empty
    /// when no plan is installed. Read-only — call before
    /// [`Server::take_utilization`], which resets the window.
    pub fn group_utilization(&self, window_s: f64) -> Vec<f64> {
        let w = window_s.max(1e-9);
        match &self.dag {
            Some(rt) => {
                let busy = self.engine_busy_window();
                rt.plan
                    .pipelines
                    .iter()
                    .enumerate()
                    .map(|(g, p)| {
                        let e = rt.engine_of_group.get(g).copied().unwrap_or(0);
                        let b = busy.get(e).copied().unwrap_or((0.0, 0.0));
                        let busy = match p.role {
                            Role::Prefill => b.0,
                            Role::Decode => b.1,
                        };
                        (busy / w).clamp(0.0, 1.0)
                    })
                    .collect()
            }
            None => Vec::new(),
        }
    }

    /// Measured per-role utilization over the last `window_s` seconds:
    /// (prefill, decode, host) busy fractions, from each engine worker
    /// thread's timed stage execution (normalized by the engines
    /// actually serving that role) and the host pool's worker
    /// busy-time. Resets the accumulators — the orchestrator's live
    /// backend calls this once per observation window.
    pub fn take_utilization(&mut self, window_s: f64) -> (f64, f64, f64) {
        let w = window_s.max(1e-9);
        let (pre_n, dec_n) = self.role_engine_counts();
        let busy = self.engine_busy_window();
        let pre_busy: f64 = busy.iter().map(|b| b.0).sum();
        let dec_busy: f64 = busy.iter().map(|b| b.1).sum();
        for (s, taken) in self.engine_stats.iter().zip(self.engine_taken_ns.iter_mut()) {
            *taken = s.busy_ns();
        }
        let pre = (pre_busy / (w * pre_n as f64)).clamp(0.0, 1.0);
        let dec = (dec_busy / (w * dec_n as f64)).clamp(0.0, 1.0);
        let host = match self.host.as_mut() {
            Some(p) => {
                let cap = p.capacity().max(1) as f64;
                (p.take_busy_seconds() / (w * cap)).clamp(0.0, 1.0)
            }
            None => 0.0,
        };
        (pre, dec, host)
    }

    /// Route one released batch to its engine: the worker's queue in
    /// threaded mode, inline execution under `serialize_engines` (the
    /// completion still round-trips through the event channel, so both
    /// modes share one code path downstream).
    fn dispatch_engine_work(&self, e: usize, work: EngineWork) {
        if self.cfg.serialize_engines {
            let done =
                engine_exec::execute_work(&self.engines[e], &self.engine_stats[e], work);
            let _ = self.event_tx.send(Event::Engine(done));
            return;
        }
        if let Err(mpsc::SendError(work)) = self.engine_tx[e].send(work) {
            // A dead worker is unreachable by construction (batch
            // execution is panic-isolated); degrade to inline execution
            // rather than dropping requests if it ever happens.
            let done =
                engine_exec::execute_work(&self.engines[e], &self.engine_stats[e], work);
            let _ = self.event_tx.send(Event::Engine(done));
        }
    }

    /// Serve until `rx` disconnects and all queued work drains. Designed
    /// to run on a dedicated thread; responses go out through `tx`.
    ///
    /// Drain ordering at exit: the loop returns only once intake is
    /// closed, every flat request has answered, every DAG request has
    /// settled, and every engine batch in flight has been consumed off
    /// the event channel — so no completion from this session can leak
    /// into a later `serve` call (admission epochs additionally guard
    /// host completions, which can outlive a session only on failure
    /// teardown paths).
    pub fn serve(
        &mut self,
        rx: mpsc::Receiver<ChatRequest>,
        tx: mpsc::Sender<ChatResponse>,
    ) -> Result<()> {
        let n_engines = self.engines.len();
        let mut batchers: Vec<Batcher<Work>> = (0..n_engines)
            .map(|_| Batcher::new(self.cfg.batch.clone()))
            .collect();
        let mut admission = AdmissionController::new(self.cfg.admission.clone());
        let m_req = self.metrics.counter("server_requests");
        let m_rej = self.metrics.counter("server_rejected");
        let m_batches = self.metrics.counter("server_batches");
        let g_depth = self.metrics.gauge("server_queue_depth");
        let g_host_queue = self.metrics.gauge("server_host_queue");
        let sinks = Sinks {
            tx: &tx,
            m_tok: self.metrics.counter("server_tokens_out"),
            h_ttft: self.metrics.histogram("server_ttft"),
            h_e2e: self.metrics.histogram("server_e2e"),
        };
        let mut dispatch = self.dag.as_ref().map(|rt| {
            DagDispatch::new(
                rt,
                self.metrics.clone(),
                self.fault.clone(),
                self.trace.clone(),
                self.cfg.kv_reuse,
            )
        });
        let seq_budget = self.engines[0].manifest.prefill_seq;
        let max_wait = self.cfg.batch.max_wait;

        // Intake forwarder: merge the caller's request channel into the
        // unified event stream so the dispatcher blocks on ONE receiver.
        let intake_tx = self.event_tx.clone();
        let forwarder = std::thread::spawn(move || {
            for req in rx.iter() {
                if intake_tx.send(Event::Intake(req, Instant::now())).is_err() {
                    return;
                }
            }
            let _ = intake_tx.send(Event::IntakeClosed);
        });

        let mut open = true;
        // Flat requests admitted but not yet answered (queued + on an
        // engine). DAG requests are admission-counted once via
        // `dispatch.in_flight()`; counting their queued LLM units too
        // would double-charge them.
        let mut flat_open = 0usize;
        // Engine batches sent but not yet reported back.
        let mut engine_inflight = 0usize;
        let mut pending: Option<Event> = None;
        loop {
            // ---- consume every available event ----------------------
            loop {
                let ev = match pending.take() {
                    Some(ev) => ev,
                    None => match self.event_rx.try_recv() {
                        Ok(ev) => ev,
                        Err(_) => break,
                    },
                };
                match ev {
                    Event::Intake(req, received) => {
                        m_req.inc();
                        // Queue depth covers both execution paths: open
                        // flat requests plus admitted-but-unfinished
                        // DAG requests (host-heavy plans never touch
                        // the batcher, yet must still shed load; each
                        // DAG request is counted exactly once).
                        let depth =
                            flat_open + dispatch.as_ref().map_or(0, |d| d.in_flight());
                        match admission.admit(Instant::now(), depth) {
                            Admission::Accept => {
                                if req.agent.is_some() {
                                    self.admit_dag(
                                        req,
                                        received,
                                        &mut dispatch,
                                        &sinks,
                                        &mut batchers,
                                    );
                                } else {
                                    flat_open += 1;
                                    let prompt = self.sessions.assemble(
                                        req.session,
                                        &req.prompt,
                                        seq_budget,
                                    );
                                    batchers[0].push(Work::Flat(FlatSlot {
                                        req,
                                        submitted: Instant::now(),
                                        prompt,
                                    }));
                                }
                            }
                            _ => {
                                m_rej.inc();
                                sinks.send(ChatResponse::rejected(req.id));
                            }
                        }
                    }
                    Event::IntakeClosed => open = false,
                    Event::Host(hd) => {
                        if let (Some(rt), Some(d), Some(pool)) =
                            (self.dag.as_ref(), dispatch.as_mut(), self.host.as_ref())
                        {
                            let step = d.on_host_done(rt, hd, pool);
                            sinks.drain(step, &mut batchers);
                        }
                    }
                    Event::Engine(done) => {
                        engine_inflight = engine_inflight.saturating_sub(1);
                        match done {
                            EngineDone::Dag { outcomes, failed, error } => {
                                if let (Some(rt), Some(d), Some(pool)) = (
                                    self.dag.as_ref(),
                                    dispatch.as_mut(),
                                    self.host.as_ref(),
                                ) {
                                    if !outcomes.is_empty() {
                                        let step = d.finish_units(rt, outcomes, pool);
                                        sinks.drain(step, &mut batchers);
                                    }
                                    if !failed.is_empty() {
                                        let msg = error
                                            .unwrap_or_else(|| "engine failure".into());
                                        let step = d.fail_engine_jobs(
                                            &failed,
                                            &msg,
                                            Instant::now(),
                                        );
                                        sinks.drain(step, &mut batchers);
                                    }
                                }
                            }
                            EngineDone::Flat { outcomes, failed, error } => {
                                for o in outcomes {
                                    flat_open = flat_open.saturating_sub(1);
                                    if let Some(sid) = o.req.session {
                                        self.sessions.record_turn(
                                            sid,
                                            &o.req.prompt,
                                            &o.output,
                                        );
                                    }
                                    let tokens = o.output.len();
                                    sinks.send(ChatResponse {
                                        id: o.req.id,
                                        output: o.output,
                                        ttft_s: o.ttft_s,
                                        tbt_mean_s: o.tbt_mean_s,
                                        e2e_s: o.e2e_s,
                                        tokens,
                                        rejected: false,
                                        failed: false,
                                        error: None,
                                        stages: Vec::new(),
                                        kv_hop_bytes: 0.0,
                                    });
                                }
                                if !failed.is_empty() {
                                    let msg =
                                        error.unwrap_or_else(|| "engine failure".into());
                                    for id in failed {
                                        flat_open = flat_open.saturating_sub(1);
                                        sinks.send(ChatResponse::failed(
                                            id,
                                            0.0,
                                            msg.clone(),
                                        ));
                                    }
                                }
                            }
                        }
                    }
                }
            }

            // ---- due modeled transfers ------------------------------
            if let (Some(rt), Some(d), Some(pool)) =
                (self.dag.as_ref(), dispatch.as_mut(), self.host.as_ref())
            {
                let step = d.poll_timers(rt, Instant::now(), pool);
                sinks.drain(step, &mut batchers);
                g_host_queue.set(pool.queued() as f64);
            }

            // ---- release due batches to the engines -----------------
            let now = Instant::now();
            for (e, batcher) in batchers.iter_mut().enumerate() {
                while let Some(batch) = batcher.poll(now) {
                    m_batches.inc();
                    let mut flat = Vec::new();
                    let mut dag = Vec::new();
                    for w in batch.members {
                        match w {
                            Work::Flat(s) => flat.push(s),
                            Work::Dag(j) => dag.push(j),
                        }
                    }
                    if !flat.is_empty() {
                        engine_inflight += 1;
                        self.dispatch_engine_work(e, EngineWork::Flat(flat));
                    }
                    if !dag.is_empty() {
                        engine_inflight += 1;
                        self.dispatch_engine_work(e, EngineWork::Dag(dag));
                    }
                }
            }
            g_depth.set(
                (flat_open + dispatch.as_ref().map_or(0, |d| d.in_flight())) as f64,
            );

            // ---- exit -----------------------------------------------
            let dag_in_flight = dispatch.as_ref().map_or(0, |d| d.in_flight());
            if !open
                && flat_open == 0
                && dag_in_flight == 0
                && engine_inflight == 0
                && batchers.iter().all(|b| b.is_empty())
            {
                break;
            }

            // ---- block until the next event or deadline -------------
            // Deadlines: the earliest pending modeled-transfer arrival
            // and each non-empty batcher's max-wait expiry. With
            // neither, block indefinitely — engine/host completions and
            // intake all arrive as events, so an idle server burns no
            // CPU (this replaces the old 200 µs busy-sleep).
            let now = Instant::now();
            let mut deadline: Option<Instant> =
                dispatch.as_ref().and_then(|d| d.next_timer_due());
            for b in &batchers {
                if !b.is_empty() {
                    let due = now + max_wait.saturating_sub(b.head_wait(now));
                    deadline = Some(match deadline {
                        Some(d) => d.min(due),
                        None => due,
                    });
                }
            }
            match deadline {
                Some(d) => {
                    let wait = d.saturating_duration_since(now);
                    if !wait.is_zero() {
                        if let Ok(ev) = self.event_rx.recv_timeout(wait) {
                            pending = Some(ev);
                        }
                    }
                }
                None => match self.event_rx.recv() {
                    Ok(ev) => pending = Some(ev),
                    // Unreachable (we hold a sender), but not worth
                    // spinning on if it ever happens.
                    Err(_) => break,
                },
            }
        }
        let _ = forwarder.join();
        Ok(())
    }

    /// Intake path for an agent-class request. `received` is when the
    /// request entered the event stream (admission wait for spans).
    fn admit_dag(
        &self,
        req: ChatRequest,
        received: Instant,
        dispatch: &mut Option<DagDispatch>,
        sinks: &Sinks<'_>,
        batchers: &mut [Batcher<Work>],
    ) {
        let (rt, d) = match (self.dag.as_ref(), dispatch.as_mut()) {
            (Some(rt), Some(d))
                if req.agent.as_deref() == Some(rt.plan.agent.as_str()) =>
            {
                (rt, d)
            }
            _ => {
                let agent = req.agent.clone().unwrap_or_default();
                sinks.send(ChatResponse::failed(
                    req.id,
                    0.0,
                    format!("no installed plan serves agent `{agent}`"),
                ));
                return;
            }
        };
        // Duplicate in-flight ids would cross-apply host completions
        // between requests; fail the newcomer closed instead.
        if d.contains(req.id) {
            sinks.send(ChatResponse::failed(
                req.id,
                0.0,
                format!("request id {} is already in flight", req.id),
            ));
            return;
        }
        let Some(pool) = self.host.as_ref() else {
            sinks.send(ChatResponse::failed(
                req.id,
                0.0,
                "plan runtime has no host pool installed".to_string(),
            ));
            return;
        };
        let step = d.admit(rt, req, Instant::now(), received, pool);
        sinks.drain(step, batchers);
    }

    /// Synchronous convenience: submit a fixed workload, get responses.
    pub fn run_workload(&mut self, requests: Vec<ChatRequest>) -> Result<Vec<ChatResponse>> {
        let (req_tx, req_rx) = mpsc::channel();
        let (resp_tx, resp_rx) = mpsc::channel();
        for r in requests {
            // The receiver is held locally, so the send cannot fail.
            let _ = req_tx.send(r);
        }
        drop(req_tx);
        self.serve(req_rx, resp_tx)?;
        let mut out: Vec<ChatResponse> = resp_rx.into_iter().collect();
        out.sort_by_key(|r| r.id);
        Ok(out)
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        // Closing the work queues retires the engine workers; join so
        // no worker outlives the engines/stats it borrows. (The host
        // pool joins its own workers in its Drop.)
        self.engine_tx.clear();
        for h in self.engine_handles.drain(..) {
            let _ = h.join();
        }
    }
}

// Engine-backed tests live in rust/tests/runtime_e2e.rs (need artifacts)
// and rust/tests/sim_vs_live.rs + rust/tests/stress_serve.rs (synthetic
// engine, non-pjrt builds).

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)]
mod tests {
    use super::*;

    #[test]
    fn server_config_derives_from_plan() {
        let plan = crate::plan::tests::tiny_plan();
        let cfg = ServerConfig::from_plan(&plan);
        assert_eq!(cfg.batch.buckets, plan.batching.buckets);
        assert_eq!(cfg.batch.max_decode_batch, plan.batching.max_decode_batch);
        assert_eq!(cfg.admission.rate, plan.admission.rate);
        assert_eq!(cfg.admission.burst, plan.admission.burst);
        assert_eq!(
            cfg.admission.max_queue_depth,
            plan.admission.max_queue_depth
        );
        assert_eq!(cfg.host_workers, plan.cpu_workers);
        // Engine-independent defaults survive.
        assert_eq!(cfg.max_new_tokens, ServerConfig::default().max_new_tokens);
        assert!(!cfg.serialize_engines, "threaded dispatch is the default");
    }

    #[test]
    #[cfg(not(feature = "pjrt"))]
    fn reconfigure_swaps_policy_between_requests() {
        use crate::runtime::Engine;

        let engine = Engine::synthetic_default();
        let mut server = Server::new(engine, ServerConfig::default());
        assert_eq!(server.config().admission.rate, 1000.0);

        let mut plan = crate::plan::tests::tiny_plan();
        plan.admission.rate = 333.0;
        plan.batching.max_decode_batch = 9;
        server.reconfigure(ServerConfig::from_plan(&plan));
        assert_eq!(server.config().admission.rate, 333.0);
        assert_eq!(server.config().batch.max_decode_batch, 9);
        assert_eq!(server.sessions.max_history, ServerConfig::default().max_history);
    }

    /// Regression (PR 3): reconfiguration must also swap the host-pool
    /// sizing derived from the new plan, not just batcher/admission.
    #[test]
    #[cfg(not(feature = "pjrt"))]
    fn reconfigure_resizes_host_pool_from_plan() {
        use crate::runtime::Engine;

        let mut plan_a = crate::plan::tests::tiny_plan();
        plan_a.cpu_workers = 2;
        let mut server =
            Server::from_plan(Engine::synthetic_default(), &plan_a).unwrap();
        assert_eq!(server.host_capacity(), Some(2));

        let mut plan_b = crate::plan::tests::tiny_plan();
        plan_b.cpu_workers = 6;
        server.reconfigure(ServerConfig::from_plan(&plan_b));
        assert_eq!(
            server.host_capacity(),
            Some(6),
            "host pool must follow the new plan's cpu_workers"
        );

        // And the full-plan path keeps pool + DAG structure in step.
        let mut plan_c = crate::plan::tests::tiny_plan();
        plan_c.cpu_workers = 3;
        server.reconfigure_plan(&plan_c).unwrap();
        assert_eq!(server.host_capacity(), Some(3));
        assert_eq!(server.plan().unwrap().cpu_workers, 3);
    }

    #[test]
    #[cfg(not(feature = "pjrt"))]
    fn dag_workload_runs_end_to_end_on_engine_pool() {
        use crate::runtime::Engine;

        let mut plan = crate::plan::tests::tiny_plan();
        plan.cpu_workers = 2;
        // Two engines: the prefill group and the decode group each get
        // their own worker thread (the multi-engine scheduling path).
        let mut server =
            Server::from_plan_with_engines(Engine::synthetic_pool(2), &plan).unwrap();
        assert_eq!(server.engine_count(), 2);
        // Keep modeled sleeps/transfers tiny so the test is fast.
        let mut cfg = server.config().clone();
        cfg.time_scale = 1e-3;
        server.reconfigure(cfg);
        server.install_plan(&plan).unwrap();

        let reqs: Vec<ChatRequest> = (0..6u64)
            .map(|i| {
                ChatRequest::new(i, format!("request {i} says "), 8)
                    .with_agent(plan.agent.clone())
            })
            .collect();
        let responses = server.run_workload(reqs).unwrap();
        assert_eq!(responses.len(), 6);
        let m = crate::cost::model_profile::llama3_8b(crate::cost::Precision::Fp16);
        for r in &responses {
            assert!(r.is_ok(), "{:?}", r.error);
            assert_eq!(r.tokens, 8, "decode budget must be honoured");
            assert_eq!(r.stages.len(), 4, "all four plan nodes must run");
            assert!(r.e2e_s >= r.ttft_s);
            assert!(r.ttft_s > 0.0);
            // Prefill (chassis 0) → decode (chassis 1/2) is a real
            // cross-chassis KV handoff, charged per request.
            let expect_kv =
                crate::cost::kv::kv_cache_bytes(&m, "request 0 says ".len() as u64, 1);
            assert!(
                (r.kv_hop_bytes - expect_kv).abs() < 1.0,
                "kv hop {} vs expected {expect_kv}",
                r.kv_hop_bytes
            );
            // Dependency order: each stage starts at/after its
            // predecessors end (cpu → prefill → decode → cpu).
            let by_node: std::collections::BTreeMap<usize, _> =
                r.stages.iter().map(|s| (s.node, s)).collect();
            assert!(by_node[&0].end_s <= by_node[&1].start_s + 1e-9);
            assert!(by_node[&1].end_s <= by_node[&2].start_s + 1e-9);
            assert!(by_node[&2].end_s <= by_node[&3].start_s + 1e-9);
        }
        // Per-role execution counters: one prefill, one decode, two
        // cpu stages per request.
        let snap = server.metrics.snapshot();
        assert_eq!(snap["server_prefill_jobs"], 6.0);
        assert_eq!(snap["server_decode_jobs"], 6.0);
        assert_eq!(snap["server_host_jobs"], 12.0);
        // Per-group ledger: every LLM job attributed to its pipeline
        // group's shape key (the cross-backend parity counters).
        assert_eq!(snap["server_group_jobs:prefill H100 tp1 pp1 b8"], 6.0);
        assert_eq!(snap["server_group_jobs:decode Gaudi3 tp1 pp1 b32"], 6.0);
        // Per-group utilization aligns with the plan's groups (read
        // before take_utilization resets the window).
        let gu = server.group_utilization(1.0);
        assert_eq!(gu.len(), 2);
        assert!(gu.iter().all(|u| (0.0..=1.0).contains(u)));
        // Measured utilization is live and sane.
        let (pre, dec, host) = server.take_utilization(1.0);
        assert!((0.0..=1.0).contains(&pre));
        assert!((0.0..=1.0).contains(&dec));
        assert!(host > 0.0, "host pool did run stages");
        assert!(host <= 1.0);
        assert!(server.host_high_watermark() <= 2);
    }

    /// The serialized fallback runs the same workload through the same
    /// event plumbing, just inline — and produces identical tokens.
    #[test]
    #[cfg(not(feature = "pjrt"))]
    fn serialized_dispatch_matches_threaded_outputs() {
        use crate::runtime::Engine;

        let mut plan = crate::plan::tests::tiny_plan();
        plan.cpu_workers = 2;
        let run = |serialize: bool| {
            let mut server =
                Server::from_plan_with_engines(Engine::synthetic_pool(2), &plan).unwrap();
            let mut cfg = server.config().clone();
            cfg.time_scale = 1e-3;
            cfg.serialize_engines = serialize;
            server.reconfigure(cfg);
            server.install_plan(&plan).unwrap();
            let reqs: Vec<ChatRequest> = (0..4u64)
                .map(|i| {
                    ChatRequest::new(i, format!("req {i}"), 6).with_agent(plan.agent.clone())
                })
                .collect();
            server
                .run_workload(reqs)
                .unwrap()
                .into_iter()
                .map(|r| (r.id, r.output, r.kv_hop_bytes))
                .collect::<Vec<_>>()
        };
        assert_eq!(run(true), run(false));
    }

    /// Windowed utilization accounting: busy time reported once, then
    /// the cursor advances (the PR 5 autoscalers rely on this).
    #[test]
    #[cfg(not(feature = "pjrt"))]
    fn take_utilization_windows_engine_busy_time() {
        use crate::runtime::Engine;

        let mut server = Server::new(Engine::synthetic_default(), ServerConfig::default());
        let reqs: Vec<ChatRequest> =
            (0..3u64).map(|i| ChatRequest::new(i, "measure me", 6)).collect();
        let responses = server.run_workload(reqs).unwrap();
        assert_eq!(responses.len(), 3);
        assert!(responses.iter().all(|r| r.is_ok()));
        let eu = server.engine_utilization(1.0);
        assert_eq!(eu.len(), 1);
        assert!(eu[0].0 > 0.0, "prefill busy time must be measured");
        assert!(eu[0].1 > 0.0, "decode busy time must be measured");
        let (pre, dec, _) = server.take_utilization(1.0);
        assert!(pre > 0.0 && dec > 0.0);
        // Window reset: nothing ran since the take.
        let (pre2, dec2, _) = server.take_utilization(1.0);
        assert_eq!((pre2, dec2), (0.0, 0.0));
    }

    #[test]
    #[cfg(not(feature = "pjrt"))]
    fn agent_request_without_plan_fails_closed() {
        use crate::runtime::Engine;

        let mut server = Server::new(Engine::synthetic_default(), ServerConfig::default());
        let req = ChatRequest::new(1, "hi", 4).with_agent("ghost_agent");
        let responses = server.run_workload(vec![req]).unwrap();
        assert_eq!(responses.len(), 1);
        assert!(responses[0].failed);
        assert!(responses[0]
            .error
            .as_deref()
            .unwrap()
            .contains("ghost_agent"));
    }
}
