//! The serving loop: channel-fed requests → admission → continuous
//! batcher → PJRT prefill/decode → responses with SLA metrics — and,
//! when an [`ExecutionPlan`] is installed, **full agent-DAG execution**:
//! a [`ChatRequest`] carrying an agent class traverses every plan
//! binding, with CPU/tool/IO stages on the bounded [`HostPool`] and LLM
//! stages batched onto the engine, mirroring the DAG simulator
//! (`cluster/dag.rs`) in wall-clock time.
//!
//! Threading model (tokio is unavailable offline): callers submit
//! [`ChatRequest`]s on an `mpsc::Sender` from any number of threads;
//! one dispatcher thread owns the **engine pool** (one engine per plan
//! pipeline group — the "one compiled executable per model variant"
//! runtime of the paper's design, replicated per group) and runs the
//! event loop (intake → host completions → contended transfer timers →
//! per-engine batch execution); host stages run on the pool's worker
//! threads and report back over a completion channel.

use std::sync::mpsc;
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::obs::{Counter, Histogram, MetricsRegistry};
use crate::plan::{ExecutionPlan, Role};
use crate::router::admission::{Admission, AdmissionConfig, AdmissionController};
use crate::router::batcher::{Batcher, BatcherConfig};
use crate::runtime::{Engine, Sampler};
use crate::server::dag_exec::{
    DagDispatch, DagRuntime, HostFault, LlmJob, LlmPhase, Step, UnitOutcome,
};
use crate::server::hostpool::HostPool;
use crate::server::request::{ChatRequest, ChatResponse};
use crate::server::session::SessionStore;
use crate::{Error, Result};

/// Server knobs (subset of [`crate::config::DeployConfig`]).
#[derive(Debug, Clone)]
pub struct ServerConfig {
    pub batch: BatcherConfig,
    pub admission: AdmissionConfig,
    /// Hard cap on generated tokens per request.
    pub max_new_tokens: usize,
    /// History budget per session, bytes.
    pub max_history: usize,
    /// Host worker pool size for the CPU/tool/IO stages of agent DAGs
    /// (derived from the plan's `cpu_workers`).
    pub host_workers: u32,
    /// Wall-clock seconds per modeled second for host-stage latencies
    /// and cross-chassis edge transfers (tests shrink it to run fast).
    pub time_scale: f64,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            batch: BatcherConfig::default(),
            admission: AdmissionConfig::default(),
            max_new_tokens: 24,
            max_history: 256,
            host_workers: 4,
            time_scale: 1.0,
        }
    }
}

impl ServerConfig {
    /// Derive the serving knobs from an
    /// [`ExecutionPlan`](crate::plan::ExecutionPlan): the batcher
    /// (buckets, wait, decode cap — the planner aligns the cap with the
    /// planned decode pipelines), the admission token bucket, and the
    /// host-pool sizing all come from the same artifact the simulator
    /// executed. Engine-bound limits (max tokens, history) stay server
    /// defaults: they follow the compiled artifact set, not the plan.
    pub fn from_plan(plan: &ExecutionPlan) -> ServerConfig {
        ServerConfig {
            batch: plan.batcher_config(),
            admission: plan.admission_config(),
            host_workers: plan.cpu_workers,
            ..ServerConfig::default()
        }
    }
}

struct InFlight {
    req: ChatRequest,
    submitted: Instant,
}

/// Batcher payload: classic flat requests and agent-DAG LLM units share
/// the same continuous batcher (and therefore the same engine batches).
enum Work {
    Flat(InFlight),
    Dag(LlmJob),
}

/// Response-side plumbing shared by every dispatch site in the loop.
struct Sinks<'a> {
    tx: &'a mpsc::Sender<ChatResponse>,
    m_tok: Arc<Counter>,
    h_ttft: Arc<Histogram>,
    h_e2e: Arc<Histogram>,
}

impl Sinks<'_> {
    /// Route a dispatcher step: jobs to the batcher, responses out.
    fn drain(&self, step: Step, batcher: &mut Batcher<Work>) -> bool {
        let progressed = !step.jobs.is_empty() || !step.responses.is_empty();
        for j in step.jobs {
            batcher.push(Work::Dag(j));
        }
        for r in step.responses {
            self.send(r);
        }
        progressed
    }

    fn send(&self, r: ChatResponse) {
        // Rejections/failures carry no meaningful latency — recording
        // their zeros would improve p50/p95 the more load is shed.
        if r.is_ok() {
            self.m_tok.add(r.tokens as u64);
            self.h_ttft.record_secs(r.ttft_s);
            self.h_e2e.record_secs(r.e2e_s);
        }
        let _ = self.tx.send(r);
    }
}

/// The serving coordinator.
pub struct Server {
    /// The engine pool: one engine per plan pipeline group (groups wrap
    /// round-robin when the pool is smaller; a single-engine pool hosts
    /// every group). The flat request path always runs on `engines[0]`.
    engines: Vec<Arc<Engine>>,
    cfg: ServerConfig,
    pub metrics: Arc<MetricsRegistry>,
    sessions: SessionStore,
    /// Plan-derived DAG execution structure (None = flat-only server).
    dag: Option<DagRuntime>,
    /// Host worker pool for CPU/tool/IO stages; persists across
    /// `serve` calls and resizes on reconfiguration.
    host: Option<HostPool>,
    host_done: Option<mpsc::Receiver<crate::server::hostpool::HostDone>>,
    fault: Option<HostFault>,
    /// Per-engine (prefill, decode) busy-second accumulators since the
    /// last [`Server::take_utilization`] (measured, wall-clock).
    engine_busy: Vec<(f64, f64)>,
}

impl Server {
    pub fn new(engine: impl Into<Arc<Engine>>, cfg: ServerConfig) -> Server {
        Server::with_engines(vec![engine.into()], cfg)
            .expect("a one-engine pool is always valid")
    }

    /// Bring up a server over an explicit engine pool — the live
    /// counterpart of the plan's pipeline fleet: LLM stages are
    /// scheduled onto the engine their role's pipeline group is bound
    /// to (see [`DagRuntime::engine_of_group`]).
    pub fn with_engines(engines: Vec<Arc<Engine>>, cfg: ServerConfig) -> Result<Server> {
        if engines.is_empty() {
            return Err(Error::Config("server needs ≥ 1 engine".into()));
        }
        let max_history = cfg.max_history;
        let n = engines.len();
        Ok(Server {
            engines,
            cfg,
            metrics: Arc::new(MetricsRegistry::new()),
            sessions: SessionStore::new(max_history),
            dag: None,
            host: None,
            host_done: None,
            fault: None,
            engine_busy: vec![(0.0, 0.0); n],
        })
    }

    /// Bring up a server configured by an execution plan (see
    /// [`ServerConfig::from_plan`]) with full agent-DAG execution
    /// installed: requests carrying the plan's agent class traverse
    /// every node binding.
    pub fn from_plan(
        engine: impl Into<Arc<Engine>>,
        plan: &ExecutionPlan,
    ) -> Result<Server> {
        Server::from_plan_with_engines(vec![engine.into()], plan)
    }

    /// [`Server::from_plan`] over an explicit engine pool.
    pub fn from_plan_with_engines(
        engines: Vec<Arc<Engine>>,
        plan: &ExecutionPlan,
    ) -> Result<Server> {
        let mut server = Server::with_engines(engines, ServerConfig::from_plan(plan))?;
        server.install_plan(plan)?;
        Ok(server)
    }

    /// Install (or swap) the agent-DAG execution structure derived from
    /// `plan`, bringing the host pool to `cfg.host_workers`. Fails
    /// before any state changes if the plan cannot execute live.
    pub fn install_plan(&mut self, plan: &ExecutionPlan) -> Result<()> {
        let rt = DagRuntime::new(plan, self.cfg.time_scale, self.engines.len())?;
        self.install_runtime(rt);
        Ok(())
    }

    fn install_runtime(&mut self, rt: DagRuntime) {
        match self.host.as_mut() {
            Some(pool) => pool.resize(self.cfg.host_workers.max(1) as usize),
            None => {
                let (done_tx, done_rx) = mpsc::channel();
                self.host = Some(HostPool::new(
                    self.cfg.host_workers.max(1) as usize,
                    done_tx,
                ));
                self.host_done = Some(done_rx);
            }
        }
        self.dag = Some(rt);
    }

    /// Swap the serving policy between workloads — the orchestrator's
    /// live backend applies each re-planned `ExecutionPlan` this way.
    /// Batcher and admission take effect at the next [`Server::serve`] /
    /// [`Server::run_workload`] call; the host pool resizes immediately
    /// to the new config's `host_workers` (the sizing the new plan
    /// derived from its `cpu_workers`). Sessions and metrics persist
    /// across the swap.
    pub fn reconfigure(&mut self, cfg: ServerConfig) {
        self.sessions.max_history = cfg.max_history;
        if let Some(pool) = self.host.as_mut() {
            pool.resize(cfg.host_workers.max(1) as usize);
        }
        self.cfg = cfg;
    }

    /// Full live re-plan: serving policy *and* the DAG execution
    /// structure (topology, units, virtual fleet, host-pool sizing)
    /// follow the new plan. Engine-bound limits and the time scale are
    /// preserved from the current config. All-or-nothing: an
    /// unexecutable plan fails before any policy or pool state changes.
    pub fn reconfigure_plan(&mut self, plan: &ExecutionPlan) -> Result<()> {
        let mut cfg = ServerConfig::from_plan(plan);
        cfg.max_new_tokens = self.cfg.max_new_tokens;
        cfg.max_history = self.cfg.max_history;
        cfg.time_scale = self.cfg.time_scale;
        let rt = DagRuntime::new(plan, cfg.time_scale, self.engines.len())?;
        self.reconfigure(cfg);
        self.install_runtime(rt);
        Ok(())
    }

    /// The active serving configuration.
    pub fn config(&self) -> &ServerConfig {
        &self.cfg
    }

    /// The installed execution plan, if any.
    pub fn plan(&self) -> Option<&ExecutionPlan> {
        self.dag.as_ref().map(|rt| &rt.plan)
    }

    /// Current host-pool capacity (None = no plan installed).
    pub fn host_capacity(&self) -> Option<usize> {
        self.host.as_ref().map(|p| p.capacity())
    }

    /// Max concurrently-running host stages ever observed.
    pub fn host_high_watermark(&self) -> u64 {
        self.host.as_ref().map(|p| p.high_watermark()).unwrap_or(0)
    }

    /// Install a host-stage fault hook (`(op, request id) -> fail?`) —
    /// failure-injection tests prove a failing tool node terminates
    /// only its request.
    pub fn inject_host_fault(
        &mut self,
        f: impl Fn(&str, u64) -> bool + Send + Sync + 'static,
    ) {
        self.fault = Some(Arc::new(f));
    }

    /// Number of engines in the pool.
    pub fn engine_count(&self) -> usize {
        self.engines.len()
    }

    /// Engines hosting ≥ 1 pipeline group of each role — the per-role
    /// utilization denominators. (1, 1) when no plan is installed: the
    /// flat path runs everything on engine 0.
    fn role_engine_counts(&self) -> (usize, usize) {
        match &self.dag {
            Some(rt) => {
                let mut pre = std::collections::BTreeSet::new();
                let mut dec = std::collections::BTreeSet::new();
                for (g, p) in rt.plan.pipelines.iter().enumerate() {
                    let e = rt.engine_of_group.get(g).copied().unwrap_or(0);
                    match p.role {
                        Role::Prefill => {
                            pre.insert(e);
                        }
                        Role::Decode => {
                            dec.insert(e);
                        }
                    }
                }
                (pre.len().max(1), dec.len().max(1))
            }
            None => (1, 1),
        }
    }

    /// Measured per-**engine** busy fractions over the last `window_s`
    /// seconds: (prefill, decode) per pool engine. Read-only — call
    /// before [`Server::take_utilization`], which resets the window.
    pub fn engine_utilization(&self, window_s: f64) -> Vec<(f64, f64)> {
        let w = window_s.max(1e-9);
        self.engine_busy
            .iter()
            .map(|b| ((b.0 / w).clamp(0.0, 1.0), (b.1 / w).clamp(0.0, 1.0)))
            .collect()
    }

    /// Measured busy fraction per **plan pipeline group** over the last
    /// `window_s` seconds, aligned with the installed plan's
    /// `pipelines` order: each group reads the (prefill or decode) half
    /// of the engine it is bound to, so the orchestrator's group
    /// signals name which hardware generation is hot. Groups sharing an
    /// engine read the same signal (the pool wraps round-robin). Empty
    /// when no plan is installed. Read-only — call before
    /// [`Server::take_utilization`], which resets the window.
    pub fn group_utilization(&self, window_s: f64) -> Vec<f64> {
        let w = window_s.max(1e-9);
        match &self.dag {
            Some(rt) => rt
                .plan
                .pipelines
                .iter()
                .enumerate()
                .map(|(g, p)| {
                    let e = rt.engine_of_group.get(g).copied().unwrap_or(0);
                    let b = self.engine_busy.get(e).copied().unwrap_or((0.0, 0.0));
                    let busy = match p.role {
                        Role::Prefill => b.0,
                        Role::Decode => b.1,
                    };
                    (busy / w).clamp(0.0, 1.0)
                })
                .collect(),
            None => Vec::new(),
        }
    }

    /// Measured per-role utilization over the last `window_s` seconds:
    /// (prefill, decode, host) busy fractions, from each engine's timed
    /// stage execution (normalized by the engines actually serving that
    /// role) and the host pool's worker busy-time. Resets the
    /// accumulators — the orchestrator's live backend calls this once
    /// per observation window.
    pub fn take_utilization(&mut self, window_s: f64) -> (f64, f64, f64) {
        let w = window_s.max(1e-9);
        let (pre_n, dec_n) = self.role_engine_counts();
        let pre_busy: f64 = self.engine_busy.iter().map(|b| b.0).sum();
        let dec_busy: f64 = self.engine_busy.iter().map(|b| b.1).sum();
        for b in self.engine_busy.iter_mut() {
            *b = (0.0, 0.0);
        }
        let pre = (pre_busy / (w * pre_n as f64)).clamp(0.0, 1.0);
        let dec = (dec_busy / (w * dec_n as f64)).clamp(0.0, 1.0);
        let host = match self.host.as_mut() {
            Some(p) => {
                let cap = p.capacity().max(1) as f64;
                (p.take_busy_seconds() / (w * cap)).clamp(0.0, 1.0)
            }
            None => 0.0,
        };
        (pre, dec, host)
    }

    /// Serve until `rx` disconnects and all queued work drains. Designed
    /// to run on a dedicated thread; responses go out through `tx`.
    pub fn serve(
        &mut self,
        rx: mpsc::Receiver<ChatRequest>,
        tx: mpsc::Sender<ChatResponse>,
    ) -> Result<()> {
        let mut batcher: Batcher<Work> = Batcher::new(self.cfg.batch.clone());
        let mut admission = AdmissionController::new(self.cfg.admission.clone());
        let m_req = self.metrics.counter("server_requests");
        let m_rej = self.metrics.counter("server_rejected");
        let m_batches = self.metrics.counter("server_batches");
        let g_depth = self.metrics.gauge("server_queue_depth");
        let g_host_queue = self.metrics.gauge("server_host_queue");
        let sinks = Sinks {
            tx: &tx,
            m_tok: self.metrics.counter("server_tokens_out"),
            h_ttft: self.metrics.histogram("server_ttft"),
            h_e2e: self.metrics.histogram("server_e2e"),
        };
        let mut dispatch = self
            .dag
            .as_ref()
            .map(|rt| DagDispatch::new(rt, self.metrics.clone(), self.fault.clone()));

        let mut open = true;
        // Flat requests waiting in the batcher (DAG requests are
        // admission-counted once via `dispatch.in_flight()`; counting
        // their queued LLM units too would double-charge them).
        let mut flat_queued = 0usize;
        loop {
            let mut progressed = false;
            // ---- intake: pull everything currently available (bounded
            // wait so batcher/transfer timeouts keep ticking) ---------
            loop {
                match rx.recv_timeout(Duration::from_millis(1)) {
                    Ok(req) => {
                        progressed = true;
                        m_req.inc();
                        // Queue depth covers both execution paths:
                        // flat requests queued for the engine plus
                        // admitted-but-unfinished DAG requests (host-
                        // heavy plans never touch the batcher, yet
                        // must still shed load; each DAG request is
                        // counted exactly once).
                        let depth = flat_queued
                            + dispatch.as_ref().map_or(0, |d| d.in_flight());
                        match admission.admit(Instant::now(), depth) {
                            Admission::Accept => {
                                if req.agent.is_some() {
                                    self.admit_dag(req, &mut dispatch, &sinks, &mut batcher);
                                } else {
                                    flat_queued += 1;
                                    batcher.push(Work::Flat(InFlight {
                                        req,
                                        submitted: Instant::now(),
                                    }));
                                }
                            }
                            _ => {
                                m_rej.inc();
                                sinks.send(ChatResponse::rejected(req.id));
                            }
                        }
                    }
                    Err(mpsc::RecvTimeoutError::Timeout) => break,
                    Err(mpsc::RecvTimeoutError::Disconnected) => {
                        open = false;
                        break;
                    }
                }
            }

            // ---- host-pool completions and modeled transfers --------
            if let (Some(rt), Some(d), Some(done_rx), Some(pool)) = (
                self.dag.as_ref(),
                dispatch.as_mut(),
                self.host_done.as_ref(),
                self.host.as_ref(),
            ) {
                while let Ok(hd) = done_rx.try_recv() {
                    progressed = true;
                    let step = d.on_host_done(rt, hd, pool);
                    sinks.drain(step, &mut batcher);
                }
                let step = d.poll_timers(rt, Instant::now(), pool);
                progressed |= sinks.drain(step, &mut batcher);
                g_host_queue.set(pool.queued() as f64);
            }
            g_depth.set(batcher.len() as f64);

            // ---- engine batch ---------------------------------------
            if let Some(batch) = batcher.poll(Instant::now()) {
                progressed = true;
                m_batches.inc();
                let mut flat = Vec::new();
                let mut dag = Vec::new();
                for w in batch.members {
                    match w {
                        Work::Flat(f) => flat.push(f),
                        Work::Dag(j) => dag.push(j),
                    }
                }
                flat_queued = flat_queued.saturating_sub(flat.len());
                if !flat.is_empty() {
                    for r in self.run_batch(flat)? {
                        sinks.send(r);
                    }
                }
                if !dag.is_empty() {
                    let outcomes = self.run_llm_batch(dag)?;
                    if let (Some(rt), Some(d), Some(pool)) =
                        (self.dag.as_ref(), dispatch.as_mut(), self.host.as_ref())
                    {
                        let step = d.finish_units(rt, outcomes, pool);
                        sinks.drain(step, &mut batcher);
                    }
                }
            }

            // ---- exit / idle ----------------------------------------
            let dag_in_flight = dispatch.as_ref().map_or(0, |d| d.in_flight());
            if !open && batcher.is_empty() && dag_in_flight == 0 {
                break;
            }
            if !progressed {
                // Waiting on host workers or a modeled transfer: park
                // briefly instead of spinning the dispatcher.
                std::thread::sleep(Duration::from_micros(200));
            }
        }
        Ok(())
    }

    /// Intake path for an agent-class request.
    fn admit_dag(
        &self,
        req: ChatRequest,
        dispatch: &mut Option<DagDispatch>,
        sinks: &Sinks<'_>,
        batcher: &mut Batcher<Work>,
    ) {
        let serveable = match (self.dag.as_ref(), dispatch.as_ref()) {
            (Some(rt), Some(_)) => req.agent.as_deref() == Some(rt.plan.agent.as_str()),
            _ => false,
        };
        if !serveable {
            let agent = req.agent.clone().unwrap_or_default();
            sinks.send(ChatResponse::failed(
                req.id,
                0.0,
                format!("no installed plan serves agent `{agent}`"),
            ));
            return;
        }
        // Duplicate in-flight ids would cross-apply host completions
        // between requests; fail the newcomer closed instead.
        if dispatch.as_ref().is_some_and(|d| d.contains(req.id)) {
            sinks.send(ChatResponse::failed(
                req.id,
                0.0,
                format!("request id {} is already in flight", req.id),
            ));
            return;
        }
        let rt = self.dag.as_ref().expect("checked above");
        let d = dispatch.as_mut().expect("checked above");
        let pool = self.host.as_ref().expect("plan install creates the pool");
        let step = d.admit(rt, req, Instant::now(), pool);
        sinks.drain(step, batcher);
    }

    /// Synchronous convenience: submit a fixed workload, get responses.
    pub fn run_workload(&mut self, requests: Vec<ChatRequest>) -> Result<Vec<ChatResponse>> {
        let (req_tx, req_rx) = mpsc::channel();
        let (resp_tx, resp_rx) = mpsc::channel();
        for r in requests {
            req_tx.send(r).unwrap();
        }
        drop(req_tx);
        self.serve(req_rx, resp_tx)?;
        let mut out: Vec<ChatResponse> = resp_rx.into_iter().collect();
        out.sort_by_key(|r| r.id);
        Ok(out)
    }

    /// Execute one flat prefill+decode batch to completion (always on
    /// engine 0 of the pool — the classic single-engine path).
    fn run_batch(&mut self, members: Vec<InFlight>) -> Result<Vec<ChatResponse>> {
        let engine = Arc::clone(&self.engines[0]);
        let seq_budget = engine.manifest.prefill_seq;
        let prompts: Vec<Vec<u8>> = members
            .iter()
            .map(|f| self.sessions.assemble(f.req.session, &f.req.prompt, seq_budget))
            .collect();
        let t_batch0 = Instant::now();
        let pre = engine.prefill(&prompts)?;
        let t_prefill_end = Instant::now();
        self.engine_busy[0].0 += t_prefill_end.duration_since(t_batch0).as_secs_f64();
        let mut kv = pre.kv;
        let n = members.len();
        let bucket = kv.bucket;

        let mut samplers: Vec<Sampler> = members
            .iter()
            .map(|f| {
                if f.req.temperature > 0.0 {
                    Sampler::new(f.req.temperature, 0, f.req.id)
                } else {
                    Sampler::greedy()
                }
            })
            .collect();

        let mut outputs: Vec<Vec<u8>> = vec![Vec::new(); n];
        let mut first_token_at: Vec<Instant> = vec![t_batch0; n];
        let mut last_token_at: Vec<Instant> = vec![t_batch0; n];
        let mut gaps: Vec<Vec<f64>> = vec![Vec::new(); n];

        // First token from prefill logits (zero-budget requests emit
        // nothing, matching the DAG path's `osl > 0` guard).
        let now = Instant::now();
        let mut next: Vec<u8> = vec![0; bucket.max(n)];
        for i in 0..n {
            if members[i].req.max_new_tokens == 0 {
                continue;
            }
            let tok = samplers[i].sample(&pre.logits[i]) as u8;
            next[i] = tok;
            outputs[i].push(tok);
            first_token_at[i] = now;
            last_token_at[i] = now;
        }

        // Decode rounds until every member hit its budget (lanes that
        // finish keep feeding their last token; outputs stop growing).
        let max_rounds = members
            .iter()
            .map(|f| f.req.max_new_tokens.saturating_sub(1))
            .max()
            .unwrap_or(0)
            .min(engine.manifest.max_seq - seq_budget - 1);
        for _round in 0..max_rounds {
            let t_r0 = Instant::now();
            let logits = engine.decode_step(&mut kv, &next)?;
            let now = Instant::now();
            self.engine_busy[0].1 += now.duration_since(t_r0).as_secs_f64();
            for i in 0..n {
                if outputs[i].len() >= members[i].req.max_new_tokens {
                    continue;
                }
                let tok = samplers[i].sample(&logits[i]) as u8;
                next[i] = tok;
                outputs[i].push(tok);
                gaps[i].push(now.duration_since(last_token_at[i]).as_secs_f64());
                last_token_at[i] = now;
            }
        }

        // Record sessions + build responses.
        let mut responses = Vec::with_capacity(n);
        for (i, f) in members.iter().enumerate() {
            if let Some(sid) = f.req.session {
                self.sessions.record_turn(sid, &f.req.prompt, &outputs[i]);
            }
            let ttft = first_token_at[i].duration_since(f.submitted).as_secs_f64();
            let e2e = last_token_at[i].duration_since(f.submitted).as_secs_f64();
            let tbt = if gaps[i].is_empty() {
                0.0
            } else {
                gaps[i].iter().sum::<f64>() / gaps[i].len() as f64
            };
            responses.push(ChatResponse {
                id: f.req.id,
                output: outputs[i].clone(),
                ttft_s: ttft,
                tbt_mean_s: tbt,
                e2e_s: e2e,
                tokens: outputs[i].len(),
                rejected: false,
                failed: false,
                error: None,
                stages: Vec::new(),
                kv_hop_bytes: 0.0,
            });
        }
        Ok(responses)
    }

    /// Execute one batch of agent-DAG LLM phases, partitioned per
    /// (engine, phase kind): every engine of the pool runs its prefill
    /// ingests and its decode rounds as separate batched passes — the
    /// live counterpart of "each pipeline group is its own serialized
    /// resource".
    fn run_llm_batch(&mut self, jobs: Vec<LlmJob>) -> Result<Vec<UnitOutcome>> {
        let n_engines = self.engines.len();
        let mut prefill: Vec<Vec<LlmJob>> = (0..n_engines).map(|_| Vec::new()).collect();
        let mut decode: Vec<Vec<LlmJob>> = (0..n_engines).map(|_| Vec::new()).collect();
        for j in jobs {
            let e = j.engine.min(n_engines - 1);
            match j.phase {
                LlmPhase::Prefill { .. } => prefill[e].push(j),
                LlmPhase::Decode { .. } => decode[e].push(j),
            }
        }
        let mut out = Vec::new();
        for e in 0..n_engines {
            let pre = std::mem::take(&mut prefill[e]);
            if !pre.is_empty() {
                out.extend(self.run_prefill_phase(e, pre)?);
            }
            let dec = std::mem::take(&mut decode[e]);
            if !dec.is_empty() {
                out.extend(self.run_decode_phase(e, dec)?);
            }
        }
        Ok(out)
    }

    /// Context ingestion for a batch of prefill phases on engine `e`.
    fn run_prefill_phase(&mut self, e: usize, jobs: Vec<LlmJob>) -> Result<Vec<UnitOutcome>> {
        let engine = Arc::clone(&self.engines[e]);
        let seq_budget = engine.manifest.prefill_seq;
        let prompts: Vec<Vec<u8>> = jobs
            .iter()
            .map(|j| match &j.phase {
                LlmPhase::Prefill { prompt } => clip_tail(prompt, seq_budget),
                LlmPhase::Decode { .. } => unreachable!("partitioned by phase"),
            })
            .collect();
        let t0 = Instant::now();
        engine.prefill(&prompts)?;
        let finished = Instant::now();
        self.engine_busy[e].0 += finished.duration_since(t0).as_secs_f64();
        Ok(jobs
            .into_iter()
            .map(|job| UnitOutcome {
                job,
                started: t0,
                finished,
                first_token: None,
                output: Vec::new(),
                tbt_sum_s: 0.0,
                tbt_n: 0,
            })
            .collect())
    }

    /// Decode rounds for a batch of decode phases on engine `e`:
    /// rebuild each lane's context (the stand-in for adopting the
    /// transferred KV cache — the synthetic state is a pure function of
    /// the context, so this reconstructs exactly what the prefill
    /// engine held), sample the first token, then continuous decode
    /// rounds until every lane hits its budget.
    fn run_decode_phase(&mut self, e: usize, jobs: Vec<LlmJob>) -> Result<Vec<UnitOutcome>> {
        let engine = Arc::clone(&self.engines[e]);
        let seq_budget = engine.manifest.prefill_seq;
        let mut prompts = Vec::with_capacity(jobs.len());
        let mut osls = Vec::with_capacity(jobs.len());
        for j in &jobs {
            match &j.phase {
                LlmPhase::Decode { prompt, osl } => {
                    prompts.push(clip_tail(prompt, seq_budget));
                    osls.push(*osl);
                }
                LlmPhase::Prefill { .. } => unreachable!("partitioned by phase"),
            }
        }
        let t0 = Instant::now();
        let pre = engine.prefill(&prompts)?;
        let ctx_end = Instant::now();
        // KV adoption is decode-side work: charge it to the decode
        // engine's decode budget, not prefill.
        self.engine_busy[e].1 += ctx_end.duration_since(t0).as_secs_f64();
        let mut kv = pre.kv;
        let n = jobs.len();

        let mut samplers: Vec<Sampler> = jobs
            .iter()
            .map(|j| {
                if j.temperature > 0.0 {
                    Sampler::new(j.temperature, 0, j.req)
                } else {
                    Sampler::greedy()
                }
            })
            .collect();
        let mut outputs: Vec<Vec<u8>> = vec![Vec::new(); n];
        let mut next: Vec<u8> = vec![0; kv.bucket.max(n)];
        let mut first_token: Vec<Option<Instant>> = vec![None; n];
        let mut last_token: Vec<Instant> = vec![ctx_end; n];
        let mut tbt_sum = vec![0.0f64; n];
        let mut tbt_n = vec![0u64; n];
        for i in 0..n {
            if osls[i] > 0 {
                let tok = samplers[i].sample(&pre.logits[i]) as u8;
                next[i] = tok;
                outputs[i].push(tok);
                first_token[i] = Some(ctx_end);
            }
        }
        let budget_cap = engine
            .manifest
            .max_seq
            .saturating_sub(seq_budget)
            .saturating_sub(1);
        let max_rounds = osls
            .iter()
            .map(|o| o.saturating_sub(1))
            .max()
            .unwrap_or(0)
            .min(budget_cap);
        for _round in 0..max_rounds {
            let t_r0 = Instant::now();
            let logits = engine.decode_step(&mut kv, &next)?;
            let now = Instant::now();
            self.engine_busy[e].1 += now.duration_since(t_r0).as_secs_f64();
            for i in 0..n {
                if outputs[i].len() >= osls[i] {
                    continue;
                }
                let tok = samplers[i].sample(&logits[i]) as u8;
                next[i] = tok;
                outputs[i].push(tok);
                tbt_sum[i] += now.duration_since(last_token[i]).as_secs_f64();
                tbt_n[i] += 1;
                last_token[i] = now;
            }
        }

        let mut outcomes = Vec::with_capacity(n);
        for (i, job) in jobs.into_iter().enumerate() {
            outcomes.push(UnitOutcome {
                job,
                started: t0,
                finished: last_token[i],
                first_token: first_token[i],
                output: std::mem::take(&mut outputs[i]),
                tbt_sum_s: tbt_sum[i],
                tbt_n: tbt_n[i],
            });
        }
        Ok(outcomes)
    }
}

/// Keep the most recent `budget` bytes of a prompt (the compiled prompt
/// bucket ingests the tail — most recent context wins).
fn clip_tail(prompt: &[u8], budget: usize) -> Vec<u8> {
    if prompt.len() > budget {
        prompt[prompt.len() - budget..].to_vec()
    } else {
        prompt.to_vec()
    }
}

// Engine-backed tests live in rust/tests/runtime_e2e.rs (need artifacts)
// and rust/tests/sim_vs_live.rs (synthetic engine, non-pjrt builds).

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn server_config_derives_from_plan() {
        let plan = crate::plan::tests::tiny_plan();
        let cfg = ServerConfig::from_plan(&plan);
        assert_eq!(cfg.batch.buckets, plan.batching.buckets);
        assert_eq!(cfg.batch.max_decode_batch, plan.batching.max_decode_batch);
        assert_eq!(cfg.admission.rate, plan.admission.rate);
        assert_eq!(cfg.admission.burst, plan.admission.burst);
        assert_eq!(
            cfg.admission.max_queue_depth,
            plan.admission.max_queue_depth
        );
        assert_eq!(cfg.host_workers, plan.cpu_workers);
        // Engine-independent defaults survive.
        assert_eq!(cfg.max_new_tokens, ServerConfig::default().max_new_tokens);
    }

    #[test]
    #[cfg(not(feature = "pjrt"))]
    fn reconfigure_swaps_policy_between_requests() {
        use crate::runtime::Engine;

        let engine = Engine::synthetic_default();
        let mut server = Server::new(engine, ServerConfig::default());
        assert_eq!(server.config().admission.rate, 1000.0);

        let mut plan = crate::plan::tests::tiny_plan();
        plan.admission.rate = 333.0;
        plan.batching.max_decode_batch = 9;
        server.reconfigure(ServerConfig::from_plan(&plan));
        assert_eq!(server.config().admission.rate, 333.0);
        assert_eq!(server.config().batch.max_decode_batch, 9);
        assert_eq!(server.sessions.max_history, ServerConfig::default().max_history);
    }

    /// Regression (PR 3): reconfiguration must also swap the host-pool
    /// sizing derived from the new plan, not just batcher/admission.
    #[test]
    #[cfg(not(feature = "pjrt"))]
    fn reconfigure_resizes_host_pool_from_plan() {
        use crate::runtime::Engine;

        let mut plan_a = crate::plan::tests::tiny_plan();
        plan_a.cpu_workers = 2;
        let mut server =
            Server::from_plan(Engine::synthetic_default(), &plan_a).unwrap();
        assert_eq!(server.host_capacity(), Some(2));

        let mut plan_b = crate::plan::tests::tiny_plan();
        plan_b.cpu_workers = 6;
        server.reconfigure(ServerConfig::from_plan(&plan_b));
        assert_eq!(
            server.host_capacity(),
            Some(6),
            "host pool must follow the new plan's cpu_workers"
        );

        // And the full-plan path keeps pool + DAG structure in step.
        let mut plan_c = crate::plan::tests::tiny_plan();
        plan_c.cpu_workers = 3;
        server.reconfigure_plan(&plan_c).unwrap();
        assert_eq!(server.host_capacity(), Some(3));
        assert_eq!(server.plan().unwrap().cpu_workers, 3);
    }

    #[test]
    #[cfg(not(feature = "pjrt"))]
    fn dag_workload_runs_end_to_end_on_engine_pool() {
        use crate::runtime::Engine;

        let mut plan = crate::plan::tests::tiny_plan();
        plan.cpu_workers = 2;
        // Two engines: the prefill group and the decode group each get
        // their own (the multi-engine scheduling path).
        let mut server =
            Server::from_plan_with_engines(Engine::synthetic_pool(2), &plan).unwrap();
        assert_eq!(server.engine_count(), 2);
        // Keep modeled sleeps/transfers tiny so the test is fast.
        let mut cfg = server.config().clone();
        cfg.time_scale = 1e-3;
        server.reconfigure(cfg);
        server.install_plan(&plan).unwrap();

        let reqs: Vec<ChatRequest> = (0..6u64)
            .map(|i| {
                ChatRequest::new(i, format!("request {i} says "), 8)
                    .with_agent(plan.agent.clone())
            })
            .collect();
        let responses = server.run_workload(reqs).unwrap();
        assert_eq!(responses.len(), 6);
        let m = crate::cost::model_profile::llama3_8b(crate::cost::Precision::Fp16);
        for r in &responses {
            assert!(r.is_ok(), "{:?}", r.error);
            assert_eq!(r.tokens, 8, "decode budget must be honoured");
            assert_eq!(r.stages.len(), 4, "all four plan nodes must run");
            assert!(r.e2e_s >= r.ttft_s);
            assert!(r.ttft_s > 0.0);
            // Prefill (chassis 0) → decode (chassis 1/2) is a real
            // cross-chassis KV handoff, charged per request.
            let expect_kv =
                crate::cost::kv::kv_cache_bytes(&m, "request 0 says ".len() as u64, 1);
            assert!(
                (r.kv_hop_bytes - expect_kv).abs() < 1.0,
                "kv hop {} vs expected {expect_kv}",
                r.kv_hop_bytes
            );
            // Dependency order: each stage starts at/after its
            // predecessors end (cpu → prefill → decode → cpu).
            let by_node: std::collections::BTreeMap<usize, _> =
                r.stages.iter().map(|s| (s.node, s)).collect();
            assert!(by_node[&0].end_s <= by_node[&1].start_s + 1e-9);
            assert!(by_node[&1].end_s <= by_node[&2].start_s + 1e-9);
            assert!(by_node[&2].end_s <= by_node[&3].start_s + 1e-9);
        }
        // Per-role execution counters: one prefill, one decode, two
        // cpu stages per request.
        let snap = server.metrics.snapshot();
        assert_eq!(snap["server_prefill_jobs"], 6.0);
        assert_eq!(snap["server_decode_jobs"], 6.0);
        assert_eq!(snap["server_host_jobs"], 12.0);
        // Per-group ledger: every LLM job attributed to its pipeline
        // group's shape key (the cross-backend parity counters).
        assert_eq!(snap["server_group_jobs:prefill H100 tp1 pp1 b8"], 6.0);
        assert_eq!(snap["server_group_jobs:decode Gaudi3 tp1 pp1 b32"], 6.0);
        // Per-group utilization aligns with the plan's groups (read
        // before take_utilization resets the window).
        let gu = server.group_utilization(1.0);
        assert_eq!(gu.len(), 2);
        assert!(gu.iter().all(|u| (0.0..=1.0).contains(u)));
        // Measured utilization is live and sane.
        let (pre, dec, host) = server.take_utilization(1.0);
        assert!((0.0..=1.0).contains(&pre));
        assert!((0.0..=1.0).contains(&dec));
        assert!(host > 0.0, "host pool did run stages");
        assert!(host <= 1.0);
        assert!(server.host_high_watermark() <= 2);
    }

    #[test]
    #[cfg(not(feature = "pjrt"))]
    fn agent_request_without_plan_fails_closed() {
        use crate::runtime::Engine;

        let mut server = Server::new(Engine::synthetic_default(), ServerConfig::default());
        let req = ChatRequest::new(1, "hi", 4).with_agent("ghost_agent");
        let responses = server.run_workload(vec![req]).unwrap();
        assert_eq!(responses.len(), 1);
        assert!(responses[0].failed);
        assert!(responses[0]
            .error
            .as_deref()
            .unwrap()
            .contains("ghost_agent"));
    }
}
