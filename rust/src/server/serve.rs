//! The serving loop: channel-fed requests → admission → continuous
//! batcher → PJRT prefill/decode → responses with SLA metrics.
//!
//! Threading model (tokio is unavailable offline): callers submit
//! [`ChatRequest`]s on an `mpsc::Sender` from any number of threads;
//! one dispatcher thread owns the engine and runs the batch loop;
//! responses return on a per-server `mpsc::Receiver`. The engine is the
//! serialized resource — exactly the "one compiled executable per model
//! variant" runtime of the paper's design.

use std::sync::mpsc;
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::obs::MetricsRegistry;
use crate::router::admission::{Admission, AdmissionConfig, AdmissionController};
use crate::router::batcher::{Batcher, BatcherConfig};
use crate::runtime::{Engine, Sampler};
use crate::server::request::{ChatRequest, ChatResponse};
use crate::server::session::SessionStore;
use crate::Result;

/// Server knobs (subset of [`crate::config::DeployConfig`]).
#[derive(Debug, Clone)]
pub struct ServerConfig {
    pub batch: BatcherConfig,
    pub admission: AdmissionConfig,
    /// Hard cap on generated tokens per request.
    pub max_new_tokens: usize,
    /// History budget per session, bytes.
    pub max_history: usize,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            batch: BatcherConfig::default(),
            admission: AdmissionConfig::default(),
            max_new_tokens: 24,
            max_history: 256,
        }
    }
}

impl ServerConfig {
    /// Derive the serving knobs from an
    /// [`ExecutionPlan`](crate::plan::ExecutionPlan): the batcher
    /// (buckets, wait, decode cap — the planner aligns the cap with the
    /// planned decode pipelines) and the admission token bucket come
    /// from the same artifact the simulator executed. Engine-bound
    /// limits (max tokens, history) stay server defaults: they follow
    /// the compiled artifact set, not the plan.
    pub fn from_plan(plan: &crate::plan::ExecutionPlan) -> ServerConfig {
        ServerConfig {
            batch: plan.batcher_config(),
            admission: plan.admission_config(),
            ..ServerConfig::default()
        }
    }
}

struct InFlight {
    req: ChatRequest,
    submitted: Instant,
}

/// The serving coordinator.
pub struct Server {
    engine: Arc<Engine>,
    cfg: ServerConfig,
    pub metrics: Arc<MetricsRegistry>,
    sessions: SessionStore,
}

impl Server {
    pub fn new(engine: impl Into<Arc<Engine>>, cfg: ServerConfig) -> Server {
        let max_history = cfg.max_history;
        Server {
            engine: engine.into(),
            cfg,
            metrics: Arc::new(MetricsRegistry::new()),
            sessions: SessionStore::new(max_history),
        }
    }

    /// Bring up a server configured by an execution plan (see
    /// [`ServerConfig::from_plan`]).
    pub fn from_plan(
        engine: impl Into<Arc<Engine>>,
        plan: &crate::plan::ExecutionPlan,
    ) -> Result<Server> {
        plan.validate()?;
        Ok(Server::new(engine, ServerConfig::from_plan(plan)))
    }

    /// Swap the serving policy between workloads — the orchestrator's
    /// live backend applies each re-planned `ExecutionPlan` this way.
    /// Takes effect at the next [`Server::serve`] / [`Server::run_workload`]
    /// call (the batcher and admission controller are rebuilt from the
    /// config there); sessions and metrics persist across the swap.
    pub fn reconfigure(&mut self, cfg: ServerConfig) {
        self.sessions.max_history = cfg.max_history;
        self.cfg = cfg;
    }

    /// The active serving configuration.
    pub fn config(&self) -> &ServerConfig {
        &self.cfg
    }

    /// Serve until `rx` disconnects and all queued work drains. Designed
    /// to run on a dedicated thread; responses go out through `tx`.
    pub fn serve(
        &mut self,
        rx: mpsc::Receiver<ChatRequest>,
        tx: mpsc::Sender<ChatResponse>,
    ) -> Result<()> {
        let mut batcher: Batcher<InFlight> = Batcher::new(self.cfg.batch.clone());
        let mut admission = AdmissionController::new(self.cfg.admission.clone());
        let m_req = self.metrics.counter("server_requests");
        let m_rej = self.metrics.counter("server_rejected");
        let m_tok = self.metrics.counter("server_tokens_out");
        let m_batches = self.metrics.counter("server_batches");
        let h_ttft = self.metrics.histogram("server_ttft");
        let h_e2e = self.metrics.histogram("server_e2e");
        let g_depth = self.metrics.gauge("server_queue_depth");

        let mut open = true;
        while open || !batcher.is_empty() {
            // Pull everything currently available (bounded wait so the
            // batcher timeout keeps ticking).
            loop {
                match rx.recv_timeout(Duration::from_millis(1)) {
                    Ok(req) => {
                        m_req.inc();
                        match admission.admit(Instant::now(), batcher.len()) {
                            Admission::Accept => batcher.push(InFlight {
                                req,
                                submitted: Instant::now(),
                            }),
                            _ => {
                                m_rej.inc();
                                let _ = tx.send(ChatResponse::rejected(req_id(&req)));
                            }
                        }
                    }
                    Err(mpsc::RecvTimeoutError::Timeout) => break,
                    Err(mpsc::RecvTimeoutError::Disconnected) => {
                        open = false;
                        break;
                    }
                }
            }
            g_depth.set(batcher.len() as f64);

            let Some(batch) = batcher.poll(Instant::now()) else {
                if !open && batcher.is_empty() {
                    break;
                }
                continue;
            };
            m_batches.inc();
            let responses = self.run_batch(batch.members)?;
            for r in responses {
                m_tok.add(r.tokens as u64);
                h_ttft.record_secs(r.ttft_s);
                h_e2e.record_secs(r.e2e_s);
                let _ = tx.send(r);
            }
        }
        Ok(())
    }

    /// Synchronous convenience: submit a fixed workload, get responses.
    pub fn run_workload(&mut self, requests: Vec<ChatRequest>) -> Result<Vec<ChatResponse>> {
        let (req_tx, req_rx) = mpsc::channel();
        let (resp_tx, resp_rx) = mpsc::channel();
        for r in requests {
            req_tx.send(r).unwrap();
        }
        drop(req_tx);
        self.serve(req_rx, resp_tx)?;
        let mut out: Vec<ChatResponse> = resp_rx.into_iter().collect();
        out.sort_by_key(|r| r.id);
        Ok(out)
    }

    /// Execute one prefill+decode batch to completion.
    fn run_batch(&mut self, members: Vec<InFlight>) -> Result<Vec<ChatResponse>> {
        let seq_budget = self.engine.manifest.prefill_seq;
        let prompts: Vec<Vec<u8>> = members
            .iter()
            .map(|f| self.sessions.assemble(f.req.session, &f.req.prompt, seq_budget))
            .collect();
        let t_batch0 = Instant::now();
        let pre = self.engine.prefill(&prompts)?;
        let mut kv = pre.kv;
        let n = members.len();
        let bucket = kv.bucket;

        let mut samplers: Vec<Sampler> = members
            .iter()
            .map(|f| {
                if f.req.temperature > 0.0 {
                    Sampler::new(f.req.temperature, 0, f.req.id)
                } else {
                    Sampler::greedy()
                }
            })
            .collect();

        let mut outputs: Vec<Vec<u8>> = vec![Vec::new(); n];
        let mut first_token_at: Vec<Instant> = vec![t_batch0; n];
        let mut last_token_at: Vec<Instant> = vec![t_batch0; n];
        let mut gaps: Vec<Vec<f64>> = vec![Vec::new(); n];

        // First token from prefill logits.
        let now = Instant::now();
        let mut next: Vec<u8> = vec![0; bucket];
        for i in 0..n {
            let tok = samplers[i].sample(&pre.logits[i]) as u8;
            next[i] = tok;
            outputs[i].push(tok);
            first_token_at[i] = now;
            last_token_at[i] = now;
        }

        // Decode rounds until every member hit its budget (lanes that
        // finish keep feeding their last token; outputs stop growing).
        let max_rounds = members
            .iter()
            .map(|f| f.req.max_new_tokens.saturating_sub(1))
            .max()
            .unwrap_or(0)
            .min(self.engine.manifest.max_seq - seq_budget - 1);
        for _round in 0..max_rounds {
            let logits = self.engine.decode_step(&mut kv, &next)?;
            let now = Instant::now();
            for i in 0..n {
                if outputs[i].len() >= members[i].req.max_new_tokens {
                    continue;
                }
                let tok = samplers[i].sample(&logits[i]) as u8;
                next[i] = tok;
                outputs[i].push(tok);
                gaps[i].push(now.duration_since(last_token_at[i]).as_secs_f64());
                last_token_at[i] = now;
            }
        }

        // Record sessions + build responses.
        let mut responses = Vec::with_capacity(n);
        for (i, f) in members.iter().enumerate() {
            if let Some(sid) = f.req.session {
                self.sessions.record_turn(sid, &f.req.prompt, &outputs[i]);
            }
            let ttft = first_token_at[i].duration_since(f.submitted).as_secs_f64();
            let e2e = last_token_at[i].duration_since(f.submitted).as_secs_f64();
            let tbt = if gaps[i].is_empty() {
                0.0
            } else {
                gaps[i].iter().sum::<f64>() / gaps[i].len() as f64
            };
            responses.push(ChatResponse {
                id: f.req.id,
                output: outputs[i].clone(),
                ttft_s: ttft,
                tbt_mean_s: tbt,
                e2e_s: e2e,
                tokens: outputs[i].len(),
                rejected: false,
            });
        }
        Ok(responses)
    }
}

fn req_id(r: &ChatRequest) -> u64 {
    r.id
}

// Engine-backed tests live in rust/tests/runtime_e2e.rs (need artifacts).

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn server_config_derives_from_plan() {
        let plan = crate::plan::tests::tiny_plan();
        let cfg = ServerConfig::from_plan(&plan);
        assert_eq!(cfg.batch.buckets, plan.batching.buckets);
        assert_eq!(cfg.batch.max_decode_batch, plan.batching.max_decode_batch);
        assert_eq!(cfg.admission.rate, plan.admission.rate);
        assert_eq!(cfg.admission.burst, plan.admission.burst);
        assert_eq!(
            cfg.admission.max_queue_depth,
            plan.admission.max_queue_depth
        );
        // Engine-independent defaults survive.
        assert_eq!(cfg.max_new_tokens, ServerConfig::default().max_new_tokens);
    }

    #[test]
    #[cfg(not(feature = "pjrt"))]
    fn reconfigure_swaps_policy_between_requests() {
        use crate::runtime::manifest::Manifest;
        use crate::runtime::Engine;

        // The stub engine can't load artifacts, but reconfiguration is
        // pure policy state — construct the server around a manifest-only
        // engine the same way the live orchestrator backend does.
        let engine = Engine {
            manifest: Manifest {
                dir: std::path::PathBuf::new(),
                vocab: 256,
                d_model: 64,
                n_layers: 2,
                n_heads: 2,
                n_kv_heads: 2,
                head_dim: 32,
                max_seq: 128,
                prefill_seq: 64,
                buckets: vec![1, 2, 4],
                num_params: 1_000,
                kv_cache_bytes_b1: 1_024,
            },
        };
        let mut server = Server::new(engine, ServerConfig::default());
        assert_eq!(server.config().admission.rate, 1000.0);

        let mut plan = crate::plan::tests::tiny_plan();
        plan.admission.rate = 333.0;
        plan.batching.max_decode_batch = 9;
        server.reconfigure(ServerConfig::from_plan(&plan));
        assert_eq!(server.config().admission.rate, 333.0);
        assert_eq!(server.config().batch.max_decode_batch, 9);
        assert_eq!(server.sessions.max_history, ServerConfig::default().max_history);
    }
}
