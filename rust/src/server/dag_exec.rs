//! Live agent-DAG execution: walk a request through its
//! [`ExecutionPlan`] node bindings on the real serving stack — CPU/
//! tool/IO stages on the bounded [`HostPool`], LLM stages through the
//! admission → batcher → engine loop — exactly the graph the DAG
//! simulator (`cluster/dag.rs`) executes in modeled time.
//!
//! Split of responsibilities:
//!
//! * [`DagRuntime`] — static, derived once per installed plan: the
//!   topology ([`DagTopology`]), the engine inference units
//!   ([`crate::plan::instance::llm_units`]), the virtual pipeline fleet
//!   (expanded replicas with chassis **and pipeline group**, so every
//!   LLM stage routes to the engine its role's group is bound to), the
//!   group → engine map over the server's engine pool, and the time
//!   scale that maps planner-profiled latencies onto wall-clock sleeps.
//! * [`DagDispatch`] — the per-request bookkeeping the serving loop
//!   drives: dependency counts, ready-unit extraction, **contended**
//!   cross-chassis transfer timers (the same
//!   [`TransferClock`](crate::transport::fabric::TransferClock) FIFO
//!   reservation model the simulator prices, behind the thread-safe
//!   [`SharedTransferClock`] handle so KV handoffs charge one shared
//!   reservation state no matter which engine thread ran the prefill),
//!   per-stage spans, payload
//!   propagation along DAG edges, and failure isolation (a failing tool
//!   node terminates *its* request; every other request and the
//!   dispatcher keep running).
//!
//! LLM units execute in **two phases**: the prefill binding runs on the
//! engine of its prefill group; the fused decode binding runs on the
//! engine of its decode group, and whenever the two groups sit on
//! different chassis the prefill → decode KV handoff is charged as a
//! real timed transfer over the contended clock before the decode phase
//! may start — closing the gap where one fused engine pass meant KV
//! never moved and live latencies undercut the simulator on
//! cross-chassis plans.
//!
//! The dispatcher returns [`LlmJob`]s for the serving loop to feed into
//! its continuous batcher, and receives [`UnitOutcome`]s back once an
//! engine has executed a batch — it never touches the engines itself.

// Per-request DAG bookkeeping runs on the live dispatcher thread: a
// panic here takes every in-flight request down, so unwrap/expect are
// banned outside tests — inconsistent state must degrade per-request.
#![deny(clippy::unwrap_used, clippy::expect_used)]

use std::cmp::Reverse;
use std::collections::{BTreeMap, BinaryHeap};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::cluster::dag::{KvReuse, KvReuseConfig};
use crate::cost::kv::kv_cache_bytes;
use crate::cost::model_profile::{by_short_name, ModelProfile};
use crate::obs::trace::{classify_host_op, Span, SpanKind, TraceSink};
use crate::obs::MetricsRegistry;
use crate::plan::instance::{edge_payload_bytes, llm_units, DagTopology, LlmUnit};
use crate::plan::{ExecutionPlan, Role, Stage};
use crate::router::router::{RouteReason, Router, RouterConfig, WorkerState};
use crate::server::hostpool::{HostDone, HostPool, HostTask};
use crate::server::request::{ChatRequest, ChatResponse, StageSpan};
use crate::transport::fabric::{Fabric, SharedTransferClock};
use crate::{Error, Result};

/// Globally-unique admission epochs: the host pool and the server's
/// completion channel outlive individual `serve` sessions, so epoch
/// uniqueness must span dispatchers — a stale completion or timer from
/// any earlier session must never match a later run reusing an id.
static EPOCH_SEQ: AtomicU64 = AtomicU64::new(1);

/// Fault-injection hook for host stages: `(op, request id) -> fail?`.
/// Installed via [`crate::server::Server::inject_host_fault`]; used by
/// the failure-injection tests to prove a failing tool node never
/// wedges the dispatcher.
pub type HostFault = Arc<dyn Fn(&str, u64) -> bool + Send + Sync>;

/// splitmix64 — the same mixer the synthetic engine builds on.
fn mix(x: u64) -> u64 {
    let mut z = x.wrapping_add(0x9E37_79B9_7F4A_7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// Context identity of a prefill's full input bytes (prompt plus dep
/// payloads in edge order). Two prefills share a prefix-cache entry
/// exactly when these bytes are identical — the same equivalence class
/// the simulator derives structurally from (request, gating-dep list),
/// which is what makes per-group hit counts comparable across backends.
fn context_hash(bytes: &[u8]) -> u64 {
    let mut h = 0xC0FF_EE00_D15E_A5E5u64 ^ (bytes.len() as u64);
    for &b in bytes {
        h = mix(h ^ b as u64);
    }
    h
}

/// The deterministic payload a host stage emits: an op-tagged digest of
/// its input bytes. Real data flows along DAG edges — a changed tool
/// result changes every downstream prompt — while staying cheap and
/// reproducible for conformance runs.
pub fn host_payload(op: &str, input: &[u8]) -> Vec<u8> {
    let mut h = 0x5EED_F00D_u64 ^ (input.len() as u64);
    for &b in op.as_bytes() {
        h = mix(h ^ b as u64);
    }
    for &b in input {
        h = mix(h ^ b as u64);
    }
    format!("{op}#{h:016x};").into_bytes()
}

/// One virtual pipeline replica of the plan's fleet, carrying per-role
/// routing, request accounting, chassis placement for edge-transfer
/// pricing, and the plan pipeline **group** it expands — the group is
/// what binds the replica to an engine of the server's pool.
#[derive(Debug, Clone)]
pub struct VPipe {
    pub class: String,
    pub chassis: u32,
    /// Index into `ExecutionPlan::pipelines`.
    pub group: usize,
}

/// Static per-plan execution structure. See module docs.
pub struct DagRuntime {
    pub plan: ExecutionPlan,
    pub topo: DagTopology,
    pub units: Vec<LlmUnit>,
    pub unit_of: Vec<Option<usize>>,
    /// Incoming unit-external edge count per unit (readiness counter).
    unit_ext_edges: Vec<u32>,
    pub prefill_pipes: Vec<VPipe>,
    pub decode_pipes: Vec<VPipe>,
    /// Engine index (into the server's pool) per plan pipeline group;
    /// groups wrap round-robin when the pool is smaller than the fleet.
    pub engine_of_group: Vec<usize>,
    model: Option<ModelProfile>,
    /// Fabric template for the dispatcher's contended transfer clock.
    fabric: Fabric,
    /// Wall-clock seconds per modeled second (CPU sleeps, transfers).
    pub time_scale: f64,
}

impl DagRuntime {
    /// Derive the execution structure for a plan served by a pool of
    /// `n_engines` engines (≥ 1; each pipeline group is bound to one
    /// engine, wrapping when the pool is smaller than the fleet).
    pub fn new(plan: &ExecutionPlan, time_scale: f64, n_engines: usize) -> Result<DagRuntime> {
        plan.validate()?;
        // Static pre-flight (shared with the simulator and the
        // orchestrator): Error-severity diagnostics reject the plan
        // before any serving state is touched, with the diagnostics
        // table attached.
        crate::plan::verify::ensure_loadable(plan)?;
        if plan.bindings.is_empty() {
            return Err(Error::Runtime(
                "plan has no bindings to execute".into(),
            ));
        }
        let has_llm = plan.bindings.iter().any(|b| b.stage != Stage::Cpu);
        let model = by_short_name(&plan.model);
        if has_llm && model.is_none() {
            return Err(Error::Config(format!(
                "plan model `{}` not in the profile catalog",
                plan.model
            )));
        }
        let topo = DagTopology::of(plan);
        let (units, unit_of) = llm_units(plan);
        // `ext_deps` carries one entry per incoming external edge, so
        // its length is exactly the readiness count deliver_dep drains.
        let unit_ext_edges = units.iter().map(|u| u.ext_deps.len() as u32).collect();
        let n_engines = n_engines.max(1);
        let mut prefill_pipes = Vec::new();
        let mut decode_pipes = Vec::new();
        for (g, p) in plan.pipelines.iter().enumerate() {
            for r in 0..p.replicas {
                let vp = VPipe {
                    class: p.device.clone(),
                    chassis: p.chassis + r,
                    group: g,
                };
                match p.role {
                    Role::Prefill => prefill_pipes.push(vp),
                    Role::Decode => decode_pipes.push(vp),
                }
            }
        }
        Ok(DagRuntime {
            topo,
            units,
            unit_of,
            unit_ext_edges,
            prefill_pipes,
            decode_pipes,
            engine_of_group: (0..plan.pipelines.len()).map(|g| g % n_engines).collect(),
            model,
            fabric: plan.build_fabric()?,
            time_scale: time_scale.max(0.0),
            plan: plan.clone(),
        })
    }

    /// Engine (pool index) a routed virtual pipe is bound to.
    pub fn engine_of(&self, role: Role, pipe: usize) -> usize {
        let p = match role {
            Role::Prefill => &self.prefill_pipes[pipe],
            Role::Decode => &self.decode_pipes[pipe],
        };
        self.engine_of_group.get(p.group).copied().unwrap_or(0)
    }

    /// Prompt tokens a node processes (byte-LM: bytes ≈ tokens), scaled
    /// by its `token_fraction` — mirrors `DagSim::isl_of`.
    fn isl_of(&self, prompt_len: usize, node: usize) -> u64 {
        let tf = self.plan.bindings[node].token_fraction;
        ((prompt_len as f64 * tf).round() as u64).max(1)
    }

    /// Decode token budget of a node — mirrors `DagSim::osl_of`.
    fn osl_of(&self, max_new: usize, node: usize) -> usize {
        let tf = self.plan.bindings[node].token_fraction;
        (((max_new as f64) * tf).round() as usize).max(1)
    }

    /// Payload bytes an edge into `to_node` carries (shared sizing rule
    /// — KV for prefill → decode, the plan's estimate otherwise).
    fn hop_bytes(&self, prompt_len: usize, from_stage: Stage, to_node: usize) -> f64 {
        edge_payload_bytes(
            self.model.as_ref(),
            from_stage,
            &self.plan.bindings[to_node],
            self.isl_of(prompt_len, to_node),
        )
    }
}

/// Which half of an LLM unit a job executes.
#[derive(Debug, Clone)]
pub enum LlmPhase {
    /// Context ingestion of the unit's prompt on its **prefill** engine.
    Prefill { prompt: Vec<u8> },
    /// Decode rounds on the unit's **decode** engine: re-ingest the
    /// context (the synthetic KV state is a pure function of it — the
    /// stand-in for adopting a transferred KV cache) and generate up to
    /// `osl` tokens.
    Decode { prompt: Vec<u8>, osl: usize },
}

/// One engine inference the serving loop should batch: one phase of
/// unit `unit` of request `req`, on engine `engine` of the pool.
#[derive(Debug, Clone)]
pub struct LlmJob {
    pub req: u64,
    pub unit: usize,
    /// Engine pool index this phase is scheduled on.
    pub engine: usize,
    pub phase: LlmPhase,
    pub temperature: f64,
    /// When the dispatcher emitted the job — `started - enqueued` is
    /// the batcher + channel wait ([`Span::queue_wait`] for LLM spans).
    pub enqueued: Instant,
}

/// What an engine did with one [`LlmJob`] (timestamps are wall-clock).
#[derive(Debug)]
pub struct UnitOutcome {
    pub job: LlmJob,
    /// Phase execution start on the engine.
    pub started: Instant,
    /// Phase execution end (prefill done / last decode token).
    pub finished: Instant,
    /// First sampled token (decode phases with a token budget).
    pub first_token: Option<Instant>,
    pub output: Vec<u8>,
    /// Sum and count of token-to-token gaps.
    pub tbt_sum_s: f64,
    pub tbt_n: u64,
}

/// What one dispatcher step produced: jobs for the batcher, responses
/// for the client channel.
#[derive(Debug, Default)]
pub struct Step {
    pub jobs: Vec<LlmJob>,
    pub responses: Vec<ChatResponse>,
}

/// What a due transfer timer delivers.
#[derive(Debug, Clone, Copy)]
enum TimerKind {
    /// A dependency edge's payload arrived at `node`. `from` is the
    /// completed upstream node the payload left (the candidate gating
    /// edge recorded as [`Span::parent`]).
    Dep { node: usize, from: i64 },
    /// The fused prefill → decode KV handoff landed: the unit's decode
    /// phase may start on its engine.
    KvArrived { unit: usize },
}

/// A modeled cross-chassis transfer in flight, priced on the contended
/// clock. `epoch` pins the timer to one admission of that id — a stale
/// timer from a torn-down run must never deliver into a later request
/// reusing the id.
struct Timer {
    due: Instant,
    seq: u64,
    req: u64,
    epoch: u64,
    kind: TimerKind,
}

impl PartialEq for Timer {
    fn eq(&self, other: &Self) -> bool {
        self.due == other.due && self.seq == other.seq
    }
}
impl Eq for Timer {}
impl PartialOrd for Timer {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for Timer {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        self.due.cmp(&other.due).then(self.seq.cmp(&other.seq))
    }
}

/// Per-request run state.
struct ReqRun {
    req: ChatRequest,
    /// Admission epoch (see [`Timer::epoch`]).
    epoch: u64,
    submitted: Instant,
    /// Unsatisfied dependency edges per node (CPU nodes).
    remaining: Vec<u32>,
    /// Unsatisfied external edges per unit (LLM nodes).
    unit_remaining: Vec<u32>,
    unit_dispatched: Vec<bool>,
    node_done: Vec<bool>,
    /// Virtual pipe each LLM node routed to.
    node_pipe: Vec<Option<(Role, usize)>>,
    /// Last-arriving (gating) dependency edge per node, -1 = root —
    /// [`Span::parent`], same overwrite-on-arrival rule as the
    /// simulator's `dep_from`.
    dep_from: Vec<i64>,
    /// Seconds the request waited in admission before `submitted`
    /// (intake-channel wait), carried onto the envelope span.
    admit_wait_s: f64,
    pipe_released: Vec<bool>,
    /// Output payload per completed node (real dataflow between stages).
    payload: Vec<Option<Vec<u8>>>,
    nodes_left: usize,
    /// Host tasks + engine jobs currently in flight.
    outstanding: u32,
    failed: Option<String>,
    first_token: Option<Instant>,
    last_done: Instant,
    output: Vec<u8>,
    tokens: usize,
    tbt_sum_s: f64,
    tbt_n: u64,
    /// Bytes this request moved over cross-chassis pipeline → pipeline
    /// edges (the fused prefill → decode KV handoff plus any cross-unit
    /// LLM edges) — one definition with `DagSim`'s `kv_bytes_moved`.
    kv_hop_bytes: f64,
    stages: Vec<Option<StageSpan>>,
}

/// The per-request dispatcher the serving loop drives. See module docs.
pub struct DagDispatch {
    runs: BTreeMap<u64, ReqRun>,
    timers: BinaryHeap<Reverse<Timer>>,
    timer_seq: u64,
    /// Contended edge-transfer clock (modeled seconds; `origin` is the
    /// wall instant that maps to modeled t = 0). Thread-safe so the
    /// reservation state could be shared beyond the dispatcher; today
    /// only the dispatcher charges hops, which keeps the chassis-
    /// granular FIFO order deterministic per completion-event order.
    clock: SharedTransferClock,
    origin: Instant,
    /// Outstanding LLM nodes routed to each virtual pipe, per role.
    prefill_load: Vec<usize>,
    decode_load: Vec<usize>,
    /// Per-binding stage-latency histograms, resolved once (the op set
    /// is fixed at plan install; no per-completion registry lookups).
    stage_hist: Vec<Arc<crate::obs::Histogram>>,
    metrics: Arc<MetricsRegistry>,
    fault: Option<HostFault>,
    /// Span recorder shared with the serving loop (None = tracing off —
    /// the emission sites skip all span allocation on that path).
    trace: Option<Arc<TraceSink>>,
    /// Copy of [`DagRuntime::time_scale`] so span timestamps can be
    /// mapped to modeled seconds without threading `rt` everywhere.
    time_scale: f64,
    /// Cross-step prefix-KV state (None = reuse off, the default): the
    /// same accounting engine the simulator runs, so hit/miss ledgers
    /// agree across backends by construction.
    reuse: Option<KvReuse>,
    /// Prefix-hit router over the prefill groups' cache nodes; only its
    /// `PrefixHit` verdict overrides least-loaded assignment, so with no
    /// resident prefix the routing is byte-identical to reuse-off.
    router: Option<Router>,
    /// Cache node id → plan pipeline group, to honor router verdicts.
    group_of_node: BTreeMap<u32, usize>,
}

impl DagDispatch {
    pub fn new(
        rt: &DagRuntime,
        metrics: Arc<MetricsRegistry>,
        fault: Option<HostFault>,
        trace: Option<Arc<TraceSink>>,
        kv_reuse: bool,
    ) -> DagDispatch {
        let stage_hist = rt
            .plan
            .bindings
            .iter()
            .map(|b| metrics.stage_histogram(&b.op))
            .collect();
        // Prefix-KV reuse: one cache node per prefill group (assigned
        // in pipeline order so the node ↔ group map is deterministic),
        // each registered as a router worker so `find_prefix` verdicts
        // resolve back to a group.
        let mut reuse = None;
        let mut router = None;
        let mut group_of_node = BTreeMap::new();
        if kv_reuse {
            if let Some(m) = &rt.model {
                let mut rz = KvReuse::new(
                    &KvReuseConfig::default(),
                    rt.plan.pipelines.len(),
                    kv_cache_bytes(m, 1, 1),
                );
                let mut r = Router::new(RouterConfig::default());
                for (g, p) in rt.plan.pipelines.iter().enumerate() {
                    if p.role != Role::Prefill {
                        continue;
                    }
                    if let Some(nid) = rz.node_for(&p.shape_key()) {
                        group_of_node.entry(nid).or_insert(g);
                        r.upsert_worker(WorkerState {
                            id: nid,
                            models: vec![rt.plan.model.clone()],
                            outstanding: 0,
                            draining: false,
                        });
                    }
                }
                reuse = Some(rz);
                router = Some(r);
            }
        }
        DagDispatch {
            runs: BTreeMap::new(),
            timers: BinaryHeap::new(),
            timer_seq: 0,
            clock: SharedTransferClock::new(rt.fabric.clone()),
            origin: Instant::now(),
            prefill_load: vec![0; rt.prefill_pipes.len()],
            decode_load: vec![0; rt.decode_pipes.len()],
            stage_hist,
            metrics,
            fault,
            trace,
            time_scale: rt.time_scale,
            reuse,
            router,
            group_of_node,
        }
    }

    /// Wall instant → span time: modeled seconds since the dispatcher's
    /// origin (wall ÷ time scale), or raw wall seconds when the scale
    /// collapses modeled time — the same clock the simulator stamps
    /// spans in, so sim and live traces line up unit-for-unit.
    fn span_time(&self, at: Instant) -> f64 {
        let wall = at.saturating_duration_since(self.origin).as_secs_f64();
        if self.time_scale > 0.0 {
            wall / self.time_scale
        } else {
            wall
        }
    }

    /// Wall duration → span seconds (same scaling as [`Self::span_time`]).
    fn span_secs(&self, wall_s: f64) -> f64 {
        if self.time_scale > 0.0 {
            wall_s / self.time_scale
        } else {
            wall_s
        }
    }

    #[inline]
    fn emit(&self, span: Span) {
        if let Some(s) = &self.trace {
            s.record(span);
        }
    }

    /// Requests admitted but not yet answered.
    pub fn in_flight(&self) -> usize {
        self.runs.len()
    }

    /// Is a request with this id already in flight? (Duplicate ids
    /// would cross-apply completions; the server fails them closed.)
    pub fn contains(&self, id: u64) -> bool {
        self.runs.contains_key(&id)
    }

    /// Earliest pending modeled-transfer arrival, if any.
    pub fn next_timer_due(&self) -> Option<Instant> {
        self.timers.peek().map(|Reverse(t)| t.due)
    }

    /// Wall instant → modeled seconds on the shared clock.
    fn modeled_now(&self, now: Instant, time_scale: f64) -> f64 {
        now.saturating_duration_since(self.origin).as_secs_f64() / time_scale
    }

    /// Wall-clock delay of a cross-chassis hop reserved on the
    /// contended clock at wall instant `at` (0.0 when the time scale
    /// collapses transfers, or for same-chassis hops).
    fn transfer_delay(
        &mut self,
        rt: &DagRuntime,
        from_chassis: u32,
        to_chassis: u32,
        bytes: f64,
        at: Instant,
    ) -> f64 {
        if from_chassis == to_chassis || rt.time_scale <= 0.0 {
            return 0.0;
        }
        let m_now = self.modeled_now(at, rt.time_scale);
        match self.clock.transfer(from_chassis, to_chassis, bytes, m_now) {
            Ok(m_done) => (m_done - m_now).max(0.0) * rt.time_scale,
            // Addresses derive from the validated plan; an out-of-range
            // chassis would be a plan bug — degrade to an instant hop.
            Err(_) => 0.0,
        }
    }

    /// Input bytes a node consumes: the request prompt followed by
    /// every dependency's payload, in edge order — real dataflow, so a
    /// tool result changes what the downstream stage sees.
    fn inputs(rt: &DagRuntime, run: &ReqRun, node: usize) -> Vec<u8> {
        let mut buf = run.req.prompt.clone();
        for &d in &rt.plan.bindings[node].deps {
            if let Some(p) = &run.payload[d] {
                buf.extend_from_slice(p);
            }
        }
        buf
    }

    /// Admit one agent request: instantiate its DAG, dispatch the
    /// roots. Host stages go straight to the pool; ready LLM units come
    /// back in the [`Step`] for the batcher. `received` is when the
    /// request entered the server's intake channel — `now - received`
    /// is the admission wait carried onto the envelope span.
    pub fn admit(
        &mut self,
        rt: &DagRuntime,
        req: ChatRequest,
        now: Instant,
        received: Instant,
        pool: &HostPool,
    ) -> Step {
        let mut step = Step::default();
        let n = rt.topo.len();
        let mut run = ReqRun {
            epoch: EPOCH_SEQ.fetch_add(1, Ordering::Relaxed),
            submitted: now,
            remaining: rt.topo.indeg.clone(),
            unit_remaining: rt.unit_ext_edges.clone(),
            unit_dispatched: vec![false; rt.units.len()],
            node_done: vec![false; n],
            node_pipe: vec![None; n],
            dep_from: vec![-1; n],
            admit_wait_s: self
                .span_secs(now.saturating_duration_since(received).as_secs_f64()),
            pipe_released: vec![false; n],
            payload: vec![None; n],
            nodes_left: n,
            outstanding: 0,
            failed: None,
            first_token: None,
            last_done: now,
            output: Vec::new(),
            tokens: 0,
            tbt_sum_s: 0.0,
            tbt_n: 0,
            kv_hop_bytes: 0.0,
            stages: vec![None; n],
            req,
        };
        // CPU roots.
        for node in rt.topo.roots() {
            if rt.plan.bindings[node].stage == Stage::Cpu {
                self.dispatch_cpu(rt, &mut run, node, pool);
            }
        }
        // Units with no external edges are ready at arrival.
        for u in 0..rt.units.len() {
            if run.unit_remaining[u] == 0 && !run.unit_dispatched[u] {
                self.dispatch_unit(rt, &mut run, u, &mut step);
            }
        }
        self.runs.insert(run.req.id, run);
        step
    }

    /// One host-pool completion landed.
    pub fn on_host_done(&mut self, rt: &DagRuntime, d: HostDone, pool: &HostPool) -> Step {
        let mut step = Step::default();
        let Some(mut run) = self.runs.remove(&d.req) else {
            return step;
        };
        // A stale completion from an earlier serve session (or an
        // earlier admission of this id) belongs to a torn-down run.
        if run.epoch != d.epoch {
            self.runs.insert(d.req, run);
            return step;
        }
        run.outstanding = run.outstanding.saturating_sub(1);
        match d.result {
            Ok(payload) => {
                if run.failed.is_none() {
                    run.payload[d.node] = Some(payload);
                    let span = StageSpan {
                        node: d.node,
                        op: rt.plan.bindings[d.node].op.clone(),
                        role: rt.plan.bindings[d.node].stage.name(),
                        start_s: d.started.duration_since(run.submitted).as_secs_f64(),
                        end_s: d.finished.duration_since(run.submitted).as_secs_f64(),
                    };
                    if self.trace.is_some() {
                        self.emit(Span {
                            request: d.req,
                            node: d.node as i64,
                            kind: classify_host_op(&rt.plan.bindings[d.node].op),
                            group: "host".to_string(),
                            chassis: 0,
                            t_start: self.span_time(d.started),
                            t_end: self.span_time(d.finished),
                            parent: run.dep_from[d.node],
                            queue_wait: self.span_secs(
                                d.started
                                    .saturating_duration_since(d.submitted)
                                    .as_secs_f64(),
                            ),
                        });
                    }
                    self.complete_node(rt, &mut run, d.node, d.finished, span, pool, &mut step);
                }
            }
            Err(e) => {
                if run.failed.is_none() {
                    self.metrics.counter("server_stage_failures").inc();
                    run.failed = Some(format!(
                        "{} (node {}): {e}",
                        rt.plan.bindings[d.node].op, d.node
                    ));
                }
                // The failing stage's own wall time still counts
                // toward the failed response's e2e.
                if d.finished > run.last_done {
                    run.last_done = d.finished;
                }
            }
        }
        self.settle(run, &mut step);
        step
    }

    /// Deliver every modeled transfer due by `now`.
    pub fn poll_timers(&mut self, rt: &DagRuntime, now: Instant, pool: &HostPool) -> Step {
        let mut step = Step::default();
        while matches!(self.timers.peek(), Some(Reverse(t)) if t.due <= now) {
            let Some(Reverse(t)) = self.timers.pop() else {
                break;
            };
            let Some(mut run) = self.runs.remove(&t.req) else {
                continue;
            };
            // A stale timer from a torn-down run must not deliver into
            // a later request that reused the id.
            if run.epoch != t.epoch {
                self.runs.insert(t.req, run);
                continue;
            }
            if run.failed.is_none() {
                match t.kind {
                    TimerKind::Dep { node, from } => {
                        self.deliver_dep(rt, &mut run, node, from, pool, &mut step);
                    }
                    TimerKind::KvArrived { unit } => {
                        self.dispatch_decode(rt, &mut run, unit, &mut step);
                    }
                }
            }
            self.settle(run, &mut step);
        }
        step
    }

    /// An engine finished a batch of unit phases.
    pub fn finish_units(
        &mut self,
        rt: &DagRuntime,
        outcomes: Vec<UnitOutcome>,
        pool: &HostPool,
    ) -> Step {
        let mut step = Step::default();
        for o in outcomes {
            let Some(mut run) = self.runs.remove(&o.job.req) else {
                continue;
            };
            run.outstanding = run.outstanding.saturating_sub(1);
            if run.failed.is_none() {
                let unit = &rt.units[o.job.unit];
                match &o.job.phase {
                    LlmPhase::Prefill { .. } => {
                        // A prefill outcome for a unit without a prefill
                        // phase can only come from a torn-down runtime;
                        // drop it rather than panic the serving thread.
                        let Some(p) = unit.prefill else {
                            self.settle(run, &mut step);
                            continue;
                        };
                        run.payload[p] = Some(Vec::new());
                        if self.trace.is_some() {
                            let (group, chassis) = Self::span_placement(rt, &run, p);
                            self.emit(Span {
                                request: o.job.req,
                                node: p as i64,
                                kind: SpanKind::Prefill,
                                group,
                                chassis,
                                t_start: self.span_time(o.started),
                                t_end: self.span_time(o.finished),
                                parent: run.dep_from[p],
                                queue_wait: self.span_secs(
                                    o.started
                                        .saturating_duration_since(o.job.enqueued)
                                        .as_secs_f64(),
                                ),
                            });
                        }
                        let span = StageSpan {
                            node: p,
                            op: rt.plan.bindings[p].op.clone(),
                            role: rt.plan.bindings[p].stage.name(),
                            start_s: o.started.duration_since(run.submitted).as_secs_f64(),
                            end_s: o.finished.duration_since(run.submitted).as_secs_f64(),
                        };
                        self.complete_node(
                            rt, &mut run, p, o.finished, span, pool, &mut step,
                        );
                        // The fused decode starts only after the KV
                        // handoff lands (a real timed transfer when the
                        // two phases sit on different chassis).
                        if run.failed.is_none() && unit.decode.is_some() {
                            self.schedule_decode_after_hop(
                                rt, &mut run, o.job.unit, o.finished, &mut step,
                            );
                        }
                    }
                    LlmPhase::Decode { .. } => {
                        let Some(dnode) = unit.decode else {
                            self.settle(run, &mut step);
                            continue;
                        };
                        run.output.extend_from_slice(&o.output);
                        run.tokens += o.output.len();
                        if let Some(ft) = o.first_token {
                            let earlier = match run.first_token {
                                Some(cur) => ft < cur,
                                None => true,
                            };
                            if earlier {
                                run.first_token = Some(ft);
                            }
                        }
                        run.tbt_sum_s += o.tbt_sum_s;
                        run.tbt_n += o.tbt_n;
                        if self.trace.is_some() {
                            let (group, chassis) = Self::span_placement(rt, &run, dnode);
                            self.emit(Span {
                                request: o.job.req,
                                node: dnode as i64,
                                kind: SpanKind::Decode,
                                group,
                                chassis,
                                t_start: self.span_time(o.started),
                                t_end: self.span_time(o.finished),
                                parent: run.dep_from[dnode],
                                queue_wait: self.span_secs(
                                    o.started
                                        .saturating_duration_since(o.job.enqueued)
                                        .as_secs_f64(),
                                ),
                            });
                        }
                        let span = StageSpan {
                            node: dnode,
                            op: rt.plan.bindings[dnode].op.clone(),
                            role: rt.plan.bindings[dnode].stage.name(),
                            start_s: o.started.duration_since(run.submitted).as_secs_f64(),
                            end_s: o.finished.duration_since(run.submitted).as_secs_f64(),
                        };
                        run.payload[dnode] = Some(o.output);
                        self.complete_node(
                            rt, &mut run, dnode, o.finished, span, pool, &mut step,
                        );
                    }
                }
            }
            self.settle(run, &mut step);
        }
        step
    }

    /// An engine batch died wholesale (engine error or worker panic):
    /// fail one outstanding engine job per entry in `reqs`. Each job's
    /// outstanding slot is returned and its request terminates once the
    /// rest of its in-flight work drains — the same isolation rule as a
    /// failing host stage: only the affected requests die.
    pub fn fail_engine_jobs(&mut self, reqs: &[u64], err: &str, now: Instant) -> Step {
        let mut step = Step::default();
        for &id in reqs {
            let Some(mut run) = self.runs.remove(&id) else {
                continue;
            };
            run.outstanding = run.outstanding.saturating_sub(1);
            if run.failed.is_none() {
                self.metrics.counter("server_stage_failures").inc();
                run.failed = Some(format!("engine phase failed: {err}"));
            }
            if now > run.last_done {
                run.last_done = now;
            }
            self.settle(run, &mut step);
        }
        step
    }

    /// Re-insert the run or finalize it into a response.
    fn settle(&mut self, run: ReqRun, step: &mut Step) {
        if let Some(err) = &run.failed {
            if run.outstanding == 0 {
                let e2e = run.last_done.duration_since(run.submitted).as_secs_f64();
                self.release_pipes(&run);
                let mut resp = ChatResponse::failed(run.req.id, e2e, err.clone());
                resp.kv_hop_bytes = run.kv_hop_bytes;
                step.responses.push(resp);
                return;
            }
        } else if run.nodes_left == 0 {
            self.release_pipes(&run);
            if self.trace.is_some() {
                // Request envelope — the root the critical-path walk
                // starts from (node -1, empty group), mirroring the
                // simulator's completion-time envelope span.
                self.emit(Span {
                    request: run.req.id,
                    node: -1,
                    kind: SpanKind::Request,
                    group: String::new(),
                    chassis: 0,
                    t_start: self.span_time(run.submitted),
                    t_end: self.span_time(run.last_done),
                    parent: -1,
                    queue_wait: run.admit_wait_s,
                });
            }
            step.responses.push(finalize(run));
            return;
        }
        self.runs.insert(run.req.id, run);
    }

    /// Return any still-held virtual-pipe slots (failure teardown).
    fn release_pipes(&mut self, run: &ReqRun) {
        for (node, p) in run.node_pipe.iter().enumerate() {
            if let Some((role, k)) = p {
                if !run.pipe_released[node] {
                    match role {
                        Role::Prefill => {
                            self.prefill_load[*k] = self.prefill_load[*k].saturating_sub(1)
                        }
                        Role::Decode => {
                            self.decode_load[*k] = self.decode_load[*k].saturating_sub(1)
                        }
                    }
                }
            }
        }
    }

    /// Route an LLM node to the least-loaded virtual pipe of its class.
    fn assign_pipe(&mut self, rt: &DagRuntime, run: &mut ReqRun, node: usize) {
        if run.node_pipe[node].is_some() {
            return;
        }
        let binding = &rt.plan.bindings[node];
        let (pipes, loads, role) = match binding.stage {
            Stage::LlmPrefill => (&rt.prefill_pipes, &mut self.prefill_load, Role::Prefill),
            Stage::LlmDecode => (&rt.decode_pipes, &mut self.decode_load, Role::Decode),
            Stage::Cpu => return,
        };
        let k = (0..pipes.len())
            .filter(|&k| pipes[k].class == binding.class)
            .min_by_key(|&k| loads[k]);
        if let Some(k) = k {
            loads[k] += 1;
            run.node_pipe[node] = Some((role, k));
        }
    }

    /// Prefix-affinity routing: when the router reports this context
    /// already resident on a group's cache node (`RouteReason::
    /// PrefixHit`), take the least-loaded class-matched pipe of that
    /// group. Every other outcome falls through to the default
    /// least-loaded assignment — with no resident prefix the routing is
    /// byte-identical to reuse-off.
    fn assign_pipe_prefix(&mut self, rt: &DagRuntime, run: &mut ReqRun, node: usize, hash: u64) {
        if run.node_pipe[node].is_some() {
            return;
        }
        let routed = match (&self.router, &self.reuse) {
            (Some(r), Some(rz)) => {
                r.route(&rt.plan.model, None, Some(hash), rz.cache()).ok()
            }
            _ => None,
        };
        let Some((wid, RouteReason::PrefixHit)) = routed else {
            return;
        };
        let Some(&g) = self.group_of_node.get(&wid) else {
            return;
        };
        let class = &rt.plan.bindings[node].class;
        let k = (0..rt.prefill_pipes.len())
            .filter(|&k| rt.prefill_pipes[k].group == g && &rt.prefill_pipes[k].class == class)
            .min_by_key(|&k| self.prefill_load[k]);
        if let Some(k) = k {
            self.prefill_load[k] += 1;
            run.node_pipe[node] = Some((Role::Prefill, k));
        }
    }

    /// Consult the routed group's prefix cache and clip the prefill
    /// prompt to its uncached suffix. Hits and misses land on
    /// `server_prefix_hits:<shape key>` / `server_prefix_misses:<shape
    /// key>` counters — the live mirror of the simulator's per-group
    /// ledger, pinned exactly by the conformance suite.
    fn consult_prefix(
        &mut self,
        rt: &DagRuntime,
        run: &ReqRun,
        node: usize,
        hash: u64,
        full: Vec<u8>,
    ) -> Vec<u8> {
        let Some((Role::Prefill, k)) = run.node_pipe[node] else {
            return full;
        };
        let gkey = rt.plan.pipelines[rt.prefill_pipes[k].group].shape_key();
        let Some(rz) = self.reuse.as_mut() else {
            return full;
        };
        let tokens = (full.len() as u64).max(1);
        let (uncached, _restore, hit) = rz.consult(&gkey, hash, tokens);
        let kind = if hit { "hits" } else { "misses" };
        self.metrics
            .counter(&format!("server_prefix_{kind}:{gkey}"))
            .inc();
        if hit {
            // Byte-LM: bytes ≈ tokens, so keep the uncached tail. The
            // fused decode re-derives the *full* context from the dep
            // payloads, so generated output is byte-identical to a
            // reuse-off run — only prefill work shrinks.
            let keep = (uncached.min(tokens) as usize).max(1).min(full.len());
            full[full.len() - keep..].to_vec()
        } else {
            full
        }
    }

    fn chassis_of(rt: &DagRuntime, run: &ReqRun, node: usize) -> Option<u32> {
        match run.node_pipe[node] {
            Some((Role::Prefill, k)) => Some(rt.prefill_pipes[k].chassis),
            Some((Role::Decode, k)) => Some(rt.decode_pipes[k].chassis),
            None => None,
        }
    }

    /// (pipeline-group shape key, chassis) of a routed LLM node — the
    /// same `Span::group` key the simulator stamps (both sides build on
    /// `shape_key_of`), so cross-backend traces share track names.
    fn span_placement(rt: &DagRuntime, run: &ReqRun, node: usize) -> (String, u32) {
        match run.node_pipe[node] {
            Some((Role::Prefill, k)) => {
                let p = &rt.prefill_pipes[k];
                (rt.plan.pipelines[p.group].shape_key(), p.chassis)
            }
            Some((Role::Decode, k)) => {
                let p = &rt.decode_pipes[k];
                (rt.plan.pipelines[p.group].shape_key(), p.chassis)
            }
            None => (String::new(), 0),
        }
    }

    /// Bump the per-group job ledger for a routed LLM node — the live
    /// counterpart of the simulator's `DagDetail::jobs_by_group`. Keys
    /// are `server_group_jobs:<shape key>` in the metrics snapshot, so
    /// the conformance suite can pin per-group request counts across
    /// backends exactly.
    fn count_group_job(&self, rt: &DagRuntime, run: &ReqRun, node: usize) {
        let group = match run.node_pipe[node] {
            Some((Role::Prefill, k)) => rt.prefill_pipes[k].group,
            Some((Role::Decode, k)) => rt.decode_pipes[k].group,
            None => return,
        };
        if let Some(p) = rt.plan.pipelines.get(group) {
            self.metrics
                .counter(&format!("server_group_jobs:{}", p.shape_key()))
                .inc();
        }
    }

    /// Submit one CPU/tool/IO stage to the host pool.
    fn dispatch_cpu(&mut self, rt: &DagRuntime, run: &mut ReqRun, node: usize, pool: &HostPool) {
        let binding = &rt.plan.bindings[node];
        let sleep_s = binding.latency_s * rt.time_scale;
        let op = binding.op.clone();
        let req_id = run.req.id;
        let fault = self.fault.clone();
        let input = Self::inputs(rt, run, node);
        run.outstanding += 1;
        self.metrics.counter("server_host_jobs").inc();
        pool.submit(HostTask {
            req: req_id,
            node,
            epoch: run.epoch,
            submitted: Instant::now(),
            work: Box::new(move || {
                if sleep_s > 0.0 {
                    std::thread::sleep(Duration::from_secs_f64(sleep_s));
                }
                if let Some(f) = fault {
                    if f(&op, req_id) {
                        return Err(Error::Runtime(format!(
                            "injected host-stage fault in {op}"
                        )));
                    }
                }
                Ok(host_payload(&op, &input))
            }),
        });
    }

    /// A unit's external dependencies are satisfied: start its first
    /// phase — prefill on the prefill engine, or, for decode-only
    /// units, the decode phase directly.
    fn dispatch_unit(&mut self, rt: &DagRuntime, run: &mut ReqRun, unit: usize, step: &mut Step) {
        run.unit_dispatched[unit] = true;
        let u = &rt.units[unit];
        if let Some(p) = u.prefill {
            let full = Self::inputs(rt, run, p);
            let hash = self.reuse.is_some().then(|| context_hash(&full));
            if let Some(h) = hash {
                self.assign_pipe_prefix(rt, run, p, h);
            }
            self.assign_pipe(rt, run, p);
            self.metrics.counter("server_prefill_jobs").inc();
            self.count_group_job(rt, run, p);
            run.outstanding += 1;
            let engine = run.node_pipe[p]
                .map(|(role, k)| rt.engine_of(role, k))
                .unwrap_or(0);
            let prompt = match hash {
                Some(h) => self.consult_prefix(rt, run, p, h, full),
                None => full,
            };
            self.metrics
                .counter("server_prefill_tokens")
                .add(prompt.len() as u64);
            step.jobs.push(LlmJob {
                req: run.req.id,
                unit,
                engine,
                phase: LlmPhase::Prefill { prompt },
                temperature: run.req.temperature,
                enqueued: Instant::now(),
            });
        } else {
            self.dispatch_decode(rt, run, unit, step);
        }
    }

    /// Emit a unit's decode phase onto its decode engine.
    fn dispatch_decode(&mut self, rt: &DagRuntime, run: &mut ReqRun, unit: usize, step: &mut Step) {
        let u = &rt.units[unit];
        let Some(d) = u.decode else {
            return;
        };
        self.assign_pipe(rt, run, d);
        self.metrics.counter("server_decode_jobs").inc();
        self.count_group_job(rt, run, d);
        run.outstanding += 1;
        let engine = run.node_pipe[d]
            .map(|(role, k)| rt.engine_of(role, k))
            .unwrap_or(0);
        // The decode context is the prefill's prompt (same unit input):
        // payloads of completed deps are stable, so this reconstructs
        // exactly what the prefill engine ingested.
        let src = u.prefill.unwrap_or(d);
        let prompt = Self::inputs(rt, run, src);
        let osl = rt.osl_of(run.req.max_new_tokens, d);
        step.jobs.push(LlmJob {
            req: run.req.id,
            unit,
            engine,
            phase: LlmPhase::Decode { prompt, osl },
            temperature: run.req.temperature,
            enqueued: Instant::now(),
        });
    }

    /// Prefill finished: route the fused decode, charge the KV handoff
    /// on the contended clock when the two phases sit on different
    /// chassis, and start (or schedule) the decode phase.
    fn schedule_decode_after_hop(
        &mut self,
        rt: &DagRuntime,
        run: &mut ReqRun,
        unit: usize,
        end: Instant,
        step: &mut Step,
    ) {
        let u = &rt.units[unit];
        let (Some(p), Some(d)) = (u.prefill, u.decode) else {
            return;
        };
        self.assign_pipe(rt, run, d);
        // The fused decode is gated by its prefill — the KV edge is its
        // parent, same as the simulator's intra-unit dependency.
        run.dep_from[d] = p as i64;
        let from = Self::chassis_of(rt, run, p);
        let to = Self::chassis_of(rt, run, d);
        let mut delay_s = 0.0;
        if let (Some(f), Some(t)) = (from, to) {
            if f != t {
                let bytes = rt.hop_bytes(run.req.prompt.len(), Stage::LlmPrefill, d);
                run.kv_hop_bytes += bytes;
                delay_s = self.transfer_delay(rt, f, t, bytes, end);
            }
        }
        if delay_s > 1e-6 {
            if self.trace.is_some() {
                let (group, chassis) = Self::span_placement(rt, run, d);
                let t0 = self.span_time(end);
                self.emit(Span {
                    request: run.req.id,
                    node: d as i64,
                    kind: SpanKind::KvTransfer,
                    group,
                    chassis,
                    t_start: t0,
                    t_end: t0 + self.span_secs(delay_s),
                    parent: p as i64,
                    queue_wait: 0.0,
                });
            }
            self.timer_seq += 1;
            self.timers.push(Reverse(Timer {
                due: end + Duration::from_secs_f64(delay_s),
                seq: self.timer_seq,
                req: run.req.id,
                epoch: run.epoch,
                kind: TimerKind::KvArrived { unit },
            }));
        } else {
            self.dispatch_decode(rt, run, unit, step);
        }
    }

    /// One dependency edge into `node` is satisfied. `from` is the
    /// upstream node it came from: edges land in completion order, so
    /// the last write before the node dispatches is its gating edge
    /// ([`Span::parent`]) — the simulator applies the same rule.
    fn deliver_dep(
        &mut self,
        rt: &DagRuntime,
        run: &mut ReqRun,
        node: usize,
        from: i64,
        pool: &HostPool,
        step: &mut Step,
    ) {
        run.dep_from[node] = from;
        match rt.plan.bindings[node].stage {
            Stage::Cpu => {
                run.remaining[node] = run.remaining[node].saturating_sub(1);
                if run.remaining[node] == 0 {
                    self.dispatch_cpu(rt, run, node, pool);
                }
            }
            Stage::LlmPrefill | Stage::LlmDecode => {
                // Every LLM node is assigned a unit at runtime build;
                // a miss means the edge raced a teardown, so drop it.
                let Some(u) = rt.unit_of[node] else {
                    return;
                };
                run.unit_remaining[u] = run.unit_remaining[u].saturating_sub(1);
                if run.unit_remaining[u] == 0 && !run.unit_dispatched[u] {
                    self.dispatch_unit(rt, run, u, step);
                }
            }
        }
    }

    /// Node finished: record its span, release its pipe slot, and
    /// propagate to successors — cross-chassis pipeline → pipeline
    /// edges pay a contended-clock transfer, exactly as the simulator
    /// prices them.
    #[allow(clippy::too_many_arguments)]
    fn complete_node(
        &mut self,
        rt: &DagRuntime,
        run: &mut ReqRun,
        node: usize,
        end: Instant,
        span: StageSpan,
        pool: &HostPool,
        step: &mut Step,
    ) {
        if run.node_done[node] {
            return;
        }
        run.node_done[node] = true;
        self.stage_hist[node].record_secs(span.duration_s());
        run.stages[node] = Some(span);
        if end > run.last_done {
            run.last_done = end;
        }
        run.nodes_left -= 1;
        if let Some((role, k)) = run.node_pipe[node] {
            if !run.pipe_released[node] {
                run.pipe_released[node] = true;
                match role {
                    Role::Prefill => {
                        self.prefill_load[k] = self.prefill_load[k].saturating_sub(1)
                    }
                    Role::Decode => {
                        self.decode_load[k] = self.decode_load[k].saturating_sub(1)
                    }
                }
            }
        }
        let from_chassis = Self::chassis_of(rt, run, node);
        let from_stage = rt.plan.bindings[node].stage;
        for &v in &rt.topo.succ[node] {
            if run.failed.is_some() {
                break;
            }
            // Intra-unit edges (prefill → its fused decode) are the KV
            // handoff `schedule_decode_after_hop` charges — nothing to
            // deliver through the dependency machinery.
            if rt.unit_of[node].is_some() && rt.unit_of[node] == rt.unit_of[v] {
                continue;
            }
            let to_binding = &rt.plan.bindings[v];
            let mut delay_s = 0.0;
            // Pipeline → pipeline edges pay the modeled fabric hop;
            // host stages ingest as part of their profiled latency.
            if let Some(from_ch) = from_chassis.filter(|_| to_binding.stage != Stage::Cpu) {
                self.assign_pipe(rt, run, v);
                if let Some(to_chassis) = Self::chassis_of(rt, run, v) {
                    if from_ch != to_chassis {
                        let bytes = rt.hop_bytes(run.req.prompt.len(), from_stage, v);
                        // Every cross-chassis pipeline edge counts —
                        // the same definition as the simulator's
                        // kv_bytes_moved, so the conformance suite can
                        // equate the two byte streams exactly.
                        run.kv_hop_bytes += bytes;
                        delay_s = self.transfer_delay(rt, from_ch, to_chassis, bytes, end);
                    }
                }
            }
            if delay_s > 1e-6 {
                if self.trace.is_some() {
                    let (group, chassis) = Self::span_placement(rt, run, v);
                    let t0 = self.span_time(end);
                    self.emit(Span {
                        request: run.req.id,
                        node: v as i64,
                        kind: SpanKind::KvTransfer,
                        group,
                        chassis,
                        t_start: t0,
                        t_end: t0 + self.span_secs(delay_s),
                        parent: node as i64,
                        queue_wait: 0.0,
                    });
                }
                self.timer_seq += 1;
                self.timers.push(Reverse(Timer {
                    due: end + Duration::from_secs_f64(delay_s),
                    seq: self.timer_seq,
                    req: run.req.id,
                    epoch: run.epoch,
                    kind: TimerKind::Dep { node: v, from: node as i64 },
                }));
            } else {
                self.deliver_dep(rt, run, v, node as i64, pool, step);
            }
        }
    }
}

/// Build the final response for a fully-executed request.
fn finalize(run: ReqRun) -> ChatResponse {
    let e2e = run.last_done.duration_since(run.submitted).as_secs_f64();
    let ttft = match run.first_token {
        Some(ft) => ft.duration_since(run.submitted).as_secs_f64(),
        // No decode stages: time to completion (the simulator's rule).
        None => e2e,
    };
    let tbt = if run.tbt_n > 0 {
        run.tbt_sum_s / run.tbt_n as f64
    } else {
        0.0
    };
    let mut stages: Vec<StageSpan> = run.stages.into_iter().flatten().collect();
    stages.sort_by(|a, b| {
        a.start_s
            .partial_cmp(&b.start_s)
            .unwrap_or(std::cmp::Ordering::Equal)
    });
    ChatResponse {
        id: run.req.id,
        output: run.output,
        ttft_s: ttft,
        tbt_mean_s: tbt,
        e2e_s: e2e,
        tokens: run.tokens,
        rejected: false,
        failed: false,
        error: None,
        stages,
        kv_hop_bytes: run.kv_hop_bytes,
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)]
mod tests {
    use super::*;
    use crate::plan::tests::tiny_plan;

    #[test]
    fn runtime_derives_units_pipes_and_engine_map() {
        let plan = tiny_plan();
        let rt = DagRuntime::new(&plan, 1.0, 2).unwrap();
        assert_eq!(rt.topo.len(), 4);
        assert_eq!(rt.units.len(), 1);
        assert_eq!(rt.unit_ext_edges, vec![1]); // cpu input → prefill
        assert_eq!(rt.prefill_pipes.len(), 1);
        assert_eq!(rt.decode_pipes.len(), 2); // 2 replicas expanded
        assert_eq!(rt.decode_pipes[0].chassis, 1);
        assert_eq!(rt.decode_pipes[1].chassis, 2);
        // Group → engine binding: prefill group 0 → engine 0, decode
        // group 1 → engine 1; both decode replicas share their group's
        // engine.
        assert_eq!(rt.prefill_pipes[0].group, 0);
        assert_eq!(rt.decode_pipes[0].group, 1);
        assert_eq!(rt.decode_pipes[1].group, 1);
        assert_eq!(rt.engine_of_group, vec![0, 1]);
        assert_eq!(rt.engine_of(Role::Prefill, 0), 0);
        assert_eq!(rt.engine_of(Role::Decode, 0), 1);
        assert_eq!(rt.engine_of(Role::Decode, 1), 1);
    }

    #[test]
    fn single_engine_pool_hosts_every_group() {
        let plan = tiny_plan();
        let rt = DagRuntime::new(&plan, 1.0, 1).unwrap();
        assert_eq!(rt.engine_of_group, vec![0, 0]);
        assert_eq!(rt.engine_of(Role::Prefill, 0), 0);
        assert_eq!(rt.engine_of(Role::Decode, 1), 0);
    }

    #[test]
    fn runtime_rejects_unknown_model() {
        let mut plan = tiny_plan();
        plan.model = "unknown-model".into();
        assert!(DagRuntime::new(&plan, 1.0, 1).is_err());
    }

    #[test]
    fn osl_scales_with_token_fraction() {
        let mut plan = tiny_plan();
        plan.bindings[2].token_fraction = 0.5;
        let rt = DagRuntime::new(&plan, 1.0, 1).unwrap();
        assert_eq!(rt.osl_of(24, 2), 12);
        assert_eq!(rt.osl_of(1, 2), 1, "floors at one token");
        assert_eq!(rt.isl_of(100, 2), 50);
    }

    #[test]
    fn host_payload_is_deterministic_and_input_sensitive() {
        let a = host_payload("tool.search", b"query one");
        let b = host_payload("tool.search", b"query one");
        assert_eq!(a, b, "same op + input must digest identically");
        let c = host_payload("tool.search", b"query two");
        assert_ne!(a, c, "payloads must depend on the input bytes");
        let d = host_payload("tool.lookup", b"query one");
        assert_ne!(a, d, "payloads must depend on the op");
        assert!(String::from_utf8(a).unwrap().starts_with("tool.search#"));
    }

    #[test]
    fn hop_bytes_prices_kv_for_prefill_decode_edges() {
        use crate::cost::kv::kv_cache_bytes;
        use crate::cost::model_profile::llama3_8b;
        use crate::cost::Precision;

        let plan = tiny_plan();
        let rt = DagRuntime::new(&plan, 1.0, 1).unwrap();
        let m = llama3_8b(Precision::Fp16);
        let kv = rt.hop_bytes(64, Stage::LlmPrefill, 2); // → llm.decode
        assert!((kv - kv_cache_bytes(&m, 64, 1)).abs() < 1e-6);
        // A non-KV edge carries the plan's estimate.
        let est = rt.hop_bytes(64, Stage::LlmDecode, 2);
        assert_eq!(est, plan.bindings[2].xfer_bytes);
    }
}
